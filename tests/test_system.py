"""System tests: end-to-end trainer, checkpoint/restart, fault tolerance,
data pipeline, serving engine — the substrate layers working together."""
import os
import shutil
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import DataConfig, SyntheticTokenStream
from repro.models import transformer as tfm
from repro.optim import OptConfig
from repro.serving import DecodeEngine, ServeConfig
from repro.train import (CheckpointManager, PreemptionGuard, StepMonitor,
                         Trainer, TrainerConfig)


def tiny_cfg():
    return configs.reduce(configs.get("qwen2-0.5b"))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


class TestData:
    def test_deterministic_across_instances(self):
        cfg = DataConfig(vocab=128, seq_len=32, global_batch=4, seed=7)
        a = SyntheticTokenStream(cfg).next_host_batch()
        b = SyntheticTokenStream(cfg).next_host_batch()
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_resume_is_exact(self):
        cfg = DataConfig(vocab=128, seq_len=32, global_batch=4, seed=3)
        s1 = SyntheticTokenStream(cfg)
        batches = [s1.next_host_batch() for _ in range(4)]
        s2 = SyntheticTokenStream(cfg)
        s2.restore({"step": 2, "seed": 3})
        np.testing.assert_array_equal(s2.next_host_batch()["tokens"],
                                      batches[2]["tokens"])

    def test_shard_rows_independent(self):
        """Any row range regenerates identically (elastic workers)."""
        cfg = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=1)
        s = SyntheticTokenStream(cfg)
        full = s.batch_rows(5, 0, 8)
        part = s.batch_rows(5, 3, 6)
        np.testing.assert_array_equal(full["tokens"][3:6], part["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab=128, seq_len=16, global_batch=2, seed=1)
        b = SyntheticTokenStream(cfg).next_host_batch()
        assert b["tokens"].shape == (2, 16)
        assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()

    def test_learnable_signal(self):
        """The Markov structure bounds each token's successor set."""
        cfg = DataConfig(vocab=64, seq_len=256, global_batch=8, seed=0,
                         branch=2, noise=0.0)
        b = SyntheticTokenStream(cfg).next_host_batch()
        succ = {}
        for row in b["tokens"]:
            for t in range(len(row) - 1):
                succ.setdefault(int(row[t]), set()).add(int(row[t + 1]))
        assert max(len(v) for v in succ.values()) <= 2


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


class TestCheckpoint:
    def test_roundtrip_and_keep_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.int32)}}
        for step in (1, 2, 3):
            mgr.save(step, tree, extra={"data_state": {"step": step,
                                                       "seed": 0}})
        assert mgr.steps() == [2, 3]          # keep-k pruned step 1
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        restored, meta = mgr.restore(template)
        assert meta["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                      np.asarray(tree["b"]["c"]))

    def test_no_tmp_dirs_after_commit(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(1, {"x": jnp.zeros((2,))})
        leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]
        assert leftovers == []

    def test_missing_leaf_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.zeros((2,))})
        template = {"x": jax.ShapeDtypeStruct((2,), jnp.float32),
                    "y": jax.ShapeDtypeStruct((2,), jnp.float32)}
        with pytest.raises(KeyError):
            mgr.restore(template)

    def test_shape_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.zeros((2,))})
        with pytest.raises(ValueError):
            mgr.restore({"x": jax.ShapeDtypeStruct((3,), jnp.float32)})

    def test_elastic_mesh_restore(self, tmp_path):
        """Spec-tagged save restores onto a (1,1)-mesh with filtered axes."""
        from jax.sharding import PartitionSpec as P
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
        mgr.save(1, tree, spec_tree={"w": P("data", "model")})
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        restored, _ = mgr.restore(
            {"w": jax.ShapeDtypeStruct((4, 8), jnp.float32)}, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# trainer end-to-end
# ---------------------------------------------------------------------------


class TestTrainer:
    def _tcfg(self, tmp, **kw):
        d = dict(steps=4, ckpt_dir=str(tmp), ckpt_every=2, log_every=10,
                 seq_len=32, global_batch=2)
        d.update(kw)
        return TrainerConfig(**d)

    def test_train_checkpoint_resume(self, tmp_path):
        cfg = tiny_cfg()
        opt = OptConfig(warmup=1, total_steps=4)
        t1 = Trainer(cfg, opt, self._tcfg(tmp_path), log_fn=lambda s: None)
        s1 = t1.run()
        assert int(jax.device_get(s1.step)) == 4
        assert t1.ckpt.steps() == [2, 4]
        # resume: restores step 4, no further steps executed
        t2 = Trainer(cfg, opt, self._tcfg(tmp_path), log_fn=lambda s: None)
        s2 = t2.run()
        assert int(jax.device_get(s2.step)) == 4
        for a, b in zip(jax.tree.leaves(s1.master),
                        jax.tree.leaves(s2.master)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_losses_finite_and_stable(self, tmp_path):
        cfg = tiny_cfg()
        tcfg = self._tcfg(tmp_path, steps=8, ckpt_every=100)
        t = Trainer(cfg, OptConfig(lr_peak=3e-3, warmup=2, total_steps=8),
                    tcfg, log_fn=lambda s: None)
        t.run()
        losses = [h["loss"] for h in t.history]
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0] + 0.1   # not diverging

    def test_microbatch_matches_full_batch(self, tmp_path):
        """Gradient accumulation = exact full-batch mean: same losses."""
        cfg = tiny_cfg()
        opt = OptConfig(warmup=1, total_steps=3)
        t_full = Trainer(cfg, opt, self._tcfg(
            tmp_path / "a", steps=3, ckpt_every=100, global_batch=4),
            log_fn=lambda s: None)
        t_full.run()
        t_micro = Trainer(cfg, opt, self._tcfg(
            tmp_path / "b", steps=3, ckpt_every=100, global_batch=4,
            microbatch=2), log_fn=lambda s: None)
        t_micro.run()
        for a, b in zip(t_full.history, t_micro.history):
            np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-4)

    def test_grad_compression_path(self, tmp_path):
        cfg = tiny_cfg()
        tcfg = self._tcfg(tmp_path, steps=2, ckpt_every=100,
                          grad_compression=10)
        t = Trainer(cfg, OptConfig(warmup=1, total_steps=2), tcfg,
                    log_fn=lambda s: None)
        t.run()
        assert len(t.history) == 2
        assert all(np.isfinite(h["loss"]) for h in t.history)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


class TestFault:
    def test_preemption_guard_catches_sigterm(self):
        with PreemptionGuard() as guard:
            os.kill(os.getpid(), signal.SIGTERM)
            assert guard.fired

    def test_preemption_guard_restores_handler(self):
        prev = signal.getsignal(signal.SIGTERM)
        with PreemptionGuard():
            pass
        assert signal.getsignal(signal.SIGTERM) is prev

    def test_straggler_detection(self):
        mon = StepMonitor(alpha=0.5, threshold=1.5, trip_limit=2, warmup=0)
        # feed synthetic step times through the monitor's clock
        times = iter([0.0, 0.1,    # step 0 (0.1s, sets EWMA)
                      0.2, 0.3,    # step 1 (0.1s)
                      0.4, 0.9,    # step 2 (0.5s -> straggler)
                      1.0, 1.6])   # step 3 (0.6s -> straggler)
        import repro.train.fault as fault
        orig = fault.time.perf_counter
        fault.time.perf_counter = lambda: next(times)
        try:
            events = []
            for i in range(4):
                mon.start()
                ev = mon.stop(i)
                if ev:
                    events.append(ev)
            assert len(events) == 2
            assert mon.exclusion_recommended
        finally:
            fault.time.perf_counter = orig


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


class TestServing:
    @pytest.fixture(scope="class")
    def engine_setup(self):
        cfg = tiny_cfg()
        params, _ = tfm.init_params(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def test_continuous_batching_drains_queue(self, engine_setup):
        cfg, params = engine_setup
        eng = DecodeEngine(cfg, params, ServeConfig(slots=2, max_len=48))
        rng = np.random.default_rng(0)
        reqs = [eng.submit(rng.integers(1, cfg.vocab, size=5),
                           max_new_tokens=4) for _ in range(5)]
        done = eng.run()
        assert len(done) == 5
        for r in reqs:
            assert len(r.out_tokens) == 4
            assert r.t_done >= r.t_first >= r.t_submit

    def test_greedy_matches_manual_decode(self, engine_setup):
        """Engine greedy decode == prefill + manual forward_decode chain."""
        cfg, params = engine_setup
        prompt = np.arange(1, 7, dtype=np.int32)
        eng = DecodeEngine(cfg, params, ServeConfig(slots=1, max_len=32))
        eng.submit(prompt, max_new_tokens=3)
        done = eng.run()
        got = done[0].out_tokens

        logits, cache = jax.jit(
            lambda p, b: tfm.forward_prefill(cfg, p, b, 32))(
                params, {"tokens": jnp.asarray(prompt[None, :])})
        want = [int(jnp.argmax(logits[0, -1]))]
        tok = jnp.asarray([[want[0]]], jnp.int32)
        for _ in range(2):
            logits, cache = jax.jit(
                lambda p, t, c: tfm.forward_decode(cfg, p, t, c))(
                    params, tok, cache)
            nxt = int(jnp.argmax(logits[0, -1]))
            want.append(nxt)
            tok = jnp.asarray([[nxt]], jnp.int32)
        assert got == want

    def test_warmup_with_precision_store(self, engine_setup, tmp_path,
                                         caplog):
        """warmup(precision_store=...) logs auto-selected layer codecs and
        restores (sb, wb) retile winners into the layer plans."""
        import logging

        from repro.models.sparse_linear import PackSELLLinear
        from repro.precision import PrecisionStore

        cfg, params = engine_setup
        eng = DecodeEngine(cfg, params, ServeConfig(slots=1, max_len=32))
        w = np.random.default_rng(0).standard_normal((48, 32)) \
            .astype(np.float32)
        path = str(tmp_path / "prec.json")
        lin = PackSELLLinear.from_dense(w, density=0.4, codec="auto",
                                        error_budget=1e-3, store=path,
                                        C=8, sigma=32)
        st = PrecisionStore(path)
        tiles = [(4, 16)] * len(lin.plan.tiles)
        st.put_retile(lin.fingerprint,
                      f"plan_{lin.mat.codec_name}{lin.mat.D}", tiles)
        with caplog.at_level(logging.INFO, logger="repro.serving.engine"):
            eng.warmup(sparse_layers=[lin], precision_store=path)
        msgs = " ".join(r.getMessage() for r in caplog.records)
        assert "auto-selected" in msgs
        assert "retiled from store" in msgs
        assert lin.plan.tiles == tuple(tiles)

    def test_eos_terminates(self, engine_setup):
        cfg, params = engine_setup
        # find the first greedy token, then make it the EOS
        eng0 = DecodeEngine(cfg, params, ServeConfig(slots=1, max_len=32))
        eng0.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=1)
        first = eng0.run()[0].out_tokens[0]
        eng = DecodeEngine(cfg, params,
                           ServeConfig(slots=1, max_len=32, eos_id=first))
        req = eng.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=8)
        eng.run()
        assert req.out_tokens[-1] == first
        assert len(req.out_tokens) == 1
