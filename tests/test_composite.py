"""CompositePlan: the shared block-composition engine (DESIGN.md §9).

Covers: composite-vs-dense-oracle equivalence (members, terms, fp32/fp64
SELL blocks, spmm), SpMVPlan as the single-member case, unified memory
accounting, retile plumbing, the consolidated kind-string parser, the
WarmupSpec path, and — multi-device gated, run by ``make verify-composite``
— the dist_mixed operator plus ``adaptive_pcg_dist`` iteration parity
against single-device ``adaptive_pcg`` (the acceptance criterion).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import codecs as cd
from repro.core import packsell, sell, testmats
from repro.kernels import composite as kc
from repro.kernels import plan as kplan
from repro.precision import PrecisionClass, PrecisionPlan
from repro.solvers import cg
from repro.solvers import operators as op
from repro.solvers.operators import parse_kind

NDEV = jax.device_count()
RNG = np.random.default_rng(21)

need4 = pytest.mark.skipif(NDEV < 4, reason="needs >=4 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=4)")


def _x(m, seed=0):
    return np.random.default_rng(seed).standard_normal(m).astype(np.float32)


def _quantized_dense(a, classes):
    """Dense oracle: each class's rows quantized at its codec."""
    dense = a.toarray().astype(np.float64)
    out = np.zeros_like(dense)
    for codec, D, rows in classes:
        rows = np.arange(a.shape[0]) if rows is None else np.asarray(rows)
        if codec in ("fp32", "fp64"):
            out[rows] = dense[rows].astype(
                np.float32 if codec == "fp32" else np.float64)
        else:
            out[rows] = cd.quantize_np(
                dense[rows].astype(np.float32), cd.make_codec(codec), D)
    return out


def _random_classes(n, rng, codec_pool):
    """Random row partition into 1..4 classes (empty classes allowed)."""
    k = int(rng.integers(1, 5))
    assign = rng.integers(0, k, size=n)
    classes = []
    for c in range(k):
        rows = np.nonzero(assign == c)[0]
        codec, D = codec_pool[int(rng.integers(0, len(codec_pool)))]
        if len(rows):
            classes.append((codec, D, rows))
    # make sure every row is covered even if a class came up empty
    covered = np.concatenate([c[2] for c in classes])
    missing = np.setdiff1d(np.arange(n), covered)
    if len(missing):
        classes.append(("fp32", 0, missing))
    return classes


CODEC_POOL = [("e8m", 8), ("e8m", 12), ("fp16", 15), ("bf16", 15),
              ("fp32", 0)]


# ---------------------------------------------------------------------------
# composite vs dense oracle (single device)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_composite_matches_quantized_dense_oracle(seed):
    rng = np.random.default_rng(seed)
    a = testmats.powerlaw(300, mean_deg=5, seed=seed)
    classes = _random_classes(300, rng, CODEC_POOL)
    cp = kc.CompositePlan.from_classes(a, classes, C=8, sigma=32)
    x = _x(300, seed=seed + 1)
    y = np.asarray(cp.spmv(jnp.asarray(x)), np.float64)
    want = _quantized_dense(a, classes) @ x.astype(np.float64)
    np.testing.assert_allclose(y, want, rtol=0,
                               atol=2e-5 * max(np.abs(want).max(), 1))


def test_composite_spmm_matches_stacked_spmv():
    a = testmats.random_banded(200, 12, 4, seed=3)
    classes = [("e8m", 8, np.arange(0, 120)), ("fp32", 0, np.arange(120,
                                                                    200))]
    cp = kc.CompositePlan.from_classes(a, classes, C=8, sigma=32)
    X = RNG.standard_normal((200, 3)).astype(np.float32)
    Y = np.asarray(cp.spmm(jnp.asarray(X)))
    for j in range(3):
        np.testing.assert_allclose(
            Y[:, j], np.asarray(cp.spmv(jnp.asarray(X[:, j]))),
            rtol=1e-6, atol=1e-6)


def test_composite_two_terms_sum():
    """Terms ADD (the distributed local/remote composition): splitting a
    matrix column-wise into two members on separate terms reproduces the
    full product."""
    a = testmats.random_banded(96, 8, 3, seed=4).tocsr()
    lo = a.copy()
    lo[:, 48:] = 0
    lo.eliminate_zeros()
    hi = a.copy()
    hi[:, :48] = 0
    hi.eliminate_zeros()
    m0 = kc.member_from_csr(lo.tocsr(), "fp32", 0, C=8, sigma=16, term=0)
    m1 = kc.member_from_csr(hi.tocsr(), "fp32", 0, C=8, sigma=16, term=1)
    cp = kc.CompositePlan([m0, m1], n=96, m=96)
    x = _x(96, seed=5)
    y = np.asarray(cp.spmv(jnp.asarray(x)), np.float64)
    want = (a.toarray().astype(np.float32).astype(np.float64)
            @ x.astype(np.float64))
    np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)


def test_single_member_composite_matches_plan_engine():
    """SpMVPlan is the single-member case of the composition engine."""
    a = testmats.scattered(256, nnz_per_row=6, spd=True, seed=6)
    mat = packsell.from_csr(a, C=8, sigma=32, D=8, codec="e8m")
    plan = kplan.get_plan(mat)
    cp = plan.as_composite(mat)
    assert len(cp.members) == 1 and cp.n_terms == 1
    x = _x(256, seed=7)
    np.testing.assert_array_equal(
        np.asarray(cp.spmv(jnp.asarray(x))),
        np.asarray(plan.spmv(mat, jnp.asarray(x))))
    s = sell.from_csr(a, C=8, sigma=32, value_dtype="float32")
    cps = kc.CompositePlan.single(s)
    np.testing.assert_allclose(
        np.asarray(cps.spmv(jnp.asarray(x))),
        np.asarray(sell.sell_spmv_jnp(s, jnp.asarray(x))),
        rtol=1e-6, atol=1e-6)


def test_composite_rejects_overlap_and_uncovered():
    a = testmats.random_banded(64, 4, 3, seed=1)
    with pytest.raises(ValueError, match="cover"):
        kc.CompositePlan.from_classes(
            a, [("e8m", 8, np.arange(10))], C=8, sigma=16)
    with pytest.raises(ValueError, match="overlap"):
        kc.CompositePlan.from_classes(
            a, [("e8m", 8, np.arange(40)),
                ("fp32", 0, np.arange(30, 64))], C=8, sigma=16)


def test_composite_memory_stats_and_describe():
    a = testmats.powerlaw(200, mean_deg=4, seed=8)
    classes = [("e8m", 8, np.arange(0, 100)), ("fp32", 0,
                                               np.arange(100, 200))]
    cp = kc.CompositePlan.from_classes(a, classes, C=8, sigma=32)
    st = cp.memory_stats()
    assert st["composite_bytes"] == sum(m["bytes"] for m in st["members"])
    assert st["nnz"] == sum(m["nnz"] for m in st["members"]) == a.nnz
    d = cp.describe()
    assert d["terms"] == 1 and len(d["members"]) == 2
    assert d["members"][0]["fmt"] == "packsell"
    assert d["members"][1]["fmt"] == "sell"


def test_composite_retile_plumbing():
    a = testmats.random_banded(128, 8, 3, seed=9)
    cp = kc.CompositePlan.from_classes(a, [("fp16", 15, None)], C=8,
                                       sigma=32)
    x = jnp.asarray(_x(128, seed=10))
    y0 = np.asarray(cp.spmv(x))
    cp.retile(0, [(4, 16)] * len(cp.members[0].plan.tiles))
    assert cp.members[0].plan.tiles[0] == (4, 16)
    np.testing.assert_array_equal(np.asarray(cp.spmv(x)), y0)
    with pytest.raises(ValueError, match="SELL"):
        kc.CompositePlan.from_classes(
            a, [("fp32", 0, None)], C=8, sigma=32).retile(0, [])


# ---------------------------------------------------------------------------
# kind-string parsing (satellite: one parser, informative errors)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,family,codec,D,budget", [
    ("fp64", "dense", "fp64", None, None),
    ("csr64", "csr64", None, None, None),
    ("packsell_e8m8", "packsell", "e8m", 8, None),
    ("plan_fp16", "plan", "fp16", 15, None),
    ("dist_bf16", "dist", "bf16", 15, None),
    ("auto:1e-3", "auto", None, None, 1e-3),
    ("mixed:0.01", "mixed", None, None, 0.01),
    ("dist_auto:1e-4", "dist_auto", None, None, 1e-4),
    ("dist_mixed:1e-3", "dist_mixed", None, None, 1e-3),
])
def test_parse_kind_valid(kind, family, codec, D, budget):
    spec = parse_kind(kind)
    assert spec.family == family
    assert spec.codec == codec
    assert spec.D == D
    assert spec.budget == budget
    assert spec.distributed == family.startswith("dist")


@pytest.mark.parametrize("bad", [
    "frobnicate", "plan_", "plan_e8mx", "plan_e8m", "packsell_fp64",
    "dist_fp32", "auto:", "auto:banana", "mixed:-1", "mixed:0",
    "dist_mixed:", "plan_fp16_extra", 42,
])
def test_parse_kind_malformed_lists_valid_kinds(bad):
    with pytest.raises(ValueError, match="valid kinds"):
        parse_kind(bad)


def test_operator_set_rejects_malformed_kinds():
    a = testmats.random_banded(32, 3, 2, seed=0)
    s, _ = op.sym_scale(a)
    ops = op.OperatorSet(s, C=8, sigma=16)
    with pytest.raises(ValueError, match="valid kinds"):
        ops.matvec("plan_e9m8")
    with pytest.raises(ValueError, match="plan_"):
        ops.plan_pair("dist_fp16")
    with pytest.raises(ValueError, match="dist"):
        ops.dist_plan("plan_fp16")


# ---------------------------------------------------------------------------
# dist composite (device-free reference replay)
# ---------------------------------------------------------------------------

def test_dist_mixed_reference_matches_oracle():
    from repro.distributed import build_composite_operands, reference_spmv

    a = testmats.powerlaw(150, mean_deg=5, seed=11)
    classes = [("e8m", 8, np.arange(0, 70)), ("fp32", 0,
                                              np.arange(70, 150))]
    ops = build_composite_operands(a, 3, classes=classes, C=8, sigma=16)
    assert len(ops.members) in (2, 4)       # per side, per class
    x = _x(150, seed=12)
    y = reference_spmv(ops, x)
    want = _quantized_dense(a, classes) @ x.astype(np.float64)
    np.testing.assert_allclose(y, want, rtol=0,
                               atol=2e-5 * max(np.abs(want).max(), 1))


def test_per_shard_selection_coalesces_to_feasible_fleet_codec():
    """dist_auto coalescing: a shard's pick can be range-infeasible on
    another shard (fp16 overflow) — the fleet pick must be certified on
    EVERY shard, and probe_error must not report a perfect probe for an
    overflowing codec (nan-poisoning regression)."""
    from repro.precision import analyze as an
    from repro.precision.store import select_codec_per_shard

    n = 64
    rng = np.random.default_rng(0)
    a = sp.random(n, n, density=0.2, random_state=rng,
                  data_rvs=lambda k: rng.standard_normal(k)).tocsr()
    a = a.tolil()
    a[:n // 2, :] = a[:n // 2, :] * 1e5     # fp16 max is 65504: overflows
    a = a.tocsr()
    assert an.probe_error(a, "fp16", 15) == float("inf")
    plans, fleet = select_codec_per_shard(
        a, 2, 1e-2, candidates=(("fp16", 15), ("bf16", 15)))
    picks = {p.primary.codec for p in plans if p is not None}
    assert "fp16" in picks                  # the well-ranged shard's pick
    assert fleet.codec == "bf16"            # feasible on every shard


def test_per_shard_selection_records_shard_fingerprints(tmp_path):
    """The store keys of per-shard selection are the shard fingerprints —
    a repartition-stable restart hits the same entries."""
    from repro.precision import PrecisionStore
    from repro.precision.store import (select_codec_per_shard,
                                       shard_fingerprints)

    a = testmats.random_banded(96, 6, 3, seed=18)
    store = PrecisionStore(tmp_path / "store.json")
    plans, fleet = select_codec_per_shard(a, 3, 1e-2, store=store,
                                          n_probes=2)
    fps = shard_fingerprints(a, 3)
    assert all(fp in store for fp in fps)
    assert fleet.codec is not None
    # second run is a pure store hit: identical plans come back
    plans2, fleet2 = select_codec_per_shard(a, 3, 1e-2, store=store,
                                            n_probes=2)
    assert [p.primary.label for p in plans2] == \
        [p.primary.label for p in plans]
    assert (fleet2.codec, fleet2.D) == (fleet.codec, fleet.D)


def test_dist_classes_must_partition_rows():
    from repro.distributed import build_composite_operands

    a = testmats.random_banded(40, 4, 2, seed=13)
    with pytest.raises(ValueError, match="partition"):
        build_composite_operands(a, 2, classes=[("e8m", 8,
                                                 np.arange(10))],
                                 C=8, sigma=16)


# ---------------------------------------------------------------------------
# WarmupSpec (satellite: consolidated warmup surface)
# ---------------------------------------------------------------------------

def test_warmup_spec_composites_and_backcompat():
    from repro import configs
    from repro.models import transformer as tfm
    from repro.serving import DecodeEngine, ServeConfig, WarmupSpec

    cfg = configs.reduce(configs.get("qwen2-0.5b"))
    params, _ = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, ServeConfig(slots=1, max_len=32))
    a = testmats.random_banded(64, 4, 3, seed=14)
    cp = kc.CompositePlan.from_classes(a, [("fp16", 15, None)], C=8,
                                       sigma=16)
    eng.warmup(WarmupSpec(composites=(cp,), nb=2))
    assert True in cp._fns and False in cp._fns   # spmv + spmm traced
    eng.warmup()                                  # back-compat: bare call
    with pytest.raises(ValueError, match="not both"):
        eng.warmup(WarmupSpec(), composites=(cp,))


# ---------------------------------------------------------------------------
# dist_mixed × adaptive_pcg_dist (the acceptance criterion; mesh-gated)
# ---------------------------------------------------------------------------

@need4
def test_dist_mixed_operator_matches_mixed_on_4_devices():
    a = testmats.powerlaw(240, mean_deg=5, seed=15)
    a = (a + a.T + sp.eye(240)).tocsr()
    s, _ = op.sym_scale(a)
    ops = op.OperatorSet(s, C=8, sigma=16)
    x = jnp.asarray(_x(240, seed=16))
    y_mixed = np.asarray(ops.matvec("mixed:1e-3")(x))
    y_dist = np.asarray(ops.matvec("dist_mixed:1e-3")(x))
    np.testing.assert_allclose(y_dist, y_mixed, rtol=2e-5, atol=2e-5)
    dp = ops.dist_plan("dist_mixed:1e-3")
    assert dp.n_shards == min(NDEV, 4) or dp.n_shards == NDEV
    st = dp.memory_stats()
    assert st["composite_bytes"] == sum(m["bytes"] for m in st["members"])


@need4
def test_adaptive_pcg_dist_matches_single_device():
    """dist_mixed budget → adaptive_pcg_dist on 4 devices: ≤1e-8 true
    relative residual, iteration counts identical to single-device
    adaptive_pcg."""
    a = testmats.hpcg(8, 8, 8)
    s, _ = op.sym_scale(a)
    ops = op.OperatorSet(s, C=32, sigma=64)
    budget = 1e-3
    b = jnp.asarray(RNG.standard_normal(s.shape[0]))
    d = s.diagonal()

    mvs, labels, sub32, hi = ops.adaptive_tiers(budget)
    dinv = jnp.where(d == 0, 1.0, 1.0 / d)
    x1, i1 = cg.adaptive_pcg(mvs, b, M=lambda r: r * dinv, matvec_hi=hi,
                             tol=1e-8, maxiter=60, m_in=16,
                             dtype=jnp.float64)

    # the dist_mixed operator kind is live on the same budget/matrix
    assert ops.matvec(f"dist_mixed:{budget}") is not None
    ladder = ops.dist_adaptive_tiers(budget, n_shards=4)
    assert ladder.labels == labels
    xd, idd = cg.adaptive_pcg_dist(ladder, d, b, tol=1e-8, maxiter=60,
                                   m_in=16, dtype=jnp.float64)

    # ≤ 1e-8 TRUE relative residual
    r = np.asarray(s @ np.asarray(xd, np.float64)) - np.asarray(
        b, np.float64)
    true_rel = np.linalg.norm(r) / np.linalg.norm(np.asarray(b))
    assert true_rel <= 1e-8
    # iteration counts and promotion schedule identical
    assert int(idd.iters) == int(i1.iters)
    k = int(i1.iters)
    np.testing.assert_array_equal(np.asarray(idd.tier_history[:k]),
                                  np.asarray(i1.tier_history[:k]))
    # the solve actually ran sub-32-bit inner matvecs
    assert int(np.asarray(idd.tier_matvecs)[np.asarray(ladder.sub32)]
               .sum()) > 0
    np.testing.assert_allclose(np.asarray(xd), np.asarray(x1),
                               rtol=1e-4, atol=1e-8)


@need4
def test_dist_auto_selects_and_runs():
    a = testmats.hpcg(6, 6, 6)
    s, _ = op.sym_scale(a)
    ops = op.OperatorSet(s, C=8, sigma=16)
    x = jnp.asarray(_x(s.shape[0], seed=17))
    y = np.asarray(ops.matvec("dist_auto:1e-3")(x), np.float64)
    want = np.asarray(s.astype(np.float64) @ np.asarray(x, np.float64))
    assert (np.max(np.abs(y - want)) / np.max(np.abs(want))) < 1e-3
