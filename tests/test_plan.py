"""SpMVPlan execution engine: cache semantics, fused scatter, σ-permutation
round-trip, multi-RHS kernel, and the explicit variant policy."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packsell, testmats
from repro.kernels import ops, ref
from repro.kernels import packsell_spmv as kpk
from repro.kernels import plan as kplan
from repro.solvers import cg

RNG = np.random.default_rng(42)


def _x(m):
    return jnp.asarray(RNG.standard_normal(m).astype(np.float32))


@pytest.fixture()
def banded_mat():
    a = testmats.random_banded(600, 30, 8, seed=1)
    return a, packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16")


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_plan_cache_hit_miss(banded_mat):
    _, mat = banded_mat
    kplan.clear_cache()
    p1 = kplan.get_plan(mat, sb=4, wb=8)
    assert kplan.cache_stats() == dict(hits=0, misses=1, evicted=0, size=1)
    p2 = kplan.get_plan(mat, sb=4, wb=8)
    assert p2 is p1
    assert kplan.cache_stats()["hits"] == 1
    # different tile parameters -> different plan
    p3 = kplan.get_plan(mat, sb=2, wb=4)
    assert p3 is not p1
    assert kplan.cache_stats()["misses"] == 2
    # different matrix -> different plan even with equal params
    a2 = testmats.random_banded(600, 30, 8, seed=2)
    mat2 = packsell.from_csr(a2, C=8, sigma=32, D=15, codec="fp16")
    p4 = kplan.get_plan(mat2, sb=4, wb=8)
    assert p4 is not p1
    assert kplan.cache_stats() == dict(hits=1, misses=3, evicted=0, size=3)


def test_plan_cache_token_survives_id_reuse():
    """Cache keys use a monotonically assigned per-matrix token, not
    ``id(mat)``: after GC recycles an address, the dead matrix's deferred
    weakref callback must not evict (or alias) the new matrix's plan."""
    import gc

    def make(seed):
        return packsell.from_csr(testmats.stencil_1d(150, 2, seed=seed),
                                 C=8, sigma=16, D=10, codec="e8m")

    kplan.clear_cache()
    mat = make(0)
    kplan.get_plan(mat)
    tok0 = mat._plan_token
    dead_id = id(mat)
    del mat
    gc.collect()
    assert kplan.cache_stats()["size"] == 0
    hit_reused_id = False
    for seed in range(1, 16):
        m2 = make(seed)
        hit_reused_id |= (id(m2) == dead_id)
        p2 = kplan.get_plan(m2)
        assert m2._plan_token != tok0          # tokens are never recycled
        gc.collect()                           # flush stale weakref drops
        assert kplan.get_plan(m2) is p2        # id reuse cannot evict/alias
        tok0 = m2._plan_token
        dead_id = id(m2)
        del m2, p2
        gc.collect()
    # CPython reuses freed addresses aggressively; the loop above almost
    # always exercises a genuine id collision, but correctness of the
    # assertions does not depend on it.


def test_plan_cache_evicts_on_matrix_death():
    kplan.clear_cache()
    a = testmats.stencil_1d(200, 2, seed=3)
    mat = packsell.from_csr(a, C=8, sigma=32, D=10, codec="e8m")
    kplan.get_plan(mat)
    assert kplan.cache_stats()["size"] == 1
    del mat
    import gc
    gc.collect()
    st = kplan.cache_stats()
    assert st["size"] == 0 and st["evicted"] == 1


def test_repeated_spmv_reuses_plan(banded_mat):
    a, mat = banded_mat
    kplan.clear_cache()
    x = _x(a.shape[1])
    y1 = ops.packsell_spmv(mat, x)
    y2 = ops.packsell_spmv(mat, x)
    st = kplan.cache_stats()
    assert st["misses"] == 1 and st["hits"] == 1
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


# ---------------------------------------------------------------------------
# fused scatter epilogue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("force", ["full", "jnp"])
def test_fused_scatter_matches_per_bucket_baseline(force):
    a = testmats.powerlaw(700, mean_deg=4, seed=5)   # pow2 -> several buckets
    mat = packsell.from_csr(a, C=8, sigma=64, D=6, codec="e8m")
    assert len(mat.packs) > 1, "test needs a multi-bucket matrix"
    x = _x(a.shape[1])
    # seed baseline: one full-length scatter per bucket
    y_base = jnp.zeros((mat.n,), jnp.float32)
    for pack, d0, outrow in zip(mat.packs, mat.d0s, mat.outrows):
        if force == "full":
            t = kpk.packsell_spmv_bucket(pack, d0, x, codec_name="e8m",
                                         D=6, sb=4, wb=8, interpret=True)
        else:
            t = packsell._bucket_spmv_scan(
                pack, d0, x, mat.codec, mat.D,
                np.int32(mat.m - 1), jnp.float32)
        y_base = y_base.at[outrow].set(t.reshape(-1), mode="drop")
    # the legacy decode-cache modes keep the baseline's accumulation order:
    # bit-for-bit — same bucket outputs, same scatter targets
    plan = kplan.build_plan(mat, sb=4, wb=8, force=force, decode_cache="0")
    np.testing.assert_array_equal(np.asarray(plan.spmv(mat, x)),
                                  np.asarray(y_base))
    # the default checkpoint decode reorders the accumulation (fused
    # ragged stream / grid-parallel width blocks): equal up to rounding
    y_fused = ops.packsell_spmv(mat, x, sb=4, wb=8, force=force)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_base),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# permuted fast path / σ-permutation round-trip
# ---------------------------------------------------------------------------

def test_permuted_fast_path_roundtrip(banded_mat):
    a, mat = banded_mat
    x = _x(a.shape[1])
    plan = kplan.get_plan(mat)
    y = ops.packsell_spmv(mat, x)
    y_stored = ops.packsell_spmv(mat, x, permuted=True)
    assert y_stored.shape == (plan.total_stored,)
    # scattering the stored-row output reproduces y bit-for-bit
    np.testing.assert_array_equal(np.asarray(plan.from_stored(y_stored)),
                                  np.asarray(y))
    # gather/scatter round-trip is the identity on original-order vectors
    v = _x(mat.n)
    np.testing.assert_array_equal(
        np.asarray(plan.from_stored(plan.to_stored(v))), np.asarray(v))
    # σ-padding slots are zero in stored space
    stored = np.asarray(plan.to_stored(v))
    pad = np.asarray(plan.outrow_cat) >= mat.n
    assert np.all(stored[pad] == 0)


def test_jacobi_pcg_stored_matches_original_order():
    a = testmats.stencil_3d(8, 8, 8, neighbours=27)
    from repro.solvers import operators as op
    s, _ = op.sym_scale(a)
    ops_set = op.OperatorSet(s, C=32, sigma=64)
    mat, plan = ops_set.plan_pair("plan_fp16")
    b = jnp.asarray(RNG.standard_normal(s.shape[0]).astype(np.float32))
    x_s, info_s = cg.jacobi_pcg_stored(mat, plan, s.diagonal(), b,
                                       tol=1e-5, maxiter=300,
                                       dtype=jnp.float32)
    diag = jnp.asarray(s.diagonal().astype(np.float32))
    x_o, info_o = cg.pcg(ops_set.matvec("plan_fp16"), b,
                         M=lambda r: r / diag, tol=1e-5, maxiter=300,
                         dtype=jnp.float32)
    assert int(info_s.iters) == int(info_o.iters)
    np.testing.assert_allclose(np.asarray(x_s), np.asarray(x_o),
                               rtol=1e-4, atol=1e-5)
    # true residual vs the *unquantized* matrix floors at the fp16 codec's
    # quantization error, not the solver tolerance
    r = np.asarray(b, np.float64) - s @ np.asarray(x_s, np.float64)
    assert np.linalg.norm(r) / np.linalg.norm(np.asarray(b)) < 1e-3


# ---------------------------------------------------------------------------
# multi-RHS kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec,D", [("fp16", 15), ("bf16", 15), ("e8m", 8),
                                     ("fixed16", 10)])
def test_spmm_bucket_vs_jnp_oracle(codec, D):
    a = testmats.random_banded(500, 25, 7, seed=6)
    mat = packsell.from_csr(a, C=8, sigma=32, D=D, codec=codec)
    X = jnp.asarray(RNG.standard_normal((a.shape[1], 5)).astype(np.float32))
    Y_ref = packsell.packsell_spmm_jnp(mat, X)
    Y = ops.packsell_spmm(mat, X, sb=4, wb=8, force="full")
    np.testing.assert_allclose(np.asarray(Y), np.asarray(Y_ref),
                               rtol=1e-6, atol=1e-6)
    # engine jnp variant agrees too
    Yj = ops.packsell_spmm(mat, X, force="jnp")
    np.testing.assert_allclose(np.asarray(Yj), np.asarray(Y_ref),
                               rtol=1e-6, atol=1e-6)


def test_spmm_single_rhs_consistent_with_spmv(banded_mat):
    a, mat = banded_mat
    x = _x(a.shape[1])
    y = ops.packsell_spmv(mat, x, force="full", sb=4, wb=8)
    Y = ops.packsell_spmm(mat, x[:, None], force="full", sb=4, wb=8)
    np.testing.assert_allclose(np.asarray(Y[:, 0]), np.asarray(y),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# decode strategies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec,D", [("fp16", 15), ("e8m", 8),
                                     ("fixed16", 10)])
def test_scan_decode_matches_loop_decode(codec, D):
    a = testmats.scattered(400, nnz_per_row=6, seed=7)
    mat = packsell.from_csr(a, C=8, sigma=32, D=D, codec=codec)
    x = _x(a.shape[1])
    ys = packsell.packsell_spmv_jnp(mat, x, decode="scan")
    yl = packsell.packsell_spmv_jnp(mat, x, decode="loop")
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yl),
                               rtol=1e-6, atol=1e-6)
    yd = ref.packsell_spmv_dense_oracle(mat, np.asarray(x))
    np.testing.assert_allclose(np.asarray(ys), yd, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# variant policy
# ---------------------------------------------------------------------------

def test_policy_explicit_and_logged(banded_mat):
    _, mat = banded_mat
    plan = kplan.get_plan(mat)      # CPU backend -> auto picks jnp
    assert plan.variant == "jnp" and "auto" in plan.policy
    plan_f = kplan.get_plan(mat, force="full")
    assert plan_f.variant == "full" and "forced" in plan_f.policy


def test_policy_env_override(banded_mat, monkeypatch):
    _, mat = banded_mat
    kplan.clear_cache()
    monkeypatch.setenv("REPRO_SPMV_POLICY", "full")
    plan = kplan.get_plan(mat)
    assert plan.variant == "full" and "REPRO_SPMV_POLICY" in plan.policy
    monkeypatch.setenv("REPRO_SPMV_POLICY", "bogus")
    with pytest.raises(ValueError):
        kplan.get_plan(mat, sb=2)


def test_band_policy_infeasible_raises():
    a = testmats.scattered(600, nnz_per_row=5, seed=8)
    mat = packsell.from_csr(a, C=8, sigma=32, D=4, codec="e8m")
    with pytest.raises(ValueError):
        kplan.get_plan(mat, hw=128, force="band")


# ---------------------------------------------------------------------------
# tracing (outer jit) still works, plans are not cached for tracers
# ---------------------------------------------------------------------------

def test_engine_inside_jit_is_ephemeral(banded_mat):
    a, mat = banded_mat
    x = _x(a.shape[1])
    kplan.clear_cache()

    @jax.jit
    def f(mat, x):
        return ops.packsell_spmv(mat, x)

    y = f(mat, x)
    assert kplan.cache_stats()["size"] == 0          # tracer plans uncached
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.packsell_spmv_ref(mat, x)),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# autotune retile
# ---------------------------------------------------------------------------

def test_retile_preserves_results(banded_mat):
    a, mat = banded_mat
    x = _x(a.shape[1])
    plan = kplan.get_plan(mat, force="full")
    y1 = np.asarray(plan.spmv(mat, x))
    plan.retile([(2, 4)] * len(mat.packs))
    y2 = np.asarray(plan.spmv(mat, x))
    np.testing.assert_allclose(y1, y2, rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError):
        plan.retile([(2, 4)] * (len(mat.packs) + 1))
