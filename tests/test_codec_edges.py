"""Codec edge cases: E8MY round-to-nearest-even corners, special values,
delta-overflow validation, chained dummy words (ISSUE 3 satellites)."""
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import codecs as cd
from repro.core import delta as de
from repro.core import packsell

F32 = np.float32


def _q(name, D, vals):
    return cd.quantize_np(np.asarray(vals, F32), cd.make_codec(name), D)


# ---------------------------------------------------------------------------
# E8MY round-to-nearest-even
# ---------------------------------------------------------------------------


def test_e8m_rne_ties_to_even():
    """A value exactly halfway between two representable outputs must round
    to the one with an even (zero) last kept mantissa bit."""
    D = 15                      # Y = 7 mantissa bits kept
    # construct exact ties: kept mantissa k, dropped bits = 100...0
    for k in (0, 1, 2, 3):
        u = np.array([0x3F800000 | (k << (D + 1)) | (1 << D)], np.uint32)
        v = u.view(F32)[0]
        q = _q("e8m", D, [v])[0]
        qu = int(np.asarray([q], F32).view(np.uint32)[0])
        kept = (qu >> (D + 1)) & 0x7F
        assert kept % 2 == 0, (k, hex(qu))        # ties -> even


def test_e8m_rounds_to_nearest_not_truncate():
    D = 15
    # value just ABOVE the halfway point must round up
    v = np.array([0x3F800000 | (1 << D) | 1], np.uint32).view(F32)[0]
    q = _q("e8m", D, [v])[0]
    assert q > v
    # value just BELOW halfway must round down (to the base)
    v2 = np.array([0x3F800000 | ((1 << D) - 1)], np.uint32).view(F32)[0]
    q2 = _q("e8m", D, [v2])[0]
    assert q2 == np.float32(1.0)


@pytest.mark.parametrize("D", [1, 8, 15, 22])
def test_e8m_special_values_preserved(D):
    """inf stays inf, NaN stays NaN (or inf at Y=0 where no mantissa bit
    survives — documented), signs preserved, no uint32 wraparound."""
    vals = np.array([np.inf, -np.inf, np.nan, 0.0, -0.0], F32)
    q = _q("e8m", D, vals)
    assert np.isposinf(q[0]) and np.isneginf(q[1])
    if D <= 21:                 # Y >= 1: a mantissa bit survives
        assert np.isnan(q[2])
    else:                       # Y = 0: NaN collapses to inf
        assert np.isinf(q[2])
    assert q[3] == 0.0 and q[4] == 0.0


@pytest.mark.parametrize("name,D", [("e8m", 8), ("bf16", 15)])
def test_rne_no_wraparound_on_allones_patterns(name, D):
    """The old rounding added the increment to ALL patterns; an all-ones
    NaN pattern (0xFFFFFFFF) wrapped past 2^32 into a tiny positive
    number. Regression: specials never round."""
    u = np.array([0xFFFFFFFF, 0x7FFFFFFF], np.uint32)
    vals = u.view(F32)
    q = _q(name, D, vals)
    assert np.all(np.isnan(q))


@pytest.mark.parametrize("name,D", [("e8m", 8), ("e8m", 1), ("bf16", 15),
                                    ("fp16", 15)])
def test_overflow_rounds_to_inf_not_wrap(name, D):
    """Finite values at the top of the range round to ±inf (IEEE), never
    wrap into the other sign or a small number."""
    vals = np.array([3.4028235e38, -3.4028235e38], F32)  # max finite fp32
    q = _q(name, D, vals)
    if name == "e8m" and D == 1:
        # Y=21: max finite survives
        assert np.isfinite(q).all() or np.isinf(q).all()
        assert np.sign(q[0]) > 0 and np.sign(q[1]) < 0
    else:
        assert np.isposinf(q[0]) and np.isneginf(q[1])


def test_e8m_subnormal_inputs_truncate_toward_zero_magnitude():
    """Subnormals keep exponent 0: truncation yields a (smaller-magnitude)
    subnormal or zero — never a normal number or a wrapped pattern."""
    tiny = np.array([1e-40, -1e-40, 5e-324, 2.0 ** -149], F32)
    for D in (1, 8, 22):
        q = _q("e8m", D, tiny)
        # RNE on the subnormal grid: at most one truncated-ulp above
        assert np.all(np.abs(q) <= np.abs(tiny) + (1 << D) * 2.0 ** -149)
        assert np.all(np.isfinite(q))
        assert np.all(np.sign(q) * np.sign(tiny) >= 0)


@pytest.mark.parametrize("D", [1, 22])
def test_e8m_extreme_D_roundtrip_bounds(D):
    """D at both extremes: Y=21 is near-lossless, Y=0 keeps only sign+exp
    (error up to a factor of 2 relative)."""
    rng = np.random.default_rng(0)
    vals = (rng.standard_normal(2048) *
            np.exp(rng.uniform(-20, 20, 2048))).astype(F32)
    q = _q("e8m", D, vals)
    Y = 22 - D
    rel = np.abs(q.astype(np.float64) - vals.astype(np.float64)) / \
        np.abs(vals.astype(np.float64))
    assert np.all(rel <= 2.0 ** -(Y + 1) + 1e-12)


def test_e8m_idempotent():
    """quantize(quantize(v)) == quantize(v) for every D (RNE to a fixed
    grid is a projection)."""
    rng = np.random.default_rng(1)
    vals = rng.standard_normal(512).astype(F32)
    for D in (1, 8, 15, 22):
        q1 = _q("e8m", D, vals)
        q2 = _q("e8m", D, q1)
        np.testing.assert_array_equal(q1, q2)


# ---------------------------------------------------------------------------
# hypothesis round-trip property (guarded: container may lack hypothesis)
# ---------------------------------------------------------------------------


def _ulp_bound(name: str, D: int) -> float:
    return {"e8m": 2.0 ** -(23 - D), "bf16": 2.0 ** -8,
            "fp16": 2.0 ** -11}.get(name, np.inf)


def test_roundtrip_error_within_documented_ulp_bound_all_codecs():
    """decode(encode(v)) error <= the documented ulp bound for every
    registered codec (dense random sweep; the hypothesis variant below
    explores adversarial bit patterns when available)."""
    rng = np.random.default_rng(2)
    vals = (rng.standard_normal(4096) *
            np.exp(rng.uniform(-8, 8, 4096))).astype(F32)
    cases = [("fp16", 15), ("fp16", 8), ("bf16", 15)] + \
        [("e8m", D) for D in (1, 4, 8, 12, 15, 22)]
    for name, D in cases:
        q = _q(name, D, vals).astype(np.float64)
        v64 = vals.astype(np.float64)
        if name == "fp16":
            in_range = (np.abs(v64) < 65504) & (np.abs(v64) >= 2.0 ** -14)
        else:
            in_range = np.abs(v64) >= 2.0 ** -126
        rel = np.abs(q - v64)[in_range] / np.abs(v64)[in_range]
        assert rel.max(initial=0.0) <= _ulp_bound(name, D) + 1e-12, (name, D)
    # fixed point: absolute bound within range
    for frac, D in ((16, 10), (8, 4)):
        c = cd.make_codec(f"fixed{frac}")
        vals_f = rng.uniform(-100, 100, 1024).astype(F32)
        V = cd.vbits_for(D)
        lim = 2.0 ** (V - 1 - frac)
        ok = np.abs(vals_f) < lim * 0.99
        q = cd.quantize_np(vals_f, c, D).astype(np.float64)
        aerr = np.abs(q - vals_f.astype(np.float64))[ok]
        assert aerr.max(initial=0.0) <= 2.0 ** -(frac + 1) + 1e-12


def test_roundtrip_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st_

    @settings(max_examples=200, deadline=None)
    @given(st_.floats(width=32, allow_nan=False, allow_infinity=False,
                      min_value=2.0 ** -120, max_value=3e38),
           st_.sampled_from([("e8m", 1), ("e8m", 8), ("e8m", 15),
                             ("e8m", 22), ("bf16", 15), ("fp16", 15)]),
           st_.booleans())
    def prop(v, case, neg):
        name, D = case
        v = -v if neg else v
        q = float(_q(name, D, [v])[0])
        if name == "fp16" and (abs(v) >= 65504 or abs(v) < 2.0 ** -14):
            return
        if not np.isfinite(q):
            # RNE overflow at the very top of the fp32 range (coarse Y
            # rounds values above ~1.5*2^127 up to inf)
            assert abs(v) > 1e38
            return
        assert abs(q - v) <= _ulp_bound(name, D) * abs(v) + 1e-45

    prop()
    del hyp


# ---------------------------------------------------------------------------
# delta-overflow validation + chained dummies (satellite bugfix)
# ---------------------------------------------------------------------------


def test_pack_words_rejects_overflowing_flag1_delta():
    c = cd.make_codec("fp16")
    with pytest.raises(ValueError, match="overflows the D=4-bit field"):
        cd.pack_words_np(np.zeros(1, F32), np.array([1 << 10]),
                         np.array([1], np.uint8), c, 4)


def test_pack_words_rejects_overflowing_dummy_delta():
    c = cd.make_codec("fp16")
    with pytest.raises(ValueError, match="chain dummy words"):
        cd.pack_words_np(np.zeros(1, F32), np.array([1 << 31]),
                         np.array([0], np.uint8), c, 4)


def test_pack_words_rejects_negative_delta():
    c = cd.make_codec("fp16")
    with pytest.raises(ValueError, match="negative delta"):
        cd.pack_words_np(np.zeros(1, F32), np.array([-1]),
                         np.array([1], np.uint8), c, 4)


def test_chained_dummies_reconstruct_any_delta():
    deltas = np.array([5, (1 << 31) + 12345, (1 << 33) + 7, 1 << 40],
                      np.int64)
    nd = de.dummies_for_deltas(deltas, 4)
    assert nd.tolist() == [0, 2, 5, 513]
    wv, wd, wf, pos, nw = de.emit_word_stream(
        np.arange(len(deltas), dtype=F32), deltas, nd)
    assert nw == len(deltas) + nd.sum()
    # the chain sums back to the original delta ahead of each element
    acc, got = 0, []
    for d, f in zip(wd, wf):
        acc += int(d)
        if f == 1:
            got.append(acc)
            acc = 0
    assert got == deltas.tolist()
    # and every emitted word fits its field
    c = cd.make_codec("fp16")
    words = cd.pack_words_np(wv, wd, wf, c, 4)
    _, d2, f2 = cd.unpack_words_np(words, c, 4)
    np.testing.assert_array_equal(d2, wd)
    np.testing.assert_array_equal(f2, wf)


def test_from_csr_pathological_gap_matrix_regression():
    """Regression (satellite bugfix): sparse rows whose column gap exceeds
    the D-bit delta field must decode exactly via auto-inserted dummy
    words — never silently wrap the column cursor."""
    n, m = 8, 1_000_001
    rows, cols, vals = [], [], []
    for i in range(n):
        rows += [i, i, i]
        cols += [0, 65_537, 999_999]      # gaps straddle 2^16 and ~2^20
        vals += [1.0, 2.0, 3.0]
    a = sp.csr_matrix((vals, (rows, cols)), shape=(n, m))
    for D, codec in ((1, "e8m"), (8, "e8m"), (15, "fp16"), (22, "e8m")):
        mat = packsell.from_csr(a, C=4, sigma=8, D=D, codec=codec)
        # gaps ~2^16 and ~2^20: dummies required below D=20, not at D=22
        assert (mat.n_dummy > 0) == (D < 20)
        dense = packsell.decode_to_dense(mat)
        ref = np.zeros((n, m), np.float32)
        ref[:, 0], ref[:, 65_537], ref[:, 999_999] = 1.0, 2.0, 3.0
        # values land on exactly the right columns, codec-quantized
        # (at D=22 / Y=0 even 3.0 rounds — compare the quantized truth)
        want = cd.quantize_np(ref, cd.make_codec(codec), D)
        np.testing.assert_array_equal(dense, want)


def test_from_csr_beyond_31_bit_gap_uses_dummy_chain():
    """A column gap >= 2^31 (previously an assert / silent wrap under -O)
    now packs via a chain of dummy words and decodes to the right
    columns."""
    m = (1 << 31) + 1000
    a = sp.csr_matrix(
        (np.array([1.0, 2.0]),
         (np.array([0, 0]), np.array([3, (1 << 31) + 500], dtype=np.int64))),
        shape=(2, m))
    mat = packsell.from_csr(a, C=2, sigma=4, D=8, codec="e8m")
    assert mat.n_dummy >= 2
    pack = np.asarray(mat.packs[0])
    S, w, C = pack.shape
    v, d, f = cd.unpack_words_np(pack.reshape(-1), mat.codec, mat.D)
    d = d.astype(np.int64).reshape(S, w, C)
    f = f.reshape(S, w, C)
    v = np.asarray(v, F32).reshape(S, w, C)
    cols = np.asarray(mat.d0s[0])[:, None, None] + np.cumsum(d, axis=1)
    got = sorted((int(cols[s, j, c]), float(v[s, j, c]))
                 for s in range(S) for j in range(w) for c in range(C)
                 if f[s, j, c] == 1)
    assert got == [(3, 1.0), ((1 << 31) + 500, 2.0)]
