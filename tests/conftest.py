"""Test configuration.

FP64 is enabled globally: the paper's outer Krylov layers run in double
precision (convergence criterion 1e-9 needs it). All other tests construct
their dtypes explicitly, so this is safe for the whole suite.

NOTE: XLA_FLAGS --xla_force_host_platform_device_count is deliberately NOT
set here — smoke tests and benchmarks must see 1 device; only
``repro.launch.dryrun`` requests 512 placeholder devices.
"""
import jax

jax.config.update("jax_enable_x64", True)
