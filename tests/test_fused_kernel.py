"""Fused-stream Pallas SpMV kernel + 'fused' plan variant (DESIGN.md §14).

Covers the PR end to end:

* interpret-mode BIT-FOR-BIT parity of ``packsell_spmv_fused`` /
  ``packsell_spmm_fused`` against the jnp fused decode — tiny-suite
  classes × {fp16/D15, e8m/D8} × every checkpoint width, integer-valued
  data so every sum is exact and accumulation/fusion differences cannot
  hide column bugs; plus a hypothesis property over codec × D × wr ×
  bucket shapes, dummy-word chains straddling checkpoint and row-tile
  boundaries, empty matrices and multi-RHS;
* the 'fused' plan variant: policy selection (auto stays 'jnp' in
  interpret mode, force/env runs the kernel), the decode_cache override
  to 'checkpoint' (logged), loud demotion when no compact encoding fits,
  the spmm VMEM-residency fallback (the former silent policy hole, now
  routed + logged), retile ``(sb, wb, wr)`` triples rebuilding the
  stream, and the steady-state trace-count guard;
* backend-keyed retile entries in the precision store (qualified keys,
  legacy un-keyed read-compat, cross-backend isolation) and the
  ``(sb, wb, wr)`` autotune sweep persisting through them;
* fused-variant solver iteration parity for ``jacobi_pcg_stored`` /
  ``adaptive_pcg``, composite single-member dispatch, and the
  distributed shard-body replay under ``REPRO_SPMV_POLICY=fused``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import packsell, testmats
from repro.core import codecs as cd
from repro.kernels import ops, ref
from repro.kernels import packsell_spmv as kpk
from repro.kernels import plan as kplan
from repro.precision.store import PrecisionStore
from repro.solvers import cg


def _int_csr(n, m, nnz_per_row, seed=0):
    """Random integer-valued CSR (values exact in every codec, sums exact
    in fp32 — so kernel-vs-XLA comparisons can be bitwise)."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    for i in range(n):
        k = rng.integers(0, nnz_per_row + 1)
        if k == 0:
            continue
        cs = rng.choice(m, size=min(k, m), replace=False)
        for c in cs:
            rows.append(i)
            cols.append(c)
            vals.append(float(rng.integers(1, 9)) * rng.choice([-1.0, 1.0]))
    a = sp.csr_matrix((vals, (rows, cols)), shape=(n, m))
    a.sort_indices()
    return a


def _int_x(m, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.integers(-8, 9, size=m)).astype(np.float32))


def _int_suite():
    """The tiny benchmark suite with values replaced by small integers
    (structure preserved — the column/delta/dummy patterns are what the
    kernel must survive; integer values make parity exact)."""
    rng = np.random.default_rng(11)
    out = {}
    for name, a in testmats.suite("tiny").items():
        a = a.tocsr()
        vals = rng.integers(-8, 9, size=a.nnz).astype(np.float64)
        vals[vals == 0] = 1
        out[name] = sp.csr_matrix((vals, a.indices, a.indptr),
                                  shape=a.shape)
    return out


SUITE = _int_suite()
CODECS = (("fp16", 15), ("e8m", 8))


# ---------------------------------------------------------------------------
# kernel-level parity: Pallas fused kernel == jnp fused body, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("klass", sorted(SUITE))
@pytest.mark.parametrize("codec,D", CODECS)
@pytest.mark.parametrize("wr", kplan._CKPT_WIDTHS)
def test_kernel_parity_suite(klass, codec, D, wr):
    """Every tiny-suite class × codec × checkpoint width: the interpret-
    mode kernel output must equal the jnp fused decode bit for bit —
    group partials AND full plan dispatch. Infeasible (codec, matrix)
    cells must demote loudly, identically for both variants."""
    a = SUITE[klass]
    mat = packsell.from_csr(a, C=8, sigma=32, D=D, codec=codec)
    pj = kplan.build_plan(mat, force="jnp", decode_cache="checkpoint",
                          ckpt_wr=wr)
    pf = kplan.build_plan(mat, force="fused", ckpt_wr=wr)
    if pf.variant != "fused":
        assert "demoted to jnp" in pf.policy
        assert pj.fused is None          # same feasibility verdict
        return
    lay = pf.fused_layout
    assert lay.wr == wr
    words3d, ckpt = pf.fused
    x = _int_x(mat.m, seed=3)
    part_ref = kplan._fused_part_spmv(words3d, ckpt, x, mat.codec, mat.D,
                                      lay)
    part_ker = kpk.packsell_spmv_fused(
        words3d, ckpt, x, codec_name=mat.codec_name, D=mat.D,
        encoding=lay.encoding, scale=lay.scale, interpret=True)
    np.testing.assert_array_equal(np.asarray(part_ker),
                                  np.asarray(part_ref))
    # plan-level: both epilogues, vs each other and the dense oracle
    oracle = ref.packsell_spmv_dense_oracle(
        mat, np.asarray(x)).astype(np.float32)
    yj, yf = np.asarray(pj.spmv(mat, x)), np.asarray(pf.spmv(mat, x))
    np.testing.assert_array_equal(yf, yj)
    np.testing.assert_array_equal(yf, oracle)
    np.testing.assert_array_equal(
        np.asarray(pf.spmv(mat, x, permuted=True)),
        np.asarray(pj.spmv(mat, x, permuted=True)))


@pytest.mark.parametrize("nb", [1, 3, 8])
def test_kernel_parity_multi_rhs(nb):
    a = SUITE["hpcg_mini"]
    mat = packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16")
    pj = kplan.build_plan(mat, force="jnp", decode_cache="checkpoint")
    pf = kplan.build_plan(mat, force="fused")
    assert pf.variant == "fused"
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.integers(-8, 9, (mat.m, nb)).astype(np.float32))
    lay = pf.fused_layout
    words3d, ckpt = pf.fused
    part_ref = kplan._fused_part_spmm(words3d, ckpt, X, mat.codec, mat.D,
                                      lay)
    part_ker = kpk.packsell_spmm_fused(
        words3d, ckpt, X, codec_name=mat.codec_name, D=mat.D,
        encoding=lay.encoding, scale=lay.scale, interpret=True)
    np.testing.assert_array_equal(np.asarray(part_ker),
                                  np.asarray(part_ref))
    np.testing.assert_array_equal(np.asarray(pf.spmm(mat, X)),
                                  np.asarray(pj.spmm(mat, X)))


def test_kernel_dummy_chains_straddle_boundaries():
    """Wide random column spans at a narrow delta field force dummy-word
    chains across checkpoint AND group-tile boundaries; the build-time
    rebased offsets must make the kernel immune to all of it."""
    a = _int_csr(64, 4096, 4, seed=13)
    mat = packsell.from_csr(a, C=4, sigma=16, D=6, codec="fp16")
    assert mat.n_dummy > 0               # the case exercises dummy words
    x = _int_x(4096, seed=14)
    oracle = ref.packsell_spmv_dense_oracle(
        mat, np.asarray(x)).astype(np.float32)
    for wr in (8, 32):
        for gb in (2, 8):                # group tiles straddle segments
            pf = kplan.build_plan(mat, force="fused", ckpt_wr=wr)
            if pf.variant != "fused":
                assert "demoted to jnp" in pf.policy
                continue
            lay = pf.fused_layout
            part = kpk.packsell_spmv_fused(
                pf.fused[0], pf.fused[1], x, codec_name=mat.codec_name,
                D=mat.D, encoding=lay.encoding, scale=lay.scale, gb=gb,
                interpret=True)
            y = pf._fused_epilogue(part, pf._device_operands(),
                                   permuted=False)
            np.testing.assert_array_equal(np.asarray(y), oracle)


def test_kernel_empty_matrix():
    a = sp.csr_matrix((5, 7))
    mat = packsell.from_csr(a, C=4, sigma=8, D=15, codec="fp16")
    pf = kplan.build_plan(mat, force="fused")
    x = _int_x(7)
    y = np.asarray(pf.spmv(mat, x))
    assert y.shape == (5,)
    np.testing.assert_array_equal(y, np.zeros(5, np.float32))
    Y = np.asarray(pf.spmm(mat, jnp.stack([x, x], axis=1)))
    np.testing.assert_array_equal(Y, np.zeros((5, 2), np.float32))


def test_kernel_word_tile_partials_sum():
    """wk < wr splits the word axis into grid tiles whose partials are
    summed outside the kernel — exact on integer data, so the tiled grid
    must still match the untiled kernel bitwise."""
    mat = packsell.from_csr(_int_csr(40, 50, 6, seed=7), C=8, sigma=32,
                            D=15, codec="fp16")
    pf = kplan.build_plan(mat, force="fused", ckpt_wr=32)
    assert pf.variant == "fused"
    lay = pf.fused_layout
    x = _int_x(50, seed=8)
    full = kpk.packsell_spmv_fused(
        pf.fused[0], pf.fused[1], x, codec_name=mat.codec_name, D=mat.D,
        encoding=lay.encoding, scale=lay.scale, interpret=True)
    for wk in (8, 16):
        tiled = kpk.packsell_spmv_fused(
            pf.fused[0], pf.fused[1], x, codec_name=mat.codec_name,
            D=mat.D, encoding=lay.encoding, scale=lay.scale, wk=wk,
            interpret=True)
        np.testing.assert_array_equal(np.asarray(tiled), np.asarray(full))


# ---------------------------------------------------------------------------
# 'fused' plan variant: policy, spmm fallback, retile, trace count
# ---------------------------------------------------------------------------


def test_policy_env_selects_fused(monkeypatch):
    monkeypatch.setenv("REPRO_SPMV_POLICY", "fused")
    mat = packsell.from_csr(_int_csr(30, 40, 5, seed=2), C=8, sigma=32,
                            D=15, codec="fp16")
    plan = kplan.build_plan(mat)
    assert plan.variant == "fused"
    assert "REPRO_SPMV_POLICY" in plan.policy


def test_policy_auto_interpret_stays_jnp():
    """On interpret backends auto must keep the XLA fused path (the
    kernel would run its body in Python) — and say how to override."""
    mat = packsell.from_csr(_int_csr(30, 40, 5, seed=2), C=8, sigma=32,
                            D=15, codec="fp16")
    plan = kplan.build_plan(mat, force="auto", interpret=True)
    assert plan.variant == "jnp"
    assert "force='fused'" in plan.policy


def test_policy_auto_compiled_prefers_fused():
    """interpret=False models a compiled backend: auto must pick the
    fused kernel when the stream is feasible and x fits residency."""
    mat = packsell.from_csr(_int_csr(30, 40, 5, seed=2), C=8, sigma=32,
                            D=15, codec="fp16")
    plan = kplan.build_plan(mat, force="auto", interpret=False)
    assert plan.variant == "fused"
    assert "fused stream feasible" in plan.policy


def test_fused_forces_checkpoint_mode_and_logs():
    """The fused stream IS the decode cache: 'full'/'0' env modes are
    overridden to 'checkpoint' with the decision in plan.policy."""
    mat = packsell.from_csr(_int_csr(30, 40, 5, seed=2), C=8, sigma=32,
                            D=15, codec="fp16")
    for mode in ("full", "0"):
        plan = kplan.build_plan(mat, force="fused", decode_cache=mode)
        assert plan.variant == "fused"
        assert plan.cache_mode == "checkpoint"
        assert f"decode_cache={mode!r} overridden" in plan.policy
    plan = kplan.build_plan(mat, force="fused", decode_cache="checkpoint")
    assert "overridden" not in plan.policy


def test_fused_infeasible_demotes_loudly():
    """e8m/D8 on a scattered matrix: 23 value bits + wide offsets fit no
    compact encoding — forced fused must demote to jnp + full cursor
    cache with the reason in plan.policy, and still be exact."""
    a = _int_csr(60, 2048, 5, seed=9)
    mat = packsell.from_csr(a, C=8, sigma=32, D=8, codec="e8m")
    plan = kplan.build_plan(mat, force="fused")
    assert plan.variant == "jnp"
    assert plan.cache_mode == "full" and plan.cols is not None
    assert "demoted to jnp" in plan.policy
    x = _int_x(2048, seed=10)
    oracle = ref.packsell_spmv_dense_oracle(
        mat, np.asarray(x)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(plan.spmv(mat, x)), oracle)


def test_spmm_vmem_fallback_band_regression(monkeypatch):
    """The former silent policy hole: a band/full plan with
    m > _FULL_X_LIMIT used to RAISE from spmm. It must now route to the
    scan-decode body, return the exact result, and log the decision."""
    a = testmats.random_banded(256, 16, 4, seed=3).tocsr()
    rng = np.random.default_rng(4)
    a = sp.csr_matrix((rng.integers(-8, 9, a.nnz).astype(np.float64),
                       a.indices, a.indptr), shape=a.shape)
    mat = packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16")
    plan = kplan.build_plan(mat, force="band", interpret=True)
    assert plan.variant == "band"
    monkeypatch.setattr(kplan, "_FULL_X_LIMIT", 100)   # < m = 256
    X = jnp.asarray(rng.integers(-8, 9, (mat.m, 2)).astype(np.float32))
    Y = np.asarray(plan.spmm(mat, X))                  # used to raise
    assert "; spmm:" in plan.policy and "routed to" in plan.policy
    for j in range(2):
        oracle = ref.packsell_spmv_dense_oracle(
            mat, np.asarray(X[:, j])).astype(np.float32)
        np.testing.assert_array_equal(Y[:, j], oracle)


def test_spmm_vmem_fallback_fused(monkeypatch):
    """A fused plan past the residency limit routes spmm to the jnp
    fused body — same stream, same decode, exact, logged."""
    mat = packsell.from_csr(_int_csr(80, 90, 6, seed=5), C=8, sigma=32,
                            D=15, codec="fp16")
    plan = kplan.build_plan(mat, force="fused")
    assert plan.variant == "fused"
    pj = kplan.build_plan(mat, force="jnp", decode_cache="checkpoint")
    monkeypatch.setattr(kplan, "_FULL_X_LIMIT", 50)    # < m = 90
    rng = np.random.default_rng(6)
    X = jnp.asarray(rng.integers(-8, 9, (mat.m, 3)).astype(np.float32))
    Y = np.asarray(plan.spmm(mat, X))
    assert "; spmm:" in plan.policy and "jnp fused body" in plan.policy
    np.testing.assert_array_equal(Y, np.asarray(pj.spmm(mat, X)))


def test_retile_triples_rebuild_stream():
    """(sb, wb, wr) triples: a new wr rebuilds the stream, the stored
    order and both inverse permutations; results stay exact."""
    mat = packsell.from_csr(_int_csr(70, 80, 6, seed=21), C=8, sigma=32,
                            D=15, codec="fp16")
    plan = kplan.build_plan(mat, force="fused", ckpt_wr=32)
    assert plan.fused_layout.wr == 32
    x = _int_x(80, seed=22)
    oracle = ref.packsell_spmv_dense_oracle(
        mat, np.asarray(x)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(plan.spmv(mat, x)), oracle)
    plan.retile([(sb, wb, 8) for sb, wb in plan.tiles])
    assert plan.fused_layout.wr == 8
    np.testing.assert_array_equal(np.asarray(plan.spmv(mat, x)), oracle)
    # pairs still accepted; wr disagreement rejected
    plan.retile(list(plan.tiles))
    if len(plan.tiles) >= 1:
        with pytest.raises(ValueError, match="plan-global"):
            bad = [(sb, wb, 8 + 8 * i) for i, (sb, wb)
                   in enumerate(plan.tiles + ((2, 8),))][:len(plan.tiles)]
            if len(bad) < 2:
                raise ValueError("plan-global")  # single bucket: same check
            plan.retile(bad)


def test_fused_steady_state_single_trace():
    mat = packsell.from_csr(_int_csr(40, 50, 5, seed=17), C=8, sigma=32,
                            D=15, codec="fp16")
    plan = kplan.build_plan(mat, force="fused")
    assert plan.variant == "fused"
    x = _int_x(50, seed=18)
    for _ in range(10):
        plan.spmv(mat, x)
    assert plan._dispatch("spmv")._cache_size() == 1


# ---------------------------------------------------------------------------
# backend-keyed retile store
# ---------------------------------------------------------------------------


def test_store_retile_backend_qualified_roundtrip(tmp_path):
    store = PrecisionStore(path=str(tmp_path / "store.json"))
    store.put_retile("fp", "plan_fp16", [(2, 16, 32), (4, 8, 32)],
                     backend="tpu", save=False)
    assert store.get_retile("fp", "plan_fp16", backend="tpu") == \
        [(2, 16, 32), (4, 8, 32)]
    # the on-disk key is qualified
    assert "plan_fp16@tpu" in store._entries["fp"]["retile"]
    # default backend resolves jax.default_backend() both ways
    store.put_retile("fp", "auto_key", [(8, 32)], save=False)
    assert store.get_retile("fp", "auto_key") == [(8, 32)]


def test_store_retile_cross_backend_isolated(tmp_path):
    """A CPU interpret sweep must never poison a TPU selection."""
    store = PrecisionStore(path=str(tmp_path / "store.json"))
    store.put_retile("fp", "k", [(2, 8)], backend="cpu", save=False)
    store.put_retile("fp", "k", [(8, 32)], backend="tpu", save=False)
    assert store.get_retile("fp", "k", backend="cpu") == [(2, 8)]
    assert store.get_retile("fp", "k", backend="tpu") == [(8, 32)]
    assert store.get_retile("fp", "k", backend="gpu") is None


def test_store_retile_legacy_unkeyed_migrates(tmp_path):
    """Pre-PR entries have bare keys: they must still resolve (read
    compat) until a qualified entry for this backend shadows them."""
    store = PrecisionStore(path=str(tmp_path / "store.json"))
    ent = store._entries.setdefault("fp", {})
    ent["retile"] = {"plan_e8m8": [[4, 16]]}          # legacy format
    assert store.get_retile("fp", "plan_e8m8") == [(4, 16)]
    store.put_retile("fp", "plan_e8m8", [(8, 32)], save=False)
    assert store.get_retile("fp", "plan_e8m8") == [(8, 32)]
    # the legacy entry is untouched — other backends still read it
    assert store.get_retile("fp", "plan_e8m8",
                            backend="other") == [(4, 16)]


def test_store_apply_retile_triples_rebuild_wr(tmp_path):
    store = PrecisionStore(path=str(tmp_path / "store.json"))
    mat = packsell.from_csr(_int_csr(60, 70, 5, seed=23), C=8, sigma=32,
                            D=15, codec="fp16")
    plan = kplan.build_plan(mat, force="fused", ckpt_wr=32)
    assert plan.fused_layout.wr == 32
    store.put_retile("fp", "k", [(sb, wb, 8) for sb, wb in plan.tiles],
                     save=False)
    assert store.apply_retile("fp", "k", plan)
    assert plan.fused_layout.wr == 8
    x = _int_x(70, seed=24)
    oracle = ref.packsell_spmv_dense_oracle(
        mat, np.asarray(x)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(plan.spmv(mat, x)), oracle)


def test_autotune_fused_wr_sweep_persists(tmp_path):
    from benchmarks import bench_kernels
    store = PrecisionStore(path=str(tmp_path / "store.json"))
    mat = packsell.from_csr(_int_csr(50, 60, 5, seed=25), C=8, sigma=32,
                            D=15, codec="fp16")
    x = _int_x(60, seed=26)
    plan, records = bench_kernels.autotune(
        mat, x, force="fused", wrs=(8, 32), repeats=1,
        store=store, fingerprint="fp", store_key="k")
    assert plan.variant == "fused"
    assert {r["wr"] for r in records} <= {8, 32} and records
    tiles = store.get_retile("fp", "k")
    assert tiles is not None and all(len(t) == 3 for t in tiles)
    assert tiles[0][2] == plan.fused_layout.wr


# ---------------------------------------------------------------------------
# solver iteration parity, composite + distributed dispatch
# ---------------------------------------------------------------------------


def _spd_problem():
    a = testmats.stencil_3d(6, 6, 6, neighbours=27)
    from repro.solvers import operators as op
    s, _ = op.sym_scale(a)
    mat = packsell.from_csr(s, C=8, sigma=32, D=15, codec="fp16")
    b = jnp.asarray(np.random.default_rng(5).standard_normal(s.shape[0])
                    .astype(np.float32))
    return s, mat, b


def test_jacobi_pcg_stored_fused_variant_parity():
    s, mat, b = _spd_problem()
    diag = s.diagonal()
    pj = kplan.build_plan(mat, force="jnp", decode_cache="checkpoint")
    pf = kplan.build_plan(mat, force="fused")
    assert pf.variant == "fused"
    kw = dict(tol=1e-6, maxiter=200, dtype=jnp.float32)
    x_j, i_j = cg.jacobi_pcg_stored(mat, pj, diag, b, **kw)
    x_f, i_f = cg.jacobi_pcg_stored(mat, pf, diag, b, **kw)
    assert int(i_f.iters) == int(i_j.iters)
    # float SPD data: the compiled kernel contracts mul+add to FMA, so
    # iterates agree to ULP noise, not bitwise (integer-data tests above
    # cover bitwise; solvers gate on the iteration trajectory)
    np.testing.assert_allclose(np.asarray(x_f), np.asarray(x_j),
                               rtol=1e-4, atol=1e-5)


def test_adaptive_pcg_fused_variant_parity():
    s, mat, b = _spd_problem()
    diag = jnp.asarray(s.diagonal().astype(np.float32))
    dense = jnp.asarray(s.toarray().astype(np.float64))
    hi = lambda v: (dense @ v.astype(jnp.float64)).astype(jnp.float32)  # noqa: E731
    M = lambda r: r / diag                                              # noqa: E731
    kw = dict(M=M, tol=1e-8, maxiter=40, m_in=8, dtype=jnp.float32)
    pj = kplan.build_plan(mat, force="jnp", decode_cache="checkpoint")
    pf = kplan.build_plan(mat, force="fused")
    assert pf.variant == "fused"
    x_j, a_j = cg.adaptive_pcg([lambda v: pj.spmv(mat, v), hi], b, **kw)
    x_f, a_f = cg.adaptive_pcg([lambda v: pf.spmv(mat, v), hi], b, **kw)
    assert int(a_f.iters) == int(a_j.iters)
    assert int(a_f.promotions) == int(a_j.promotions)
    np.testing.assert_allclose(np.asarray(x_f), np.asarray(x_j),
                               rtol=1e-4, atol=1e-5)


def test_composite_single_member_fused_dispatch():
    mat = packsell.from_csr(_int_csr(60, 60, 5, seed=27), C=8, sigma=32,
                            D=15, codec="fp16")
    pf = kplan.build_plan(mat, force="fused")
    assert pf.variant == "fused"
    comp = pf.as_composite(mat)
    x = _int_x(60, seed=28)
    oracle = ref.packsell_spmv_dense_oracle(
        mat, np.asarray(x)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(comp.spmv(x)), oracle)


def test_distributed_shard_bodies_ride_fused(monkeypatch):
    """REPRO_SPMV_POLICY=fused threads the kernel into the per-shard
    plans; the host shard-body replay must stay exact vs scipy."""
    from repro.distributed import plan as dplan
    a = _int_csr(96, 96, 5, seed=29)
    a = a + sp.eye(96, format="csr")     # no empty rows across shards
    x = np.asarray(_int_x(96, seed=30))
    monkeypatch.setenv("REPRO_SPMV_POLICY", "fused")
    ops_d = dplan.build_composite_operands(
        a, 2, classes=[("fp16", 15, None)], C=8, sigma=32)
    kinds = {p.variant for m in ops_d.members for p in (m.plans or [])}
    assert "fused" in kinds              # the shard plans run the kernel
    y = dplan.reference_spmv(ops_d, x)
    np.testing.assert_allclose(np.asarray(y)[:96], a @ x, rtol=0,
                               atol=0)


# ---------------------------------------------------------------------------
# observe wiring: span + variant-labelled dispatch counter
# ---------------------------------------------------------------------------


def test_fused_kernel_span_and_dispatch_counter():
    from repro import observe
    from repro.observe.profile import SPAN_NAMES
    assert "packsell.fused_kernel" in SPAN_NAMES
    mat = packsell.from_csr(_int_csr(40, 50, 5, seed=31), C=8, sigma=32,
                            D=15, codec="fp16")
    plan = kplan.build_plan(mat, force="fused")
    assert plan.variant == "fused"
    x = _int_x(50, seed=32)
    prev = observe.enable(True)
    try:
        observe.reset()
        plan.spmv(mat, x)
        rep = observe.report()
        keys = [k for k in rep["counters"]
                if k.startswith("spmv.dispatch") and "variant=fused" in k]
        assert keys and rep["counters"][keys[0]] >= 1
    finally:
        observe.enable(prev)
        observe.reset()


# ---------------------------------------------------------------------------
# hypothesis property: kernel == jnp fused body over random cases
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
    _HAVE_HYP = True
except Exception:                            # pragma: no cover
    _HAVE_HYP = False

if _HAVE_HYP:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HYP_CODECS = [("fp16", 15), ("fp16", 8), ("bf16", 12), ("e8m", 16),
                  ("e8m", 8), ("fixed16", 15), ("fixed16", 9)]

    @st.composite
    def kernel_cases(draw):
        n = draw(st.integers(1, 60))
        m = draw(st.integers(1, 80))
        nnz_per_row = draw(st.integers(0, 10))
        codec, D = draw(st.sampled_from(HYP_CODECS))
        C = draw(st.sampled_from([2, 4, 8]))
        sigma = C * draw(st.sampled_from([1, 2, 4]))
        wr = draw(st.sampled_from([8, 16, 32, 128]))
        gb = draw(st.sampled_from([2, 8]))
        nb = draw(st.sampled_from([0, 2, 5]))     # 0 = spmv only
        seed = draw(st.integers(0, 2 ** 16))
        return n, m, nnz_per_row, codec, D, C, sigma, wr, gb, nb, seed

    @settings(max_examples=25, deadline=None)
    @given(kernel_cases())
    def test_property_kernel_matches_jnp_fused(case):
        n, m, nnz_per_row, codec, D, C, sigma, wr, gb, nb, seed = case
        a = _int_csr(n, m, nnz_per_row, seed=seed)
        mat = packsell.from_csr(a, C=C, sigma=sigma, D=D, codec=codec)
        pf = kplan.build_plan(mat, force="fused", ckpt_wr=wr)
        if pf.variant != "fused":
            assert "demoted to jnp" in pf.policy
            return
        lay = pf.fused_layout
        words3d, ckpt = pf.fused
        x = _int_x(m, seed=seed + 1)
        oracle = ref.packsell_spmv_dense_oracle(
            mat, np.asarray(x)).astype(np.float32)
        part_ref = kplan._fused_part_spmv(words3d, ckpt, x, mat.codec, D,
                                          lay)
        part_ker = kpk.packsell_spmv_fused(
            words3d, ckpt, x, codec_name=mat.codec_name, D=D,
            encoding=lay.encoding, scale=lay.scale, gb=gb, interpret=True)
        np.testing.assert_array_equal(np.asarray(part_ker),
                                      np.asarray(part_ref))
        np.testing.assert_array_equal(np.asarray(pf.spmv(mat, x)), oracle)
        if nb:
            rng = np.random.default_rng(seed + 2)
            X = jnp.asarray(rng.integers(-8, 9, (m, nb))
                            .astype(np.float32))
            mm_ref = kplan._fused_part_spmm(words3d, ckpt, X, mat.codec,
                                            D, lay)
            mm_ker = kpk.packsell_spmm_fused(
                words3d, ckpt, X, codec_name=mat.codec_name, D=D,
                encoding=lay.encoding, scale=lay.scale, gb=gb,
                interpret=True)
            np.testing.assert_array_equal(np.asarray(mm_ker),
                                          np.asarray(mm_ref))
