"""Paper future-work extensions: RCM reordering (§5.1.1) and the PackSELL
sparse triangular solver (§6 #3)."""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp
from scipy.sparse.linalg import spsolve_triangular

from repro.core import packsell as pk
from repro.core import reorder, testmats, trisolve


class TestRCM:
    def test_bandwidth_shrinks_on_scattered(self):
        a = testmats.scattered(400, nnz_per_row=4, seed=0)
        a = (a + a.T).tocsr()           # symmetric pattern for RCM
        b0 = reorder.bandwidth(a)
        ar, perm = reorder.rcm_reorder(a)
        assert reorder.bandwidth(ar) < b0
        assert sorted(perm.tolist()) == list(range(a.shape[0]))

    def test_dummy_elements_drop(self):
        """RCM shrinks deltas -> fewer dummies at small D (the paper's
        stated motivation for reordering)."""
        a = testmats.scattered(600, nnz_per_row=5, seed=1)
        a = (a + a.T).tocsr()
        ar, _ = reorder.rcm_reorder(a)
        m0 = pk.from_csr(a, C=8, sigma=32, D=6, codec="e8m", device=False)
        m1 = pk.from_csr(ar, C=8, sigma=32, D=6, codec="e8m", device=False)
        assert m1.n_dummy < m0.n_dummy

    def test_symmetric_permutation_preserves_values(self):
        a = testmats.stencil_1d(200, 2)
        ar, perm = reorder.rcm_reorder(a)
        # spectra preserved: check via x^T A x on permuted vectors
        rng = np.random.default_rng(0)
        x = rng.standard_normal(200)
        q0 = x @ (a @ x)
        q1 = x[np.argsort(perm)] @ (ar @ x[np.argsort(perm)])
        # P A P^T with y = P x means y[i] = x[perm[i]]
        y = x[perm]
        q2 = y @ (ar @ y)
        np.testing.assert_allclose(q2, q0, rtol=1e-10)


class TestSpMM:
    def test_matches_column_spmvs(self):
        a = testmats.random_banded(300, 20, 5, seed=4)
        mat = pk.from_csr(a, C=8, sigma=32, D=8, codec="e8m")
        rng = np.random.default_rng(4)
        X = jnp.asarray(rng.standard_normal((300, 7)), jnp.float32)
        Y = pk.packsell_spmm_jnp(mat, X)
        for j in range(7):
            yj = pk.packsell_spmv_jnp(mat, X[:, j])
            np.testing.assert_allclose(np.asarray(Y[:, j]), np.asarray(yj),
                                       rtol=1e-6, atol=1e-6)

    def test_sparse_linear_batched_uses_spmm(self):
        from repro.models.sparse_linear import PackSELLLinear
        rng = np.random.default_rng(5)
        w = rng.standard_normal((64, 96)).astype(np.float32)
        lin = PackSELLLinear.from_dense(w, density=0.4, codec="bf16",
                                        C=16, sigma=32)
        x = jnp.asarray(rng.standard_normal((3, 5, 64)), jnp.float32)
        y = lin(x)
        assert y.shape == (3, 5, 96)
        y0 = lin(x[0, 0])
        np.testing.assert_allclose(np.asarray(y[0, 0]), np.asarray(y0),
                                   rtol=1e-5, atol=1e-5)


class TestTriSolve:
    def _lower(self, n=300, seed=0):
        a = testmats.stencil_1d(n, 2, spd=True, seed=seed)
        lo = sp.tril(a).tocsr()
        lo.sort_indices()
        return lo

    def test_matches_scipy(self):
        lo = self._lower()
        rng = np.random.default_rng(1)
        b = rng.standard_normal(lo.shape[0])
        x, solver = trisolve.trisolve(lo, b, lower=True, D=1)
        want = spsolve_triangular(lo.tocsr(), b, lower=True)
        np.testing.assert_allclose(np.asarray(x), want, rtol=2e-4,
                                   atol=2e-4)

    def test_exact_at_level_count(self):
        """The Jacobi iteration is exact at n_levels even when the
        iteration matrix is NOT contractive (spectral radius > 1) — only
        nilpotency, not convergence, is at work. Fewer iterations diverge."""
        n = 60
        lo = sp.eye(n, format="csr") + sp.diags(
            [-1.2 * np.ones(n - 1)], [-1], format="csr")
        lo = lo.tocsr()
        lo.sort_indices()
        rng = np.random.default_rng(2)
        b = jnp.asarray(rng.standard_normal(n))
        solver = trisolve.PackSELLTriSolver(lo, lower=True, D=1)
        assert solver.levels == n
        want = spsolve_triangular(lo.tocsr(), np.asarray(b), lower=True)
        x_full = solver.solve(b)
        np.testing.assert_allclose(
            np.asarray(x_full), want,
            rtol=1e-3, atol=1e-3 * np.abs(want).max())
        x_half = solver.solve(b, iters=solver.levels // 2)
        err_half = np.abs(np.asarray(x_half) - want).max()
        err_full = np.abs(np.asarray(x_full) - want).max()
        assert err_half > 100 * max(err_full, 1e-6)

    def test_upper_triangular(self):
        a = testmats.stencil_1d(150, 1, spd=True, seed=3)
        up = sp.triu(a).tocsr()
        rng = np.random.default_rng(3)
        b = rng.standard_normal(150)
        x, _ = trisolve.trisolve(up, b, lower=False, D=1)
        want = spsolve_triangular(up.tocsr(), b, lower=False)
        np.testing.assert_allclose(np.asarray(x), want, rtol=2e-4,
                                   atol=2e-4)

    def test_rejects_non_triangular(self):
        a = testmats.stencil_1d(50, 1)
        with pytest.raises(ValueError):
            trisolve.trisolve(a, np.ones(50))

    def test_footprint_benefit_carries_over(self):
        """The triangular factor gets the same PackSELL compression."""
        lo = self._lower(n=2000)
        solver = trisolve.PackSELLTriSolver(lo, lower=True, D=8, C=32,
                                            sigma=64)
        from repro.core import sell as sl
        strict, _ = trisolve.split_triangular(lo, True)
        se = sl.from_csr(strict, C=32, sigma=64, value_dtype="float32",
                         device=False)
        ratio = solver.memory_stats()["packsell_bytes"] / \
            se.memory_stats()["sell_bytes"]
        assert ratio < 0.75
