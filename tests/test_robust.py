"""Guarded execution: fault injection, checksum/ABFT detection, recovery.

Covers DESIGN.md §11: the seeded injectors (``robust.inject``), structural
validation and the checksum+ABFT guard (``robust.guard``), the self-healing
``guarded_solve`` escalation (``robust.recover``), plus the robustness
satellites — store quarantine/locking, bounded plan caches, input
validation, and serving-warmup plan rebuilds. Multi-device dist cases are
gated on ``jax.device_count()`` (``make verify-robust`` forces 8).
"""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import codecs as cd
from repro.core import packsell as pk
from repro.core import testmats
from repro.kernels import ops as kops
from repro.kernels import plan as kplan
from repro.robust import guard as gd
from repro.robust import inject as inj
from repro.robust import recover as rc
from repro.solvers import operators as op

NDEV = jax.device_count()
need4 = pytest.mark.skipif(NDEV < 4, reason="needs >=4 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")

TINY = sorted(testmats.suite("tiny"))


def _spd(a: sp.csr_matrix) -> sp.csr_matrix:
    """Symmetrize + diagonally-dominant shift (the tiny suite is not all
    SPD; guarded_solve's PCG inner needs it)."""
    s = ((a + a.T) / 2).tocsr()
    shift = float(np.abs(s).sum(axis=1).max())
    return (s + sp.eye(s.shape[0]) * shift).tocsr()


def _mat_plan(a, *, C=32, sigma=64, codec="fp16", D=15, **plan_kw):
    mat = pk.from_csr(a.tocsr(), C=C, sigma=sigma, codec=codec, D=D)
    return mat, kplan.get_plan(mat, **plan_kw)


def _x(m, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(m), jnp.float32)


# ---------------------------------------------------------------------------
# checksum primitive
# ---------------------------------------------------------------------------

def test_checksum_detects_single_bit_and_transposition():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2 ** 32, size=257, dtype=np.uint32)
    ref = gd.checksum([a])
    for bit in (0, 7, 16, 31):
        b = a.copy()
        b[100] ^= np.uint32(1 << bit)
        assert gd.checksum([b]) != ref
    # a swap is plain-sum-invariant; the weighted half must catch it
    c = a.copy()
    c[[3, 200]] = c[[200, 3]]
    assert c.sum(dtype=np.uint32) == a.sum(dtype=np.uint32)
    assert gd.checksum([c]) != ref


def test_checksum_host_matches_device():
    rng = np.random.default_rng(1)
    arrs = [rng.integers(0, 2 ** 32, size=s, dtype=np.uint32)
            for s in (5, 64, 1)]
    arrs.append(rng.integers(-100, 100, size=17).astype(np.int32))
    s0, s1 = gd._checksum_jnp([jnp.asarray(a) for a in arrs])
    r0, r1 = gd._checksum_ref_pair(gd.checksum(arrs))
    assert int(s0) == int(r0) and int(s1) == int(r1)


# ---------------------------------------------------------------------------
# structural validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", TINY)
def test_validate_clean_build(name):
    a = testmats.suite("tiny")[name]
    mat, plan = _mat_plan(a, C=16, sigma=32)
    assert mat.validate(raise_=False) == []
    assert plan.validate(mat, raise_=False) == []


def test_validate_plan_flags_corrupted_checkpoint():
    mat, plan = _mat_plan(testmats.random_banded(256, 16, 5, seed=3),
                          C=16, sigma=32)
    assert plan.fused is not None
    i = inj.corrupt_fused_checkpoint(mat, plan, seed=7)
    issues = plan.validate(mat, raise_=False)
    # an out-of-range or non-monotone checkpoint must surface (a small
    # in-range shift may legitimately pass structure — then the checksum
    # guard is the detector, covered below)
    if not i.value_neutral and issues == []:
        ck = np.asarray(plan.fused[1])
        assert 0 <= int(ck.min()) and int(ck.max()) < mat.m
    i.undo()
    assert plan.validate(mat, raise_=False) == []


def test_validate_matrix_flags_nonfinite_payload():
    mat, plan = _mat_plan(testmats.stencil_1d(128, 2), C=8, sigma=16)
    # force an Inf fp16 payload into a real (flag=1) word
    packs = [np.asarray(p).copy() for p in mat.packs]
    w = packs[0]
    _, _, flag = cd.unpack_words_np(w.reshape(-1), mat.codec, mat.D)
    live = np.nonzero(flag == 1)[0]
    bad = w.reshape(-1).copy()
    # fp16 +inf pattern in the payload's high 16 bits, keep flag/delta bits
    bad[live[0]] = (bad[live[0]] & np.uint32(0x0000FFFF)) \
        | (np.uint32(0x7C00) << np.uint32(16))
    packs[0] = bad.reshape(w.shape)
    orig = mat.packs
    mat.packs = tuple(jnp.asarray(p) for p in packs)
    try:
        issues = mat.validate(raise_=False)
        assert any("non-finite" in s for s in issues)
        with pytest.raises(gd.IntegrityError):
            mat.validate(raise_=True)
    finally:
        mat.packs = orig


def test_build_plan_rejects_garbage():
    # _quick_validate runs inside build_plan: a broken outrow_cat on a
    # fresh build must be rejected at build time
    bad = pk.from_csr(testmats.stencil_1d(96, 2).tocsr(), C=8, sigma=16)
    o0 = np.asarray(bad.outrows[0]).copy()
    real = np.nonzero(o0 < bad.n)[0]     # skip padding slots (== n)
    assert len(real) >= 2
    o0[real[1]] = o0[real[0]]            # duplicate a stored row
    bad.outrows = (jnp.asarray(o0),) + tuple(bad.outrows[1:])
    with pytest.raises(ValueError):
        kplan.build_plan(bad)


# ---------------------------------------------------------------------------
# guarded SpMV: detection property over seeded injection campaigns
# ---------------------------------------------------------------------------

def test_guard_clean_matvec_passes():
    mat, plan = _mat_plan(testmats.random_banded(512, 24, 6, seed=1))
    gs = gd.build_guard(mat, plan)
    x = _x(mat.m)
    y, ok, rel = gd.guarded_spmv(mat, plan, gs, x)
    assert bool(ok)
    assert float(rel) < 1e-6
    assert np.allclose(np.asarray(y),
                       np.asarray(plan.spmv(mat, x)), atol=0)


@pytest.mark.parametrize("injector", ["fused_word", "ckpt", "perm"])
def test_guard_detects_fused_plan_corruption(injector):
    mat, plan = _mat_plan(testmats.random_banded(512, 24, 6, seed=1))
    assert plan.fused is not None
    gs = gd.build_guard(mat, plan)
    x = _x(mat.m)
    y0 = np.asarray(plan.spmv(mat, x))
    make = {"fused_word": lambda s: inj.flip_fused_word(mat, plan, s),
            "ckpt": lambda s: inj.corrupt_fused_checkpoint(mat, plan, s),
            "perm": lambda s: inj.corrupt_permutation(mat, plan, s)}[
        injector]
    affecting = detected = 0
    for seed in range(24):
        i = make(seed)
        y, ok, _ = gd.guarded_spmv(mat, plan, gs, x)
        tripped = not bool(ok)
        if not i.value_neutral:
            affecting += 1
            detected += tripped
            assert tripped, f"value-affecting {injector} seed={seed} missed"
        i.undo()
        y2, ok2, _ = gd.guarded_spmv(mat, plan, gs, x)
        assert bool(ok2)
        assert np.array_equal(np.asarray(y2), y0)
    assert affecting > 0 and detected == affecting


def test_guard_detects_low_order_payload_flip():
    """A low-order mantissa flip moves sum(y) far below any honest
    analytic tolerance — only the exact checksum sees it. This is the
    case that makes the checksum mandatory."""
    mat, plan = _mat_plan(testmats.random_banded(512, 24, 6, seed=1))
    gs = gd.build_guard(mat, plan)
    x = _x(mat.m)
    i = inj.flip_fused_word(mat, plan, seed=11, bit=16)  # payload LSB
    _, ok, rel = gd.guarded_spmv(mat, plan, gs, x)
    if not i.value_neutral:
        assert not bool(ok)
        assert float(rel) < gs.tau_rel  # analytic alone would have missed
    i.undo()


def test_guard_detects_pack_word_corruption_nonfused_paths():
    for mode in ("full", "0"):
        a = testmats.random_banded(256, 16, 5, seed=2)
        mat = pk.from_csr(a.tocsr(), C=16, sigma=32, codec="fp16")
        plan = kplan.get_plan(mat, decode_cache=mode)
        gs = gd.build_guard(mat, plan)
        x = _x(mat.m, seed=3)
        assert bool(gd.guarded_spmv(mat, plan, gs, x)[1])
        affecting = detected = 0
        for seed in range(16):
            i = inj.flip_pack_word(mat, plan, seed)
            _, ok, _ = gd.guarded_spmv(mat, plan, gs, x)
            if not i.value_neutral:
                affecting += 1
                detected += not bool(ok)
            i.undo()
        assert affecting > 0 and detected == affecting, mode


def test_guard_trips_on_poisoned_x():
    mat, plan = _mat_plan(testmats.stencil_1d(200, 2), C=8, sigma=16)
    gs = gd.build_guard(mat, plan)
    for mode in ("nan", "inf"):
        xp, i = inj.poison_x(np.ones(mat.m), seed=5, mode=mode)
        assert not i.value_neutral
        _, ok, _ = gd.guarded_spmv(mat, plan, gs,
                                   jnp.asarray(xp, jnp.float32))
        assert not bool(ok), mode


def test_guard_csr_source_certifies_packing():
    a = testmats.random_banded(200, 12, 4, seed=6).tocsr()
    mat, plan = _mat_plan(a, C=8, sigma=16, codec="e8m", D=8)
    gs = gd.build_guard(mat, plan, csr=a)
    assert gs.source == "csr" and gs.tau_quant > 0
    assert bool(gd.guarded_spmv(mat, plan, gs, _x(mat.m))[1])


def test_check_integrity_probe_and_refresh():
    mat, plan = _mat_plan(testmats.stencil_1d(128, 2), C=8, sigma=16)
    gs = gd.build_guard(mat, plan)
    assert gd.check_integrity(mat, plan, gs)
    i = inj.flip_fused_word(mat, plan, seed=1)
    assert not gd.check_integrity(mat, plan, gs)
    # refresh re-baselines (the legitimate-change path, e.g. retile)
    gs.refresh_checksum(mat, plan)
    assert gd.check_integrity(mat, plan, gs)
    i.undo()


def test_guarded_spmv_inside_jit_traces():
    mat, plan = _mat_plan(testmats.stencil_1d(128, 2), C=8, sigma=16)
    gs = gd.build_guard(mat, plan)

    @jax.jit
    def f(x):
        y, ok, _ = gd.guarded_spmv(mat, plan, gs, x)
        return y, ok

    y, ok = f(_x(mat.m))
    assert bool(ok)


def test_hypothesis_random_bit_flip_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    a = testmats.random_banded(256, 16, 5, seed=9)
    mat, plan = _mat_plan(a, C=16, sigma=32)
    gs = gd.build_guard(mat, plan)
    x = _x(mat.m, seed=4)
    G, wr, C = np.asarray(plan.fused[0]).shape

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, G - 1), st.integers(0, wr - 1),
           st.integers(0, C - 1), st.integers(0, 31))
    def prop(g, j, c, bit):
        i = inj.flip_fused_word(mat, plan, seed=0, bit=bit, pos=(g, j, c))
        try:
            _, ok, _ = gd.guarded_spmv(mat, plan, gs, x)
            # xor always changes the stored word, and the exact checksum
            # detects ANY single-word operand change — even value-neutral
            # ones (i.value_neutral says whether y could differ, not
            # whether the guard should trip)
            assert not bool(ok)
        finally:
            i.undo()

    prop()


# ---------------------------------------------------------------------------
# plan health + serving rebuild
# ---------------------------------------------------------------------------

def test_plan_health_marking():
    mat, plan = _mat_plan(testmats.stencil_1d(96, 2), C=8, sigma=16)
    assert gd.is_healthy(plan) and gd.plan_health(plan) is None
    gd.mark_unhealthy(plan, "guard_trip")
    assert not gd.is_healthy(plan)
    assert gd.plan_health(plan) == "guard_trip"


def test_sparse_linear_rebuild_heals():
    from repro.models.sparse_linear import PackSELLLinear
    w = np.random.default_rng(0).standard_normal((64, 48)).astype(
        np.float32)
    lin = PackSELLLinear.from_dense(w, density=0.4, codec="fp16", C=8,
                                    sigma=16)
    x = _x(64, seed=1)
    y0 = np.asarray(lin(x))
    old_plan = lin.plan
    gd.mark_unhealthy(old_plan, "guard_trip")
    new_plan = lin.rebuild()
    assert new_plan is not old_plan and gd.is_healthy(new_plan)
    assert np.array_equal(np.asarray(lin(x)), y0)


def test_engine_warmup_rebuilds_unhealthy_layer(caplog):
    import logging
    from repro import configs
    from repro.models import transformer as tfm
    from repro.models.sparse_linear import PackSELLLinear
    from repro.serving import DecodeEngine, ServeConfig, WarmupSpec

    cfg = configs.reduce(configs.get("qwen2-0.5b"))
    params, _ = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, ServeConfig(slots=1, max_len=32))
    w = np.random.default_rng(1).standard_normal((32, 32)).astype(
        np.float32)
    lin = PackSELLLinear.from_dense(w, density=0.5, codec="fp16", C=8,
                                    sigma=16)
    sick = lin.plan
    gd.mark_unhealthy(sick, "guard_trip")
    with caplog.at_level(logging.WARNING, logger="repro.serving.engine"):
        eng.warmup(WarmupSpec(sparse_layers=(lin,)))
    assert lin.plan is not sick and gd.is_healthy(lin.plan)
    assert any("unhealthy" in r.getMessage() for r in caplog.records)


# ---------------------------------------------------------------------------
# guarded operator kind
# ---------------------------------------------------------------------------

def test_parse_kind_guarded():
    spec = op.parse_kind("guarded:plan_e8m8")
    assert spec.family == "guarded" and spec.inner.raw == "plan_e8m8"
    assert spec.codec == "e8m" and spec.D == 8
    for bad in ("guarded:fp32", "guarded:dist_fp16", "guarded:nope"):
        with pytest.raises(ValueError):
            op.parse_kind(bad)


def test_guarded_matvec_counts_trips():
    a = _spd(testmats.suite("tiny")["banded"])
    ops = op.OperatorSet(a, C=32, sigma=64)
    fn = ops.matvec("guarded:plan_fp16")
    x = _x(a.shape[0])
    fn(x)
    assert fn.trips() == 0
    mat, plan = fn.pair
    i = inj.flip_fused_word(mat, plan, seed=3, bit=28)
    fn(x)
    assert (fn.trips() == 1) == (not i.value_neutral)
    if not i.value_neutral:
        assert gd.plan_health(plan) == "guard_trip"
    i.undo()
    plan._unhealthy = None


# ---------------------------------------------------------------------------
# guarded_solve: self-healing on every suite class
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", TINY)
def test_guarded_solve_survives_midsolve_fault(name):
    a = _spd(testmats.suite("tiny")[name])
    ops = op.OperatorSet(a, C=32, sigma=64)
    rng = np.random.default_rng(17)
    b = rng.standard_normal(a.shape[0])

    fired = []

    def sabotage(step, ctx):
        if step == 1 and not fired and ctx["plan"] is not None \
                and ctx["plan"].fused is not None:
            fired.append(
                inj.flip_fused_word(ctx["mat"], ctx["plan"], seed=19,
                                    bit=27))

    x, info = rc.guarded_solve(ops, "guarded:plan_fp16", b, tol=1e-8,
                               maxiter=60, m_in=16, on_step=sabotage)
    r = b - a @ x
    assert np.linalg.norm(r) / np.linalg.norm(b) <= 1e-8
    assert info.relres <= 1e-8
    assert fired, "solve converged before the fault could fire"
    assert info.trips >= 1
    # the log is machine-readable and names the escalation taken
    assert all({"step", "event", "action", "detail"} <= set(e) for e in
               info.log)
    assert info.log[0]["action"] in ("retry", "promote", "rebuild",
                                    "fp32_fallback")


def test_guarded_solve_clean_no_trips():
    a = _spd(testmats.suite("tiny")["stencil1d"])
    ops = op.OperatorSet(a, C=32, sigma=64)
    b = np.random.default_rng(3).standard_normal(a.shape[0])
    x, info = rc.guarded_solve(ops, "plan_fp16", b, tol=1e-9, maxiter=60)
    assert info.trips == 0 and info.log == []
    assert info.relres <= 1e-9
    assert info.final_kind == "plan_fp16"


def test_guarded_solve_poisoned_x_heals():
    a = _spd(testmats.suite("tiny")["scattered"])
    ops = op.OperatorSet(a, C=32, sigma=64)
    b = np.random.default_rng(5).standard_normal(a.shape[0])

    def sabotage(step, ctx):
        if step == 1:
            # poison the live iterate in place: revert must come from the
            # snapshot, not the (mutated) live array
            ctx["x"][0] = np.nan

    x, info = rc.guarded_solve(ops, "plan_fp16", b, tol=1e-8, maxiter=60,
                               on_step=sabotage)
    assert np.all(np.isfinite(x))
    assert info.relres <= 1e-8
    assert info.trips >= 1
    assert any(e["event"] in ("nonfinite_residual", "guard_trip",
                              "divergence") for e in info.log)


def test_promotion_ladder_shape():
    lad = rc.promotion_ladder("plan_fp16")
    assert lad[0] == "plan_fp16" and lad[-1] == "fp32"
    assert len(lad) >= 2
    with pytest.raises(ValueError):
        rc.promotion_ladder("fp64")


# ---------------------------------------------------------------------------
# distributed cases (multi-device gated)
# ---------------------------------------------------------------------------

@need4
def test_dist_nan_halo_detected():
    from repro.distributed import build_dist_plan
    a = _spd(testmats.random_banded(256, 16, 5, seed=8))
    dplan = build_dist_plan(a, C=8, sigma=16, codec="fp16")
    x = np.ones(a.shape[0])
    y0 = np.asarray(dplan.spmv(jnp.asarray(x, jnp.float32)))
    assert np.all(np.isfinite(y0))
    # poison an entry that travels through the halo exchange
    xp, i = inj.poison_x(x, seed=21, mode="nan")
    y = np.asarray(dplan.spmv(jnp.asarray(xp, jnp.float32)))
    assert not np.all(np.isfinite(y))   # the detection signal


@need4
def test_dist_checkpoint_corruption_detected_and_undone():
    from repro.distributed import build_dist_plan
    a = _spd(testmats.random_banded(256, 16, 5, seed=8))
    dplan = build_dist_plan(a, C=8, sigma=16, codec="fp16")
    if not any(k.endswith("_fckpt") for k in dplan.dev):
        pytest.skip("no fused checkpoints in this dist plan variant")
    x = jnp.asarray(_x(a.shape[0], seed=2))
    y0 = np.asarray(dplan.spmv(x))
    keys = sorted(k for k in dplan.dev if k.endswith("_fckpt"))
    ref = gd.checksum([np.asarray(dplan.dev[k]) for k in keys])
    i = inj.corrupt_dist_checkpoint(dplan, seed=23)
    assert gd.checksum([np.asarray(dplan.dev[k]) for k in keys]) != ref
    y1 = np.asarray(dplan.spmv(x))
    assert not np.array_equal(y0, y1)   # the corruption reached the kernel
    i.undo()
    assert gd.checksum([np.asarray(dplan.dev[k]) for k in keys]) == ref
    assert np.array_equal(np.asarray(dplan.spmv(x)), y0)


# ---------------------------------------------------------------------------
# composite injection
# ---------------------------------------------------------------------------

def test_composite_corruption_detected_by_validate_or_checksum():
    from repro.kernels import composite as kc
    a = _spd(testmats.random_banded(128, 8, 3, seed=10))
    comp = kc.CompositePlan.from_classes(a, [("fp16", 15, None)], C=8,
                                         sigma=16)
    assert comp.validate(raise_=False) == []
    mem = next(i for i, m in enumerate(comp.members) if m.plan is not None)
    x = _x(a.shape[0], seed=6)
    y0 = np.asarray(comp.spmv(x))
    ref = gd.checksum(gd.guard_arrays(comp.members[mem].mat,
                                      comp.members[mem].plan))
    i = inj.corrupt_composite_word(comp, mem, seed=12)
    assert gd.checksum(gd.guard_arrays(comp.members[mem].mat,
                                       comp.members[mem].plan)) != ref
    y1 = np.asarray(comp.spmv(x))
    if not i.value_neutral:
        assert not np.array_equal(y0, y1)
    i.undo()
    assert np.array_equal(np.asarray(comp.spmv(x)), y0)


# ---------------------------------------------------------------------------
# precision-store quarantine + lock (satellite a)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["truncate", "garble"])
def test_store_corruption_quarantined(tmp_path, mode):
    from repro.precision.store import PrecisionStore
    p = str(tmp_path / "store.json")
    s = PrecisionStore(p)
    s.put_retile("fp0", "plan_fp16", [(8, 32)])
    i = inj.corrupt_store(p, seed=31, mode=mode)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        s2 = PrecisionStore(p)
    try:
        json.load(open(p))
        corrupted_parsed = True     # garble can leave valid JSON...
    except Exception:
        corrupted_parsed = False
    if len(s2) == 0:
        # quarantined: warned, sidecar file kept, store empty but usable
        assert any("quarantined" in str(x.message) for x in w)
        assert os.path.exists(p + ".corrupt")
        s2.put_retile("fp1", "plan_fp16", [(4, 16)])
        assert PrecisionStore(p).get_retile("fp1", "plan_fp16") == [(4, 16)]
    else:
        # astronomically unlikely: garbling produced a different valid
        # store — still a clean load, nothing crashed
        assert corrupted_parsed
    i.undo()


def test_store_concurrent_writers_merge(tmp_path):
    from repro.precision.store import PrecisionStore
    p = str(tmp_path / "store.json")
    s1 = PrecisionStore(p)
    s2 = PrecisionStore(p)
    s1.put_retile("A", "k", [(8, 32)])
    s2.put_retile("B", "k", [(4, 16)])     # would clobber A without merge
    final = PrecisionStore(p)
    assert final.get_retile("A", "k") == [(8, 32)]
    assert final.get_retile("B", "k") == [(4, 16)]
    assert os.path.exists(p + ".lock")


# ---------------------------------------------------------------------------
# bounded caches (satellite b)
# ---------------------------------------------------------------------------

def test_plan_cache_lru_eviction_bit_identical(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_CAP", "2")
    kplan.clear_cache()
    a = testmats.stencil_1d(160, 2)
    mats = [pk.from_csr(a.tocsr(), C=8, sigma=16) for _ in range(3)]
    x = _x(mats[0].m, seed=7)
    y0 = np.asarray(kplan.get_plan(mats[0]).spmv(mats[0], x))
    kplan.get_plan(mats[1])
    kplan.get_plan(mats[2])                # evicts mats[0]'s plan
    stats = kplan.cache_stats()
    assert stats["size"] <= 2 and stats["evicted"] >= 1
    # rebuilt plan produces a bit-identical result
    y1 = np.asarray(kplan.get_plan(mats[0]).spmv(mats[0], x))
    assert np.array_equal(y0, y1)


def test_plan_cache_lru_hit_refreshes_recency(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_CAP", "2")
    kplan.clear_cache()
    a = testmats.stencil_1d(160, 2)
    mats = [pk.from_csr(a.tocsr(), C=8, sigma=16) for _ in range(3)]
    p0 = kplan.get_plan(mats[0])
    kplan.get_plan(mats[1])
    assert kplan.get_plan(mats[0]) is p0   # hit → MRU
    kplan.get_plan(mats[2])                # evicts mats[1], not mats[0]
    assert kplan.get_plan(mats[0]) is p0


def test_jit_cache_bounded(monkeypatch):
    monkeypatch.setenv("REPRO_JIT_CACHE_CAP", "4")
    mat, plan = _mat_plan(testmats.stencil_1d(96, 2), C=8, sigma=16)
    plan._fns.clear()
    y_first = np.asarray(plan.spmm(mat, jnp.ones((mat.m, 1), jnp.float32)))
    for nb in range(2, 10):                # distinct shapes → new entries
        plan.spmm(mat, jnp.ones((mat.m, nb), jnp.float32))
    assert len(plan._fns) <= 4
    # evicted entry retraces and stays bit-identical
    y_again = np.asarray(plan.spmm(mat, jnp.ones((mat.m, 1), jnp.float32)))
    assert np.array_equal(y_first, y_again)


# ---------------------------------------------------------------------------
# input validation (satellite c)
# ---------------------------------------------------------------------------

def test_from_dense_rejects_nonfinite_and_bad_shape():
    with pytest.raises(ValueError, match="non-finite"):
        pk.from_dense(np.array([[1.0, np.nan], [0.0, 2.0]]), C=1, sigma=1)
    with pytest.raises(ValueError, match="non-finite"):
        pk.from_dense(np.array([[np.inf, 1.0], [0.0, 2.0]]), C=1, sigma=1)
    with pytest.raises(ValueError, match="2-D"):
        pk.from_dense(np.ones(4), C=1, sigma=1)


def test_from_csr_rejects_nonfinite():
    a = sp.random(20, 20, density=0.2, random_state=0, format="csr")
    a.data[0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        pk.from_csr(a, C=4, sigma=8)


def test_debug_finite_env_guard(monkeypatch):
    mat, plan = _mat_plan(testmats.stencil_1d(96, 2), C=8, sigma=16)
    xp, _ = inj.poison_x(np.ones(mat.m), seed=2)
    x_bad = jnp.asarray(xp, jnp.float32)
    # off (default): NaNs flow through silently
    monkeypatch.delenv("REPRO_DEBUG_FINITE", raising=False)
    kops.packsell_spmv(mat, x_bad)
    monkeypatch.setenv("REPRO_DEBUG_FINITE", "1")
    with pytest.raises(FloatingPointError, match="non-finite"):
        kops.packsell_spmv(mat, x_bad)
    kops.packsell_spmv(mat, jnp.ones((mat.m,), jnp.float32))  # clean ok
