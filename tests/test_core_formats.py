"""Unit tests: codecs, delta encoding, PackSELL/SELL construction + SpMV."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import codecs as cd
from repro.core import delta as de
from repro.core import packsell, sell, sparse, testmats


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec,D", [("fp16", 15), ("fp16", 8), ("bf16", 15),
                                     ("e8m", 1), ("e8m", 8), ("e8m", 15),
                                     ("fixed16", 10)])
def test_codec_roundtrip_words(codec, D):
    rng = np.random.default_rng(0)
    c = cd.make_codec(codec)
    vals = rng.standard_normal(256).astype(np.float32)
    deltas = rng.integers(0, 1 << D, size=256)
    flags = np.ones(256, dtype=np.uint8)
    words = cd.pack_words_np(vals, deltas, flags, c, D)
    v_np, d_np, f_np = cd.unpack_words_np(words, c, D)
    v_j, d_j = cd.unpack_words_jnp(jnp.asarray(words), c, D)
    np.testing.assert_array_equal(d_np, deltas)
    np.testing.assert_array_equal(np.asarray(d_j), deltas)
    np.testing.assert_allclose(np.asarray(v_j, np.float32),
                               np.asarray(v_np, np.float32))
    # quantization error bounded by the codec's precision
    want = cd.quantize_np(vals, c, D)
    np.testing.assert_allclose(np.asarray(v_np, np.float32), want, rtol=0,
                               atol=0)


def test_dummy_words_carry_large_deltas():
    c = cd.make_codec("fp16")
    D = 4
    deltas = np.array([0, 3, 100, (1 << 30) + 5], dtype=np.int64)
    flags = np.array([1, 1, 0, 0], dtype=np.uint8)
    words = cd.pack_words_np(np.zeros(4, np.float32), deltas, flags, c, D)
    v, d, f = cd.unpack_words_np(words, c, D)
    np.testing.assert_array_equal(d, deltas)
    np.testing.assert_array_equal(f, flags)
    assert np.all(np.asarray(v, np.float32)[f == 0] == 0.0)


def test_e8m_matches_bf16_at_d15():
    # E8M7 (D=15) is bit-identical to RNE bf16 truncation
    rng = np.random.default_rng(1)
    vals = (rng.standard_normal(512) *
            10.0 ** rng.integers(-3, 3, 512)).astype(np.float32)
    e = cd.quantize_np(vals, cd.make_codec("e8m"), 15)
    b = cd.quantize_np(vals, cd.make_codec("bf16"), 15)
    np.testing.assert_array_equal(e, b)


def test_e8m_error_decreases_with_mantissa():
    rng = np.random.default_rng(2)
    vals = rng.standard_normal(4096).astype(np.float32)
    errs = []
    for D in (15, 10, 5, 1):  # Y = 7, 12, 17, 21
        q = cd.quantize_np(vals, cd.make_codec("e8m"), D)
        errs.append(np.abs(q - vals).max())
    assert errs == sorted(errs, reverse=True) or errs[0] > errs[-1]
    # E8M21 (D=1): 2 dropped bits -> tiny error
    assert errs[-1] <= 2.0 ** -19


# ---------------------------------------------------------------------------
# delta encoding
# ---------------------------------------------------------------------------

def test_delta_encoding_banded_has_no_dummies():
    a = testmats.stencil_1d(300, 2)
    indptr, indices = a.indptr.astype(np.int64), a.indices.astype(np.int64)
    k_left = de.lower_bandwidth(indptr, indices, a.shape[0])
    assert k_left == 2
    d0 = de.d0_for_rows(a.shape[0], 256, k_left)
    deltas, needs_dummy, stored = de.encode_rows(indptr, indices, d0, D=15)
    assert needs_dummy.sum() == 0
    assert np.all(deltas >= 0)


def test_delta_encoding_scattered_needs_dummies():
    a = testmats.scattered(400, nnz_per_row=6, seed=3)
    indptr, indices = a.indptr.astype(np.int64), a.indices.astype(np.int64)
    k_left = de.lower_bandwidth(indptr, indices, a.shape[0])
    d0 = de.d0_for_rows(a.shape[0], 256, k_left)
    _, needs_dummy, _ = de.encode_rows(indptr, indices, d0, D=2)
    assert needs_dummy.sum() > 0


# ---------------------------------------------------------------------------
# format construction + SpMV vs dense oracle
# ---------------------------------------------------------------------------

MATS = list(testmats.suite("tiny").items())


def _tol_for(codec, D):
    if codec in ("fp16", "bf16"):
        return 2e-2
    return max(2.0 ** -(22 - D), 1e-6) * 40


@pytest.mark.parametrize("name,a", MATS, ids=[m[0] for m in MATS])
@pytest.mark.parametrize("codec,D", [("fp16", 15), ("e8m", 2), ("e8m", 12)])
def test_packsell_spmv_matches_dense(name, a, codec, D):
    mat = packsell.from_csr(a, C=8, sigma=32, D=D, codec=codec)
    dense_q = packsell.decode_to_dense(mat)
    # decode must reproduce the quantized matrix exactly
    want = cd.quantize_np(a.toarray().astype(np.float32),
                          cd.make_codec(codec), D)
    np.testing.assert_allclose(dense_q, want, rtol=0, atol=0)
    rng = np.random.default_rng(4)
    x = rng.standard_normal(a.shape[1]).astype(np.float32)
    y = np.asarray(mat.spmv(jnp.asarray(x)))
    y_ref = want.astype(np.float64) @ x
    scale = np.abs(want).sum(axis=1) @ np.abs(x) / max(a.shape[0], 1) + 1e-30
    assert np.max(np.abs(y - y_ref)) / max(np.abs(y_ref).max(), 1e-30) < 1e-5


@pytest.mark.parametrize("name,a", MATS, ids=[m[0] for m in MATS])
def test_sell_spmv_matches_dense(name, a):
    mat = sell.from_csr(a, C=8, sigma=32, value_dtype="float32")
    rng = np.random.default_rng(5)
    x = rng.standard_normal(a.shape[1]).astype(np.float32)
    y = np.asarray(mat.spmv(jnp.asarray(x)))
    y_ref = a.astype(np.float64) @ x
    np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name,a", MATS, ids=[m[0] for m in MATS])
def test_csr_coo_spmv(name, a):
    rng = np.random.default_rng(6)
    x = rng.standard_normal(a.shape[1]).astype(np.float32)
    for build in (sparse.csr_from_scipy, sparse.coo_from_scipy):
        mat = build(a)
        y = np.asarray(mat.spmv(jnp.asarray(x)))
        np.testing.assert_allclose(y, a.astype(np.float64) @ x,
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bucket", ["pow2", "uniform", "exact"])
def test_bucket_strategies_agree(bucket):
    a = testmats.powerlaw(300, mean_deg=4, seed=7)
    rng = np.random.default_rng(8)
    x = rng.standard_normal(a.shape[1]).astype(np.float32)
    ref = None
    mat = packsell.from_csr(a, C=4, sigma=16, D=8, codec="e8m",
                            bucket_strategy=bucket)
    y = np.asarray(mat.spmv(jnp.asarray(x)))
    want = cd.quantize_np(a.toarray().astype(np.float32),
                          cd.make_codec("e8m"), 8).astype(np.float64) @ x
    np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)


def test_memory_footprint_ratio_banded():
    """Paper Fig. 7: dense-banded matrices approach the 0.75 lower bound."""
    a = testmats.random_banded(4096, 40, 30, seed=9)
    pmat = packsell.from_csr(a, C=32, sigma=256, D=15, codec="fp16")
    smat = sell.from_csr(a, C=32, sigma=256, value_dtype="float16")
    r = pmat.memory_stats()["packsell_bytes"] / smat.memory_stats()["sell_bytes"]
    assert pmat.n_dummy == 0
    assert 0.6 < r < 0.8  # 32 bits vs 48 bits ≈ 0.67 + perm/offsets


def test_empty_and_tiny_matrices():
    a = sp.csr_matrix((8, 8))
    mat = packsell.from_csr(a, C=4, sigma=8, D=5, codec="e8m")
    y = np.asarray(mat.spmv(jnp.ones(8, jnp.float32)))
    np.testing.assert_array_equal(y, np.zeros(8))
    a2 = sp.csr_matrix(np.eye(3, dtype=np.float32))
    mat2 = packsell.from_csr(a2, C=4, sigma=8, D=5, codec="e8m")
    y2 = np.asarray(mat2.spmv(jnp.arange(3).astype(jnp.float32)))
    np.testing.assert_allclose(y2, [0.0, 1.0, 2.0])


def test_rectangular_matrix():
    a = testmats.scattered(96, m=200, nnz_per_row=4, seed=10)
    mat = packsell.from_csr(a, C=8, sigma=16, D=6, codec="e8m")
    x = np.random.default_rng(11).standard_normal(200).astype(np.float32)
    want = cd.quantize_np(a.toarray().astype(np.float32),
                          cd.make_codec("e8m"), 6).astype(np.float64) @ x
    np.testing.assert_allclose(np.asarray(mat.spmv(jnp.asarray(x))), want,
                               rtol=1e-5, atol=1e-5)
