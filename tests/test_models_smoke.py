"""Per-architecture smoke tests: reduced config, one forward/train step and
one prefill+decode step on CPU, asserting shapes + finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import SHAPES, io_spec, transformer as tfm


def _tiny_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    fl, tl = io_spec.frontend_lens(cfg, S)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, tl)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, tl)), jnp.int32),
        "mask": jnp.ones((B, tl), jnp.float32),
    }
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, fl, io_spec.STUB_DIM)), jnp.float32)
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, fl, io_spec.STUB_DIM)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_forward(arch):
    cfg = configs.reduce(configs.get(arch))
    params, specs = tfm.init_params(cfg, jax.random.PRNGKey(0))
    # spec tree mirrors param tree
    assert jax.tree.structure(jax.tree.map(lambda x: 0, params)) == \
        jax.tree.structure(jax.tree.map(lambda s: 0, specs,
                                        is_leaf=lambda s: not isinstance(s, dict)))
    batch = _tiny_batch(cfg)
    loss = jax.jit(lambda p, b: tfm.forward_train(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_grads_finite(arch):
    cfg = configs.reduce(configs.get(arch))
    params, _ = tfm.init_params(cfg, jax.random.PRNGKey(1))
    batch = _tiny_batch(cfg, seed=1)
    g = jax.jit(jax.grad(lambda p, b: tfm.forward_train(cfg, p, b)))(
        params, batch)
    leaves = jax.tree.leaves(g)
    assert leaves
    for leaf in leaves:
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), arch
    # at least one nonzero gradient
    assert any(np.any(np.asarray(l) != 0) for l in leaves), arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = configs.reduce(configs.get(arch))
    params, _ = tfm.init_params(cfg, jax.random.PRNGKey(2))
    B, S, MAX = 2, 16, 32
    batch = _tiny_batch(cfg, B=B, S=S, seed=2)
    batch.pop("labels")
    batch.pop("mask")
    logits, cache = jax.jit(
        lambda p, b: tfm.forward_prefill(cfg, p, b, MAX))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    step = jax.jit(lambda p, t, c: tfm.forward_decode(cfg, p, t, c))
    for _ in range(3):
        logits, cache = step(params, tok, cache)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    assert int(cache["len"][0]) == (S if cfg.family != "encdec" else S) + 3


def test_param_count_sanity_full_configs():
    """Analytic parameter counts for the FULL configs are in the right
    ballpark (name plausibility check, no allocation)."""
    approx = {
        "internlm2-20b": 20e9, "yi-6b": 6e9, "granite-3-2b": 2.6e9,
        "qwen2-0.5b": 0.5e9, "dbrx-132b": 132e9, "qwen2-moe-a2.7b": 14e9,
        "llava-next-mistral-7b": 7.2e9, "zamba2-2.7b": 2.7e9,
        "mamba2-1.3b": 1.3e9, "seamless-m4t-large-v2": 1.4e9,
    }
    for arch, want in approx.items():
        n = configs.get(arch).param_count()
        assert 0.5 * want < n < 2.1 * want, (arch, n, want)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_abstract_params_no_allocation(arch):
    """FULL configs must be shape-inferable without allocating memory."""
    cfg = configs.get(arch)
    shapes, specs = tfm.abstract_params(cfg)
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    # matches the analytic count to within 2% (analytic omits small norms)
    assert abs(total - cfg.param_count()) / cfg.param_count() < 0.02, arch


def test_decode_matches_prefill_continuation():
    """Decoding token-by-token must agree with prefilling the longer prompt
    (cache correctness), for a dense arch."""
    cfg = configs.reduce(configs.get("yi-6b"))
    params, _ = tfm.init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 9)), jnp.int32)
    # prefill 8, decode the 9th
    l8, cache = tfm.forward_prefill(cfg, params, {"tokens": toks[:, :8]}, 16)
    l9_dec, _ = tfm.forward_decode(cfg, params, toks[:, 8:9], cache)
    # prefill all 9: last-position logits must match the decode step
    l9_pre, _ = tfm.forward_prefill(cfg, params, {"tokens": toks}, 16)
    np.testing.assert_allclose(np.asarray(l9_dec, np.float32),
                               np.asarray(l9_pre, np.float32),
                               rtol=2e-3, atol=2e-3)
