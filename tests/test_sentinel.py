"""Perf sentinel (DESIGN.md §13): exporters, span profiling, and the
noise-aware benchmark regression gate.

The load-bearing guarantees:

* **Prometheus round-trip** — ``prometheus_text`` → ``parse_prometheus_
  text`` reproduces the registry exactly: dotted names (via # HELP),
  label values with quotes/backslashes/newlines, histogram summaries
  with reservoir quantiles;
* **JSONL sink under fire** — concurrent flushers + a registry reset
  mid-stream produce only whole records, monotone sequence numbers, and
  a rebase marker instead of negative deltas;
* **trajectory schema contract** — every committed BENCH_*.json ingests
  (they all carry the schema-versioned meta header); a pre-schema file
  is rejected with an error that says how to fix it;
* **gate statistics** — the two-threshold design: single-class noise
  within severe_tol passes, correlated multi-class drift fails, and a
  synthetic 2x slowdown on ONE class fails (the severe path);
* **span profiling** — attribution on a real plan dispatch accounts for
  the measured wall (or degrades to an explicit ``profiler_unavailable``
  wallclock fallback when tracing is unavailable).
"""
from __future__ import annotations

import glob
import json
import os
import threading

import numpy as np
import pytest

from repro import observe
from repro.observe import export, metrics, trajectory

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def obs_on():
    prev = observe.enable(True)
    observe.reset()
    yield
    observe.reset()
    observe.enable(prev)


# ---------------------------------------------------------------------------
# reservoir quantiles (metrics satellite)
# ---------------------------------------------------------------------------


def test_histogram_quantiles_in_snapshot(obs_on):
    for i in range(1, 1001):
        metrics.observe("q.test", float(i))
    snap = observe.snapshot()
    h = snap["histograms"]["q.test"]
    assert h["count"] == 1000
    # cap-256 reservoir over a uniform ramp: quantiles are approximate
    assert 350 <= h["p50"] <= 650
    assert h["p95"] >= 800
    assert h["p99"] >= 850
    assert h["p50"] <= h["p95"] <= h["p99"] <= h["max"] == 1000


def test_small_histogram_quantiles_exact(obs_on):
    for v in (1.0, 2.0, 3.0, 4.0):
        metrics.observe("q.small", v)
    h = observe.snapshot()["histograms"]["q.small"]
    # below the reservoir cap the sample IS the population: nearest-rank
    assert h["p50"] == 3.0 and h["p99"] == 4.0


def test_observe_disabled_records_nothing():
    prev = observe.enable(False)
    try:
        metrics.observe("q.off", 1.0)
        assert metrics.raw_snapshot()["histograms"] == {}
    finally:
        observe.enable(prev)


# ---------------------------------------------------------------------------
# Prometheus exposition round-trip
# ---------------------------------------------------------------------------


def _populate():
    metrics.inc("spmv.dispatch", 3, variant="jnp", codec="fp16")
    metrics.inc("spmv.dispatch", 2, variant="band", codec="e8m")
    metrics.inc("serving.tick", 7)
    metrics.gauge("spmv.bytes_per_nnz", 7.51, codec="fp16")
    metrics.gauge("weird.gauge", -2.5, note='quo"te', path="a\\b", nl="x\ny")
    for v in (0.1, 0.2, 0.4, 0.8):
        metrics.observe("solver.time_s", v, solver="pcg")


def test_prometheus_round_trip_exact(obs_on):
    _populate()
    snap = metrics.raw_snapshot()
    text = export.prometheus_text()
    back = export.parse_prometheus_text(text)
    assert back["counters"] == snap["counters"]
    assert back["gauges"] == snap["gauges"]
    assert set(back["histograms"]) == set(snap["histograms"])
    for k, h in snap["histograms"].items():
        assert back["histograms"][k] == {
            f: h[f] for f in ("p50", "p95", "p99",
                              "count", "sum", "min", "max", "last")}


def test_prometheus_text_shape(obs_on):
    _populate()
    text = export.prometheus_text()
    assert "# HELP spmv_dispatch spmv.dispatch" in text
    assert "# TYPE spmv_dispatch counter" in text
    assert 'quantile="0.5"' in text
    assert "solver_time_s_count" in text
    # escaped label values stay on one sample line
    [line] = [l for l in text.splitlines() if l.startswith("weird_gauge")]
    assert '\\n' in line and '\\"' in line


def test_prometheus_rejects_malformed():
    with pytest.raises(ValueError, match="malformed"):
        export.parse_prometheus_text("# TYPE x counter\nx{ 1\n")
    with pytest.raises(ValueError, match="no # TYPE"):
        export.parse_prometheus_text("nosuch 1\n")


# ---------------------------------------------------------------------------
# JSONL sink
# ---------------------------------------------------------------------------


def test_jsonl_sink_delta_semantics(obs_on, tmp_path):
    p = tmp_path / "m.jsonl"
    sink = export.JsonlSink(str(p), meta={"run": "t1"})
    metrics.inc("c.a", 5)
    sink.flush()
    metrics.inc("c.a", 2)
    metrics.gauge("g.b", 1.5)
    sink.flush()
    recs = export.JsonlSink.read(str(p))
    assert recs[0]["kind"] == "meta" and recs[0]["run"] == "t1"
    assert recs[1]["counters"] == {"c.a": 5}
    assert recs[2]["counters"] == {"c.a": 2}
    assert recs[2]["gauges"]["g.b"] == 1.5


def test_jsonl_sink_rebase_after_reset(obs_on, tmp_path):
    p = tmp_path / "m.jsonl"
    sink = export.JsonlSink(str(p))
    metrics.inc("c.a", 10)
    sink.flush()
    observe.reset()
    metrics.inc("c.a", 3)          # absolute 3 < last-flushed 10
    sink.flush()
    recs = export.JsonlSink.read(str(p))
    assert recs[-1]["rebased"] is True
    assert recs[-1]["counters"] == {"c.a": 3}


def test_jsonl_sink_concurrent_flush_and_reset(obs_on, tmp_path):
    p = tmp_path / "m.jsonl"
    sink = export.JsonlSink(str(p))
    stop = threading.Event()

    def hammer():
        while not stop.is_set():
            sink.flush()

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for i in range(300):
        metrics.inc("c.hot", 1, lane=str(i % 3))
        metrics.observe("h.hot", float(i % 7))
        if i == 150:
            observe.reset()
    stop.set()
    for t in threads:
        t.join()
    sink.flush()
    recs = export.JsonlSink.read(str(p))    # every line parsed = whole
    assert recs[0]["kind"] == "meta"
    deltas = [r for r in recs[1:] if r["kind"] == "delta"]
    assert [r["seq"] for r in deltas] == list(range(len(deltas)))
    for r in deltas:
        assert all(v >= 0 for v in r["counters"].values())


def test_exporter_thread_clean_shutdown(obs_on, tmp_path):
    p = tmp_path / "exp.jsonl"
    exp = export.start_exporter(interval_s=0.05, path=str(p))
    try:
        metrics.inc("c.exp", 4)
        import time
        time.sleep(0.2)
    finally:
        exp.stop()
    assert not exp.alive
    recs = export.JsonlSink.read(str(p))
    total = sum(r.get("counters", {}).get("c.exp", 0)
                for r in recs if r["kind"] == "delta")
    assert total == 4                       # final flush lost nothing
    n = len(recs)
    exp.stop()                              # idempotent
    assert len(export.JsonlSink.read(str(p))) == n


# ---------------------------------------------------------------------------
# trajectory schema contract
# ---------------------------------------------------------------------------


def test_ingest_accepts_every_committed_bench_file():
    files = sorted(glob.glob(os.path.join(REPO, "BENCH_*.json")))
    assert files, "no committed BENCH files?"
    for f in files:
        recs = trajectory.ingest(f)
        assert recs, f"{f} produced no trajectory records"
        for r in recs:
            assert r["schema_version"] >= 1
            assert {"bench", "klass", "metric", "value"} <= set(r)


def test_ingest_rejects_pre_schema_file(tmp_path):
    p = tmp_path / "BENCH_old.json"
    p.write_text(json.dumps({"scale": "small", "rows": [{"t": 1.0}]}))
    with pytest.raises(trajectory.SchemaError,
                       match="pre-schema-version"):
        trajectory.ingest(str(p))
    p2 = tmp_path / "BENCH_v0.json"
    p2.write_text(json.dumps({"meta": {"schema_version": 0}, "rows": []}))
    with pytest.raises(trajectory.SchemaError, match="schema_version"):
        trajectory.ingest(str(p2))


def test_ingest_spmv_yields_gated_metric():
    recs = trajectory.ingest(os.path.join(REPO, "BENCH_spmv.json"))
    keys = {(r["bench"], r["metric"]) for r in recs}
    assert ("spmv", "dispatch_cached_s") in keys
    assert ("spmv", "fused_speedup_vs_pr1") in keys


# ---------------------------------------------------------------------------
# gate statistics
# ---------------------------------------------------------------------------


def _recs(**times):
    """Synthetic gated records: klass -> dispatch_cached_s."""
    return [{"bench": "spmv", "klass": k, "codec": "", "scale": "tiny",
             "metric": "dispatch_cached_s", "value": v,
             "git_sha": "t", "backend": "cpu"}
            for k, v in times.items()]


def _baseline():
    runs = [_recs(a=1.00, b=2.00, c=4.00),
            _recs(a=1.05, b=1.95, c=4.10),
            _recs(a=0.95, b=2.05, c=3.90)]
    return trajectory.build_baseline(runs)


def test_gate_passes_clean():
    res = trajectory.gate(_recs(a=1.02, b=1.98, c=4.05), _baseline())
    assert res["ok"] and not res["regressed"]
    assert len(res["checked"]) == 3


def test_gate_single_class_noise_passes():
    # one class +40%: above rel_tol but below severe_tol, only 1 cell
    res = trajectory.gate(_recs(a=1.40, b=2.00, c=4.00), _baseline())
    assert res["ok"]
    assert len(res["regressed"]) == 1 and not res["severe"]


def test_gate_fails_on_synthetic_2x_single_class():
    # the acceptance self-test: 2x slowdown in ONE bench class must fail
    res = trajectory.gate(_recs(a=2.00, b=2.00, c=4.00), _baseline())
    assert not res["ok"]
    assert len(res["severe"]) == 1
    assert res["severe"][0]["klass"] == "a"


def test_gate_fails_on_correlated_drift():
    # +40% on two classes: each alone tolerable (see the single-class
    # test above), together a real slowdown -> min_classes=2 trips
    res = trajectory.gate(_recs(a=1.40, b=2.80, c=4.00), _baseline())
    assert not res["ok"] and len(res["regressed_classes"]) == 2


def test_gate_iqr_widens_threshold():
    # a key whose baseline reps are wildly dispersed gets a wider lane
    runs = [_recs(a=1.0), _recs(a=2.0), _recs(a=1.5)]
    base = trajectory.build_baseline(runs)
    res = trajectory.gate(_recs(a=2.2), base)     # +47% vs median 1.5
    assert res["ok"], res        # 3x IQR/median = 2.0 > observed drift


def test_gate_direction_inversion():
    runs = [[{"bench": "roofline", "klass": "k", "codec": "fp16",
              "metric": "achieved_frac_of_peak", "value": 0.30,
              "scale": "tiny", "git_sha": "t", "backend": "cpu"}]] * 3
    base = trajectory.build_baseline(runs)
    cur = [dict(runs[0][0], value=0.10)]          # higher-is-better fell 3x
    res = trajectory.gate(cur, base)
    assert not res["ok"] and res["severe"]


def test_gate_scale_mismatch_skips():
    base = _baseline()
    cur = _recs(a=5.0)
    for r in cur:
        r["scale"] = "small"
    res = trajectory.gate(cur, base)
    assert res["ok"]
    assert res["skipped"] and "scale mismatch" in res["skipped"][0]["reason"]


def test_baseline_save_load_round_trip(tmp_path):
    p = tmp_path / "base.json"
    trajectory.save_baseline(_baseline(), str(p))
    assert trajectory.load_baseline(str(p))["entries"]
    bad = {"meta": {"schema_version": 99}, "entries": {}}
    p2 = tmp_path / "bad.json"
    p2.write_text(json.dumps(bad))
    with pytest.raises(trajectory.SchemaError, match="perf-baseline"):
        trajectory.load_baseline(str(p2))


# ---------------------------------------------------------------------------
# span profiling
# ---------------------------------------------------------------------------


def test_hlo_span_map_parses_scope_paths():
    from repro.observe import profile
    txt = (
        'HloModule jit__execute, entry_computation_layout={()->f32[4]}\n'
        '  %fusion.1 = f32[4] fusion(), metadata={op_name="jit(f)/'
        'packsell.fused_decode/mul"}\n'
        '  ROOT %gather.2 = f32[4] gather(), metadata={op_name="jit(f)/'
        'packsell.gather_epilogue/gather"}\n'
        '  %other.3 = f32[4] add(), metadata={op_name="jit(f)/plain/add"}\n'
    )
    m = profile.hlo_span_map(txt)
    assert m[("jit__execute", "fusion.1")] == "packsell.fused_decode"
    assert m[("jit__execute", "gather.2")] == "packsell.gather_epilogue"
    assert ("jit__execute", "other.3") not in m


def test_profile_dispatch_attributes_plan_spans(obs_on):
    import jax
    from repro.core import packsell as pk
    from repro.core import testmats
    from repro.kernels import plan as kplan
    from repro.observe import profile

    a = testmats.suite("tiny")["hpcg_mini"]
    mat = pk.from_csr(a.tocsr(), C=32, sigma=256, D=15, codec="fp16")
    plan = kplan.get_plan(mat)
    import jax.numpy as jnp
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(mat.n).astype(np.float32))
    fn = jax.jit(plan._execute, static_argnums=(3,))
    txt = fn.lower(plan._exec_mat(mat), plan._device_operands(), x,
                   False).compile().as_text()
    prof = profile.profile_dispatch(
        lambda v: plan.spmv(mat, v), x, hlo_texts=(txt,), repeats=5)
    if prof.profiler_unavailable:
        assert prof.mode == "wallclock" and prof.wall_s > 0
        return
    assert prof.mode == "trace"
    # the acceptance figure: the breakdown explains >= 80% of the wall
    assert prof.accounted_frac_of_wall >= 0.8
    assert prof.attributed_frac >= 0.8
    assert any(s["device_s"] > 0 for s in prof.spans.values())
    d = prof.to_dict()
    assert d["spans"] and d["wall_s"] > 0


def test_profile_dispatch_fallback_marker(obs_on, monkeypatch):
    import jax
    from repro.observe import profile

    def boom(*a, **k):
        raise RuntimeError("no profiler here")

    monkeypatch.setattr(jax.profiler, "trace", boom)
    f = jax.jit(lambda x: x * 2.0)
    prof = profile.profile_dispatch(f, np.float32(3.0), repeats=3)
    assert prof.profiler_unavailable is True
    assert prof.mode == "wallclock"
    assert "trace failed" in prof.note
    assert prof.wall_s > 0


# ---------------------------------------------------------------------------
# wiring: save_bench_json + serving endpoint
# ---------------------------------------------------------------------------


def test_save_bench_json_embeds_report_and_archive(obs_on, tmp_path,
                                                  monkeypatch):
    from benchmarks import common
    monkeypatch.setenv("REPRO_OBS_ARCHIVE_DIR", str(tmp_path / "obs"))
    metrics.inc("c.bench", 2)
    out = tmp_path / "BENCH_x.json"
    common.save_bench_json(str(out), {"rows": [{"klass": "k", "t_s": 1.0}]})
    d = json.loads(out.read_text())
    assert d["meta"]["schema_version"] >= 1
    assert d["observe_report"]["counters"]["c.bench"] == 2
    arch = export.JsonlSink.read(str(tmp_path / "obs" / "BENCH_x.jsonl"))
    assert arch[0]["kind"] == "meta"
    assert arch[0]["bench_file"] == "BENCH_x.json"
    assert arch[1]["counters"]["c.bench"] == 2
    # and the file it wrote ingests cleanly
    assert trajectory.ingest(str(out))


def test_metrics_endpoint_text_serves_registry(obs_on):
    # endpoint formatting only — engine construction is covered by
    # test_observe; the endpoint is a thin prometheus_text wrapper
    metrics.inc("serving.tick", 3)
    from repro.serving.engine import DecodeEngine
    text = DecodeEngine.metrics_endpoint_text(
        type("E", (), {})())           # no engine state touched
    assert "serving_tick 3" in text
