"""Property-based tests (hypothesis) on the format's invariants:
pack/unpack bijectivity, delta-encoding reconstruction, SpMV linearity,
format-agreement between PackSELL / SELL / CSR, and σ-permutation identity.
"""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import codecs as cd
from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core import sparse as sps


# ---------------------------------------------------------------------------
# random sparse matrices as a hypothesis strategy
# ---------------------------------------------------------------------------


@st.composite
def sparse_mats(draw, max_n=96, max_m=96):
    n = draw(st.integers(1, max_n))
    m = draw(st.integers(1, max_m))
    density = draw(st.floats(0.01, 0.35))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    a = sp.random(n, m, density=density, random_state=rng,
                  data_rvs=lambda k: rng.standard_normal(k))
    a = a.tocsr()
    a.sort_indices()
    return a


FORMATS = st.sampled_from([("fp16", 15), ("bf16", 15), ("e8m", 1),
                           ("e8m", 4), ("e8m", 12)])
LAYOUT = st.sampled_from([(8, 16), (16, 32), (4, 8)])   # (C, sigma)


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------


@given(sparse_mats(), FORMATS, LAYOUT)
@settings(max_examples=30, deadline=None)
def test_decode_matches_quantized_matrix(a, fmt, layout):
    """decode(encode(A)) == codec-quantized A: the format loses exactly the
    value-codec precision, never structure."""
    codec, D = fmt
    C, sigma = layout
    mat = pk.from_csr(a, C=C, sigma=sigma, D=D, codec=codec, device=False)
    dec = pk.decode_to_dense(mat)
    cobj = cd.make_codec(codec)
    want = np.zeros(a.shape)
    coo = a.tocoo()
    qvals = cobj.decode_np(cobj.encode_np(coo.data.astype(np.float32), D),
                           D).astype(np.float64)
    for r, c, v in zip(coo.row, coo.col, qvals):
        want[r, c] += v
    np.testing.assert_allclose(dec, want, rtol=0, atol=0)


@given(sparse_mats(), FORMATS, LAYOUT)
@settings(max_examples=20, deadline=None)
def test_spmv_matches_decoded_dense(a, fmt, layout):
    codec, D = fmt
    C, sigma = layout
    mat = pk.from_csr(a, C=C, sigma=sigma, D=D, codec=codec)
    x = np.random.default_rng(0).standard_normal(a.shape[1]) \
        .astype(np.float32)
    y = np.asarray(pk.packsell_spmv_jnp(mat, jnp.asarray(x)))
    want = pk.decode_to_dense(mat) @ x.astype(np.float64)
    np.testing.assert_allclose(y, want, rtol=2e-4, atol=2e-4)


@given(sparse_mats(), LAYOUT)
@settings(max_examples=20, deadline=None)
def test_spmv_linearity(a, layout):
    C, sigma = layout
    mat = pk.from_csr(a, C=C, sigma=sigma, D=1, codec="e8m")  # E8M21
    rng = np.random.default_rng(1)
    x1 = jnp.asarray(rng.standard_normal(a.shape[1]), jnp.float32)
    x2 = jnp.asarray(rng.standard_normal(a.shape[1]), jnp.float32)
    y = np.asarray(pk.packsell_spmv_jnp(mat, 2.0 * x1 - 3.0 * x2))
    y12 = 2.0 * np.asarray(pk.packsell_spmv_jnp(mat, x1)) \
        - 3.0 * np.asarray(pk.packsell_spmv_jnp(mat, x2))
    np.testing.assert_allclose(y, y12, rtol=1e-3, atol=1e-3)


@given(sparse_mats(), LAYOUT)
@settings(max_examples=20, deadline=None)
def test_formats_agree(a, layout):
    """PackSELL(E8M21) ≈ SELL(fp32) ≈ CSR(fp32) on the same matrix."""
    C, sigma = layout
    x = jnp.asarray(np.random.default_rng(2)
                    .standard_normal(a.shape[1]), jnp.float32)
    y_pk = np.asarray(pk.packsell_spmv_jnp(
        pk.from_csr(a, C=C, sigma=sigma, D=1, codec="e8m"), x))
    y_sl = np.asarray(sl.sell_spmv_jnp(
        sl.from_csr(a, C=C, sigma=sigma, value_dtype="float32"), x))
    y_cs = np.asarray(sps.csr_from_scipy(a, "float32").spmv(x))
    np.testing.assert_allclose(y_pk, y_sl, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(y_sl, y_cs, rtol=2e-4, atol=2e-4)


@given(st.integers(0, 2**31 - 1), st.integers(1, 200))
@settings(max_examples=30, deadline=None)
def test_empty_and_dense_rows(seed, n):
    """Degenerate structures: empty rows, a full row, single column."""
    rng = np.random.default_rng(seed)
    a = np.zeros((n, 8), np.float32)
    if n >= 2:
        a[1, :] = rng.standard_normal(8)      # dense row
    a[n // 2, 3] = 5.0                        # lone element
    mat = pk.from_dense(a, C=8, sigma=16, D=4, codec="e8m")
    x = jnp.asarray(rng.standard_normal(8), jnp.float32)
    y = np.asarray(pk.packsell_spmv_jnp(mat, x))
    want = pk.decode_to_dense(mat) @ np.asarray(x, np.float64)
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)


@given(st.integers(1, 2**20), st.integers(1, 15))
@settings(max_examples=100, deadline=None)
def test_dummy_insertion_threshold(delta, D):
    """Deltas < 2^D need no dummy; larger ones round-trip via a dummy."""
    n = 1
    m = delta + 2
    a = sp.csr_matrix((np.array([1.5, 2.5]),
                       np.array([0, delta + 1]),
                       np.array([0, 2])), shape=(n, m))
    mat = pk.from_csr(a, C=1, sigma=1, D=D, codec="fp16", device=False)
    expected_dummies = 0 if (delta + 1) < 2 ** D else \
        int(np.ceil(0)) + 1 if (delta + 1) < 2 ** 31 else None
    # reconstruction is exact regardless of dummy count
    dec = pk.decode_to_dense(mat)
    assert dec[0, 0] == 1.5
    assert dec[0, delta + 1] == 2.5
    if (delta + 1) < 2 ** D:
        assert mat.n_dummy == 0
    else:
        assert mat.n_dummy >= 1
