"""Flight recorder (DESIGN.md §12): registry semantics, zero-effect
instrumentation, and the wired integration points.

The load-bearing guarantees:

* **registry** — counters/gauges/histograms with labeled series, off by
  default, snapshot/reset/export round-trip;
* **bit-for-bit parity** — ``REPRO_OBS=1`` must not change ANY solver
  result: counters fire only at host-side dispatch entries and spans are
  metadata-only, so iteration counts, residual histories and solution
  vectors are compared bitwise against the recorder-off run;
* **no retrace** — instrumented steady-state matvecs stay ONE jitted
  executable across 10 calls (the per-dispatch record must not perturb
  the jit cache);
* **serving** — a poisoned precision-store retile entry trips the
  warmup failure counter + warning but leaves the engine usable.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import observe
from repro.core import packsell, testmats
from repro.kernels import plan as kplan
from repro.observe import metrics
from repro.solvers import cg
from repro.solvers import operators as op


@pytest.fixture
def obs_on():
    """Recorder enabled with a clean slate; global state restored after."""
    prev = observe.enable(True)
    observe.reset()
    yield
    observe.reset()
    observe.enable(prev)


def _x(m, seed=1):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(m).astype(np.float32))


def _spd(a):
    import scipy.sparse as sp
    s = ((a + a.T) / 2).tocsr()
    return (s + sp.eye(s.shape[0]) * float(np.abs(s).sum(axis=1).max())
            ).tocsr()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_env_default_matches_repro_obs():
    # tier-1 runs with REPRO_OBS unset (recorder off); verify-observe /
    # the ci.sh observe step re-run this suite with REPRO_OBS=1
    assert metrics._env_on(os.environ.get("REPRO_OBS")) \
        == metrics._env_on(os.environ.get("REPRO_OBS"))  # tautology guard
    assert observe.enabled() == metrics._env_on(os.environ.get("REPRO_OBS"))


def test_disabled_recorder_is_zero_cost():
    prev = observe.enable(False)
    try:
        observe.reset()
        observe.inc("x.count", variant="jnp")
        observe.gauge("x.g", 3.5)
        observe.observe("x.h", 1.0)
        snap = observe.snapshot()
        assert snap["enabled"] is False
        assert snap["counters"] == {} and snap["histograms"] == {}
    finally:
        observe.enable(prev)


def test_counters_gauges_histograms_labeled(obs_on):
    observe.inc("c", variant="jnp")
    observe.inc("c", 2, variant="jnp")
    observe.inc("c", variant="band")
    observe.gauge("g", 1.5, codec="fp16")
    for v in (1.0, 3.0, 2.0):
        observe.observe("h", v)
    snap = observe.snapshot()
    assert snap["counters"]["c{variant=jnp}"] == 3
    assert snap["counters"]["c{variant=band}"] == 1
    assert snap["gauges"]["g{codec=fp16}"] == 1.5
    h = snap["histograms"]["h"]
    assert h["count"] == 3 and h["sum"] == 6.0
    assert h["min"] == 1.0 and h["max"] == 3.0 and h["last"] == 2.0


def test_reset_and_export_roundtrip(obs_on, tmp_path):
    observe.inc("c")
    p = tmp_path / "obs.json"
    observe.export_json(p)
    blob = json.loads(p.read_text())
    assert blob["counters"]["c"] == 1
    observe.reset()
    assert observe.snapshot()["counters"] == {}


def test_trace_buffer_bounded(obs_on):
    for i in range(metrics._TRACE_CAP + 50):
        observe.record_trace("t", {"i": i})
    traces = observe.snapshot()["traces"]["t"]
    assert len(traces) == metrics._TRACE_CAP
    assert traces[-1]["i"] == metrics._TRACE_CAP + 49   # keeps the newest


def test_span_is_usable_enabled_and_disabled(obs_on):
    with observe.span("packsell.test_span"):
        y = jnp.sum(jnp.arange(4.0))
    observe.enable(False)
    with observe.span("packsell.test_span"):
        y2 = jnp.sum(jnp.arange(4.0))
    assert float(y) == float(y2)


def test_span_inside_jit_does_not_change_result(obs_on):
    def f(v):
        with observe.span("packsell.jit_span"):
            return v * 2.0 + 1.0
    x = _x(64)
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)),
                                  np.asarray(f(x)))


# ---------------------------------------------------------------------------
# bit-for-bit parity: REPRO_OBS=1 changes no solver results
# ---------------------------------------------------------------------------

def _solve_outputs(fn):
    """Run ``fn`` recorder-off then recorder-on (fresh plan caches both
    times) and return the two (x, iters, history) triples."""
    out = []
    for on in (False, True):
        prev = observe.enable(on)
        try:
            observe.reset()
            kplan.clear_cache()
            x, info = fn()
            out.append((np.asarray(x).tobytes(), int(info.iters),
                        np.asarray(info.history).tobytes()))
        finally:
            observe.enable(prev)
            observe.reset()
    return out


@pytest.mark.parametrize("klass", ["stencil1d", "banded"])
def test_obs_parity_jacobi_pcg(klass):
    s = _spd(testmats.suite("tiny")[klass])
    ops_ = op.OperatorSet(s, C=32, sigma=64)
    b = jnp.asarray(np.random.default_rng(3).standard_normal(s.shape[0]))
    diag = jnp.asarray(s.diagonal())
    dinv = jnp.where(diag == 0, 1.0, 1.0 / diag)

    def solve():
        mv = ops_.matvec("plan_fp16")
        return cg.pcg(mv, b, M=lambda r: r * dinv, tol=1e-8, maxiter=100)

    off, on = _solve_outputs(solve)
    assert off == on


@pytest.mark.parametrize("klass", ["stencil1d", "banded"])
def test_obs_parity_adaptive_pcg(klass):
    s = _spd(testmats.suite("tiny")[klass])
    ops_ = op.OperatorSet(s, C=32, sigma=64)
    b = jnp.asarray(np.random.default_rng(5).standard_normal(s.shape[0]))

    def solve():
        tiers, labels, sub32, hi = ops_.adaptive_tiers(1e-3, n_probes=2)
        return cg.adaptive_pcg(tiers, b, matvec_hi=hi, tol=1e-8,
                               maxiter=40, m_in=8)

    off, on = _solve_outputs(solve)
    assert off == on


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (XLA_FLAGS host platform)")
def test_obs_parity_dist_pcg():
    from repro.distributed import build_dist_plan
    a = testmats.hpcg(6, 6, 6)
    s, _ = op.sym_scale(a)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(s.shape[0]))
    P = min(2, jax.device_count())

    def solve():
        dplan = build_dist_plan(s, P, C=32, sigma=64, D=15, codec="fp16")
        return cg.jacobi_pcg_dist(dplan, s.diagonal(), b, tol=1e-6,
                                  maxiter=60, dtype=jnp.float64)

    off, on = _solve_outputs(solve)
    assert off == on


# ---------------------------------------------------------------------------
# instrumented dispatch: counters fire, jit cache does not churn
# ---------------------------------------------------------------------------

def test_instrumented_spmv_no_retrace_and_counters(obs_on):
    a = testmats.stencil_1d(128, 3)
    mat = packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16")
    kplan.clear_cache()
    plan = kplan.get_plan(mat)
    for i in range(10):
        jax.block_until_ready(plan.spmv(mat, _x(mat.m, seed=i)))
    assert plan._fns["spmv"]._cache_size() == 1, \
        "instrumented steady-state spmv retraced"
    snap = observe.snapshot()
    disp = [v for k, v in snap["counters"].items()
            if k.startswith("spmv.dispatch{")]
    assert disp == [10]
    bpn = [v for k, v in snap["gauges"].items()
           if k.startswith("spmv.bytes_per_nnz{")]
    assert bpn and bpn[0] > 0


def test_record_solve_skips_tracers(obs_on):
    from repro.solvers.cg import SolveInfo
    traced = {}

    def f(b):
        info = SolveInfo(jnp.int32(3), jnp.float32(1e-9), b)
        observe.record_solve("fake", info)     # tracer leaves: must skip
        return b * 2

    jax.block_until_ready(jax.jit(f)(_x(8)))
    assert "solver.solves{solver=fake}" not in \
        observe.snapshot()["counters"]
    info = SolveInfo(3, 1e-9, np.full(8, -1.0))
    observe.record_solve("fake", info)
    snap = observe.snapshot()
    assert snap["counters"]["solver.solves{solver=fake}"] == 1
    rec = snap["traces"]["solver.trace{solver=fake}"][-1]
    assert rec["iters"] == 3 and len(rec["history"]) == 4


def test_report_populated_after_dispatch(obs_on):
    a = testmats.stencil_1d(96, 2)
    mat = packsell.from_csr(a, C=8, sigma=16, D=15, codec="fp16")
    kplan.clear_cache()
    plan = kplan.get_plan(mat)
    jax.block_until_ready(plan.spmv(mat, _x(mat.m)))
    rep = observe.report()
    assert rep["enabled"] is True
    assert any(k.startswith("spmv.dispatch{") for k in rep["counters"])
    assert any(k.startswith("plan_cache.miss") for k in rep["counters"])
    assert rep["plan_cache"]["misses"] >= 1
    assert rep["plan_cache"]["jit_cache_cap"] >= 1


# ---------------------------------------------------------------------------
# serving: poisoned precision-store retile must not take warmup down
# ---------------------------------------------------------------------------

def test_warmup_survives_poisoned_store_retile(obs_on, tmp_path, caplog):
    import logging
    from repro import configs
    from repro.models import transformer as tfm
    from repro.models.sparse_linear import PackSELLLinear
    from repro.serving import DecodeEngine, ServeConfig, WarmupSpec

    w = np.random.default_rng(2).standard_normal((32, 32)).astype(
        np.float32)
    lin = PackSELLLinear.from_dense(w, density=0.5, codec="fp16", C=8,
                                    sigma=16)
    desc = lin.describe()
    key = f"plan_{desc['codec']}{desc['D']}"
    # right tile count, garbage contents: apply_retile's length check
    # passes and plan.retile() raises on int("bogus")
    poison = [["bogus", 8]] * len(lin.plan.tiles)
    path = tmp_path / "store.json"
    path.write_text(json.dumps({
        "version": 1,
        "entries": {desc["fingerprint"]: {"retile": {key: poison}}}}))

    cfg = configs.reduce(configs.get("qwen2-0.5b"))
    params, _ = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = DecodeEngine(cfg, params, ServeConfig(slots=1, max_len=32))
    x = _x(32, seed=7)
    with caplog.at_level(logging.WARNING, logger="repro.serving.engine"):
        eng.warmup(WarmupSpec(sparse_layers=(lin,),
                              precision_store=os.fspath(path)))
    assert any("retile from store FAILED" in r.getMessage()
               for r in caplog.records)
    snap = observe.snapshot()
    assert snap["counters"][f"serving.warmup_retile_failure{{key={key}}}"] \
        == 1
    # the engine and the layer both stay usable with build-time tiles
    y = np.asarray(lin(x))
    assert np.all(np.isfinite(y))
    req = eng.submit(np.array([1, 2, 3], np.int32), 2)
    for _ in range(20):
        if req.t_done:
            break
        eng.step()
    assert len(req.out_tokens) == 2
