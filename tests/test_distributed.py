"""Distributed PackSELL: partitioner, halo maps, padded shard blocks, the
shard_map DistSpMVPlan dispatch, and the distributed Jacobi-PCG.

Device-free tests (partition correctness, the reference replay of the
stacked operands) always run; real multi-device tests are gated on
``jax.device_count()`` and exercised by ``make verify-dist`` under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import packsell, testmats
from repro.distributed import (assemble_global, build_dist_plan,
                               build_operands, comm_matrix, halo,
                               partition_rows, reference_spmv, split_csr)
from repro.kernels import plan as kplan
from repro.solvers import cg
from repro.solvers import operators as op

NDEV = jax.device_count()
RNG = np.random.default_rng(11)

need4 = pytest.mark.skipif(NDEV < 4, reason="needs >=4 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")
need8 = pytest.mark.skipif(NDEV < 8, reason="needs >=8 devices")


def _x(m, seed=0):
    return np.random.default_rng(seed).standard_normal(m).astype(np.float32)


# ---------------------------------------------------------------------------
# partitioner (host-side, device-free)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,P", [(10, 1), (10, 3), (64, 4), (5, 8), (1, 2)])
def test_partition_rows_balanced(n, P):
    part = partition_rows(n, P)
    assert int(part.counts.sum()) == n
    assert int(part.counts.max() - part.counts.min()) <= 1
    # every row owned by exactly the shard whose range contains it
    owners = part.owner(np.arange(n))
    for p in range(P):
        r0, r1 = part.rows_of(p)
        assert np.all(owners[r0:r1] == p)


@pytest.mark.parametrize("P", [1, 2, 3, 5])
def test_split_roundtrip_and_halo_classification(P):
    a = testmats.scattered(120, nnz_per_row=6, spd=True, seed=2)
    part = partition_rows(a.shape[0], P)
    splits, h_pad = split_csr(a, part, n_pad=int(part.counts.max()))
    back = assemble_global(part, splits, a.shape)
    assert (abs(a - back) > 0).nnz == 0
    for p, s in enumerate(splits):
        r0, r1 = part.rows_of(p)
        # halo columns are exactly the off-block ones, sorted and distinct
        assert np.all(np.diff(s.halo_cols) > 0)
        assert not np.any((s.halo_cols >= r0) & (s.halo_cols < r1))
        assert len(s.halo_cols) <= h_pad
    if P > 1:
        cm = comm_matrix(part, splits)
        assert np.all(np.diag(cm) == 0)
        assert cm.sum() == sum(len(s.halo_cols) for s in splits)


def test_halo_maps_agree_between_modes():
    a = testmats.random_banded(200, 30, 6, seed=4)
    part = partition_rows(a.shape[0], 4)
    n_pad = int(part.counts.max())
    splits, h_pad = split_csr(a, part, n_pad=n_pad)
    maps = halo.build_halo_maps(part, [s.halo_cols for s in splits],
                                n_pad=n_pad, h_pad=h_pad)
    xs = RNG.standard_normal((4, n_pad)).astype(np.float32)
    via_gather = halo.gather_halo_reference(xs, maps, "all_gather")
    via_ring = halo.gather_halo_reference(xs, maps, "ppermute")
    # both modes fill the valid halo slots with identical entries
    for p, s in enumerate(splits):
        h = len(s.halo_cols)
        np.testing.assert_array_equal(via_gather[p, :h], via_ring[p, :h])
        # and those entries are the owners' x values
        own = part.owner(s.halo_cols)
        want = xs[own, s.halo_cols - part.starts[own]]
        np.testing.assert_array_equal(via_gather[p, :h], want)


# ---------------------------------------------------------------------------
# per-partition build hooks (core/packsell.py)
# ---------------------------------------------------------------------------

def test_pad_uniform_preserves_decode():
    a = testmats.random_banded(100, 10, 4, seed=5)
    mat = packsell.from_csr(a, C=8, sigma=16, D=10, codec="e8m",
                            bucket_strategy="uniform", device=False)
    S, w, C = mat.packs[0].shape
    padded = packsell.pad_uniform(mat, n_slices=S + 3, width=w + 5,
                                  n_rows=(S + 3) * C, device=False)
    dense = packsell.decode_to_dense(mat)
    dense_p = packsell.decode_to_dense(padded)
    np.testing.assert_array_equal(dense_p[:mat.n], dense)
    assert not np.any(dense_p[mat.n:])          # padding rows stay dead
    with pytest.raises(ValueError):
        packsell.pad_uniform(mat, n_slices=S - 1)
    with pytest.raises(ValueError):
        packsell.pad_uniform(mat, n_rows=(S + 3) * C + 1, n_slices=S + 3)


def test_pad_uniform_padding_rows_dead_through_gather_epilogue():
    """Padding rows must produce exactly 0 through BOTH epilogue forms —
    the sentinel-drop scatter and the plan engine's inverse-permutation
    gather (each row needs its own all-PAD stored slot)."""
    a = testmats.random_banded(100, 10, 4, seed=5)
    mat = packsell.from_csr(a, C=8, sigma=16, bucket_strategy="uniform",
                            device=False)
    S, w, C = mat.packs[0].shape
    padded = packsell.pad_uniform(mat, n_slices=S + 2, width=w + 3,
                                  n_rows=(S + 2) * C)
    x = jnp.asarray(_x(a.shape[1], seed=12))
    plan = kplan.get_plan(padded)
    assert plan.inv_cat is not None            # gather form is exercised
    y = np.asarray(plan.spmv(padded, x))
    y_ref = np.asarray(packsell.packsell_spmv_jnp(
        packsell.from_csr(a, C=8, sigma=16, bucket_strategy="uniform"), x))
    np.testing.assert_allclose(y[:mat.n], y_ref, rtol=1e-6, atol=1e-6)
    assert not np.any(y[mat.n:])


def test_aggregate_memory_stats():
    mats = [packsell.from_csr(testmats.stencil_1d(80, 2, seed=s), C=8,
                              sigma=16, device=False) for s in range(3)]
    agg = packsell.aggregate_memory_stats(mats)
    assert agg["shards"] == 3
    assert agg["nnz"] == sum(m.nnz for m in mats)
    assert agg["max_shard_bytes"] >= agg["min_shard_bytes"] > 0


# ---------------------------------------------------------------------------
# stacked operands: host reference replay (device-free)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec,D", [("fp16", 15), ("bf16", 15),
                                     ("e8m", 8), ("fixed16", 10)])
@pytest.mark.parametrize("P", [1, 3, 6])
def test_reference_spmv_matches_single_device(codec, D, P):
    a = testmats.scattered(150, nnz_per_row=7, spd=True, seed=6)
    ops = build_operands(a, P, C=8, sigma=16, D=D, codec=codec)
    x = _x(a.shape[0], seed=1)
    y = reference_spmv(ops, x)
    mat = packsell.from_csr(a, C=8, sigma=16, D=D, codec=codec)
    y1 = np.asarray(packsell.packsell_spmv_jnp(mat, jnp.asarray(x)))
    np.testing.assert_allclose(y, y1, rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(y, reference_spmv(ops, x,
                                                    mode="ppermute"))


def test_reference_spmv_empty_shards():
    a = testmats.stencil_1d(5, 1)                 # 5 rows over 8 shards
    ops = build_operands(a, 8, C=4, sigma=4)
    assert np.any(ops.part.counts == 0)
    x = _x(5)
    np.testing.assert_allclose(
        reference_spmv(ops, x),
        np.asarray(a.astype(np.float64) @ x), rtol=1e-3, atol=1e-3)


def test_reference_spmv_all_halo_columns():
    # pure off-diagonal circulant: with P=2 every referenced column is
    # remote, A_loc is empty on both shards
    n = 32
    rows = np.arange(n)
    a = sp.csr_matrix((np.ones(n, np.float32),
                       (rows, (rows + n // 2) % n)), shape=(n, n))
    ops = build_operands(a, 2, C=4, sigma=8)
    assert all(s == 0 for s in
               (m.nnz for m in ops.mats_loc))
    x = _x(n, seed=2)
    np.testing.assert_allclose(reference_spmv(ops, x),
                               np.asarray(a @ x), rtol=1e-3, atol=1e-3)


def test_shard_vector_roundtrip_and_mask():
    a = testmats.stencil_1d(37, 2)
    ops = build_operands(a, 3, C=8, sigma=8)
    v = _x(37, seed=3)
    vs = ops.stack_vector(v)
    assert vs.shape == (3, ops.n_pad)
    np.testing.assert_array_equal(ops.unstack_vector(vs), v)
    # mask matches the per-shard row counts
    np.testing.assert_array_equal(
        ops.host["rowmask"].sum(axis=1).astype(int), ops.part.counts)


# ---------------------------------------------------------------------------
# real shard_map dispatch (P=1 always; multi-device gated)
# ---------------------------------------------------------------------------

def test_dist_plan_single_device_matches_plan_engine():
    a = testmats.random_banded(300, 20, 6, seed=7)
    dplan = build_dist_plan(a, 1, C=8, sigma=32, D=15, codec="fp16")
    x = _x(a.shape[0], seed=4)
    mat = packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16")
    y1 = np.asarray(kplan.get_plan(mat).spmv(mat, jnp.asarray(x)))
    np.testing.assert_allclose(np.asarray(dplan.spmv(x)), y1,
                               rtol=1e-6, atol=1e-6)
    st = dplan.memory_stats()
    assert st["shards"] == 1 and st["h_pad"] == 0


@need4
@pytest.mark.parametrize("codec,D", [("fp16", 15), ("bf16", 15),
                                     ("e8m", 8), ("fixed16", 10)])
def test_dist_spmv_matches_single_device(codec, D):
    a = testmats.hpcg(8, 8, 8)
    x = _x(a.shape[0], seed=5)
    mat = packsell.from_csr(a, C=32, sigma=64, D=D, codec=codec)
    y1 = np.asarray(kplan.get_plan(mat).spmv(mat, jnp.asarray(x)))
    dplan = build_dist_plan(a, 4, C=32, sigma=64, D=D, codec=codec)
    for mode in halo.EXCHANGE_MODES:
        np.testing.assert_allclose(np.asarray(dplan.spmv(x, mode=mode)),
                                   y1, rtol=2e-5, atol=2e-5)


@need4
def test_dist_exchange_modes_bitwise_equal():
    a = testmats.scattered(400, nnz_per_row=9, spd=True, seed=8)
    dplan = build_dist_plan(a, 4, C=16, sigma=32)
    x = _x(a.shape[0], seed=6)
    np.testing.assert_array_equal(
        np.asarray(dplan.spmv(x, mode="ppermute")),
        np.asarray(dplan.spmv(x, mode="all_gather")))


@need4
def test_dist_spmm_matches_spmv_columns():
    a = testmats.random_banded(256, 16, 5, seed=9)
    dplan = build_dist_plan(a, 4, C=16, sigma=32)
    X = RNG.standard_normal((a.shape[0], 4)).astype(np.float32)
    Y = np.asarray(dplan.spmm(X))
    for j in range(4):
        np.testing.assert_allclose(Y[:, j], np.asarray(dplan.spmv(X[:, j])),
                                   rtol=1e-6, atol=1e-6)


@need4
def test_dist_matches_reference_replay():
    a = testmats.powerlaw(300, mean_deg=4, seed=10)
    a = a + a.T + sp.eye(300)                     # symmetric, nonzero diag
    a = a.tocsr()
    dplan = build_dist_plan(a, 4, C=8, sigma=16, D=8, codec="e8m")
    x = _x(300, seed=7)
    np.testing.assert_allclose(np.asarray(dplan.spmv(x)),
                               reference_spmv(dplan.ops, x),
                               rtol=1e-6, atol=1e-6)


@need8
def test_dist_spmv_8_devices_all_codecs():
    a = testmats.hpcg(8, 8, 8)
    x = _x(a.shape[0], seed=8)
    for codec, D in [("fp16", 15), ("bf16", 15), ("e8m", 8),
                     ("e8m", 4), ("fixed16", 10)]:
        mat = packsell.from_csr(a, C=32, sigma=64, D=D, codec=codec)
        y1 = np.asarray(kplan.get_plan(mat).spmv(mat, jnp.asarray(x)))
        dplan = build_dist_plan(a, 8, C=32, sigma=64, D=D, codec=codec)
        np.testing.assert_allclose(np.asarray(dplan.spmv(x)), y1,
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# distributed solvers
# ---------------------------------------------------------------------------

@need4
def test_jacobi_pcg_dist_matches_stored_iterations():
    a = testmats.hpcg(8, 8, 8)
    s, _ = op.sym_scale(a)
    b = jnp.asarray(RNG.standard_normal(s.shape[0]))
    ops_set = op.OperatorSet(s, C=32, sigma=64)
    mat, plan = ops_set.plan_pair("plan_fp16")
    x1, info1 = cg.jacobi_pcg_stored(mat, plan, s.diagonal(), b,
                                     tol=1e-6, maxiter=400,
                                     dtype=jnp.float64)
    dplan = build_dist_plan(s, 4, C=32, sigma=64, D=15, codec="fp16")
    xd, infod = cg.jacobi_pcg_dist(dplan, s.diagonal(), b, tol=1e-6,
                                   maxiter=400, dtype=jnp.float64)
    assert int(infod.iters) == int(info1.iters)
    assert float(infod.relres) < 1e-6
    np.testing.assert_allclose(np.asarray(xd), np.asarray(x1),
                               rtol=1e-4, atol=1e-6)
    # history agrees to summation-order rounding
    h1, hd = np.asarray(info1.history), np.asarray(infod.history)
    k = int(info1.iters)
    np.testing.assert_allclose(hd[:k + 1], h1[:k + 1], rtol=1e-5, atol=0)


@need8
def test_jacobi_pcg_dist_8_devices():
    a = testmats.hpcg(8, 8, 8)
    s, _ = op.sym_scale(a)
    b = jnp.asarray(RNG.standard_normal(s.shape[0]))
    ops_set = op.OperatorSet(s, C=32, sigma=64)
    mat, plan = ops_set.plan_pair("plan_fp16")
    _, info1 = cg.jacobi_pcg_stored(mat, plan, s.diagonal(), b,
                                    tol=1e-6, maxiter=400,
                                    dtype=jnp.float64)
    dplan = build_dist_plan(s, 8, C=32, sigma=64, D=15, codec="fp16")
    _, infod = cg.jacobi_pcg_dist(dplan, s.diagonal(), b, tol=1e-6,
                                  maxiter=400, dtype=jnp.float64)
    assert int(infod.iters) == int(info1.iters)


def test_operator_set_dist_kind():
    a = testmats.stencil_3d(6, 6, 6, neighbours=7)
    s, _ = op.sym_scale(a)
    ops_set = op.OperatorSet(s, C=16, sigma=32)
    mv = ops_set.matvec("dist_fp16")              # P = visible devices
    x = _x(s.shape[0], seed=9)
    y_ref = np.asarray(ops_set.matvec("plan_fp16")(jnp.asarray(x)))
    np.testing.assert_allclose(np.asarray(mv(jnp.asarray(x))), y_ref,
                               rtol=2e-5, atol=2e-5)
    dplan = ops_set.dist_plan("dist_fp16")
    assert dplan.n_shards == NDEV
    with pytest.raises(ValueError):
        ops_set.dist_plan("plan_fp16")


def test_dist_matvec_inside_solver_loop():
    """The dist_ matvec must be tracer-compatible: solvers call it on
    loop-carried iterates inside ``lax.while_loop`` (the 'drops into any
    solver unchanged' contract)."""
    a = testmats.stencil_3d(6, 6, 6, neighbours=7)
    s, _ = op.sym_scale(a)
    ops_set = op.OperatorSet(s, C=16, sigma=32)
    b = jnp.asarray(_x(s.shape[0], seed=13))
    diag = jnp.asarray(s.diagonal().astype(np.float32))
    M = lambda r: r / diag
    x_d, info_d = cg.pcg(ops_set.matvec("dist_fp16"), b, M=M, tol=1e-5,
                         maxiter=300, dtype=jnp.float32)
    x_p, info_p = cg.pcg(ops_set.matvec("plan_fp16"), b, M=M, tol=1e-5,
                         maxiter=300, dtype=jnp.float32)
    assert int(info_d.iters) == int(info_p.iters)
    np.testing.assert_allclose(np.asarray(x_d), np.asarray(x_p),
                               rtol=1e-4, atol=1e-5)
