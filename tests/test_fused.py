"""Fused ragged-bucket SpMV with compact cursor checkpoints (DESIGN.md §10).

Covers the tentpole end to end:

* checkpoint decode ≡ full cursor decode ≡ scan decode ≡ host numpy
  oracle — as a hypothesis property over random codecs, delta widths,
  checkpoint widths, bucket counts and shapes (integer-valued data, so
  every path is EXACT and accumulation order cannot hide column bugs),
  plus deterministic edges: empty matrix, single word, dummy words
  straddling checkpoint boundaries, span-overflow fallback;
* the fused ragged pass ≡ the per-bucket oracle for spmv/spmm and for
  two-member composites (one concatenated word-stream operand);
* Pallas interpret parity for the checkpoint-seeded full/band/spmm
  kernels against both the legacy carry kernels and the jnp oracle;
* the trace-count regression guard: steady-state matvec = exactly one
  jitted dispatch, no retrace across 10 calls;
* the `_unpermute` fix: traced (ephemeral) plans match concrete plans
  bit-for-bit (scatter fallback ≡ inverse-permutation gather);
* the fused solver step: jacobi_pcg_stored / pcg / adaptive_pcg with the
  jitted cached solve — iteration counts and iterates unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import packsell, testmats
from repro.core import codecs as cd
from repro.kernels import composite as kc
from repro.kernels import ops, ref
from repro.kernels import packsell_spmv as kpk
from repro.kernels import plan as kplan
from repro.solvers import cg

RNG = np.random.default_rng(7)


def _int_csr(n, m, nnz_per_row, seed=0):
    """Random integer-valued CSR (values exact in every codec, sums exact
    in fp32 — so cross-path comparisons can be bitwise)."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    for i in range(n):
        k = rng.integers(0, nnz_per_row + 1)
        if k == 0:
            continue
        cs = rng.choice(m, size=min(k, m), replace=False)
        for c in cs:
            rows.append(i)
            cols.append(c)
            vals.append(float(rng.integers(1, 9)) * rng.choice([-1.0, 1.0]))
    a = sp.csr_matrix((vals, (rows, cols)), shape=(n, m))
    a.sort_indices()
    return a


def _int_x(m, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.integers(-8, 9, size=m)).astype(np.float32))


# ---------------------------------------------------------------------------
# decode-path equivalence (deterministic core cases)
# ---------------------------------------------------------------------------

MODES = ("checkpoint", "full", "0")


@pytest.mark.parametrize("codec,D", [("fp16", 15), ("bf16", 12),
                                     ("e8m", 16), ("e8m", 8),
                                     ("fixed16", 15), ("fixed16", 10)])
def test_decode_modes_agree_exactly(codec, D):
    """checkpoint ≡ full cursor ≡ scan ≡ numpy oracle, bit for bit, on
    integer data — across split16 ('f16'/'top16'/'fixed16'), rebased
    'words' and the overflow fallback encodings."""
    a = _int_csr(90, 110, 7, seed=3)
    mat = packsell.from_csr(a, C=8, sigma=32, D=D, codec=codec)
    x = _int_x(110)
    oracle = ref.packsell_spmv_dense_oracle(mat, np.asarray(x))
    ys = {}
    for mode in MODES:
        plan = kplan.build_plan(mat, force="jnp", decode_cache=mode)
        ys[mode] = np.asarray(plan.spmv(mat, x))
        np.testing.assert_array_equal(ys[mode], oracle.astype(np.float32))
    np.testing.assert_array_equal(ys["checkpoint"], ys["full"])
    np.testing.assert_array_equal(ys["checkpoint"], ys["0"])


def test_checkpoint_widths_all_agree(monkeypatch):
    """Every checkpoint width (run chunking) decodes identically."""
    a = _int_csr(70, 80, 9, seed=5)
    mat = packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16")
    x = _int_x(80)
    base = None
    for wr in (8, 16, 32, 64, 128):
        monkeypatch.setattr(kplan, "_CKPT_WIDTHS", (wr,))
        plan = kplan.build_plan(mat, force="jnp", decode_cache="checkpoint")
        assert plan.fused_layout.wr == wr
        y = np.asarray(plan.spmv(mat, x))
        if base is None:
            base = y
        else:
            np.testing.assert_array_equal(y, base)


def test_edge_empty_and_single_word():
    # empty matrix: nnz = 0, every mode returns zeros
    a = sp.csr_matrix((4, 6))
    mat = packsell.from_csr(a, C=4, sigma=4, D=10, codec="fp16")
    x = _int_x(6)
    for mode in MODES:
        plan = kplan.build_plan(mat, force="jnp", decode_cache=mode)
        np.testing.assert_array_equal(np.asarray(plan.spmv(mat, x)),
                                      np.zeros(4, np.float32))
    # single stored word
    a1 = sp.csr_matrix(([3.0], ([0], [2])), shape=(1, 5))
    m1 = packsell.from_csr(a1, C=4, sigma=4, D=10, codec="fp16")
    x1 = _int_x(5)
    for mode in MODES:
        plan = kplan.build_plan(m1, force="jnp", decode_cache=mode)
        np.testing.assert_array_equal(
            np.asarray(plan.spmv(m1, x1)),
            np.asarray([3.0 * float(x1[2])], np.float32))


def test_edge_dummy_words_straddle_checkpoint_boundary(monkeypatch):
    """Column gaps force dummy-word chains; rows long enough that the
    dummies land on / straddle run boundaries must decode exactly."""
    n, m = 8, 5000
    rows, cols, vals = [], [], []
    rng = np.random.default_rng(11)
    for i in range(n):
        # 20 entries: dense prefix then huge jumps (dummies under D=4)
        cs = np.unique(np.concatenate([
            np.arange(6) + i, rng.choice(m - 100, size=14, replace=False)]))
        for c in cs:
            rows.append(i)
            cols.append(int(c))
            vals.append(float(rng.integers(1, 5)))
    a = sp.csr_matrix((vals, (rows, cols)), shape=(n, m))
    monkeypatch.setattr(kplan, "_CKPT_WIDTHS", (8,))
    for codec, D in (("fp16", 15), ("e8m", 4)):
        mat = packsell.from_csr(a, C=4, sigma=8, D=D, codec=codec)
        x = _int_x(m)
        oracle = ref.packsell_spmv_dense_oracle(mat, np.asarray(x))
        plan = kplan.build_plan(mat, force="jnp", decode_cache="checkpoint")
        np.testing.assert_array_equal(np.asarray(plan.spmv(mat, x)),
                                      oracle.astype(np.float32))


def test_span_overflow_falls_back_to_cursor_cache():
    """e8m D=4 ('words' encoding needs run-local offsets < 2^4): wide
    in-run column spans cannot be re-based — the plan must fall back to
    the full cursor cache, loudly, and stay correct."""
    a = _int_csr(60, 4000, 6, seed=9)     # scattered: spans >> 16
    mat = packsell.from_csr(a, C=8, sigma=32, D=4, codec="e8m")
    plan = kplan.build_plan(mat, force="jnp", decode_cache="checkpoint")
    assert plan.fused is None and plan.cols is not None
    assert "fell back to full cursor cache" in plan.policy
    x = _int_x(4000)
    np.testing.assert_array_equal(
        np.asarray(plan.spmv(mat, x)),
        ref.packsell_spmv_dense_oracle(mat, np.asarray(x))
        .astype(np.float32))


def test_env_mode_parsing(monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CURSOR_CACHE", "1")   # PR-1 spelling
    assert kplan._env_cache_mode() == "checkpoint"
    monkeypatch.setenv("REPRO_PLAN_CURSOR_CACHE", "full")
    assert kplan._env_cache_mode() == "full"
    monkeypatch.setenv("REPRO_PLAN_CURSOR_CACHE", "0")
    assert kplan._env_cache_mode() == "0"
    monkeypatch.setenv("REPRO_PLAN_CURSOR_CACHE", "bogus")
    with pytest.raises(ValueError):
        kplan._env_cache_mode()


def test_decode_cache_memory_shrinks_8x_on_suite():
    """The acceptance floor: checkpoints >= 8x smaller than the PR-1
    cursor cache on every tiny-suite class."""
    for name, a in testmats.suite("tiny").items():
        mat = packsell.from_csr(a, C=32, sigma=256, D=15, codec="fp16")
        plan = kplan.build_plan(mat, force="jnp",
                                decode_cache="checkpoint")
        st = plan.decode_cache_stats()
        assert st["shrink_vs_full"] >= 8.0, (name, st)


# ---------------------------------------------------------------------------
# fused ragged pass vs per-bucket oracle (spmv / spmm / composites)
# ---------------------------------------------------------------------------

def test_fused_matches_per_bucket_oracle_spmv_spmm():
    a = _int_csr(120, 100, 11, seed=13)   # pow2 -> multiple buckets
    mat = packsell.from_csr(a, C=8, sigma=64, D=15, codec="fp16")
    assert len(mat.packs) > 1
    x = _int_x(100)
    X = jnp.stack([_int_x(100, seed=s) for s in range(3)], axis=1)
    plan = kplan.build_plan(mat, force="jnp", decode_cache="checkpoint")
    np.testing.assert_array_equal(
        np.asarray(plan.spmv(mat, x)),
        np.asarray(packsell.packsell_spmv_jnp(mat, x)))
    np.testing.assert_array_equal(
        np.asarray(plan.spmm(mat, X)),
        np.asarray(packsell.packsell_spmm_jnp(mat, X)))


def test_two_member_composite_fused_stream(monkeypatch):
    """Row-class composite: ONE concatenated word-stream operand feeds
    both members; outputs match the dense per-class oracle and the
    execute_with (per-member operands) path bit-for-bit. The fused cat
    stream only exists in checkpoint mode, so pin it (the CI loop runs
    this module under all three cursor-cache modes)."""
    monkeypatch.setenv("REPRO_PLAN_CURSOR_CACHE", "checkpoint")
    a = _int_csr(80, 80, 6, seed=17)
    rows = np.arange(80)
    classes = [("fp16", 15, rows[: 40]), ("bf16", 12, rows[40:])]
    cp = kc.CompositePlan.from_classes(a, classes, C=8, sigma=32)
    cat = cp.fused_cat()
    assert cat is not None and sum(s is not None for s in cat[2]) == 2
    x = _int_x(80)
    y = np.asarray(cp.spmv(x))
    # dense oracle: each class quantized at its codec
    dense = np.zeros((80, 80))
    for (codec, D, rws), mem in zip(classes, cp.members):
        sub = a[rws].toarray()
        q = cd.quantize_np(sub.ravel(), cd.make_codec(codec), D)
        dense[rws] = q.reshape(sub.shape)
    np.testing.assert_array_equal(y, (dense @ np.asarray(x))
                                  .astype(np.float32))
    y2 = np.asarray(cp.execute_with(cp.member_mats(), cp.member_devs(),
                                    cp.invs, (x,)))
    np.testing.assert_array_equal(y, y2)


# ---------------------------------------------------------------------------
# Pallas checkpoint kernels (interpret parity)
# ---------------------------------------------------------------------------

def test_pallas_ckpt_kernels_match_legacy_and_oracle():
    a = testmats.random_banded(600, 30, 8, seed=21)
    mat = packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16",
                            bucket_strategy="uniform")
    x = jnp.asarray(RNG.standard_normal(600).astype(np.float32))
    X = jnp.asarray(RNG.standard_normal((600, 3)).astype(np.float32))
    oracle = np.asarray(packsell.packsell_spmv_jnp(mat, x))
    for force in ("full", "band"):
        p_ck = kplan.build_plan(mat, sb=4, wb=8, force=force,
                                decode_cache="checkpoint")
        p_legacy = kplan.build_plan(mat, sb=4, wb=8, force=force,
                                    decode_cache="0")
        assert p_ck.kckpts is not None and p_legacy.kckpts is None
        np.testing.assert_allclose(np.asarray(p_ck.spmv(mat, x)), oracle,
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(p_legacy.spmv(mat, x)),
                                   oracle, rtol=1e-6, atol=1e-6)
    Y = np.asarray(packsell.packsell_spmm_jnp(mat, X))
    p_full = kplan.build_plan(mat, sb=4, wb=8, force="full",
                              decode_cache="checkpoint")
    np.testing.assert_allclose(np.asarray(p_full.spmm(mat, X)), Y,
                               rtol=1e-5, atol=1e-5)


def test_retile_recomputes_block_checkpoints():
    a = testmats.random_banded(300, 20, 6, seed=23)
    mat = packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16")
    x = jnp.asarray(RNG.standard_normal(300).astype(np.float32))
    plan = kplan.build_plan(mat, sb=4, wb=8, force="full",
                            decode_cache="checkpoint")
    y1 = np.asarray(plan.spmv(mat, x))
    plan.retile([(2, 16)] * len(mat.packs))
    assert all(int(c.shape[1]) == -(-int(p.shape[1]) // 16)
               for c, p in zip(plan.kckpts, mat.packs))
    np.testing.assert_allclose(np.asarray(plan.spmv(mat, x)), y1,
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# trace-count regression guard + traced-vs-concrete epilogue
# ---------------------------------------------------------------------------

def test_steady_state_matvec_single_dispatch_no_retrace():
    """10 matvecs = ONE jitted executable, zero retraces (the CI guard)."""
    a = _int_csr(100, 100, 5, seed=29)
    mat = packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16")
    kplan.clear_cache()
    plan = kplan.get_plan(mat)
    for i in range(10):
        x = _int_x(100, seed=i)
        jax.block_until_ready(plan.spmv(mat, x))
    fn = plan._fns["spmv"]
    assert fn._cache_size() == 1, "steady-state spmv retraced"
    assert kplan.cache_stats()["misses"] == 1


def test_traced_plan_matches_concrete_bit_for_bit():
    """The `_unpermute` regression (issue satellite): an ephemeral traced
    plan (drop-mode scatter epilogue, scan decode) must equal the concrete
    plan with the same decode (inverse-permutation gather) bitwise."""
    a = _int_csr(90, 90, 6, seed=31)
    mat = packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16")
    x = _int_x(90)
    plan0 = kplan.build_plan(mat, force="jnp", decode_cache="0")
    y_concrete = np.asarray(plan0.spmv(mat, x))

    @jax.jit
    def traced(mat, x):
        return ops.packsell_spmv(mat, x, decode_cache="0")

    np.testing.assert_array_equal(np.asarray(traced(mat, x)), y_concrete)
    # and the default checkpoint mode agrees exactly on integer data
    y_fused = np.asarray(kplan.build_plan(
        mat, force="jnp", decode_cache="checkpoint").spmv(mat, x))
    np.testing.assert_array_equal(y_fused, y_concrete)


# ---------------------------------------------------------------------------
# fused solver step
# ---------------------------------------------------------------------------

def _spd_problem(n=216):
    a = testmats.stencil_3d(6, 6, 6, neighbours=27)
    from repro.solvers import operators as op
    s, _ = op.sym_scale(a)
    mat = packsell.from_csr(s, C=8, sigma=32, D=15, codec="fp16")
    plan = kplan.get_plan(mat, force="jnp")
    b = jnp.asarray(np.random.default_rng(5).standard_normal(s.shape[0])
                    .astype(np.float32))
    return s, mat, plan, b


def test_jacobi_pcg_stored_fused_solve_matches_eager():
    s, mat, plan, b = _spd_problem()
    diag = s.diagonal()
    x_f, info_f = cg.jacobi_pcg_stored(mat, plan, diag, b, tol=1e-6,
                                       maxiter=200, dtype=jnp.float32)
    # eager reference: the historical un-jitted composition
    dinv = jnp.where(jnp.asarray(diag) == 0, 1.0, 1.0 / jnp.asarray(diag))
    dinv_s = plan.to_stored(dinv.astype(b.dtype))
    b_s = plan.to_stored(b)
    x_s, info_e = cg.pcg(
        lambda x_s: plan.spmv(mat, plan.from_stored(x_s), permuted=True),
        b_s, M=lambda r: r * dinv_s, tol=1e-6, maxiter=200,
        dtype=jnp.float32)
    x_e = plan.from_stored(x_s)
    assert int(info_f.iters) == int(info_e.iters)
    np.testing.assert_array_equal(np.asarray(x_f), np.asarray(x_e))
    # second call reuses the cached executable
    key = [k for k in plan._fns if isinstance(k, tuple)
           and k and k[0] == "jpcg_stored"]
    assert len(key) == 1
    fn = plan._fns[key[0]]
    cg.jacobi_pcg_stored(mat, plan, diag, b, tol=1e-6, maxiter=200,
                         dtype=jnp.float32)
    assert fn._cache_size() == 1


def test_pcg_jit_cache_matches_uncached():
    s, mat, plan, b = _spd_problem()
    matvec = lambda v: plan.spmv(mat, v)   # noqa: E731
    cache = {}
    x1, i1 = cg.pcg(matvec, b, tol=1e-6, maxiter=150, dtype=jnp.float32)
    x2, i2 = cg.pcg(matvec, b, tol=1e-6, maxiter=150, dtype=jnp.float32,
                    jit_cache=cache, jit_key="t")
    assert int(i1.iters) == int(i2.iters)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert len(cache) == 1


def test_adaptive_pcg_jit_cache_iterations_unchanged():
    s, mat, plan, b = _spd_problem()
    diag = jnp.asarray(s.diagonal().astype(np.float32))
    dense = jnp.asarray(s.toarray().astype(np.float64))
    tiers = [lambda v: plan.spmv(mat, v),
             lambda v: (dense @ v.astype(jnp.float64)).astype(jnp.float32)]
    M = lambda r: r / diag                  # noqa: E731
    kw = dict(M=M, tol=1e-8, maxiter=40, m_in=8, dtype=jnp.float32)
    x1, a1 = cg.adaptive_pcg(tiers, b, **kw)
    cache = {}
    x2, a2 = cg.adaptive_pcg(tiers, b, jit_cache=cache, jit_key="t", **kw)
    assert int(a1.iters) == int(a2.iters)
    assert int(a1.promotions) == int(a2.promotions)
    np.testing.assert_array_equal(np.asarray(a1.tier_history),
                                  np.asarray(a2.tier_history))
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


# ---------------------------------------------------------------------------
# hypothesis property: all decode paths == numpy oracle, exactly
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
    _HAVE_HYP = True
except Exception:                            # pragma: no cover
    _HAVE_HYP = False

if _HAVE_HYP:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    CODECS = [("fp16", 15), ("fp16", 8), ("bf16", 12), ("e8m", 16),
              ("e8m", 8), ("fixed16", 15), ("fixed16", 9)]

    @st.composite
    def fused_cases(draw):
        n = draw(st.integers(1, 60))
        m = draw(st.integers(1, 80))
        nnz_per_row = draw(st.integers(0, 10))
        codec, D = draw(st.sampled_from(CODECS))
        C = draw(st.sampled_from([2, 4, 8]))
        sigma = C * draw(st.sampled_from([1, 2, 4]))
        wr = draw(st.sampled_from([8, 16, 32, 128]))
        seed = draw(st.integers(0, 2 ** 16))
        return n, m, nnz_per_row, codec, D, C, sigma, wr, seed

    @settings(max_examples=25, deadline=None)
    @given(fused_cases())
    def test_property_decode_paths_match_oracle(case):
        n, m, nnz_per_row, codec, D, C, sigma, wr, seed = case
        a = _int_csr(n, m, nnz_per_row, seed=seed)
        mat = packsell.from_csr(a, C=C, sigma=sigma, D=D, codec=codec)
        x = _int_x(m, seed=seed + 1)
        oracle = ref.packsell_spmv_dense_oracle(
            mat, np.asarray(x)).astype(np.float32)
        old = kplan._CKPT_WIDTHS
        kplan._CKPT_WIDTHS = (wr,)
        try:
            for mode in MODES:
                plan = kplan.build_plan(mat, force="jnp",
                                        decode_cache=mode)
                np.testing.assert_array_equal(
                    np.asarray(plan.spmv(mat, x)), oracle)
                X = x[:, None]
                np.testing.assert_array_equal(
                    np.asarray(plan.spmm(mat, X))[:, 0], oracle)
        finally:
            kplan._CKPT_WIDTHS = old
