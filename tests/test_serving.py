"""Serving front-end semantics (DESIGN.md §15).

Everything here is deterministic: policies run on a
:class:`~repro.serving.policy.ManualClock`, the frontend runs with
``background=False`` (warmup/rebuild inline), and backoff schedules are
seeded — deadline math, breaker transitions, and shed order become
exact assertions, never sleeps.
"""
import numpy as np
import pytest

from repro.core import testmats
from repro.observe import export as _export
from repro.observe import metrics as _obs
from repro.serving import frontend as fe
from repro.serving import policy as pol


@pytest.fixture(scope="module")
def tiny_csr():
    return testmats.suite("tiny")["stencil1d"]


@pytest.fixture()
def obs():
    """Recorder on + clean registry for metric assertions; restored."""
    was = _obs.enabled()
    _obs.enable(True)
    _obs.reset()
    yield _obs
    _obs.reset()
    _obs.enable(was)


def mk_frontend(clock=None, **kw):
    kw.setdefault("slots", 4)
    kw.setdefault("background", False)
    kw.setdefault("C", 32)
    kw.setdefault("sigma", 64)
    return fe.ServingFrontend(fe.FrontendConfig(**kw),
                              clock=clock or pol.ManualClock())


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


class TestPolicies:
    def test_manual_clock_is_monotonic(self):
        c = pol.ManualClock()
        c.advance(1.5)
        assert c() == 1.5
        with pytest.raises(ValueError):
            c.advance(-0.1)

    def test_backoff_schedule_is_deterministic(self):
        bp = pol.BackoffPolicy(base=0.01, mult=2.0, max_delay=0.05,
                               max_attempts=4)
        assert [bp.delay(k) for k in (1, 2, 3, 4)] == \
            [0.01, 0.02, 0.04, 0.05]           # exponential, capped
        assert not bp.exhausted(3)
        assert bp.exhausted(4)
        with pytest.raises(ValueError):
            bp.delay(0)                        # attempts are 1-based

    def test_backoff_jitter_is_seeded(self):
        a = pol.BackoffPolicy(jitter=0.5, seed=7)
        b = pol.BackoffPolicy(jitter=0.5, seed=7)
        seq_a = [a.delay(k) for k in (1, 2, 3)]
        seq_b = [b.delay(k) for k in (1, 2, 3)]
        assert seq_a == seq_b                  # same seed, same schedule
        assert all(d <= pol.BackoffPolicy().delay(k)
                   for d, k in zip(seq_a, (1, 2, 3)))

    def test_breaker_full_lifecycle(self):
        clk = pol.ManualClock()
        cb = pol.CircuitBreaker(fail_threshold=2, cooldown_s=1.0,
                                probe_successes=2, clock=clk)
        cb.record_failure()
        assert cb.state == pol.CLOSED          # below threshold
        cb.record_failure()
        assert cb.state == pol.OPEN
        assert not cb.allow()
        cb.note_rebuilt()
        assert not cb.allow()                  # cooldown not elapsed
        clk.advance(1.0)
        assert cb.allow()                      # lazy OPEN -> HALF_OPEN
        assert cb.state == pol.HALF_OPEN
        cb.record_success()
        assert cb.state == pol.HALF_OPEN       # needs 2 probes
        cb.record_success()
        assert cb.state == pol.CLOSED
        assert [(s, d) for _, s, d in cb.transitions] == \
            [(pol.CLOSED, pol.OPEN), (pol.OPEN, pol.HALF_OPEN),
             (pol.HALF_OPEN, pol.CLOSED)]

    def test_breaker_probe_failure_reopens(self):
        clk = pol.ManualClock()
        cb = pol.CircuitBreaker(fail_threshold=1, cooldown_s=0.0, clock=clk)
        cb.record_failure()
        cb.note_rebuilt()
        assert cb.allow() and cb.state == pol.HALF_OPEN
        cb.record_failure()
        assert cb.state == pol.OPEN
        assert not cb.rebuilt                  # needs a FRESH rebuild

    def test_breaker_open_without_rebuild_never_probes(self):
        clk = pol.ManualClock()
        cb = pol.CircuitBreaker(fail_threshold=1, cooldown_s=0.1, clock=clk)
        cb.record_failure()
        clk.advance(100.0)
        assert not cb.allow()                  # nobody repaired it

    def test_admission_vmem_residency_math(self):
        adm = pol.AdmissionPolicy(max_queue=4, vmem_limit_words=1000)
        assert adm.vmem_ok(n=300, m=200, nb=2)       # (m+n)*nb = 1000
        assert not adm.vmem_ok(n=300, m=200, nb=3)
        assert adm.queue_ok(3) and not adm.queue_ok(4)
        assert adm.occupancy(2) == 0.5

    def test_degradation_hysteresis(self):
        dp = pol.DegradationPolicy(demote1=0.5, demote2=0.8, recover=0.35)
        assert dp.level(0.2) == 0
        assert dp.level(0.6) == 1
        assert dp.level(0.85) == 2
        assert dp.level(0.4, prev_level=2) == 2      # hold band
        assert dp.level(0.3, prev_level=2) == 0      # recovered
        ic = pol.DEFAULT_CLASSES[0]                  # interactive, tier 2
        assert dp.tier_for(ic, 0, 4) == 2
        assert dp.tier_for(ic, 2, 4) == 3            # floor-clamped

    def test_request_class_floor_validation(self):
        with pytest.raises(ValueError):
            pol.RequestClass("bad", priority=0, deadline_s=1.0,
                             tier=2, tier_floor=1)

    def test_tier_budgets_widen_down_the_ladder(self):
        budgets = [pol.tier_error_budget(k) for k in pol.DEFAULT_LADDER]
        assert budgets == sorted(budgets)            # fp32 tightest
        assert budgets[0] < budgets[-1]


# ---------------------------------------------------------------------------
# frontend semantics
# ---------------------------------------------------------------------------


class TestFrontend:
    def test_deadline_miss_on_monotonic_clock(self, tiny_csr):
        clk = pol.ManualClock()
        with mk_frontend(clk) as f:
            fp = f.register(tiny_csr, warm=False)
            rng = np.random.default_rng(0)
            late = f.submit(fp, rng.standard_normal(tiny_csr.shape[1]),
                            klass="standard", deadline_s=0.1)
            ok = f.submit(fp, rng.standard_normal(tiny_csr.shape[1]),
                          klass="standard", deadline_s=10.0)
            clk.advance(0.2)                   # past `late`, not `ok`
            f.run_until_drained()
            assert late.status == "deadline_miss"
            assert late.y is None
            assert ok.status == "ok" and not ok.missed_deadline
            assert f.stats()["deadline_misses"] == 1

    def test_completed_late_is_accounted(self, tiny_csr, monkeypatch):
        clk = pol.ManualClock()
        with mk_frontend(clk) as f:
            fp = f.register(tiny_csr, warm=False)
            r = f.submit(fp, np.ones(tiny_csr.shape[1]), deadline_s=0.1)
            orig = fe.ServingFrontend._run_batch

            def slow(self, *a, **kw):          # service time > deadline
                clk.advance(0.5)
                return orig(self, *a, **kw)

            monkeypatch.setattr(fe.ServingFrontend, "_run_batch", slow)
            f.run_until_drained()
            assert r.status == "ok"            # answered, but late
            assert r.missed_deadline
            assert f.stats()["deadline_misses"] == 1

    def test_queue_full_rejects_loudly(self, tiny_csr, caplog, obs):
        import logging

        with mk_frontend(admission=pol.AdmissionPolicy(max_queue=2)) as f:
            fp = f.register(tiny_csr, warm=False)
            x = np.ones(tiny_csr.shape[1])
            a, b = f.submit(fp, x), f.submit(fp, x)
            with caplog.at_level(logging.WARNING):
                c = f.submit(fp, x)
            assert (a.status, b.status) == ("queued", "queued")
            assert c.status == "rejected" and c.reason == "queue_full"
            assert any("REJECTED" in m for m in caplog.messages)
            shed = {k: v for k, v in _obs.snapshot()["counters"].items()
                    if k.startswith("frontend.shed")}
            assert sum(shed.values()) == 1

    def test_vmem_admission_guard_rejects(self, tiny_csr):
        n, m = tiny_csr.shape
        adm = pol.AdmissionPolicy(vmem_limit_words=(n + m) * 2)
        with mk_frontend(slots=4, admission=adm) as f:
            fp = f.register(tiny_csr, warm=False)
            r = f.submit(fp, np.ones(m))       # (n+m)*4 > limit
            assert r.status == "rejected" and r.reason == "vmem"
        with mk_frontend(slots=2, admission=adm) as f:
            fp = f.register(tiny_csr, warm=False)
            assert f.submit(fp, np.ones(m)).status == "queued"

    def test_unknown_fingerprint_and_class_raise(self, tiny_csr):
        with mk_frontend() as f:
            with pytest.raises(fe.AdmissionError):
                f.submit("deadbeef", np.ones(4))
            fp = f.register(tiny_csr, warm=False)
            with pytest.raises(fe.AdmissionError):
                f.submit(fp, np.ones(tiny_csr.shape[1]), klass="nope")
            with pytest.raises(fe.AdmissionError):
                f.submit(fp, np.ones(3))       # shape mismatch

    def test_coalesced_spmm_bitexact_vs_spmv(self, tiny_csr):
        # integer data => fp32 arithmetic is exact => the batched spmm
        # slot must reproduce per-request spmv answers BIT FOR BIT
        a = tiny_csr.copy()
        rng = np.random.default_rng(3)
        a.data = rng.integers(-4, 5, size=a.nnz).astype(np.float64)
        a.eliminate_zeros()
        with mk_frontend(slots=4) as f:
            fp = f.register(a, warm=False)
            xs = [rng.integers(-8, 9, size=a.shape[1]).astype(np.float32)
                  for _ in range(4)]
            reqs = [f.submit(fp, x, klass="interactive") for x in xs]
            f.run_until_drained()
            assert all(r.status == "ok" for r in reqs)
            kind = reqs[0].tier_kind
            assert kind.startswith("plan_")
            entry = f._entry(fp)
            mat, plan, _ = entry.bind(kind)
            for r, x in zip(reqs, xs):
                single = np.asarray(plan.spmv(mat, x))
                np.testing.assert_array_equal(r.y, single)

    def test_lru_pool_eviction_and_rewarm(self, obs):
        mats = testmats.suite("tiny")
        names = ["stencil1d", "banded", "scattered"]
        with mk_frontend(plan_pool=2) as f:
            fps = [f.register(mats[k], warm=False) for k in names]
            x = {fp: np.random.default_rng(1).standard_normal(
                mats[k].shape[1]) for fp, k in zip(fps, names)}
            for fp in fps:                     # third build evicts the LRU
                f.submit(fp, x[fp])
                f.run_until_drained()
            assert len(f.pool) == 2
            assert fps[0] not in f.pool        # oldest evicted
            assert _obs.snapshot()["counters"]["frontend.pool_evict"] == 1
            r = f.submit(fps[0], x[fps[0]])    # re-warm from registry
            f.run_until_drained()
            assert r.status == "ok"
            assert fps[0] in f.pool and fps[1] not in f.pool
            a64 = mats["stencil1d"].astype(np.float64)
            ref = a64 @ np.asarray(x[fps[0]], np.float64)
            assert np.max(np.abs(r.y - ref)) <= \
                pol.tier_error_budget(r.tier_kind) * np.max(np.abs(ref))

    def test_shed_order_drops_best_effort_first(self, tiny_csr):
        adm = pol.AdmissionPolicy(max_queue=10, shed_watermark=0.5)
        clk = pol.ManualClock()
        with mk_frontend(clk, admission=adm) as f:
            fp = f.register(tiny_csr, warm=False)
            x = np.ones(tiny_csr.shape[1])
            batch = []
            for _ in range(4):
                batch.append(f.submit(fp, x, klass="batch"))
                clk.advance(0.001)
            inter = []
            for _ in range(4):
                inter.append(f.submit(fp, x, klass="interactive"))
                clk.advance(0.001)
            f.step()                           # sheds down to watermark
            shed = [r for r in batch + inter if r.status == "shed"]
            assert len(shed) == 3              # 8 queued -> 5 kept
            assert all(r.klass.name == "batch" for r in shed)
            # newest best-effort requests go first
            assert [r.uid for r in shed] == [r.uid for r in batch[1:]]
            f.run_until_drained()
            assert all(r.status == "ok" for r in inter)

    def test_overload_demotes_down_the_ladder(self, tiny_csr):
        adm = pol.AdmissionPolicy(max_queue=10, shed_watermark=0.95)
        with mk_frontend(admission=adm) as f:
            fp = f.register(tiny_csr, warm=False)
            x = np.ones(tiny_csr.shape[1])
            reqs = [f.submit(fp, x, klass="interactive") for _ in range(9)]
            f.run_until_drained()              # occupancy 0.9 -> level 2
            assert all(r.status == "ok" for r in reqs)
            # interactive tier 2 + level 2, floor-clamped to tier 3
            assert reqs[0].tier_kind == pol.DEFAULT_LADDER[3]
            # once drained, later traffic recovers full precision
            r = f.submit(fp, x, klass="interactive")
            f.run_until_drained()
            assert r.tier_kind == pol.DEFAULT_LADDER[2]

    def test_solve_requests_are_served(self, tiny_csr):
        with mk_frontend() as f:
            fp = f.register(tiny_csr, warm=False)
            b = tiny_csr.astype(np.float64) @ np.ones(tiny_csr.shape[0])
            r = f.submit(fp, b, klass="batch", op="solve")
            f.run_until_drained()
            assert r.status == "ok"
            assert r.tier_kind.startswith("solve:")
            assert r.solve_info.relres <= 1e-7


# ---------------------------------------------------------------------------
# exporter lifecycle (satellite: engine/exporter teardown guarantees)
# ---------------------------------------------------------------------------


class TestExporterLifecycle:
    def test_exporter_context_manager_stops_and_flushes(self, tmp_path,
                                                        obs):
        _obs.inc("lifecycle.probe")
        path = str(tmp_path / "m.jsonl")
        with _export.Exporter(_export.JsonlSink(path), 60.0) as ex:
            assert ex.alive
        assert not ex.alive
        assert ex.flushes >= 1                 # final flush on __exit__
        assert (tmp_path / "m.jsonl").exists()

    def test_frontend_context_manager_stops_exporter(self, tmp_path,
                                                     tiny_csr, obs):
        path = str(tmp_path / "fe.jsonl")
        with mk_frontend() as f:
            fp = f.register(tiny_csr, warm=False)
            ex = f.start_metrics_exporter(path=path, interval_s=60.0)
            f.submit(fp, np.ones(tiny_csr.shape[1]))
            f.run_until_drained()
            assert ex.alive
        assert not ex.alive and f._exporter is None
        assert (tmp_path / "fe.jsonl").exists()

    def test_engine_exit_and_run_flush_guarantees(self, tmp_path, obs,
                                                  monkeypatch):
        import jax

        from repro import configs
        from repro.models import transformer as tfm
        from repro.serving import DecodeEngine, ServeConfig

        cfg = configs.reduce(configs.get("qwen2-0.5b"))
        params, _ = tfm.init_params(cfg, jax.random.PRNGKey(0))
        path = str(tmp_path / "eng.jsonl")
        with DecodeEngine(cfg, params, ServeConfig(slots=1,
                                                   max_len=32)) as eng:
            ex = eng.start_metrics_exporter(path=path, interval_s=60.0)
            assert ex.alive
            # regression: an exception mid-run must still land tallies
            eng.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=2)

            def boom(self):
                raise RuntimeError("boom")

            monkeypatch.setattr(DecodeEngine, "step", boom)
            with pytest.raises(RuntimeError):
                eng.run()
            assert (tmp_path / "eng.jsonl").exists()
        # __exit__ guarantee: exporter stopped + detached however we left
        assert not ex.alive
        assert eng._exporter is None
