"""Property-based tests (hypothesis) for the distributed layer: for random
matrices, shard counts, codecs, and delta widths, partition-then-SpMV must
equal the single-device result — including empty shards (n < P) and shards
whose rows reference only remote columns. Uses the host reference replay of
the stacked operands, so the properties hold on a single device; the real
shard_map dispatch is pinned to the replay in tests/test_distributed.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import packsell as pk
from repro.distributed import build_operands, reference_spmv

FORMATS = st.sampled_from([("fp16", 15), ("bf16", 15), ("e8m", 4),
                           ("e8m", 8), ("e8m", 12), ("fixed16", 10)])


@st.composite
def square_mats(draw, max_n=80):
    n = draw(st.integers(1, max_n))
    density = draw(st.floats(0.02, 0.4))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    a = sp.random(n, n, density=density, random_state=rng,
                  data_rvs=lambda k: rng.standard_normal(k)).tocsr()
    a.sort_indices()
    return a


@given(square_mats(), st.integers(1, 7), FORMATS)
@settings(max_examples=25, deadline=None)
def test_partition_spmv_matches_single_device(a, n_shards, fmt):
    codec, D = fmt
    ops = build_operands(a, n_shards, C=4, sigma=8, D=D, codec=codec)
    x = np.random.default_rng(0).standard_normal(a.shape[0]) \
        .astype(np.float32)
    y = reference_spmv(ops, x)
    mat = pk.from_csr(a, C=4, sigma=8, D=D, codec=codec)
    y1 = np.asarray(pk.packsell_spmv_jnp(mat, jnp.asarray(x)))
    np.testing.assert_allclose(y, y1, rtol=3e-5, atol=3e-5)


@given(square_mats(max_n=40), st.integers(2, 6))
@settings(max_examples=15, deadline=None)
def test_partition_spmv_off_diagonal_only(a, n_shards):
    """All-halo-column stress: zero the diagonal blocks so every stored
    entry of every shard is remote."""
    n = a.shape[0]
    coo = a.tocoo()
    # drop entries whose row and column land in the same shard
    base, rem = divmod(n, n_shards)
    counts = base + (np.arange(n_shards) < rem)
    starts = np.concatenate([[0], np.cumsum(counts)])
    owner = lambda i: np.searchsorted(starts, i, side="right") - 1
    keep = owner(coo.row) != owner(coo.col)
    a_off = sp.csr_matrix((coo.data[keep], (coo.row[keep], coo.col[keep])),
                          shape=a.shape)
    ops = build_operands(a_off, n_shards, C=4, sigma=8)
    assert all(m.nnz == 0 for m in ops.mats_loc)
    x = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    y1 = np.asarray(pk.packsell_spmv_jnp(
        pk.from_csr(a_off, C=4, sigma=8), jnp.asarray(x)))
    np.testing.assert_allclose(reference_spmv(ops, x), y1,
                               rtol=3e-5, atol=3e-5)


@given(st.integers(1, 12), st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_partition_handles_empty_shards(n, n_shards):
    """n < P leaves trailing shards with zero rows; SpMV must still match."""
    rng = np.random.default_rng(n * 31 + n_shards)
    a = sp.csr_matrix(rng.standard_normal((n, n)) *
                      (rng.random((n, n)) < 0.5))
    ops = build_operands(a, n_shards, C=4, sigma=4)
    x = rng.standard_normal(n).astype(np.float32)
    y1 = np.asarray(pk.packsell_spmv_jnp(
        pk.from_csr(a, C=4, sigma=4), jnp.asarray(x)))
    np.testing.assert_allclose(reference_spmv(ops, x), y1,
                               rtol=3e-5, atol=3e-5)
