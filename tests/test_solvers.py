"""Solver stack tests: convergence to the paper's criterion (eq. 6) on SPD
and nonsymmetric systems, mixed-precision behaviour, F3R and IO-CG."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from repro.core import testmats
from repro.solvers import (OperatorSet, f3r, fcg, fgmres, iocg, pcg, precond,
                           sym_scale)

TOL = 1e-9


def _spd_system(n=600, seed=0):
    a = testmats.stencil_3d(8, 8, 9, neighbours=27)  # n=576 SPD
    a_s, d = sym_scale(a.tocsr())
    rng = np.random.default_rng(seed)
    b = rng.random(a.shape[0])
    return a_s, jnp.asarray(b, jnp.float64)


def _true_relres(csr, x, b):
    r = np.asarray(b) - csr @ np.asarray(x)
    return np.linalg.norm(r) / np.linalg.norm(np.asarray(b))


def test_pcg_converges_spd():
    a, b = _spd_system()
    ops = OperatorSet(a, C=8, sigma=32)
    M = precond.jacobi(ops.diag(), dtype=jnp.float64)
    x, info = pcg(ops.matvec("fp64"), b, M=M, tol=TOL, maxiter=2000)
    assert _true_relres(a, x, b) < 5 * TOL
    assert int(info.iters) < 2000
    # residual history is monotone-ish and recorded
    h = np.asarray(info.history)
    assert h[0] > 0 and h[int(info.iters)] < TOL


def test_fcg_with_inner_pcg_converges():
    a, b = _spd_system()
    ops = OperatorSet(a, C=8, sigma=32)
    cfg = iocg.IOCGConfig(m_in=20, inner_spmv="fp32", tol=TOL)
    x, info = iocg.solve(ops, b, cfg)
    assert _true_relres(a, x, b) < 5 * TOL


@pytest.mark.parametrize("variant", ["fp32", "e8m8", "e8m12"])
def test_iocg_variants_converge(variant):
    a, b = _spd_system()
    ops = OperatorSet(a, C=8, sigma=32)
    cfg = iocg.variant(variant, m_in=20)
    x, info = iocg.solve(ops, b, cfg)
    assert _true_relres(a, x, b) < 5 * TOL


def test_iocg_e8m_converges_like_fp32_and_beats_fp16_outer_iters():
    """Paper Fig. 12: E8MY (large Y) tracks FP32 convergence; FP16 degrades
    with large m_in."""
    a, b = _spd_system()
    ops = OperatorSet(a, C=8, sigma=32)
    it = {}
    for v in ["fp32", "e8m8", "fp16"]:
        cfg = iocg.variant(v, m_in=50)
        x, info = iocg.solve(ops, b, cfg)
        it[v] = int(info.iters)
        assert _true_relres(a, x, b) < 1e-6, v
    assert it["e8m8"] <= it["fp16"]


def test_fgmres_nonsymmetric():
    a = testmats.hpgmp(6, 6, 6)
    a_s, _ = sym_scale(a.tocsr())
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.random(a.shape[0]), jnp.float64)
    ops = OperatorSet(a_s, C=8, sigma=32)
    M = precond.jacobi(ops.diag(), dtype=jnp.float64)
    x, info = fgmres(ops.matvec("fp64"), b, M=M, m=30, tol=TOL,
                     max_cycles=50)
    assert _true_relres(a_s, x, b) < 5 * TOL


@pytest.mark.parametrize("variant", ["fp64", "fp16", "packsell"])
def test_f3r_variants_converge(variant):
    a, b = _spd_system()
    ops = OperatorSet(a, C=8, sigma=32)
    cfg = f3r.presets(variant)
    x, info = f3r.solve(ops, b, cfg)
    assert _true_relres(a, x, b) < 5 * TOL, variant


def test_f3r_fp16_and_packsell_identical_convergence():
    """Paper §5.2.1: FP16 values embed exactly in PackSELL, so FP16-F3R and
    PackSELL-F3R must take the same outer iterations."""
    a, b = _spd_system()
    ops = OperatorSet(a, C=8, sigma=32)
    _, i16 = f3r.solve(ops, b, f3r.presets("fp16"))
    _, ipk = f3r.solve(ops, b, f3r.presets("packsell"))
    assert int(i16.iters) == int(ipk.iters)


def test_backward_error_definition():
    """Paper eq. (5): backward error of low-precision SpMV."""
    a = testmats.random_banded(800, 40, 9, seed=2)
    from repro.solvers.operators import row_scale
    a_s, _ = row_scale(a.tocsr())
    ops = OperatorSet(a_s.tocsr(), C=8, sigma=32)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(a.shape[0]), jnp.float32)
    y16 = np.asarray(ops.matvec("packsell_fp16")(x))
    y_exact = a_s @ np.asarray(x, np.float64)
    anorm = np.abs(a_s).max(axis=1).toarray().ravel().max()
    be16 = np.abs(y16 - y_exact).max() / (anorm * np.abs(np.asarray(x)).max())
    y8m = np.asarray(ops.matvec("packsell_e8m2")(x))
    be8m = np.abs(y8m - y_exact).max() / (anorm * np.abs(np.asarray(x)).max())
    assert be8m < be16  # E8M20 ≈ FP32-level accuracy, far below FP16
    assert be16 < 1e-2
