"""Chaos harness for the serving front end (DESIGN.md §15.6).

``robust/inject.py``-driven campaigns against a LIVE frontend:
fused-word bit flips mid-service, precision-store garbling, and
plan-token staleness.  The contract under attack is always the same
three-part promise:

1. the breaker opens on persistent corruption (and the fp32 fallback
   keeps answering while it is open),
2. every response the service COMPLETES stays within its tier's error
   budget against the fp64 oracle — corrupted answers are retried or
   rerouted, never delivered,
3. after the background rebuild, half-open probes re-close the breaker
   and the packed tier serves again.

The final test is the acceptance trace: sustained 2x-capacity overload
plus a 50-injection campaign, holding zero out-of-budget deliveries,
>= 70% of pre-overload goodput, and full breaker recovery.
"""
import numpy as np
import pytest

from repro.core import testmats
from repro.observe import metrics as _obs
from repro.robust import inject as inj
from repro.serving import frontend as fe
from repro.serving import policy as pol

#: chaos-harness tier budget safety: tight enough that a delivered
#: corrupted answer (not just quantization noise) would fail the check
SAFETY = 16.0


@pytest.fixture(scope="module")
def amat():
    return testmats.suite("tiny")["stencil1d"]


@pytest.fixture()
def obs():
    was = _obs.enabled()
    _obs.enable(True)
    _obs.reset()
    yield _obs
    _obs.reset()
    _obs.enable(was)


def mk_frontend(clock=None, **kw):
    kw.setdefault("slots", 4)
    kw.setdefault("background", False)
    kw.setdefault("C", 32)
    kw.setdefault("sigma", 64)
    return fe.ServingFrontend(fe.FrontendConfig(**kw),
                              clock=clock or pol.ManualClock())


def in_budget(req, a_csr) -> bool:
    """Paper eq. (5) backward error of a completed spmv response vs the
    fp64 oracle, against the responding tier's budget."""
    kind = "fp32" if req.tier_kind == "fp32_fallback" else req.tier_kind
    budget = pol.tier_error_budget(kind, safety=SAFETY)
    x = np.asarray(req.x, np.float64)
    exact = a_csr.astype(np.float64) @ x
    num = float(np.max(np.abs(np.asarray(req.y, np.float64) - exact)))
    anorm = float(np.max(np.abs(a_csr).sum(axis=1)))
    xnorm = float(np.max(np.abs(x)))
    return num <= budget * max(anorm * xnorm, 1e-300)


class TestChaosCampaigns:
    def test_breaker_opens_on_persistent_corruption_then_recloses(
            self, amat):
        clk = pol.ManualClock()
        # cooldown strictly below the attempt-2 backoff delay (0.01 s),
        # so the first post-rebuild retry is an eligible half-open probe
        with mk_frontend(clk, fail_threshold=2, cooldown_s=0.008,
                         backoff=pol.BackoffPolicy(base=0.005,
                                                   max_attempts=6)) as f:
            fp = f.register(amat, warm=False)
            rng = np.random.default_rng(0)
            r0 = f.submit(fp, rng.standard_normal(amat.shape[1]),
                          klass="interactive")
            f.run_until_drained()
            kind = r0.tier_kind
            entry = f._entry(fp)

            # corruption that SURVIVES the first repair: re-flip a fused
            # word after the first rebuild, so the breaker must actually
            # reach its consecutive-failure threshold
            mat, plan, _ = entry.bind(kind)
            inj.flip_fused_word(mat, plan, seed=1)
            real_rebuild, sabotage = entry.rebuild, {"left": 1}

            def sabotaged(k):
                real_rebuild(k)
                if sabotage["left"] > 0:
                    sabotage["left"] -= 1
                    m2, p2, _ = entry.bind(k)
                    inj.flip_fused_word(m2, p2, seed=2)

            entry.rebuild = sabotaged
            reqs = [f.submit(fp, rng.standard_normal(amat.shape[1]),
                             klass="interactive") for _ in range(3)]
            f.run_until_drained(max_ticks=500)

            assert all(r.status == "ok" for r in reqs)
            assert all(in_budget(r, amat) for r in reqs + [r0])
            edges = [(s, d) for _, s, d in entry.breaker.transitions]
            assert (pol.CLOSED, pol.OPEN) in edges          # quarantined
            assert (pol.HALF_OPEN, pol.CLOSED) in edges     # re-admitted
            assert entry.breaker.state == pol.CLOSED
            assert entry.healthy(kind)
            # the packed tier is serving again post-recovery
            r2 = f.submit(fp, rng.standard_normal(amat.shape[1]),
                          klass="interactive")
            f.run_until_drained()
            assert r2.tier_kind == kind and in_budget(r2, amat)

    def test_store_garbling_never_reaches_responses(self, amat, tmp_path,
                                                    obs):
        from repro.precision import PrecisionStore

        path = str(tmp_path / "store.json")
        PrecisionStore(path).lookup_or_select(amat, 1e-3, sigma=64)
        inj.corrupt_store(path, seed=0, mode="garble")
        with mk_frontend(store=path) as f:
            fp = f.register(amat, warm=False)
            rng = np.random.default_rng(1)
            reqs = [f.submit(fp, rng.standard_normal(amat.shape[1]),
                             klass="interactive") for _ in range(3)]
            f.run_until_drained()
            assert all(r.status == "ok" for r in reqs)
            assert all(in_budget(r, amat) for r in reqs)

    def test_plan_token_staleness_forces_rebuild(self, amat, obs):
        with mk_frontend() as f:
            fp = f.register(amat, warm=False)
            rng = np.random.default_rng(2)
            r0 = f.submit(fp, rng.standard_normal(amat.shape[1]),
                          klass="interactive")
            f.run_until_drained()
            kind = r0.tier_kind
            entry = f._entry(fp)
            mat, _, _ = entry.bind(kind)
            mat._plan_token = object()         # operand refreshed under us
            assert entry.stale(kind)
            r = f.submit(fp, rng.standard_normal(amat.shape[1]),
                         klass="interactive")
            f.run_until_drained()
            assert r.status == "ok" and in_budget(r, amat)
            assert not entry.stale(kind)       # token re-recorded
            stale = [v for k, v in _obs.snapshot()["counters"].items()
                     if k.startswith("frontend.stale_plan")]
            assert sum(stale) == 1


class TestAcceptanceTrace:
    """The ISSUE acceptance criterion: 2x-capacity sustained overload +
    a 50-injection chaos campaign, in simulated time."""

    def test_overload_plus_fault_trace(self, amat, obs):
        DT = 0.01                              # simulated step duration
        SLOTS = 4                              # capacity: SLOTS/DT req/s
        clk = pol.ManualClock()
        adm = pol.AdmissionPolicy(max_queue=32, shed_watermark=0.9)
        cfg = dict(slots=SLOTS, admission=adm, fail_threshold=1,
                   cooldown_s=0.02, plan_pool=4,
                   backoff=pol.BackoffPolicy(base=0.005, max_attempts=3))
        rng = np.random.default_rng(7)
        classes = ["interactive", "standard", "batch"]

        with mk_frontend(clk, **cfg) as f:
            fp = f.register(amat, warm=False)
            entry_holder = {}

            def arrive(rate_per_s):
                k = rng.poisson(rate_per_s * DT)
                out = []
                for _ in range(k):
                    out.append(f.submit(
                        fp, rng.standard_normal(amat.shape[1]),
                        klass=classes[int(rng.integers(3))]))
                return out

            def inject_burst(n, seed0):
                entry = f._entry(fp)
                entry_holder["e"] = entry
                kinds = [k for k in entry.guards] or []
                done = 0
                for i in range(n):
                    if not kinds:
                        break
                    kind = kinds[int(rng.integers(len(kinds)))]
                    mat, plan, _ = entry.bind(kind)
                    try:
                        inj.flip_fused_word(mat, plan, seed=seed0 + i)
                    except ValueError:         # plan carries no fused
                        inj.flip_pack_word(mat, plan, seed=seed0 + i)
                    done += 1
                return done

            cap = SLOTS / DT                   # 400 req/s service ceiling
            all_reqs = []
            # phase 1 — normal load at 0.8x capacity
            for _ in range(60):
                all_reqs += arrive(0.8 * cap)
                f.step()
                clk.advance(DT)
            t_pre_end = clk()
            # phase 2 — 2x capacity overload + 50-injection campaign
            injected = 0
            for t in range(150):
                all_reqs += arrive(2.0 * cap)
                if t % 15 == 5 and injected < 50:
                    injected += inject_burst(5, seed0=100 + injected)
                f.step()
                clk.advance(DT)
            t_over_end = clk()
            assert injected == 50              # full campaign landed
            # phase 3 — recovery: clean light traffic, then drain
            for _ in range(40):
                all_reqs += arrive(0.3 * cap)
                f.step()
                clk.advance(DT)
            f.run_until_drained(max_ticks=2000)

            oks = [r for r in all_reqs if r.status == "ok"
                   and r.op == "spmv"]
            # 1) ZERO out-of-budget deliveries, fp64-oracle checked
            bad = [r for r in oks if not in_budget(r, amat)]
            assert not bad, f"{len(bad)} out-of-budget responses"
            # 2) goodput under overload >= 70% of pre-overload QPS
            pre_ok = sum(1 for r in oks if r.t_done <= t_pre_end)
            over_ok = sum(1 for r in oks
                          if t_pre_end < r.t_done <= t_over_end)
            pre_qps = pre_ok / t_pre_end
            over_qps = over_ok / (t_over_end - t_pre_end)
            assert over_qps >= 0.7 * pre_qps, (pre_qps, over_qps)
            # 3) overload actually engaged the valves: sheds happened and
            # tight-SLO traffic demoted down the ladder instead of dying
            st = f.stats()
            assert st["by_status"].get("shed", 0) > 0
            assert any(r.tier_kind == pol.DEFAULT_LADDER[3] for r in oks
                       if r.klass.name == "interactive")
            # every terminal status is a DEFINED behavior (loud rejection
            # at the full queue included) — never 'failed'
            assert all(r.status in ("ok", "shed", "rejected",
                                    "deadline_miss") for r in all_reqs)
            # 4) quarantined plans recovered within the trace
            entry = entry_holder["e"]
            edges = [(s, d) for _, s, d in entry.breaker.transitions]
            assert (pol.CLOSED, pol.OPEN) in edges
            assert entry.breaker.state == pol.CLOSED
            for kind in list(entry.guards):
                assert entry.healthy(kind)
            # and the service still answers on packed tiers afterwards
            r = f.submit(fp, rng.standard_normal(amat.shape[1]),
                         klass="interactive")
            f.run_until_drained()
            assert r.status == "ok" and r.tier_kind.startswith("plan_")
