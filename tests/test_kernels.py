"""Pallas kernel validation (interpret=True on CPU) vs pure-jnp oracles.

Sweeps shapes (slice size C, block sizes, widths), codecs/dtypes, and matrix
structures, asserting allclose against ref.py for every combination.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codecs as cd
from repro.core import packsell, sell, testmats
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _x(m):
    return jnp.asarray(RNG.standard_normal(m).astype(np.float32))


# ---------------------------------------------------------------------------
# PackSELL kernel: full-x variant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec,D", [("fp16", 15), ("bf16", 15), ("e8m", 2),
                                     ("e8m", 8), ("e8m", 12), ("fixed16", 10)])
def test_packsell_kernel_codec_sweep(codec, D):
    a = testmats.random_banded(600, 30, 8, seed=1)
    mat = packsell.from_csr(a, C=8, sigma=32, D=D, codec=codec)
    x = _x(a.shape[1])
    y_k = np.asarray(ops.packsell_spmv(mat, x, sb=4, wb=8, force="full"))
    y_r = np.asarray(ref.packsell_spmv_ref(mat, x))
    np.testing.assert_allclose(y_k, y_r, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("C", [4, 8, 16, 32, 128])
def test_packsell_kernel_slice_size_sweep(C):
    a = testmats.stencil_1d(5 * C + 3, 2, seed=2)
    mat = packsell.from_csr(a, C=C, sigma=4 * C, D=10, codec="e8m")
    x = _x(a.shape[1])
    y_k = np.asarray(ops.packsell_spmv(mat, x, sb=2, wb=4, force="full"))
    y_r = np.asarray(ref.packsell_spmv_ref(mat, x))
    np.testing.assert_allclose(y_k, y_r, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("sb,wb", [(1, 1), (2, 4), (8, 32), (4, 64)])
def test_packsell_kernel_block_sweep(sb, wb):
    a = testmats.powerlaw(700, mean_deg=4, seed=3)
    mat = packsell.from_csr(a, C=8, sigma=64, D=6, codec="e8m")
    x = _x(a.shape[1])
    y_k = np.asarray(ops.packsell_spmv(mat, x, sb=sb, wb=wb, force="full"))
    y_r = np.asarray(ref.packsell_spmv_ref(mat, x))
    np.testing.assert_allclose(y_k, y_r, rtol=1e-6, atol=1e-6)


def test_packsell_kernel_vs_dense_oracle():
    a = testmats.scattered(300, nnz_per_row=6, seed=4)
    mat = packsell.from_csr(a, C=8, sigma=32, D=3, codec="e8m")
    x = RNG.standard_normal(a.shape[1]).astype(np.float32)
    y_k = np.asarray(ops.packsell_spmv(mat, jnp.asarray(x), sb=4, wb=8,
                                       force="full"))
    y_d = ref.packsell_spmv_dense_oracle(mat, x)
    np.testing.assert_allclose(y_k, y_d, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# PackSELL kernel: band-windowed variant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hw", [256, 512])
def test_packsell_band_kernel_banded(hw):
    # band kernel wants column locality within slice-blocks -> uniform
    # bucketing keeps slices contiguous (cheap in the low-RSD banded regime)
    a = testmats.random_banded(2000, 50, 9, seed=5)
    mat = packsell.from_csr(a, C=8, sigma=32, D=12, codec="e8m",
                            bucket_strategy="uniform")
    x = _x(a.shape[1])
    y_k = np.asarray(ops.packsell_spmv(mat, x, sb=4, wb=8, hw=hw,
                                       force="band"))
    y_r = np.asarray(ref.packsell_spmv_ref(mat, x))
    np.testing.assert_allclose(y_k, y_r, rtol=1e-6, atol=1e-6)


def test_band_plan_infeasible_for_scattered():
    a = testmats.scattered(600, nnz_per_row=5, seed=6)
    mat = packsell.from_csr(a, C=8, sigma=32, D=4, codec="e8m")
    assert ops.band_plan(mat, sb=4, hw=128) is None
    with pytest.raises(ValueError):
        ops.packsell_spmv(mat, _x(600), sb=4, hw=128, force="band")


def test_band_matches_full_on_stencil():
    a = testmats.stencil_3d(12, 12, 12, neighbours=27)
    mat = packsell.from_csr(a, C=16, sigma=64, D=15, codec="fp16",
                            bucket_strategy="uniform")
    x = _x(a.shape[1])
    y_b = np.asarray(ops.packsell_spmv(mat, x, sb=4, wb=8, hw=1024,
                                       force="band"))
    y_f = np.asarray(ops.packsell_spmv(mat, x, sb=4, wb=8, force="full"))
    np.testing.assert_allclose(y_b, y_f, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# SELL baseline kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "float16", "bfloat16"])
def test_sell_kernel_dtype_sweep(dtype):
    a = testmats.random_banded(500, 40, 7, seed=7)
    mat = sell.from_csr(a, C=8, sigma=32, value_dtype=dtype)
    x = _x(a.shape[1])
    y_k = np.asarray(ops.sell_spmv(mat, x, sb=4, wb=8))
    y_r = np.asarray(ref.sell_spmv_ref(mat, x))
    np.testing.assert_allclose(y_k, y_r, rtol=1e-6, atol=1e-6)


def test_kernels_inside_jit():
    """Wrappers must be jit-compatible (static meta via pytree aux)."""
    a = testmats.stencil_1d(300, 2, seed=8)
    mat = packsell.from_csr(a, C=8, sigma=32, D=15, codec="fp16")
    x = _x(a.shape[1])

    @jax.jit
    def f(mat, x):
        return ops.packsell_spmv(mat, x, sb=4, wb=8, force="full")

    y = np.asarray(f(mat, x))
    np.testing.assert_allclose(y, np.asarray(ref.packsell_spmv_ref(mat, x)),
                               rtol=1e-6, atol=1e-6)
