"""Adaptive precision subsystem: analyze / select / mixed / store /
adaptive_pcg (DESIGN.md §8)."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import codecs as cd
from repro.core import packsell as pk
from repro.core import testmats
from repro.precision import (MixedPackSELL, PrecisionStore, analyze,
                             matrix_fingerprint, select_codec, tier_ladder)
from repro.precision.select import (PrecisionClass, PrecisionPlan,
                                    build_tier_matvecs, operator_kind)
from repro.solvers import cg
from repro.solvers.operators import OperatorSet, sym_scale

TINY = list(testmats.suite("tiny").items())


# ---------------------------------------------------------------------------
# analyze
# ---------------------------------------------------------------------------


def test_matrix_stats_values_and_deltas():
    a = sp.csr_matrix(np.array([[2.0, 0, 0, 0.5],
                                [0, -8.0, 0, 0],
                                [0, 0, 0.25, 0],
                                [1.0, 0, 0, 4.0]]))
    st = analyze.matrix_stats(a, sigma=4)
    assert st.n == st.m == 4 and st.nnz == 6
    assert st.max_abs == 8.0 and st.min_abs_nz == 0.25
    assert st.dyn_range == 32.0
    assert st.row_max_abs[1] == 8.0
    assert st.row_min_abs_nz[0] == 0.5
    assert st.max_delta == 3  # row 0: 0 -> 3


@pytest.mark.parametrize("name,a", TINY, ids=[m[0] for m in TINY])
def test_dummy_word_count_matches_format(name, a):
    """stats.dummy_words(D) must equal what from_csr actually inserts."""
    st = analyze.matrix_stats(a, sigma=32)
    for D in (2, 8, 15):
        mat = pk.from_csr(a, C=8, sigma=32, D=D, codec="e8m"
                          if D <= 22 else "fp16")
        assert st.dummy_words(D) == mat.n_dummy


def test_model_error_orders_by_mantissa():
    a = testmats.stencil_1d(200, 2)
    st = analyze.matrix_stats(a)
    errs = [analyze.model_error("e8m", D, st) for D in (15, 10, 5, 1)]
    assert errs == sorted(errs, reverse=True)
    # fp16 overflow clipping -> inf
    big = sp.csr_matrix(np.array([[1e38, 0], [0, 1.0]]))
    stb = analyze.matrix_stats(big)
    assert analyze.model_error("fp16", 15, stb) == np.inf
    assert np.isfinite(analyze.model_error("bf16", 15, stb))


@pytest.mark.parametrize("codec,D", [("e8m", 8), ("e8m", 1), ("bf16", 15),
                                     ("fp16", 15)])
def test_probe_error_within_model_bound(codec, D):
    """The measured probe error respects the a-priori element bound up to
    the row-sum amplification (|A||x| / |Ax| is O(1) for these SPD mats)."""
    a = testmats.hpcg(6, 6, 6)
    st = analyze.matrix_stats(a)
    bound = analyze.model_error(codec, D, st)
    probe = analyze.probe_error(a, codec, D, n_probes=2, seed=0)
    assert probe <= 50 * bound
    assert probe >= 0.0


def test_probe_error_rows_flags_quantization_heavy_rows():
    rng = np.random.default_rng(0)
    dense = np.zeros((8, 8))
    dense[:4, :4] = rng.standard_normal((4, 4))
    dense[4:, 4:] = rng.standard_normal((4, 4)) * 1.0000001  # same scale
    a = sp.csr_matrix(dense)
    errs = analyze.probe_error_rows(a, "e8m", 8, n_probes=2)
    assert errs.shape == (8,)
    assert np.all(errs <= 2.0 ** -13)  # elementwise bound, no cancellation


# ---------------------------------------------------------------------------
# select — the acceptance property
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,a", TINY, ids=[m[0] for m in TINY])
@pytest.mark.parametrize("budget", [1e-2, 1e-4, 1e-6])
@pytest.mark.parametrize("seed", [0, 7])
def test_selected_codec_respects_budget(name, a, budget, seed):
    """Acceptance: on every Table-1 analogue class, the selected codec's
    measured probe error — re-measured with INDEPENDENT probe vectors —
    respects the requested budget."""
    plan = select_codec(a, budget, n_probes=2, seed=seed)
    c = plan.primary
    if c.codec == "fp32":
        return  # fallback is exact
    fresh = analyze.probe_error(a, c.codec, c.D, n_probes=3,
                                seed=seed + 1000)
    assert fresh <= budget, (name, c.label, fresh, budget)


def test_select_rationale_is_machine_readable():
    a = TINY[0][1]
    plan = select_codec(a, 1e-3, n_probes=2)
    blob = json.loads(plan.to_json())
    assert blob["mode"] == "global"
    decisions = [c["decision"] for c in blob["rationale"]["candidates"]]
    assert any(d.startswith("selected") for d in decisions)
    # round-trip
    plan2 = PrecisionPlan.from_json(plan.to_json())
    assert plan2.primary == plan.primary
    assert plan2.error_budget == plan.error_budget


def test_select_prefers_fewer_words_at_budget():
    """The cost ranking must price delta feasibility: a long-gap matrix at
    a loose budget should pick a D large enough to avoid dummy words."""
    a = testmats.scattered(512, nnz_per_row=5, seed=3)
    plan = select_codec(a, 1e-2, n_probes=2)
    st = analyze.matrix_stats(a)
    c = plan.primary
    sel = next(x for x in plan.rationale["candidates"]
               if x["decision"].startswith("selected"))
    # no candidate with fewer words also fits the budget
    for cand in plan.rationale["candidates"]:
        if cand["words"] < sel["words"]:
            assert not cand["decision"].startswith("selected")
    assert st.dummy_words(c.D) == sel["dummy_words"]


def test_select_falls_back_to_fp32_on_impossible_budget():
    a = TINY[0][1]
    plan = select_codec(a, 1e-12, n_probes=2)
    assert plan.primary.codec == "fp32"
    assert "fallback" in plan.rationale


def test_select_rows_partitions_and_respects_budget():
    a = testmats.powerlaw(512, mean_deg=5, seed=5)
    budget = 1e-4
    plan = select_codec(a, budget, mode="rows", n_probes=2, max_classes=2)
    assert plan.mode == "rows"
    assert len(plan.classes) <= 2
    all_rows = np.concatenate([np.asarray(c.rows) for c in plan.classes])
    assert sorted(all_rows.tolist()) == list(range(a.shape[0]))
    # every row's class respects the budget on fresh probes
    for c in plan.classes:
        if c.codec == "fp32":
            continue
        errs = analyze.probe_error_rows(a, c.codec, c.D, n_probes=2,
                                        seed=99)
        assert np.all(errs[np.asarray(c.rows)] <= budget)


# ---------------------------------------------------------------------------
# MixedPackSELL
# ---------------------------------------------------------------------------


def _mixed_reference(a, plan):
    """Dense reference: each row quantized at its class codec."""
    dense = a.toarray().astype(np.float64)
    out = np.zeros_like(dense)
    for c in plan.classes:
        rows = (np.arange(a.shape[0]) if c.rows is None
                else np.asarray(c.rows))
        if c.codec == "fp32":
            out[rows] = dense[rows].astype(np.float32)
        else:
            out[rows] = cd.quantize_np(
                dense[rows].astype(np.float32), cd.make_codec(c.codec), c.D)
    return out


def test_mixed_spmv_matches_per_class_quantized_reference():
    a = testmats.powerlaw(512, mean_deg=5, seed=5)
    plan = select_codec(a, 1e-4, mode="rows", n_probes=2, max_classes=3)
    mx = MixedPackSELL(a, plan, C=8, sigma=32)
    ref = _mixed_reference(a, plan)
    rng = np.random.default_rng(1)
    x = rng.standard_normal(a.shape[1]).astype(np.float32)
    y = np.asarray(mx.spmv(jnp.asarray(x)), np.float64)
    want = ref @ x.astype(np.float64)
    np.testing.assert_allclose(y, want, rtol=0, atol=2e-5 * np.abs(want).max())


def test_mixed_handcrafted_classes_and_fp32_block():
    a = testmats.random_banded(200, 10, 4, seed=2)
    rows_lo = tuple(range(0, 100))
    rows_hi = tuple(range(100, 200))
    plan = PrecisionPlan(
        mode="rows",
        classes=(PrecisionClass("e8m", 12, rows_lo),
                 PrecisionClass("fp32", 0, rows_hi)),
        error_budget=1e-3, rationale={})
    mx = MixedPackSELL(a, plan, C=8, sigma=32)
    x = np.random.default_rng(3).standard_normal(200).astype(np.float32)
    y = np.asarray(mx.spmv(jnp.asarray(x)), np.float64)
    ref = _mixed_reference(a, plan) @ x.astype(np.float64)
    np.testing.assert_allclose(y, ref, rtol=0, atol=1e-5 * np.abs(ref).max())
    # fp32 rows are exact vs the fp32 dense product
    st = mx.memory_stats()
    assert len(st["classes"]) == 2
    assert st["mixed_bytes"] == sum(c["bytes"] for c in st["classes"])
    assert st["bytes_per_nnz"] > 0


def test_mixed_spmm_matches_stacked_spmv():
    a = testmats.powerlaw(256, mean_deg=4, seed=6)
    plan = select_codec(a, 1e-3, mode="rows", n_probes=2)
    mx = MixedPackSELL(a, plan, C=8, sigma=32)
    rng = np.random.default_rng(4)
    X = rng.standard_normal((a.shape[1], 3)).astype(np.float32)
    Y = np.asarray(mx.spmm(jnp.asarray(X)))
    for j in range(3):
        yj = np.asarray(mx.spmv(jnp.asarray(X[:, j])))
        np.testing.assert_allclose(Y[:, j], yj, rtol=1e-6, atol=1e-6)


def test_mixed_rejects_non_covering_classes():
    a = testmats.random_banded(64, 4, 3, seed=1)
    plan = PrecisionPlan(
        mode="rows",
        classes=(PrecisionClass("e8m", 8, tuple(range(10))),
                 PrecisionClass("fp32", 0, tuple(range(20, 64)))),
        error_budget=1e-3, rationale={})
    with pytest.raises(ValueError, match="cover"):
        MixedPackSELL(a, plan, C=8, sigma=32)
    # a single partial class must raise too, never silently widen to all
    # rows (the uncovered rows were never budget-certified at that codec)
    one = PrecisionPlan(mode="rows",
                        classes=(PrecisionClass("e8m", 8, tuple(range(10))),),
                        error_budget=1e-3, rationale={})
    with pytest.raises(ValueError, match="cover"):
        MixedPackSELL(a, one, C=8, sigma=32)


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------


def test_fingerprint_stable_and_content_sensitive():
    a = testmats.random_banded(128, 8, 4, seed=0)
    assert matrix_fingerprint(a) == matrix_fingerprint(a.copy())
    b = a.copy()
    b.data = b.data.copy()
    b.data[0] *= 2.0
    assert matrix_fingerprint(a) != matrix_fingerprint(b)
    c = testmats.random_banded(128, 8, 4, seed=1)
    assert matrix_fingerprint(a) != matrix_fingerprint(c)


def test_store_roundtrip_tmpdir(tmp_path):
    path = os.fspath(tmp_path / "sub" / "store.json")
    a = testmats.random_banded(128, 8, 4, seed=0)
    st = PrecisionStore(path)
    plan, hit = st.lookup_or_select(a, 1e-3, n_probes=2)
    assert not hit
    assert os.path.exists(path)
    # fresh handle: hit, identical selection
    st2 = PrecisionStore(path)
    plan2, hit2 = st2.lookup_or_select(a, 1e-3, n_probes=2)
    assert hit2
    assert plan2.primary == plan.primary
    assert plan2.rationale["candidates"] == plan.rationale["candidates"]
    # retile winners merge into the same entry and survive reload
    fp = matrix_fingerprint(a)
    st2.put_retile(fp, "plan_e8m8", [(8, 32), (4, 16)])
    st3 = PrecisionStore(path)
    assert st3.get_retile(fp, "plan_e8m8") == [(8, 32), (4, 16)]
    assert st3.get_plan(fp).primary == plan.primary
    # the JSON on disk is one valid document (atomic write)
    with open(path) as f:
        blob = json.load(f)
    assert blob["version"] == 1 and fp in blob["entries"]


def test_store_validate_reselects_on_budget_miss(tmp_path):
    path = os.fspath(tmp_path / "store.json")
    a = testmats.random_banded(128, 8, 4, seed=0)
    st = PrecisionStore(path)
    st.lookup_or_select(a, 1e-2, n_probes=2)
    # tighter budget: the stored (looser) plan must NOT satisfy it
    plan, hit = st.lookup_or_select(a, 1e-7, n_probes=2)
    assert not hit
    assert plan.error_budget == 1e-7


def test_store_keeps_modes_separate(tmp_path):
    """A rows-mode plan must never be returned for a global request (its
    primary class is only budget-certified for a row subset), and vice
    versa; both live side by side in one entry."""
    path = os.fspath(tmp_path / "store.json")
    a = testmats.powerlaw(256, mean_deg=5, seed=5)
    st = PrecisionStore(path)
    p_rows, hit = st.lookup_or_select(a, 1e-4, mode="rows", n_probes=2)
    assert not hit and p_rows.mode == "rows"
    p_glob, hit = st.lookup_or_select(a, 1e-4, mode="global", n_probes=2)
    assert not hit and p_glob.mode == "global"
    # both now hit, each under its own mode
    assert st.lookup_or_select(a, 1e-4, mode="rows", n_probes=2)[1]
    assert st.lookup_or_select(a, 1e-4, mode="global", n_probes=2)[1]
    fp = matrix_fingerprint(a)
    assert PrecisionStore(path).get_plan(fp, mode="rows").mode == "rows"
    assert PrecisionStore(path).get_plan(fp).mode == "global"


def test_store_hit_requires_safety_at_least_as_tight(tmp_path):
    path = os.fspath(tmp_path / "store.json")
    a = testmats.random_banded(128, 8, 4, seed=0)
    st = PrecisionStore(path)
    st.lookup_or_select(a, 1e-3, n_probes=2, safety=0.9)
    # a stricter safety must NOT reuse the loosely-certified plan
    plan, hit = st.lookup_or_select(a, 1e-3, n_probes=2, safety=0.1)
    assert not hit
    assert plan.rationale["safety"] == 0.1


def test_precision_plan_cache_keys_on_selection_params():
    a0 = testmats.random_banded(256, 12, 5, seed=4)
    a, _ = sym_scale(a0)
    ops = OperatorSet(a, C=8, sigma=32)
    p1 = ops.precision_plan(1e-3, n_probes=2, safety=0.5)
    p2 = ops.precision_plan(1e-3, n_probes=2, safety=0.01)
    assert p1.rationale["safety"] == 0.5
    assert p2.rationale["safety"] == 0.01
    assert ops.precision_plan(1e-3, n_probes=2, safety=0.5) is p1


def test_tier_ladder_fp32_fallback_is_single_tier():
    plan = PrecisionPlan(mode="global",
                         classes=(PrecisionClass("fp32", 0),),
                         error_budget=1e-15, rationale={})
    assert tier_ladder(plan) == [PrecisionClass("fp32", 0)]


def test_store_fp32_fallback_does_not_hit_looser_budgets(tmp_path):
    """A stored fp32-fallback plan certifies 'nothing packed fits THAT
    budget' — a looser request may admit a packed codec and must
    reselect (regression: the hit rule used to serve fp32 forever)."""
    path = os.fspath(tmp_path / "store.json")
    a = testmats.random_banded(128, 8, 4, seed=0)
    st = PrecisionStore(path)
    p0, _ = st.lookup_or_select(a, 1e-12, n_probes=2)
    assert p0.primary.codec == "fp32"
    plan, hit = st.lookup_or_select(a, 1e-3, n_probes=2)
    assert not hit
    assert plan.primary.codec != "fp32"
    # tighter-than-stored requests may reuse the fallback (still correct)
    plan2, hit2 = st.lookup_or_select(a, 1e-13, n_probes=2)
    assert plan2.primary.codec == "fp32"


def test_store_hit_respects_candidate_restriction(tmp_path):
    """A caller that restricts `candidates` must never receive a stored
    plan built from codecs outside that set (e.g. a deployment that only
    ships e8m kernels)."""
    path = os.fspath(tmp_path / "store.json")
    a = testmats.random_banded(128, 8, 4, seed=0)
    st = PrecisionStore(path)
    p0, _ = st.lookup_or_select(a, 1e-3, n_probes=2)
    restricted = (("e8m", 4),)
    assert (p0.primary.codec, p0.primary.D) not in restricted
    plan, hit = st.lookup_or_select(a, 1e-3, n_probes=2,
                                    candidates=restricted)
    assert not hit
    assert (plan.primary.codec, plan.primary.D) in set(restricted) | \
        {("fp32", 0)}


def test_store_apply_retile_on_plan(tmp_path):
    from repro.kernels import plan as kplan
    a = testmats.random_banded(128, 8, 4, seed=0)
    mat = pk.from_csr(a, C=8, sigma=32, D=8, codec="e8m")
    plan = kplan.get_plan(mat)
    st = PrecisionStore(os.fspath(tmp_path / "s.json"))
    fp = matrix_fingerprint(a)
    tiles = [(4, 16)] * len(plan.tiles)
    st.put_retile(fp, "plan_e8m8", tiles)
    assert st.apply_retile(fp, "plan_e8m8", plan)
    assert plan.tiles == tuple(tiles)
    # wrong arity: not applied
    st.put_retile(fp, "bad", [(4, 16)] * (len(plan.tiles) + 1))
    assert not st.apply_retile(fp, "bad", plan)


# ---------------------------------------------------------------------------
# adaptive_pcg — the acceptance criterion
# ---------------------------------------------------------------------------


def _run_adaptive(a0, budget=1e-3, tol=1e-8):
    a, _ = sym_scale(a0)
    ops = OperatorSet(a, C=32, sigma=256)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(a.shape[0]))
    diag = ops.diag()
    dinv = jnp.asarray(np.where(diag == 0, 1.0, 1.0 / diag))
    M = lambda r: r * dinv                                   # noqa: E731
    x32, info32 = cg.pcg(ops.matvec("fp32"), b, M=M, tol=tol, maxiter=2000)
    tiers, labels, sub32, hi = ops.adaptive_tiers(budget, n_probes=2)
    x, info = cg.adaptive_pcg(tiers, b, M=M, matvec_hi=hi, tol=tol,
                              maxiter=60, m_in=16)
    btrue = np.asarray(b, np.float64)
    t32 = np.linalg.norm(btrue - a @ np.asarray(x32, np.float64)) \
        / np.linalg.norm(btrue)
    tad = np.linalg.norm(btrue - a @ np.asarray(x, np.float64)) \
        / np.linalg.norm(btrue)
    counts = np.asarray(info.tier_matvecs)
    frac = counts[np.asarray(sub32)].sum() / \
        (counts.sum() + int(info.hi_matvecs))
    return t32, tad, float(info.relres), frac, info


@pytest.mark.parametrize("name,gen", [
    ("banded", lambda: testmats.random_banded(1200, 24, 6, seed=1)),
    ("powerlaw", lambda: testmats.powerlaw(1200, mean_deg=5, spd=True,
                                           seed=2)),
])
def test_adaptive_pcg_acceptance(name, gen):
    """Acceptance: adaptive_pcg on banded + power-law classes matches the
    full-FP32 PCG final residual (<= 1e-8) with >= 80% of matvecs in a
    sub-32-bit codec."""
    t32, tad, relres, frac, info = _run_adaptive(gen())
    assert relres <= 1e-8
    assert tad <= 1e-8          # TRUE residual, not just the recurrence
    assert tad <= max(t32, 1e-8)  # no worse than the fp32 baseline
    assert frac >= 0.80, (name, frac)


def test_adaptive_pcg_promotes_on_stagnation():
    """An ill-conditioned operator under a coarse codec (E8M7: eps*kappa>1,
    iterative refinement cannot contract) must trigger tier promotion and
    still converge through the finer tiers."""
    n = 96
    a = sp.diags([-np.ones(n - 1), 2 * np.ones(n), -np.ones(n - 1)],
                 [-1, 0, 1]).tocsr()   # 1D Laplacian, kappa ~ 4 n^2 / pi^2
    ops = OperatorSet(a, C=8, sigma=32)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(n))
    ladder = [PrecisionClass("e8m", 15), PrecisionClass("e8m", 1),
              PrecisionClass("fp32", 0)]
    tiers, labels, sub32 = build_tier_matvecs(ops, ladder)
    # m_in ~ sqrt(kappa) iterations so the inner solve is accurate enough
    # that any stall is the codec's fault, not the inner solver's
    x, info = cg.adaptive_pcg(tiers, b, matvec_hi=ops.matvec("fp64"),
                              tol=1e-8, maxiter=60, m_in=48)
    assert int(info.promotions) >= 1
    assert float(info.relres) <= 1e-8
    used = np.asarray(info.tier_history)[:int(info.iters)]
    assert used[0] == 0 and used[-1] > 0  # started low, ended promoted


def test_adaptive_pcg_tier_ladder_shapes():
    plan = PrecisionPlan(mode="global",
                         classes=(PrecisionClass("e8m", 12),),
                         error_budget=1e-3, rationale={})
    ladder = tier_ladder(plan)
    assert ladder[0] == PrecisionClass("e8m", 12)
    assert ladder[-1].codec == "fp32"
    errs = [0.0 if c.codec == "fp32" else 2.0 ** -(23 - c.D)
            for c in ladder]
    assert errs == sorted(errs, reverse=True)
    assert operator_kind(ladder[0]) == "plan_e8m12"
    assert operator_kind(ladder[-1]) == "fp32"


def test_operator_set_auto_and_mixed_kinds():
    a0 = testmats.random_banded(300, 12, 5, seed=4)
    a, _ = sym_scale(a0)
    ops = OperatorSet(a, C=8, sigma=32)
    x = jnp.asarray(np.random.default_rng(5).standard_normal(a.shape[0])
                    .astype(np.float32))
    y_auto = np.asarray(ops.matvec("auto:1e-3")(x), np.float64)
    y_mixed = np.asarray(ops.matvec("mixed:1e-3")(x), np.float64)
    ref = a.astype(np.float64) @ np.asarray(x, np.float64)
    for y in (y_auto, y_mixed):
        assert np.linalg.norm(y - ref) / np.linalg.norm(ref) <= 1e-3
    # the mixed kind exposes its MixedPackSELL for memory accounting
    mx = ops.stored("mixed:1e-3")
    assert isinstance(mx, MixedPackSELL)
    assert mx.memory_stats()["nnz"] == a.nnz


def test_packsell_linear_auto_codec(tmp_path):
    from repro.models.sparse_linear import PackSELLLinear
    rng = np.random.default_rng(0)
    w = rng.standard_normal((64, 48)).astype(np.float32)
    path = os.fspath(tmp_path / "prec.json")
    lin = PackSELLLinear.from_dense(w, density=0.4, codec="auto",
                                    error_budget=1e-3, store=path,
                                    C=8, sigma=32)
    assert lin.precision_plan is not None
    assert not lin.from_store
    d = lin.describe()
    assert d["auto_selected"] and d["codec"] == lin.mat.codec_name
    # restart: same weight hits the store
    lin2 = PackSELLLinear.from_dense(w, density=0.4, codec="auto",
                                     error_budget=1e-3, store=path,
                                     C=8, sigma=32)
    assert lin2.from_store
    assert lin2.mat.codec_name == lin.mat.codec_name
    assert lin2.mat.D == lin.mat.D
    # the layer still computes
    y = lin(jnp.asarray(rng.standard_normal(64).astype(np.float32)))
    assert y.shape == (48,)
    # caller-fixed codecs still get a fingerprint (warmup retile restore)
    fixed = PackSELLLinear.from_dense(w, density=0.4, codec="e8m", D=8,
                                      C=8, sigma=32)
    assert fixed.fingerprint is not None
    assert fixed.precision_plan is None and not fixed.describe()["auto_selected"]
