"""Property-based tests (hypothesis) for the block-composition engine:
ANY composite — random member count, codecs, delta widths, shard counts,
empty members/shards — must match the dense per-class-quantized oracle.
Single-device composites run their real jitted dispatch; distributed
composites use the host reference replay of the stacked operands (the
real shard_map dispatch is pinned to the replay in tests/test_composite.py
and tests/test_distributed.py). The dist_mixed ↔ single-device
``adaptive_pcg`` iteration-parity property is deterministic and mesh-gated:
``test_composite.py::test_adaptive_pcg_dist_matches_single_device``.
"""
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import codecs as cd
from repro.distributed import build_composite_operands, reference_spmv
from repro.kernels import composite as kc

CODEC_POOL = [("e8m", 4), ("e8m", 8), ("e8m", 12), ("fp16", 15),
              ("bf16", 15), ("fp32", 0)]


@st.composite
def composite_cases(draw, max_n=64):
    """(csr matrix, classes): random size/density and a random row
    partition into 1..4 classes — classes may own zero rows of some shard
    (or even be dropped entirely when they draw no rows)."""
    n = draw(st.integers(1, max_n))
    density = draw(st.floats(0.03, 0.4))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    a = sp.random(n, n, density=density, random_state=rng,
                  data_rvs=lambda k: rng.standard_normal(k)).tocsr()
    a.sort_indices()
    k = draw(st.integers(1, 4))
    assign = np.asarray(draw(st.lists(st.integers(0, k - 1), min_size=n,
                                      max_size=n)))
    classes = []
    for c in range(k):
        rows = np.nonzero(assign == c)[0]
        if len(rows) == 0:
            continue                      # empty member: dropped class
        codec, D = draw(st.sampled_from(CODEC_POOL))
        classes.append((codec, D, rows))
    return a, classes


def _oracle(a, classes):
    dense = a.toarray().astype(np.float64)
    out = np.zeros_like(dense)
    for codec, D, rows in classes:
        rows = np.asarray(rows)
        if codec == "fp32":
            out[rows] = dense[rows].astype(np.float32)
        else:
            out[rows] = cd.quantize_np(
                dense[rows].astype(np.float32), cd.make_codec(codec), D)
    return out


@given(composite_cases())
@settings(max_examples=25, deadline=None)
def test_composite_matches_dense_oracle(case):
    a, classes = case
    cp = kc.CompositePlan.from_classes(a, classes, C=4, sigma=8)
    x = np.random.default_rng(1).standard_normal(a.shape[0]) \
        .astype(np.float32)
    y = np.asarray(cp.spmv(jnp.asarray(x)), np.float64)
    want = _oracle(a, classes) @ x.astype(np.float64)
    np.testing.assert_allclose(y, want, rtol=0,
                               atol=3e-5 * max(np.abs(want).max(), 1.0))


@given(composite_cases(max_n=48), st.integers(1, 7))
@settings(max_examples=20, deadline=None)
def test_dist_composite_matches_dense_oracle(case, n_shards):
    """Distributed × mixed members over random shard counts — including
    empty shards (n < P) and shards holding zero rows of some class —
    replayed through the stacked operands."""
    a, classes = case
    ops = build_composite_operands(a, n_shards, classes=classes, C=4,
                                   sigma=8)
    x = np.random.default_rng(2).standard_normal(a.shape[0]) \
        .astype(np.float32)
    y = np.asarray(reference_spmv(ops, x), np.float64)
    want = _oracle(a, classes) @ x.astype(np.float64)
    np.testing.assert_allclose(y, want, rtol=0,
                               atol=3e-5 * max(np.abs(want).max(), 1.0))


@given(composite_cases(max_n=40), st.integers(2, 5))
@settings(max_examples=10, deadline=None)
def test_dist_composite_spmm_matches_spmv(case, n_shards):
    a, classes = case
    ops = build_composite_operands(a, n_shards, classes=classes, C=4,
                                   sigma=8)
    X = np.random.default_rng(3).standard_normal((a.shape[0], 2)) \
        .astype(np.float32)
    Y = reference_spmv(ops, X, multi_rhs=True)
    for j in range(2):
        np.testing.assert_allclose(Y[:, j], reference_spmv(ops, X[:, j]),
                                   rtol=1e-6, atol=1e-6)
