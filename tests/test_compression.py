"""Gradient-compression substrate: E8M truncation, error feedback, and the
integer-wire reduction codecs (§Perf C / paper §4.2.2 applied to DP)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim.compression import (_f32_to_u8, _f32_to_u16, _u8_to_f32,
                                     _u16_to_f32, compress, e8m_truncate)


class TestE8MTruncate:
    @given(st.floats(allow_nan=False, allow_infinity=False, width=32),
           st.integers(min_value=1, max_value=22))
    @settings(max_examples=200, deadline=None)
    def test_relative_error_bound(self, x, bits):
        if abs(x) < 1e-30 and x != 0.0:
            return   # subnormal rounding has no relative-error guarantee
        q = float(e8m_truncate(jnp.float32(x), bits))
        if x == 0.0:
            assert q == 0.0
            return
        assert abs(q - x) <= abs(x) * 2.0 ** (-bits) * (1 + 1e-6)

    @given(st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                     width=32),
           st.integers(min_value=1, max_value=22))
    @settings(max_examples=100, deadline=None)
    def test_idempotent(self, x, bits):
        q1 = e8m_truncate(jnp.float32(x), bits)
        q2 = e8m_truncate(q1, bits)
        assert float(q1) == float(q2)

    def test_error_feedback_is_exact(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal(256).astype(np.float32))
        e = jnp.zeros_like(g)
        q, e2 = compress(g, e, 8)
        np.testing.assert_allclose(np.asarray(q + e2), np.asarray(g),
                                   rtol=0, atol=0)   # g == q + err exactly

    def test_error_feedback_accumulates(self):
        """Sum of quantized+EF over steps tracks the true sum."""
        rng = np.random.default_rng(1)
        gs = rng.standard_normal((50, 64)).astype(np.float32) * 1e-3
        e = jnp.zeros((64,), jnp.float32)
        acc = jnp.zeros((64,), jnp.float32)
        for g in gs:
            q, e = compress(jnp.asarray(g), e, 4)
            acc = acc + q
        true = jnp.asarray(gs.sum(axis=0))
        # with EF, the residual is bounded by one quantization step
        resid = np.abs(np.asarray(acc + e - true)).max()
        assert resid < 1e-5


class TestWireCodecs:
    def test_u16_is_bf16_bits(self):
        x = jnp.asarray([1.0, -2.5, 3.14159, 1e-20, 65504.0], jnp.float32)
        u = _f32_to_u16(x)
        back = _u16_to_f32(u)
        want = x.astype(jnp.bfloat16).astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(back), np.asarray(want),
                                   rtol=1e-7)

    @given(st.lists(st.floats(min_value=-1e4, max_value=1e4,
                              allow_nan=False, width=32),
                    min_size=1, max_size=64))
    @settings(max_examples=50, deadline=None)
    def test_u16_roundtrip_error(self, xs):
        x = jnp.asarray(xs, jnp.float32)
        back = _u16_to_f32(_f32_to_u16(x))
        err = np.abs(np.asarray(back - x))
        bound = np.abs(np.asarray(x)) * 2.0 ** (-7) + 1e-30
        assert (err <= bound).all()

    def test_u8_roundtrip_scaled(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal(512).astype(np.float32))
        scale = jnp.max(jnp.abs(x)) / 448.0
        back = _u8_to_f32(_f32_to_u8(x, scale), scale)
        rel = np.abs(np.asarray(back - x)).max() / np.abs(np.asarray(x)).max()
        assert rel < 0.1     # e4m3: ~2 mantissa-bit relative accuracy


class TestCompressedWireReduce:
    """The multi-device collective path is exercised by the dryrun pod_wire
    cells (and was validated on 2 forced host devices); here we verify the
    reduction SEMANTICS against a numpy emulation of RS+AG: quantize each
    shard, exchange, sum in fp32, re-quantize, gather."""

    def test_semantics_match_numpy_emulation(self):
        rng = np.random.default_rng(3)
        n = 2
        g = rng.standard_normal((n, 515)).astype(np.float32)

        def emulate(g):
            bf = lambda x: np.asarray(
                jnp.asarray(x).astype(jnp.bfloat16).astype(jnp.float32))
            flat = g / n
            pad = -flat.shape[1] % n
            flat = np.pad(flat, ((0, 0), (0, pad)))
            chunks = flat.reshape(n, n, -1)     # [device, chunk, m]
            q = bf(chunks)
            parts = [q[:, j].sum(axis=0) for j in range(n)]   # per-owner sum
            out = np.concatenate([bf(p) for p in parts])
            return out[:flat.shape[1] - pad] if pad else out

        want = emulate(g)
        true = g.mean(axis=0)
        # emulated compressed mean within bf16 error of the true mean
        rel = np.abs(want - true).max() / np.abs(true).max()
        assert rel < 0.02
