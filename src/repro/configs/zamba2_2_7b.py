"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attn block.

Simplification (DESIGN.md §6): the shared transformer block (attention+MLP,
one parameter set) is applied every 6 Mamba2 layers; the reference model's
LoRA-specialized projections and concatenated residual stream are omitted.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, attn_every=6,
)
