"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf] — enc-dec; speech frontend is
a stub providing precomputed frame embeddings (assignment rule)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab=256206, head_dim=64,
    enc_layers=24, frontend="audio_stub",
)
