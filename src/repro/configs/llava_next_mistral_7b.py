"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

anyres tiling: the frontend stub supplies 2880 precomputed patch embeddings
(5 tiles x 576 patches) per the assignment; only the LM backbone is built.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000, head_dim=128,
    frontend="vision_stub", frontend_len=2880,
)
