"""Architecture registry: ``get(arch_id)`` and ``reduce()`` for smoke tests.

The 10 assigned architectures (exact public configs) plus the paper's own
solver scenario configs (``packsell_solver``).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeConfig  # noqa: F401

_MODULES = {
    "internlm2-20b": "internlm2_20b",
    "yi-6b": "yi_6b",
    "granite-3-2b": "granite_3_2b",
    "qwen2-0.5b": "qwen2_0_5b",
    "dbrx-132b": "dbrx_132b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-2.7b": "zamba2_2_7b",
    "mamba2-1.3b": "mamba2_1_3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCH_IDS = tuple(_MODULES)


def get(arch_id: str) -> ModelConfig:
    key = arch_id.replace("_", "-") if arch_id not in _MODULES else arch_id
    if key not in _MODULES:
        # allow module-style ids too
        key = arch_id.replace("_", "-").replace("-0-5b", "-0.5b") \
            .replace("-a2-7b", "-a2.7b").replace("-2-7b", "-2.7b") \
            .replace("-1-3b", "-1.3b")
    mod = importlib.import_module(f"repro.configs.{_MODULES[key]}")
    return mod.CONFIG


def reduce(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (assignment rule: small
    layers/width, few experts, tiny vocab)."""
    r = dict(
        n_layers=4 if cfg.family == "hybrid" else 2,
        d_model=128,
        d_ff=0 if cfg.family == "ssm" else 256,
        vocab=512,
        dtype="float32",
        param_dtype="float32",
    )
    if cfg.n_heads:
        r.update(n_heads=4, n_kv_heads=2, head_dim=32)
    if cfg.family == "moe":
        r.update(n_experts=8, top_k=2,
                 n_shared_experts=min(cfg.n_shared_experts, 2), d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        r.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        r.update(attn_every=2)
    if cfg.enc_layers:
        r.update(enc_layers=2)
    if cfg.frontend:
        r.update(frontend_len=8)
    return dataclasses.replace(cfg, **r)
