"""Seeded, deterministic fault injectors for every execution path.

Each injector corrupts ONE operand of a live plan (copy-modify-replace —
jax arrays are immutable), returns an :class:`Injection` describing what
changed and whether the corruption is provably **value-neutral** (y is
bit-identical for every finite x — e.g. a bit flip inside a padding word,
or a delta-field flip under the 'full' cursor cache, whose columns were
decoded at build time). The neutrality oracle is exact: it compares the
corrupted operand's per-row coefficient vectors against the originals
under the same clip semantics the runtime gather uses.

Injectors never invalidate a plan's jitted dispatch functions — operands
flow through the dispatch as jit *arguments*, which is precisely why a
corrupted buffer reaches the kernel (and why the guard must checksum the
buffers, not trust the trace). Only the plan's cached operand dict
(``_fns['_dev']``) is refreshed so the next call ships the corrupted
arrays.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs as cd
from repro.core import packsell as pk

from .guard import _decode_stream_np


@dataclasses.dataclass
class Injection:
    """One injected fault: what was corrupted, where, and whether it can
    change any SpMV result (``value_neutral=False`` ⇒ some finite x sees a
    different y). ``undo()`` restores the original operand."""

    target: str                       # 'fused_word' | 'ckpt' | 'perm' | ...
    detail: dict
    value_neutral: bool
    _undo: Optional[Callable[[], None]] = None
    undone: bool = False

    def undo(self) -> None:
        if not self.undone and self._undo is not None:
            self._undo()
        self.undone = True


def _refresh(plan) -> None:
    plan._fns.pop("_dev", None)


def _decode_word(word: np.uint32, mat, layout):
    """(value float64, run-local offset int) of one fused-stream word."""
    v, local = _decode_stream_np(
        np.asarray(word, np.uint32).reshape(1, 1, 1), mat, layout)
    return float(v[0, 0, 0]), int(local[0, 0, 0])


def _lane_coeff_fused(words, ck_val: int, mat, layout, m: int):
    """Coefficient vector of one fused group lane: coeff[col] = Σ v over
    the lane's words (runtime clip semantics). Equal coefficient vectors
    ⇔ identical y for every finite x."""
    w3 = np.asarray(words, np.uint32).reshape(1, -1, 1)
    v, local = _decode_stream_np(w3, mat, layout)
    cols = np.clip(ck_val + local[0, :, 0], 0, max(m - 1, 0))
    coeff = np.zeros(max(m, 1), np.float64)
    contrib = v[0, :, 0] != 0
    np.add.at(coeff, cols[contrib], v[0, :, 0][contrib])
    return coeff


def _lane_coeff_pack(words, d0_val: int, codec, D, m: int,
                     cols_override=None):
    """Coefficient vector of one bucketed-pack lane (scan/checkpoint
    decode: columns re-derived from the deltas; 'full' cursor cache:
    ``cols_override`` pins the build-time columns)."""
    v, d, flag = cd.unpack_words_np(np.asarray(words, np.uint32), codec, D)
    if cols_override is None:
        cols = d0_val + np.cumsum(d.astype(np.int64))
    else:
        cols = cols_override
    cols = np.clip(cols, 0, max(m - 1, 0))
    coeff = np.zeros(max(m, 1), np.float64)
    f1 = flag == 1
    np.add.at(coeff, cols[f1], v[f1].astype(np.float64))
    return coeff, cols


def _coeff_equal(a: np.ndarray, b: np.ndarray) -> bool:
    # array_equal is False on NaN: a corruption that decodes NaN is
    # value-affecting by definition
    return bool(np.array_equal(a, b))


# ---------------------------------------------------------------------------
# SpMVPlan operand injectors
# ---------------------------------------------------------------------------


def flip_fused_word(mat, plan, seed: int, *, bit: int | None = None,
                    pos: tuple | None = None) -> Injection:
    """Flip one bit of one word of the fused ragged stream. Fused columns
    are checkpoint-absolute (no carry across words), so exactly one
    (value, column) pair changes — the neutrality oracle compares just
    that pair."""
    if plan.fused is None:
        raise ValueError("plan has no fused stream to corrupt")
    rng = np.random.default_rng(seed)
    orig = plan.fused
    w_np = np.asarray(orig[0]).copy()
    G, wr, C = w_np.shape
    if w_np.size == 0:
        raise ValueError("fused stream is empty")
    g, j, c = (pos if pos is not None else
               (int(rng.integers(G)), int(rng.integers(wr)),
                int(rng.integers(C))))
    b = int(rng.integers(32)) if bit is None else int(bit)
    old = np.uint32(w_np[g, j, c])
    new = np.uint32(old ^ np.uint32(1 << b))
    w_np[g, j, c] = new
    ck_val = int(np.asarray(orig[1])[g, c])
    layout = plan.fused_layout
    vo, lo = _decode_word(old, mat, layout)
    vn, ln = _decode_word(new, mat, layout)
    mlim = max(plan.m - 1, 0)
    neutral = bool(
        (vo == 0.0 and vn == 0.0)
        or (vo == vn and np.isfinite(vn)
            and min(max(ck_val + lo, 0), mlim)
            == min(max(ck_val + ln, 0), mlim)))

    plan.fused = (jnp.asarray(w_np), orig[1])
    _refresh(plan)

    def undo():
        plan.fused = orig
        _refresh(plan)

    return Injection("fused_word",
                     dict(pos=(g, j, c), bit=b, old=int(old), new=int(new),
                          v_old=vo, v_new=vn, seed=seed),
                     neutral, undo)


def corrupt_fused_checkpoint(mat, plan, seed: int) -> Injection:
    """Shift one cursor checkpoint by a random nonzero offset — every word
    of that group lane then gathers from the wrong columns. Neutral only
    when the lane carries no contributing word (all padding) or the clip
    happens to map every contributing column identically."""
    if plan.fused is None:
        raise ValueError("plan has no fused checkpoints to corrupt")
    rng = np.random.default_rng(seed)
    orig = plan.fused
    ck_np = np.asarray(orig[1]).copy()
    G, C = ck_np.shape
    if ck_np.size == 0:
        raise ValueError("fused checkpoint array is empty")
    g, c = int(rng.integers(G)), int(rng.integers(C))
    delta = int(rng.integers(1, max(plan.m, 2))) * (1 if rng.random() < 0.5
                                                    else -1)
    old = int(ck_np[g, c])
    ck_np[g, c] = np.int32(old + delta)
    lane = np.asarray(orig[0])[g, :, c]
    co = _lane_coeff_fused(lane, old, mat, plan.fused_layout, plan.m)
    cn = _lane_coeff_fused(lane, old + delta, mat, plan.fused_layout,
                           plan.m)
    plan.fused = (orig[0], jnp.asarray(ck_np))
    _refresh(plan)

    def undo():
        plan.fused = orig
        _refresh(plan)

    return Injection("ckpt", dict(pos=(g, c), old=old, delta=delta,
                                  seed=seed),
                     _coeff_equal(co, cn), undo)


def flip_pack_word(mat, plan, seed: int, *, bit: int | None = None) -> \
        Injection:
    """Flip one bit of one bucketed pack word (the non-fused execution
    paths: 'full' cursor cache, scan decode, Pallas buckets). Under the
    'full' cache the columns were decoded at build time, so delta-field
    corruption is value-neutral — only payload/flag changes reach y; the
    oracle accounts for the plan's cache mode."""
    rng = np.random.default_rng(seed)
    sizes = [int(np.prod(p.shape)) for p in mat.packs]
    if not sizes or sum(sizes) == 0:
        raise ValueError("matrix has no packed words")
    bkt = int(rng.choice(len(sizes), p=np.asarray(sizes, np.float64)
                         / sum(sizes)))
    words = np.asarray(mat.packs[bkt]).copy()
    S, w, C = words.shape
    s, j, c = (int(rng.integers(S)), int(rng.integers(w)),
               int(rng.integers(C)))
    b = int(rng.integers(32)) if bit is None else int(bit)
    old_lane = words[s, :, c].copy()
    words[s, j, c] = np.uint32(words[s, j, c] ^ np.uint32(1 << b))
    new_lane = words[s, :, c]
    d0_val = int(np.asarray(mat.d0s[bkt])[s])
    full_cache = plan.cache_mode == "full" and plan.cols is not None
    co, cols_old = _lane_coeff_pack(old_lane, d0_val, mat.codec, mat.D,
                                    mat.m)
    cn, _ = _lane_coeff_pack(new_lane, d0_val, mat.codec, mat.D, mat.m,
                             cols_override=cols_old if full_cache else None)
    orig_packs = mat.packs
    packs = list(mat.packs)
    packs[bkt] = jnp.asarray(words) if not isinstance(
        orig_packs[bkt], np.ndarray) else words
    mat.packs = tuple(packs)
    _refresh(plan)

    def undo():
        mat.packs = orig_packs
        _refresh(plan)

    return Injection("pack_word",
                     dict(bucket=bkt, pos=(s, j, c), bit=b, seed=seed,
                          cache_mode=plan.cache_mode),
                     _coeff_equal(co, cn), undo)


def corrupt_permutation(mat, plan, seed: int) -> Injection:
    """Swap two rows of the inverse σ-permutation — y's entries for those
    rows trade places. Sum-invariant, so the analytic ABFT identity alone
    cannot see it; the operand checksum catches it exactly. Neutral only
    when the two matrix rows are identical (then the swap is a no-op on
    y)."""
    if plan.n < 2:
        raise ValueError("need n >= 2 to swap permutation rows")
    rng = np.random.default_rng(seed)
    r1, r2 = rng.choice(plan.n, size=2, replace=False)
    r1, r2 = int(r1), int(r2)
    orig_inv, orig_inv2 = plan.inv_cat, plan.inv2_cat
    if orig_inv is None and orig_inv2 is None:
        raise ValueError("plan carries no inverse permutation")
    if orig_inv is not None:
        inv = np.asarray(orig_inv).copy()
        inv[[r1, r2]] = inv[[r2, r1]]
        plan.inv_cat = jnp.asarray(inv)
    if orig_inv2 is not None:
        inv2 = np.asarray(orig_inv2).copy()
        inv2[[r1, r2]] = inv2[[r2, r1]]
        plan.inv2_cat = jnp.asarray(inv2)
    _refresh(plan)
    dense = pk.decode_to_dense(mat)
    neutral = bool(np.array_equal(dense[r1], dense[r2]))

    def undo():
        plan.inv_cat = orig_inv
        plan.inv2_cat = orig_inv2
        _refresh(plan)

    return Injection("perm", dict(rows=(r1, r2), seed=seed), neutral, undo)


# ---------------------------------------------------------------------------
# Input / halo poisoning
# ---------------------------------------------------------------------------


def poison_x(x, seed: int, mode: str = "nan"):
    """Poison one entry of an input (or halo-travelling) vector with
    NaN/Inf. Returns ``(x_poisoned, Injection)`` — the original is not
    modified, so no undo is needed."""
    if mode not in ("nan", "inf"):
        raise ValueError(f"mode={mode!r} not in ('nan', 'inf')")
    rng = np.random.default_rng(seed)
    xp = np.asarray(x, np.float64).copy()
    if xp.size == 0:
        raise ValueError("cannot poison an empty vector")
    i = int(rng.integers(xp.size))
    xp.reshape(-1)[i] = np.nan if mode == "nan" else np.inf
    return xp, Injection("x", dict(index=i, mode=mode, seed=seed), False)


# ---------------------------------------------------------------------------
# Precision-store corruption (satellite: store must survive this)
# ---------------------------------------------------------------------------


def corrupt_store(path: str, seed: int, mode: str = "truncate") -> \
        Injection:
    """Truncate or garble the on-disk precision-store JSON (simulating a
    crashed writer / bad sector). Undo restores the original bytes."""
    if mode not in ("truncate", "garble"):
        raise ValueError(f"mode={mode!r} not in ('truncate', 'garble')")
    rng = np.random.default_rng(seed)
    with open(path, "rb") as f:
        orig = f.read()
    if mode == "truncate":
        cut = int(rng.integers(1, max(len(orig), 2)))
        bad = orig[:cut]
    else:
        bad = bytearray(orig if orig else b"{")
        for _ in range(max(1, len(bad) // 16)):
            bad[int(rng.integers(len(bad)))] = int(rng.integers(256))
        bad = bytes(bad)
    with open(path, "wb") as f:
        f.write(bad)

    def undo():
        with open(path, "wb") as f:
            f.write(orig)

    return Injection("store", dict(path=os.fspath(path), mode=mode,
                                   nbytes=len(bad), seed=seed),
                     False, undo)


# ---------------------------------------------------------------------------
# Distributed / composite operand injectors
# ---------------------------------------------------------------------------


def corrupt_dist_checkpoint(dplan, seed: int) -> Injection:
    """Shift one cursor checkpoint inside a DistSpMVPlan's stacked device
    operands (a ``*_fckpt`` leaf). The solvers pass ``dplan.dev`` into the
    shard_map dispatch per call, so the corrupted leaf reaches the kernels
    without any re-bind."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    keys = sorted(k for k in dplan.dev if k.endswith("_fckpt"))
    if not keys:
        raise ValueError("dist plan has no fused checkpoint operands")
    rng = np.random.default_rng(seed)
    key = keys[int(rng.integers(len(keys)))]
    orig = dplan.dev[key]
    arr = np.asarray(orig).copy()
    i = int(rng.integers(arr.size))
    delta = int(rng.integers(1, max(int(dplan.m) if hasattr(dplan, "m")
                                    else 2 ** 15, 2)))
    flat = arr.reshape(-1)
    old = int(flat[i])
    flat[i] = np.int32(old + delta)
    shard = NamedSharding(dplan.mesh, P(dplan.axis_name))
    dplan.dev[key] = jax.device_put(arr, shard)

    def undo():
        dplan.dev[key] = orig

    return Injection("dist_ckpt", dict(key=key, index=i, old=old,
                                       delta=delta, seed=seed),
                     False, undo)


def corrupt_composite_word(comp, member: int, seed: int) -> Injection:
    """Flip a word inside one member block of a CompositePlan and
    invalidate the composite's concatenated stream copy so the corruption
    reaches the composite dispatch."""
    mem = comp.members[member]
    if mem.plan is None:
        raise ValueError(f"member {member} ({mem.label}) is not a "
                         f"PackSELL block")

    def _invalidate():
        comp._cat = None
        comp._cat_built = False

    if mem.plan.fused is not None:
        inj = flip_fused_word(mem.mat, mem.plan, seed)
    else:
        inj = flip_pack_word(mem.mat, mem.plan, seed)
    _invalidate()
    inner_undo = inj._undo

    def undo():
        if inner_undo is not None:
            inner_undo()
        _invalidate()

    inj._undo = undo
    inj.detail["member"] = member
    inj.target = "composite_" + inj.target
    return inj
