"""Guarded execution: fault injection, ABFT checksum guards, recovery.

The paper's pitch — aggressive sub-32-bit packing on the hot path of
long-running PCG solves — is exactly the regime where a flipped bit in a
packed word stream, a NaN-poisoned input, or a corrupted autotune store
silently destroys a solve. This subsystem makes the other five survive
faults (DESIGN.md §11):

* :mod:`repro.robust.inject` — seeded, deterministic fault injectors for
  every execution path (plan / composite / distributed operands, input
  vectors, the precision store file);
* :mod:`repro.robust.guard` — structural ``validate()`` passes plus the
  ABFT checksum guard (``c = eᵀA`` at build, ``c·x`` vs ``sum(y)`` in
  fp64 + an exact mod-2³² stream checksum inside the jitted dispatch);
* :mod:`repro.robust.recover` — ``guarded_solve``: PCG/refinement with
  per-step guard checks and a bounded escalation policy (retry → promote
  precision tier → rebuild from the retained CSR → fp32 reference),
  recording a machine-readable recovery log.
"""
from .guard import (GuardState, IntegrityError, build_guard, checksum,
                    guarded_spmv, is_healthy, mark_unhealthy, plan_health,
                    validate_composite, validate_matrix, validate_plan)
from .inject import (Injection, corrupt_composite_word,
                     corrupt_dist_checkpoint, corrupt_fused_checkpoint,
                     corrupt_permutation, corrupt_store, flip_fused_word,
                     flip_pack_word, poison_x)
from .recover import GuardedSolveInfo, guarded_solve

__all__ = [
    "GuardState", "IntegrityError", "build_guard", "checksum",
    "guarded_spmv", "is_healthy", "mark_unhealthy", "plan_health",
    "validate_composite", "validate_matrix", "validate_plan",
    "Injection", "corrupt_composite_word", "corrupt_dist_checkpoint",
    "corrupt_fused_checkpoint", "corrupt_permutation", "corrupt_store",
    "flip_fused_word", "flip_pack_word", "poison_x",
    "GuardedSolveInfo", "guarded_solve",
]
