"""Self-healing solves: guarded PCG with a bounded escalation policy.

``guarded_solve`` is a host-driven iterative-refinement outer loop whose
inner correction solves run on a packed (guarded) operator. After every
outer step it checks three things: the ABFT checksum guard on the plan's
operands (:func:`~repro.robust.guard.guarded_spmv`), finiteness of the
fp64 *true* residual (computed against the retained CSR on the host —
never through the operator under suspicion), and divergence. On
detection it escalates through a bounded policy (DESIGN.md §11.3):

1. **retry**   — revert x to the last accepted iterate, re-run the step
   (heals transient faults);
2. **promote** — step up the PR-3 precision ladder
   (``precision.select.tier_ladder``): the next tier's operand is built
   fresh from the retained CSR, so promotion both heals persistent
   operand corruption and buys accuracy;
3. **rebuild** — rebuild the CURRENT kind's operand from the retained
   CSR (the ladder is exhausted but the codec was fine);
4. **fp32**    — fall back to the uncompressed fp32 reference operator
   (terminal: no packed operand left to corrupt).

Each escalation appends a machine-readable record to the recovery log,
and every tripped plan is marked unhealthy
(:func:`~repro.robust.guard.mark_unhealthy`) so the serving engine
rebuilds it before reuse.
"""
from __future__ import annotations

import types
from typing import Callable, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.observe import metrics as _obs
from repro.solvers import cg
from repro.solvers import operators as op

from . import guard as gd


class GuardedSolveInfo(NamedTuple):
    """Outcome of :func:`guarded_solve` (host values)."""

    iters: int              # accepted outer steps
    relres: float           # final TRUE relative residual ||b - Ax|| / ||b||
    history: np.ndarray     # true relres per accepted step
    log: list               # recovery log: [{step, event, action, detail}]
    final_kind: str         # operator kind that finished the solve
    trips: int              # total guard detections


def promotion_ladder(kind: str) -> list:
    """Operator kinds from ``kind`` up the PR-3 precision ladder
    (``tier_ladder`` over the kind's codec), ending at ``'fp32'``."""
    from repro.precision import select as psel

    spec = op.parse_kind(kind)
    if spec.family != "plan":
        raise ValueError(
            f"guarded_solve needs a plan_<codec> kind, got {kind!r}")
    shim = types.SimpleNamespace(
        primary=psel.PrecisionClass(spec.codec, spec.D))
    return [kind if c.codec == spec.codec and c.D == spec.D
            else psel.operator_kind(c)
            for c in psel.tier_ladder(shim)]


def _correction(matvec, r, dinv, m_in: int):
    """m_in fixed PCG iterations on A d = r from d0 = 0 (Jacobi)."""
    d, _ = cg.pcg(matvec, jnp.asarray(r), M=lambda rr: rr * dinv,
                  tol=0.0, maxiter=m_in, dtype=jnp.float64)
    return np.asarray(d, np.float64)


def guarded_solve(ops: op.OperatorSet, kind: str, b, *,
                  tol: float = 1e-9, maxiter: int = 60, m_in: int = 16,
                  on_step: Optional[Callable[[int, dict], None]] = None
                  ) -> tuple[np.ndarray, GuardedSolveInfo]:
    """Solve ``A x = b`` to the TRUE relative residual ``tol`` on a
    guarded packed operator, surviving operand corruption and poisoned
    inputs via the bounded escalation policy above.

    ``ops`` retains the source CSR — the rebuild escalations and the
    host-side true-residual checks both read it. ``kind`` is a
    ``plan_<codec>`` kind (a leading ``'guarded:'`` prefix is accepted
    and stripped — guarding is implied here). ``on_step(step, ctx)`` runs
    before each outer step with ``ctx = {mat, plan, guard, x, kind}`` —
    the fault-injection hook the robustness tests and benchmarks use.
    """
    if kind.startswith("guarded:"):
        kind = kind[len("guarded:"):]
    ladder = promotion_ladder(kind)

    a64 = ops.csr.tocsr().astype(np.float64)
    b = np.asarray(b, np.float64)
    bnorm = float(np.linalg.norm(b))
    bnorm = bnorm if bnorm > 0 else 1.0
    diag = np.asarray(ops.diag(), np.float64)
    dinv = jnp.asarray(np.where(diag == 0, 1.0, 1.0 / diag))

    def _bind(k: str):
        """(matvec, mat, plan, guard) for a ladder kind ('fp32': no
        guard — the reference operator has no packed operands)."""
        if k == "fp32":
            return ops.matvec("fp32"), None, None, None
        mat, plan = ops.plan_pair(k)
        fn = lambda v: plan.spmv(mat, v)
        return fn, mat, plan, gd.build_guard(mat, plan)

    tier = 0
    cur = ladder[tier]
    matvec, mat, plan, gs = _bind(cur)

    x = np.zeros(a64.shape[0], np.float64)
    r = b - a64 @ x
    relres = float(np.linalg.norm(r)) / bnorm
    hist = [relres]
    log: list = []
    trips = 0
    attempts = 0          # consecutive detections (escalation state)
    rebuilt = False
    steps = 0

    for outer in range(maxiter):
        if relres < tol:
            break
        # snapshot the accepted iterate: a fault that poisons the live x
        # (ctx['x'] is the real array) must not destroy the revert target
        x_snap = x.copy()
        if on_step is not None:
            on_step(outer, dict(mat=mat, plan=plan, guard=gs, x=x,
                                kind=cur))

        d = _correction(matvec, r, dinv, m_in)
        x_new = x + d
        r_new = b - a64 @ x_new
        rel_new = float(np.linalg.norm(r_new)) / bnorm

        # -- detection --------------------------------------------------
        event = None
        if gs is not None:
            _, ok, rel_err = gd.guarded_spmv(mat, plan, gs, jnp.asarray(d))
            if not bool(ok):
                event = ("guard_trip",
                         dict(rel_err=float(np.asarray(rel_err))))
        if event is None and not np.all(np.isfinite(r_new)):
            event = ("nonfinite_residual", {})
        if event is None and np.isfinite(rel_new) \
                and rel_new > 10.0 * max(relres, tol):
            event = ("divergence", dict(relres=rel_new))

        if event is None:
            x, r, relres = x_new, r_new, rel_new
            hist.append(relres)
            steps += 1
            attempts = 0
            continue

        # -- escalation -------------------------------------------------
        trips += 1
        attempts += 1
        _obs.observe("guard.detection_latency_calls",
                     gs.last_check_latency if gs is not None else 1,
                     event=event[0])
        x = x_snap                          # revert to the last good iterate
        r = b - a64 @ x
        relres = float(np.linalg.norm(r)) / bnorm
        if plan is not None:
            gd.mark_unhealthy(plan, event[0])
        if attempts == 1:
            action, detail = "retry", dict(kind=cur)
        elif tier + 1 < len(ladder) - 1:
            tier += 1
            cur = ladder[tier]
            matvec, mat, plan, gs = _bind(cur)
            action, detail = "promote", dict(kind=cur)
        elif not rebuilt and cur != "fp32":
            rebuilt = True
            ops._cache.pop(cur, None)       # force a fresh from_csr build
            matvec, mat, plan, gs = _bind(cur)
            action, detail = "rebuild", dict(kind=cur)
        else:
            tier = len(ladder) - 1
            cur = ladder[tier]              # 'fp32'
            matvec, mat, plan, gs = _bind(cur)
            action, detail = "fp32_fallback", dict(kind=cur)
        log.append(dict(step=outer, event=event[0], action=action,
                        detail={**event[1], **detail}))
        _obs.inc("guard.trip", event=event[0], action=action)

    if _obs.enabled():
        _obs.inc("guard.solves", kind=cur)
        _obs.record_trace(
            "guard.solve",
            dict(iters=steps, relres=relres, trips=trips, final_kind=cur,
                 log=[dict(step=e["step"], event=e["event"],
                           action=e["action"]) for e in log]),
            kind=kind)
    return x, GuardedSolveInfo(steps, relres, np.asarray(hist), log, cur,
                               trips)
