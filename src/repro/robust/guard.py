"""Integrity validation + the ABFT checksum guard (DESIGN.md §11).

Two complementary detection layers, priced separately:

* **Structural validation** (:func:`validate_matrix`, :func:`validate_plan`,
  :func:`validate_composite`) — host-side numpy passes over the packed /
  fused operands: checkpoint monotonicity and range, fused-stream length
  accounting, delta-field (column) range, permutation bijectivity. Run at
  build time (the cheap subset, see ``kernels.plan._quick_validate``) and
  on demand after suspicion.

* **The ABFT guard** (:func:`build_guard` + :func:`guarded_spmv`) — the
  classic algorithm-based-fault-tolerance checksum-vector construction:
  precompute ``c = eᵀA`` (fp64 column sums of the *decoded* operator) once
  at build, then every guarded matvec verifies ``c·x ≈ sum(y)`` in fp64
  inside the SAME jitted dispatch — one extra dot per matvec — with a
  codec-aware tolerance derived from the PR-3 error model
  (``precision.analyze.ulp_bound``). The analytic identity is blind to
  corruptions below the fp32 rounding floor (a low-order mantissa flip in
  one packed fp16 payload moves ``sum(y)`` by ~2⁻¹¹·|a·x_j|, far under any
  honest tolerance over thousands of nonzeros), so the same dispatch also
  recomputes an exact mod-2³² word checksum over every operand array the
  execution reads: a single flipped bit changes the sum by ±2^b ≠ 0
  (mod 2³²), so single-word operand corruption is detected exactly, at the
  cost of one integer pass over the stream.

What each layer catches: the checksum — any operand corruption (words,
checkpoints, cursor caches, permutations), including value-neutral ones;
the analytic identity — NaN/Inf poison in ``x`` or the operands, and any
corruption introduced *before* the guard was built when the reference
column sums come from the original CSR (``build_guard(..., csr=...)``).
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs as cd
from repro.core.packsell import PackSELLMatrix
from repro.observe import metrics as _obs


class IntegrityError(ValueError):
    """An operand failed structural validation or a guard check."""


# ---------------------------------------------------------------------------
# Plan health (serving integration: tripped plans are rebuilt before reuse)
# ---------------------------------------------------------------------------


def mark_unhealthy(plan, reason: str) -> None:
    """Flag a plan as tripped; the serving engine rebuilds flagged plans
    before reuse (``serving.engine.DecodeEngine.warmup``)."""
    plan._unhealthy = str(reason)
    _obs.inc("guard.plan_unhealthy", reason=str(reason))


def plan_health(plan) -> str | None:
    """The trip reason, or None for a healthy plan."""
    return getattr(plan, "_unhealthy", None)


def is_healthy(plan) -> bool:
    return plan_health(plan) is None


# ---------------------------------------------------------------------------
# Exact mod-2^32 operand checksums
# ---------------------------------------------------------------------------


def _as_u32_np(a: np.ndarray) -> np.ndarray:
    a = np.ascontiguousarray(a)
    if a.dtype == np.uint32:
        return a
    if a.dtype.itemsize in (4, 8):
        return a.view(np.uint32)    # 64-bit: both halves, matching bitcast
    return a.astype(np.uint32)


def checksum(arrays) -> np.uint64:
    """Host reference checksum over every 32-bit word in ``arrays``: the
    mod-2³² word sum (any single-bit flip changes it by ±2^b ≠ 0 — exact
    single-word detection) packed with a position-weighted sum
    ``Σ (i+1)·wᵢ mod 2³²`` (a plain sum is blind to *transpositions* —
    e.g. a swapped permutation pair — the weighted sum is not, unless
    ``(wᵢ−wⱼ)·(j−i) ≡ 0 mod 2³²``, which a random swap essentially never
    hits). Matches the device-side :func:`_checksum_jnp` bit for bit."""
    s0 = 0
    s1 = 0
    for a in arrays:
        a = np.asarray(a)
        if not a.size:
            continue
        u = _as_u32_np(a).ravel()
        s0 = (s0 + int(u.sum(dtype=np.uint32))) & 0xFFFFFFFF
        w = np.arange(1, u.size + 1, dtype=np.uint32)
        s1 = (s1 + int((u * w).sum(dtype=np.uint32))) & 0xFFFFFFFF
    return np.uint64((s0 << 32) | s1)


def _checksum_ref_pair(ref: np.uint64):
    ref = np.uint64(ref)
    return (np.uint32(ref >> np.uint64(32)),
            np.uint32(ref & np.uint64(0xFFFFFFFF)))


def _checksum_jnp(arrays):
    """Device-side (plain, weighted) mod-2³² word checksums (same values
    as the two halves of :func:`checksum`)."""
    s0 = jnp.uint32(0)
    s1 = jnp.uint32(0)
    for a in arrays:
        if a is None or a.size == 0:
            continue
        if a.dtype != jnp.uint32:
            a = jax.lax.bitcast_convert_type(a, jnp.uint32)
        u = a.ravel()
        s0 = s0 + jnp.sum(u, dtype=jnp.uint32)
        w = jnp.arange(1, u.size + 1, dtype=jnp.uint32)
        s1 = s1 + jnp.sum(u * w, dtype=jnp.uint32)
    return s0, s1


def guard_arrays(mat: PackSELLMatrix, plan) -> list:
    """Every operand array the plan's execution path actually reads — the
    checksum coverage set (and the injection surface of
    ``robust.inject``). Fused 'jnp' plans stream the repacked words, not
    the bucketed packs, so only the former is covered."""
    dev = plan._device_operands()
    arrs = []
    if dev.get("fused") is not None and plan.variant == "jnp":
        arrs += [dev["fused"][0], dev["fused"][1]]
    else:
        arrs += list(mat.packs) + list(mat.d0s)
        if dev.get("cols") is not None:
            arrs += list(dev["cols"])
        if dev.get("kckpt") is not None:
            arrs += list(dev["kckpt"])
    if dev.get("inv2") is not None:
        arrs.append(dev["inv2"])
    elif dev.get("inv") is not None:
        arrs.append(dev["inv"])
    arrs.append(dev["outrow"])
    return arrs


# ---------------------------------------------------------------------------
# ABFT column sums (host, fp64)
# ---------------------------------------------------------------------------


def matrix_colsums(mat: PackSELLMatrix):
    """``(c, cabs)``: fp64 column sums of the decoded (quantized) operator
    and of its magnitudes — the ABFT checksum vectors. Decoding the packed
    words (not the source CSR) makes ``c·x = eᵀ(Ax)`` exact up to fp32
    matvec rounding: quantization cancels out of the identity."""
    c = np.zeros(mat.m, np.float64)
    cabs = np.zeros(mat.m, np.float64)
    codec = mat.codec
    for pack, d0, outrow in zip(mat.packs, mat.d0s, mat.outrows):
        words = np.asarray(pack)
        S, w, C = words.shape
        if words.size == 0:
            continue
        v, d, flag = cd.unpack_words_np(words.reshape(-1), codec, mat.D)
        v = v.astype(np.float64).reshape(S, w, C)
        cols = np.asarray(d0)[:, None, None].astype(np.int64) + \
            np.cumsum(d.astype(np.int64).reshape(S, w, C), axis=1)
        rows_ok = (np.asarray(outrow).reshape(S, C) < mat.n)[:, None, :]
        valid = (flag.reshape(S, w, C) == 1) & rows_ok
        cc = np.clip(cols[valid], 0, max(mat.m - 1, 0))
        np.add.at(c, cc, v[valid])
        np.add.at(cabs, cc, np.abs(v[valid]))
    return c, cabs


def _max_row_words(mat: PackSELLMatrix) -> int:
    return max((int(p.shape[1]) for p in mat.packs), default=1)


@dataclasses.dataclass
class GuardState:
    """Per-plan ABFT guard operands, built once (:func:`build_guard`).

    ``tau_rel`` scales the fp32 rounding-noise bound: the guarded matvec
    accepts ``|sum(y) - c·x| <= tau_rel·(cabs·|x| + |c·x|) + tau_quant·
    cabs·|x|``. ``tau_quant`` is nonzero only when the column sums come
    from the original CSR (``source='csr'``): the decoded operator then
    differs from the reference by codec quantization, bounded per entry by
    the PR-3 error model's ``ulp_bound(codec, D)``.

    ``every`` amortizes the full guard: with ``every=K > 1``, only every
    K-th :func:`guarded_spmv` call runs the ABFT identity + exact operand
    checksum; the other K-1 calls run a single-reduction finiteness check
    on ``y`` (which still catches NaN/Inf poisoning immediately). Exact
    reductions on this backend cost ~0.2 ns/word — comparable to the SpMV
    itself on very sparse matrices — so the full guard cannot be free per
    call; striding bounds silent-corruption detection latency at K
    matvecs while keeping steady-state overhead ~(full_cost/K).
    """

    c: jnp.ndarray            # fp64 [m] colsums
    cabs: jnp.ndarray         # fp64 [m] magnitude colsums
    ref_checksum: np.uint64   # packed (plain, weighted) operand checksum
    tau_rel: float
    tau_quant: float
    source: str               # 'decoded' | 'csr'
    every: int = 1            # full-guard stride (1 = every call)
    calls: int = 0            # guarded_spmv call counter (host-side)
    calls_since_full: int = 0  # light checks since the last full guard
    last_check_latency: int = 1  # detection-latency (calls) of the most
    #                              recent check: guarded calls since the
    #                              last full guard, inclusive — the window
    #                              a silent corruption could have survived
    _dev: dict | None = dataclasses.field(default=None, repr=False)

    def dev(self) -> dict:
        """The jit-argument form (so one compiled guard serves rebuilt
        guard states). Cached — rebuilding these small device arrays per
        call would dominate the guard's cost on small matrices."""
        if self._dev is None:
            self._dev = {
                "c": self.c, "cabs": self.cabs,
                "ref": jnp.asarray(_checksum_ref_pair(self.ref_checksum),
                                   jnp.uint32),
                "tau": jnp.asarray([self.tau_rel, self.tau_quant],
                                   jnp.float64)}
        return self._dev

    def refresh_checksum(self, mat: PackSELLMatrix, plan) -> None:
        """Re-baseline the operand checksum (after a legitimate operand
        change, e.g. ``plan.retile``)."""
        self.ref_checksum = checksum(guard_arrays(mat, plan))
        self._dev = None


#: guard-tolerance safety factor over the worst-case fp32 rounding model
#: (per-term rounding ~eps32, row depth w, plus gather/fma reassociation
#: slack). Loose enough that clean solves never trip; the exact checksum,
#: not this tolerance, carries the single-bit detection guarantee.
_TAU_SAFETY = 32.0


def build_guard(mat: PackSELLMatrix, plan, *, csr=None,
                safety: float = _TAU_SAFETY,
                every: int | None = None) -> GuardState:
    """Precompute the ABFT guard for ``(mat, plan)``: fp64 column sums
    (``c = eᵀA``), the exact operand word checksum, and the tolerance
    constants. ``csr`` (the original scipy matrix) switches the reference
    column sums to the *source* data — the guard then also certifies the
    packing itself, at the price of a codec-aware quantization term
    (``precision.analyze.ulp_bound``) in the tolerance. ``every`` is the
    full-guard stride (default: env ``REPRO_GUARD_EVERY``, else 1 —
    fully guarded)."""
    from repro.precision import analyze as an

    if every is None:
        every = int(os.environ.get("REPRO_GUARD_EVERY", "1"))
    if every < 1:
        raise ValueError(f"build_guard: every must be >= 1, got {every}")

    if csr is not None:
        a = csr.tocsr().astype(np.float64)
        c = np.asarray(a.sum(axis=0)).ravel()
        cabs = np.asarray(abs(a).sum(axis=0)).ravel()
        tau_quant = float(an.ulp_bound(mat.codec_name, mat.D))
        if not np.isfinite(tau_quant):
            raise IntegrityError(
                f"codec {mat.codec_name!r} has no finite ulp bound; build "
                f"the guard from the decoded operator (csr=None)")
        source = "csr"
    else:
        c, cabs = matrix_colsums(mat)
        tau_quant = 0.0
        source = "decoded"
    eps32 = float(np.finfo(np.float32).eps)
    tau_rel = safety * eps32 * (_max_row_words(mat) + 8)
    return GuardState(
        c=jnp.asarray(c, jnp.float64), cabs=jnp.asarray(cabs, jnp.float64),
        ref_checksum=checksum(guard_arrays(mat, plan)),
        tau_rel=tau_rel, tau_quant=tau_quant, source=source, every=every)


def _guard_terms(gdev: dict, x, y):
    """The shared guard arithmetic: fp64 ABFT sums + finite checks.
    Returns (ok_analytic, rel_err)."""
    x64 = x.astype(jnp.float64)
    s_y = jnp.sum(y.astype(jnp.float64))
    s_c = jnp.dot(gdev["c"], x64)
    mag = jnp.dot(gdev["cabs"], jnp.abs(x64))
    tau = gdev["tau"][0] * (mag + jnp.abs(s_c)) + gdev["tau"][1] * mag
    err = jnp.abs(s_y - s_c)
    # NaN/Inf anywhere => comparisons go False / err non-finite: tripped
    ok = (err <= tau) & jnp.all(jnp.isfinite(y)) & jnp.isfinite(mag)
    rel = err / jnp.where(mag > 0, mag, 1.0)
    return ok, rel


def guarded_spmv(mat: PackSELLMatrix, plan, gs: GuardState, x, *,
                 full: bool | None = None):
    """``(y, ok, rel_err)`` — the guarded matvec: the plan's normal
    execution body plus the ABFT identity check and the exact operand
    checksum, all inside ONE jitted dispatch. ``ok`` is a device bool
    scalar (False = guard tripped); ``rel_err`` the analytic residual
    scaled by ``cabs·|x|``. Callers that confirm a trip should
    :func:`mark_unhealthy` the plan.

    ``full`` selects the check depth: ``True`` = ABFT identity + exact
    operand checksum, ``False`` = one-reduction finiteness check on ``y``
    only (``rel_err`` is then 0). ``None`` (default) follows the guard's
    amortization stride: full every ``gs.every``-th call, light
    otherwise — see :class:`GuardState`."""
    if not isinstance(x, jax.Array):   # asarray on a device array is ~30us
        x = jnp.asarray(x)
    if full is None:
        full = gs.every <= 1 or (gs.calls % gs.every == 0)
        gs.calls += 1
    traced = plan.ephemeral or isinstance(x, jax.core.Tracer)
    if not traced:
        # detection-latency accounting (host entry points only; a traced
        # guard is one fused check inside the caller's loop)
        gs.last_check_latency = gs.calls_since_full + 1
        gs.calls_since_full = 0 if full else gs.calls_since_full + 1
        _obs.inc("guard.check", depth="full" if full else "light")
    if not full and not traced:
        key = ("guarded_spmv_light", x.shape, x.dtype)
        fn = plan._fns.get(key)
        if fn is None:
            def impl_light(matv, dev, xx):
                y = plan._execute(matv, dev, xx, False)
                # isfinite fuses into the boolean reduce (no materialized
                # temporary) — the cheapest real check on this backend
                return (y, jnp.all(jnp.isfinite(y)),
                        jnp.zeros((), jnp.float64))

            fn = jax.jit(impl_light)
            plan._fns[key] = fn
        return fn(plan._exec_mat(mat), plan._device_operands(), x)
    if plan.ephemeral or isinstance(x, jax.core.Tracer):
        dev = plan._device_operands()
        y = plan._execute(mat, dev, x, False)
        gdev = gs.dev()
        ok, rel = _guard_terms(gdev, x, y)
        with _obs.span("packsell.guard_checksum"):
            cs0, cs1 = _checksum_jnp(guard_arrays(mat, plan))
        return (y, ok & (cs0 == gdev["ref"][0]) & (cs1 == gdev["ref"][1]),
                rel)

    key = ("guarded_spmv", x.shape, x.dtype)
    fn = plan._fns.get(key)
    if fn is None:
        def impl(matv, dev, gdev, xx):
            y = plan._execute(matv, dev, xx, False)
            ok, rel = _guard_terms(gdev, xx, y)
            with _obs.span("packsell.guard_checksum"):
                cs0, cs1 = _checksum_jnp(_guard_arrays_traced(matv, dev,
                                                              plan))
            return (y, ok & (cs0 == gdev["ref"][0])
                    & (cs1 == gdev["ref"][1]), rel)

        fn = jax.jit(impl)
        plan._fns[key] = fn
    # the fused 'jnp' variant streams the plan operands, not mat.packs --
    # but the checksum of the non-fused variants covers the packs, so only
    # ship the placeholder view when the packs are NOT read
    matv = plan._exec_mat(mat)
    return fn(matv, plan._device_operands(), gs.dev(), x)


def _guard_arrays_traced(matv, dev, plan):
    """The :func:`guard_arrays` coverage set from jit-argument operands
    (shared by the spmv and spmm guarded bodies)."""
    arrs = []
    if dev.get("fused") is not None and plan.variant == "jnp":
        arrs += [dev["fused"][0], dev["fused"][1]]
    else:
        arrs += list(matv.packs) + list(matv.d0s)
        if dev.get("cols") is not None:
            arrs += list(dev["cols"])
        if dev.get("kckpt") is not None:
            arrs += list(dev["kckpt"])
    if dev.get("inv2") is not None:
        arrs.append(dev["inv2"])
    elif dev.get("inv") is not None:
        arrs.append(dev["inv"])
    arrs.append(dev["outrow"])
    return arrs


def _guard_terms_mm(gdev: dict, x, y):
    """Per-column ABFT identity for multi-RHS: ``eᵀ(AX) = (eᵀA)X``
    column by column.  Returns (ok over all columns, max column rel)."""
    x64 = x.astype(jnp.float64)
    s_y = jnp.sum(y.astype(jnp.float64), axis=0)          # [nb]
    s_c = gdev["c"] @ x64                                  # [nb]
    mag = gdev["cabs"] @ jnp.abs(x64)                      # [nb]
    tau = gdev["tau"][0] * (mag + jnp.abs(s_c)) + gdev["tau"][1] * mag
    err = jnp.abs(s_y - s_c)
    ok = jnp.all(err <= tau) & jnp.all(jnp.isfinite(y)) \
        & jnp.all(jnp.isfinite(mag))
    rel = jnp.max(err / jnp.where(mag > 0, mag, 1.0))
    return ok, rel


def guarded_spmm(mat: PackSELLMatrix, plan, gs: GuardState, x, *,
                 full: bool | None = None):
    """``(Y, ok, rel_err)`` — the multi-RHS analogue of
    :func:`guarded_spmv` for the serving front end's coalesced slots:
    ``plan.spmm``'s execution body plus a per-column ABFT identity and
    the exact operand checksum in ONE jitted dispatch.  The checksum is
    shared across all ``nb`` columns, so the guard amortizes over the
    batch — guarding a full slot costs the same integer pass as
    guarding one request.  ``full`` semantics match
    :func:`guarded_spmv` (a batch counts as ONE guarded call in the
    stride accounting)."""
    if not isinstance(x, jax.Array):
        x = jnp.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"guarded_spmm wants x of shape [m, nb], got "
                         f"{x.shape}")
    if full is None:
        full = gs.every <= 1 or (gs.calls % gs.every == 0)
        gs.calls += 1
    traced = plan.ephemeral or isinstance(x, jax.core.Tracer)
    if not traced:
        gs.last_check_latency = gs.calls_since_full + 1
        gs.calls_since_full = 0 if full else gs.calls_since_full + 1
        _obs.inc("guard.check", depth="full" if full else "light",
                 op="spmm")
    if traced:
        dev = plan._device_operands()
        y = plan._execute_mm(mat, dev, x, False)
        gdev = gs.dev()
        ok, rel = _guard_terms_mm(gdev, x, y)
        if not full:
            return y, jnp.all(jnp.isfinite(y)), jnp.zeros((), jnp.float64)
        cs0, cs1 = _checksum_jnp(guard_arrays(mat, plan))
        return (y, ok & (cs0 == gdev["ref"][0]) & (cs1 == gdev["ref"][1]),
                rel)
    if not full:
        key = ("guarded_spmm_light", x.shape, x.dtype)
        fn = plan._fns.get(key)
        if fn is None:
            def impl_light(matv, dev, xx):
                y = plan._execute_mm(matv, dev, xx, False)
                return (y, jnp.all(jnp.isfinite(y)),
                        jnp.zeros((), jnp.float64))

            fn = jax.jit(impl_light)
            plan._fns[key] = fn
        return fn(plan._exec_mat(mat), plan._device_operands(), x)

    key = ("guarded_spmm", x.shape, x.dtype)
    fn = plan._fns.get(key)
    if fn is None:
        def impl(matv, dev, gdev, xx):
            y = plan._execute_mm(matv, dev, xx, False)
            ok, rel = _guard_terms_mm(gdev, xx, y)
            with _obs.span("packsell.guard_checksum"):
                cs0, cs1 = _checksum_jnp(_guard_arrays_traced(matv, dev,
                                                              plan))
            return (y, ok & (cs0 == gdev["ref"][0])
                    & (cs1 == gdev["ref"][1]), rel)

        fn = jax.jit(impl)
        plan._fns[key] = fn
    return fn(plan._exec_mat(mat), plan._device_operands(), gs.dev(), x)


def check_integrity(mat: PackSELLMatrix, plan, gs: GuardState) -> bool:
    """Recompute the operand checksum and compare with the build-time
    reference (no matvec) — the cheap on-demand probe ``guarded_solve``
    runs per outer step."""
    cs = checksum([np.asarray(a) for a in guard_arrays(mat, plan)])
    return bool(np.uint64(cs) == np.uint64(gs.ref_checksum))


# ---------------------------------------------------------------------------
# Structural validation
# ---------------------------------------------------------------------------


def validate_matrix(mat: PackSELLMatrix, *, raise_: bool = False) -> list:
    """Structural checks on the packed buckets (host numpy): delta-decoded
    column range, permutation bijectivity, slice-base (d0) range. Returns
    a list of problem strings (empty = valid); ``raise_=True`` raises
    :class:`IntegrityError` instead."""
    issues = []
    codec = mat.codec
    mlim = max(mat.m - 1, 0)
    outrow_all = []
    for b, (pack, d0, outrow) in enumerate(
            zip(mat.packs, mat.d0s, mat.outrows)):
        words = np.asarray(pack)
        d0 = np.asarray(d0)
        outrow = np.asarray(outrow)
        outrow_all.append(outrow)
        S, w, C = words.shape
        if len(d0) != S:
            issues.append(f"bucket {b}: d0 length {len(d0)} != S={S}")
            continue
        if len(outrow) != S * C:
            issues.append(
                f"bucket {b}: outrow length {len(outrow)} != S*C={S * C}")
            continue
        if S and (d0.min(initial=0) < 0 or d0.max(initial=0) > mlim):
            issues.append(f"bucket {b}: d0 outside [0, {mlim}]")
        if words.size == 0:
            continue
        v, d, flag = cd.unpack_words_np(words.reshape(-1), codec, mat.D)
        if not np.all(np.isfinite(v[flag == 1])):
            issues.append(f"bucket {b}: non-finite packed value")
        cols = d0[:, None, None].astype(np.int64) + \
            np.cumsum(d.astype(np.int64).reshape(S, w, C), axis=1)
        rows_ok = (outrow.reshape(S, C) < mat.n)[:, None, :]
        f1 = (flag.reshape(S, w, C) == 1) & rows_ok
        if np.any(f1) and int(cols[f1].max()) > mlim:
            issues.append(
                f"bucket {b}: decoded column {int(cols[f1].max())} >= "
                f"m={mat.m}")
    if outrow_all:
        cat = np.concatenate(outrow_all)
        counts = np.bincount(cat[cat < mat.n], minlength=mat.n)
        if len(cat) and (counts.min(initial=1) < 1
                         or counts.max(initial=1) > 1):
            issues.append("outrow is not a bijection onto [0, n)")
    if issues and raise_:
        raise IntegrityError("; ".join(issues))
    return issues


def validate_plan(mat: PackSELLMatrix, plan, *, raise_: bool = False) -> list:
    """Structural checks on a plan's derived operands: fused-stream length
    accounting, segment coverage, checkpoint monotonicity and range,
    offset (delta-field) range under the stream encoding, inverse-
    permutation bijectivity. Host numpy; run on demand (the cheap subset
    already ran at build — ``kernels.plan._quick_validate``)."""
    issues = []
    outrow = np.asarray(plan.outrow_cat)
    if len(outrow) != plan.total_stored:
        issues.append(f"outrow_cat length {len(outrow)} != total_stored="
                      f"{plan.total_stored}")
    counts = np.bincount(outrow[outrow < plan.n], minlength=plan.n)
    if plan.n and (counts.min(initial=1) < 1 or counts.max(initial=1) > 1):
        issues.append("outrow_cat is not a bijection onto [0, n)")
    if plan.inv_cat is not None:
        inv = np.asarray(plan.inv_cat)
        if len(inv) != plan.n:
            issues.append(f"inv_cat length {len(inv)} != n={plan.n}")
        elif plan.n and not np.array_equal(
                outrow[np.clip(inv, 0, len(outrow) - 1)],
                np.arange(plan.n)):
            issues.append("inv_cat does not invert outrow_cat")
    if plan.inv2_cat is not None and plan.inv_cat is not None:
        inv2 = np.asarray(plan.inv2_cat)
        if not np.array_equal(inv2[:, 0] * mat.C + inv2[:, 1],
                              np.asarray(plan.inv_cat)):
            issues.append("inv2_cat disagrees with inv_cat")

    layout = plan.fused_layout
    if plan.fused is not None and layout is not None:
        words3d = np.asarray(plan.fused[0])
        ckpt = np.asarray(plan.fused[1])
        if words3d.shape != (layout.groups, layout.wr, layout.C):
            issues.append(
                f"fused stream shape {words3d.shape} != layout "
                f"({layout.groups}, {layout.wr}, {layout.C})")
        if ckpt.shape != (layout.groups, layout.C):
            issues.append(f"fused checkpoint shape {ckpt.shape} != "
                          f"({layout.groups}, {layout.C})")
        g_sum = sum(seg.groups for seg in layout.segments)
        if g_sum != layout.groups:
            issues.append(f"segment group accounting {g_sum} != "
                          f"{layout.groups}")
        stored = sum(seg.stored for seg in layout.segments)
        if stored != plan.total_stored:
            issues.append(f"segment stored accounting {stored} != "
                          f"{plan.total_stored}")
        mlim = max(plan.m - 1, 0)
        if ckpt.size and (int(ckpt.min()) < 0 or int(ckpt.max()) > mlim):
            issues.append(f"checkpoint outside [0, {mlim}]")
        for si, seg in enumerate(layout.segments):
            levels = seg.levels
            if any(levels[k] < levels[k + 1]
                   for k in range(len(levels) - 1)):
                issues.append(f"segment {si}: level sizes not "
                              f"non-increasing: {levels}")
            if levels and levels[0] > seg.S:
                issues.append(f"segment {si}: level 0 covers {levels[0]} "
                              f"> S={seg.S} slices")
            # checkpoint monotonicity: along one slice's run chain the
            # cursor may only advance
            if not issues and words3d.size:
                off = 0
                prev = None
                for Sk in levels:
                    cur = ckpt[seg.g0 + off:seg.g0 + off + Sk]
                    if prev is not None and np.any(cur < prev[:Sk]):
                        issues.append(
                            f"segment {si}: checkpoint not monotone")
                        break
                    prev = cur
                    off += Sk
        # offset (delta-field) range under the encoding: every decoded
        # column must land in [0, m)
        if not issues and words3d.size:
            v, local = _decode_stream_np(words3d, mat, layout)
            cols = ckpt[:, None, :].astype(np.int64) + local
            contrib = v != 0
            if np.any(contrib) and int(cols[contrib].max()) > mlim:
                issues.append(
                    f"fused offset overflow: column "
                    f"{int(cols[contrib].max())} >= m={plan.m}")
            if not np.all(np.isfinite(v)):
                issues.append("fused stream decodes a non-finite value")
    if issues and raise_:
        raise IntegrityError("; ".join(issues))
    return issues


def _decode_stream_np(words3d: np.ndarray, mat: PackSELLMatrix, layout):
    """Numpy mirror of ``kernels.plan._fused_decode``: (value fp64,
    run-local offset int64) for the whole stream."""
    w = words3d.astype(np.uint32)
    enc = layout.encoding
    if enc == "f16":
        v = (w >> np.uint32(16)).astype(np.uint16).view(np.float16)
        local = (w & np.uint32(0xFFFF)).astype(np.int64)
    elif enc == "top16":
        v = (w & np.uint32(0xFFFF0000)).view(np.float32)
        local = (w & np.uint32(0xFFFF)).astype(np.int64)
    elif enc == "fixed16":
        v = (w.view(np.int32) >> np.int32(16)).astype(np.float64) \
            * layout.scale
        local = (w & np.uint32(0xFFFF)).astype(np.int64)
    else:                            # 'words'
        v, d, flag = cd.unpack_words_np(w.reshape(-1), mat.codec, mat.D)
        v = np.where(flag == 1, v, 0.0).reshape(w.shape)
        local = d.astype(np.int64).reshape(w.shape)
    return np.asarray(v, np.float64), local


def validate_composite(comp, *, raise_: bool = False) -> list:
    """Validate every member block of a
    :class:`~repro.kernels.composite.CompositePlan` plus the per-term
    inverse permutations (each term's inverse must index a valid slot per
    covered row)."""
    issues = []
    for i, mem in enumerate(comp.members):
        if isinstance(mem.mat, PackSELLMatrix):
            for msg in validate_matrix(mem.mat):
                issues.append(f"member {i} ({mem.label}): {msg}")
            if mem.plan is not None:
                for msg in validate_plan(mem.mat, mem.plan):
                    issues.append(f"member {i} ({mem.label}): {msg}")
    for t, inv in enumerate(comp._invs_np):
        inv = np.asarray(inv)
        if len(inv) != comp.n:
            issues.append(f"term {t}: inverse length {len(inv)} != "
                          f"n={comp.n}")
        else:
            stored = sum(mem.stored for mem in comp.members
                         if mem.term == t) + (1 if comp.pad_slot else 0)
            if len(inv) and (int(inv.min()) < 0
                             or int(inv.max()) >= stored):
                issues.append(f"term {t}: inverse indexes outside "
                              f"[0, {stored})")
    if issues and raise_:
        raise IntegrityError("; ".join(issues))
    return issues
