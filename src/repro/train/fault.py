"""Fault-tolerance runtime pieces: preemption capture, straggler detection.

These are host-side policies (they wrap the jitted step, they don't live
inside it), so they work unchanged from 1 CPU to a multi-pod fleet:

* ``PreemptionGuard`` — converts SIGTERM/SIGINT (the cloud preemption
  notice) into a flag the training loop polls; the loop then commits a
  final checkpoint and exits cleanly instead of dying mid-step.
* ``StepMonitor`` — EWMA step-time tracker. A step slower than
  ``threshold ×`` the EWMA is flagged as a straggler event; after
  ``trip_limit`` consecutive events the monitor recommends exclusion
  (on a real fleet the launcher maps this to removing the slow host and
  re-meshing via the elastic checkpoint restore; on one host it logs).
"""
from __future__ import annotations

import dataclasses
import signal
import time


class PreemptionGuard:
    """Install with ``with PreemptionGuard() as guard: ... guard.fired``."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.signals = signals
        self.fired = False
        self._prev = {}

    def _handler(self, signum, frame):
        self.fired = True

    def __enter__(self):
        for s in self.signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ewma: float
    ratio: float


class StepMonitor:
    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 trip_limit: int = 3, warmup: int = 2):
        self.alpha = alpha
        self.threshold = threshold
        self.trip_limit = trip_limit
        self.warmup = warmup
        self.ewma: float | None = None
        self.events: list[StragglerEvent] = []
        self._consecutive = 0
        self._seen = 0
        self._t0: float | None = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> StragglerEvent | None:
        dt = time.perf_counter() - self._t0
        self._seen += 1
        if self._seen <= self.warmup:        # compile steps don't count
            return None
        if self.ewma is None:
            self.ewma = dt
            return None
        ratio = dt / self.ewma
        ev = None
        if ratio > self.threshold:
            ev = StragglerEvent(step, dt, self.ewma, ratio)
            self.events.append(ev)
            self._consecutive += 1
        else:
            self._consecutive = 0
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return ev

    @property
    def exclusion_recommended(self) -> bool:
        return self._consecutive >= self.trip_limit
