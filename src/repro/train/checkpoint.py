"""Fault-tolerant checkpointing with elastic re-shard restore.

Layout of one checkpoint::

    <dir>/step_<k>/
        metadata.json      # tree structure, per-leaf dtype/shape/spec, extra
        arrays.npz         # one entry per leaf, keyed by flattened path

Guarantees:

* **Atomic commit** — everything is written into ``<dir>/.tmp_step_<k>`` and
  ``os.rename``d into place; a crash mid-save never corrupts the latest
  checkpoint, and ``latest_step`` only ever sees committed directories.
* **Keep-k retention** — older committed checkpoints beyond ``keep`` are
  deleted after a successful commit (never before).
* **Elastic re-shard restore** — leaves are stored as *global* arrays along
  with their logical PartitionSpec. ``restore_resharded`` places each leaf
  on the *current* mesh with ``jax.device_put`` + the stored spec filtered
  to whatever axes that mesh has (``sanitize_spec``), so a checkpoint taken
  on a 16×16 mesh restores onto 2×16×16, 4×4, or a single device
  unchanged — the logical content is mesh-independent.

The pytree is addressed by flattened key paths, so saving a ``TrainState``
and restoring into a freshly-initialized ``TrainState`` of the same
architecture round-trips exactly.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.parallel import sanitize_spec

_SEP = "/"


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        elif hasattr(e, "name"):
            parts.append(str(e.name))
        else:
            parts.append(str(e))
    return _SEP.join(parts)


def _spec_to_json(spec) -> list:
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append(list(e))
        else:
            out.append(e)
    return out


def _spec_from_json(entries) -> P:
    return P(*(tuple(e) if isinstance(e, list) else e for e in entries))


def flatten_with_paths(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_str(path): leaf for path, leaf in flat}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- discovery ---------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.isfile(
                    os.path.join(self.dir, name, "metadata.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, spec_tree=None, extra: dict | None = None):
        """Write checkpoint ``step``. ``spec_tree`` mirrors ``state`` with
        logical PartitionSpecs (or None for fully-replicated)."""
        t0 = time.time()
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        leaves = flatten_with_paths(state)
        if spec_tree is None:
            specs = {k: P() for k in leaves}
        else:
            spec_flat = jax.tree_util.tree_flatten_with_path(
                spec_tree, is_leaf=lambda s: isinstance(s, P))[0]
            specs = {_path_str(p): s for p, s in spec_flat}

        arrays, meta_leaves = {}, {}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            arrays[key] = arr
            meta_leaves[key] = {
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
                "spec": _spec_to_json(specs.get(key, P())),
            }
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace(_SEP, "__"): v for k, v in arrays.items()})
        meta = {
            "step": step,
            "leaves": meta_leaves,
            "extra": extra or {},
            "time": time.time(),
        }
        with open(os.path.join(tmp, "metadata.json"), "w") as f:
            json.dump(meta, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)             # atomic commit
        self._prune()
        return {"save_s": time.time() - t0, "path": final}

    def _prune(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def load_raw(self, step: int | None = None) -> tuple[dict, dict]:
        """(arrays by path-key, metadata) for a committed step."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "metadata.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k.replace("__", _SEP): z[k] for k in z.files}
        return arrays, meta

    def restore(self, template, step: int | None = None, mesh=None):
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs), re-sharded onto ``mesh`` if given."""
        arrays, meta = self.load_raw(step)
        return restore_resharded(template, arrays, meta, mesh=mesh), meta


def restore_resharded(template, arrays: dict, meta: dict, mesh=None):
    """Rebuild ``template``'s pytree from stored global arrays, placing each
    leaf with its stored logical spec adapted to ``mesh`` (elastic)."""
    flat = jax.tree_util.tree_flatten_with_path(template)
    leaves, treedef = flat
    out = []
    for path, leaf in leaves:
        key = _path_str(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {want_shape}")
        arr = arr.astype(leaf.dtype)
        if mesh is not None:
            spec = _spec_from_json(meta["leaves"][key]["spec"])
            sh = NamedSharding(mesh, sanitize_spec(spec, arr.shape, mesh))
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
