"""Training substrate: trainer loop, checkpointing, fault tolerance."""
from .checkpoint import CheckpointManager, restore_resharded  # noqa: F401
from .fault import PreemptionGuard, StepMonitor  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
