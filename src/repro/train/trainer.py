"""The training driver: mesh + data + step + checkpoints + fault tolerance.

The same Trainer runs the CPU examples (1 device, debug mesh) and the
production configuration (the launcher passes the 16×16 / 2×16×16 mesh);
everything mesh-dependent flows through the logical-spec machinery in
``repro.parallel`` so no code changes between scales.

Gradient compression (beyond-paper application of the paper's E8MY codec,
see ``repro/optim/compression.py``) is wired as an opt-in pure-DP step
built with ``shard_map``: each data shard computes grads locally, truncates
mantissas with error feedback, and psums the narrow payload — the exact
construction that would run on the inter-pod axis at scale.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.data import DataConfig, SyntheticTokenStream
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import (OptConfig, TrainState, apply_updates, init_state,
                         zero_spec_tree)
from repro.optim.compression import compress
from repro.parallel import shard_map_compat, tree_shardings_shaped
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import PreemptionGuard, StepMonitor

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    # data
    seq_len: int = 256
    global_batch: int = 8
    # distribution
    data_axis: int = 1            # debug-mesh DP size (examples/tests)
    model_axis: int = 1
    # gradient accumulation: microbatch size per step (None = full batch).
    # Halving the microbatch roughly halves activation residency — the
    # knob that fits dbrx-132b train_4k under 16 GB/device (EXPERIMENTS §B)
    microbatch: int | None = None
    # fault tolerance
    straggler_threshold: float = 2.0
    # gradient compression (None = off; int = E8M<bits> mantissa)
    grad_compression: int | None = None


class Trainer:
    def __init__(self, model_cfg: ModelConfig, opt_cfg: OptConfig,
                 tcfg: TrainerConfig, mesh=None,
                 log_fn: Callable[[str], None] = print):
        self.cfg = model_cfg
        self.opt = opt_cfg
        self.tcfg = tcfg
        self.log = log_fn
        self.mesh = mesh or jax.make_mesh(
            (tcfg.data_axis, tcfg.model_axis), ("data", "model"))
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.monitor = StepMonitor(threshold=tcfg.straggler_threshold)
        self.data = SyntheticTokenStream(DataConfig(
            vocab=model_cfg.vocab, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed))
        self.history: list[dict] = []
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        cfg, opt, mesh = self.cfg, self.opt, self.mesh
        shapes, specs = tfm.abstract_params(cfg)
        self.param_specs = specs
        dsize = mesh.shape.get("data", 1)
        self.zspecs = zero_spec_tree(specs, shapes, data_size=dsize)
        self.state_specs = TrainState(P(), self.zspecs, self.zspecs,
                                      self.zspecs)
        if self.tcfg.grad_compression is None:
            step = self._make_pjit_step()
        else:
            step = self._make_compressed_step()
        self._step_fn = step

    def _make_pjit_step(self):
        cfg, opt = self.cfg, self.opt
        specs, zspecs = self.param_specs, self.zspecs
        from repro.parallel import constrain

        def to_compute(master):
            # blocks stay master-typed; the layer scan casts per layer (B4a)
            cdtype = jnp.dtype(cfg.dtype)
            out = {}
            for key, sub in master.items():
                if key in ("blocks", "enc_blocks"):
                    out[key] = sub
                    continue
                leaves, treedef = jax.tree.flatten(sub)
                sp_leaves = jax.tree.flatten(
                    specs[key], is_leaf=lambda s: isinstance(s, P))[0]
                out[key] = jax.tree.unflatten(
                    treedef, [constrain(x.astype(cdtype), sp)
                              for x, sp in zip(leaves, sp_leaves)])
            return out

        mb = self.tcfg.microbatch
        gb = self.tcfg.global_batch
        if mb is not None and (gb % mb != 0 or mb >= gb):
            raise ValueError(f"microbatch {mb} must divide global batch "
                             f"{gb} and be smaller")

        def loss_fn(master, batch):
            return tfm.forward_train(cfg, to_compute(master), batch)

        def train_step(state: TrainState, batch):
            if mb is None:
                loss, grads = jax.value_and_grad(loss_fn)(state.master,
                                                          batch)
            else:
                # gradient accumulation over gb/mb microbatches: activation
                # residency scales with mb, gradients/loss are the exact
                # full-batch mean (each microbatch weighted equally)
                n_micro = gb // mb
                stacked = jax.tree.map(
                    lambda x: x.reshape((n_micro, mb) + x.shape[1:]), batch)

                def acc_step(carry, mbatch):
                    loss_sum, gacc = carry
                    l, g = jax.value_and_grad(loss_fn)(state.master, mbatch)
                    gacc = jax.tree.map(jnp.add, gacc, g)
                    return (loss_sum + l, gacc), None

                zero_g = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), state.master)
                (loss_sum, gsum), _ = jax.lax.scan(
                    acc_step, (jnp.zeros((), jnp.float32), zero_g), stacked)
                loss = loss_sum / n_micro
                grads = jax.tree.map(lambda g: g / n_micro, gsum)
            new_state = apply_updates(state, grads, opt, zero_specs=zspecs)
            return new_state, {"loss": loss}

        return train_step

    def _make_compressed_step(self):
        """Pure-DP step with E8MY-compressed gradient psum (shard_map)."""
        cfg, opt, mesh = self.cfg, self.opt, self.mesh
        bits = self.tcfg.grad_compression

        def shard_step(state, err, batch):
            # params replicated; batch sharded over 'data'
            def loss_fn(master):
                p = jax.tree.map(
                    lambda x: x.astype(jnp.dtype(cfg.dtype)), master)
                return tfm.forward_train(cfg, p, batch)

            loss, grads = jax.value_and_grad(loss_fn)(state.master)
            nshards = jax.lax.psum(1, "data")

            def one(g, e):
                q, e2 = compress(g / nshards, e, bits)
                return jax.lax.psum(q, "data"), e2

            flat_g, treedef = jax.tree.flatten(grads)
            flat_e = jax.tree.leaves(err)
            summed, new_err = [], []
            for g, e in zip(flat_g, flat_e):
                s, e2 = one(g, e)
                summed.append(s)
                new_err.append(e2)
            grads = jax.tree.unflatten(treedef, summed)
            err = jax.tree.unflatten(treedef, new_err)
            loss = jax.lax.pmean(loss, "data")
            new_state = apply_updates(state, grads, opt)
            return new_state, err, {"loss": loss}

        rep = P()
        bspec = P("data")

        def spec_like(tree, spec):
            return jax.tree.map(lambda _: spec, tree)

        def train_step(state, err, batch):
            shapes = jax.tree.map(lambda x: x, state)
            fn = shard_map_compat(
                shard_step, mesh,
                in_specs=(spec_like(state, rep), spec_like(err, rep),
                          spec_like(batch, bspec)),
                out_specs=(spec_like(shapes, rep), spec_like(err, rep),
                           {"loss": rep}))
            return fn(state, err, batch)

        return train_step

    # ------------------------------------------------------------------
    def init_or_restore(self) -> TrainState:
        shapes, _ = tfm.abstract_params(self.cfg)
        latest = self.ckpt.latest_step()
        if latest is not None:
            f32 = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), shapes)
            template = TrainState(
                jax.ShapeDtypeStruct((), jnp.int32), f32,
                jax.tree.map(lambda s: s, f32), jax.tree.map(lambda s: s, f32))
            state, meta = self.ckpt.restore(template, mesh=self.mesh)
            self.data.restore(meta["extra"]["data_state"])
            self.log(f"[trainer] restored step {meta['step']} "
                     f"from {self.tcfg.ckpt_dir}")
            return state
        params = tfm.init_params(self.cfg, jax.random.PRNGKey(
            self.tcfg.seed))[0]
        return init_state(params)

    def _save(self, state: TrainState, step: int):
        info = self.ckpt.save(
            step, state, spec_tree=self.state_specs,
            extra={"data_state": self.data.state(),
                   "model": self.cfg.name})
        self.log(f"[trainer] checkpoint step {step} "
                 f"({info['save_s']:.2f}s) -> {info['path']}")

    # ------------------------------------------------------------------
    def run(self, state: TrainState | None = None) -> TrainState:
        tcfg = self.tcfg
        with self.mesh:
            if state is None:
                state = self.init_or_restore()
            start = int(jax.device_get(state.step))
            jit_step = jax.jit(self._step_fn, donate_argnums=(0,)) \
                if tcfg.grad_compression is None else None
            err = None
            if tcfg.grad_compression is not None:
                err = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), state.master)
                jit_step = jax.jit(self._step_fn, donate_argnums=(0, 1))

            with PreemptionGuard() as guard:
                for step in range(start, tcfg.steps):
                    self.monitor.start()
                    batch = self.data.next_placed_batch(self.mesh)
                    if tcfg.grad_compression is None:
                        state, metrics = jit_step(state, batch)
                    else:
                        state, err, metrics = jit_step(state, err, batch)
                    loss = float(jax.device_get(metrics["loss"]))
                    ev = self.monitor.stop(step)
                    if ev is not None:
                        self.log(f"[straggler] step {ev.step}: "
                                 f"{ev.step_time:.3f}s = {ev.ratio:.1f}x "
                                 f"EWMA {ev.ewma:.3f}s"
                                 + ("  -> exclusion recommended"
                                    if self.monitor.exclusion_recommended
                                    else ""))
                    rec = {"step": step + 1, "loss": loss}
                    self.history.append(rec)
                    if (step + 1) % tcfg.log_every == 0 or step == start:
                        self.log(f"[train] step {step + 1:5d}  "
                                 f"loss {loss:.4f}")
                    if (step + 1) % tcfg.ckpt_every == 0:
                        self._save(state, step + 1)
                    if guard.fired:
                        self.log("[trainer] preemption signal — saving and "
                                 "exiting cleanly")
                        self._save(state, step + 1)
                        break
        return state

    def dump_history(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.history, f, indent=1)
