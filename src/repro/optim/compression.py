"""E8MY gradient compression for data-parallel reduction (beyond paper).

Applies the paper's E8MY idea (§4.2.2) to the DP gradient all-reduce: each
shard truncates its fp32 gradient to the top V bits (RNE) before the psum and
keeps the truncation error in an fp32 *error-feedback* buffer added to the
next step's gradient — the standard EF-SGD construction, so convergence is
preserved while inter-pod DCI traffic drops ~2× (E8M10 ≈ 19 bits on the
wire after packing; here we model it as a bf16/E8MY-valued psum).

Used through ``compressed_psum`` inside a ``shard_map`` over the DP axes —
see ``repro/train/trainer.py`` (opt-in: TrainerConfig.grad_compression).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def e8m_truncate(x: jnp.ndarray, mantissa_bits: int) -> jnp.ndarray:
    """Round fp32 to E8M<mantissa_bits> (RNE), staying in fp32 storage."""
    drop = 23 - mantissa_bits
    u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    lsb = (u >> np.uint32(drop)) & np.uint32(1)
    half = np.uint32((1 << (drop - 1)) - 1)
    r = (u + lsb + half) & ~np.uint32((1 << drop) - 1)
    return jax.lax.bitcast_convert_type(r, jnp.float32)


def compress(grad: jnp.ndarray, err: jnp.ndarray, mantissa_bits: int):
    """(gradient + error feedback) -> (quantized gradient, new error)."""
    g = grad.astype(jnp.float32) + err
    q = e8m_truncate(g, mantissa_bits)
    return q, g - q


def compressed_psum(grad_tree, err_tree, axis_name, mantissa_bits: int = 10):
    """Quantize -> psum over the DP axis -> new error feedback."""
    def one(g, e):
        q, e2 = compress(g, e, mantissa_bits)
        return jax.lax.psum(q, axis_name), e2

    out = jax.tree.map(one, grad_tree, err_tree)
    summed = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    errs = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    return summed, errs


# ---------------------------------------------------------------------------
# Integer-wire compressed reduction (§Perf C — the paper's packing idea on
# the inter-pod link). A float psum cannot carry a narrow wire format
# (XLA re-widens the dtype around the collective), so the reduction is done
# GShard-style by hand: quantize -> all_to_all the shards (INTEGER wire) ->
# local dequant+sum -> quantize -> all_gather (INTEGER wire) -> dequant.
# Wire cost per device: payload/2 + payload/2 = 1x quantized payload vs
# 2x fp32 payload for a ring all-reduce -> 4x (uint16) / 8x (uint8) less
# DCI traffic. Runs inside a shard_map manual region over ``axis_name``.
# ---------------------------------------------------------------------------


def _f32_to_u16(x: jnp.ndarray) -> jnp.ndarray:
    """Top 16 bits of an RNE-rounded fp32 == the bf16 bit pattern."""
    r = e8m_truncate(x, 7)
    u = jax.lax.bitcast_convert_type(r, jnp.uint32)
    return (u >> np.uint32(16)).astype(jnp.uint16)


def _u16_to_f32(u: jnp.ndarray) -> jnp.ndarray:
    w = u.astype(jnp.uint32) << np.uint32(16)
    return jax.lax.bitcast_convert_type(w, jnp.float32)


def _f32_to_u8(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Scale-normalized float8_e4m3 wire byte."""
    y = (x / scale).astype(jnp.float8_e4m3fn)
    return jax.lax.bitcast_convert_type(y, jnp.uint8)


def _u8_to_f32(u: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    y = jax.lax.bitcast_convert_type(u, jnp.float8_e4m3fn)
    return y.astype(jnp.float32) * scale


def compressed_wire_reduce(g: jnp.ndarray, axis_name: str, n_shards: int,
                           wire: str = "u16") -> jnp.ndarray:
    """Mean-reduce ``g`` over ``axis_name`` with an integer wire format.

    Must run inside a shard_map manual region over ``axis_name`` (size
    ``n_shards``). Semantics: RS(quantized) + local sum + AG(quantized) —
    i.e. one quantization before and one after the sum, like bf16-reduce
    hardware offload.
    """
    shape = g.shape
    flat = g.reshape(-1).astype(jnp.float32) / n_shards
    pad = -flat.size % n_shards
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n_shards, -1)            # [n, m]

    if wire == "u16":
        sent = _f32_to_u16(chunks)
        recv = jax.lax.all_to_all(sent, axis_name, split_axis=0,
                                  concat_axis=0, tiled=False)
        part = jnp.sum(_u16_to_f32(recv), axis=0)  # my shard, reduced
        out = jax.lax.all_gather(_f32_to_u16(part), axis_name)
        flat_out = _u16_to_f32(out).reshape(-1)
    elif wire == "u8":
        scale = jnp.maximum(jnp.max(jnp.abs(chunks)), 1e-30) / 448.0
        scale = jax.lax.pmax(scale, axis_name)     # shared scalar scale
        sent = _f32_to_u8(chunks, scale)
        recv = jax.lax.all_to_all(sent, axis_name, split_axis=0,
                                  concat_axis=0, tiled=False)
        part = jnp.sum(_u8_to_f32(recv, scale), axis=0)
        # the sum of n quantized chunks can exceed ±448·scale: fresh scale
        # for the gather leg (e4m3fn has no inf — overflow would be NaN)
        scale2 = jnp.maximum(jnp.max(jnp.abs(part)), 1e-30) / 448.0
        scale2 = jax.lax.pmax(scale2, axis_name)
        out = jax.lax.all_gather(_f32_to_u8(part, scale2), axis_name)
        flat_out = _u8_to_f32(out, scale2).reshape(-1)
    else:
        raise ValueError(wire)
    if pad:
        flat_out = flat_out[:-pad]
    return flat_out.reshape(shape)
