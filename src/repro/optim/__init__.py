"""Optimizer substrate: AdamW + ZeRO sharding, schedules, grad compression."""
from . import adamw, compression  # noqa: F401
from .adamw import (OptConfig, TrainState, apply_updates, init_state,  # noqa: F401
                    lr_at, zero_spec, zero_spec_tree)
