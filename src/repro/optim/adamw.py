"""AdamW with ZeRO-1/3-style state sharding (pure JAX, no optax).

The train state holds fp32 *master* params and Adam moments, all sharded over
``model`` × ``data`` (``zero_spec`` adds the data axis to the first free dim
of each param spec). The compute params are materialized per step as
``bf16 = cast(constrain(master, param_spec))`` — GSPMD turns that into an
all-gather over ``data``; its transpose in backward is exactly the ZeRO
reduce-scatter of gradients. No hand-written collectives needed.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel import constrain

PyTree = Any


def zero_spec(spec: P, shape=None, data_size: int = 16) -> P:
    """Add ZeRO sharding over the FULL DP domain ('pod','data') on the
    largest unsharded dim the axes divide evenly (§Perf C4: sharding the
    master/moments across pods turns the pod-axis gradient all-reduce into
    a reduce-scatter — half the ring wire — and halves optimizer bytes).
    On meshes without a 'pod' axis the name is filtered out downstream."""
    entries = list(spec)
    if shape is not None and len(entries) < len(shape):
        entries += [None] * (len(shape) - len(entries))
    best, best_dim = None, 0
    for i, e in enumerate(entries):
        if e is not None:
            continue
        dim = shape[i] if shape is not None else 0
        if shape is None:
            best = i
            break
        if dim % data_size == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best is None:
        return P(*entries)
    entries[best] = ("pod", "data")
    return P(*entries)


def zero_spec_tree(spec_tree, shape_tree=None, data_size: int = 16):
    if shape_tree is None:
        return jax.tree.map(zero_spec, spec_tree,
                            is_leaf=lambda s: isinstance(s, P))
    spec_leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda s: isinstance(s, P))
    shape_leaves = jax.tree.leaves(shape_tree)
    out = [zero_spec(s, sh.shape, data_size)
           for s, sh in zip(spec_leaves, shape_leaves)]
    return jax.tree.unflatten(treedef, out)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    step: jnp.ndarray
    master: PyTree      # fp32, ZeRO-sharded
    m: PyTree           # fp32, ZeRO-sharded
    v: PyTree           # fp32, ZeRO-sharded

    def tree_flatten(self):
        return ((self.step, self.master, self.m, self.v), None)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def init_state(params: PyTree) -> TrainState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return TrainState(jnp.zeros((), jnp.int32), f32(params), zeros,
                      jax.tree.map(jnp.copy, zeros))


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr_peak: float = 3e-4
    warmup: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptConfig, step):
    warm = cfg.lr_peak * (step + 1) / max(cfg.warmup, 1)
    t = jnp.clip((step - cfg.warmup) /
                 max(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * cfg.lr_peak * (1 + jnp.cos(np.pi * t))
    return jnp.where(step < cfg.warmup, warm, cos).astype(jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(state: TrainState, grads: PyTree, opt: OptConfig,
                  zero_specs: PyTree | None = None) -> TrainState:
    """One AdamW step on the (sharded) master params."""
    step = state.step + 1
    lr = lr_at(opt, state.step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-12))

    def upd(g, mm, vv, p, spec=None):
        g = g.astype(jnp.float32) * scale
        if spec is not None:
            g = constrain(g, spec)
        mm = opt.b1 * mm + (1 - opt.b1) * g
        vv = opt.b2 * vv + (1 - opt.b2) * g * g
        mhat = mm / (1 - opt.b1 ** step.astype(jnp.float32))
        vhat = vv / (1 - opt.b2 ** step.astype(jnp.float32))
        p = p - lr * (mhat / (jnp.sqrt(vhat) + opt.eps)
                      + opt.weight_decay * p)
        return p, mm, vv

    g_leaves, treedef = jax.tree.flatten(grads)
    m_leaves = jax.tree.leaves(state.m)
    v_leaves = jax.tree.leaves(state.v)
    p_leaves = jax.tree.leaves(state.master)
    if zero_specs is None:
        s_leaves = [None] * len(g_leaves)
    else:
        s_leaves = jax.tree.flatten(
            zero_specs, is_leaf=lambda s: isinstance(s, P))[0]
    new_p, new_m, new_v = [], [], []
    for g, mm, vv, p, sp in zip(g_leaves, m_leaves, v_leaves, p_leaves,
                                s_leaves):
        p2, m2, v2 = upd(g, mm, vv, p, sp)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return TrainState(step, jax.tree.unflatten(treedef, new_p),
                      jax.tree.unflatten(treedef, new_m),
                      jax.tree.unflatten(treedef, new_v))
