"""Linear operators over the sparse formats, with per-precision variants.

The paper's solvers (§5.2) mix SpMV precisions inside one Krylov hierarchy:
an FP64 operator for the outer iteration and FP16 / E8MY PackSELL operators
inside. ``OperatorSet`` builds all requested variants of one matrix once and
hands out matvec callables; solvers are written against plain callables so
any format/precision combination plugs in.

Kind strings are parsed in ONE place (:func:`parse_kind`) — every entry
point (``matvec`` / ``plan_pair`` / ``dist_plan``) consumes the parsed
:class:`KindSpec` instead of re-splitting prefixes ad hoc, and malformed
kinds fail with the full menu of valid ones.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core import sparse as sps
from repro.kernels import plan as kplan

Matvec = Callable[[jnp.ndarray], jnp.ndarray]


def row_scale(a: sp.csr_matrix) -> tuple[sp.csr_matrix, np.ndarray]:
    """G^{-1} A with g_i = sum_j |a_ij| (paper §5.1.2 scaling for SpMV)."""
    g = np.asarray(np.abs(a).sum(axis=1)).ravel()
    g = np.where(g == 0, 1.0, g)
    return sp.diags(1.0 / g) @ a, g


def sym_scale(a: sp.csr_matrix) -> tuple[sp.csr_matrix, np.ndarray]:
    """Ḡ^{-1} A Ḡ^{-1} with ḡ_i = sqrt(|a_ii|) (paper §5.2 scaling)."""
    d = np.sqrt(np.abs(a.diagonal()))
    d = np.where(d == 0, 1.0, d)
    dinv = sp.diags(1.0 / d)
    s = (dinv @ a @ dinv).tocsr()
    s.sort_indices()
    return s, d


# ---------------------------------------------------------------------------
# Kind-string parsing (satellite: one parser, informative errors)
# ---------------------------------------------------------------------------

#: engine-less dense/baseline kinds
DENSE_KINDS = ("fp64", "fp32", "fp16", "bf16")

#: the valid-kind menu malformed inputs are pointed at
KIND_MENU = (
    "fp64 | fp32 | fp16 | bf16 | csr64 | packsell_<codec> | plan_<codec> "
    "| dist_<codec> | auto:<budget> | mixed:<budget> | dist_auto:<budget> "
    "| dist_mixed:<budget> | guarded:plan_<codec>   (<codec>: fp16 | bf16 "
    "| e8m<D>, e.g. e8m8; <budget>: a positive float, e.g. 1e-3)")


@dataclasses.dataclass(frozen=True)
class KindSpec:
    """One parsed operator-kind string.

    ``family`` is the dispatch class: ``'dense'`` (SELL at a float dtype),
    ``'csr64'``, ``'packsell'`` (per-call jnp path), ``'plan'`` (cached
    SpMVPlan engine), ``'dist'`` (DistSpMVPlan shard_map), ``'auto'`` /
    ``'mixed'`` (budget-driven selection, global / per-row-class) and their
    distributed compositions ``'dist_auto'`` / ``'dist_mixed'``.
    """

    raw: str
    family: str
    codec: Optional[str] = None     # codec families
    D: Optional[int] = None
    budget: Optional[float] = None  # budget families
    inner: Optional["KindSpec"] = None  # 'guarded:' wraps a plan_ kind

    @property
    def distributed(self) -> bool:
        return self.family.startswith("dist")


def _parse_codec(sub: str, kind: str) -> tuple[str, int]:
    if sub in ("fp16", "bf16"):
        return sub, 15
    if sub.startswith("e8m") and sub[3:].isdigit():
        # *_e8mD where D is the *delta* width (Y = 22 - D)
        return "e8m", int(sub[3:])
    raise ValueError(
        f"unknown codec {sub!r} in operator kind {kind!r}; valid kinds: "
        f"{KIND_MENU}")


def _parse_budget(sub: str, kind: str) -> float:
    try:
        budget = float(sub)
    except ValueError:
        raise ValueError(
            f"malformed error budget {sub!r} in operator kind {kind!r}; "
            f"valid kinds: {KIND_MENU}") from None
    if not budget > 0:
        raise ValueError(
            f"error budget must be positive, got {budget} in operator "
            f"kind {kind!r}; valid kinds: {KIND_MENU}")
    return budget


def parse_kind(kind: str) -> KindSpec:
    """Parse an operator kind string; raises ValueError listing every
    valid kind on malformed input."""
    if not isinstance(kind, str):
        raise ValueError(
            f"operator kind must be a string, got {type(kind).__name__}; "
            f"valid kinds: {KIND_MENU}")
    if kind in DENSE_KINDS:
        return KindSpec(kind, "dense", codec=kind)
    if kind == "csr64":
        return KindSpec(kind, "csr64")
    if kind.startswith("guarded:"):
        inner = parse_kind(kind[len("guarded:"):])
        if inner.family != "plan":
            raise ValueError(
                f"guarded: wraps plan_<codec> kinds only (ABFT checksums "
                f"need the plan engine's packed operands), got "
                f"{inner.raw!r} in {kind!r}; valid kinds: {KIND_MENU}")
        return KindSpec(kind, "guarded", codec=inner.codec, D=inner.D,
                        inner=inner)
    for family in ("dist_auto", "dist_mixed", "auto", "mixed"):
        if kind.startswith(family + ":"):
            return KindSpec(kind, family,
                            budget=_parse_budget(kind[len(family) + 1:],
                                                 kind))
    for family in ("packsell", "plan", "dist"):
        if kind.startswith(family + "_"):
            codec, D = _parse_codec(kind[len(family) + 1:], kind)
            return KindSpec(kind, family, codec=codec, D=D)
    raise ValueError(
        f"unknown operator kind {kind!r}; valid kinds: {KIND_MENU}")


@dataclasses.dataclass
class OperatorSet:
    """All precision variants of one (scaled) matrix, built lazily.

    ``store`` — an optional :class:`~repro.precision.store.PrecisionStore`
    (or path) every budget-driven kind consults, including the per-shard
    fingerprint lookups of ``dist_auto:<budget>``."""

    csr: sp.csr_matrix
    C: int = 32
    sigma: int = 256
    store: object = None
    _cache: dict = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.csr.shape[0]

    def diag(self) -> np.ndarray:
        return self.csr.diagonal()

    # -- adaptive-precision hooks (repro.precision; DESIGN.md §8) ----------
    def precision_plan(self, error_budget: float, *, mode: str = "global",
                       store=None, **select_kw):
        """Budget → :class:`~repro.precision.select.PrecisionPlan` for this
        matrix (cached per (budget, mode)). ``store`` — a
        :class:`~repro.precision.store.PrecisionStore` (or path) — skips
        re-analysis across restarts."""
        from repro import precision as pr

        store = store if store is not None else self.store
        key = ("pplan", error_budget, mode,
               None if store is None else getattr(store, "path", store),
               tuple(sorted(select_kw.items())))
        if key in self._cache:
            return self._cache[key]
        if store is not None:
            store = pr.PrecisionStore.coerce(store)
            plan, _ = store.lookup_or_select(self.csr, error_budget,
                                             mode=mode, sigma=self.sigma,
                                             **select_kw)
        else:
            plan = pr.select_codec(self.csr, error_budget, mode=mode,
                                   sigma=self.sigma, **select_kw)
        self._cache[key] = plan
        return plan

    def adaptive_tiers(self, error_budget: float, *, store=None,
                       **select_kw):
        """The ``adaptive_pcg`` inputs for this matrix at a budget:
        ``(matvecs, labels, sub32_mask, matvec_hi)`` over the plan's tier
        ladder. ``matvec_hi`` is the FP64 operator for the outer
        (true-residual) recomputation — iterative refinement recovers the
        OUTER precision, so the 1e-8 criterion needs it even though every
        inner tier stays sub-32-bit."""
        from repro.precision import select as psel

        plan = self.precision_plan(error_budget, store=store, **select_kw)
        mvs, labels, sub32 = psel.build_tier_matvecs(
            self, psel.tier_ladder(plan))
        return mvs, labels, sub32, self.matvec("fp64")

    def dist_adaptive_tiers(self, error_budget: float, *,
                            n_shards: int | None = None, mesh=None,
                            exchange: str = "ppermute", store=None,
                            **select_kw):
        """The SAME tier ladder as :meth:`adaptive_tiers`, materialized as
        a :class:`~repro.distributed.plan.DistTierLadder` for
        ``cg.adaptive_pcg_dist`` — per-tier stacked member sets over one
        shared partition plus the exact fp64 outer operator. Identical
        ladder ⇒ the distributed solve reproduces the single-device
        iteration and promotion schedule."""
        from repro.distributed import build_dist_tiers
        from repro.precision import select as psel

        plan = self.precision_plan(error_budget, store=store, **select_kw)
        return build_dist_tiers(self.csr, psel.tier_ladder(plan),
                                n_shards=n_shards, mesh=mesh,
                                exchange=exchange, C=self.C,
                                sigma=self.sigma)

    def matvec(self, kind: str) -> Matvec:
        """kind: any entry of :data:`KIND_MENU` — dense SELL dtypes, the
        per-call ``packsell_`` path, the cached single-dispatch ``plan_``
        engine, the shard_map ``dist_`` engine (global vectors in/out, so
        it drops into any solver unchanged), budget-driven ``auto:`` /
        ``mixed:`` selection (global /
        :class:`~repro.precision.mixed.MixedPackSELL` per-row-class), and
        their distributed compositions ``dist_auto:`` (per-shard
        fingerprinted selection coalesced to one fleet codec) and
        ``dist_mixed:`` (per-shard per-class composite members — the
        distributed × mixed-precision operator)."""
        if kind in self._cache:
            return self._cache[kind][0]
        spec = parse_kind(kind)
        if spec.family == "dense":
            dtype = {"fp64": "float64", "fp32": "float32",
                     "fp16": "float16", "bf16": "bfloat16"}[spec.codec]
            mat = sl.from_csr(self.csr, C=self.C, sigma=self.sigma,
                              value_dtype=dtype)
            comp = jnp.float64 if spec.codec == "fp64" else jnp.float32
            fn = lambda x, mat=mat, comp=comp: sl.sell_spmv_jnp(mat, x, comp)
        elif spec.family == "packsell":
            mat = pk.from_csr(self.csr, C=self.C, sigma=self.sigma,
                              D=spec.D, codec=spec.codec)
            fn = lambda x, mat=mat: pk.packsell_spmv_jnp(mat, x, jnp.float32)
        elif spec.family == "plan":
            mat = pk.from_csr(self.csr, C=self.C, sigma=self.sigma,
                              D=spec.D, codec=spec.codec)
            p = kplan.get_plan(mat)
            fn = lambda x, mat=mat, p=p: p.spmv(mat, x)
        elif spec.family == "dist":
            from repro.distributed import build_dist_plan
            mat = build_dist_plan(self.csr, C=self.C, sigma=self.sigma,
                                  D=spec.D, codec=spec.codec)
            fn = lambda x, dp=mat: dp.spmv(x)
        elif spec.family == "csr64":
            mat = sps.csr_from_scipy(self.csr, "float64")
            fn = lambda x, mat=mat: mat.spmv(x, jnp.float64)
        elif spec.family == "guarded":
            # 'guarded:plan_<codec>' — the inner plan engine with the ABFT
            # checksum guard run on every host-level call. Tracers pass
            # through unguarded (inside jit the caller owns detection);
            # tripped calls mark the plan unhealthy and count in
            # ``fn.trips()``. ``fn.guard`` / ``fn.pair`` expose the
            # GuardState and (mat, plan) for solvers and tests.
            from repro.robust import guard as gd
            mat, p = self.plan_pair(spec.inner.raw)
            gs = gd.build_guard(mat, p)
            state = {"trips": 0}

            def fn(x, mat=mat, p=p, gs=gs, state=state):
                if isinstance(x, jax.core.Tracer):
                    return p.spmv(mat, x)
                y, ok, _ = gd.guarded_spmv(mat, p, gs, x)
                if not bool(ok):
                    state["trips"] += 1
                    gd.mark_unhealthy(p, "guard_trip")
                return y

            fn.guard = gs
            fn.pair = (mat, p)
            fn.trips = lambda state=state: state["trips"]
        elif spec.family == "auto":
            # budget-driven global selection ('auto:1e-3') — delegates to
            # the selected codec's plan_ kind (or fp32 fallback)
            from repro.precision import select as psel
            plan = self.precision_plan(spec.budget)
            fn = self.matvec(psel.operator_kind(plan.primary))
            mat = self._cache[psel.operator_kind(plan.primary)][1]
        elif spec.family == "mixed":
            # budget-driven per-row-class selection ('mixed:1e-3') — a
            # MixedPackSELL composite operator
            from repro import precision as pr
            plan = self.precision_plan(spec.budget, mode="rows")
            mat = pr.MixedPackSELL(self.csr, plan, C=self.C,
                                   sigma=self.sigma)
            fn = mat.spmv
        elif spec.family == "dist_auto":
            # per-shard fingerprinted selection, coalesced to the most
            # conservative fleet codec (SPMD dispatch needs ONE program)
            from repro.distributed import build_dist_plan
            from repro.precision.store import select_codec_per_shard
            _, fleet = select_codec_per_shard(
                self.csr, self._dist_shards(), spec.budget,
                store=self.store, sigma=self.sigma)
            mat = build_dist_plan(self.csr, C=self.C, sigma=self.sigma,
                                  classes=[(fleet.codec, fleet.D, None)])
            fn = lambda x, dp=mat: dp.spmv(x)
        elif spec.family == "dist_mixed":
            # distributed × mixed: per-shard per-class composite members
            from repro.distributed import build_dist_plan
            plan = self.precision_plan(spec.budget, mode="rows")
            mat = build_dist_plan(self.csr, C=self.C, sigma=self.sigma,
                                  pplan=plan)
            fn = lambda x, dp=mat: dp.spmv(x)
        else:  # pragma: no cover — parse_kind is exhaustive
            raise ValueError(kind)
        self._cache[kind] = (fn, mat)
        return fn

    @staticmethod
    def _dist_shards() -> int:
        import jax
        return jax.device_count()

    def stored(self, kind: str):
        """The underlying format object (for memory stats)."""
        self.matvec(kind)
        return self._cache[kind][1]

    def plan_pair(self, kind: str):
        """(mat, plan) for a 'plan_<codec>' kind — the inputs the
        stored-row-order solvers (cg.jacobi_pcg_stored) consume."""
        if parse_kind(kind).family != "plan":
            raise ValueError(
                f"{kind!r} is not a plan_ kind (valid: plan_<codec> with "
                f"<codec>: fp16 | bf16 | e8m<D>)")
        self.matvec(kind)
        mat = self._cache[kind][1]
        return mat, kplan.get_plan(mat)

    def dist_plan(self, kind: str):
        """The :class:`~repro.distributed.plan.DistSpMVPlan` behind any
        distributed kind (``dist_<codec>`` / ``dist_auto:<b>`` /
        ``dist_mixed:<b>``) — what ``cg.jacobi_pcg_dist`` consumes."""
        if not parse_kind(kind).distributed:
            raise ValueError(
                f"{kind!r} is not a distributed kind (valid: dist_<codec> "
                f"| dist_auto:<budget> | dist_mixed:<budget>)")
        self.matvec(kind)
        return self._cache[kind][1]
