"""Linear operators over the sparse formats, with per-precision variants.

The paper's solvers (§5.2) mix SpMV precisions inside one Krylov hierarchy:
an FP64 operator for the outer iteration and FP16 / E8MY PackSELL operators
inside. ``OperatorSet`` builds all requested variants of one matrix once and
hands out matvec callables; solvers are written against plain callables so
any format/precision combination plugs in.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core import sparse as sps
from repro.kernels import plan as kplan

Matvec = Callable[[jnp.ndarray], jnp.ndarray]


def row_scale(a: sp.csr_matrix) -> tuple[sp.csr_matrix, np.ndarray]:
    """G^{-1} A with g_i = sum_j |a_ij| (paper §5.1.2 scaling for SpMV)."""
    g = np.asarray(np.abs(a).sum(axis=1)).ravel()
    g = np.where(g == 0, 1.0, g)
    return sp.diags(1.0 / g) @ a, g


def sym_scale(a: sp.csr_matrix) -> tuple[sp.csr_matrix, np.ndarray]:
    """Ḡ^{-1} A Ḡ^{-1} with ḡ_i = sqrt(|a_ii|) (paper §5.2 scaling)."""
    d = np.sqrt(np.abs(a.diagonal()))
    d = np.where(d == 0, 1.0, d)
    dinv = sp.diags(1.0 / d)
    s = (dinv @ a @ dinv).tocsr()
    s.sort_indices()
    return s, d


@dataclasses.dataclass
class OperatorSet:
    """All precision variants of one (scaled) matrix, built lazily."""

    csr: sp.csr_matrix
    C: int = 32
    sigma: int = 256
    _cache: dict = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.csr.shape[0]

    def diag(self) -> np.ndarray:
        return self.csr.diagonal()

    @staticmethod
    def _parse_codec(sub: str) -> tuple[str, int]:
        if sub in ("fp16", "bf16"):
            return sub, 15
        if sub.startswith("e8m"):
            # *_e8mD where D is the *delta* width (Y = 22 - D)
            return "e8m", int(sub[3:])
        raise ValueError(sub)

    def matvec(self, kind: str) -> Matvec:
        """kind: 'fp64' | 'fp32' | 'fp16' | 'bf16' | 'packsell_fp16' |
        'packsell_bf16' | 'packsell_e8m<D>' (e.g. packsell_e8m8) |
        'plan_<codec>' (same codecs, dispatched through the cached
        :class:`~repro.kernels.plan.SpMVPlan` engine — the single-dispatch
        hot path for Krylov inner loops) | 'dist_<codec>' (same codecs,
        partitioned over every visible device and dispatched through a
        :class:`~repro.distributed.plan.DistSpMVPlan` shard_map; global
        vectors in/out, so it drops into any solver unchanged)."""
        if kind in self._cache:
            return self._cache[kind][0]
        if kind in ("fp64", "fp32", "fp16", "bf16"):
            dtype = {"fp64": "float64", "fp32": "float32", "fp16": "float16",
                     "bf16": "bfloat16"}[kind]
            mat = sl.from_csr(self.csr, C=self.C, sigma=self.sigma,
                              value_dtype=dtype)
            comp = jnp.float64 if kind == "fp64" else jnp.float32
            fn = lambda x, mat=mat, comp=comp: sl.sell_spmv_jnp(mat, x, comp)
        elif kind.startswith("packsell_"):
            codec, D = self._parse_codec(kind[len("packsell_"):])
            mat = pk.from_csr(self.csr, C=self.C, sigma=self.sigma, D=D,
                              codec=codec)
            fn = lambda x, mat=mat: pk.packsell_spmv_jnp(mat, x, jnp.float32)
        elif kind.startswith("plan_"):
            codec, D = self._parse_codec(kind[len("plan_"):])
            mat = pk.from_csr(self.csr, C=self.C, sigma=self.sigma, D=D,
                              codec=codec)
            p = kplan.get_plan(mat)
            fn = lambda x, mat=mat, p=p: p.spmv(mat, x)
        elif kind.startswith("dist_"):
            from repro.distributed import build_dist_plan
            codec, D = self._parse_codec(kind[len("dist_"):])
            mat = build_dist_plan(self.csr, C=self.C, sigma=self.sigma,
                                  D=D, codec=codec)
            fn = lambda x, dp=mat: dp.spmv(x)
        elif kind == "csr64":
            mat = sps.csr_from_scipy(self.csr, "float64")
            fn = lambda x, mat=mat: mat.spmv(x, jnp.float64)
        else:
            raise ValueError(kind)
        self._cache[kind] = (fn, mat)
        return fn

    def stored(self, kind: str):
        """The underlying format object (for memory stats)."""
        self.matvec(kind)
        return self._cache[kind][1]

    def plan_pair(self, kind: str):
        """(mat, plan) for a 'plan_<codec>' kind — the inputs the
        stored-row-order solvers (cg.jacobi_pcg_stored) consume."""
        if not kind.startswith("plan_"):
            raise ValueError(f"{kind!r} is not a plan_ kind")
        self.matvec(kind)
        mat = self._cache[kind][1]
        return mat, kplan.get_plan(mat)

    def dist_plan(self, kind: str):
        """The :class:`~repro.distributed.plan.DistSpMVPlan` behind a
        'dist_<codec>' kind — what ``cg.jacobi_pcg_dist`` consumes."""
        if not kind.startswith("dist_"):
            raise ValueError(f"{kind!r} is not a dist_ kind")
        self.matvec(kind)
        return self._cache[kind][1]
