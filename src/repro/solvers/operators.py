"""Linear operators over the sparse formats, with per-precision variants.

The paper's solvers (§5.2) mix SpMV precisions inside one Krylov hierarchy:
an FP64 operator for the outer iteration and FP16 / E8MY PackSELL operators
inside. ``OperatorSet`` builds all requested variants of one matrix once and
hands out matvec callables; solvers are written against plain callables so
any format/precision combination plugs in.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core import sparse as sps
from repro.kernels import plan as kplan

Matvec = Callable[[jnp.ndarray], jnp.ndarray]


def row_scale(a: sp.csr_matrix) -> tuple[sp.csr_matrix, np.ndarray]:
    """G^{-1} A with g_i = sum_j |a_ij| (paper §5.1.2 scaling for SpMV)."""
    g = np.asarray(np.abs(a).sum(axis=1)).ravel()
    g = np.where(g == 0, 1.0, g)
    return sp.diags(1.0 / g) @ a, g


def sym_scale(a: sp.csr_matrix) -> tuple[sp.csr_matrix, np.ndarray]:
    """Ḡ^{-1} A Ḡ^{-1} with ḡ_i = sqrt(|a_ii|) (paper §5.2 scaling)."""
    d = np.sqrt(np.abs(a.diagonal()))
    d = np.where(d == 0, 1.0, d)
    dinv = sp.diags(1.0 / d)
    s = (dinv @ a @ dinv).tocsr()
    s.sort_indices()
    return s, d


@dataclasses.dataclass
class OperatorSet:
    """All precision variants of one (scaled) matrix, built lazily."""

    csr: sp.csr_matrix
    C: int = 32
    sigma: int = 256
    _cache: dict = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.csr.shape[0]

    def diag(self) -> np.ndarray:
        return self.csr.diagonal()

    @staticmethod
    def _parse_codec(sub: str) -> tuple[str, int]:
        if sub in ("fp16", "bf16"):
            return sub, 15
        if sub.startswith("e8m"):
            # *_e8mD where D is the *delta* width (Y = 22 - D)
            return "e8m", int(sub[3:])
        raise ValueError(sub)

    # -- adaptive-precision hooks (repro.precision; DESIGN.md §8) ----------
    def precision_plan(self, error_budget: float, *, mode: str = "global",
                       store=None, **select_kw):
        """Budget → :class:`~repro.precision.select.PrecisionPlan` for this
        matrix (cached per (budget, mode)). ``store`` — a
        :class:`~repro.precision.store.PrecisionStore` (or path) — skips
        re-analysis across restarts."""
        from repro import precision as pr

        key = ("pplan", error_budget, mode,
               None if store is None else getattr(store, "path", store),
               tuple(sorted(select_kw.items())))
        if key in self._cache:
            return self._cache[key]
        if store is not None:
            store = pr.PrecisionStore.coerce(store)
            plan, _ = store.lookup_or_select(self.csr, error_budget,
                                             mode=mode, sigma=self.sigma,
                                             **select_kw)
        else:
            plan = pr.select_codec(self.csr, error_budget, mode=mode,
                                   sigma=self.sigma, **select_kw)
        self._cache[key] = plan
        return plan

    def adaptive_tiers(self, error_budget: float, *, store=None,
                       **select_kw):
        """The ``adaptive_pcg`` inputs for this matrix at a budget:
        ``(matvecs, labels, sub32_mask, matvec_hi)`` over the plan's tier
        ladder. ``matvec_hi`` is the FP64 operator for the outer
        (true-residual) recomputation — iterative refinement recovers the
        OUTER precision, so the 1e-8 criterion needs it even though every
        inner tier stays sub-32-bit."""
        from repro.precision import select as psel

        plan = self.precision_plan(error_budget, store=store, **select_kw)
        mvs, labels, sub32 = psel.build_tier_matvecs(
            self, psel.tier_ladder(plan))
        return mvs, labels, sub32, self.matvec("fp64")

    def matvec(self, kind: str) -> Matvec:
        """kind: 'fp64' | 'fp32' | 'fp16' | 'bf16' | 'packsell_fp16' |
        'packsell_bf16' | 'packsell_e8m<D>' (e.g. packsell_e8m8) |
        'plan_<codec>' (same codecs, dispatched through the cached
        :class:`~repro.kernels.plan.SpMVPlan` engine — the single-dispatch
        hot path for Krylov inner loops) | 'dist_<codec>' (same codecs,
        partitioned over every visible device and dispatched through a
        :class:`~repro.distributed.plan.DistSpMVPlan` shard_map; global
        vectors in/out, so it drops into any solver unchanged) |
        'auto:<budget>' (adaptive: ``repro.precision`` selects the codec
        for the error budget, e.g. auto:1e-3) | 'mixed:<budget>'
        (per-row-class selection composed as one
        :class:`~repro.precision.mixed.MixedPackSELL` operator)."""
        if kind in self._cache:
            return self._cache[kind][0]
        if kind in ("fp64", "fp32", "fp16", "bf16"):
            dtype = {"fp64": "float64", "fp32": "float32", "fp16": "float16",
                     "bf16": "bfloat16"}[kind]
            mat = sl.from_csr(self.csr, C=self.C, sigma=self.sigma,
                              value_dtype=dtype)
            comp = jnp.float64 if kind == "fp64" else jnp.float32
            fn = lambda x, mat=mat, comp=comp: sl.sell_spmv_jnp(mat, x, comp)
        elif kind.startswith("packsell_"):
            codec, D = self._parse_codec(kind[len("packsell_"):])
            mat = pk.from_csr(self.csr, C=self.C, sigma=self.sigma, D=D,
                              codec=codec)
            fn = lambda x, mat=mat: pk.packsell_spmv_jnp(mat, x, jnp.float32)
        elif kind.startswith("plan_"):
            codec, D = self._parse_codec(kind[len("plan_"):])
            mat = pk.from_csr(self.csr, C=self.C, sigma=self.sigma, D=D,
                              codec=codec)
            p = kplan.get_plan(mat)
            fn = lambda x, mat=mat, p=p: p.spmv(mat, x)
        elif kind.startswith("dist_"):
            from repro.distributed import build_dist_plan
            codec, D = self._parse_codec(kind[len("dist_"):])
            mat = build_dist_plan(self.csr, C=self.C, sigma=self.sigma,
                                  D=D, codec=codec)
            fn = lambda x, dp=mat: dp.spmv(x)
        elif kind == "csr64":
            mat = sps.csr_from_scipy(self.csr, "float64")
            fn = lambda x, mat=mat: mat.spmv(x, jnp.float64)
        elif kind.startswith("auto:"):
            # budget-driven global selection ('auto:1e-3') — delegates to
            # the selected codec's plan_ kind (or fp32 fallback)
            from repro.precision import select as psel
            plan = self.precision_plan(float(kind[len("auto:"):]))
            fn = self.matvec(psel.operator_kind(plan.primary))
            mat = self._cache[psel.operator_kind(plan.primary)][1]
        elif kind.startswith("mixed:"):
            # budget-driven per-row-class selection ('mixed:1e-3') — a
            # MixedPackSELL composite operator
            from repro import precision as pr
            plan = self.precision_plan(float(kind[len("mixed:"):]),
                                       mode="rows")
            mat = pr.MixedPackSELL(self.csr, plan, C=self.C,
                                   sigma=self.sigma)
            fn = mat.spmv
        else:
            raise ValueError(kind)
        self._cache[kind] = (fn, mat)
        return fn

    def stored(self, kind: str):
        """The underlying format object (for memory stats)."""
        self.matvec(kind)
        return self._cache[kind][1]

    def plan_pair(self, kind: str):
        """(mat, plan) for a 'plan_<codec>' kind — the inputs the
        stored-row-order solvers (cg.jacobi_pcg_stored) consume."""
        if not kind.startswith("plan_"):
            raise ValueError(f"{kind!r} is not a plan_ kind")
        self.matvec(kind)
        mat = self._cache[kind][1]
        return mat, kplan.get_plan(mat)

    def dist_plan(self, kind: str):
        """The :class:`~repro.distributed.plan.DistSpMVPlan` behind a
        'dist_<codec>' kind — what ``cg.jacobi_pcg_dist`` consumes."""
        if not kind.startswith("dist_"):
            raise ValueError(f"{kind!r} is not a dist_ kind")
        self.matvec(kind)
        return self._cache[kind][1]
