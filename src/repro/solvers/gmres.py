"""Restarted (flexible) GMRES with modified Gram-Schmidt Arnoldi.

FGMRES stores the preconditioned basis Z so the preconditioner may itself be
an inner Krylov solve — the building block of the paper's F3R hierarchy.
Fully jit-compatible: the Arnoldi cycle is a fori_loop with masked MGS, the
restart loop is a while_loop.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .cg import SolveInfo

Matvec = Callable[[jnp.ndarray], jnp.ndarray]

_EPS = 1e-30


def _fgmres_cycle(matvec: Matvec, M: Matvec, b, x, m: int, dtype):
    """One FGMRES(m) cycle from iterate x. Returns (x_new, relres_est)."""
    n = b.shape[0]
    r = b - matvec(x).astype(dtype)
    beta = jnp.linalg.norm(r)
    V = jnp.zeros((m + 1, n), dtype=dtype).at[0].set(
        r / jnp.where(beta == 0, 1.0, beta))
    Z = jnp.zeros((m, n), dtype=dtype)
    H = jnp.zeros((m + 1, m), dtype=dtype)

    def arnoldi(j, carry):
        V, Z, H = carry
        z = M(V[j]).astype(dtype)
        w = matvec(z).astype(dtype)
        # masked modified Gram-Schmidt against v_0..v_j
        mask = (jnp.arange(m + 1) <= j).astype(dtype)
        h = (V @ w) * mask                      # [m+1]
        w = w - V.T @ h
        # single reorthogonalization pass (cheap, stabilizes fp32 layers)
        h2 = (V @ w) * mask
        w = w - V.T @ h2
        h = h + h2
        hnext = jnp.linalg.norm(w)
        V = V.at[j + 1].set(w / jnp.where(hnext < _EPS, 1.0, hnext))
        H = H.at[:, j].set(h).at[j + 1, j].set(hnext)
        Z = Z.at[j].set(z)
        return V, Z, H

    V, Z, H = jax.lax.fori_loop(0, m, arnoldi, (V, Z, H))
    e1 = jnp.zeros((m + 1,), dtype=dtype).at[0].set(beta)
    y, *_ = jnp.linalg.lstsq(H, e1)
    x_new = x + Z.T @ y
    res = jnp.linalg.norm(e1 - H @ y)
    return x_new, res


def fgmres(matvec: Matvec, b: jnp.ndarray, *, M: Matvec | None = None,
           m: int = 30, tol: float = 1e-9, max_cycles: int = 100, x0=None,
           dtype=None) -> tuple[jnp.ndarray, SolveInfo]:
    dtype = dtype or b.dtype
    b = b.astype(dtype)
    x0 = jnp.zeros_like(b) if x0 is None else x0.astype(dtype)
    M = M or (lambda r: r)
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)
    hdtype = jnp.float64 if dtype == jnp.float64 else jnp.float32
    hist0 = jnp.full((max_cycles + 1,), -1.0, dtype=hdtype)
    r0 = jnp.linalg.norm(b - matvec(x0).astype(dtype)) / bnorm
    hist0 = hist0.at[0].set(r0.astype(hdtype))

    def cond(s):
        k, x, hist, relres = s
        return jnp.logical_and(k < max_cycles, relres >= tol)

    def body(s):
        k, x, hist, _ = s
        x, res = _fgmres_cycle(matvec, M, b, x, m, dtype)
        relres = (res / bnorm).astype(dtype)
        hist = hist.at[k + 1].set(relres.astype(hdtype))
        return (k + 1, x, hist, relres)

    s0 = (jnp.asarray(0), x0, hist0, r0.astype(dtype))
    k, x, hist, relres = jax.lax.while_loop(cond, body, s0)
    return x, SolveInfo(k, relres, hist)


def fgmres_fixed_cycles(matvec: Matvec, M: Matvec, m: int, cycles: int = 1,
                        dtype=jnp.float32) -> Matvec:
    """FGMRES(m) × cycles from x0 = 0, packaged as a (flexible)
    preconditioner — the middle layers of F3R."""

    def apply(rhs: jnp.ndarray) -> jnp.ndarray:
        b = rhs.astype(dtype)
        x = jnp.zeros_like(b)
        for _ in range(cycles):
            x, _ = _fgmres_cycle(matvec, M, b, x, m, dtype)
        return x

    return apply
