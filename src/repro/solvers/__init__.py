"""Mixed-precision Krylov solvers built on the PackSELL SpMV substrate."""
from . import cg, f3r, gmres, iocg, operators, precond, richardson  # noqa: F401
from .cg import adaptive_pcg, fcg, pcg, pcg_fixed_iters  # noqa: F401
from .gmres import fgmres, fgmres_fixed_cycles  # noqa: F401
from .operators import OperatorSet, row_scale, sym_scale  # noqa: F401
