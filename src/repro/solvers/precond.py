"""Preconditioners applied via SpMV-style kernels.

The paper uses SD-AINV (a sparse approximate inverse, applied as SpMV). That
exact factorization is external to the paper; we implement the same *role* —
an approximate inverse whose application is a small fixed number of SpMV
calls — as a truncated scaled Neumann series (documented divergence,
DESIGN.md §6):

    M r = sum_{k=0}^{K-1} (I - D^{-1} A)^k D^{-1} r

evaluated by the Jacobi-style recurrence ``z <- D^{-1} r + (I - D^{-1}A) z``,
so every application is K-1 SpMVs in whatever precision the supplied matvec
uses (FP16 PackSELL inside F3R, exactly like the paper's inner layers).
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

Matvec = Callable[[jnp.ndarray], jnp.ndarray]


def identity() -> Matvec:
    return lambda r: r


def jacobi(diag: np.ndarray, dtype=jnp.float32) -> Matvec:
    dinv = jnp.asarray(np.where(diag == 0, 1.0, 1.0 / diag), dtype=dtype)
    return lambda r: dinv * r.astype(dtype)


def neumann_ainv(diag: np.ndarray, matvec: Matvec, k: int = 2,
                 dtype=jnp.float32) -> Matvec:
    """Truncated Neumann approximate inverse (SD-AINV role), K SpMV terms."""
    dinv = jnp.asarray(np.where(diag == 0, 1.0, 1.0 / diag), dtype=dtype)

    def apply(r: jnp.ndarray) -> jnp.ndarray:
        r = r.astype(dtype)
        z = dinv * r
        for _ in range(k - 1):
            z = z + dinv * (r - matvec(z).astype(dtype))
        return z

    return apply
