"""F3R: the FP16-enabled nested Krylov solver of Suzuki & Iwashita (2025),
reproduced at the structure level the PackSELL paper relies on (§5.2.1):

    L1  FGMRES            — FP64 SpMV, convergence-controlling outer loop
    L2  FGMRES (fixed)    — FP32 SpMV, preconditioner of L1
    L3  FGMRES (fixed)    — **FP16 SpMV** (SELL or PackSELL), preconditioner of L2
    L4  Richardson (fixed)— **FP16 SpMV** + approximate-inverse preconditioner

The two inner layers (L3 + L4) execute the overwhelming majority of SpMVs
(>85% under these defaults, matching the paper's observation), so swapping
their SpMV between SELL-FP16 and PackSELL-FP16 measures exactly what the
paper's Fig. 10 measures. Exact F3R hyper-parameters are not given in the
PackSELL paper; defaults below are documented assumptions (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from . import precond
from .cg import SolveInfo
from .gmres import fgmres, fgmres_fixed_cycles
from .operators import OperatorSet
from .richardson import richardson_fixed_iters

Matvec = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass
class F3RConfig:
    m_outer: int = 20         # L1 restart length
    m_mid: int = 10           # L2 Arnoldi steps per application
    m_inner: int = 5          # L3 Arnoldi steps per application
    richardson_iters: int = 4  # L4
    ainv_terms: int = 2        # Neumann terms in the SD-AINV-role precond
    tol: float = 1e-9
    max_cycles: int = 200
    # SpMV kinds per layer ('fp64'/'fp32'/'fp16'/'packsell_fp16'/...)
    spmv_outer: str = "fp64"
    spmv_mid: str = "fp32"
    spmv_inner: str = "fp16"


def presets(variant: str) -> F3RConfig:
    """The paper's three F3R builds (§5.2.1)."""
    if variant == "fp64":          # FP64-F3R
        return F3RConfig(spmv_outer="fp64", spmv_mid="fp64", spmv_inner="fp64")
    if variant == "fp16":          # FP16-F3R (SELL fp16 inner SpMV)
        return F3RConfig(spmv_inner="fp16")
    if variant == "packsell":      # PackSELL-F3R (V=16, D=15 fp16 embed)
        return F3RConfig(spmv_inner="packsell_fp16")
    raise ValueError(variant)


def solve(ops: OperatorSet, b: jnp.ndarray,
          config: F3RConfig) -> tuple[jnp.ndarray, SolveInfo]:
    diag = ops.diag()
    A64 = ops.matvec(config.spmv_outer)
    A32 = ops.matvec(config.spmv_mid)
    A16 = ops.matvec(config.spmv_inner)

    ainv = precond.neumann_ainv(diag, A16, k=config.ainv_terms,
                                dtype=jnp.float32)
    l4 = richardson_fixed_iters(A16, ainv, config.richardson_iters,
                                dtype=jnp.float32)
    l3 = fgmres_fixed_cycles(A16, l4, m=config.m_inner, dtype=jnp.float32)
    l2 = fgmres_fixed_cycles(A32, l3, m=config.m_mid, dtype=jnp.float32)
    return fgmres(A64, b, M=l2, m=config.m_outer, tol=config.tol,
                  max_cycles=config.max_cycles, dtype=b.dtype)
