"""CG / PCG / flexible CG (Notay 2000), jit-compatible with residual history.

Convergence criterion matches the paper's eq. (6): ||b - A x||_2 / ||b||_2 <
tol, tracked via the CG recurrence residual (benchmarks re-verify the true
residual afterwards).

Fused solver step (DESIGN.md §10.4): ``pcg`` / ``adaptive_pcg`` accept a
``jit_cache``/``jit_key`` pair that compiles the ENTIRE solve — setup
(initial residual, preconditioned direction, norms), the ``while_loop``
recurrence (matvec + α/β axpys + residual dot in one loop body) and the
epilogue — into one cached jitted, buffer-donating dispatch, so repeated
solves pay zero per-call tracing and zero intermediate host round-trips.
``jacobi_pcg_stored`` parks its fused solve on the plan's function cache
automatically. The computation graph is identical to the uncached path, so
iteration counts (and bits) are unchanged.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import observe as _obs

Matvec = Callable[[jnp.ndarray], jnp.ndarray]


def _donate(*argnums) -> tuple:
    """Donation argnums, except on CPU where XLA cannot alias the buffers
    and jit would warn on every call."""
    return argnums if jax.default_backend() != "cpu" else ()


class SolveInfo(NamedTuple):
    iters: jnp.ndarray       # iterations executed
    relres: jnp.ndarray      # final relative residual (recurrence)
    history: jnp.ndarray     # relres per iteration, -1 past convergence


class AdaptiveSolveInfo(NamedTuple):
    """Outcome of :func:`adaptive_pcg` (all device scalars/arrays)."""

    iters: jnp.ndarray         # outer (refinement) steps executed
    relres: jnp.ndarray        # final TRUE relative residual ||b-Ax||/||b||
    history: jnp.ndarray       # true relres per outer step, -1 past end
    tier_history: jnp.ndarray  # int32 tier used per outer step, -1 past end
    promotions: jnp.ndarray    # number of codec-tier promotions
    tier_matvecs: jnp.ndarray  # int32[n_tiers] inner matvecs per tier
    hi_matvecs: jnp.ndarray    # high-precision (residual) matvecs


def dist_dot(axis_name: str):
    """⟨a, b⟩ over a device mesh axis: the local partial reduces with a
    ``psum`` so every shard holds the identical global scalar (vectors are
    real; σ/shard padding slots must be zero — the distributed layer's
    row-mask invariant guarantees it for its vectors)."""
    return lambda a, b: jax.lax.psum(jnp.vdot(a, b), axis_name)


def dist_norm(axis_name: str):
    """‖a‖₂ over a device mesh axis (psum of local squared sums)."""
    return lambda a: jnp.sqrt(jax.lax.psum(jnp.sum(a * a), axis_name))


def _prep(b, x0, dtype, norm):
    dtype = dtype or b.dtype
    b = b.astype(dtype)
    x0 = jnp.zeros_like(b) if x0 is None else x0.astype(dtype)
    bnorm = norm(b)
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)
    return b, x0, bnorm, dtype


def pcg(matvec: Matvec, b: jnp.ndarray, *, M: Matvec | None = None,
        tol: float = 1e-9, maxiter: int = 1000, x0=None,
        dtype=None, dot=None, norm=None, jit_cache: dict | None = None,
        jit_key=None) -> tuple[jnp.ndarray, SolveInfo]:
    """Preconditioned CG. ``M`` must be a *fixed* operator (SPD).

    ``dot`` / ``norm`` default to the single-device ``jnp.vdot`` /
    ``jnp.linalg.norm``; the distributed solvers inject psum-reduced
    versions (:func:`dist_dot` / :func:`dist_norm`) so the identical
    iteration runs on sharded vectors inside a shard_map region — the
    recurrence, and therefore the iteration count, is unchanged.

    ``jit_cache`` (any dict the caller owns, e.g. a plan's ``_fns``)
    compiles the whole solve once per ``(jit_key, tol, maxiter, shape,
    dtype)`` into a single buffer-donating dispatch — the fused solver
    step. The caller must guarantee ``jit_key`` uniquely identifies the
    ``matvec``/``M``/``dot``/``norm`` closures it passes.
    """
    if jit_cache is not None and not isinstance(b, jax.core.Tracer):
        b = jnp.asarray(b)
        sdtype = jnp.dtype(dtype or b.dtype)
        key = ("pcg", jit_key, float(tol), int(maxiter), b.shape,
               sdtype.name)
        fn = jit_cache.get(key)
        if fn is None:
            fn = jax.jit(
                lambda b, x0: pcg(matvec, b, M=M, tol=tol, maxiter=maxiter,
                                  x0=x0, dtype=dtype, dot=dot, norm=norm),
                donate_argnums=_donate(1))
            jit_cache[key] = fn
        # donation must never eat a caller-owned buffer: copy supplied x0
        x0 = (jnp.zeros(b.shape, sdtype) if x0 is None
              else jnp.array(x0, sdtype, copy=True))
        x, info = fn(b, x0)
        _obs.record_solve("pcg", info, path="jit_cache")
        return x, info

    dot = dot or jnp.vdot
    norm = norm or jnp.linalg.norm
    b, x0, bnorm, dtype = _prep(b, x0, dtype, norm)
    M = M or (lambda r: r)

    r0 = b - matvec(x0).astype(dtype)
    z0 = M(r0).astype(dtype)
    rz0 = dot(r0, z0)
    hist0 = jnp.full((maxiter + 1,), -1.0, dtype=jnp.float64 if
                     dtype == jnp.float64 else jnp.float32)
    hist0 = hist0.at[0].set(norm(r0) / bnorm)

    def cond(s):
        k, x, r, z, p, rz, hist, done = s
        return jnp.logical_and(k < maxiter, jnp.logical_not(done))

    def body(s):
        k, x, r, z, p, rz, hist, done = s
        Ap = matvec(p).astype(dtype)
        pAp = dot(p, Ap)
        alpha = rz / jnp.where(pAp == 0, 1.0, pAp)
        x = x + alpha * p
        r = r - alpha * Ap
        relres = norm(r) / bnorm
        hist = hist.at[k + 1].set(relres.astype(hist.dtype))
        done = relres < tol
        z = M(r).astype(dtype)
        rz_new = dot(r, z)
        beta = rz_new / jnp.where(rz == 0, 1.0, rz)
        p = z + beta * p
        return (k + 1, x, r, z, p, rz_new, hist, done)

    s0 = (jnp.asarray(0), x0, r0, z0, z0, rz0, hist0, jnp.asarray(False))
    with _obs.span("packsell.solver_while"):
        k, x, r, z, p, rz, hist, done = jax.lax.while_loop(cond, body, s0)
    info = SolveInfo(k, norm(r) / bnorm, hist)
    _obs.record_solve("pcg", info, path="eager")
    return x, info


def fcg(matvec: Matvec, b: jnp.ndarray, *, M: Matvec, tol: float = 1e-9,
        maxiter: int = 1000, x0=None,
        dtype=None) -> tuple[jnp.ndarray, SolveInfo]:
    """Flexible CG (Notay 2000), FCG(1): tolerates a varying preconditioner
    (e.g. an inner Krylov solve — the IO-CG outer iteration, paper §5.2.2)."""
    b, x0, bnorm, dtype = _prep(b, x0, dtype, jnp.linalg.norm)

    r0 = b - matvec(x0).astype(dtype)
    z0 = M(r0).astype(dtype)
    p0 = z0
    hist0 = jnp.full((maxiter + 1,), -1.0, dtype=jnp.float64 if
                     dtype == jnp.float64 else jnp.float32)
    hist0 = hist0.at[0].set(jnp.linalg.norm(r0) / bnorm)

    def cond(s):
        k, x, r, p, hist, done = s
        return jnp.logical_and(k < maxiter, jnp.logical_not(done))

    def body(s):
        k, x, r, p, hist, done = s
        Ap = matvec(p).astype(dtype)
        pAp = jnp.vdot(p, Ap)
        alpha = jnp.vdot(p, r) / jnp.where(pAp == 0, 1.0, pAp)
        x = x + alpha * p
        r = r - alpha * Ap
        relres = jnp.linalg.norm(r) / bnorm
        hist = hist.at[k + 1].set(relres.astype(hist.dtype))
        done = relres < tol
        z = M(r).astype(dtype)
        # one-step A-orthogonalization against the previous direction
        beta = jnp.vdot(z, Ap) / jnp.where(pAp == 0, 1.0, pAp)
        p = z - beta * p
        return (k + 1, x, r, p, hist, done)

    s0 = (jnp.asarray(0), x0, r0, p0, hist0, jnp.asarray(False))
    k, x, r, p, hist, done = jax.lax.while_loop(cond, body, s0)
    return x, SolveInfo(k, jnp.linalg.norm(r) / bnorm, hist)


def jacobi_pcg_stored(mat, plan, diag: jnp.ndarray, b: jnp.ndarray, *,
                      tol: float = 1e-9, maxiter: int = 1000,
                      dtype=None) -> tuple[jnp.ndarray, SolveInfo]:
    """Jacobi-PCG run entirely in σ-stored-row order (plan engine fast path).

    The operator is the symmetrically permuted ``P A Pᵀ`` (SPD iff A is):
    the matvec consumes the stored → original-order gather and the kernel's
    ``permuted=True`` output is already stored-row order — the σ-scatter
    epilogue is skipped on every iteration. The Jacobi preconditioner and
    the right-hand side are permuted ONCE at setup. σ-padding slots stay
    zero throughout, so stored-space dot products and norms equal their
    original-space values and the convergence criterion is unchanged.

    The WHOLE solve — permutation setup, the PCG ``while_loop`` (matvec +
    α/β axpys + residual dot), and the final unpermute — is one jitted,
    buffer-donating dispatch cached on the plan (DESIGN.md §10.4): the
    plan's device operands flow as arguments, repeated solves re-trace
    nothing, and the computation graph (hence the iteration count, bit for
    bit) matches the historical eager path.

    ``mat``/``plan``: a PackSELL matrix and its SpMVPlan (see
    ``OperatorSet.plan_pair``); ``diag``: the matrix diagonal in original
    row order.
    """
    from repro.kernels import plan as _kp

    diag = jnp.asarray(diag)
    b = jnp.asarray(b)
    if (plan.ephemeral or plan.inv_cat is None
            or isinstance(b, jax.core.Tracer)):
        # tracing / ephemeral fallback: same graph, no caching
        dinv = jnp.where(diag == 0, 1.0, 1.0 / diag)
        dinv_s = plan.to_stored(dinv.astype(b.dtype))
        b_s = plan.to_stored(b)

        def matvec_s(x_s):
            return plan.spmv(mat, plan.from_stored(x_s), permuted=True)

        x_s, info = pcg(matvec_s, b_s, M=lambda r: r * dinv_s, tol=tol,
                        maxiter=maxiter, dtype=dtype)
        _obs.record_solve("jacobi_pcg_stored", info, path="fallback")
        return plan.from_stored(x_s), info

    sdtype = jnp.dtype(dtype if dtype is not None else b.dtype)
    key = ("jpcg_stored", float(tol), int(maxiter), b.shape, sdtype.name)
    fn = plan._fns.get(key)
    if fn is None:
        def solve(mat_a, dev, diag_a, b_a, x0_s):
            dinv = jnp.where(diag_a == 0, 1.0, 1.0 / diag_a)
            dinv_s = _kp.stored_permute(dinv.astype(b_a.dtype),
                                        dev["outrow"], plan.n)
            b_s = _kp.stored_permute(b_a, dev["outrow"], plan.n)

            def matvec_s(x_s):
                return plan.execute_with(
                    mat_a, dev, _kp.stored_unpermute(x_s, dev["inv"]),
                    permuted=True)

            x_s, info = pcg(matvec_s, b_s, M=lambda r: r * dinv_s,
                            tol=tol, maxiter=maxiter, dtype=dtype,
                            x0=x0_s)
            return _kp.stored_unpermute(x_s, dev["inv"]), info

        fn = jax.jit(solve, donate_argnums=_donate(4))
        plan._fns[key] = fn
    x0_s = jnp.zeros((plan.total_stored,), sdtype)
    x, info = fn(mat, plan._device_operands(), diag, b, x0_s)
    _obs.record_solve("jacobi_pcg_stored", info, path="fused")
    return x, info


def jacobi_pcg_dist(dplan, diag: jnp.ndarray, b: jnp.ndarray, *,
                    tol: float = 1e-9, maxiter: int = 1000,
                    dtype=None, mode: str | None = None
                    ) -> tuple[jnp.ndarray, SolveInfo]:
    """Jacobi-PCG over a device mesh: the ENTIRE solve runs inside one
    jitted shard_map region.

    ``dplan`` is a :class:`~repro.distributed.plan.DistSpMVPlan`; each
    iteration's matvec is the per-shard halo-exchange SpMV body (local
    block overlapping the exchange, remote block on the gathered halo), and
    every dot/norm is psum-reduced (:func:`dist_dot` / :func:`dist_norm`) so
    all shards advance through the identical scalar recurrence — the
    iteration count matches the single-device solver up to summation-order
    rounding. Vectors stay sharded for the whole solve; only the final x
    (and the replicated scalars/history) come back to the host.

    ``diag``: matrix diagonal in global row order (the Jacobi
    preconditioner); ``b``: global right-hand side; ``mode`` overrides the
    plan's halo-exchange mode.
    """
    from jax.sharding import PartitionSpec as Pspec

    from repro.parallel.sharding import shard_map_compat

    b = jnp.asarray(b)
    dtype = dtype or b.dtype
    mode = mode or dplan.exchange
    diag = jnp.asarray(diag)
    dinv = jnp.where(diag == 0, 1.0, 1.0 / diag).astype(dtype)
    ax = dplan.axis_name

    def build():
        dot, norm = dist_dot(ax), dist_norm(ax)

        def body(dev, bs, ds):
            ops = jax.tree.map(lambda leaf: leaf[0], dev)
            b_l, dinv_l = bs[0], ds[0]

            def matvec(v):
                return dplan.ops.shard_body(ops, v, axis_name=ax, mode=mode)

            x_l, info = pcg(matvec, b_l, M=lambda r: r * dinv_l, tol=tol,
                            maxiter=maxiter, dtype=dtype, dot=dot, norm=norm)
            return x_l[None], info.iters, info.relres, info.history

        f = shard_map_compat(
            body, dplan.mesh,
            in_specs=(dplan.dev_specs, Pspec(ax), Pspec(ax)),
            out_specs=(Pspec(ax), Pspec(), Pspec(), Pspec()))
        return jax.jit(f)

    fn = dplan.cached_fn(("pcg", tol, maxiter, jnp.dtype(dtype).name, mode),
                         build)
    xs, k, relres, hist = fn(dplan.dev, dplan.shard_vector(b.astype(dtype)),
                             dplan.shard_vector(dinv))
    info = SolveInfo(k, relres, hist)
    _obs.record_solve("jacobi_pcg_dist", info, shards=dplan.n_shards)
    return dplan.unshard_vector(xs), info


def adaptive_pcg(tiers, b: jnp.ndarray, *, M: Matvec | None = None,
                 matvec_hi: Matvec | None = None, tol: float = 1e-9,
                 maxiter: int = 60, m_in: int = 16, x0=None,
                 dtype=None, stag_factor: float = 0.25,
                 start_tier: int = 0, dot=None, norm=None,
                 prestage=None, jit_cache: dict | None = None,
                 jit_key=None
                 ) -> tuple[jnp.ndarray, AdaptiveSolveInfo]:
    """Residual-adaptive mixed-precision PCG (the paper's §6 recipe,
    iterative-refinement style; DESIGN.md §8.5).

    ``tiers`` is an ordered codec ladder of matvec callables, lowest
    precision first and an (effectively) exact operator last — typically
    ``precision.select.build_tier_matvecs`` over a
    :class:`~repro.precision.select.PrecisionPlan`'s
    :func:`~repro.precision.select.tier_ladder`. The solve runs entirely
    inside ONE ``lax.while_loop``:

    * each outer step runs ``m_in`` inner PCG iterations on the correction
      equation ``A_q d = r`` using the CURRENT tier's low-precision
      operator (and the preconditioner ``M``), then updates ``x`` and
      recomputes the TRUE residual with ``matvec_hi`` (default: the last
      tier) — the classic iterative-refinement outer loop, so the final
      accuracy is set by the outer precision, not the codec;
    * **residual stagnation** — the true residual contracting by less than
      ``stag_factor`` over an outer step (the contraction of refinement is
      ≈ ``ε_codec·κ``, so a weak contraction means the tier's quantization
      floor has been hit) — **promotes** the operator to the next codec
      tier mid-solve. Tier choice is a traced ``lax.switch``: no re-trace,
      no loop exit.

    ``dot`` / ``norm`` default to the single-device reductions; the
    distributed solver injects psum-reduced versions (:func:`dist_dot` /
    :func:`dist_norm`) so the identical recurrence — and therefore the
    iteration and promotion schedule — runs on sharded vectors inside a
    shard_map region. ``prestage`` (distributed: the halo gather) maps the
    matvec input to extra operands every tier *and* ``matvec_hi`` receive
    as trailing arguments; it is hoisted out of the tier ``lax.switch`` so
    one collective per matvec serves whichever tier is active.

    ``jit_cache``/``jit_key`` compile the whole refinement loop into one
    cached buffer-donating dispatch, exactly as in :func:`pcg` (the fused
    solver step; the caller's key must identify the tier closures).

    Returns ``(x, AdaptiveSolveInfo)`` with per-tier matvec counts, so
    callers can verify how much of the solve ran sub-32-bit.
    """
    if not tiers:
        raise ValueError("need at least one tier")
    if jit_cache is not None and not isinstance(b, jax.core.Tracer):
        b = jnp.asarray(b)
        sdtype = jnp.dtype(dtype or b.dtype)
        key = ("adaptive", jit_key, float(tol), int(maxiter), int(m_in),
               float(stag_factor), int(start_tier), b.shape, sdtype.name)
        fn = jit_cache.get(key)
        if fn is None:
            fn = jax.jit(
                lambda b, x0: adaptive_pcg(
                    tiers, b, M=M, matvec_hi=matvec_hi, tol=tol,
                    maxiter=maxiter, m_in=m_in, x0=x0, dtype=dtype,
                    stag_factor=stag_factor, start_tier=start_tier,
                    dot=dot, norm=norm, prestage=prestage),
                donate_argnums=_donate(1))
            jit_cache[key] = fn
        # donation must never eat a caller-owned buffer: copy supplied x0
        x0 = (jnp.zeros(b.shape, sdtype) if x0 is None
              else jnp.array(x0, sdtype, copy=True))
        x, info = fn(b, x0)
        _obs.record_solve("adaptive_pcg", info, path="jit_cache")
        return x, info
    n_tiers = len(tiers)
    dot = dot or jnp.vdot
    norm = norm or jnp.linalg.norm
    pre = prestage or (lambda v: ())
    b, x0, bnorm, dtype = _prep(b, x0, dtype, norm)
    M = M or (lambda r: r)
    hi_raw = matvec_hi or tiers[-1]
    branches = [lambda v, *ex, f=f: f(v, *ex).astype(dtype) for f in tiers]

    def mv(tier, v):
        return jax.lax.switch(tier, branches, v, *pre(v))

    def hi(v):
        return hi_raw(v, *pre(v)).astype(dtype)

    def inner_solve(tier, rhs):
        """m_in PCG iterations on A_tier d = rhs from d0 = 0."""
        d = jnp.zeros_like(rhs)
        r = rhs
        z = M(r).astype(dtype)
        p = z
        rz = dot(r, z)

        def body(_, s):
            d, r, z, p, rz = s
            Ap = mv(tier, p)
            pAp = dot(p, Ap)
            alpha = rz / jnp.where(pAp == 0, 1.0, pAp)
            d = d + alpha * p
            r = r - alpha * Ap
            z = M(r).astype(dtype)
            rz_new = dot(r, z)
            beta = rz_new / jnp.where(rz == 0, 1.0, rz)
            p = z + beta * p
            return (d, r, z, p, rz_new)

        d, *_ = jax.lax.fori_loop(0, m_in, body, (d, r, z, p, rz))
        return d

    hist_dtype = jnp.float64 if dtype == jnp.float64 else jnp.float32
    r0 = b - hi(x0).astype(dtype)
    rel0 = norm(r0) / bnorm
    hist0 = jnp.full((maxiter + 1,), -1.0, hist_dtype).at[0].set(
        rel0.astype(hist_dtype))
    thist0 = jnp.full((maxiter + 1,), -1, jnp.int32)
    mv0 = jnp.zeros((n_tiers,), jnp.int32)

    def cond(s):
        k, x, r, relres, tier, nprom, mvc, hic, hist, thist = s
        return jnp.logical_and(k < maxiter, relres >= tol)

    def body(s):
        k, x, r, relres, tier, nprom, mvc, hic, hist, thist = s
        d = inner_solve(tier, r)
        x = x + d
        r = b - hi(x).astype(dtype)
        rel_new = norm(r) / bnorm
        mvc = mvc.at[tier].add(m_in)
        hic = hic + 1
        # stagnation: the tier's quantization floor caps the contraction
        stalled = rel_new > stag_factor * relres
        promote = jnp.logical_and(
            jnp.logical_and(stalled, rel_new >= tol),
            tier < n_tiers - 1)
        tier_next = tier + promote.astype(tier.dtype)
        hist = hist.at[k + 1].set(rel_new.astype(hist_dtype))
        thist = thist.at[k].set(tier.astype(jnp.int32))
        return (k + 1, x, r, rel_new, tier_next,
                nprom + promote.astype(nprom.dtype), mvc, hic, hist, thist)

    s0 = (jnp.asarray(0), x0, r0, rel0,
          jnp.asarray(min(start_tier, n_tiers - 1)), jnp.asarray(0),
          mv0, jnp.asarray(1), hist0, thist0)
    with _obs.span("packsell.solver_while"):
        k, x, r, relres, tier, nprom, mvc, hic, hist, thist = \
            jax.lax.while_loop(cond, body, s0)
    info = AdaptiveSolveInfo(k, relres, hist, thist, nprom, mvc, hic)
    _obs.record_solve("adaptive_pcg", info, path="eager")
    return x, info


def adaptive_pcg_dist(ladder, diag: jnp.ndarray, b: jnp.ndarray, *,
                      tol: float = 1e-9, maxiter: int = 60, m_in: int = 16,
                      stag_factor: float = 0.25, start_tier: int = 0,
                      dtype=None, mode: str | None = None
                      ) -> tuple[jnp.ndarray, AdaptiveSolveInfo]:
    """Residual-adaptive mixed-precision PCG over a device mesh: the
    ENTIRE tier-promoting refinement loop runs inside ONE jitted shard_map
    region (DESIGN.md §9.4).

    ``ladder`` is a :class:`~repro.distributed.plan.DistTierLadder` — one
    stacked member set per codec tier over one shared partition, plus the
    exact fp64 set for the outer true-residual step. The body is
    :func:`adaptive_pcg` verbatim with three injections:

    * ``dot`` / ``norm`` psum-reduce over the mesh axis, so every shard
      advances through the identical scalar recurrence — iteration counts
      and tier promotions match the single-device solver up to
      summation-order rounding;
    * each tier's matvec is the per-shard composite body
      (``DistOperands.shard_body``) selected by the traced ``lax.switch``;
    * the halo gather is the shared ``prestage``, hoisted out of the
      switch — one collective per matvec regardless of the active tier.

    ``diag``: matrix diagonal in global row order (Jacobi preconditioner);
    ``b``: global right-hand side; ``mode`` overrides the ladder's
    halo-exchange mode.
    """
    from jax.sharding import PartitionSpec as Pspec

    from repro.distributed import halo as dh
    from repro.parallel.sharding import shard_map_compat

    b = jnp.asarray(b)
    dtype = dtype or b.dtype
    mode = mode or ladder.exchange
    diag = jnp.asarray(diag)
    dinv = jnp.where(diag == 0, 1.0, 1.0 / diag).astype(dtype)
    ax = ladder.axis_name
    h_pad = ladder.h_pad

    def build():
        dot, norm = dist_dot(ax), dist_norm(ax)

        def body(dev, bs, ds):
            sh = jax.tree.map(lambda leaf: leaf[0], dev)
            b_l, dinv_l = bs[0], ds[0]
            pre = dh.prestage(sh["shared"], axis_name=ax,
                              n_shards=ladder.n_shards, h_pad=h_pad,
                              mode=mode)

            def tier_fn(ops_t, dev_t):
                def matvec(v, *extras):
                    return ops_t.shard_body(
                        dev_t, v, axis_name=ax, mode=mode,
                        x_halo=extras[0] if extras else None,
                        shared=sh["shared"])
                return matvec

            tiers = [tier_fn(o, d)
                     for o, d in zip(ladder.tiers, sh["tiers"])]
            hi = tier_fn(ladder.hi, sh["hi"])
            x_l, info = adaptive_pcg(
                tiers, b_l, M=lambda r: r * dinv_l, matvec_hi=hi,
                tol=tol, maxiter=maxiter, m_in=m_in, dtype=dtype,
                stag_factor=stag_factor, start_tier=start_tier,
                dot=dot, norm=norm, prestage=pre)
            return (x_l[None],) + tuple(info)

        f = shard_map_compat(
            body, ladder.mesh,
            in_specs=(ladder.dev_specs, Pspec(ax), Pspec(ax)),
            out_specs=(Pspec(ax),) + (Pspec(),) * 7)
        return jax.jit(f)

    fn = ladder.cached_fn(
        ("adaptive", tol, maxiter, m_in, stag_factor, start_tier,
         jnp.dtype(dtype).name, mode), build)
    out = fn(ladder.dev, ladder.shard_vector(b.astype(dtype)),
             ladder.shard_vector(dinv))
    info = AdaptiveSolveInfo(*out[1:])
    _obs.record_solve("adaptive_pcg_dist", info, shards=ladder.n_shards)
    return ladder.unshard_vector(out[0]), info


def pcg_fixed_iters(matvec: Matvec, M: Matvec, m_in: int,
                    dtype=jnp.float32) -> Matvec:
    """m_in PCG iterations from x0 = 0, packaged as a preconditioner —
    the inner solver of IO-CG (paper §5.2.2)."""

    def apply(rhs: jnp.ndarray) -> jnp.ndarray:
        b = rhs.astype(dtype)
        x = jnp.zeros_like(b)
        r = b
        z = M(r).astype(dtype)
        p = z
        rz = jnp.vdot(r, z)

        def body(_, s):
            x, r, z, p, rz = s
            Ap = matvec(p).astype(dtype)
            pAp = jnp.vdot(p, Ap)
            alpha = rz / jnp.where(pAp == 0, 1.0, pAp)
            x = x + alpha * p
            r = r - alpha * Ap
            z = M(r).astype(dtype)
            rz_new = jnp.vdot(r, z)
            beta = rz_new / jnp.where(rz == 0, 1.0, rz)
            p = z + beta * p
            return (x, r, z, p, rz_new)

        x, *_ = jax.lax.fori_loop(0, m_in, body, (x, r, z, p, rz))
        return x

    return apply
