"""Preconditioned Richardson iteration — the innermost layer of F3R."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Matvec = Callable[[jnp.ndarray], jnp.ndarray]


def richardson_fixed_iters(matvec: Matvec, M: Matvec, iters: int,
                           dtype=jnp.float32) -> Matvec:
    """x_{k+1} = x_k + M (b - A x_k), x_0 = M b, fixed iteration count."""

    def apply(rhs: jnp.ndarray) -> jnp.ndarray:
        b = rhs.astype(dtype)
        x = M(b).astype(dtype)

        def body(_, x):
            return x + M(b - matvec(x).astype(dtype)).astype(dtype)

        return jax.lax.fori_loop(0, iters, body, x)

    return apply
