"""Inner-outer CG (paper §5.2.2): FP64 flexible CG preconditioned by m_in
iterations of lower-precision PCG whose SpMV runs in FP32 / FP16 / E8MY.

Variants (paper Fig. 11): fp64 / fp32 / fp16 / e8m<D> — the last one is the
PackSELL-enabled solver that tunes the mantissa width Y = 22 - D.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from . import precond
from .cg import SolveInfo, fcg, pcg_fixed_iters
from .operators import OperatorSet


@dataclasses.dataclass
class IOCGConfig:
    m_in: int = 50             # inner PCG iterations (paper: 20 / 50 / 80)
    inner_spmv: str = "fp32"   # 'fp64'|'fp32'|'fp16'|'packsell_e8m<D>'
    ainv_terms: int = 2
    tol: float = 1e-9
    maxiter: int = 2000        # outer FCG iterations


def variant(name: str, m_in: int = 50) -> IOCGConfig:
    if name == "fp64":
        return IOCGConfig(m_in=m_in, inner_spmv="fp64")
    if name == "fp32":
        return IOCGConfig(m_in=m_in, inner_spmv="fp32")
    if name == "fp16":
        return IOCGConfig(m_in=m_in, inner_spmv="fp16")
    if name.startswith("e8m"):  # e8m<D> with D the delta width
        return IOCGConfig(m_in=m_in, inner_spmv=f"packsell_{name}")
    raise ValueError(name)


def solve(ops: OperatorSet, b: jnp.ndarray,
          config: IOCGConfig) -> tuple[jnp.ndarray, SolveInfo]:
    A_out = ops.matvec("fp64")
    A_in = ops.matvec(config.inner_spmv)
    inner_dtype = jnp.float64 if config.inner_spmv == "fp64" else jnp.float32
    M_in = precond.neumann_ainv(ops.diag(), A_in, k=config.ainv_terms,
                                dtype=inner_dtype)
    M = pcg_fixed_iters(A_in, M_in, config.m_in, dtype=inner_dtype)
    return fcg(A_out, b, M=M, tol=config.tol, maxiter=config.maxiter,
               dtype=b.dtype)


def pcg_reference(ops: OperatorSet, b: jnp.ndarray, *, tol: float = 1e-9,
                  maxiter: int = 20000,
                  ainv_terms: int = 2) -> tuple[jnp.ndarray, SolveInfo]:
    """The paper's baseline: standard full-precision PCG with the same
    approximate-inverse preconditioner."""
    from .cg import pcg
    A = ops.matvec("fp64")
    M = precond.neumann_ainv(ops.diag(), A, k=ainv_terms, dtype=jnp.float64)
    return pcg(A, b, M=M, tol=tol, maxiter=maxiter, dtype=b.dtype)
