"""Process-local metrics registry and trace spans (DESIGN.md §12).

The flight recorder behind every PackSELL dispatch: counters, gauges and
histograms keyed by ``(name, labels)``, plus ``span()`` context managers
that name hot regions in XLA profiles. Two invariants shape the design:

* **Zero-cost when disabled.** ``REPRO_OBS=0`` (the tier-1 default) makes
  every recording call a single predicate check and ``span()`` a bare
  ``yield`` — no dict lookups, no allocation, no lock.
* **Jit-compatible.** Recording happens only at host-side dispatch entry
  points; code inside a ``jax.jit``-traced body runs once at trace time,
  so counters there would silently freeze. ``span()`` *is* legal inside
  traced code — ``jax.named_scope`` only attaches metadata to the ops it
  encloses and ``jax.profiler.TraceAnnotation`` marks host trace-time —
  neither can change numerics, which is what the REPRO_OBS=1 bit-for-bit
  parity tests pin down.

Series naming follows ``subsystem.event`` with labels for dimensions, e.g.
``spmv.dispatch{cache_mode=checkpoint,codec=fp16,variant=jnp}``. The full
span/series naming map lives in DESIGN.md §12.
"""
from __future__ import annotations

import contextlib
import json
import os
import random
import threading

__all__ = [
    "enabled", "enable", "inc", "gauge", "observe", "record_trace",
    "series_key", "inc_many", "counter_bump", "snapshot", "raw_snapshot",
    "reset", "export_json", "span",
]


def _env_on(val: str | None) -> bool:
    return (val or "0").strip().lower() not in ("", "0", "false", "off", "no")


_ENABLED = _env_on(os.environ.get("REPRO_OBS"))

_LOCK = threading.Lock()
# series key: (name, (("k","v"), ...)) with labels sorted by key
_COUNTERS: dict = {}
_GAUGES: dict = {}
_HISTS: dict = {}          # key -> {"count", "sum", "min", "max", "last"}
_TRACES: dict = {}         # key -> list of records (bounded)
_TRACE_CAP = 256           # per-series record cap (drop-oldest)
_RES_CAP = 256             # per-histogram quantile reservoir size
_QUANTILES = (("p50", 0.50), ("p95", 0.95), ("p99", 0.99))
# Algorithm-R replacement draws: a dedicated seeded stream so reservoir
# contents are reproducible per process and never perturb user RNG state
_RES_RNG = random.Random(0xC0FFEE)


def enabled() -> bool:
    """True when the registry records (``REPRO_OBS`` truthy or enable())."""
    return _ENABLED


def enable(on: bool = True) -> bool:
    """Flip recording on/off at runtime (benchmarks/tests; the env var
    only sets the process default). Returns the previous state."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    return prev


def _key(name: str, labels: dict):
    if not labels:
        return (name, ())
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


def inc(name: str, value: float = 1, **labels) -> None:
    """Add ``value`` to counter ``name{labels}`` (no-op when disabled)."""
    if not _ENABLED:
        return
    k = _key(name, labels)
    with _LOCK:
        _COUNTERS[k] = _COUNTERS.get(k, 0) + value


def series_key(name: str, **labels):
    """Precompute a series handle for :func:`inc_many` — hot dispatch
    paths pay the label sort/stringification once at plan setup instead
    of on every call (the <3% overhead budget of DESIGN.md §12.5)."""
    return _key(name, labels)


def inc_many(pairs) -> None:
    """Bump several precomputed ``(series_key, value)`` counters — the
    steady-state dispatch record.  Deliberately lock-free: each get/set
    is GIL-atomic, so the only cross-thread hazard is a lost increment
    when two threads interleave on the SAME series — acceptable for a
    flight recorder, and it keeps the hot dispatch path inside the §12.5
    overhead budget (the lock acquisition costs as much as both bumps)."""
    if not _ENABLED:
        return
    for k, v in pairs:
        _COUNTERS[k] = _COUNTERS.get(k, 0) + v


def counter_bump(pairs):
    """Compile ``(series_key, value)`` pairs into a zero-arg closure —
    the cheapest possible steady-state record (everything resolvable is
    bound at build time; the common two-counter case is unrolled).  The
    closure re-checks ``_ENABLED`` so a cached bump goes quiet when the
    recorder is turned off.  Same lock-free tradeoff as
    :func:`inc_many`."""
    pairs = tuple(pairs)
    C = _COUNTERS          # reset() clears in place, never rebinds
    if len(pairs) == 2:
        (k1, v1), (k2, v2) = pairs

        def bump(C=C, get=C.get):
            if _ENABLED:
                C[k1] = get(k1, 0) + v1
                C[k2] = get(k2, 0) + v2
        return bump

    def bump(C=C, get=C.get):
        if _ENABLED:
            for k, v in pairs:
                C[k] = get(k, 0) + v
    return bump


def gauge(name: str, value: float, **labels) -> None:
    """Set gauge ``name{labels}`` to the latest ``value``."""
    if not _ENABLED:
        return
    k = _key(name, labels)
    with _LOCK:
        _GAUGES[k] = value


def observe(name: str, value: float, **labels) -> None:
    """Record ``value`` into histogram ``name{labels}``: count/sum/min/
    max/last aggregates plus a bounded reservoir (Algorithm R, cap
    ``_RES_CAP``) that :func:`snapshot` turns into p50/p95/p99 — serving
    latency SLOs need percentiles, not means.  Still strictly a no-op
    when the recorder is disabled."""
    if not _ENABLED:
        return
    k = _key(name, labels)
    v = float(value)
    with _LOCK:
        h = _HISTS.get(k)
        if h is None:
            _HISTS[k] = {"count": 1, "sum": v, "min": v, "max": v,
                         "last": v, "res": [v]}
        else:
            h["count"] += 1
            h["sum"] += v
            h["min"] = min(h["min"], v)
            h["max"] = max(h["max"], v)
            h["last"] = v
            res = h["res"]
            if len(res) < _RES_CAP:
                res.append(v)
            else:
                # uniform reservoir: each of the count values seen so far
                # keeps an equal _RES_CAP/count chance of being resident
                j = _RES_RNG.randrange(h["count"])
                if j < _RES_CAP:
                    res[j] = v


def _quantiles(res: list) -> dict:
    """Nearest-rank percentiles from a reservoir sample (exact when the
    series has fewer than ``_RES_CAP`` observations)."""
    s = sorted(res)
    n = len(s)
    return {tag: s[min(int(q * n), n - 1)] for tag, q in _QUANTILES}


def record_trace(name: str, record: dict, **labels) -> None:
    """Append a structured record (e.g. one solve's convergence history)
    to trace series ``name{labels}``; oldest records drop past the cap."""
    if not _ENABLED:
        return
    k = _key(name, labels)
    with _LOCK:
        lst = _TRACES.setdefault(k, [])
        lst.append(record)
        if len(lst) > _TRACE_CAP:
            del lst[: len(lst) - _TRACE_CAP]


def _fmt_key(k) -> str:
    name, labels = k
    if not labels:
        return name
    return name + "{" + ",".join(f"{a}={b}" for a, b in labels) + "}"


def _hist_view(h: dict) -> dict:
    """Exported histogram record: aggregates + reservoir percentiles (the
    raw reservoir stays private to the registry)."""
    out = {k: v for k, v in h.items() if k != "res"}
    out.update(_quantiles(h["res"]))
    return out


def snapshot() -> dict:
    """Point-in-time copy of every series, keyed ``name{k=v,...}``."""
    with _LOCK:
        return {
            "enabled": _ENABLED,
            "counters": {_fmt_key(k): v for k, v in sorted(_COUNTERS.items())},
            "gauges": {_fmt_key(k): v for k, v in sorted(_GAUGES.items())},
            "histograms": {_fmt_key(k): _hist_view(v)
                           for k, v in sorted(_HISTS.items())},
            "traces": {_fmt_key(k): [dict(r) for r in v]
                       for k, v in sorted(_TRACES.items())},
        }


def raw_snapshot() -> dict:
    """Structured twin of :func:`snapshot` for machine consumers (the
    exporters): series keyed by the raw ``(name, ((label, value), ...))``
    tuples instead of formatted strings, so no string parsing is ever
    needed to recover labels.  Traces are excluded — they are logs, not
    metrics."""
    with _LOCK:
        return {
            "counters": dict(_COUNTERS),
            "gauges": dict(_GAUGES),
            "histograms": {k: _hist_view(v) for k, v in _HISTS.items()},
        }


def reset() -> None:
    """Clear every series (the enabled flag is left as-is)."""
    with _LOCK:
        _COUNTERS.clear()
        _GAUGES.clear()
        _HISTS.clear()
        _TRACES.clear()


def export_json(path: str) -> dict:
    """Write :func:`snapshot` to ``path``; returns the snapshot."""
    snap = snapshot()
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, default=float)
    return snap


@contextlib.contextmanager
def span(name: str):
    """Name a hot region in XLA profiles: ``jax.named_scope`` tags the ops
    traced inside (visible in HLO metadata / device profiles) and
    ``TraceAnnotation`` marks the host-side interval. Single bare yield
    when disabled. Safe inside jit-traced code — metadata only."""
    if not _ENABLED:
        yield
        return
    import jax

    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield
