"""Span-level device-time attribution (DESIGN.md §13.1).

The PR-7 recorder counts *dispatches*; this module answers *where inside
a dispatch the time goes*.  :func:`profile_dispatch` runs a callable
under ``jax.profiler.trace``, parses the captured Chrome-trace events,
and buckets per-op device time under the PR-7 span names
(``packsell.fused_decode``, ``gather_epilogue``, ...).

How attribution works (two event sources, one join):

* The profiler emits one event per executed HLO thunk, tagged with the
  post-optimization instruction name (``args.hlo_op``) and module
  (``args.hlo_module``) — but NOT the ``named_scope`` path.
* The *compiled HLO text* of the dispatched executable carries each
  instruction's ``metadata={op_name="jit(f)/.../packsell.fused_decode/
  ..."}`` — the scope path ``observe.span`` planted.  (For fusions the
  metadata is the fusion root's, which inherits the root's scope.)
* :func:`hlo_span_map` parses that text into ``(module, op) -> span``;
  trace events then join against it.  Ops whose scope path names no
  known span are aggregated into a top-k ``unattributed`` list — an op
  showing up there means a hot region nobody wrapped in a span yet.

Host-side ``TraceAnnotation`` intervals whose name IS a span name (the
eager-solver ``packsell.solver_while`` wrapper) are credited as host
time for that span.  The whole measured region is bracketed by a marker
annotation, so ``wall_s`` is the real per-call dispatch wall time,
including host overhead the device events cannot see.

When the profiler plugin is unavailable (no trace produced, trace API
raises, or no parseable events), :func:`profile_dispatch` degrades to a
pure wall-clock measurement with ``profiler_unavailable=True`` — CPU CI
keeps running, and consumers (``bench_roofline --profile``) surface the
marker instead of fabricating a breakdown.
"""
from __future__ import annotations

import dataclasses
import glob
import gzip
import json
import os
import re
import shutil
import tempfile
import time

from . import metrics as _obs

__all__ = ["SPAN_NAMES", "SpanProfile", "hlo_span_map", "profile_dispatch"]

#: the fixed span vocabulary of DESIGN.md §12.2 — attribution targets
#: (``bucket_decode`` covers the non-fused bucketed/cursor dispatch body,
#: added when span profiling surfaced it as 100%-unattributed)
SPAN_NAMES = (
    "packsell.plan_build",
    "packsell.fused_decode",
    "packsell.fused_kernel",
    "packsell.bucket_decode",
    "packsell.gather_epilogue",
    "packsell.halo_prestage",
    "packsell.guard_checksum",
    "packsell.solver_while",
)

#: marker annotation bracketing each measured call
_MARKER = "packsell.profile_dispatch"

#: instruction definition with op_name metadata, post-optimization HLO
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([A-Za-z0-9_.\-]+)\s*=\s*.*"
    r"metadata=\{[^}]*op_name=\"([^\"]+)\"")
_MODULE_RE = re.compile(r"^HloModule\s+([^,\s]+)", re.MULTILINE)


@dataclasses.dataclass
class SpanProfile:
    """Per-span time attribution for one dispatched callable.

    ``spans`` maps span name -> ``{"device_s", "host_s", "ops",
    "events"}`` (seconds are per-call averages across ``repeats``).
    ``coverage_of_wall`` = attributed span device time / clean wall; on
    tiny CPU dispatches this is structurally small because the wall is
    host-dispatch-bound, so the breakdown also carries an explicit
    ``host_overhead_s`` bucket and ``accounted_frac_of_wall`` = (device
    + host overhead) / wall — the ">= 0.8" acceptance figure: either
    the spans explain the wall, or the profile says out loud that the
    dispatch is host-overhead-bound (and by how much)."""

    mode: str                       # "trace" | "wallclock"
    backend: str
    repeats: int
    wall_s: float                   # per-call dispatch wall, no profiler
    traced_wall_s: float = 0.0      # per-call wall under the trace (the
    #                                 marker interval; includes per-thunk
    #                                 TraceMe instrumentation cost)
    device_total_s: float = 0.0     # per-call, all hlo-op events
    host_overhead_s: float = 0.0    # wall - device time: pjit python
    #                                 dispatch, argument parsing, buffer
    #                                 await — the part no HLO op covers
    spans: dict = dataclasses.field(default_factory=dict)
    unattributed: list = dataclasses.field(default_factory=list)
    attributed_frac: float = 0.0    # of device_total_s
    coverage_of_wall: float = 0.0   # span device time / wall
    accounted_frac_of_wall: float = 0.0   # (span + unattributed device
    #                                 + host overhead) / wall — how much
    #                                 of the wall the breakdown explains
    profiler_unavailable: bool = False
    note: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def hlo_span_map(hlo_text: str, spans=SPAN_NAMES) -> dict:
    """Parse post-optimization HLO text into ``(module, op) -> span``.
    An op maps to the FIRST span name appearing as a path component of
    its ``op_name`` metadata (named_scope components are exact path
    segments; transform wrappers like ``jit(...)`` never collide)."""
    m = _MODULE_RE.search(hlo_text)
    module = m.group(1) if m else ""
    spanset = set(spans)
    out = {}
    for line in hlo_text.splitlines():
        im = _INSTR_RE.match(line)
        if not im:
            continue
        op, op_name = im.groups()
        for comp in op_name.split("/"):
            if comp in spanset:
                out[(module, op)] = comp
                break
    return out


def _find_trace_json(trace_dir: str) -> str | None:
    hits = sorted(glob.glob(os.path.join(
        trace_dir, "plugins", "profile", "*", "*.trace.json.gz")))
    return hits[-1] if hits else None


def _parse_events(path: str) -> list[dict]:
    with gzip.open(path, "rt") as f:
        payload = json.load(f)
    return [e for e in payload.get("traceEvents", [])
            if e.get("ph") == "X" and "dur" in e]


def _wallclock(fn, args, repeats: int, backend: str,
               note: str) -> SpanProfile:
    t0 = time.perf_counter()
    for _ in range(repeats):
        _block(fn(*args))
    wall = (time.perf_counter() - t0) / max(repeats, 1)
    return SpanProfile(mode="wallclock", backend=backend, repeats=repeats,
                       wall_s=wall, profiler_unavailable=True, note=note)


def _block(out):
    import jax

    jax.block_until_ready(out)
    return out


def profile_dispatch(fn, *args, spans=SPAN_NAMES, hlo_texts=(),
                     repeats: int = 10, warmup: int = 2,
                     top_k: int = 8) -> SpanProfile:
    """Profile ``repeats`` calls of ``fn(*args)`` and attribute device
    time to named spans.

    ``hlo_texts``: compiled-HLO module texts of the executables ``fn``
    dispatches (builds the op->span join).  When ``fn`` itself is a
    jit-wrapped callable its lowering is harvested automatically; for a
    host wrapper around a cached dispatch (``plan.spmv``) pass the text
    explicitly — ``bench_roofline`` reuses the lowering it already does
    for the HLO byte cross-check."""
    import jax

    backend = jax.default_backend()
    # the recorder must be ON from the first (compiling) call: span() is
    # a bare yield when disabled, and a function traced that way bakes an
    # HLO with no scope metadata — nothing to attribute.  Callers whose
    # executables were compiled recorder-off should rebuild/clear their
    # jit caches before profiling.
    prev = _obs.enable(True)
    try:
        for _ in range(max(warmup, 1)):        # compile outside the trace
            _block(fn(*args))
        t0 = time.perf_counter()               # clean wall: what a bench
        for _ in range(repeats):               # measures, no per-thunk
            _block(fn(*args))                  # TraceMe instrumentation
        wall_clean = (time.perf_counter() - t0) / max(repeats, 1)

        texts = list(hlo_texts)
        if not texts and hasattr(fn, "lower"):
            try:
                texts.append(fn.lower(*args).compile().as_text())
            except Exception:
                pass
    finally:
        _obs.enable(prev)
    span_map = {}
    for txt in texts:
        span_map.update(hlo_span_map(txt, spans))
    by_op = {}                     # op-name fallback when module unmatched
    for (_, op), s in span_map.items():
        by_op[op] = s

    td = tempfile.mkdtemp(prefix="repro_profile_")
    prev = _obs.enable(True)       # host-side span annotations must fire
    try:
        try:
            with jax.profiler.trace(td):
                t0 = time.perf_counter()
                for _ in range(repeats):
                    with jax.profiler.TraceAnnotation(_MARKER):
                        _block(fn(*args))
                t_wall = time.perf_counter() - t0
        except Exception as e:     # profiler plugin unavailable/busy
            return _wallclock(fn, args, repeats, backend,
                              f"trace failed: {e!r}")
        finally:
            _obs.enable(prev)
        tj = _find_trace_json(td)
        if tj is None:
            return _wallclock(fn, args, repeats, backend,
                              "no trace.json.gz produced")
        events = _parse_events(tj)
    finally:
        _obs.enable(prev)
        shutil.rmtree(td, ignore_errors=True)

    spanset = set(spans)
    acc = {s: {"device_s": 0.0, "host_s": 0.0, "ops": 0, "events": 0}
           for s in spans}
    marker_us = 0.0
    device_us = 0.0
    unattr: dict = {}
    for e in events:
        name = e["name"]
        dur = float(e["dur"])      # microseconds
        eargs = e.get("args") or {}
        if name == _MARKER:
            marker_us += dur
            continue
        if "hlo_op" in eargs:
            device_us += dur
            key = (eargs.get("hlo_module", ""), eargs["hlo_op"])
            span = span_map.get(key) or by_op.get(eargs["hlo_op"])
            if span is not None:
                acc[span]["device_s"] += dur * 1e-6
                acc[span]["events"] += 1
            else:
                unattr[name] = unattr.get(name, 0.0) + dur
            continue
        if name in spanset:        # host TraceAnnotation from observe.span
            acc[name]["host_s"] += dur * 1e-6
            acc[name]["events"] += 1

    reps = max(repeats, 1)
    for (_, op), s in span_map.items():
        acc[s]["ops"] += 1
    for s in acc.values():
        s["device_s"] /= reps
        s["host_s"] /= reps
    traced = (marker_us * 1e-6 / reps) if marker_us else t_wall / reps
    dev_total = device_us * 1e-6 / reps
    span_dev = sum(s["device_s"] for s in acc.values())
    top = sorted(unattr.items(), key=lambda kv: -kv[1])[:top_k]
    note = ""
    # host overhead: the wall the device ops do not explain.  On a CPU
    # backend with many small thunks the per-thunk TraceMe cost can
    # inflate summed device durations past the clean wall — clamp and
    # say so rather than report a negative host share.
    host_over = wall_clean - dev_total
    if host_over < 0:
        host_over = 0.0
        note = ("summed device events exceed the untraced wall "
                "(per-thunk instrumentation inflation); host overhead "
                "clamped to 0")
    accounted = min((dev_total + host_over) / wall_clean, 1.0) \
        if wall_clean else 0.0
    return SpanProfile(
        mode="trace", backend=backend, repeats=repeats, wall_s=wall_clean,
        traced_wall_s=traced,
        device_total_s=dev_total,
        host_overhead_s=host_over,
        spans={k: v for k, v in acc.items()
               if v["events"] or v["ops"]},
        unattributed=[{"op": k, "device_s": v * 1e-6 / reps}
                      for k, v in top],
        attributed_frac=(span_dev / dev_total) if dev_total else 0.0,
        coverage_of_wall=(span_dev / wall_clean) if wall_clean else 0.0,
        accounted_frac_of_wall=accounted,
        note=note,
    )
