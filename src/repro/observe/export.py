"""Getting metrics OUT of the process (DESIGN.md §13.2).

The PR-7 registry is process-local: perfect for benchmarks, useless for
a long-running serving engine that someone else has to watch.  This
module is the egress layer:

* :func:`prometheus_text` — Prometheus/OpenMetrics text exposition of
  the registry (counters, gauges, histogram summaries with reservoir
  quantiles).  Metric names are sanitized (``spmv.dispatch`` →
  ``spmv_dispatch``) with the original series name preserved in the
  ``# HELP`` line, so :func:`parse_prometheus_text` round-trips the
  exact registry state — the property ``tests/test_sentinel.py`` pins.
* :class:`JsonlSink` — an append-only JSONL archive
  (``artifacts/obs/*.jsonl``): one ``meta`` header record per file
  (:func:`run_meta`, the same provenance header BENCH_*.json carries),
  then one snapshot-*delta* record per flush.  Deltas are computed
  against the last flushed state and re-base automatically after a
  registry ``reset()`` (a negative counter delta means the registry
  restarted, not that traffic ran backwards).
* :func:`start_exporter` — a daemon-thread flusher with a clean
  ``stop()`` (final flush + join), the piece a serving engine wires in.

Everything here reads :func:`metrics.raw_snapshot` — tuple-keyed series,
no string parsing — and never *writes* the registry, so an exporter
thread can never perturb what it measures beyond the cost of a copy.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time

from . import metrics

__all__ = [
    "run_meta", "prometheus_text", "parse_prometheus_text",
    "JsonlSink", "Exporter", "start_exporter",
]

#: order of the quantile sample lines inside a histogram summary
_QTAGS = {"p50": "0.5", "p95": "0.95", "p99": "0.99"}
_QTAGS_INV = {v: k for k, v in _QTAGS.items()}
#: histogram aggregate fields exported as suffixed samples
_HSUFFIXES = ("count", "sum", "min", "max", "last")


# ---------------------------------------------------------------------------
# provenance header
# ---------------------------------------------------------------------------

def run_meta(**extra) -> dict:
    """Provenance header for exported telemetry: commit, toolchain,
    backend, machine, UTC timestamp.  ``benchmarks.common.bench_meta``
    delegates here so BENCH_*.json files and telemetry archives carry
    the same fields and stay joinable in the trajectory store."""
    import platform
    import subprocess
    from datetime import datetime, timezone

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    cpu = ""
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    cpu = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    meta = {
        "generated_at": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "git_sha": sha,
        "platform": platform.platform(),
        "cpu": cpu or platform.processor() or platform.machine() or "unknown",
        "python": platform.python_version(),
    }
    try:                                 # backend info is best-effort: the
        import jax                       # exporter must work before (or

        meta["jax_version"] = jax.__version__       # without) jax init
        meta["backend"] = jax.default_backend()
    except Exception:
        meta["jax_version"] = meta["backend"] = "unknown"
    meta.update(extra)
    return meta


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _sanitize(name: str) -> str:
    s = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not re.match(r"[a-zA-Z_:]", s):
        s = "_" + s
    return s


def _esc(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _unesc(v: str) -> str:
    out, i = [], 0
    while i < len(v):
        c = v[i]
        if c == "\\" and i + 1 < len(v):
            n = v[i + 1]
            out.append({"n": "\n", "\\": "\\", '"': '"'}.get(n, "\\" + n))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def _labelstr(labels, extra=()) -> str:
    items = list(labels) + list(extra)
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_esc(str(v))}"' for k, v in items) + "}"


def _num(v) -> str:
    # repr round-trips both int and float exactly through the parser
    if isinstance(v, bool):
        return repr(int(v))
    if isinstance(v, int):
        return repr(v)
    return repr(float(v))


def prometheus_text(snap: dict | None = None) -> str:
    """Render the registry (or a :func:`metrics.raw_snapshot`) in
    Prometheus text exposition format.  One ``# HELP`` line per family
    records the original dotted series name; histograms export as
    summaries (quantile samples + ``_count``/``_sum``/``_min``/``_max``/
    ``_last``)."""
    snap = snap if snap is not None else metrics.raw_snapshot()
    # family: sanitized name -> (original name, type, [(labels, value)])
    fams: dict = {}
    for kind, typ in (("counters", "counter"), ("gauges", "gauge")):
        for (name, labels), v in sorted(snap.get(kind, {}).items()):
            fams.setdefault((_sanitize(name), typ), (name, []))[1] \
                .append((labels, v))
    lines = []
    for (sname, typ), (name, series) in sorted(fams.items()):
        lines.append(f"# HELP {sname} {name}")
        lines.append(f"# TYPE {sname} {typ}")
        for labels, v in series:
            lines.append(f"{sname}{_labelstr(labels)} {_num(v)}")
    for (name, labels), h in sorted(snap.get("histograms", {}).items()):
        sname = _sanitize(name)
        lines.append(f"# HELP {sname} {name}")
        lines.append(f"# TYPE {sname} summary")
        for tag, q in _QTAGS.items():
            lines.append(f"{sname}"
                         f"{_labelstr(labels, [('quantile', q)])} "
                         f"{_num(h[tag])}")
        for suf in _HSUFFIXES:
            lines.append(f"{sname}_{suf}{_labelstr(labels)} "
                         f"{_num(h[suf])}")
    return "\n".join(lines) + "\n"


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _parse_num(s: str):
    try:
        return int(s)
    except ValueError:
        return float(s)


def parse_prometheus_text(text: str) -> dict:
    """Inverse of :func:`prometheus_text`: rebuild the tuple-keyed
    ``{"counters", "gauges", "histograms"}`` structure, restoring
    original dotted names from the ``# HELP`` lines.  Raises
    ``ValueError`` on a malformed sample line."""
    helps: dict = {}
    types: dict = {}
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    hists: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            _, _, rest = line.partition("# HELP ")
            sname, _, orig = rest.partition(" ")
            helps[sname] = orig
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            sname, _, typ = rest.partition(" ")
            types[sname] = typ
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"malformed exposition line: {line!r}")
        sname, labelblob, val = m.groups()
        labels = tuple((k, _unesc(v))
                       for k, v in _LABEL_RE.findall(labelblob or ""))
        # histogram summaries: quantile label or an aggregate suffix
        base, field = sname, None
        if any(l[0] == "quantile" for l in labels):
            field = _QTAGS_INV[dict(labels)["quantile"]]
            labels = tuple(l for l in labels if l[0] != "quantile")
        else:
            for suf in _HSUFFIXES:
                cand = sname[: -len(suf) - 1]
                if sname.endswith("_" + suf) and types.get(cand) == "summary":
                    base, field = cand, suf
                    break
        if field is not None and types.get(base) == "summary":
            key = (helps.get(base, base), labels)
            hists.setdefault(key, {})[field] = _parse_num(val)
            continue
        kind = {"counter": "counters", "gauge": "gauges"}.get(
            types.get(sname))
        if kind is None:
            raise ValueError(f"sample {sname!r} has no # TYPE line")
        out[kind][(helps.get(sname, sname), labels)] = _parse_num(val)
    out["histograms"] = hists
    return out


# ---------------------------------------------------------------------------
# JSONL sink
# ---------------------------------------------------------------------------

def _fmt_key(k) -> str:
    name, labels = k
    if not labels:
        return name
    return name + "{" + ",".join(f"{a}={b}" for a, b in labels) + "}"


class JsonlSink:
    """Append-only telemetry archive: ``{"kind": "meta", ...}`` header on
    first flush, then one ``{"kind": "delta", ...}`` record per flush.

    Counter/histogram-count deltas are against the previous flush; a
    registry ``reset()`` between flushes makes the raw delta negative, in
    which case the current absolute value is taken (re-base) and the
    record is marked ``"rebased": true``.  All methods are serialized by
    an internal lock, so concurrent flushers (exporter thread + an
    explicit engine flush) interleave whole records, never partial
    lines."""

    def __init__(self, path: str, meta: dict | None = None):
        self.path = os.fspath(path)
        self._lock = threading.Lock()
        self._last_counters: dict = {}
        self._last_hists: dict = {}
        self._meta = meta
        self._seq = 0
        self._header_written = False

    def _write(self, rec: dict) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(rec, default=float) + "\n")

    def flush(self) -> dict | None:
        """Write one snapshot-delta record; returns it (None when there
        is nothing to report and nothing was written yet)."""
        with self._lock:
            snap = metrics.raw_snapshot()
            if not self._header_written:
                self._write({"kind": "meta",
                             **(self._meta or run_meta())})
                self._header_written = True
            rebased = False
            counters = {}
            for k, v in snap["counters"].items():
                d = v - self._last_counters.get(k, 0)
                if d < 0:                      # registry reset since last
                    d, rebased = v, True       # flush: re-base on absolute
                if d:
                    counters[_fmt_key(k)] = d
            hists = {}
            for k, h in snap["histograms"].items():
                prev = self._last_hists.get(k, {"count": 0, "sum": 0.0})
                dc = h["count"] - prev["count"]
                ds = h["sum"] - prev["sum"]
                if dc < 0:
                    dc, ds, rebased = h["count"], h["sum"], True
                if dc:
                    hists[_fmt_key(k)] = {
                        "count": dc, "sum": ds, "min": h["min"],
                        "max": h["max"], "last": h["last"],
                        "p50": h["p50"], "p95": h["p95"], "p99": h["p99"],
                    }
            self._last_counters = dict(snap["counters"])
            self._last_hists = {k: {"count": h["count"], "sum": h["sum"]}
                                for k, h in snap["histograms"].items()}
            rec = {
                "kind": "delta",
                "seq": self._seq,
                "t": time.time(),
                "counters": counters,
                "gauges": {_fmt_key(k): v
                           for k, v in snap["gauges"].items()},
                "histograms": hists,
            }
            if rebased:
                rec["rebased"] = True
            self._seq += 1
            self._write(rec)
            return rec

    @staticmethod
    def read(path: str) -> list[dict]:
        """Load every record of an archive (convenience for tests and
        the trajectory store)."""
        out = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out


# ---------------------------------------------------------------------------
# exporter thread
# ---------------------------------------------------------------------------

class Exporter:
    """Daemon-thread flusher around a :class:`JsonlSink`.  ``stop()``
    wakes the thread, takes a final flush, and joins — telemetry from
    the last partial interval is never lost on clean shutdown."""

    def __init__(self, sink: JsonlSink, interval_s: float):
        self.sink = sink
        self.interval_s = float(interval_s)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._loop, name="repro-obs-exporter", daemon=True)
        self.flushes = 0

    def start(self) -> "Exporter":
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.flush()

    def flush(self) -> None:
        self.sink.flush()
        self.flushes += 1

    def stop(self, timeout: float = 5.0) -> None:
        if not self._stop.is_set():
            self._stop.set()
            self._thread.join(timeout)
            self.flush()                       # final partial interval

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    def __enter__(self) -> "Exporter":
        if not self._thread.is_alive() and not self._stop.is_set():
            self.start()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()


def start_exporter(interval_s: float = 1.0,
                   path: str = "artifacts/obs/metrics.jsonl",
                   meta: dict | None = None) -> Exporter:
    """Start a daemon flusher writing snapshot-deltas to ``path`` every
    ``interval_s`` seconds.  Returns the :class:`Exporter`; call
    ``stop()`` for a clean final flush."""
    return Exporter(JsonlSink(path, meta=meta), interval_s).start()
