"""PackSELL flight recorder: one observability surface for the stack.

``repro.observe`` is the metrics/tracing layer every dispatch, solve,
guard check and cache flows through (DESIGN.md §12). Recording is off by
default (``REPRO_OBS=0``); flip it with the env var or :func:`enable`.

    from repro import observe
    observe.enable()
    ...  # run solves / benchmarks
    print(json.dumps(observe.report(), indent=1))
"""
from __future__ import annotations

from .metrics import (enable, enabled, export_json, gauge, inc, observe,
                      raw_snapshot, record_trace, reset, snapshot, span)

__all__ = [
    "enable", "enabled", "export_json", "gauge", "inc", "observe",
    "raw_snapshot", "record_trace", "record_solve", "reset", "snapshot",
    "span", "report",
    # perf-sentinel layers (DESIGN.md §13): imported as submodules to keep
    # `import repro.observe` light — `from repro.observe import export,
    # profile, trajectory`
]


def record_solve(solver: str, info, **labels) -> None:
    """Post-hoc solver convergence trace from an ``Info`` pytree
    (``SolveInfo`` / ``AdaptiveSolveInfo``): per-outer-iteration residual
    plus tier history, emitted once the arrays are concrete — never a host
    callback inside ``lax.while_loop``. Silently skips under tracing (the
    inner ``pcg`` of a jitted fused solve sees tracers; the outer host
    wrapper records), so nesting never double-counts."""
    if not enabled():
        return
    import jax
    import numpy as np

    leaves = jax.tree_util.tree_leaves(info)
    if any(isinstance(leaf, jax.core.Tracer) for leaf in leaves):
        return
    rec: dict = {"solver": solver}
    iters = int(np.asarray(info.iters))
    rec["iters"] = iters
    rec["relres"] = float(np.asarray(info.relres))
    hist = np.asarray(info.history, dtype=np.float64)
    # history buffers are fixed-size (lax.while_loop carry): trim the
    # unwritten tail (zeros past ``iters`` entries; entry 0 is the seed)
    rec["history"] = [float(h) for h in hist[: iters + 1]]
    tiers = getattr(info, "tier_history", None)
    if tiers is not None:
        th = np.asarray(tiers)
        rec["tier_history"] = [int(t) for t in th[: iters + 1]]
    if getattr(info, "promotions", None) is not None:
        rec["promotions"] = int(np.asarray(info.promotions))
    record_trace("solver.trace", rec, solver=solver, **labels)
    inc("solver.solves", solver=solver, **labels)
    inc("solver.iters", iters, solver=solver, **labels)


def report() -> dict:
    """One-call populated snapshot: every registry series plus the live
    plan/jit cache statistics (``kernels.plan.cache_stats()`` — present
    even when recording was off, so the scoreboard always has the cache
    column)."""
    snap = snapshot()
    try:
        from repro.kernels import plan as _kplan

        snap["plan_cache"] = dict(_kplan.cache_stats())
        snap["plan_cache"]["jit_cache_cap"] = _kplan.LRUDict.default_cap()
    except Exception:  # pragma: no cover - plan layer unavailable
        snap["plan_cache"] = {}
    return snap
