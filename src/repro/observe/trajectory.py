"""BENCH trajectory store + noise-aware perf regression gate (§13.3).

Every ``BENCH_*.json`` this repo commits is a snapshot that the next
``make bench-*`` overwrites; nothing ever *compared* two of them.  This
module turns those artifacts into an enforced contract:

* :func:`ingest` flattens one schema-versioned BENCH payload into flat
  records keyed ``(bench, klass, codec, metric)`` + provenance
  (``git_sha``, ``backend``, ``scale``) from the PR-7 ``meta`` header.
  Files *without* that header (pre-PR-7 snapshots) are rejected with
  :class:`SchemaError` — an unversioned number cannot be compared.
* :func:`append` accumulates records into the unified
  ``artifacts/trajectory.jsonl`` (append-only, one JSON record/line).
* :func:`build_baseline` reduces repeated runs to per-key median + IQR;
  :func:`gate` compares a current run against that committed baseline
  (``artifacts/perf_baseline.json``).

The gate statistics (why two thresholds): small-scale CPU timings on a
shared container are noisy — IQR across baseline reps is routinely
10-30% of the median — so a single class drifting 25% is weather, not
a regression.  A metric *regresses* when its ratio to the baseline
median exceeds ``max(rel_tol, iqr_k x IQR/median)``; the gate FAILS
when either (a) >= ``min_classes`` distinct (bench, klass) cells
regress — correlated drift across classes is a real slowdown — or (b)
any single cell exceeds the ``severe_tol`` hard threshold (a 2x
slowdown must never pass just because it only hit one class).
Higher-is-better metrics declare ``"higher"`` in GATED_METRICS and the
ratio is inverted.
"""
from __future__ import annotations

import json
import os

__all__ = [
    "SchemaError", "ingest", "ingest_many", "append", "read_trajectory",
    "build_baseline", "gate", "GATED_METRICS",
]

#: trajectory/baseline record schema (independent of BENCH_SCHEMA_VERSION)
TRAJECTORY_SCHEMA_VERSION = 1

#: payload keys that are never metric rows
_SKIP_KEYS = {"meta", "note", "observe_report", "legacy_dryrun",
              "peak_bandwidth", "telemetry"}
#: row fields that identify rather than measure
_ID_FIELDS = {"klass", "case", "name", "codec", "bench", "status", "cell"}

#: the metrics the regression gate watches, with their direction.
#: Timings gate the hot path; everything else in the trajectory is
#: recorded but advisory.  Keyed by (bench, metric).
GATED_METRICS = {
    ("spmv", "dispatch_cached_s"): "lower",
    ("spmv", "fused_speedup_vs_pr1"): "higher",
    ("roofline", "t_spmv_s"): "lower",
    ("roofline", "achieved_frac_of_peak"): "higher",
}


class SchemaError(ValueError):
    """A BENCH payload without (or with an incompatible) ``meta`` header."""


# ---------------------------------------------------------------------------
# ingest
# ---------------------------------------------------------------------------

def _bench_name(path: str) -> str:
    base = os.path.basename(path)
    if base.startswith("BENCH_") and base.endswith(".json"):
        return base[len("BENCH_"):-len(".json")]
    return os.path.splitext(base)[0]


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _row_records(bench, klass, codec, row: dict):
    sub = row.get("bench")
    name = f"{bench}.{sub}" if sub and sub != bench else bench
    for k, v in row.items():
        if k in _ID_FIELDS or not _is_num(v):
            continue
        yield {"bench": name, "klass": str(klass), "codec": str(codec),
               "metric": k, "value": float(v)}


def _iter_rows(bench: str, payload: dict):
    """Yield flat records from every row-shaped section of a BENCH
    payload: dict-of-dicts sections (``cases``) use the dict key as the
    class, list-of-dicts sections (``rows``, ``cells``, ``frontier``,
    ...) read ``klass``/``case``/``name`` fields."""
    for section, val in payload.items():
        if section in _SKIP_KEYS:
            continue
        if isinstance(val, dict) and val and \
                all(isinstance(v, dict) for v in val.values()):
            for klass, row in val.items():
                yield from _row_records(bench, klass,
                                        row.get("codec", ""), row)
        elif isinstance(val, list):
            for i, row in enumerate(val):
                if not isinstance(row, dict):
                    continue
                klass = row.get("klass") or row.get("case") \
                    or row.get("name") or row.get("cell") or f"row{i}"
                yield from _row_records(bench, klass,
                                        row.get("codec", ""), row)


def ingest(path: str, payload: dict | None = None) -> list[dict]:
    """Flatten one BENCH_*.json into trajectory records.  Requires the
    PR-7 schema-versioned ``meta`` header; raises :class:`SchemaError`
    otherwise (with the fix spelled out)."""
    if payload is None:
        with open(path) as f:
            payload = json.load(f)
    if not isinstance(payload, dict) or "meta" not in payload:
        raise SchemaError(
            f"{path}: no 'meta' header — this is a pre-schema-version "
            "BENCH file; regenerate it with benchmarks.common."
            "save_bench_json (make bench-<name>) so runs are comparable")
    meta = payload["meta"]
    sv = meta.get("schema_version")
    if not isinstance(sv, int) or sv < 1:
        raise SchemaError(
            f"{path}: meta.schema_version={sv!r} — need a versioned "
            "header (>=1) to compare runs; regenerate the file")
    bench = _bench_name(path)
    prov = {"git_sha": meta.get("git_sha", "unknown"),
            "backend": meta.get("backend", "unknown"),
            "scale": payload.get("scale", meta.get("scale", "unknown")),
            "schema_version": sv,
            "generated_at": meta.get("generated_at", "")}
    return [{**rec, **prov} for rec in _iter_rows(bench, payload)]


def ingest_many(paths) -> list[dict]:
    out = []
    for p in paths:
        out.extend(ingest(p))
    return out


def append(records, path: str = "artifacts/trajectory.jsonl") -> int:
    """Append records to the unified trajectory JSONL; returns the count."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    n = 0
    with open(path, "a") as f:
        for rec in records:
            f.write(json.dumps(rec, default=float) + "\n")
            n += 1
    return n


def read_trajectory(path: str = "artifacts/trajectory.jsonl") -> list[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def _key(rec: dict) -> str:
    return "|".join((rec["bench"], rec["klass"], rec["codec"],
                     rec["metric"]))


def _median(xs):
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def _iqr(xs):
    s = sorted(xs)
    n = len(s)
    if n < 2:
        return 0.0
    q1 = s[max(0, int(0.25 * (n - 1)))]
    q3 = s[min(n - 1, int(round(0.75 * (n - 1))))]
    return float(q3 - q1)


def build_baseline(runs, *, gated_only: bool = True,
                   meta: dict | None = None) -> dict:
    """Reduce repeated runs (a list of record-lists, one per rep) to the
    committed baseline: per key, the median across reps plus the
    observed IQR — the dispersion term of the gate threshold."""
    vals: dict = {}
    prov: dict = {}
    for run in runs:
        for rec in run:
            if gated_only and \
                    (rec["bench"].split(".")[0], rec["metric"]) \
                    not in GATED_METRICS:
                continue
            vals.setdefault(_key(rec), []).append(rec["value"])
            prov.setdefault(_key(rec), rec)
    entries = {}
    for k, xs in sorted(vals.items()):
        r = prov[k]
        entries[k] = {
            "bench": r["bench"], "klass": r["klass"], "codec": r["codec"],
            "metric": r["metric"], "median": _median(xs), "iqr": _iqr(xs),
            "n": len(xs), "values": xs,
        }
    base_meta = {"schema_version": TRAJECTORY_SCHEMA_VERSION,
                 "reps": max((e["n"] for e in entries.values()), default=0)}
    if runs and runs[0]:
        base_meta.update({f: runs[0][0].get(f, "unknown")
                          for f in ("git_sha", "backend", "scale")})
    if meta:
        base_meta.update(meta)
    return {"meta": base_meta, "entries": entries}


# ---------------------------------------------------------------------------
# gate
# ---------------------------------------------------------------------------

def gate(current: list[dict], baseline: dict, *, rel_tol: float = 0.25,
         iqr_k: float = 3.0, severe_tol: float = 0.75,
         min_classes: int = 2) -> dict:
    """Compare a current run's records against a committed baseline.

    Returns ``{"ok": bool, "checked": [...], "regressed": [...],
    "severe": [...], "skipped": [...]}`` — every comparison is reported,
    pass or fail, so a green gate still shows its work.  See the module
    docstring for the two-threshold statistics."""
    bmeta = baseline.get("meta", {})
    entries = baseline.get("entries", {})
    checked, regressed, severe, skipped = [], [], [], []
    seen = set()
    for rec in current:
        if (rec["bench"].split(".")[0], rec["metric"]) not in GATED_METRICS:
            continue
        k = _key(rec)
        if k in seen:
            continue
        seen.add(k)
        ent = entries.get(k)
        if ent is None:
            skipped.append({"key": k, "reason": "not in baseline"})
            continue
        if bmeta.get("scale") not in (None, "unknown") and \
                rec.get("scale") not in (None, "unknown") and \
                rec["scale"] != bmeta["scale"]:
            skipped.append({"key": k, "reason":
                            f"scale mismatch ({rec['scale']} vs "
                            f"{bmeta['scale']})"})
            continue
        direction = GATED_METRICS[(rec["bench"].split(".")[0],
                                   rec["metric"])]
        base, iqr = float(ent["median"]), float(ent["iqr"])
        cur = float(rec["value"])
        if base <= 0 or cur <= 0:
            skipped.append({"key": k, "reason": "non-positive value"})
            continue
        ratio = (cur / base) if direction == "lower" else (base / cur)
        regression = ratio - 1.0               # >0 means worse
        noise = iqr_k * iqr / base
        threshold = max(rel_tol, noise)
        row = {"key": k, "bench": rec["bench"], "klass": rec["klass"],
               "codec": rec["codec"], "metric": rec["metric"],
               "direction": direction, "baseline": base, "current": cur,
               "baseline_iqr": iqr, "regression": regression,
               "threshold": threshold, "severe_tol": severe_tol,
               "regressed": bool(regression > threshold),
               "severe": bool(regression > max(severe_tol, threshold))}
        checked.append(row)
        if row["severe"]:
            severe.append(row)
        if row["regressed"]:
            regressed.append(row)
    # correlated drift: count distinct (bench, klass) cells that regressed
    cells = {(r["bench"], r["klass"]) for r in regressed}
    ok = not severe and len(cells) < min_classes
    return {"ok": ok, "checked": checked, "regressed": regressed,
            "severe": severe, "skipped": skipped,
            "regressed_classes": sorted("/".join(c) for c in cells),
            "min_classes": min_classes, "rel_tol": rel_tol,
            "iqr_k": iqr_k, "severe_tol": severe_tol,
            "baseline_meta": bmeta}


def load_baseline(path: str) -> dict:
    with open(path) as f:
        base = json.load(f)
    sv = base.get("meta", {}).get("schema_version")
    if sv != TRAJECTORY_SCHEMA_VERSION:
        raise SchemaError(
            f"{path}: baseline schema_version={sv!r}, expected "
            f"{TRAJECTORY_SCHEMA_VERSION}; refresh with `make "
            "perf-baseline`")
    return base


def save_baseline(baseline: dict, path: str) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(baseline, f, indent=1, default=float)


def main(argv=None) -> int:
    """``python -m repro.observe.trajectory BENCH_*.json`` — ingest into
    the unified trajectory store."""
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+", help="BENCH_*.json files")
    ap.add_argument("--out", default="artifacts/trajectory.jsonl")
    args = ap.parse_args(argv)
    recs = ingest_many(args.files)
    n = append(recs, args.out)
    print(f"[trajectory] appended {n} records from {len(args.files)} "
          f"files -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
