"""DistSpMVPlan: one jitted shard_map dispatch for distributed SpMV
(DESIGN.md §7.3).

Layering mirrors the single-device engine (``kernels/plan.py``): every
host-side decision happens once at build time, the hot path is a single
jitted call.

* :func:`build_operands` partitions the matrix (``partition.py``), builds
  one σ-sorted-per-partition PackSELL block pair (local + remote) per shard,
  pads all shards to one static ``[S, w, C]`` shape
  (``core.packsell.pad_uniform``), builds a concrete
  :class:`~repro.kernels.plan.SpMVPlan` per block, and **stacks** the plans'
  device operands (packed words, cursor caches, inverse σ-permutations)
  along a leading shard axis — plus the halo-exchange index maps
  (``halo.py``) and a row-validity mask.
* :class:`DistSpMVPlan` places the stacked operands on a 1-D device mesh
  and jits ONE ``shard_map`` dispatch per entry point (spmv / spmm / each
  exchange mode). Inside the mapped body each shard slices its row of every
  operand and reuses the template plan via
  :meth:`~repro.kernels.plan.SpMVPlan.execute_with` — plan reuse inside
  shard_map, no per-trace replanning.
* The body issues the halo gather FIRST, then the local-block matvec (which
  depends only on resident data), then the remote-block matvec: XLA's
  scheduler can overlap the collective with the local compute, the
  communication/computation overlap of the Kreutzer-et-al. recipe.

``reference_spmv`` replays the exact same stacked operands shard-by-shard
on the host (no mesh, no collectives) — the oracle that lets partition and
map construction be tested on a single device.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import packsell as pk
from repro.kernels import plan as kplan
from repro.parallel.sharding import make_shard_mesh, shard_map_compat

from . import halo as dh
from . import partition as dp

_ceil_to = pk._ceil_to


@dataclasses.dataclass
class DistOperands:
    """Mesh-independent distributed operands: the partition, the halo maps,
    the per-shard padded PackSELL blocks, their template plans, and every
    stacked host array the shard_map body consumes (leading dim = shard)."""

    part: dp.RowPartition
    maps: dh.HaloMaps
    n: int
    n_pad: int                 # padded rows == padded local x length
    h_pad: int                 # padded halo buffer length (0: no halo)
    C: int
    sigma: int
    D: int
    codec: str
    host: dict                 # str -> np.ndarray [P, ...]
    mats_loc: list             # per-shard padded PackSELLMatrix (host)
    mats_rem: list             # per-shard padded PackSELLMatrix (or [])
    tpl_loc: kplan.SpMVPlan    # template plan (identical statics ∀ shards)
    tpl_rem: kplan.SpMVPlan | None

    # -- vector layout (host) ----------------------------------------------
    def stack_vector(self, v: np.ndarray) -> np.ndarray:
        """Global [n(, nb)] → stacked padded [P, n_pad(, nb)] (zeros pad)."""
        v = np.asarray(v)
        out = np.zeros((self.part.n_shards, self.n_pad) + v.shape[1:],
                       v.dtype)
        for p in range(self.part.n_shards):
            r0, r1 = self.part.rows_of(p)
            out[p, :r1 - r0] = v[r0:r1]
        return out

    def unstack_vector(self, ys: np.ndarray) -> np.ndarray:
        """Stacked padded [P, n_pad(, nb)] → global [n(, nb)]."""
        ys = np.asarray(ys)
        return np.concatenate([ys[p, :c]
                               for p, c in enumerate(self.part.counts)])

    # -- the per-shard SpMV body -------------------------------------------
    def _view(self, ops: dict, kind: str) -> pk.PackSELLMatrix:
        """A PackSELLMatrix over this shard's operand slices. Only fields
        the execution path reads are meaningful; accounting fields are 0."""
        return pk.PackSELLMatrix(
            packs=(ops[f"pack_{kind}"],), d0s=(ops[f"d0_{kind}"],),
            outrows=(ops[f"outrow_{kind}"],),
            maxcols=(jnp.zeros_like(ops[f"d0_{kind}"]),),
            perm=jnp.zeros((1,), jnp.uint8),
            n=self.n_pad, m=self.n_pad if kind == "loc" else self.h_pad,
            C=self.C, sigma=self.sigma, D=self.D, codec_name=self.codec,
            k_left=0, nnz=0, n_dummy=0, words_sell_padded=0,
            words_bucketed=0)

    def _dev_dict(self, ops: dict, kind: str) -> dict:
        cols = ops.get(f"cols_{kind}")
        return {"cols": None if cols is None else (cols,),
                "inv": ops[f"inv_{kind}"], "outrow": ops[f"outrow_{kind}"]}

    def shard_body(self, ops: dict, x: jnp.ndarray, *,
                   axis_name: str | None, mode: str,
                   multi_rhs: bool = False,
                   x_halo: jnp.ndarray | None = None) -> jnp.ndarray:
        """One shard's ``y_p = A_loc x_loc + A_rem x_halo`` (masked).

        Runs inside a shard_map body (``axis_name`` names the mesh axis the
        collectives run over) or standalone when ``x_halo`` is supplied
        (:func:`reference_spmv`). The gather is issued before the local
        matvec so the collective can overlap the resident-block compute.
        """
        xc = x.astype(jnp.float32)
        if self.h_pad > 0 and x_halo is None:
            x_halo = dh.gather_halo(
                xc, ops, axis_name=axis_name, n_shards=self.part.n_shards,
                h_pad=self.h_pad, mode=mode)
        y = self.tpl_loc.execute_with(
            self._view(ops, "loc"), self._dev_dict(ops, "loc"), xc,
            multi_rhs=multi_rhs)
        if self.h_pad > 0:
            y = y + self.tpl_rem.execute_with(
                self._view(ops, "rem"), self._dev_dict(ops, "rem"),
                x_halo.astype(jnp.float32), multi_rhs=multi_rhs)
        mask = ops["rowmask"]
        return y * (mask[:, None] if multi_rhs else mask)


def build_operands(a: sp.csr_matrix, n_shards: int, *, C: int = 32,
                   sigma: int = 256, D: int = 15,
                   codec: str = "fp16") -> DistOperands:
    """Partition ``a`` over ``n_shards`` row blocks and build the stacked
    distributed operands (host-side; no devices touched)."""
    a = a.tocsr()
    n = a.shape[0]
    part = dp.partition_rows(n, n_shards)
    n_pad = _ceil_to(max(int(part.counts.max(initial=0)), 1), C)
    splits, h_pad = dp.split_csr(a, part, n_pad=n_pad)
    maps = dh.build_halo_maps(part, [s.halo_cols for s in splits],
                              n_pad=n_pad, h_pad=h_pad)
    S_pad = n_pad // C

    def build_blocks(blocks):
        raw = [pk.from_csr(b, C=C, sigma=sigma, D=D, codec=codec,
                           bucket_strategy="uniform", device=False)
               for b in blocks]
        w = max(int(m.packs[0].shape[1]) for m in raw)
        mats = [pk.pad_uniform(m, n_slices=S_pad, width=w, n_rows=n_pad,
                               device=False) for m in raw]
        plans = [kplan.build_plan(m, force="jnp") for m in mats]
        return mats, plans

    mats_loc, plans_loc = build_blocks([s.a_loc for s in splits])
    host = {
        "pack_loc": np.stack([np.asarray(m.packs[0]) for m in mats_loc]),
        "d0_loc": np.stack([np.asarray(m.d0s[0]) for m in mats_loc]),
        "outrow_loc": np.stack([np.asarray(p.outrow_cat)
                                for p in plans_loc]),
        "inv_loc": np.stack([np.asarray(p.inv_cat) for p in plans_loc]),
        "rowmask": (np.arange(n_pad)[None, :]
                    < part.counts[:, None]).astype(np.float32),
        "halo_src": maps.halo_src,
        "send_idx": maps.send_idx,
        "recv_slot": maps.recv_slot,
    }
    if plans_loc[0].cols is not None:
        host["cols_loc"] = np.stack([np.asarray(p.cols[0])
                                     for p in plans_loc])
    mats_rem, tpl_rem = [], None
    if h_pad > 0:
        mats_rem, plans_rem = build_blocks([s.a_rem for s in splits])
        tpl_rem = plans_rem[0]
        host["pack_rem"] = np.stack([np.asarray(m.packs[0])
                                     for m in mats_rem])
        host["d0_rem"] = np.stack([np.asarray(m.d0s[0]) for m in mats_rem])
        host["outrow_rem"] = np.stack([np.asarray(p.outrow_cat)
                                       for p in plans_rem])
        host["inv_rem"] = np.stack([np.asarray(p.inv_cat)
                                    for p in plans_rem])
        if plans_rem[0].cols is not None:
            host["cols_rem"] = np.stack([np.asarray(p.cols[0])
                                         for p in plans_rem])
    return DistOperands(part=part, maps=maps, n=n, n_pad=n_pad, h_pad=h_pad,
                        C=C, sigma=sigma, D=D, codec=codec, host=host,
                        mats_loc=mats_loc, mats_rem=mats_rem,
                        tpl_loc=plans_loc[0], tpl_rem=tpl_rem)


def reference_spmv(ops: DistOperands, x, mode: str = "all_gather",
                   multi_rhs: bool = False) -> np.ndarray:
    """Host oracle: replay the stacked operands shard-by-shard with the
    host-side exchange reference — no mesh, no collectives. Validates the
    partition, the maps, and the padded blocks on a single device."""
    xs = ops.stack_vector(np.asarray(x, np.float32))
    xh = (dh.gather_halo_reference(xs, ops.maps, mode)
          if ops.h_pad > 0 else None)
    ys = []
    for p in range(ops.part.n_shards):
        ops_p = {k: jnp.asarray(v[p]) for k, v in ops.host.items()}
        y = ops.shard_body(
            ops_p, jnp.asarray(xs[p]), axis_name=None, mode=mode,
            multi_rhs=multi_rhs,
            x_halo=None if xh is None else jnp.asarray(xh[p]))
        ys.append(np.asarray(y))
    return ops.unstack_vector(np.stack(ys))


class DistSpMVPlan:
    """Stacked distributed operands bound to a 1-D device mesh, with one
    jitted ``shard_map`` dispatch per (entry point, exchange mode).

    Entry points take and return **global** vectors (``spmv`` / ``spmm``)
    or stay in the stacked-sharded layout (``spmv_sharded`` — solvers and
    benchmarks chain matvecs without host round-trips). ``shard_vector`` /
    ``unshard_vector`` convert between the two.
    """

    def __init__(self, ops: DistOperands, mesh, *,
                 exchange: str = "ppermute"):
        if len(mesh.axis_names) != 1:
            raise ValueError(f"need a 1-D mesh, got axes {mesh.axis_names}")
        if mesh.devices.size != ops.part.n_shards:
            raise ValueError(
                f"mesh has {mesh.devices.size} devices but operands were "
                f"built for {ops.part.n_shards} shards")
        if exchange not in dh.EXCHANGE_MODES:
            raise ValueError(f"exchange={exchange!r} not in "
                             f"{dh.EXCHANGE_MODES}")
        self.ops = ops
        self.mesh = mesh
        self.axis_name = mesh.axis_names[0]
        self.exchange = exchange
        shard = NamedSharding(mesh, P(self.axis_name))
        self.dev = {k: jax.device_put(v, shard)
                    for k, v in ops.host.items()}
        self._fns: dict = {}

    # -- convenience passthroughs ------------------------------------------
    @property
    def n(self) -> int:
        return self.ops.n

    @property
    def n_shards(self) -> int:
        return self.ops.part.n_shards

    @property
    def dev_specs(self):
        """in_specs pytree for the stacked operands (leading shard axis)."""
        return jax.tree.map(lambda _: P(self.axis_name), self.dev)

    def shard_vector(self, v) -> jnp.ndarray:
        """Global [n(, nb)] → device-sharded stacked [P, n_pad(, nb)]."""
        if isinstance(v, jax.core.Tracer):
            return self._shard_traced(v)
        return jax.device_put(
            self.ops.stack_vector(np.asarray(v)),
            NamedSharding(self.mesh, P(self.axis_name)))

    def unshard_vector(self, ys) -> jnp.ndarray:
        if isinstance(ys, jax.core.Tracer):
            return self._unshard_traced(ys)
        return jnp.asarray(self.ops.unstack_vector(np.asarray(ys)))

    def _shard_traced(self, v: jnp.ndarray) -> jnp.ndarray:
        """jnp mirror of ``stack_vector`` (static slices/pads only), used
        when the global vector is a tracer — a solver's loop-carried
        iterate. The jitted shard_map dispatch inlines into the enclosing
        trace, so ``dist_<codec>`` matvecs drop into unchanged solvers."""
        parts = []
        for p in range(self.n_shards):
            r0, r1 = self.ops.part.rows_of(p)
            pad = [(0, self.ops.n_pad - (r1 - r0))] + [(0, 0)] * (v.ndim - 1)
            parts.append(jnp.pad(v[r0:r1], pad))
        return jnp.stack(parts)

    def _unshard_traced(self, ys: jnp.ndarray) -> jnp.ndarray:
        return jnp.concatenate(
            [ys[p, :int(c)] for p, c in enumerate(self.ops.part.counts)])

    # -- jitted dispatch ----------------------------------------------------
    def cached_fn(self, key, builder):
        """Build-once cache for jitted shard_map dispatches (the distributed
        analogue of ``SpMVPlan._dispatch``; solvers park theirs here too)."""
        fn = self._fns.get(key)
        if fn is None:
            fn = builder()
            self._fns[key] = fn
        return fn

    def _spmv_fn(self, mode: str, multi_rhs: bool):
        def build():
            ax = self.axis_name

            def body(dev, xs):
                o = jax.tree.map(lambda leaf: leaf[0], dev)
                y = self.ops.shard_body(o, xs[0], axis_name=ax, mode=mode,
                                        multi_rhs=multi_rhs)
                return y[None]

            f = shard_map_compat(body, self.mesh,
                                 in_specs=(self.dev_specs, P(ax)),
                                 out_specs=P(ax))
            return jax.jit(f)

        return self.cached_fn(("spmm" if multi_rhs else "spmv", mode), build)

    def spmv_sharded(self, xs: jnp.ndarray, *, mode: str | None = None,
                     multi_rhs: bool = False) -> jnp.ndarray:
        """Stacked-sharded [P, n_pad(, nb)] → same layout; one dispatch."""
        mode = mode or self.exchange
        if mode not in dh.EXCHANGE_MODES:
            # validate here, not only in gather_halo: halo-free partitions
            # (h_pad == 0) never reach the gather
            raise ValueError(f"mode={mode!r} not in {dh.EXCHANGE_MODES}")
        return self._spmv_fn(mode, multi_rhs)(self.dev, xs)

    def spmv(self, x, *, mode: str | None = None) -> jnp.ndarray:
        """y = A @ x for a global [n] vector (shard → dispatch → unshard)."""
        return self.unshard_vector(self.spmv_sharded(
            self.shard_vector(x), mode=mode))

    def spmm(self, x, *, mode: str | None = None) -> jnp.ndarray:
        """Y = A @ X for a global [n, nb] block (multi-RHS path: one pass
        over each shard's packed words serves all nb right-hand sides)."""
        if np.ndim(x) != 2:
            raise ValueError(f"spmm expects [n, nb], got {np.shape(x)}")
        return self.unshard_vector(self.spmv_sharded(
            self.shard_vector(x), mode=mode, multi_rhs=True))

    def warmup(self, nb: int = 0, modes=None) -> "DistSpMVPlan":
        """Pre-trace the dispatches (serving-engine contract: the first
        tick pays neither tracing nor plan construction)."""
        for mode in (modes or (self.exchange,)):
            jax.block_until_ready(
                self.spmv(np.zeros(self.n, np.float32), mode=mode))
            if nb:
                jax.block_until_ready(
                    self.spmm(np.zeros((self.n, nb), np.float32), mode=mode))
        return self

    # -- accounting ---------------------------------------------------------
    def memory_stats(self) -> dict:
        """Fleet memory + communication profile: per-shard PackSELL stats
        aggregated over local and remote blocks, plus halo traffic."""
        st = pk.aggregate_memory_stats(self.ops.mats_loc + self.ops.mats_rem)
        st.update(
            shards=self.n_shards, n_pad=self.ops.n_pad, h_pad=self.ops.h_pad,
            halo_entries=int(self.ops.maps.counts.sum()),
            halo_k_max=self.ops.maps.k_max, exchange=self.exchange)
        return st


def build_dist_plan(a: sp.csr_matrix, n_shards: int | None = None, *,
                    mesh=None, axis_name: str = "shards",
                    exchange: str = "ppermute", C: int = 32,
                    sigma: int = 256, D: int = 15, codec: str = "fp16",
                    devices=None) -> DistSpMVPlan:
    """Partition ``a`` across a 1-D device mesh and build the jitted
    distributed plan (the slow path — run once per matrix, like
    ``kernels.plan.build_plan``). With no mesh given, one shard per visible
    local device."""
    if mesh is None:
        mesh = make_shard_mesh(n_shards, axis_name=axis_name,
                               devices=devices)
    ops = build_operands(a, int(mesh.devices.size), C=C, sigma=sigma, D=D,
                         codec=codec)
    return DistSpMVPlan(ops, mesh, exchange=exchange)
