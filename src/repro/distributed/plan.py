"""DistSpMVPlan: one jitted shard_map dispatch for distributed SpMV
(DESIGN.md §7.3, §9).

Layering mirrors the single-device engine (``kernels/plan.py``): every
host-side decision happens once at build time, the hot path is a single
jitted call. Since PR 4 the per-shard execution body is the shared
block-composition engine (:class:`~repro.kernels.composite.CompositePlan`,
DESIGN.md §9) — the local/remote block pair is a two-**term** composite
(local members consume the resident x-block, remote members the
halo-exchange pre-stage output; each term ends in ONE inverse-permutation
gather, terms add). Members may themselves be per-precision-class blocks
(``classes=`` / ``pplan=``), which is what makes **distributed ×
mixed-precision** compose: ``dist_mixed:<budget>`` operators and
``cg.adaptive_pcg_dist``.

* :func:`build_composite_operands` partitions the matrix
  (``partition.py``), builds per-shard per-class blocks (PackSELL for
  packed codecs, uncompressed SELL for fp32/fp64), pads every member to
  one static ``[S, w, C]`` shape across shards
  (``core.packsell.pad_uniform`` / ``core.sell.pad_uniform``), and
  **stacks** each member's device operands along a leading shard axis —
  plus per-term inverse permutations, the halo-exchange index maps
  (``halo.py``) and a row-validity mask.
* :class:`DistSpMVPlan` places the stacked operands on a 1-D device mesh
  and jits ONE ``shard_map`` dispatch per entry point. Inside the mapped
  body each shard slices its row of every operand and reuses the template
  composite via :meth:`~repro.kernels.composite.CompositePlan.execute_with`
  — plan reuse inside shard_map, no per-trace replanning.
* The body issues the halo gather FIRST (the composite *pre-stage*), then
  the members: XLA's scheduler can overlap the collective with the local
  compute, the communication/computation overlap of the Kreutzer-et-al.
  recipe.
* :func:`build_dist_tiers` stacks one member set per codec tier over ONE
  shared partition — the distributed tier ladder ``adaptive_pcg_dist``
  promotes through via ``lax.switch``.

``reference_spmv`` replays the exact same stacked operands shard-by-shard
on the host (no mesh, no collectives) — the oracle that lets partition and
map construction be tested on a single device.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import packsell as pk
from repro.core import sell as sl
from repro.kernels import composite as kc
from repro.kernels import plan as kplan
from repro.observe import metrics as _obs
from repro.parallel.sharding import make_shard_mesh, shard_map_compat

from . import halo as dh
from . import partition as dp

_ceil_to = pk._ceil_to

#: stacked-operand keys shared by every member set (halo maps + row mask)
SHARED_KEYS = ("rowmask", "halo_src", "send_idx", "recv_slot")


@dataclasses.dataclass
class DistMember:
    """One composite member's per-shard blocks + stacked host arrays.

    All shards share one static block shape (padded), one codec, one term
    and one input index; the per-shard ``rows_local`` maps (block row →
    shard-local row) are baked into the stacked per-term inverse
    permutations.
    """

    key: str                   # host-dict prefix, e.g. 'm0'
    fmt: str                   # 'packsell' | 'sell'
    codec: str
    D: int
    term: int                  # 0 = local, 1 = remote
    x_index: int               # 0 = x_loc, 1 = x_halo (pre-stage output)
    label: str
    mats: list                 # per-shard padded host blocks
    plans: list | None         # per-shard SpMVPlans (PackSELL members)
    rows_local: list           # per-shard int64 shard-local row ids
    #                            (None = all shard rows, identity map)

    def n_rows(self) -> int:
        """Rows this member covers, summed over shards."""
        return sum(int(m.n) if r is None else len(r)
                   for r, m in zip(self.rows_local, self.mats))

    def shard_member(self, p: int) -> kc.CompositeMember:
        """This member's shard-p block as a CompositeMember (shard 0 is
        the composite template; the others feed inverse-perm builds)."""
        return kc.CompositeMember(
            mat=self.mats[p],
            plan=None if self.plans is None else self.plans[p],
            codec=self.codec, D=self.D, rows=self.rows_local[p],
            x_index=self.x_index, term=self.term, label=self.label)

    def host_arrays(self) -> dict:
        """Stacked [P, ...] device operands for the shard_map body.

        With the fused checkpoint stream (the default decode cache) the
        member ships ONLY ``{k}_fwords``/``{k}_fckpt`` — the packs/d0s are
        not read by the fused execution body, so stacking them would
        double the fleet's device footprint for nothing."""
        k = self.key
        if self.fmt == "packsell":
            if self.plans[0].fused is not None:
                w3, ck = zip(*(p.fused for p in self.plans))
                return {f"{k}_fwords": np.stack([np.asarray(w) for w in w3]),
                        f"{k}_fckpt": np.stack([np.asarray(c) for c in ck])}
            out = {f"{k}_pack": np.stack([np.asarray(m.packs[0])
                                          for m in self.mats]),
                   f"{k}_d0": np.stack([np.asarray(m.d0s[0])
                                        for m in self.mats])}
            if self.plans[0].cols is not None:
                out[f"{k}_cols"] = np.stack([np.asarray(p.cols[0])
                                             for p in self.plans])
            return out
        return {f"{k}_val": np.stack([np.asarray(m.vals[0])
                                      for m in self.mats]),
                f"{k}_col": np.stack([np.asarray(m.cols[0])
                                      for m in self.mats])}


def _normalize_classes(classes) -> list:
    """Accept ``(codec, D, rows|None)`` tuples or PrecisionClass objects."""
    out = []
    for c in classes:
        if isinstance(c, (tuple, list)):
            codec, D, rows = (c + (None,))[:3] if isinstance(c, tuple) \
                else (list(c) + [None])[:3]
        else:
            codec, D, rows = c.codec, c.D, c.rows
        out.append((codec, int(D),
                    None if rows is None else np.asarray(rows, np.int64)))
    return out


def _build_dist_member(idx: int, blocks, rows_local, codec: str, D: int, *,
                       C: int, sigma: int, term: int,
                       x_index: int, label: str) -> DistMember:
    """Build one member's per-shard blocks padded to a common shape."""
    if codec in kc.SELL_CODECS:
        vd = {"fp32": "float32", "fp64": "float64"}[codec]
        raw = [sl.from_csr(b, C=C, sigma=sigma, value_dtype=vd,
                           bucket_strategy="uniform", device=False)
               for b in blocks]
        S = max(int(m.vals[0].shape[0]) for m in raw)
        w = max(int(m.vals[0].shape[1]) for m in raw)
        mats = [sl.pad_uniform(m, n_slices=S, width=w, device=False)
                for m in raw]
        plans = None
    else:
        raw = [pk.from_csr(b, C=C, sigma=sigma, D=D, codec=codec,
                           bucket_strategy="uniform", device=False)
               for b in blocks]
        S = max(int(m.packs[0].shape[0]) for m in raw)
        w = max(int(m.packs[0].shape[1]) for m in raw)
        mats = [pk.pad_uniform(m, n_slices=S, width=w, device=False)
                for m in raw]
        # fused_trim=False: the fused layout must be shape-derived so all
        # shards share one static layout (shapes are pad_uniform'd equal).
        # REPRO_SPMV_POLICY=fused rides the fused Pallas kernel inside the
        # shard bodies; the default stays the jnp fused-stream body.
        force_v = "fused" if kplan._env_policy() == "fused" else "jnp"
        plans = [kplan.build_plan(m, force=force_v, fused_trim=False)
                 for m in mats]
        # ... but the ENCODING is still data-dependent (column-span
        # overflow falls back per shard), so any mismatch demotes the
        # whole member to the full cursor cache
        lays = {(None if p.fused_layout is None else
                 (p.fused_layout.wr, p.fused_layout.encoding))
                for p in plans}
        if len(lays) > 1:
            plans = [kplan.build_plan(m, force="jnp", decode_cache="full")
                     for m in mats]
    return DistMember(key=f"m{idx}", fmt="sell" if plans is None
                      else "packsell", codec=codec, D=D, term=term,
                      x_index=x_index, label=label, mats=mats, plans=plans,
                      rows_local=rows_local)


@dataclasses.dataclass
class DistOperands:
    """Mesh-independent distributed operands: the partition, the halo maps,
    the per-shard member blocks, the shard-0 composite template, and every
    stacked host array the shard_map body consumes (leading dim = shard)."""

    part: dp.RowPartition
    maps: dh.HaloMaps
    n: int
    n_pad: int                 # padded rows == padded local x length
    h_pad: int                 # padded halo buffer length (0: no halo)
    C: int
    sigma: int
    D: int
    codec: str                 # 'mixed' for multi-class member sets
    classes: list              # [(codec, D, rows|None)] build record
    host: dict                 # str -> np.ndarray [P, ...]
    members: list              # list[DistMember]
    tpl: kc.CompositePlan      # shard-0 template (identical statics ∀ shards)

    # -- back-compat views --------------------------------------------------
    @property
    def mats_loc(self) -> list:
        """Per-shard local blocks, flattened over members."""
        return [m for dm in self.members if dm.x_index == 0
                for m in dm.mats]

    @property
    def mats_rem(self) -> list:
        return [m for dm in self.members if dm.x_index == 1
                for m in dm.mats]

    # -- vector layout (host) ----------------------------------------------
    def stack_vector(self, v: np.ndarray) -> np.ndarray:
        """Global [n(, nb)] → stacked padded [P, n_pad(, nb)] (zeros pad)."""
        v = np.asarray(v)
        out = np.zeros((self.part.n_shards, self.n_pad) + v.shape[1:],
                       v.dtype)
        for p in range(self.part.n_shards):
            r0, r1 = self.part.rows_of(p)
            out[p, :r1 - r0] = v[r0:r1]
        return out

    def unstack_vector(self, ys: np.ndarray) -> np.ndarray:
        """Stacked padded [P, n_pad(, nb)] → global [n(, nb)]."""
        ys = np.asarray(ys)
        return np.concatenate([ys[p, :c]
                               for p, c in enumerate(self.part.counts)])

    # -- the per-shard SpMV body -------------------------------------------
    def _member_view(self, dm: DistMember, ops: dict):
        """A format-block view over this shard's operand slices. Only the
        fields the composite execution path reads are meaningful;
        accounting fields are 0 / shard-0 statics."""
        t = dm.mats[0]
        if dm.fmt == "packsell":
            if f"{dm.key}_fwords" in ops:
                # fused checkpoint stream: the execution body never reads
                # the packs, so the view carries placeholder leaves
                d0 = jnp.zeros((1,), jnp.int32)
            else:
                d0 = ops[f"{dm.key}_d0"]
            pack = ops.get(f"{dm.key}_pack",
                           jnp.zeros((1, 1, 1), jnp.uint32))
            return pk.PackSELLMatrix(
                packs=(pack,), d0s=(d0,), outrows=(d0,),
                maxcols=(jnp.zeros_like(d0),),
                perm=jnp.zeros((1,), jnp.uint8),
                n=t.n, m=t.m, C=self.C, sigma=self.sigma, D=dm.D,
                codec_name=dm.codec, k_left=0, nnz=0, n_dummy=0,
                words_sell_padded=0, words_bucketed=0)
        return sl.SELLMatrix(
            vals=(ops[f"{dm.key}_val"],), cols=(ops[f"{dm.key}_col"],),
            outrows=(jnp.zeros((1,), jnp.int32),),
            perm=jnp.zeros((1,), jnp.uint8),
            n=t.n, m=t.m, C=self.C, sigma=self.sigma,
            value_dtype=t.value_dtype, nnz=0, words_sell_padded=0,
            words_bucketed=0)

    def _member_dev(self, dm: DistMember, ops: dict) -> dict:
        if dm.fmt != "packsell":
            return {}
        cols = ops.get(f"{dm.key}_cols")
        fw = ops.get(f"{dm.key}_fwords")
        return {"cols": None if cols is None else (cols,),
                "inv": None, "outrow": None,
                "fused": None if fw is None
                else (fw, ops[f"{dm.key}_fckpt"])}

    def shard_body(self, ops: dict, x: jnp.ndarray, *,
                   axis_name: str | None, mode: str,
                   multi_rhs: bool = False,
                   x_halo: jnp.ndarray | None = None,
                   shared: dict | None = None) -> jnp.ndarray:
        """One shard's ``y_p = Σ_term (gather ∘ concat ∘ members)`` via the
        composite template (masked).

        Runs inside a shard_map body (``axis_name`` names the mesh axis the
        collectives run over) or standalone when ``x_halo`` is supplied
        (:func:`reference_spmv`, and the tier ladder whose pre-stage is
        hoisted out of the ``lax.switch``). The halo gather — the composite
        *pre-stage* — is issued before the member matvecs so the collective
        can overlap the resident-block compute. ``shared`` optionally
        supplies the halo maps / row mask when this member set's host dict
        carries only member arrays (the tier-ladder layout).
        """
        sh = ops if shared is None else shared
        xs = (x,)
        if self.h_pad > 0:
            if x_halo is None:
                x_halo = dh.gather_halo(
                    x, sh, axis_name=axis_name,
                    n_shards=self.part.n_shards, h_pad=self.h_pad,
                    mode=mode)
            xs = (x, x_halo)
        mats = tuple(self._member_view(dm, ops) for dm in self.members)
        devs = tuple(self._member_dev(dm, ops) for dm in self.members)
        invs = tuple(ops[f"inv{t}"] for t in range(self.tpl.n_terms))
        y = self.tpl.execute_with(mats, devs, invs, xs, multi_rhs=multi_rhs)
        mask = sh["rowmask"]
        return y * (mask[:, None] if multi_rhs else mask)


@dataclasses.dataclass
class _PartitionCtx:
    """One partition/split/halo-map build, shared by every member set
    over the same matrix and fleet size (the tier ladder builds T+1 sets;
    the CSR split and map construction only need to happen once)."""

    part: dp.RowPartition
    splits: list
    maps: dh.HaloMaps
    n_pad: int
    h_pad: int


def _partition_context(a: sp.csr_matrix, n_shards: int,
                       C: int) -> _PartitionCtx:
    part = dp.partition_rows(a.shape[0], n_shards)
    n_pad = _ceil_to(max(int(part.counts.max(initial=0)), 1), C)
    splits, h_pad = dp.split_csr(a, part, n_pad=n_pad)
    maps = dh.build_halo_maps(part, [s.halo_cols for s in splits],
                              n_pad=n_pad, h_pad=h_pad)
    return _PartitionCtx(part=part, splits=splits, maps=maps, n_pad=n_pad,
                         h_pad=h_pad)


def build_composite_operands(a: sp.csr_matrix, n_shards: int, *,
                             classes, C: int = 32, sigma: int = 256,
                             ctx: _PartitionCtx | None = None
                             ) -> DistOperands:
    """Partition ``a`` over ``n_shards`` row blocks and build the stacked
    member operands for a per-class composite (host-side; no devices
    touched). ``classes``: ``(codec, D, rows|None)`` tuples or
    ``PrecisionClass`` objects whose row sets partition the global rows
    (``rows=None`` = all rows, single-class only). ``ctx`` reuses a
    precomputed :func:`_partition_context` (tier ladders share one)."""
    a = a.tocsr()
    n = a.shape[0]
    norm = _normalize_classes(classes)
    count = np.zeros(n, np.int64)
    for codec, D, rows in norm:
        if rows is None:
            count += 1
        else:
            count[rows] += 1
    if np.any(count != 1):
        raise ValueError(
            f"precision classes cover {int((count > 0).sum())} of {n} rows "
            f"(max multiplicity {int(count.max(initial=0))}); the classes "
            f"must partition the rows")

    ctx = ctx or _partition_context(a, n_shards, C)
    part, splits, maps = ctx.part, ctx.splits, ctx.maps
    n_pad, h_pad = ctx.n_pad, ctx.h_pad

    host = {
        "rowmask": (np.arange(n_pad)[None, :]
                    < part.counts[:, None]).astype(np.float32),
        "halo_src": maps.halo_src,
        "send_idx": maps.send_idx,
        "recv_slot": maps.recv_slot,
    }
    members: list[DistMember] = []
    sides = [("loc", 0, 0)] + ([("rem", 1, 1)] if h_pad > 0 else [])
    for side, term, x_index in sides:
        for codec, D, rows in norm:
            mask = np.ones(n, bool) if rows is None else \
                np.zeros(n, bool)
            if rows is not None:
                mask[rows] = True
            blocks, rows_local = [], []
            for p in range(part.n_shards):
                r0, r1 = part.rows_of(p)
                src = (splits[p].a_loc if side == "loc"
                       else splits[p].a_rem)
                if rows is None:
                    # all-rows class: the split block IS the member block
                    # (identity row map; no CSR fancy-index copy)
                    blocks.append(src)
                    rows_local.append(None)
                else:
                    rl = np.nonzero(mask[r0:r1])[0].astype(np.int64)
                    blocks.append(src[rl])
                    rows_local.append(rl)
            members.append(_build_dist_member(
                len(members), blocks, rows_local, codec, D, C=C,
                sigma=sigma, term=term, x_index=x_index,
                label=f"{side}:{codec}" + ("" if codec in kc.SELL_CODECS
                                           else f"/D={D}")))
    for dm in members:
        host.update(dm.host_arrays())

    n_terms = 1 + (1 if h_pad > 0 else 0)
    for t in range(n_terms):
        tms = [dm for dm in members if dm.term == t]
        host[f"inv{t}"] = np.stack([
            kc.term_inverse(n_pad, [dm.shard_member(p) for dm in tms],
                            allow_uncovered=True, term=t)
            for p in range(part.n_shards)])

    tpl = kc.CompositePlan([dm.shard_member(0) for dm in members],
                           n=n_pad, m=n_pad, allow_uncovered=True,
                           name="dist")
    codec0, D0 = ((norm[0][0], norm[0][1]) if len(norm) == 1
                  else ("mixed", 0))
    return DistOperands(part=part, maps=maps, n=n, n_pad=n_pad, h_pad=h_pad,
                        C=C, sigma=sigma, D=D0, codec=codec0,
                        classes=norm, host=host, members=members, tpl=tpl)


def build_operands(a: sp.csr_matrix, n_shards: int, *, C: int = 32,
                   sigma: int = 256, D: int = 15,
                   codec: str = "fp16") -> DistOperands:
    """Single-class distributed operands (the historical entry point): one
    local + one remote member per shard at a fleet-wide ``(codec, D)``."""
    return build_composite_operands(a, n_shards,
                                    classes=[(codec, D, None)],
                                    C=C, sigma=sigma)


def reference_spmv(ops: DistOperands, x, mode: str = "all_gather",
                   multi_rhs: bool = False) -> np.ndarray:
    """Host oracle: replay the stacked operands shard-by-shard with the
    host-side exchange reference — no mesh, no collectives. Validates the
    partition, the maps, and the padded member blocks on a single device."""
    xs = ops.stack_vector(np.asarray(x, np.float32))
    xh = (dh.gather_halo_reference(xs, ops.maps, mode)
          if ops.h_pad > 0 else None)
    ys = []
    for p in range(ops.part.n_shards):
        ops_p = {k: jnp.asarray(v[p]) for k, v in ops.host.items()}
        y = ops.shard_body(
            ops_p, jnp.asarray(xs[p]), axis_name=None, mode=mode,
            multi_rhs=multi_rhs,
            x_halo=None if xh is None else jnp.asarray(xh[p]))
        ys.append(np.asarray(y))
    return ops.unstack_vector(np.stack(ys))


class _MeshBound:
    """Shared mesh-binding plumbing: device placement, in_specs, vector
    shard/unshard, and the build-once cache for jitted shard_map
    dispatches (``DistSpMVPlan`` and the tier ladder both use it)."""

    def _bind(self, ops_like, mesh, host: dict) -> None:
        if len(mesh.axis_names) != 1:
            raise ValueError(f"need a 1-D mesh, got axes {mesh.axis_names}")
        if mesh.devices.size != ops_like.part.n_shards:
            raise ValueError(
                f"mesh has {mesh.devices.size} devices but operands were "
                f"built for {ops_like.part.n_shards} shards")
        self._ops0 = ops_like
        self.mesh = mesh
        self.axis_name = mesh.axis_names[0]
        shard = NamedSharding(mesh, P(self.axis_name))
        self.dev = jax.tree.map(
            lambda v: jax.device_put(v, shard), host)
        self._fns: dict = {}

    @property
    def n(self) -> int:
        return self._ops0.n

    @property
    def n_shards(self) -> int:
        return self._ops0.part.n_shards

    @property
    def dev_specs(self):
        """in_specs pytree for the stacked operands (leading shard axis)."""
        return jax.tree.map(lambda _: P(self.axis_name), self.dev)

    def cached_fn(self, key, builder):
        """Build-once cache for jitted shard_map dispatches (the
        distributed analogue of ``SpMVPlan._dispatch``; solvers park
        theirs here too)."""
        fn = self._fns.get(key)
        if fn is None:
            fn = builder()
            self._fns[key] = fn
        return fn

    def shard_vector(self, v) -> jnp.ndarray:
        """Global [n(, nb)] → device-sharded stacked [P, n_pad(, nb)]."""
        if isinstance(v, jax.core.Tracer):
            return self._shard_traced(v)
        return jax.device_put(
            self._ops0.stack_vector(np.asarray(v)),
            NamedSharding(self.mesh, P(self.axis_name)))

    def unshard_vector(self, ys) -> jnp.ndarray:
        if isinstance(ys, jax.core.Tracer):
            return self._unshard_traced(ys)
        return jnp.asarray(self._ops0.unstack_vector(np.asarray(ys)))

    def _shard_traced(self, v: jnp.ndarray) -> jnp.ndarray:
        """jnp mirror of ``stack_vector`` (static slices/pads only), used
        when the global vector is a tracer — a solver's loop-carried
        iterate. The jitted shard_map dispatch inlines into the enclosing
        trace, so ``dist_<codec>`` matvecs drop into unchanged solvers."""
        parts = []
        for p in range(self.n_shards):
            r0, r1 = self._ops0.part.rows_of(p)
            pad = [(0, self._ops0.n_pad - (r1 - r0))] + \
                [(0, 0)] * (v.ndim - 1)
            parts.append(jnp.pad(v[r0:r1], pad))
        return jnp.stack(parts)

    def _unshard_traced(self, ys: jnp.ndarray) -> jnp.ndarray:
        return jnp.concatenate(
            [ys[p, :int(c)] for p, c in enumerate(self._ops0.part.counts)])


class DistSpMVPlan(_MeshBound):
    """Stacked distributed operands bound to a 1-D device mesh, with one
    jitted ``shard_map`` dispatch per (entry point, exchange mode).

    Entry points take and return **global** vectors (``spmv`` / ``spmm``)
    or stay in the stacked-sharded layout (``spmv_sharded`` — solvers and
    benchmarks chain matvecs without host round-trips). ``shard_vector`` /
    ``unshard_vector`` convert between the two.
    """

    def __init__(self, ops: DistOperands, mesh, *,
                 exchange: str = "ppermute"):
        if exchange not in dh.EXCHANGE_MODES:
            raise ValueError(f"exchange={exchange!r} not in "
                             f"{dh.EXCHANGE_MODES}")
        self.ops = ops
        self.exchange = exchange
        self._bind(ops, mesh, ops.host)

    def _spmv_fn(self, mode: str, multi_rhs: bool):
        def build():
            ax = self.axis_name

            def body(dev, xs):
                o = jax.tree.map(lambda leaf: leaf[0], dev)
                y = self.ops.shard_body(o, xs[0], axis_name=ax, mode=mode,
                                        multi_rhs=multi_rhs)
                return y[None]

            f = shard_map_compat(body, self.mesh,
                                 in_specs=(self.dev_specs, P(ax)),
                                 out_specs=P(ax))
            return jax.jit(f)

        return self.cached_fn(("spmm" if multi_rhs else "spmv", mode), build)

    def spmv_sharded(self, xs: jnp.ndarray, *, mode: str | None = None,
                     multi_rhs: bool = False) -> jnp.ndarray:
        """Stacked-sharded [P, n_pad(, nb)] → same layout; one dispatch."""
        mode = mode or self.exchange
        if mode not in dh.EXCHANGE_MODES:
            # validate here, not only in gather_halo: halo-free partitions
            # (h_pad == 0) never reach the gather
            raise ValueError(f"mode={mode!r} not in {dh.EXCHANGE_MODES}")
        if _obs.enabled() and not isinstance(xs, jax.core.Tracer):
            _obs.inc("dist.dispatch", mode=mode, shards=self.n_shards,
                     kind="spmm" if multi_rhs else "spmv")
        return self._spmv_fn(mode, multi_rhs)(self.dev, xs)

    def spmv(self, x, *, mode: str | None = None) -> jnp.ndarray:
        """y = A @ x for a global [n] vector (shard → dispatch → unshard)."""
        return self.unshard_vector(self.spmv_sharded(
            self.shard_vector(x), mode=mode))

    def spmm(self, x, *, mode: str | None = None) -> jnp.ndarray:
        """Y = A @ X for a global [n, nb] block (multi-RHS path: one pass
        over each shard's packed words serves all nb right-hand sides)."""
        if np.ndim(x) != 2:
            raise ValueError(f"spmm expects [n, nb], got {np.shape(x)}")
        return self.unshard_vector(self.spmv_sharded(
            self.shard_vector(x), mode=mode, multi_rhs=True))

    def warmup(self, nb: int = 0, modes=None) -> "DistSpMVPlan":
        """Pre-trace the dispatches (serving-engine contract: the first
        tick pays neither tracing nor plan construction)."""
        for mode in (modes or (self.exchange,)):
            jax.block_until_ready(
                self.spmv(np.zeros(self.n, np.float32), mode=mode))
            if nb:
                jax.block_until_ready(
                    self.spmm(np.zeros((self.n, nb), np.float32), mode=mode))
        return self

    # -- accounting ---------------------------------------------------------
    def memory_stats(self) -> dict:
        """Fleet memory + communication profile via the unified composite
        blend (:func:`repro.kernels.composite.composite_memory_stats`):
        per-member breakdown over every shard's blocks, plus halo traffic
        and per-shard footprint extremes (partitioner load-balance
        signal)."""
        ops = self.ops
        st = kc.composite_memory_stats(
            [(dm.label, dm.codec, dm.D,
              dm.n_rows(), dm.mats)
             for dm in ops.members],
            halo={"shards": self.n_shards, "n_pad": ops.n_pad,
                  "h_pad": ops.h_pad,
                  "halo_entries": int(ops.maps.counts.sum()),
                  "halo_k_max": ops.maps.k_max,
                  "exchange": self.exchange})
        per_shard = [sum(kc._block_bytes(dm.mats[p]) for dm in ops.members)
                     for p in range(self.n_shards)]
        st["max_shard_bytes"] = max(per_shard) if per_shard else 0
        st["min_shard_bytes"] = min(per_shard) if per_shard else 0
        return st


def build_dist_plan(a: sp.csr_matrix, n_shards: int | None = None, *,
                    mesh=None, axis_name: str = "shards",
                    exchange: str = "ppermute", C: int = 32,
                    sigma: int = 256, D: int = 15, codec: str = "fp16",
                    classes=None, pplan=None,
                    devices=None) -> DistSpMVPlan:
    """Partition ``a`` across a 1-D device mesh and build the jitted
    distributed plan (the slow path — run once per matrix, like
    ``kernels.plan.build_plan``). With no mesh given, one shard per visible
    local device.

    ``classes`` (or ``pplan``, a rows-mode
    :class:`~repro.precision.select.PrecisionPlan`) builds a distributed ×
    mixed-precision composite: per-shard per-class members instead of one
    fleet-wide ``(codec, D)``.
    """
    if mesh is None:
        mesh = make_shard_mesh(n_shards, axis_name=axis_name,
                               devices=devices)
    if pplan is not None:
        if classes is not None:
            raise ValueError("pass either classes= or pplan=, not both")
        classes = [(c.codec, c.D, c.rows) for c in pplan.classes]
    if classes is None:
        classes = [(codec, D, None)]
    ops = build_composite_operands(a, int(mesh.devices.size),
                                   classes=classes, C=C, sigma=sigma)
    return DistSpMVPlan(ops, mesh, exchange=exchange)


# ---------------------------------------------------------------------------
# Distributed tier ladder (adaptive_pcg_dist)
# ---------------------------------------------------------------------------


class DistTierLadder(_MeshBound):
    """One member set per codec tier over ONE shared partition — what
    :func:`repro.solvers.cg.adaptive_pcg_dist` promotes through.

    Every tier shares the halo maps and row mask (``dev['shared']``); each
    tier's member arrays + inverse permutations live under
    ``dev['tiers'][k]`` and the exact fp64 operator (the outer
    true-residual recomputation of iterative refinement) under
    ``dev['hi']``. Tier choice inside the solve is a traced ``lax.switch``
    over the per-tier composite bodies; the halo gather is hoisted out of
    the switch as the shared pre-stage (one collective per matvec,
    whatever the tier).
    """

    def __init__(self, tiers_ops: list, hi_ops: DistOperands, mesh, *,
                 labels, sub32, exchange: str = "ppermute"):
        if exchange not in dh.EXCHANGE_MODES:
            raise ValueError(f"exchange={exchange!r} not in "
                             f"{dh.EXCHANGE_MODES}")
        self.tiers = list(tiers_ops)
        self.hi = hi_ops
        self.labels = list(labels)
        self.sub32 = np.asarray(sub32, bool)
        self.exchange = exchange

        def member_only(ops):
            return {k: v for k, v in ops.host.items()
                    if k not in SHARED_KEYS}

        host = {
            "shared": {k: self.tiers[0].host[k] for k in SHARED_KEYS},
            "tiers": [member_only(o) for o in self.tiers],
            "hi": member_only(hi_ops),
        }
        self._bind(self.tiers[0], mesh, host)

    @property
    def h_pad(self) -> int:
        return self.tiers[0].h_pad


def build_dist_tiers(a: sp.csr_matrix, ladder, *, mesh=None,
                     n_shards: int | None = None,
                     axis_name: str = "shards",
                     exchange: str = "ppermute", C: int = 32,
                     sigma: int = 256, devices=None) -> DistTierLadder:
    """Materialize a whole-operator codec ladder (e.g.
    ``precision.select.tier_ladder``) as distributed member sets sharing
    one partition, plus the exact fp64 member set for the refinement
    outer step."""
    if mesh is None:
        mesh = make_shard_mesh(n_shards, axis_name=axis_name,
                               devices=devices)
    ncls = _normalize_classes(ladder)
    a = a.tocsr()
    ctx = _partition_context(a, int(mesh.devices.size), C)
    tiers_ops = [build_composite_operands(
        a, int(mesh.devices.size), classes=[(codec, D, None)],
        C=C, sigma=sigma, ctx=ctx) for codec, D, _ in ncls]
    hi_ops = build_composite_operands(
        a, int(mesh.devices.size), classes=[("fp64", 0, None)],
        C=C, sigma=sigma, ctx=ctx)
    labels = [codec if codec in kc.SELL_CODECS else f"{codec}/D={D}"
              for codec, D, _ in ncls]
    sub32 = [codec not in kc.SELL_CODECS for codec, D, _ in ncls]
    return DistTierLadder(tiers_ops, hi_ops, mesh, labels=labels,
                          sub32=sub32, exchange=exchange)
