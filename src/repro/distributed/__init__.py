"""Distributed PackSELL: row-block partitioning, halo-exchange SpMV, and
the multi-device plan layer (DESIGN.md §7)."""
from . import halo  # noqa: F401
from .halo import HaloMaps, build_halo_maps, gather_halo  # noqa: F401
from .partition import (RowPartition, ShardSplit,  # noqa: F401
                        assemble_global, comm_matrix, partition_rows,
                        split_csr)
from .plan import (DistOperands, DistSpMVPlan,  # noqa: F401
                   DistTierLadder, build_composite_operands,
                   build_dist_plan, build_dist_tiers, build_operands,
                   reference_spmv)
