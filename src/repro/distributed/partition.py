"""Row-block partitioning with a local/remote column split (DESIGN.md §7.1).

The canonical distributed-SpMV recipe (Kreutzer et al., "SpMV on GPGPU
clusters", arXiv:1112.5588): rows are split into contiguous blocks, one per
device, and each block's columns are classified against the row ownership —

* **local** columns fall inside the shard's own row range; they are
  renumbered to ``[0, n_loc)`` and index the shard's resident x-block.
* **halo** columns belong to other shards; the sorted set of distinct halo
  columns is renumbered to ``[0, n_halo)`` and indexes the buffer the halo
  exchange fills (``repro.distributed.halo``).

Each shard therefore stores TWO sparse blocks, ``A_loc`` and ``A_rem``, and
``y_p = A_loc @ x_loc + A_rem @ x_halo`` — the split is what lets the local
matvec overlap with the communication that produces ``x_halo``.

Everything in this module is host-side numpy/scipy (format construction
happens on the host, like the paper's single-device build); σ-sorting is
applied *per partition* downstream (``from_csr`` on each block — SELL-C-σ,
arXiv:1307.6209 §3, keeps padding low exactly when σ spans one partition).

Square matrices only: column ownership must coincide with row ownership for
x and y to share one partition (the Krylov-solver contract).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Contiguous balanced row blocks: shard p owns rows
    ``[starts[p], starts[p+1])`` (and, square matrices, the same columns)."""

    n: int
    n_shards: int
    starts: np.ndarray          # int64 [n_shards + 1]

    @property
    def counts(self) -> np.ndarray:
        return np.diff(self.starts)

    def rows_of(self, p: int) -> tuple[int, int]:
        return int(self.starts[p]), int(self.starts[p + 1])

    def owner(self, cols: np.ndarray) -> np.ndarray:
        """Owning shard of each (global) column index."""
        return np.searchsorted(self.starts, np.asarray(cols), side="right") - 1


def partition_rows(n: int, n_shards: int) -> RowPartition:
    """Balanced contiguous split: the first ``n % n_shards`` shards get one
    extra row. Shards may be empty when ``n < n_shards`` (padding downstream
    keeps SPMD shapes uniform)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    base, rem = divmod(n, n_shards)
    counts = base + (np.arange(n_shards) < rem).astype(np.int64)
    starts = np.zeros(n_shards + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    return RowPartition(n=n, n_shards=n_shards, starts=starts)


@dataclasses.dataclass(frozen=True)
class ShardSplit:
    """One shard's row block, split and renumbered.

    ``a_loc``: [n_loc, n_pad] CSR over local columns (global col g ↦
    g - starts[p]; the column space is padded to the fleet-wide ``n_pad`` so
    every shard's x-block has one static length).
    ``a_rem``: [n_loc, h_pad] CSR over halo slots (global col ↦ its rank in
    ``halo_cols``); absent (None) when the whole fleet has no halo columns.
    ``halo_cols``: sorted distinct global column ids this shard must receive.
    """

    a_loc: sp.csr_matrix
    a_rem: sp.csr_matrix | None
    halo_cols: np.ndarray


def split_csr(a: sp.csr_matrix, part: RowPartition, *,
              n_pad: int) -> tuple[list[ShardSplit], int]:
    """Split ``a`` by ``part`` into per-shard (A_loc, A_rem, halo_cols).

    Returns ``(splits, h_pad)`` where ``h_pad`` is the fleet-wide maximum
    halo count — every ``a_rem`` is built with ``m = h_pad`` so the halo
    buffer has one static length (0 when no shard has halo columns).
    """
    a = a.tocsr()
    if a.shape[0] != a.shape[1]:
        raise ValueError(f"distribution needs a square matrix, got {a.shape}")
    if n_pad < int(part.counts.max(initial=0)):
        raise ValueError(f"n_pad={n_pad} smaller than the largest shard")

    coos, halos = [], []
    for p in range(part.n_shards):
        r0, r1 = part.rows_of(p)
        blk = a[r0:r1].tocoo()
        local = (blk.col >= r0) & (blk.col < r1)
        coos.append((blk, local, r0))
        halos.append(np.unique(blk.col[~local]).astype(np.int64))
    h_pad = max((len(h) for h in halos), default=0)

    splits = []
    for (blk, local, r0), halo_cols in zip(coos, halos):
        n_loc = blk.shape[0]
        a_loc = sp.csr_matrix(
            (blk.data[local], (blk.row[local], blk.col[local] - r0)),
            shape=(n_loc, n_pad))
        a_loc.sum_duplicates()
        a_loc.sort_indices()
        a_rem = None
        if h_pad > 0:
            slot = np.searchsorted(halo_cols, blk.col[~local])
            a_rem = sp.csr_matrix(
                (blk.data[~local], (blk.row[~local], slot)),
                shape=(n_loc, h_pad))
            a_rem.sum_duplicates()
            a_rem.sort_indices()
        splits.append(ShardSplit(a_loc=a_loc, a_rem=a_rem,
                                 halo_cols=halo_cols))
    return splits, h_pad


def comm_counts(part: RowPartition,
                halo_cols_list: list[np.ndarray]) -> np.ndarray:
    """``counts[p, q]`` = x-entries shard p must receive from shard q — the
    halo-exchange traffic matrix (diagonal is zero by construction)."""
    counts = np.zeros((part.n_shards, part.n_shards), np.int64)
    for p, hc in enumerate(halo_cols_list):
        owners = part.owner(hc)
        for q in np.unique(owners):
            counts[p, q] = int((owners == q).sum())
    return counts


def comm_matrix(part: RowPartition,
                splits: list[ShardSplit]) -> np.ndarray:
    """:func:`comm_counts` over a list of :class:`ShardSplit`."""
    return comm_counts(part, [s.halo_cols for s in splits])


def assemble_global(part: RowPartition, splits: list[ShardSplit],
                    shape: tuple[int, int]) -> sp.csr_matrix:
    """Reassemble the global matrix from per-shard blocks (test oracle:
    ``assemble_global(split_csr(a)) == a``)."""
    rows, cols, vals = [], [], []
    for p, s in enumerate(splits):
        r0, _ = part.rows_of(p)
        loc = s.a_loc.tocoo()
        rows.append(loc.row + r0)
        cols.append(loc.col + r0)
        vals.append(loc.data)
        if s.a_rem is not None and s.a_rem.nnz:
            rem = s.a_rem.tocoo()
            rows.append(rem.row + r0)
            cols.append(s.halo_cols[rem.col])
            vals.append(rem.data)
    out = sp.csr_matrix(
        (np.concatenate(vals) if vals else np.zeros(0),
         (np.concatenate(rows) if rows else np.zeros(0, np.int64),
          np.concatenate(cols) if cols else np.zeros(0, np.int64))),
        shape=shape)
    out.sum_duplicates()
    out.sort_indices()
    return out
