"""Halo exchange for distributed PackSELL SpMV (DESIGN.md §7.2).

Before ``y_p = A_loc @ x_loc + A_rem @ x_halo`` can run, each shard must
receive the x-entries its halo columns reference. Two exchange modes, both
driven entirely by **precomputed index maps** (host-built once per
partition, no device-side set logic):

* ``'ppermute'`` (default, the Kreutzer-et-al. recipe): P-1 rounds of
  ``jax.lax.ppermute``. In round s every shard packs the entries shard
  ``(p+s) % P`` needs from it (``send_idx``), the ring rotates by s, and the
  receiver scatters the buffer into its halo slots (``recv_slot``). Only
  owned entries that some neighbor actually needs ever move; buffers are
  padded to the fleet-wide per-pair maximum ``k_max`` so every round is one
  static-shape collective.
* ``'all_gather'``: one ``jax.lax.all_gather`` of the full x-block followed
  by a gather through ``halo_src``. Simpler, more traffic — the baseline the
  benchmarks compare against.

Sender and receiver agree on buffer order by construction: both sides
enumerate the pair's columns in sorted-global-column order.

The maps are plain stacked arrays ([P, ...] along the mesh axis) so they
flow through ``shard_map`` in_specs like any other operand; padding entries
send slot 0 (harmless read) and land on slot ``h_pad`` (dropped by the
out-of-bounds scatter mode).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.observe import metrics as _obs

from .partition import RowPartition, comm_counts

EXCHANGE_MODES = ("ppermute", "all_gather")


@dataclasses.dataclass(frozen=True)
class HaloMaps:
    """Host-built exchange index maps, stacked over shards (leading dim P).

    ``halo_src[p, k]``: flattened index into the all-gathered ``[P * n_pad]``
    x of shard p's k-th halo entry (pad → 0).
    ``send_idx[p, s-1, k]``: local x index of the k-th entry shard p sends in
    round s (pad → 0).
    ``recv_slot[p, s-1, k]``: halo slot filled by the k-th entry shard p
    receives in round s (pad → h_pad, dropped).
    """

    n_shards: int
    n_pad: int
    h_pad: int
    k_max: int
    halo_src: np.ndarray        # int32 [P, max(h_pad, 1)]
    send_idx: np.ndarray        # int32 [P, max(P-1, 1), max(k_max, 1)]
    recv_slot: np.ndarray       # int32 [P, max(P-1, 1), max(k_max, 1)]
    counts: np.ndarray          # int64 [P, P] traffic matrix


def build_halo_maps(part: RowPartition, halo_cols_list: list[np.ndarray],
                    *, n_pad: int, h_pad: int) -> HaloMaps:
    """Precompute both modes' index maps from the per-shard halo column
    sets (``ShardSplit.halo_cols``, sorted global ids)."""
    P = part.n_shards
    owners = [part.owner(hc) for hc in halo_cols_list]
    counts = comm_counts(part, halo_cols_list)
    k_max = int(counts.max(initial=0))

    halo_src = np.zeros((P, max(h_pad, 1)), np.int32)
    for p, hc in enumerate(halo_cols_list):
        own = owners[p]
        halo_src[p, :len(hc)] = (own * n_pad
                                 + (hc - part.starts[own])).astype(np.int32)

    n_steps = max(P - 1, 1)
    send_idx = np.zeros((P, n_steps, max(k_max, 1)), np.int32)
    recv_slot = np.full((P, n_steps, max(k_max, 1)), h_pad, np.int32)
    for s in range(1, P):
        for p in range(P):
            dst = (p + s) % P
            # entries dst needs from p, in dst's sorted-halo order
            need = halo_cols_list[dst][owners[dst] == p]
            send_idx[p, s - 1, :len(need)] = \
                (need - part.starts[p]).astype(np.int32)
            src = (p - s) % P
            slots = np.nonzero(owners[p] == src)[0]
            recv_slot[p, s - 1, :len(slots)] = slots.astype(np.int32)
    return HaloMaps(n_shards=P, n_pad=n_pad, h_pad=h_pad, k_max=k_max,
                    halo_src=halo_src, send_idx=send_idx,
                    recv_slot=recv_slot, counts=counts)


def gather_halo(x_loc: jnp.ndarray, dev: dict, *, axis_name: str,
                n_shards: int, h_pad: int, mode: str) -> jnp.ndarray:
    """Device-side exchange (runs inside a shard_map body). ``x_loc`` is
    this shard's ``[n_pad]`` (or ``[n_pad, nb]``) x-block; ``dev`` holds this
    shard's slices of the stacked maps. Returns ``x_halo`` ``[h_pad(, nb)]``.
    """
    out_shape = (h_pad,) + tuple(x_loc.shape[1:])
    if h_pad == 0:
        return jnp.zeros(out_shape, x_loc.dtype)
    if mode == "all_gather":
        x_full = jax.lax.all_gather(x_loc, axis_name)        # [P, n_pad(,nb)]
        x_full = x_full.reshape((-1,) + tuple(x_loc.shape[1:]))
        return jnp.take(x_full, dev["halo_src"][:h_pad], axis=0)
    if mode != "ppermute":
        raise ValueError(f"mode={mode!r} not in {EXCHANGE_MODES}")
    x_halo = jnp.zeros(out_shape, x_loc.dtype)
    for s in range(1, n_shards):
        buf = jnp.take(x_loc, dev["send_idx"][s - 1], axis=0)
        buf = jax.lax.ppermute(
            buf, axis_name,
            perm=[(p, (p + s) % n_shards) for p in range(n_shards)])
        # pad entries carry recv_slot == h_pad -> dropped (out of bounds)
        x_halo = x_halo.at[dev["recv_slot"][s - 1]].set(buf, mode="drop")
    return x_halo


def prestage(shared: dict, *, axis_name: str, n_shards: int, h_pad: int,
             mode: str):
    """The halo exchange packaged as a **composite pre-stage**
    (DESIGN.md §9.2): a function mapping a shard's local x-block to the
    tuple of extra input vectors — ``(x_halo,)``, or ``()`` for halo-free
    partitions — that remote composite members consume as input index 1.

    Hoisting the exchange into a pre-stage is what lets the distributed
    tier ladder (``cg.adaptive_pcg_dist``) run ONE collective per matvec
    outside the tier ``lax.switch``: every tier shares the same index
    maps, so the gathered buffer feeds whichever tier is active.
    """
    def pre(x_loc: jnp.ndarray) -> tuple:
        if h_pad == 0:
            return ()
        with _obs.span("packsell.halo_prestage"):
            return (gather_halo(x_loc, shared, axis_name=axis_name,
                                n_shards=n_shards, h_pad=h_pad, mode=mode),)
    return pre


def gather_halo_reference(x_stacked: np.ndarray, maps: HaloMaps,
                          mode: str = "all_gather") -> np.ndarray:
    """Host-side oracle of :func:`gather_halo` over the full stacked x
    ``[P, n_pad(, nb)]`` → ``[P, h_pad(, nb)]`` (device-free tests)."""
    P, h_pad = maps.n_shards, maps.h_pad
    out_shape = (P, h_pad) + tuple(x_stacked.shape[2:])
    out = np.zeros(out_shape, x_stacked.dtype)
    if h_pad == 0:
        return out
    if mode == "all_gather":
        flat = x_stacked.reshape((-1,) + tuple(x_stacked.shape[2:]))
        for p in range(P):
            out[p] = flat[maps.halo_src[p, :h_pad]]
        return out
    for s in range(1, P):
        for p in range(P):
            src = (p - s) % P
            buf = x_stacked[src][maps.send_idx[src, s - 1]]
            slots = maps.recv_slot[p, s - 1]
            ok = slots < h_pad
            out[p][slots[ok]] = buf[ok]
    return out
