"""Deterministic synthetic token pipeline (the training-data substrate).

Design requirements (DESIGN.md §3):

* **Deterministic & stateless**: batch ``t`` is a pure function of
  ``(seed, t)`` via counter-based Philox streams — no iterator state to
  checkpoint beyond the integer step, and any worker can regenerate any
  batch (elastic restarts never replay or skip data).
* **Learnable signal**: tokens follow an order-1 Markov chain whose
  transition table is itself derived from the seed (sparse: each token has
  ``branch`` likely successors + uniform noise). A model that learns the
  table reaches a loss floor well below uniform entropy, so the end-to-end
  example (examples/train_lm.py) shows a real, falsifiable learning curve.
* **Sharding-aware**: ``place_batch`` builds a global jax.Array for any mesh
  from per-shard callbacks (``jax.make_array_from_callback``), generating
  only the local rows on each host — the multi-host path and the
  single-process path are the same code.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branch: int = 4          # likely successors per token
    noise: float = 0.05      # probability mass on the uniform tail
    bos: int = 0


def markov_table(cfg: DataConfig) -> np.ndarray:
    """[vocab, branch] int32 successor table, derived from the seed."""
    rng = np.random.Generator(np.random.Philox(key=cfg.seed))
    return rng.integers(0, cfg.vocab, size=(cfg.vocab, cfg.branch),
                        dtype=np.int64)


def _gen_rows(cfg: DataConfig, table: np.ndarray, step: int,
              row_lo: int, row_hi: int) -> np.ndarray:
    """Generate rows [row_lo, row_hi) of global batch ``step`` (int32
    [rows, seq_len+1]): counter-based so any shard is independently
    reproducible."""
    nrows = row_hi - row_lo
    # one Philox stream per (step, row): key = (seed, step, row)
    out = np.empty((nrows, cfg.seq_len + 1), dtype=np.int64)
    for i, r in enumerate(range(row_lo, row_hi)):
        rng = np.random.Generator(
            np.random.Philox(key=(cfg.seed + 1) * 1_000_003 + step,
                             counter=np.array([r, 0, 0, 0], np.uint64)))
        u = rng.random(cfg.seq_len + 1)
        pick = rng.integers(0, cfg.branch, size=cfg.seq_len + 1)
        unif = rng.integers(0, cfg.vocab, size=cfg.seq_len + 1)
        toks = np.empty(cfg.seq_len + 1, dtype=np.int64)
        toks[0] = cfg.bos
        for t in range(1, cfg.seq_len + 1):
            if u[t] < cfg.noise:
                toks[t] = unif[t]
            else:
                toks[t] = table[toks[t - 1], pick[t]]
        out[i] = toks
    return out


class SyntheticTokenStream:
    """Batch ``t`` = f(seed, t). ``state()``/``restore()`` are just the step
    counter; the stream is identical across restarts and worker counts."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.table = markov_table(cfg)
        self._step = 0

    # -- checkpointable iterator state -----------------------------------
    def state(self) -> dict:
        return {"step": self._step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch"
        self._step = int(state["step"])

    # -- batch generation -------------------------------------------------
    def batch_rows(self, step: int, row_lo: int, row_hi: int) -> dict:
        rows = _gen_rows(self.cfg, self.table, step, row_lo, row_hi)
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
            "mask": np.ones((row_hi - row_lo, self.cfg.seq_len), np.float32),
        }

    def next_host_batch(self) -> dict:
        """Full global batch as host numpy (single-process path)."""
        b = self.batch_rows(self._step, 0, self.cfg.global_batch)
        self._step += 1
        return b

    def next_placed_batch(self, mesh) -> dict:
        """Global jax.Arrays sharded batch-over-DP on ``mesh``; each shard's
        rows are generated independently (multi-host-shaped path)."""
        step = self._step
        self._step += 1
        return place_batch(
            lambda lo, hi: self.batch_rows(step, lo, hi),
            self.cfg.global_batch, mesh)


def place_batch(row_fn, global_batch: int, mesh) -> dict:
    """Build sharded global arrays; ``row_fn(lo, hi) -> dict of np arrays``
    generates only the requested row range (per-shard generation)."""
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]
    spec = P(tuple(dp) if dp else None)
    probe = row_fn(0, 1)
    out = {}
    for name, arr in probe.items():
        gshape = (global_batch,) + arr.shape[1:]
        sh = NamedSharding(mesh, P(*(spec + (None,) * (arr.ndim - 1))))

        def cb(index, name=name):
            sl = index[0]
            lo = sl.start or 0
            hi = sl.stop if sl.stop is not None else global_batch
            return row_fn(lo, hi)[name]

        out[name] = jax.make_array_from_callback(gshape, sh, cb)
    return jax.tree.map(jnp.asarray, out)
