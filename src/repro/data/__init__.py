"""Data substrate: deterministic, resumable, sharding-aware token pipeline."""
from .synthetic import (DataConfig, SyntheticTokenStream,  # noqa: F401
                        markov_table, place_batch)
