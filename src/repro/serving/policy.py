"""Serving policies: clocks, backoff, breakers, admission, degradation.

Everything here is host-side decision logic for the request front end
(:mod:`repro.serving.frontend`) — deliberately free of jax so every
policy is unit-testable with a stubbed clock and a seed.  Four pieces:

* **Clocks** — all deadline/backoff/breaker arithmetic runs on a
  *monotonic* clock injected at construction (``time.monotonic`` in
  production, :class:`ManualClock` in tests), never wall time: NTP
  steps must not expire deadlines or re-close breakers.
* **:class:`BackoffPolicy`** — deterministic exponential retry
  schedule for transient guard trips (attempt k waits
  ``base·mult^(k-1)``, capped), optional seeded jitter.
* **:class:`CircuitBreaker`** — the classic three-state machine per
  plan: CLOSED → (``fail_threshold`` consecutive trips) → OPEN →
  (cooldown elapsed *and* the operand rebuilt) → HALF_OPEN →
  (``probe_successes`` clean batches) → CLOSED; any failure in
  HALF_OPEN re-opens.  Every transition lands in the observe layer.
* **:class:`AdmissionPolicy` / :class:`DegradationPolicy`** — the
  bounded-queue + VMEM-residency admission guard (DESIGN.md §15.2) and
  the occupancy → precision-tier demotion map (§15.4): overload sheds
  value bits (bytes/nnz, the Kreutzer figure of merit) before it sheds
  requests, and best-effort classes shed before tight-SLO ones.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Optional

from repro.observe import metrics as _obs

__all__ = [
    "ManualClock", "BackoffPolicy", "CircuitBreaker",
    "RequestClass", "DEFAULT_CLASSES", "DEFAULT_LADDER",
    "AdmissionPolicy", "DegradationPolicy", "tier_error_budget",
]


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------


class ManualClock:
    """A monotonic clock the caller advances by hand — the determinism
    substrate of every serving test (deadline math, backoff schedules,
    breaker cooldowns become exact assertions, not sleeps)."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"monotonic clocks cannot rewind (dt={dt})")
        self.t += float(dt)
        return self.t


# ---------------------------------------------------------------------------
# retry backoff
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Exponential backoff for transient guard trips.

    ``delay(k)`` for attempt k >= 1 is ``base · mult^(k-1)`` capped at
    ``max_delay``; with ``jitter > 0`` a seeded uniform factor in
    ``[1-jitter, 1]`` is applied (seeded per policy instance, so a
    schedule is reproducible — the property the serving tests pin)."""

    base: float = 0.005
    mult: float = 2.0
    max_delay: float = 0.5
    max_attempts: int = 3
    jitter: float = 0.0
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "_rng", random.Random(self.seed))

    def delay(self, attempt: int) -> float:
        """Seconds to wait before retry ``attempt`` (1-based)."""
        if attempt < 1:
            raise ValueError(f"attempt is 1-based, got {attempt}")
        d = min(self.base * self.mult ** (attempt - 1), self.max_delay)
        if self.jitter > 0:
            d *= 1.0 - self.jitter * self._rng.random()
        return d

    def exhausted(self, attempt: int) -> bool:
        return attempt >= self.max_attempts


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class CircuitBreaker:
    """Per-plan trip accounting with the OPEN → HALF_OPEN → CLOSED
    recovery path.

    The breaker is advisory: it never executes anything itself, it only
    answers :meth:`allow` (may traffic use the guarded plan right now?)
    and records outcomes.  Semantics:

    * CLOSED: traffic flows; ``fail_threshold`` *consecutive* failures
      open the breaker (a success resets the streak).
    * OPEN: traffic is rerouted (the frontend's fp32 fallback);
      :meth:`allow` turns True again only once ``cooldown_s`` has
      elapsed on the monotonic clock AND :meth:`note_rebuilt` has been
      called — probing a plan that nobody repaired is pointless.
    * HALF_OPEN: entered automatically by the first :meth:`allow` after
      the conditions above; ``probe_successes`` clean batches close the
      breaker, any failure re-opens it (and requires a fresh rebuild).
    """

    def __init__(self, *, fail_threshold: int = 2, cooldown_s: float = 0.05,
                 probe_successes: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 name: str = ""):
        if fail_threshold < 1 or probe_successes < 1:
            raise ValueError("fail_threshold and probe_successes are >= 1")
        self.fail_threshold = int(fail_threshold)
        self.cooldown_s = float(cooldown_s)
        self.probe_successes = int(probe_successes)
        self.clock = clock
        self.name = name
        self.state = CLOSED
        self.consecutive_failures = 0
        self.probes_ok = 0
        self.opened_at: Optional[float] = None
        self.rebuilt = False
        self.transitions: list = []     # [(t, from, to)] — test/debug trail

    def _move(self, to: str) -> None:
        if to == self.state:
            return
        t = self.clock()
        self.transitions.append((t, self.state, to))
        _obs.inc("frontend.breaker_transition", plan=self.name,
                 src=self.state, dst=to)
        self.state = to
        if to == OPEN:
            self.opened_at = t
            self.rebuilt = False
            self.probes_ok = 0
        elif to == CLOSED:
            self.consecutive_failures = 0
            self.probes_ok = 0

    def allow(self) -> bool:
        """True when traffic may use the guarded plan now.  The OPEN →
        HALF_OPEN edge happens here (lazily, on the first eligible
        call) so no background timer thread is needed."""
        if self.state == OPEN:
            if self.rebuilt and self.opened_at is not None \
                    and self.clock() - self.opened_at >= self.cooldown_s:
                self._move(HALF_OPEN)
        return self.state != OPEN

    def record_success(self) -> None:
        self.consecutive_failures = 0
        if self.state == HALF_OPEN:
            self.probes_ok += 1
            if self.probes_ok >= self.probe_successes:
                self._move(CLOSED)

    def record_failure(self) -> None:
        self.consecutive_failures += 1
        if self.state == HALF_OPEN \
                or self.consecutive_failures >= self.fail_threshold:
            self._move(OPEN)

    def note_rebuilt(self) -> None:
        """The quarantined operand was rebuilt from the retained CSR —
        half-open probing becomes possible once the cooldown elapses."""
        self.rebuilt = True


# ---------------------------------------------------------------------------
# request classes and the degradation ladder
# ---------------------------------------------------------------------------

#: The serving precision ladder, most accurate first.  Index == tier;
#: demotion moves RIGHT (toward fewer value bits — fp32→packed halves
#: bytes/nnz, and within the packed tiers accuracy decreases while the
#: word stream stays 4 B/nnz).  Kind strings are `solvers.operators`
#: kinds, so every tier rides the same plan engine.
DEFAULT_LADDER = ("fp32", "plan_e8m4", "plan_fp16", "plan_bf16")


def tier_error_budget(kind: str, *, safety: float = 256.0) -> float:
    """Backward-error budget of one ladder tier: the §8 error model's
    per-entry quantization bound times a safety factor covering fp32
    matvec rounding.  The chaos harness holds every completed response
    to this bound against the fp64 oracle."""
    import numpy as np

    from repro.precision import analyze as an
    from repro.solvers.operators import parse_kind

    spec = parse_kind(kind)
    eps32 = float(np.finfo(np.float32).eps)
    if spec.family == "dense":
        return safety * eps32
    return safety * max(float(an.ulp_bound(spec.codec, spec.D)), eps32)


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One SLO class: where it sits on the ladder and when it sheds.

    ``priority`` orders shedding (HIGHER sheds first — best-effort
    classes go before tight-SLO ones).  ``tier`` is the class's normal
    ladder index; ``tier_floor`` the cheapest tier overload may demote
    it to (a tight-SLO class already living at a sub-32-bit tier keeps
    it — demotion never promotes)."""

    name: str
    priority: int
    deadline_s: float
    tier: int
    tier_floor: int

    def __post_init__(self):
        if self.tier_floor < self.tier:
            raise ValueError(
                f"class {self.name!r}: tier_floor {self.tier_floor} above "
                f"(more accurate than) tier {self.tier} — demotion only "
                "moves down the ladder")


#: interactive = tight SLO, lives sub-32-bit, sheds last; batch = best
#: effort, starts at fp32 accuracy, demotes and sheds first.
DEFAULT_CLASSES = (
    RequestClass("interactive", priority=0, deadline_s=0.25, tier=2,
                 tier_floor=3),
    RequestClass("standard", priority=1, deadline_s=1.0, tier=1,
                 tier_floor=3),
    RequestClass("batch", priority=2, deadline_s=5.0, tier=0,
                 tier_floor=3),
)


# ---------------------------------------------------------------------------
# admission
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Bounded-queue + VMEM-residency admission (DESIGN.md §15.2).

    ``max_queue`` bounds host memory and tail latency (an unbounded
    queue converts overload into unbounded p99).  ``vmem_limit_words``
    bounds the multi-RHS working set: a coalesced spmm slot holds the
    whole ``[m, nb]`` x block (fp32 words) plus the ``[n, nb]`` partial
    y in VMEM, so admission requires ``(m + n) · nb <= W`` — the same
    budget ``kernels.plan`` enforces for single-RHS via
    ``REPRO_FULL_X_LIMIT``.  Requests that break it are rejected loudly
    (reason ``vmem``) instead of silently falling back to a slow body
    and blowing every deadline behind them."""

    max_queue: int = 256
    vmem_limit_words: Optional[int] = None    # None: kernels.plan limit
    shed_watermark: float = 0.9               # occupancy that starts sheds

    def _limit(self) -> int:
        if self.vmem_limit_words is not None:
            return int(self.vmem_limit_words)
        from repro.kernels import ops as kops

        return int(kops._FULL_X_LIMIT)

    def vmem_ok(self, n: int, m: int, nb: int) -> bool:
        """True when an ``[m, nb]`` x block + ``[n, nb]`` y block keeps
        VMEM residency at slot width ``nb``."""
        return (m + n) * nb <= self._limit()

    def queue_ok(self, depth: int) -> bool:
        return depth < self.max_queue

    def occupancy(self, depth: int) -> float:
        return depth / self.max_queue if self.max_queue else 1.0


# ---------------------------------------------------------------------------
# degradation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """Occupancy → ladder demotion (DESIGN.md §15.4).

    Two watermarks with hysteresis: above ``demote1`` every class drops
    one tier (toward fewer value bits), above ``demote2`` two; a class
    never drops below its ``tier_floor``.  ``recover`` (strictly below
    ``demote1``) is where demotion switches off again — the gap stops
    tier flapping at the boundary.  Demotion is *global monotone* in
    occupancy and per-class clamped, so the tight-SLO class keeps its
    sub-32-bit tier while the fp32 batch class sheds half its bytes —
    the paper's value-bits dial used as the overload valve."""

    demote1: float = 0.5
    demote2: float = 0.8
    recover: float = 0.35

    def __post_init__(self):
        if not (self.recover < self.demote1 < self.demote2):
            raise ValueError("need recover < demote1 < demote2")

    def level(self, occupancy: float, prev_level: int = 0) -> int:
        """Demotion depth for the current queue occupancy (with
        hysteresis against ``prev_level``)."""
        if occupancy >= self.demote2:
            return 2
        if occupancy >= self.demote1:
            return max(1, min(prev_level, 2)) if prev_level else 1
        if occupancy > self.recover and prev_level:
            return prev_level          # hysteresis band: hold
        return 0

    def tier_for(self, klass: RequestClass, level: int,
                 n_tiers: int) -> int:
        return min(klass.tier + max(0, int(level)), klass.tier_floor,
                   n_tiers - 1)
