"""Slot-based decode engine: batched requests, continuous batching.

Design (vLLM-style, sized for the assignment's decode cells):

* A fixed pool of ``slots`` shares one KV cache ``[L, slots, max_len, …]``
  — the decode step is compiled ONCE for the full pool and runs every
  engine tick regardless of occupancy (inactive slots are masked).
* Prefill is compiled per power-of-two prompt-length bucket with batch 1;
  its cache rows are written into the pool at the assigned slot. New
  requests are admitted whenever a slot frees up (continuous batching) —
  a finished request never blocks the rest of the batch.
* Sampling: greedy or temperature; per-slot EOS/max-token termination.

The engine is backend-agnostic: on the production mesh, params and cache
carry the same logical shardings the dry-run exercises (decode_32k /
long_500k cells); on CPU it serves the reduced configs in the examples and
tests.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from functools import partial
from typing import Optional

log = logging.getLogger(__name__)

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.observe import metrics as _obs


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4
    max_len: int = 512
    temperature: float = 0.0        # 0 => greedy
    eos_id: int = -1                # -1 => never stop on a token
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class WarmupSpec:
    """Everything :meth:`DecodeEngine.warmup` should pre-compile, in one
    place — the keyword surface (``sparse_layers=``, ``dist_plans=``, …)
    had grown one argument per subsystem; composite plans warm up through
    this single path.

    * ``prompt_lens`` — prefill prompt lengths to compile.
    * ``sparse_layers`` — ``models.sparse_linear.PackSELLLinear`` layers:
      pre-builds their cached SpMV plans (and restores store retiles).
    * ``dist_plans`` — ``repro.distributed.DistSpMVPlan``\\ s to pre-trace
      (weight matrices too large for one device).
    * ``composites`` — any object with ``warmup(nb=...)``:
      ``kernels.composite.CompositePlan``, ``precision.MixedPackSELL``,
      distributed tier ladders wrapped in a composite, …
    * ``precision_store`` — a ``repro.precision.PrecisionStore`` or path:
      restores kernel-autotune ``(sb, wb)`` retile winners into each
      layer's plan and logs auto-selected codecs.
    * ``nb`` — multi-RHS width for plan/composite warmups (default: the
      engine's slot count).
    """

    prompt_lens: tuple = ()
    sparse_layers: tuple = ()
    dist_plans: tuple = ()
    composites: tuple = ()
    precision_store: object = None
    nb: Optional[int] = None


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # [len] int32
    max_new_tokens: int
    # filled by the engine:
    out_tokens: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.key = jax.random.PRNGKey(scfg.seed)
        self.cache = tfm.init_cache(cfg, scfg.slots, scfg.max_len)
        # per-slot host state
        self.slot_req: list[Optional[Request]] = [None] * scfg.slots
        self.slot_remaining = np.zeros(scfg.slots, np.int64)
        self.last_token = np.zeros(scfg.slots, np.int32)
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self._uid = 0
        self._prefill_cache = {}
        self._decode = jax.jit(partial(tfm.forward_decode, cfg))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,),
                               static_argnums=(3,))
        self._exporter = None

    # -- perf sentinel (DESIGN.md §13.2) -------------------------------
    def metrics_endpoint_text(self) -> str:
        """The engine's metrics in Prometheus text exposition format —
        what a ``GET /metrics`` handler would return.  Serving counters
        (ticks, decode tokens, request latency quantiles) plus whatever
        else the flight recorder saw this process."""
        from repro.observe import export as _export

        return _export.prometheus_text()

    def start_metrics_exporter(self, path: str = "artifacts/obs/serving.jsonl",
                               interval_s: float = 1.0):
        """Attach a background JSONL exporter (``observe.export``): one
        snapshot-delta record per interval, plus a flush after every
        :meth:`run` drain so short-lived engines still land their tallies.
        Idempotent per engine; returns the :class:`~repro.observe.export.
        Exporter`."""
        from repro.observe import export as _export

        if self._exporter is None:
            meta = _export.run_meta(source="serving.engine",
                                    slots=self.scfg.slots,
                                    max_len=self.scfg.max_len)
            self._exporter = _export.start_exporter(
                interval_s=interval_s, path=path, meta=meta)
        return self._exporter

    def stop_metrics_exporter(self) -> None:
        """Stop the background exporter (final flush included)."""
        if self._exporter is not None:
            self._exporter.stop()
            self._exporter = None

    def __enter__(self) -> "DecodeEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # lifecycle guarantee: however the with-block exits, the daemon
        # flusher is stopped and its last partial interval lands on disk
        self.stop_metrics_exporter()

    # ------------------------------------------------------------------
    def warmup(self, spec: WarmupSpec | None = None, *, prompt_lens=(),
               sparse_layers=(), dist_plans=(), composites=(),
               precision_store=None) -> None:
        """Move compilation out of the serving hot path (the engine analogue
        of the SpMVPlan rule: host-side decisions happen at setup, ticks are
        single dispatches). Takes a :class:`WarmupSpec` — the single
        consolidated description of what to pre-compile — or, back-compat,
        the historical keyword arguments (merged into a spec internally).

        Compiles the pool decode step and the given prefill prompt lengths,
        pre-builds the cached SpMV plans of any PackSELL layers, pre-traces
        distributed plans and composite plans (one ``warmup(nb=...)`` path
        for every composition — plain, mixed-precision, distributed), and
        restores precision-store retiles; the first real tick pays neither
        tracing nor plan construction."""
        if spec is not None and not isinstance(spec, WarmupSpec):
            # historical positional call: warmup([16, 32]) meant prompt_lens
            if prompt_lens:
                raise ValueError("pass a WarmupSpec OR keyword arguments, "
                                 "not both")
            prompt_lens, spec = tuple(spec), None
        if spec is None:
            spec = WarmupSpec(prompt_lens=tuple(prompt_lens),
                              sparse_layers=tuple(sparse_layers),
                              dist_plans=tuple(dist_plans),
                              composites=tuple(composites),
                              precision_store=precision_store)
        elif (prompt_lens or sparse_layers or dist_plans or composites
              or precision_store is not None):
            raise ValueError("pass a WarmupSpec OR keyword arguments, "
                             "not both")
        store = spec.precision_store
        if store is not None:
            from repro.precision import PrecisionStore
            store = PrecisionStore.coerce(store)
        nb = self.scfg.slots if spec.nb is None else int(spec.nb)
        tokens = jnp.zeros((self.scfg.slots, 1), jnp.int32)
        logits, _ = self._decode(self.params, tokens, self.cache)
        jax.block_until_ready(logits)
        for plen in spec.prompt_lens:
            toks = jnp.zeros((1, int(plen)), jnp.int32)
            logits, _ = self._prefill_fn(int(plen))(
                self.params, {"tokens": toks})
            jax.block_until_ready(logits)
        for i, lin in enumerate(spec.sparse_layers):
            desc = lin.describe() if hasattr(lin, "describe") else {}
            # self-healing: a plan the guard layer marked unhealthy (ABFT
            # checksum trip, validation failure) is rebuilt from the
            # layer's retained CSR before any decode tick reuses it —
            # corrupted packed operands survive jit re-dispatch otherwise
            if hasattr(lin, "plan") and hasattr(lin, "rebuild"):
                from repro.robust import guard as _guard
                health = _guard.plan_health(lin.plan)
                if health is not None:
                    log.warning(
                        "warmup: layer %d plan unhealthy (%s) — rebuilding "
                        "from retained CSR", i, health)
                    _obs.inc("serving.warmup_rebuild", reason=health)
                    lin.rebuild()
            if store is not None and desc.get("fingerprint"):
                key = f"plan_{desc['codec']}{desc['D']}"
                layer_name = getattr(lin, "name", None) or f"layer_{i}"
                try:
                    applied = store.apply_retile(desc["fingerprint"], key,
                                                 lin.plan)
                except Exception as e:
                    # a poisoned store entry (malformed tiles, infeasible
                    # band retile) must not take warmup down: the layer
                    # keeps its build-time tiles, which are always valid
                    log.warning(
                        "warmup: %s (layer %d) retile from store FAILED — "
                        "shape=%s key=%s fingerprint=%s: %s", layer_name,
                        i, desc.get("shape"), key, desc["fingerprint"], e)
                    _obs.inc("serving.warmup_retile_failure", key=key)
                else:
                    if applied:
                        log.info("warmup: %s (layer %d) retiled from store "
                                 "(%s)", layer_name, i, key)
            plan = lin.warmup()
            pdesc = plan.describe()
            plan_tag = "%s/%s" % (pdesc["variant"], pdesc["cache_mode"])
            if pdesc.get("fused"):
                plan_tag += "@wr=%d" % pdesc["ckpt_width"]
            if desc.get("auto_selected"):
                log.info(
                    "warmup: layer %d codec=%s D=%d auto-selected (%s), "
                    "memory_ratio=%.3f, plan=%s", i, desc["codec"],
                    desc["D"],
                    "store hit" if desc.get("from_store") else "analyzed",
                    desc.get("memory_ratio", float("nan")), plan_tag)
            elif desc:
                log.info("warmup: layer %d codec=%s D=%d (caller-fixed), "
                         "plan=%s", i, desc["codec"], desc["D"], plan_tag)
        for dp in spec.dist_plans:
            dp.warmup(nb=nb)
        for comp in spec.composites:
            comp.warmup(nb=nb)
            if hasattr(comp, "describe"):
                log.info("warmup: composite %s", comp.describe())

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> Request:
        req = Request(self._uid, np.asarray(prompt, np.int32),
                      max_new_tokens, t_submit=time.perf_counter())
        self._uid += 1
        self.queue.append(req)
        return req

    # ------------------------------------------------------------------
    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_cache:
            self._prefill_cache[bucket] = jax.jit(
                partial(tfm.forward_prefill, self.cfg,
                        max_len=self.scfg.max_len))
        return self._prefill_cache[bucket]

    @staticmethod
    def _insert_impl(pool_cache, one_cache, slot, keys):
        """Write a B=1 prefill cache into pool slot ``slot``."""
        out = dict(pool_cache)
        for k in keys:
            v = one_cache[k]
            if k == "len":
                out[k] = pool_cache[k].at[slot].set(v[0])
            else:
                # layer-major arrays: [L, B, ...] -> write batch row
                out[k] = pool_cache[k].at[:, slot].set(v[:, 0])
        return out

    def _admit(self, req: Request):
        slot = self.slot_req.index(None)
        plen = len(req.prompt)
        # prefill at the exact prompt length: padding-free, so positions,
        # causality, and the last-token logits are exact. One compile per
        # distinct length (callers wanting fewer compiles pre-pad prompts
        # to common lengths).
        toks = req.prompt[None, :]
        logits, one_cache = self._prefill_fn(plen)(
            self.params, {"tokens": jnp.asarray(toks)})
        tok = self._sample(logits[:, -1])[0]
        req.t_first = time.perf_counter()
        req.out_tokens.append(int(tok))
        self.cache = self._insert(self.cache, one_cache, slot,
                                  tuple(sorted(one_cache.keys())))
        self.slot_req[slot] = req
        self.slot_remaining[slot] = req.max_new_tokens - 1
        self.last_token[slot] = int(tok)
        if self.slot_remaining[slot] <= 0 or int(tok) == self.scfg.eos_id:
            self._finish(slot)

    def _finish(self, slot: int):
        req = self.slot_req[slot]
        req.t_done = time.perf_counter()
        self.done.append(req)
        self.slot_req[slot] = None
        self.slot_remaining[slot] = 0
        _obs.inc("serving.finished")
        _obs.observe("serving.request_latency_s", req.latency)

    def _sample(self, logits: jnp.ndarray) -> np.ndarray:
        if self.scfg.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / self.scfg.temperature, axis=-1), np.int32)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit to free slots, decode one token for all
        active slots. Returns the number of active slots."""
        while self.queue and None in self.slot_req:
            self._admit(self.queue.pop(0))
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = jnp.asarray(self.last_token[:, None])
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        next_tok = self._sample(logits[:, -1])
        for i in active:
            tok = int(next_tok[i])
            req = self.slot_req[i]
            req.out_tokens.append(tok)
            self.last_token[i] = tok
            self.slot_remaining[i] -= 1
            if self.slot_remaining[i] <= 0 or tok == self.scfg.eos_id:
                self._finish(i)
        _obs.inc("serving.tick")
        _obs.inc("serving.decode_tokens", len(active))
        return len(active)

    def run(self, max_ticks: int = 100_000) -> list[Request]:
        """Drain the queue; returns completed requests."""
        ticks = 0
        try:
            while (self.queue or any(r is not None for r in self.slot_req)) \
                    and ticks < max_ticks:
                self.step()
                ticks += 1
        finally:
            if self._exporter is not None:   # land this batch's tallies now
                self._exporter.sink.flush()  # even when a step raised
        return self.done

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        if not self.done:
            return {}
        lat = [r.latency for r in self.done]
        ttft = [r.ttft for r in self.done]
        ntok = sum(len(r.out_tokens) for r in self.done)
        span = max(r.t_done for r in self.done) - \
            min(r.t_submit for r in self.done)
        return {
            "requests": len(self.done),
            "tokens": ntok,
            "tokens_per_s": ntok / span if span > 0 else float("nan"),
            "mean_latency_s": float(np.mean(lat)),
            "mean_ttft_s": float(np.mean(ttft)),
        }
