"""Serving substrate: KV-cache decode engine with continuous batching,
plus the resilient spmv/solve request front end (DESIGN.md §15)."""
from .engine import (DecodeEngine, Request, ServeConfig,  # noqa: F401
                     WarmupSpec)
from .frontend import (AdmissionError, FrontendConfig,  # noqa: F401
                       PlanEntry, ServingFrontend)
from .frontend import Request as ServeRequest  # noqa: F401
from .policy import (AdmissionPolicy, BackoffPolicy,  # noqa: F401
                     CircuitBreaker, DegradationPolicy, ManualClock,
                     RequestClass, tier_error_budget)
