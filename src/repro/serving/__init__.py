"""Serving substrate: KV-cache decode engine with continuous batching."""
from .engine import (DecodeEngine, Request, ServeConfig,  # noqa: F401
                     WarmupSpec)
