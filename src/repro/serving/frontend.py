"""Resilient SpMV/solve serving front end (DESIGN.md §15).

The request path the kernel library never had: ``serving.engine`` ticks
an LM decode pool, but nothing served *spmv/solve* requests — the
workload the paper's format exists for.  :class:`ServingFrontend` is
that layer, with defined behavior under both faults and saturation:

* **Admission** (§15.2) — a bounded queue with loud rejection
  (``queue_full``) plus the VMEM-residency guard: a request whose
  coalesced ``[m, nb]`` x block cannot stay VMEM-resident is rejected
  at the door (``vmem``), not silently routed to a slow body that
  blows every deadline queued behind it.
* **Coalescing** (§15.3) — same-fingerprint spmv requests batch into
  multi-RHS ``spmm`` slots (the MaxText offline-inference slot idiom:
  one compiled shape per (plan, slot-width), zero-padded partial
  slots), so k concurrent requests stream the operand words ONCE — the
  bytes/nnz × bandwidth figure of merit divides by the slot occupancy.
* **Deadlines and retries** — per-request deadlines on a monotonic
  clock; transient guard trips retry with deterministic exponential
  backoff (:class:`~repro.serving.policy.BackoffPolicy`), exhausted
  retries complete on the fp32 fallback instead of failing.
* **Breakers and self-healing** (§15.5) — every plan entry carries a
  :class:`~repro.serving.policy.CircuitBreaker`; repeated trips
  quarantine the plan (traffic reroutes to the fp32 fallback built
  from the retained CSR), a background rebuild restores the packed
  operand, and half-open probes re-admit it.
* **Degradation** (§15.4) — under overload, request classes demote
  down the PR-3 precision ladder (tight-SLO classes keep their
  sub-32-bit tiers, best-effort classes shed first), trading value
  bits for sustained QPS before any request is dropped.

Every decision is exported through the observe layer (queue depth,
shed rate, deadline misses, breaker transitions, per-tier goodput) and
the whole frontend is clock-injectable: tests drive it with
:class:`~repro.serving.policy.ManualClock` and ``background=False``
for exact, sleep-free assertions.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import queue as _queue
import threading
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.observe import metrics as _obs
from repro.robust import guard as gd

from . import policy as pol

log = logging.getLogger(__name__)

__all__ = ["FrontendConfig", "Request", "PlanEntry", "ServingFrontend",
           "AdmissionError"]


class AdmissionError(ValueError):
    """A request could not even be queued (unknown fingerprint, shape
    mismatch) — distinct from a *rejection*, which is a served answer."""


# ---------------------------------------------------------------------------
# configuration and the request record
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Everything the front end decides with, in one place."""

    slots: int = 4                         # RHS columns per spmm slot
    plan_pool: int = 8                     # resident PlanEntry cap (LRU)
    ladder: tuple = pol.DEFAULT_LADDER     # tiers, most accurate first
    classes: tuple = pol.DEFAULT_CLASSES
    admission: pol.AdmissionPolicy = dataclasses.field(
        default_factory=pol.AdmissionPolicy)
    degrade: pol.DegradationPolicy = dataclasses.field(
        default_factory=pol.DegradationPolicy)
    backoff: pol.BackoffPolicy = dataclasses.field(
        default_factory=pol.BackoffPolicy)
    fail_threshold: int = 2                # breaker: consecutive trips
    cooldown_s: float = 0.0                # breaker: OPEN dwell minimum
    probe_successes: int = 1               # breaker: half-open probes
    guard_every: int = 1                   # full-guard stride per plan
    background: bool = True                # async warmup/rebuild worker
    C: int = 32
    sigma: int = 64
    store: object = None                   # PrecisionStore or path
    solve_tol: float = 1e-8
    solve_maxiter: int = 60

    def klass(self, name: str) -> pol.RequestClass:
        for c in self.classes:
            if c.name == name:
                return c
        raise AdmissionError(
            f"unknown request class {name!r}; configured: "
            f"{[c.name for c in self.classes]}")


@dataclasses.dataclass
class Request:
    """One spmv/solve request through its lifecycle (§15.1).

    ``status`` walks queued → ok | rejected | shed | deadline_miss |
    failed; ``tier_kind`` records the operator that actually answered
    (a ladder kind, or ``'fp32_fallback'`` when a breaker rerouted
    it)."""

    uid: int
    fingerprint: str
    x: np.ndarray
    klass: pol.RequestClass
    op: str = "spmv"                     # 'spmv' | 'solve'
    deadline: float = 0.0                # absolute, monotonic
    t_submit: float = 0.0
    not_before: float = 0.0              # backoff gate
    attempts: int = 0                    # guard-trip retries so far
    status: str = "queued"
    reason: str = ""
    tier: Optional[int] = None
    tier_kind: str = ""
    y: Optional[np.ndarray] = None
    t_done: float = 0.0
    missed_deadline: bool = False
    solve_info: Optional[object] = None

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ok(self) -> bool:
        return self.status == "ok"


# ---------------------------------------------------------------------------
# plan entries and the bounded pool
# ---------------------------------------------------------------------------


class PlanEntry:
    """One registered matrix: retained CSR, lazily-built per-tier
    (mat, plan, guard) triples, the fp32 fallback, and the breaker.

    The CSR is the *source of truth* — every rebuild re-encodes from
    it, so no packed corruption is ever laundered into a repair."""

    def __init__(self, fingerprint: str, csr, cfg: FrontendConfig,
                 clock: Callable[[], float]):
        from repro.solvers.operators import OperatorSet

        self.fingerprint = fingerprint
        self.csr = csr.tocsr()
        self.cfg = cfg
        self.n, self.m = self.csr.shape
        self.ops = OperatorSet(self.csr, C=cfg.C, sigma=cfg.sigma)
        self.breaker = pol.CircuitBreaker(
            fail_threshold=cfg.fail_threshold, cooldown_s=cfg.cooldown_s,
            probe_successes=cfg.probe_successes, clock=clock,
            name=fingerprint[:8])
        self.guards: dict = {}          # kind -> GuardState
        self.tokens: dict = {}          # kind -> plan token at bind time
        self.lock = threading.RLock()   # bind/rebuild vs dispatch thread
        self._fp32_mm = None
        self.warmed: set = set()

    # -- binding -----------------------------------------------------------
    def bind(self, kind: str):
        """(mat, plan, guard) for a packed ladder kind, built/cached via
        the entry's OperatorSet.  Applies any precision-store retile
        winner on first bind (poisoned store entries are survivable:
        the build-time tiles are always valid)."""
        with self.lock:
            mat, plan = self.ops.plan_pair(kind)
            if kind not in self.guards:
                self._apply_retile(kind, plan)
                self.guards[kind] = gd.build_guard(
                    mat, plan, every=self.cfg.guard_every)
                self.tokens[kind] = getattr(mat, "_plan_token", None)
            return mat, plan, self.guards[kind]

    def _apply_retile(self, kind: str, plan) -> None:
        if self.cfg.store is None:
            return
        from repro.precision import PrecisionStore
        from repro.solvers.operators import parse_kind

        try:
            store = PrecisionStore.coerce(self.cfg.store)
            spec = parse_kind(kind)
            key = f"plan_{spec.codec}{spec.D}"   # engine-warmup convention
            store.apply_retile(self.fingerprint, key, plan)
        except Exception as e:
            # engine-warmup contract: a garbled store must never take
            # the serving path down — keep build-time tiles, loudly
            log.warning("frontend: retile from store failed for %s/%s: %s",
                        self.fingerprint[:8], kind, e)
            _obs.inc("frontend.store_retile_failure", kind=kind)

    def stale(self, kind: str) -> bool:
        """Plan-token staleness: the bound operand no longer matches
        the token recorded at bind time (a refreshed/replaced matrix
        object) — the cached dispatch would ship stale operands."""
        ent = self.ops._cache.get(kind)
        if ent is None or kind not in self.tokens:
            return False
        return getattr(ent[1], "_plan_token", None) != self.tokens[kind]

    def healthy(self, kind: str) -> bool:
        """Guard-layer health of a bound tier (unbound tiers are
        vacuously healthy — nothing has tripped yet)."""
        ent = self.ops._cache.get(kind)
        if ent is None:
            return True
        from repro.kernels import plan as kplan

        return gd.is_healthy(kplan.get_plan(ent[1]))

    # -- repair ------------------------------------------------------------
    def rebuild(self, kind: str) -> None:
        """Rebuild one tier's packed operand + guard from the retained
        CSR (PR-6 contract), then tell the breaker probing may start."""
        with self.lock:
            self.ops._cache.pop(kind, None)
            self.guards.pop(kind, None)
            self.tokens.pop(kind, None)
            self.bind(kind)
            self.breaker.note_rebuilt()
            _obs.inc("frontend.rebuild", kind=kind)

    # -- fp32 fallback -----------------------------------------------------
    def spmm_fp32(self, x2d: jnp.ndarray) -> jnp.ndarray:
        """Batched fp32 reference matvec on the uncompressed SELL
        operand — shares NO arrays with any packed tier, so it stays
        correct while a packed operand is quarantined."""
        if self._fp32_mm is None:
            fn = self.ops.matvec("fp32")   # builds + caches SELL fp32
            self._fp32_mm = jax.jit(jax.vmap(fn, in_axes=1, out_axes=1))
        return self._fp32_mm(x2d)

    # -- warmup ------------------------------------------------------------
    def warmup(self, kinds, nb: int) -> None:
        """Pre-build and pre-trace the slot-shaped guarded spmm for the
        given ladder kinds (plus the fp32 fallback), so first traffic
        pays neither packing nor compilation."""
        x2d = jnp.zeros((self.m, nb), jnp.float32)
        for kind in kinds:
            if (kind, nb) in self.warmed:
                continue
            if kind == "fp32":
                jax.block_until_ready(self.spmm_fp32(x2d))
            else:
                mat, plan, gs = self.bind(kind)
                y, _, _ = gd.guarded_spmm(mat, plan, gs, x2d, full=True)
                jax.block_until_ready(y)
            self.warmed.add((kind, nb))
        jax.block_until_ready(self.spmm_fp32(x2d))
        _obs.inc("frontend.warmup", fingerprint=self.fingerprint[:8])


# ---------------------------------------------------------------------------
# the front end
# ---------------------------------------------------------------------------


class ServingFrontend:
    """Queue → coalesce → guarded dispatch → respond, under policy.

    Construction is cheap; matrices are :meth:`register`\\ ed (warmed in
    the background by default), requests :meth:`submit`\\ ted, and
    :meth:`step` runs one scheduler tick (admit/expire/shed → form one
    slot → execute → complete).  ``run_until_drained`` loops ticks and
    knows how to advance a :class:`~repro.serving.policy.ManualClock`
    across backoff gaps so tests never sleep."""

    def __init__(self, cfg: FrontendConfig | None = None, *,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg or FrontendConfig()
        self.clock = clock
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self.registry: dict = {}                      # fingerprint -> csr
        self.pool: "collections.OrderedDict[str, PlanEntry]" = \
            collections.OrderedDict()
        self._uid = 0
        self._demote_level = 0
        self._exporter = None
        self._bg: Optional[_queue.Queue] = None
        self._bg_thread: Optional[threading.Thread] = None
        if self.cfg.background:
            self._bg = _queue.Queue()
            self._bg_thread = threading.Thread(
                target=self._bg_loop, name="repro-frontend-worker",
                daemon=True)
            self._bg_thread.start()

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "ServingFrontend":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def close(self) -> None:
        """Stop the background worker and the exporter (final flush) —
        idempotent, exception-safe teardown."""
        try:
            if self._bg is not None:
                self._bg.put(None)
                if self._bg_thread is not None:
                    self._bg_thread.join(timeout=5.0)
                self._bg = None
                self._bg_thread = None
        finally:
            self.stop_metrics_exporter()

    def _bg_loop(self) -> None:
        while True:
            fn = self._bg.get()
            if fn is None:
                return
            try:
                fn()
            except Exception:           # a failed warmup/rebuild must not
                log.exception("frontend: background task failed")
                _obs.inc("frontend.background_failure")

    def _defer(self, fn: Callable[[], None]) -> None:
        if self._bg is not None:
            self._bg.put(fn)
        else:
            fn()

    def drain_background(self, timeout: float = 30.0) -> None:
        """Block until queued background work (warmups, rebuilds) has
        run — the serving analogue of ``block_until_ready``."""
        if self._bg is None:
            return
        ev = threading.Event()
        self._bg.put(ev.set)
        if not ev.wait(timeout):
            raise TimeoutError("frontend background worker did not drain")

    # -- exporter (engine parity) -----------------------------------------
    def start_metrics_exporter(self,
                               path: str = "artifacts/obs/frontend.jsonl",
                               interval_s: float = 1.0):
        from repro.observe import export as _export

        if self._exporter is None:
            meta = _export.run_meta(source="serving.frontend",
                                    slots=self.cfg.slots,
                                    pool=self.cfg.plan_pool)
            self._exporter = _export.start_exporter(
                interval_s=interval_s, path=path, meta=meta)
        return self._exporter

    def stop_metrics_exporter(self) -> None:
        if self._exporter is not None:
            self._exporter.stop()
            self._exporter = None

    # -- registration ------------------------------------------------------
    def register(self, a, *, fingerprint: str | None = None,
                 warm: bool = True) -> str:
        """Retain a matrix for serving; returns its fingerprint (the
        coalescing key).  ``warm=True`` schedules background build +
        trace of the default tier kinds at the slot shape."""
        from repro.precision.store import matrix_fingerprint

        csr = a.tocsr()
        fp = fingerprint or matrix_fingerprint(csr)
        self.registry[fp] = csr
        if warm:
            entry = self._entry(fp)
            kinds = sorted({self.cfg.ladder[c.tier]
                            for c in self.cfg.classes})
            self._defer(lambda: entry.warmup(kinds, self.cfg.slots))
        return fp

    def _entry(self, fp: str) -> PlanEntry:
        """Pool lookup with LRU update; a miss re-builds the entry from
        the retained CSR (re-warm happens lazily on first dispatch)."""
        ent = self.pool.get(fp)
        if ent is not None:
            self.pool.move_to_end(fp)
            return ent
        if fp not in self.registry:
            raise AdmissionError(
                f"unknown fingerprint {fp!r}; register() the matrix first")
        if len(self.pool) >= self.cfg.plan_pool:
            old_fp, old = self.pool.popitem(last=False)
            log.info("frontend: plan pool full — evicted %s (LRU)",
                     old_fp[:8])
            _obs.inc("frontend.pool_evict")
            del old
        ent = PlanEntry(fp, self.registry[fp], self.cfg, self.clock)
        self.pool[fp] = ent
        _obs.inc("frontend.pool_build")
        return ent

    # -- admission ---------------------------------------------------------
    def submit(self, fingerprint: str, x, *, klass: str = "standard",
               deadline_s: float | None = None,
               op: str = "spmv") -> Request:
        """Admit one request (or reject it loudly).  Returns the
        :class:`Request`; a rejection comes back with ``status`` in
        ``('rejected',)`` and a ``reason`` — the caller is told NOW,
        not after a deadline's worth of queueing."""
        if op not in ("spmv", "solve"):
            raise AdmissionError(f"op must be spmv|solve, got {op!r}")
        if fingerprint not in self.registry:
            raise AdmissionError(
                f"unknown fingerprint {fingerprint!r}; register() first")
        kc = self.cfg.klass(klass)
        csr = self.registry[fingerprint]
        n, m = csr.shape
        x = np.asarray(x, np.float32 if op == "spmv" else np.float64)
        want = m if op == "spmv" else n
        if x.shape != (want,):
            raise AdmissionError(
                f"x shape {x.shape} != ({want},) for {op} on {n}x{m}")
        now = self.clock()
        req = Request(self._uid, fingerprint, x, kc, op=op, t_submit=now,
                      deadline=now + (deadline_s if deadline_s is not None
                                      else kc.deadline_s))
        self._uid += 1
        adm = self.cfg.admission
        if not adm.queue_ok(len(self.queue)):
            return self._reject(req, "queue_full")
        if op == "spmv" and not adm.vmem_ok(n, m, self.cfg.slots):
            return self._reject(req, "vmem")
        self.queue.append(req)
        _obs.gauge("frontend.queue_depth", len(self.queue))
        return req

    def _reject(self, req: Request, reason: str) -> Request:
        req.status, req.reason = "rejected", reason
        req.t_done = self.clock()
        self.done.append(req)
        log.warning("frontend: REJECTED request %d (%s, klass=%s)",
                    req.uid, reason, req.klass.name)
        _obs.inc("frontend.shed", reason=reason, klass=req.klass.name)
        return req

    # -- scheduling --------------------------------------------------------
    def _finish(self, req: Request, status: str, *, reason: str = "",
                y=None, tier_kind: str = "") -> None:
        now = self.clock()
        req.status, req.reason, req.t_done = status, reason, now
        req.y, req.tier_kind = y, tier_kind or req.tier_kind
        req.missed_deadline = now > req.deadline
        self.done.append(req)
        if status == "ok":
            _obs.inc("frontend.requests_ok", tier=req.tier_kind,
                     klass=req.klass.name)
            _obs.observe("frontend.latency_s", req.latency,
                         klass=req.klass.name)
            if req.missed_deadline:
                _obs.inc("frontend.deadline_miss", klass=req.klass.name,
                         stage="completed_late")
        elif status == "deadline_miss":
            _obs.inc("frontend.deadline_miss", klass=req.klass.name,
                     stage="queued")
        elif status == "shed":
            _obs.inc("frontend.shed", reason=reason, klass=req.klass.name)
        else:
            _obs.inc("frontend.failed", reason=reason,
                     klass=req.klass.name)

    def _expire_and_shed(self, now: float) -> None:
        keep = []
        for r in self.queue:
            if now > r.deadline:
                self._finish(r, "deadline_miss", reason="expired_in_queue")
            else:
                keep.append(r)
        self.queue = keep
        adm = self.cfg.admission
        target = int(adm.shed_watermark * adm.max_queue)
        if len(self.queue) > target:
            # shed-order: lowest-priority class first (highest priority
            # number), newest first within a class — tight-SLO requests
            # and the oldest work survive longest
            order = sorted(range(len(self.queue)),
                           key=lambda i: (-self.queue[i].klass.priority,
                                          -self.queue[i].t_submit))
            drop = set(order[: len(self.queue) - target])
            kept = []
            for i, r in enumerate(self.queue):
                if i in drop:
                    self._finish(r, "shed", reason="overload")
                else:
                    kept.append(r)
            self.queue = kept

    def _pick_batch(self, now: float):
        """The next slot to run: group ready spmv requests by
        (fingerprint, demoted tier), take the group containing the
        most urgent request, oldest-first, up to the slot width."""
        level = self._demote_level
        groups: dict = {}
        for r in self.queue:
            if r.op != "spmv" or r.not_before > now:
                continue
            tier = self.cfg.degrade.tier_for(r.klass, level,
                                             len(self.cfg.ladder))
            groups.setdefault((r.fingerprint, tier), []).append(r)
        if not groups:
            return None, None
        key = min(groups, key=lambda k: min(
            (r.klass.priority, r.t_submit, r.uid) for r in groups[k]))
        batch = sorted(groups[key],
                       key=lambda r: (r.t_submit, r.uid))[: self.cfg.slots]
        return key, batch

    def step(self) -> int:
        """One scheduler tick; returns the number of requests completed
        (any terminal status)."""
        now = self.clock()
        done0 = len(self.done)
        self._expire_and_shed(now)
        occ = self.cfg.admission.occupancy(len(self.queue))
        prev = self._demote_level
        self._demote_level = self.cfg.degrade.level(occ, prev)
        if self._demote_level != prev:
            _obs.inc("frontend.demote_level_change",
                     level=self._demote_level)
            log.info("frontend: occupancy %.2f -> demotion level %d",
                     occ, self._demote_level)
        key, batch = self._pick_batch(now)
        if key is not None:
            self._run_batch(key[0], key[1], batch)
        else:
            solve = next((r for r in self.queue
                          if r.op == "solve" and r.not_before <= now),
                         None)
            if solve is not None:
                self._run_solve(solve)
        _obs.gauge("frontend.queue_depth", len(self.queue))
        return len(self.done) - done0

    # -- execution ---------------------------------------------------------
    def _run_batch(self, fp: str, tier: int, batch: list) -> None:
        entry = self._entry(fp)
        kind = self.cfg.ladder[tier]
        for r in batch:
            r.tier, r.tier_kind = tier, kind
        x2d = np.zeros((entry.m, self.cfg.slots), np.float32)
        for j, r in enumerate(batch):
            x2d[:, j] = r.x
        x2d = jnp.asarray(x2d)
        use_guarded = kind != "fp32" and entry.breaker.allow()
        if use_guarded and entry.stale(kind):
            log.warning("frontend: plan token stale for %s/%s — "
                        "rebuilding before dispatch", fp[:8], kind)
            _obs.inc("frontend.stale_plan", kind=kind)
            entry.rebuild(kind)
        if not use_guarded:
            y = np.asarray(entry.spmm_fp32(x2d))
            label = "fp32" if kind == "fp32" else "fp32_fallback"
            _obs.inc("frontend.matvec", tier=label, n=len(batch))
            self._complete_batch(batch, y, label)
            return
        mat, plan, gs = entry.bind(kind)
        y, ok, rel = gd.guarded_spmm(mat, plan, gs, x2d)
        if bool(ok):
            entry.breaker.record_success()
            _obs.inc("frontend.matvec", tier=kind, n=len(batch))
            self._complete_batch(batch, np.asarray(y), kind)
            return
        # -- guard trip ----------------------------------------------------
        gd.mark_unhealthy(plan, "guard_trip")
        entry.breaker.record_failure()
        _obs.inc("frontend.guard_trip", kind=kind)
        log.warning("frontend: guard TRIP on %s/%s (rel=%.3g, breaker=%s)",
                    fp[:8], kind, float(np.asarray(rel)),
                    entry.breaker.state)
        self._defer(lambda: entry.rebuild(kind))
        now = self.clock()
        fallback = []
        for r in batch:
            r.attempts += 1
            if self.cfg.backoff.exhausted(r.attempts):
                fallback.append(r)
            else:
                r.not_before = now + self.cfg.backoff.delay(r.attempts)
                _obs.inc("frontend.retry", klass=r.klass.name)
        if fallback:
            # retries exhausted: answer NOW on the uncorruptible path
            y = np.asarray(entry.spmm_fp32(x2d))
            _obs.inc("frontend.matvec", tier="fp32_fallback",
                     n=len(fallback))
            self._complete_batch(fallback, y, "fp32_fallback",
                                 cols={r.uid: j for j, r in
                                       enumerate(batch)})

    def _complete_batch(self, batch: list, y: np.ndarray, label: str,
                        cols: dict | None = None) -> None:
        inflight = set(id(r) for r in batch)
        self.queue = [r for r in self.queue if id(r) not in inflight]
        for j, r in enumerate(batch):
            col = cols[r.uid] if cols is not None else j
            self._finish(r, "ok", y=y[:, col], tier_kind=label)

    def _run_solve(self, req: Request) -> None:
        from repro.robust import recover as rc
        from repro.solvers.operators import parse_kind

        entry = self._entry(req.fingerprint)
        tier = self.cfg.degrade.tier_for(req.klass, self._demote_level,
                                         len(self.cfg.ladder))
        # guarded_solve wants a packed plan kind to start its own
        # escalation ladder from; an fp32-tier request starts one rung in
        kinds = [k for k in self.cfg.ladder[max(tier, 1):]
                 if parse_kind(k).family == "plan"]
        kind = kinds[0] if kinds else "plan_fp16"
        req.tier, req.tier_kind = tier, f"solve:{kind}"
        self.queue.remove(req)
        try:
            x, info = rc.guarded_solve(
                entry.ops, kind, req.x, tol=self.cfg.solve_tol,
                maxiter=self.cfg.solve_maxiter)
        except Exception as e:
            log.exception("frontend: solve %d failed", req.uid)
            self._finish(req, "failed", reason=repr(e))
            return
        req.solve_info = info
        if info.trips:
            entry.breaker.record_failure()
            _obs.inc("frontend.guard_trip", kind=f"solve:{kind}")
        self._finish(req, "ok", y=x, tier_kind=f"solve:{info.final_kind}")

    # -- driving -----------------------------------------------------------
    def run_until_drained(self, max_ticks: int = 100_000) -> list:
        """Tick until the queue empties (or the tick budget runs out).
        Idle ticks (everything backoff-gated) advance a ManualClock, or
        briefly sleep a real one, to the next eligible time."""
        ticks = 0
        while self.queue and ticks < max_ticks:
            before = len(self.done)
            self.step()
            ticks += 1
            if len(self.done) == before and self.queue:
                now = self.clock()
                wait = max(min(r.not_before for r in self.queue) - now, 0.0)
                if wait > 0:
                    if hasattr(self.clock, "advance"):
                        self.clock.advance(wait)
                    else:
                        time.sleep(min(wait, 0.05))
        return self.done

    # -- reporting ---------------------------------------------------------
    def stats(self) -> dict:
        by_status: dict = {}
        by_tier: dict = {}
        lat = []
        for r in self.done:
            by_status[r.status] = by_status.get(r.status, 0) + 1
            if r.status == "ok":
                by_tier[r.tier_kind] = by_tier.get(r.tier_kind, 0) + 1
                lat.append(r.latency)
        out = {
            "submitted": self._uid,
            "completed": len(self.done),
            "queued": len(self.queue),
            "by_status": by_status,
            "by_tier": by_tier,
            "deadline_misses": sum(1 for r in self.done
                                   if r.missed_deadline
                                   or r.status == "deadline_miss"),
            "demote_level": self._demote_level,
        }
        if lat:
            s = np.sort(np.asarray(lat))
            out["p50_latency_s"] = float(s[int(0.5 * (len(s) - 1))])
            out["p99_latency_s"] = float(s[int(0.99 * (len(s) - 1))])
        return out
