"""The PackSELL sparse matrix format (paper §4) and its JAX SpMV.

Layout
------
Rows are σ-sorted (descending stored length, stable) within blocks of σ rows
(SELL-C-σ style, §4.3), then grouped into slices of C consecutive stored rows.
Each slice is padded to its max stored length with ``flag=0, delta=0`` words.

TPU adaptation (DESIGN.md §2): instead of a flat array + ``offset[]``
indirection, slices are grouped into **width buckets**: every slice's width is
rounded up to the bucket width so each bucket is a dense ``uint32[S, w, C]``
tensor. σ-sorting makes adjacent widths similar, so the extra padding is small
(reported in :meth:`PackSELLMatrix.memory_stats`), and the compute path gets
static shapes → static Pallas BlockSpecs and clean vectorization. Correctness
is unaffected because padding words are self-consistent.

The stored-row → original-row permutation is kept two ways: the paper-faithful
σ-local uint8 ``perm`` (for memory accounting and the implicit-permutation
story) and a precomputed int32 ``outrow`` gather map actually used on device.
"""
from __future__ import annotations

import dataclasses
import math
import os
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from . import codecs as cd
from . import delta as de

PAD_WORD = np.uint32(0)  # flag=0, delta=0: contributes v=0, cursor unchanged


def _ceil_to(x: int, q: int) -> int:
    return (x + q - 1) // q * q


def _cumsum0(a: np.ndarray) -> np.ndarray:
    out = np.zeros(len(a) + 1, dtype=np.int64)
    np.cumsum(a, out=out[1:])
    return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackSELLMatrix:
    """Device-side PackSELL matrix. Registered as a pytree (jit-safe)."""

    # --- array leaves (device) ---
    packs: tuple          # tuple of uint32[S_b, w_b, C]
    d0s: tuple            # tuple of int32[S_b]      base column per slice
    outrows: tuple        # tuple of int32[S_b * C]  stored row -> orig row (n == drop)
    maxcols: tuple        # tuple of int32[S_b]      max column per slice (band kernel)
    perm: jnp.ndarray     # uint8/uint16[n_padded]   σ-local perm (paper-faithful)

    # --- static metadata ---
    n: int
    m: int
    C: int
    sigma: int
    D: int
    codec_name: str
    k_left: int
    nnz: int
    n_dummy: int
    words_sell_padded: int   # words if padded per-slice (paper layout)
    words_bucketed: int      # words actually stored (bucket layout)

    _STATIC = ("n", "m", "C", "sigma", "D", "codec_name", "k_left", "nnz",
               "n_dummy", "words_sell_padded", "words_bucketed")

    @property
    def codec(self) -> cd.Codec:
        return cd.make_codec(self.codec_name)

    @property
    def shape(self):
        return (self.n, self.m)

    def tree_flatten(self):
        leaves = (self.packs, self.d0s, self.outrows, self.maxcols, self.perm)
        aux = tuple(getattr(self, f) for f in self._STATIC)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        packs, d0s, outrows, maxcols, perm = leaves
        return cls(packs, d0s, outrows, maxcols, perm, *aux)

    # ------------------------------------------------------------------
    # memory accounting (paper Fig. 7 analogue)
    # ------------------------------------------------------------------
    def memory_stats(self) -> dict:
        n_slices = sum(int(p.shape[0]) for p in self.packs)
        perm_bytes = self.perm.size * self.perm.dtype.itemsize
        pack_bytes = 4 * self.words_sell_padded
        offset_bytes = 4 * (n_slices + 1)
        packsell = pack_bytes + offset_bytes + perm_bytes
        bucket_overhead = 4 * (self.words_bucketed - self.words_sell_padded)
        return dict(
            packsell_bytes=packsell,
            bucket_overhead_bytes=bucket_overhead,
            pack_bytes=pack_bytes,
            perm_bytes=perm_bytes,
            offset_bytes=offset_bytes,
            nnz=self.nnz,
            n_dummy=self.n_dummy,
            words_sell_padded=self.words_sell_padded,
            words_bucketed=self.words_bucketed,
        )

    # ------------------------------------------------------------------
    # SpMV (vectorized jnp path; the Pallas kernel mirrors this loop)
    # ------------------------------------------------------------------
    def spmv(self, x: jnp.ndarray, compute_dtype=jnp.float32) -> jnp.ndarray:
        return packsell_spmv_jnp(self, x, compute_dtype)

    def validate(self, *, raise_: bool = True) -> list:
        """Structural integrity check (robust.guard.validate_matrix):
        offset/outrow lengths and ranges, finite packed values, decoded
        column bounds, outrow bijectivity. Returns the list of problem
        strings (empty when clean); raises IntegrityError instead when
        ``raise_`` is set."""
        from repro.robust import guard as _guard
        return _guard.validate_matrix(self, raise_=raise_)


# Width-chunk for the scan decode: parallel within a chunk, cursor carried
# across chunks. Bounds the [S, chunk, C] intermediates so wide buckets stay
# cache-resident (the full-width scan loses its edge past a few hundred
# words); buckets narrower than the chunk decode in one shot.
_SCAN_CHUNK = int(os.environ.get("REPRO_SCAN_CHUNK", 128))


def _bucket_cols_scan(pack, d0, codec, D):
    """Scan-parallel column decode (DESIGN.md §2.4): cursors are prefix sums
    of the deltas, so all [S, w, C] columns come out of ONE associative scan
    (``cumsum`` over the width axis) instead of a sequential w-step word
    walk. Returns (value [S, w, C], col int32 [S, w, C])."""
    v, d = cd.unpack_words_jnp(pack, codec, D)
    cols = d0[:, None, None].astype(jnp.int32) + \
        jnp.cumsum(d.astype(jnp.int32), axis=1)
    return v, cols


def _bucket_spmv_scan(pack, d0, xc, codec, D, mlim, compute_dtype):
    """One bucket's stored-row outputs [S, C] via the cumsum decode: per
    width-chunk, one scan + one gather + one reduction (vs the loop decode's
    w sequential gather steps)."""
    S, w, C = pack.shape
    carry = jnp.broadcast_to(d0[:, None], (S, C)).astype(jnp.int32)
    t = jnp.zeros((S, C), dtype=compute_dtype)
    for j0 in range(0, w, _SCAN_CHUNK):
        pc = pack[:, j0:j0 + _SCAN_CHUNK, :]
        v, d = cd.unpack_words_jnp(pc, codec, D)
        cols = carry[:, None, :] + jnp.cumsum(d.astype(jnp.int32), axis=1)
        xv = jnp.take(xc, jnp.minimum(cols, mlim).reshape(-1),
                      axis=0, mode="clip").reshape(cols.shape)
        t = t + jnp.sum(v.astype(compute_dtype) * xv, axis=1)
        carry = cols[:, -1, :]
    return t


def _bucket_spmv_loop(pack, d0, xc, codec, D, mlim, compute_dtype):
    """One bucket's stored-row outputs [S, C] via the sequential word walk
    (the paper's per-word recurrence; kept as the oracle/benchmark baseline
    for the scan decode)."""
    S, w, C = pack.shape
    c0 = jnp.broadcast_to(d0[:, None], (S, C)).astype(jnp.int32)
    t0 = jnp.zeros((S, C), dtype=compute_dtype)

    def body(j, carry):
        c, t = carry
        v, d = cd.unpack_words_jnp(pack[:, j, :], codec, D)
        c = c + d.astype(jnp.int32)
        xv = jnp.take(xc, jnp.minimum(c, mlim), axis=0, mode="clip")
        t = t + v.astype(compute_dtype) * xv
        return c, t

    _, t = jax.lax.fori_loop(0, w, body, (c0, t0))
    return t


def _bucket_spmm_scan(pack, d0, xc, codec, D, mlim, compute_dtype):
    """Multi-RHS bucket outputs [S, C, nb] via the chunked cumsum decode."""
    S, w, C = pack.shape
    nb = xc.shape[1]
    carry = jnp.broadcast_to(d0[:, None], (S, C)).astype(jnp.int32)
    t = jnp.zeros((S, C, nb), dtype=compute_dtype)
    for j0 in range(0, w, _SCAN_CHUNK):
        pc = pack[:, j0:j0 + _SCAN_CHUNK, :]
        v, d = cd.unpack_words_jnp(pc, codec, D)
        cols = carry[:, None, :] + jnp.cumsum(d.astype(jnp.int32), axis=1)
        xv = jnp.take(xc, jnp.minimum(cols, mlim).reshape(-1),
                      axis=0, mode="clip").reshape(cols.shape + (nb,))
        t = t + jnp.sum(v.astype(compute_dtype)[..., None] * xv, axis=1)
        carry = cols[:, -1, :]
    return t


def _bucket_spmm_loop(pack, d0, xc, codec, D, mlim, compute_dtype):
    S, w, C = pack.shape
    nb = xc.shape[1]
    c0 = jnp.broadcast_to(d0[:, None], (S, C)).astype(jnp.int32)
    t0 = jnp.zeros((S, C, nb), dtype=compute_dtype)

    def body(j, carry):
        c, t = carry
        v, d = cd.unpack_words_jnp(pack[:, j, :], codec, D)
        c = c + d.astype(jnp.int32)
        xv = jnp.take(xc, jnp.minimum(c, mlim).reshape(-1),
                      axis=0, mode="clip").reshape(S, C, nb)
        t = t + v.astype(compute_dtype)[..., None] * xv
        return c, t

    _, t = jax.lax.fori_loop(0, w, body, (c0, t0))
    return t


def packsell_spmv_jnp(mat: PackSELLMatrix, x: jnp.ndarray,
                      compute_dtype=jnp.float32,
                      decode: str = "scan") -> jnp.ndarray:
    """y = A @ x over the bucketed PackSELL layout (paper §4.4 algorithm).

    The per-word recurrence is exactly the paper's: unpack → advance column
    cursor by delta → fused multiply-accumulate. Padding and dummy words
    contribute v = 0 so no masking is required.

    ``decode='scan'`` (default) decodes all column cursors in one
    associative prefix-sum over the width axis; ``decode='loop'`` keeps the
    sequential ``fori_loop`` word walk (benchmark baseline).
    """
    body = {"scan": _bucket_spmv_scan, "loop": _bucket_spmv_loop}[decode]
    codec = mat.codec
    mlim = np.int32(max(mat.m - 1, 0))
    y = jnp.zeros((mat.n,), dtype=compute_dtype)
    xc = x.astype(compute_dtype)
    for pack, d0, outrow in zip(mat.packs, mat.d0s, mat.outrows):
        t = body(pack, d0, xc, codec, mat.D, mlim, compute_dtype)
        y = y.at[outrow].set(t.reshape(-1), mode="drop")
    return y


def packsell_spmm_jnp(mat: PackSELLMatrix, x: jnp.ndarray,
                      compute_dtype=jnp.float32,
                      decode: str = "scan") -> jnp.ndarray:
    """Y = A @ X for X: [m, nb] (multi-RHS SpMV; block-Krylov / batched
    pruned-weight serving). One pass over the packed words serves all nb
    right-hand sides — nb× arithmetic intensity vs nb separate SpMVs,
    which is exactly how the memory-bound regime wants it."""
    body = {"scan": _bucket_spmm_scan, "loop": _bucket_spmm_loop}[decode]
    codec = mat.codec
    nb = x.shape[1]
    mlim = np.int32(max(mat.m - 1, 0))
    y = jnp.zeros((mat.n, nb), dtype=compute_dtype)
    xc = x.astype(compute_dtype)
    for pack, d0, outrow in zip(mat.packs, mat.d0s, mat.outrows):
        S, w, C = pack.shape
        t = body(pack, d0, xc, codec, mat.D, mlim, compute_dtype)
        y = y.at[outrow].set(t.reshape(S * C, nb), mode="drop")
    return y


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------


def _sigma_sort(stored_len: np.ndarray, n: int, sigma: int, C: int):
    """σ-block stable descending sort. Returns (outrow, perm_local).

    outrow[stored_idx] = original row (len n_padded, sentinel n for padding
    rows); perm_local[stored_idx] = original index within the σ-block.
    """
    n_padded = _ceil_to(max(n, 1), C)
    outrow = np.full(n_padded, n, dtype=np.int64)
    for b0 in range(0, n, sigma):
        b1 = min(b0 + sigma, n)
        order = np.argsort(-stored_len[b0:b1], kind="stable")
        outrow[b0:b1] = b0 + order
    perm_dtype = np.uint8 if sigma <= 256 else np.uint16
    perm_local = (outrow[:n] - (np.arange(n) // sigma) * sigma).astype(perm_dtype)
    pad_perm = np.zeros(n_padded - n, dtype=perm_dtype)
    return outrow, np.concatenate([perm_local, pad_perm])


def _bucket_slices(widths: np.ndarray, strategy: str):
    """Group slice ids into width buckets.

    'pow2'    : bucket width = next power of two (small, bounded padding)
    'uniform' : a single bucket at max width (simplest kernels)
    'exact'   : one bucket per distinct width (zero bucket padding)
    """
    S = len(widths)
    if S == 0:
        return []
    if strategy == "uniform":
        wmax = int(widths.max())
        return [(np.arange(S), max(wmax, 1))]
    if strategy == "pow2":
        keys = np.where(widths <= 1, 1,
                        2 ** np.ceil(np.log2(np.maximum(widths, 1))).astype(np.int64))
    elif strategy == "exact":
        keys = np.maximum(widths, 1)
    else:
        raise ValueError(strategy)
    out = []
    for k in np.unique(keys):
        ids = np.nonzero(keys == k)[0]
        out.append((ids, int(k)))
    return out


def from_csr(a: sp.csr_matrix, *, C: int = 128, sigma: int = 256, D: int = 15,
             codec: str = "fp16", bucket_strategy: str = "pow2",
             device: bool = True) -> PackSELLMatrix:
    """Build a PackSELL matrix from a scipy CSR matrix."""
    if sigma % C != 0:
        raise ValueError(f"sigma ({sigma}) must be a multiple of C ({C})")
    a = a.tocsr()
    a.sort_indices()
    n, m = a.shape
    indptr = a.indptr.astype(np.int64)
    indices = a.indices.astype(np.int64)
    if a.nnz and not np.all(np.isfinite(a.data)):
        bad = int(np.count_nonzero(~np.isfinite(a.data)))
        raise ValueError(
            f"from_csr: input has {bad} non-finite (NaN/Inf) values; "
            "packed codecs cannot represent them")
    if a.nnz and (indices.min() < 0 or indices.max() >= m):
        raise ValueError(
            f"from_csr: column indices outside [0, {m}) "
            f"(min {int(indices.min())}, max {int(indices.max())})")
    values = a.data.astype(np.float32)
    codec_obj = cd.make_codec(codec)
    if not (codec_obj.min_D <= D <= codec_obj.max_D):
        raise ValueError(f"D={D} outside [{codec_obj.min_D},{codec_obj.max_D}] "
                         f"for codec {codec}")

    k_left = de.lower_bandwidth(indptr, indices, n)
    d0_row = de.d0_for_rows(n, sigma, k_left)
    deltas, n_dummies, stored_len = de.encode_rows(indptr, indices, d0_row, D)
    w_values, w_deltas, w_flags, _, n_words = de.emit_word_stream(
        values, deltas, n_dummies)
    words = cd.pack_words_np(w_values, w_deltas, w_flags, codec_obj, D)
    row_word_start = _cumsum0(stored_len)

    outrow, perm = _sigma_sort(stored_len, n, sigma, C)
    n_padded = len(outrow)
    S = n_padded // C

    stored_len_padded = np.zeros(n_padded, dtype=np.int64)
    valid = outrow < n
    stored_len_padded[valid] = stored_len[outrow[valid]]
    slice_width = stored_len_padded.reshape(S, C).max(axis=1)
    words_sell_padded = int((slice_width * C).sum())

    d0_slice = np.maximum((np.arange(S) * C // sigma) * sigma - k_left, 0)

    # per-row last column (band-kernel window metadata); empty rows -> d0
    lastcol_row = d0_row.copy()
    nz_rows = np.diff(indptr) > 0
    lastcol_row[nz_rows] = indices[indptr[1:][nz_rows] - 1]
    lastcol_padded = np.zeros(n_padded, dtype=np.int64)
    lastcol_padded[valid] = lastcol_row[outrow[valid]]
    maxcol_slice = lastcol_padded.reshape(S, C).max(axis=1)

    buckets = _bucket_slices(slice_width, bucket_strategy)
    packs, d0s, outrows, maxcols_l = [], [], [], []
    words_bucketed = 0
    # guard row for the gather below (padding rows index word 0 harmlessly)
    words_g = words if n_words > 0 else np.zeros(1, dtype=np.uint32)
    for slice_ids, w_b in buckets:
        rows = (slice_ids[:, None] * C + np.arange(C)[None, :]).reshape(-1)
        orig = outrow[rows]                         # [S_b*C]
        lens = stored_len_padded[rows]              # [S_b*C]
        starts = np.where(orig < n, row_word_start[np.minimum(orig, n - 1)], 0)
        j = np.arange(w_b, dtype=np.int64)
        idx = starts[:, None] + j[None, :]          # [S_b*C, w_b]
        ok = j[None, :] < lens[:, None]
        gathered = np.where(ok, words_g[np.minimum(idx, len(words_g) - 1)],
                            PAD_WORD)
        pack3d = gathered.reshape(len(slice_ids), C, w_b).transpose(0, 2, 1)
        packs.append(np.ascontiguousarray(pack3d.astype(np.uint32)))
        d0s.append(d0_slice[slice_ids].astype(np.int32))
        outrows.append(np.where(orig < n, orig, n).astype(np.int32))
        maxcols_l.append(maxcol_slice[slice_ids].astype(np.int32))
        words_bucketed += pack3d.size

    to_dev = jnp.asarray if device else (lambda v: v)
    return PackSELLMatrix(
        packs=tuple(to_dev(p) for p in packs),
        d0s=tuple(to_dev(d) for d in d0s),
        outrows=tuple(to_dev(o) for o in outrows),
        maxcols=tuple(to_dev(mc) for mc in maxcols_l),
        perm=to_dev(perm),
        n=n, m=m, C=C, sigma=sigma, D=D, codec_name=codec, k_left=k_left,
        nnz=int(a.nnz), n_dummy=int(n_dummies.sum()),
        words_sell_padded=words_sell_padded, words_bucketed=int(words_bucketed),
    )


def from_dense(a: np.ndarray, **kw) -> PackSELLMatrix:
    a = np.asarray(a)
    if a.ndim != 2:
        raise ValueError(f"from_dense: expected a 2-D array, got shape "
                         f"{a.shape}")
    if not np.all(np.isfinite(a)):
        bad = int(np.count_nonzero(~np.isfinite(a)))
        raise ValueError(
            f"from_dense: input has {bad} non-finite (NaN/Inf) values; "
            "packed codecs cannot represent them")
    return from_csr(sp.csr_matrix(a), **kw)


# ---------------------------------------------------------------------------
# Per-partition build hooks (distributed layer, DESIGN.md §7)
# ---------------------------------------------------------------------------


def pad_uniform(mat: PackSELLMatrix, *, n_slices: int | None = None,
                width: int | None = None, n_rows: int | None = None,
                device: bool = True) -> PackSELLMatrix:
    """Pad a single-bucket ('uniform') matrix to a common [S, w, C] shape.

    The distributed partitioner σ-sorts and builds each shard's block
    independently (per-partition sorting keeps padding low, SELL-C-σ §3),
    which leaves every shard with different slice counts and widths. SPMD
    dispatch under ``shard_map`` needs one static shape for all shards, so
    each block is padded here to the fleet-wide maxima: extra words are
    ``PAD_WORD`` (flag=0, delta=0 → contribute nothing), extra slices get
    sentinel outrows (dropped / masked), and ``n`` grows to ``n_rows`` with
    the old sentinel value remapped so padding rows stay dead.
    """
    if len(mat.packs) != 1:
        raise ValueError("pad_uniform needs a single-bucket matrix "
                         "(build with bucket_strategy='uniform')")
    pack = np.asarray(mat.packs[0])
    d0 = np.asarray(mat.d0s[0])
    outrow = np.asarray(mat.outrows[0])
    maxcol = np.asarray(mat.maxcols[0])
    perm = np.asarray(mat.perm)
    S, w, C = pack.shape
    S_t = S if n_slices is None else int(n_slices)
    w_t = w if width is None else int(width)
    n_t = mat.n if n_rows is None else int(n_rows)
    if S_t < S or w_t < w or n_t < mat.n:
        raise ValueError(f"cannot shrink: have (S={S}, w={w}, n={mat.n}), "
                         f"asked (S={S_t}, w={w_t}, n={n_t})")
    if S_t * C < n_t:
        raise ValueError(f"S={S_t} slices of C={C} cannot hold n={n_t} rows")

    pack_p = np.full((S_t, w_t, C), PAD_WORD, dtype=np.uint32)
    pack_p[:S, :w, :] = pack
    d0_p = np.zeros(S_t, np.int32)
    d0_p[:S] = d0
    maxcol_p = np.zeros(S_t, np.int32)
    maxcol_p[:S] = maxcol
    # remap the old padding sentinel (== mat.n) to the new one (== n_t)
    outrow_p = np.full(S_t * C, n_t, np.int32)
    outrow_p[:S * C] = np.where(outrow >= mat.n, n_t, outrow)
    # give every padding row a stored slot of its own, carved out of the
    # sentinel (all-PAD-word) slots: those columns decode to exactly 0, so
    # padding rows stay dead through BOTH epilogue forms — the scatter
    # (sentinel drop) and the plan engine's inverse-permutation *gather*,
    # which requires one slot per row (kernels/plan.py::_build_inverse_perm)
    sentinel = np.nonzero(outrow_p >= n_t)[0]
    extra = n_t - mat.n
    outrow_p[sentinel[:extra]] = mat.n + np.arange(extra, dtype=np.int32)
    perm_p = np.zeros(S_t * C, perm.dtype)
    perm_p[:len(perm)] = perm

    to_dev = jnp.asarray if device else (lambda v: v)
    return PackSELLMatrix(
        packs=(to_dev(pack_p),), d0s=(to_dev(d0_p),),
        outrows=(to_dev(outrow_p),), maxcols=(to_dev(maxcol_p),),
        perm=to_dev(perm_p),
        n=n_t, m=mat.m, C=C, sigma=mat.sigma, D=mat.D,
        codec_name=mat.codec_name, k_left=mat.k_left, nnz=mat.nnz,
        n_dummy=mat.n_dummy, words_sell_padded=mat.words_sell_padded,
        words_bucketed=int(pack_p.size),
    )


def aggregate_memory_stats(mats: Sequence[PackSELLMatrix]) -> dict:
    """Fleet-level :meth:`PackSELLMatrix.memory_stats`: per-shard sums plus
    the max/min shard footprint (load-balance signal for the partitioner)."""
    stats = [m.memory_stats() for m in mats]
    agg = {k: sum(s[k] for s in stats) for k in stats[0]} if stats else {}
    per_shard = [s["packsell_bytes"] for s in stats]
    agg["shards"] = len(stats)
    agg["max_shard_bytes"] = max(per_shard) if per_shard else 0
    agg["min_shard_bytes"] = min(per_shard) if per_shard else 0
    return agg


# ---------------------------------------------------------------------------
# Host-side decode (oracle for tests)
# ---------------------------------------------------------------------------


def decode_to_dense(mat: PackSELLMatrix) -> np.ndarray:
    """Reconstruct the (quantized) dense matrix by walking the packed words."""
    codec = mat.codec
    out = np.zeros((mat.n, mat.m), dtype=np.float64)
    for pack, d0, outrow in zip(mat.packs, mat.d0s, mat.outrows):
        pack = np.asarray(pack)
        d0 = np.asarray(d0)
        outrow = np.asarray(outrow)
        S, w, C = pack.shape
        v, d, flag = cd.unpack_words_np(pack.reshape(-1), codec, mat.D)
        v = v.astype(np.float64).reshape(S, w, C)
        d = d.astype(np.int64).reshape(S, w, C)
        flag = flag.reshape(S, w, C)
        cols = d0[:, None, None] + np.cumsum(d, axis=1)
        rows = outrow.reshape(S, C)
        for s in range(S):
            for l in range(C):
                r = rows[s, l]
                if r >= mat.n:
                    continue
                sel = flag[s, :, l] == 1
                out[r, cols[s, sel, l]] += v[s, sel, l]
    return out
