"""Delta encoding of column indices (paper §4.1, eqs. 2–4) + dummy insertion.

All of this is host-side *format construction* (the paper builds formats on
the CPU too); it is vectorized numpy over the CSR stream, no Python per-row
loops on the hot path.
"""
from __future__ import annotations

import numpy as np

# Largest delta a single flag=0 dummy word can carry (31-bit field).
MAX_DUMMY_DELTA = (1 << 31) - 1


def lower_bandwidth(indptr: np.ndarray, indices: np.ndarray, n: int) -> int:
    """k_left = max_i (i - j_min(i)) clipped at 0 (paper eq. 3 context)."""
    row_nnz = np.diff(indptr)
    rows = np.arange(n)[row_nnz > 0]
    if rows.size == 0:
        return 0
    jmin = indices[indptr[:-1][row_nnz > 0]]
    return int(max(0, np.max(rows - jmin)))


def d0_for_rows(n: int, sigma: int, k_left: int) -> np.ndarray:
    """Base column offset 𝔡_i, uniform within each σ-block (paper eq. 4)."""
    block_start = (np.arange(n) // sigma) * sigma
    return np.maximum(block_start - k_left, 0).astype(np.int64)


def dummies_for_deltas(deltas: np.ndarray, D: int) -> np.ndarray:
    """Dummy words required ahead of each element (int64[nnz]).

    A delta that fits the ``D``-bit flag=1 field needs none. A larger delta
    is carried by a *chain* of flag=0 dummy words, each holding at most
    :data:`MAX_DUMMY_DELTA` (31 bits) — one dummy for any matrix with
    m < 2^31, more only for column gaps beyond that.
    """
    deltas = np.asarray(deltas, dtype=np.int64)
    big = deltas >= (1 << D)
    out = np.zeros(len(deltas), dtype=np.int64)
    out[big] = -(-deltas[big] // MAX_DUMMY_DELTA)  # ceil-div, >= 1
    return out


def encode_rows(indptr: np.ndarray, indices: np.ndarray, d0: np.ndarray,
                D: int):
    """Compute per-element deltas and dummy-element placement.

    Returns
    -------
    deltas : int64[nnz]    delta of each real element (vs predecessor / 𝔡_i)
    n_dummies : int64[nnz] dummy words chained before this element (0 when
                           the delta fits ``D`` bits; truthiness/sum match
                           the old boolean ``needs_dummy`` return)
    stored_len : int64[n]  stored words per row = nnz + dummies
    """
    n = len(indptr) - 1
    nnz = len(indices)
    row_nnz = np.diff(indptr)

    prev = np.empty(nnz, dtype=np.int64)
    prev[1:] = indices[:-1]
    starts = indptr[:-1][row_nnz > 0]
    prev[starts] = d0[np.arange(n)[row_nnz > 0]]

    deltas = indices.astype(np.int64) - prev
    if np.any(deltas < 0):
        bad = np.nonzero(deltas < 0)[0][0]
        raise ValueError(
            f"negative delta at element {bad}: columns must be sorted "
            f"ascending per row and d0 must not exceed the first column")

    n_dummies = dummies_for_deltas(deltas, D)
    row_of_elem = np.repeat(np.arange(n), row_nnz)
    dummy_per_row = np.bincount(row_of_elem, weights=n_dummies,
                                minlength=n).astype(np.int64)
    stored_len = row_nnz.astype(np.int64) + dummy_per_row
    return deltas, n_dummies, stored_len


def emit_word_stream(values: np.ndarray, deltas: np.ndarray,
                     n_dummies: np.ndarray):
    """Expand (value, delta) elements into the stored word stream.

    Elements with a large delta become 1 + n_dummies[k] entries: a chain of
    dummies carrying the delta (flag=0, each at most 31 bits) followed by
    the real element with delta 0 (flag=1) (paper §4.3). ``n_dummies``
    accepts the old boolean ``needs_dummy`` array too (cast to counts).

    Returns (w_values f32, w_deltas int64, w_flags uint8, elem_out_pos int64,
    n_words) where elem_out_pos[k] is the stream position of real element k.
    """
    nnz = len(deltas)
    extra = n_dummies.astype(np.int64)
    # position of each real element in the expanded stream
    elem_pos = np.arange(nnz, dtype=np.int64) + np.cumsum(extra)
    n_words = int(nnz + extra.sum())

    w_values = np.zeros(n_words, dtype=np.float32)
    w_deltas = np.zeros(n_words, dtype=np.int64)
    w_flags = np.zeros(n_words, dtype=np.uint8)

    # real elements
    w_values[elem_pos] = values
    w_flags[elem_pos] = 1
    w_deltas[elem_pos] = np.where(extra > 0, 0, deltas)

    # dummy chains sit immediately before their element: the first e-1 links
    # carry MAX_DUMMY_DELTA each, the last carries the remainder
    big = extra > 0
    if np.any(big):
        e = extra[big]                          # chain length per big elem
        total = int(e.sum())
        # link index 0..e-1 within each chain
        link = np.arange(total, dtype=np.int64) - \
            np.repeat(np.cumsum(e) - e, e)
        pos = np.repeat(elem_pos[big] - e, e) + link
        d_big = np.repeat(deltas[big], e)
        e_rep = np.repeat(e, e)
        w_deltas[pos] = np.where(
            link < e_rep - 1, MAX_DUMMY_DELTA,
            d_big - MAX_DUMMY_DELTA * (e_rep - 1))
    # (w_flags, w_values already 0 at dummy positions)
    return w_values, w_deltas, w_flags, elem_pos, n_words
