"""Sparse triangular solve on PackSELL (paper §6 future work #3: "applying
PackSELL to other sparse matrix kernels, such as sparse triangular solves,
is promising because some of their implementations are similar to SpMV").

GPU/TPU adaptation: a serial forward-substitution is hostile to SIMT/SIMD;
the vector-friendly formulation is the **level-bounded Jacobi iteration**

    x_{k+1} = D^{-1} (b - L_strict x_k)

where ``N = D^{-1} L_strict`` is *nilpotent* with index = the number of
dependency levels of L, so the iteration is EXACT (not approximate) after
``n_levels`` steps — each step one PackSELL SpMV + elementwise ops on the
VPU. This is the standard iterative-SpTRSV construction for throughput
hardware, here running entirely on the paper's packed format so the
triangular factor enjoys the same footprint reduction as A itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from . import packsell as pk


def split_triangular(t: sp.csr_matrix, lower: bool = True):
    """(strict part CSR, diag) of a triangular matrix; validates shape."""
    t = t.tocsr()
    d = t.diagonal()
    if np.any(d == 0):
        raise ValueError("triangular solve needs a nonzero diagonal")
    strict = sp.tril(t, -1) if lower else sp.triu(t, 1)
    other = sp.triu(t, 1) if lower else sp.tril(t, -1)
    if other.nnz:
        raise ValueError("matrix is not triangular")
    strict = strict.tocsr()
    strict.sort_indices()
    return strict, d


def n_levels(strict: sp.csr_matrix, lower: bool = True) -> int:
    """Length of the longest dependency chain (host-side, O(nnz))."""
    strict = strict.tocsr()
    n = strict.shape[0]
    lev = np.zeros(n, dtype=np.int64)
    indptr, indices = strict.indptr, strict.indices
    rows = range(n) if lower else range(n - 1, -1, -1)
    for i in rows:
        deps = indices[indptr[i]:indptr[i + 1]]
        if len(deps):
            lev[i] = 1 + lev[deps].max()
    return int(lev.max()) + 1


class PackSELLTriSolver:
    """Triangular solver over a PackSELL-stored strict factor."""

    def __init__(self, t: sp.csr_matrix, *, lower: bool = True,
                 C: int = 32, sigma: int = 256, D: int = 1,
                 codec: str = "e8m"):
        strict, diag = split_triangular(t, lower)
        self.levels = n_levels(strict, lower)
        self.mat = pk.from_csr(strict, C=C, sigma=sigma, D=D, codec=codec)
        self.dinv = jnp.asarray(1.0 / diag, jnp.float32)
        self.lower = lower

    def memory_stats(self) -> dict:
        return self.mat.memory_stats()

    def solve(self, b: jnp.ndarray, iters: int | None = None) -> jnp.ndarray:
        """Exact after ``self.levels`` iterations (nilpotent Jacobi)."""
        iters = self.levels if iters is None else iters
        b = b.astype(jnp.float32)
        x0 = self.dinv * b

        def body(_, x):
            return self.dinv * (b - pk.packsell_spmv_jnp(self.mat, x))

        return jax.lax.fori_loop(0, iters, body, x0)


def trisolve(t: sp.csr_matrix, b, *, lower: bool = True, **kw):
    """One-shot helper: build + solve (tests/benchmarks)."""
    solver = PackSELLTriSolver(t, lower=lower, **kw)
    return solver.solve(jnp.asarray(b)), solver
