"""CSR / COO baseline SpMV in JAX (the paper's cuCSR / cuCOO counterparts)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSRMatrix:
    data: jnp.ndarray     # value_dtype[nnz]
    indices: jnp.ndarray  # int32[nnz]
    row_ids: jnp.ndarray  # int32[nnz]  (expanded indptr: segment ids)
    n: int
    m: int

    def tree_flatten(self):
        return ((self.data, self.indices, self.row_ids), (self.n, self.m))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @property
    def shape(self):
        return (self.n, self.m)

    def spmv(self, x: jnp.ndarray, compute_dtype=jnp.float32) -> jnp.ndarray:
        prod = self.data.astype(compute_dtype) * \
            jnp.take(x.astype(compute_dtype), self.indices, axis=0)
        return jax.ops.segment_sum(prod, self.row_ids, num_segments=self.n)

    def memory_stats(self) -> dict:
        vb = self.data.dtype.itemsize
        return dict(csr_bytes=vb * self.data.size + 4 * self.data.size
                    + 4 * (self.n + 1))


def csr_from_scipy(a: sp.csr_matrix, value_dtype="float32") -> CSRMatrix:
    a = a.tocsr()
    a.sort_indices()
    row_ids = np.repeat(np.arange(a.shape[0]), np.diff(a.indptr))
    return CSRMatrix(
        data=jnp.asarray(a.data.astype(value_dtype)),
        indices=jnp.asarray(a.indices.astype(np.int32)),
        row_ids=jnp.asarray(row_ids.astype(np.int32)),
        n=a.shape[0], m=a.shape[1])


# COO shares the CSR segment-sum implementation (row ids are explicit in both
# after expansion); kept as an alias with its own memory model.
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class COOMatrix(CSRMatrix):
    def memory_stats(self) -> dict:
        vb = self.data.dtype.itemsize
        return dict(coo_bytes=(vb + 8) * self.data.size)


def coo_from_scipy(a: sp.csr_matrix, value_dtype="float32") -> COOMatrix:
    c = csr_from_scipy(a, value_dtype)
    return COOMatrix(c.data, c.indices, c.row_ids, c.n, c.m)
