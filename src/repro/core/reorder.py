"""Matrix reordering for delta locality (paper §5.1.1 future work:
"matrix reordering to improve the locality of nonzero elements is promising
for further improvements of PackSELL").

Reverse Cuthill–McKee clusters each row's nonzeros around the diagonal, so
column deltas shrink and D-bit fields cover them without dummy elements —
exactly the regime where PackSELL hits its 0.67 lower-bound footprint.
``benchmarks/bench_memory.py`` quantifies the effect (dummy fraction and
footprint ratio before/after) on the scattered/powerlaw classes.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import reverse_cuthill_mckee


def rcm_permutation(a: sp.csr_matrix, symmetric_pattern: bool = False) \
        -> np.ndarray:
    """RCM ordering of the symmetrized pattern of a square matrix."""
    n, m = a.shape
    if n != m:
        raise ValueError("RCM needs a square matrix")
    return np.asarray(reverse_cuthill_mckee(
        a.tocsr(), symmetric_mode=symmetric_pattern), dtype=np.int64)


def apply_symmetric(a: sp.csr_matrix, perm: np.ndarray) -> sp.csr_matrix:
    """P A Pᵀ for a permutation vector ``perm`` (new index i = old
    perm[i]); preserves SPD-ness and spectra."""
    pr = sp.csr_matrix(
        (np.ones(len(perm)), (np.arange(len(perm)), perm)),
        shape=a.shape)
    out = (pr @ a @ pr.T).tocsr()
    out.sort_indices()
    return out


def rcm_reorder(a: sp.csr_matrix) -> tuple[sp.csr_matrix, np.ndarray]:
    """(reordered matrix, permutation). For solvers: solve P A Pᵀ y = P b,
    then x = Pᵀ y."""
    perm = rcm_permutation(a)
    return apply_symmetric(a, perm), perm


def bandwidth(a: sp.csr_matrix) -> int:
    """max |i - j| over stored entries (locality metric)."""
    coo = a.tocoo()
    if coo.nnz == 0:
        return 0
    return int(np.max(np.abs(coo.row.astype(np.int64) - coo.col)))
