"""Synthetic sparse matrices reproducing the *classes* of the paper's suite.

SuiteSparse is not available offline; each generator below targets one of the
structural regimes in Table 1 / §5 of the paper:

* ``stencil_1d/2d/3d``   — banded FEM-style stencils (parabolic_fem, CurlCurl,
  HPCG-like). Low RSD, high locality → PackSELL's best case. ``stencil_3d``
  with 27 neighbours *is* the HPCG operator (HPCG_x_y_z rows = 2^(x+y+z)).
* ``random_banded``      — random pattern within a bandwidth (Flan/audikw-like
  clustered rows).
* ``scattered``          — uniformly random columns (GL7d17/cont11-like):
  large deltas → many dummies, PackSELL's worst case.
* ``powerlaw``           — Zipf row degrees (language/degme-like): high RSD,
  SELL's worst case.

All generators return scipy CSR with reproducible values; SPD variants are
produced by diagonal dominance (for CG / PCG tests).
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp


def _finish(rows, cols, vals, n, m, rng, spd):
    a = sp.csr_matrix((vals, (rows, cols)), shape=(n, m))
    a.sum_duplicates()
    a.sort_indices()
    if spd:
        a = a + a.T  # symmetrize
        rowsum = np.abs(a).sum(axis=1).A1 if hasattr(np.abs(a).sum(axis=1), "A1") \
            else np.asarray(np.abs(a).sum(axis=1)).ravel()
        a = a + sp.diags(rowsum + 1.0)
        a = a.tocsr()
        a.sort_indices()
    return a


def stencil_1d(n: int, half_bw: int = 1, spd: bool = True,
               seed: int = 0) -> sp.csr_matrix:
    rng = np.random.default_rng(seed)
    diags, offs = [], []
    for k in range(-half_bw, half_bw + 1):
        if k == 0:
            continue
        diags.append(-np.abs(rng.standard_normal(n - abs(k))) - 0.1)
        offs.append(k)
    a = sp.diags(diags, offs, shape=(n, n)).tocsr()
    if spd:
        a = 0.5 * (a + a.T)
        rowsum = np.asarray(np.abs(a).sum(axis=1)).ravel()
        a = a + sp.diags(rowsum + 1.0)
    a = a.tocsr()
    a.sort_indices()
    return a


def stencil_3d(nx: int, ny: int, nz: int, neighbours: int = 27,
               spd: bool = True, seed: int = 0) -> sp.csr_matrix:
    """HPCG-style 27-point (or 7-point) stencil on an nx×ny×nz grid."""
    assert neighbours in (7, 27)
    n = nx * ny * nz
    idx = np.arange(n)
    iz, iy, ix = idx // (nx * ny), (idx // nx) % ny, idx % nx
    rows, cols = [], []
    if neighbours == 7:
        offsets = [(0, 0, 0), (1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0),
                   (0, 0, 1), (0, 0, -1)]
    else:
        offsets = [(dx, dy, dz) for dz in (-1, 0, 1) for dy in (-1, 0, 1)
                   for dx in (-1, 0, 1)]
    for dx, dy, dz in offsets:
        jx, jy, jz = ix + dx, iy + dy, iz + dz
        ok = (jx >= 0) & (jx < nx) & (jy >= 0) & (jy < ny) & (jz >= 0) & (jz < nz)
        rows.append(idx[ok])
        cols.append((jz * ny + jy)[ok] * nx + jx[ok])
    rows = np.concatenate(rows)
    cols = np.concatenate(cols)
    vals = np.where(rows == cols, 26.0 if neighbours == 27 else 6.0, -1.0)
    a = sp.csr_matrix((vals, (rows, cols)), shape=(n, n))
    a.sort_indices()
    if not spd:
        # HPGMxP-style asymmetry: scale the upper triangle
        a = sp.triu(a, 1) * 0.5 + sp.tril(a)
        a = a.tocsr()
        a.sort_indices()
    return a


def random_banded(n: int, half_bw: int, nnz_per_row: int, spd: bool = True,
                  seed: int = 0) -> sp.csr_matrix:
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n), nnz_per_row)
    off = rng.integers(-half_bw, half_bw + 1, size=rows.size)
    cols = np.clip(rows + off, 0, n - 1)
    vals = rng.standard_normal(rows.size) * 0.1
    return _finish(rows, cols, vals, n, n, rng, spd)


def scattered(n: int, m: int | None = None, nnz_per_row: int = 8,
              spd: bool = False, seed: int = 0) -> sp.csr_matrix:
    m = m or n
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(n), nnz_per_row)
    cols = rng.integers(0, m, size=rows.size)
    vals = rng.standard_normal(rows.size) * 0.1
    return _finish(rows, cols, vals, n, m, rng, spd and n == m)


def powerlaw(n: int, mean_deg: int = 8, alpha: float = 2.0,
             spd: bool = False, seed: int = 0) -> sp.csr_matrix:
    rng = np.random.default_rng(seed)
    deg = np.minimum((rng.pareto(alpha, n) + 1) * mean_deg, n // 2).astype(int)
    rows = np.repeat(np.arange(n), deg)
    cols = rng.integers(0, n, size=rows.size)
    vals = rng.standard_normal(rows.size) * 0.1
    return _finish(rows, cols, vals, n, n, rng, spd)


def hpcg(nx: int, ny: int, nz: int, seed: int = 0) -> sp.csr_matrix:
    return stencil_3d(nx, ny, nz, neighbours=27, spd=True, seed=seed)


def hpgmp(nx: int, ny: int, nz: int, seed: int = 0) -> sp.csr_matrix:
    return stencil_3d(nx, ny, nz, neighbours=27, spd=False, seed=seed)


def suite(scale: str = "small") -> dict:
    """The benchmark suite: one generator per structural class of Table 1."""
    if scale == "tiny":       # unit tests
        return {
            "stencil1d": stencil_1d(400, 2),
            "hpcg_mini": hpcg(8, 8, 8),
            "banded": random_banded(512, 24, 6),
            "scattered": scattered(512, nnz_per_row=5),
            "powerlaw": powerlaw(512, mean_deg=5),
        }
    if scale == "small":      # benchmarks on 1 CPU
        return {
            "parabolic_like": stencil_1d(60_000, 3),
            "hpcg_16": hpcg(16, 16, 16),
            "curlcurl_like": random_banded(50_000, 60, 11),
            "flan_like": random_banded(40_000, 400, 40),
            "scattered_like": scattered(30_000, nnz_per_row=17),
            "language_like": powerlaw(30_000, mean_deg=3),
        }
    if scale == "medium":     # heavier benchmark pass
        return {
            "parabolic_like": stencil_1d(250_000, 3),
            "hpcg_32": hpcg(32, 32, 32),
            "curlcurl_like": random_banded(200_000, 60, 11),
            "flan_like": random_banded(100_000, 400, 40),
            "scattered_like": scattered(80_000, nnz_per_row=17),
            "language_like": powerlaw(80_000, mean_deg=3),
        }
    raise ValueError(scale)
