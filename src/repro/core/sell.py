"""SELL-C-σ baseline format (paper §3) — the comparison target for PackSELL.

Mirrors the PackSELL bucket layout (DESIGN.md §2) so that kernel comparisons
isolate the *format* difference (packed single array vs separate val/col
arrays), exactly the contrast the paper draws against cuSPARSE SELL.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from .packsell import _bucket_slices, _ceil_to, _cumsum0, _sigma_sort


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SELLMatrix:
    vals: tuple       # tuple of dtype[S_b, w_b, C]
    cols: tuple       # tuple of int32[S_b, w_b, C]   (padding -> col 0, val 0)
    outrows: tuple    # tuple of int32[S_b * C]
    perm: jnp.ndarray

    n: int
    m: int
    C: int
    sigma: int
    value_dtype: str
    nnz: int
    words_sell_padded: int
    words_bucketed: int

    _STATIC = ("n", "m", "C", "sigma", "value_dtype", "nnz",
               "words_sell_padded", "words_bucketed")

    @property
    def shape(self):
        return (self.n, self.m)

    def tree_flatten(self):
        return ((self.vals, self.cols, self.outrows, self.perm),
                tuple(getattr(self, f) for f in self._STATIC))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    def memory_stats(self) -> dict:
        vb = jnp.dtype(self.value_dtype).itemsize
        n_slices = sum(int(v.shape[0]) for v in self.vals)
        perm_bytes = self.perm.size * self.perm.dtype.itemsize
        sell = (vb + 4) * self.words_sell_padded + 4 * (n_slices + 1) + perm_bytes
        return dict(sell_bytes=sell, value_bytes=vb,
                    words_sell_padded=self.words_sell_padded,
                    words_bucketed=self.words_bucketed)

    def spmv(self, x: jnp.ndarray, compute_dtype=jnp.float32) -> jnp.ndarray:
        return sell_spmv_jnp(self, x, compute_dtype)


def sell_spmv_jnp(mat: SELLMatrix, x: jnp.ndarray,
                  compute_dtype=jnp.float32) -> jnp.ndarray:
    """y = A @ x over SELL (paper §3 algorithm, vectorized over slices)."""
    y = jnp.zeros((mat.n,), dtype=compute_dtype)
    xc = x.astype(compute_dtype)
    for val, col, outrow in zip(mat.vals, mat.cols, mat.outrows):
        S, w, C = val.shape
        t0 = jnp.zeros((S, C), dtype=compute_dtype)

        def body(j, t, val=val, col=col):
            v = val[:, j, :].astype(compute_dtype)
            xv = jnp.take(xc, col[:, j, :], axis=0)
            return t + v * xv

        t = jax.lax.fori_loop(0, w, body, t0)
        y = y.at[outrow].set(t.reshape(-1), mode="drop")
    return y


def from_csr(a: sp.csr_matrix, *, C: int = 128, sigma: int = 256,
             value_dtype: str = "float32", bucket_strategy: str = "pow2",
             device: bool = True) -> SELLMatrix:
    if sigma % C != 0:
        raise ValueError("sigma must be a multiple of C")
    a = a.tocsr()
    a.sort_indices()
    n, m = a.shape
    indptr = a.indptr.astype(np.int64)
    indices = a.indices.astype(np.int64)
    # keep full precision here; cast happens once into value_dtype below
    values = a.data.astype(np.float64)
    row_nnz = np.diff(indptr).astype(np.int64)
    row_word_start = _cumsum0(row_nnz)

    outrow, perm = _sigma_sort(row_nnz, n, sigma, C)
    n_padded = len(outrow)
    S = n_padded // C
    lens_padded = np.zeros(n_padded, dtype=np.int64)
    valid = outrow < n
    lens_padded[valid] = row_nnz[outrow[valid]]
    slice_width = lens_padded.reshape(S, C).max(axis=1)
    words_sell_padded = int((slice_width * C).sum())

    buckets = _bucket_slices(slice_width, bucket_strategy)
    vals, cols, outrows = [], [], []
    words_bucketed = 0
    vals_g = values if a.nnz else np.zeros(1, np.float64)
    inds_g = indices if a.nnz else np.zeros(1, np.int64)
    for slice_ids, w_b in buckets:
        rows = (slice_ids[:, None] * C + np.arange(C)[None, :]).reshape(-1)
        orig = outrow[rows]
        lens = lens_padded[rows]
        starts = np.where(orig < n, row_word_start[np.minimum(orig, n - 1)], 0)
        j = np.arange(w_b, dtype=np.int64)
        idx = np.minimum(starts[:, None] + j[None, :], len(vals_g) - 1)
        ok = j[None, :] < lens[:, None]
        v = np.where(ok, vals_g[idx], 0.0).astype(value_dtype)
        c = np.where(ok, inds_g[idx], 0).astype(np.int32)
        Sb = len(slice_ids)
        vals.append(np.ascontiguousarray(v.reshape(Sb, C, w_b).transpose(0, 2, 1)))
        cols.append(np.ascontiguousarray(c.reshape(Sb, C, w_b).transpose(0, 2, 1)))
        outrows.append(np.where(orig < n, orig, n).astype(np.int32))
        words_bucketed += v.size

    to_dev = jnp.asarray if device else (lambda v: v)
    return SELLMatrix(
        vals=tuple(to_dev(v) for v in vals),
        cols=tuple(to_dev(c) for c in cols),
        outrows=tuple(to_dev(o) for o in outrows),
        perm=to_dev(perm),
        n=n, m=m, C=C, sigma=sigma, value_dtype=value_dtype, nnz=int(a.nnz),
        words_sell_padded=words_sell_padded, words_bucketed=int(words_bucketed),
    )


def from_dense(a: np.ndarray, **kw) -> SELLMatrix:
    return from_csr(sp.csr_matrix(np.asarray(a)), **kw)


def pad_uniform(mat: SELLMatrix, *, n_slices: int | None = None,
                width: int | None = None,
                device: bool = True) -> SELLMatrix:
    """Pad a single-bucket ('uniform') SELL matrix to a common [S, w, C]
    shape — the fp32/fp64 twin of :func:`repro.core.packsell.pad_uniform`,
    used by the distributed composite to stack uncompressed members across
    shards. Padding entries carry ``val=0, col=0`` (a harmless read that
    contributes nothing); padded slices get sentinel outrows (>= n)."""
    if len(mat.vals) != 1:
        raise ValueError("pad_uniform needs a single-bucket matrix "
                         "(build with bucket_strategy='uniform')")
    val = np.asarray(mat.vals[0])
    col = np.asarray(mat.cols[0])
    outrow = np.asarray(mat.outrows[0])
    perm = np.asarray(mat.perm)
    S, w, C = val.shape
    S_t = S if n_slices is None else int(n_slices)
    w_t = w if width is None else int(width)
    if S_t < S or w_t < w:
        raise ValueError(f"cannot shrink: have (S={S}, w={w}), "
                         f"asked (S={S_t}, w={w_t})")
    val_p = np.zeros((S_t, w_t, C), val.dtype)
    val_p[:S, :w, :] = val
    col_p = np.zeros((S_t, w_t, C), np.int32)
    col_p[:S, :w, :] = col
    outrow_p = np.full(S_t * C, mat.n, np.int32)
    outrow_p[:S * C] = outrow
    perm_p = np.zeros(S_t * C, perm.dtype)
    perm_p[:len(perm)] = perm

    to_dev = jnp.asarray if device else (lambda v: v)
    return SELLMatrix(
        vals=(to_dev(val_p),), cols=(to_dev(col_p),),
        outrows=(to_dev(outrow_p),), perm=to_dev(perm_p),
        n=mat.n, m=mat.m, C=C, sigma=mat.sigma,
        value_dtype=mat.value_dtype, nnz=mat.nnz,
        words_sell_padded=mat.words_sell_padded,
        words_bucketed=int(val_p.size),
    )
