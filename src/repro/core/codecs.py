"""Value codecs + branch-free word pack/unpack for PackSELL (paper §4.2).

A PackSELL word (W = 32) is laid out as::

    flag = 1 :  [ value : V bits | delta : D bits | 1 ]     V = 31 - D
    flag = 0 :  [ delta  : 31 bits              | 0 ]     (dummy / padding)

``flag=0`` words carry no value; SELL padding reuses ``flag=0, delta=0`` so the
compute path needs no masking at all (a padding word contributes ``v = 0`` and
leaves the column cursor unchanged).

The unpack path mirrors Fig. 3(b) of the paper and is fully branch-free, which
on TPU means it vectorizes across the (8, 128) VREG on the VPU:

    flag   = word & 1
    shift  = (31 - D) * flag
    delta  = (word << shift) >> (shift + 1)        # logical shifts on uint32
    vbits  = word & (~((1 << (D+1)) - 1) * flag)   # zero low D+1 bits, or all
    value  = codec.decode(vbits)

Codecs supported (all W=32):

* ``fp16``  — IEEE FP16 embedded in the top 16 bits (paper §4.2.2; D <= 15).
* ``bf16``  — bfloat16 embedded in the top 16 bits. TPU adaptation: BF16 is the
  native 16-bit type on TPU; FP16 is kept for paper fidelity.
* ``e8m<Y>`` — the paper's E8MY: sign + 8 exponent + Y mantissa bits = the top
  V = 9 + Y bits of an FP32 pattern, round-to-nearest at pack time, decoded by
  a single mask + bitcast. Requires Y = 22 - D for a fully packed word.
* ``fixed<F>`` — signed fixed-point with F fractional bits in V bits (beyond
  paper: the "few-bit integer" representation its intro motivates).

All pack/unpack entry points exist twice: a numpy version (host-side format
construction) and a jnp version (device compute / Pallas kernel bodies).

Choosing *which* codec and delta width to use is the job of the adaptive
precision subsystem (``repro.precision``): ``precision.analyze`` carries the
per-codec a-priori quantization-error model (ulp bounds, range-clipping
penalties) validated by empirical probes, and ``precision.select`` turns an
error budget into a :class:`~repro.precision.select.PrecisionPlan`. The
error model, the selection policy, and the special-value (inf/NaN/subnormal)
rounding rules of the encoders below are documented in DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

W = 32  # word width in bits; the paper evaluates W=32 and so do we.


def vbits_for(D: int) -> int:
    """Value width V for a given delta width D (W = V + D + 1)."""
    return W - D - 1


def delta_mask(D: int) -> int:
    """Low-bit mask covering the delta+flag field: (1 << (D+1)) - 1."""
    return (1 << (D + 1)) - 1


# ---------------------------------------------------------------------------
# Codec definitions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Codec:
    """A V-bit value representation living in the top bits of a 32-bit word.

    ``encode_np(values, D)`` returns uint32 payloads whose low ``D+1`` bits are
    zero; ``decode(vbits)`` maps masked uint32 words (low bits already zeroed
    by the unpack sequence) to the compute dtype.
    """

    name: str
    min_D: int
    max_D: int
    encode_np: Callable[[np.ndarray, int], np.ndarray]
    decode_jnp: Callable[[jnp.ndarray, int], jnp.ndarray]
    decode_np: Callable[[np.ndarray, int], np.ndarray]
    # Effective value bits actually used for a given D (for memory accounting).
    value_bits: Callable[[int], int]


# -- FP16 / BF16 direct embedding (top 16 bits) ------------------------------


def _encode_f16_np(values: np.ndarray, D: int) -> np.ndarray:
    assert D <= 15, "fp16 embed needs V >= 16 (D <= 15)"
    with np.errstate(over="ignore"):  # out-of-range -> inf, IEEE overflow
        h = values.astype(np.float16)
    return h.view(np.uint16).astype(np.uint32) << np.uint32(16)


def _decode_f16_jnp(vbits: jnp.ndarray, D: int) -> jnp.ndarray:
    top = (vbits >> np.uint32(16)).astype(jnp.uint16)
    return jax.lax.bitcast_convert_type(top, jnp.float16)


def _decode_f16_np(vbits: np.ndarray, D: int) -> np.ndarray:
    return (vbits >> np.uint32(16)).astype(np.uint16).view(np.float16)


def _rne_truncate_f32_np(u: np.ndarray, low: int) -> np.ndarray:
    """RNE-truncate FP32 bit patterns to their top ``32 - low`` bits.

    inf/NaN (exponent all-ones) are truncated WITHOUT rounding: adding the
    rounding increment to an all-ones pattern wraps the uint32 and would
    silently turn a NaN into a small finite number. A NaN whose surviving
    mantissa bits are all zero keeps the quiet bit (bit 22) when that bit is
    kept, so NaN stays NaN; with no mantissa bits kept it collapses to inf
    (documented in DESIGN.md §8).
    """
    u = np.asarray(u, dtype=np.uint32)
    mask = ~np.uint32((1 << low) - 1)
    lsb = (u >> np.uint32(low)) & np.uint32(1)
    with np.errstate(over="ignore"):
        rounded = (u + lsb + np.uint32((1 << (low - 1)) - 1)) & mask
    special = (u & np.uint32(0x7F800000)) == np.uint32(0x7F800000)
    if not np.any(special):
        return rounded
    trunc = u & mask
    is_nan = special & ((u & np.uint32(0x007FFFFF)) != 0)
    if low <= 22:  # quiet bit survives truncation
        trunc = np.where(is_nan, trunc | np.uint32(1 << 22), trunc)
    return np.where(special, trunc, rounded)


def _encode_bf16_np(values: np.ndarray, D: int) -> np.ndarray:
    assert D <= 15, "bf16 embed needs V >= 16 (D <= 15)"
    u = np.ascontiguousarray(values.astype(np.float32)).view(np.uint32)
    # round-to-nearest-even truncation to the top 16 bits
    return _rne_truncate_f32_np(u, 16)


def _decode_bf16_jnp(vbits: jnp.ndarray, D: int) -> jnp.ndarray:
    # low 16 bits of the masked word may contain delta bits when D < 15:
    # clear everything below the bf16 payload before bitcasting.
    return jax.lax.bitcast_convert_type(vbits & np.uint32(0xFFFF0000), jnp.float32)


def _decode_bf16_np(vbits: np.ndarray, D: int) -> np.ndarray:
    return (vbits & np.uint32(0xFFFF0000)).view(np.float32)


# -- E8MY: top V bits of an FP32 pattern (paper §4.2.2) ----------------------


def _encode_e8m_np(values: np.ndarray, D: int) -> np.ndarray:
    """Round an FP32 value to its top V = 31-D bits (RNE), low D+1 bits zero.

    Bit-level equivalent of the paper's frexpf/ldexpf + round construction,
    but round-to-nearest-even instead of round-half-away (documented in
    DESIGN.md; difference is at most 1 ulp of the truncated format).
    """
    u = np.ascontiguousarray(values.astype(np.float32)).view(np.uint32)
    return _rne_truncate_f32_np(u, D + 1)


def _decode_e8m_jnp(vbits: jnp.ndarray, D: int) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(vbits, jnp.float32)


def _decode_e8m_np(vbits: np.ndarray, D: int) -> np.ndarray:
    return vbits.view(np.float32)


# -- Fixed point (beyond paper): signed V-bit integer with F fraction bits ---


def _make_fixed(frac_bits: int):
    def encode(values: np.ndarray, D: int) -> np.ndarray:
        V = vbits_for(D)
        scaled = np.round(values.astype(np.float64) * (1 << frac_bits))
        lo, hi = -(1 << (V - 1)), (1 << (V - 1)) - 1
        q = np.clip(scaled, lo, hi).astype(np.int64)
        return (q.astype(np.uint32) << np.uint32(D + 1)) & np.uint32(0xFFFFFFFF)

    def decode_jnp(vbits: jnp.ndarray, D: int) -> jnp.ndarray:
        # arithmetic shift to sign-extend the V-bit payload
        signed = jax.lax.bitcast_convert_type(vbits, jnp.int32) >> np.int32(D + 1)
        return signed.astype(jnp.float32) * np.float32(2.0 ** (-frac_bits))

    def decode_np(vbits: np.ndarray, D: int) -> np.ndarray:
        signed = vbits.view(np.int32) >> np.int32(D + 1)
        return signed.astype(np.float32) * np.float32(2.0 ** (-frac_bits))

    return encode, decode_jnp, decode_np


def make_codec(name: str) -> Codec:
    if name == "fp16":
        return Codec("fp16", 1, 15, _encode_f16_np, _decode_f16_jnp,
                     _decode_f16_np, lambda D: 16)
    if name == "bf16":
        return Codec("bf16", 1, 15, _encode_bf16_np, _decode_bf16_jnp,
                     _decode_bf16_np, lambda D: 16)
    if name == "e8m":
        # Y = 22 - D mantissa bits; V = 31 - D total.
        return Codec("e8m", 1, 22, _encode_e8m_np, _decode_e8m_jnp,
                     _decode_e8m_np, lambda D: vbits_for(D))
    if name.startswith("fixed"):
        frac = int(name[len("fixed"):])
        enc, dec_j, dec_n = _make_fixed(frac)
        return Codec(name, 1, 24, enc, dec_j, dec_n, lambda D: vbits_for(D))
    raise ValueError(f"unknown codec {name!r}")


# ---------------------------------------------------------------------------
# Word-level pack / unpack
# ---------------------------------------------------------------------------


def pack_words_np(values: np.ndarray, deltas: np.ndarray, flags: np.ndarray,
                  codec: Codec, D: int) -> np.ndarray:
    """Pack (value, delta, flag) triples into uint32 words (Fig. 3a).

    flags==1: value embedded, delta must fit D bits.
    flags==0: delta occupies 31 bits, value ignored (dummy / padding).
    """
    deltas = np.asarray(deltas)
    if np.any(deltas < 0):
        raise ValueError("negative delta in word stream")
    deltas = deltas.astype(np.uint64)
    flags = flags.astype(np.uint32)
    # Explicit validation (not asserts): a delta that overflows its field
    # would silently wrap into the value/flag bits and corrupt the matrix.
    bad = (flags == 1) & (deltas >= (1 << D))
    if np.any(bad):
        k = int(np.nonzero(bad)[0][0])
        raise ValueError(
            f"flag=1 delta {int(deltas[k])} at word {k} overflows the "
            f"D={D}-bit field; insert a dummy word "
            f"(core.delta.emit_word_stream) or raise D")
    if np.any(deltas >= (1 << (W - 1))):
        k = int(np.nonzero(deltas >= (1 << (W - 1)))[0][0])
        raise ValueError(
            f"dummy delta {int(deltas[k])} at word {k} overflows the "
            f"{W - 1}-bit field; chain dummy words "
            f"(core.delta.dummies_for_deltas)")
    payload = codec.encode_np(np.asarray(values, dtype=np.float32), D)
    word1 = payload | ((deltas.astype(np.uint32)) << np.uint32(1)) | np.uint32(1)
    word0 = (deltas.astype(np.uint32)) << np.uint32(1)
    return np.where(flags == 1, word1, word0)


def unpack_words_jnp(words: jnp.ndarray, codec: Codec, D: int):
    """Branch-free unpack (Fig. 3b). Returns (value, delta:uint32)."""
    one = np.uint32(1)
    flag = words & one
    shift = np.uint32(W - 1 - D) * flag
    delta = (words << shift) >> (shift + one)
    vbits = words & (np.uint32(~np.uint32(delta_mask(D))) * flag)
    value = codec.decode_jnp(vbits, D)
    return value, delta


def unpack_words_np(words: np.ndarray, codec: Codec, D: int):
    """Numpy mirror of :func:`unpack_words_jnp` (host-side oracle)."""
    words = words.astype(np.uint32)
    flag = words & np.uint32(1)
    shift = (np.uint32(W - 1 - D) * flag).astype(np.uint32)
    delta = (words << shift) >> (shift + np.uint32(1))
    vbits = words & (~np.uint32(delta_mask(D)) * flag)
    value = codec.decode_np(vbits, D)
    return value, delta, flag


def quantize_np(values: np.ndarray, codec: Codec, D: int) -> np.ndarray:
    """Round-trip values through the codec (what SpMV will actually see)."""
    payload = codec.encode_np(np.asarray(values, np.float32), D)
    return np.asarray(codec.decode_np(payload, D), dtype=np.float32)
