"""Core PackSELL library: the paper's primary contribution, in JAX.

Public API::

    from repro.core import packsell, sell, sparse, codecs, testmats
    A = packsell.from_csr(csr, C=128, sigma=256, D=15, codec="fp16")
    y = A.spmv(x)                        # vectorized jnp path
    y = kernels.ops.packsell_spmv(A, x)  # Pallas TPU kernel path
"""
from . import (codecs, delta, packsell, reorder, sell, sparse,  # noqa: F401
               testmats, trisolve)
from .packsell import (PackSELLMatrix, packsell_spmm_jnp,  # noqa: F401
                       packsell_spmv_jnp)
from .sell import SELLMatrix, sell_spmv_jnp  # noqa: F401
from .sparse import CSRMatrix, COOMatrix, csr_from_scipy, coo_from_scipy  # noqa: F401
