"""Mixture-of-Experts layer: top-k routing, sort-based dispatch, capacity
dropping, optional shared experts (Qwen2-MoE style), EP-shardable.

Dispatch avoids the GShard one-hot [T, E, C] tensor (intractable at 1M-token
batches): assignments are argsorted by expert id, each expert takes its first
``capacity`` tokens via gather, runs a batched [E, C, d] × [E, d, ff] einsum
(sharded over the ``model`` axis = expert parallelism), and results scatter
back weighted by the gate. Router z-loss + load-balance aux loss included.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import layers as L


def padded_experts(E: int, ep: int = 16) -> int:
    """Experts padded up to a multiple of the EP axis (§Perf extension:
    qwen2-moe's 60 experts pad to 64 so EP applies instead of intra-expert
    TP; pad experts are never routed to, their weights stay zero-grad, and
    the 6–7% extra weight memory buys collective-free expert einsums)."""
    return -(-E // ep) * ep


def init(key, cfg, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    Ep = padded_experts(E)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": jax.random.uniform(k1, (d, E), jnp.float32, -scale, scale),
        "wi": jax.random.uniform(k2, (Ep, d, ff), dtype, -scale, scale),
        "wg": jax.random.uniform(k3, (Ep, d, ff), dtype, -scale, scale),
        "wo": jax.random.uniform(k4, (Ep, ff, d), dtype,
                                 -1.0 / np.sqrt(ff), 1.0 / np.sqrt(ff)),
    }
    wspec = P("model", None, None)       # EP always (experts padded)
    ospec = P("model", None, None)
    s = {
        "router": P(None, None),
        "wi": wspec,
        "wg": wspec,
        "wo": ospec,
    }
    if cfg.n_shared_experts:
        ks = jax.random.split(key, cfg.n_shared_experts + 4)[4:]
        shared, sspec = [], []
        for i in range(cfg.n_shared_experts):
            sp, ss = L.swiglu_init(ks[i], d, ff, dtype)
            shared.append(sp)
            sspec.append(ss)
        p["shared"] = jax.tree.map(lambda *a: jnp.stack(a), *shared)
        s["shared"] = jax.tree.map(
            lambda spec: P(*(None,) + tuple(spec)), sspec[0])
    return p, s


def apply(p, cfg, x, dtype):
    """x: [B, S, d] -> (y, aux_losses dict).

    Dispatch is PER BATCH ROW (§Perf B1): a global argsort over all T·k
    assignments cannot shard (GSPMD replicates the whole dispatch — measured
    at 100+ GB/device on dbrx-132b), so every dispatch op here keeps a
    leading B dim that shards over the DP axes, with per-row capacity
    ``ceil(S·k/E · cf)``. Expert buffers [B, E, cap, d] shard B over DP and
    E over 'model' (EP); the expert einsums are then fully local.
    """
    from repro.parallel import constrain
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    Ep = padded_experts(E)    # mirror init(): EP always, experts padded
    espec = "model"

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # [B, S, E]
    gate_vals, expert_ids = jax.lax.top_k(probs, k)           # [B, S, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (GShard/ST-MoE style) ----
    me = probs.reshape(-1, E).mean(axis=0)                    # [E]
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        jnp.full((B * S * k,), 1.0 / (B * S * k), jnp.float32))
    lb_loss = (E * jnp.sum(me * ce)).astype(jnp.float32)
    z_loss = jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2).astype(jnp.float32)

    # ---- per-row sort-based dispatch with capacity ----
    A = S * k                                                 # row assigns
    cap = int(np.ceil(S * k / E * cfg.capacity_factor))
    flat_expert = expert_ids.reshape(B, A)                    # [B, A]
    order = jnp.argsort(flat_expert, axis=-1)                 # row-batched
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=-1)
    seg_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_expert)
    rank_sorted = jnp.arange(A)[None, :] - jnp.take_along_axis(
        seg_start, sorted_expert, axis=-1)
    keep = rank_sorted < cap
    # dropped assignments are redirected out of range -> scatter-drop
    # (sentinel beyond the PADDED buffer so pad experts stay untouched)
    slot = jnp.where(keep, sorted_expert * cap +
                     jnp.minimum(rank_sorted, cap - 1), Ep * cap)

    token_of_assign = order // k                              # [B, A] in [0,S)
    dp = ("pod", "data")
    # §Perf B2: every [B, A, d] dispatch intermediate keeps d sharded over
    # 'model' — the token dim is gather/scatter-indexed (unshardable), and
    # an unsharded d makes GSPMD's masked-gather all-reduce move the full
    # activation (measured 6.4 GB x40 layers on dbrx)
    xs = constrain(x.astype(dtype), P(dp, None, "model"))
    gathered = jnp.take_along_axis(
        xs, token_of_assign[..., None], axis=1)               # [B, A, d]
    gathered = constrain(gathered, P(dp, None, "model"))

    def row_scatter(sl, g):
        return jnp.zeros((Ep * cap, d), dtype).at[sl].set(g, mode="drop")

    buf = jax.vmap(row_scatter)(slot, gathered)              # [B, Ep*cap, d]
    # scatter stays d-sharded (so its transpose-gather is local, §Perf B3);
    # the einsum below needs E-sharded — one all-to-all reshard, not a
    # masked-gather all-reduce of the full activation
    buf = constrain(buf, P(dp, None, "model"))
    buf = buf.reshape(B, Ep, cap, d)
    buf = constrain(buf, P(dp, espec, None, None))

    h = jax.nn.silu(
        jnp.einsum("becd,edf->becf", buf, p["wg"].astype(dtype))) \
        * jnp.einsum("becd,edf->becf", buf, p["wi"].astype(dtype))
    h = constrain(h, P(dp, espec, None, None))
    out = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dtype))
    out = constrain(out, P(dp, espec, None, None))
    out = out.reshape(B, Ep * cap, d)
    out = constrain(out, P(dp, None, "model"))                # §Perf B2

    gates_sorted = jnp.take_along_axis(gate_vals.reshape(B, A), order,
                                       axis=-1)
    contrib = jnp.where(
        keep[..., None],
        jnp.take_along_axis(out, jnp.minimum(slot, Ep * cap - 1)[..., None],
                            axis=1).astype(jnp.float32)
        * gates_sorted[..., None], 0.0)                       # [B, A, d]
    contrib = constrain(contrib, P(dp, None, "model"))        # §Perf B2

    def row_combine(tok, c):
        return jnp.zeros((S, d), jnp.float32).at[tok].add(c)

    y = jax.vmap(row_combine)(token_of_assign, contrib).astype(dtype)
    y = constrain(y, P(dp, None, "model"))                    # §Perf B3

    if "shared" in p:
        def shared_apply(sp):
            return L.swiglu_apply(sp, x.reshape(B * S, d), dtype)
        ys = jax.vmap(shared_apply)(p["shared"])            # [n_sh, B*S, d]
        y = y + ys.sum(axis=0).reshape(B, S, d)

    return y, {"moe_lb": lb_loss, "moe_z": z_loss}
