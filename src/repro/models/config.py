"""Model configuration for all assigned architectures.

One frozen dataclass covers the five families (dense / moe / vlm / hybrid /
ssm / encdec): family-specific fields are zero/None when unused. Exact
hyper-parameters per architecture live in ``repro/configs/<id>.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | vlm | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int                   # dense FFN dim (per-expert dim for MoE)
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0   # shared experts with the same d_ff
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128        # SSD chunk length
    attn_every: int = 0         # hybrid: shared attn block every k layers

    # encoder-decoder
    enc_layers: int = 0

    # modality frontend stub (precomputed embeddings per the assignment)
    frontend: Optional[str] = None   # None | 'vision_stub' | 'audio_stub'
    frontend_len: int = 0            # patches / frames per example

    # compute
    dtype: str = "bfloat16"     # activation/compute dtype
    param_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Embedding/head tables padded to a multiple of 256 so the vocab dim
        shards over model=16 (MaxText-style); logits at padded positions are
        masked to -inf in the loss/decode (exact semantics preserved)."""
        return (self.vocab + 255) // 256 * 256

    @property
    def d_inner(self) -> int:            # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM/hybrid decode)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d if self.n_heads else 0
        mlp_dense = 3 * d * ff
        n = 0
        if self.family in ("dense", "vlm"):
            n = self.n_layers * (att + mlp_dense + 2 * d)
        elif self.family == "moe":
            # expert tables are padded to a multiple of the EP axis (16) so
            # expert parallelism always applies (models/moe.padded_experts)
            e_pad = -(-self.n_experts // 16) * 16
            moe = e_pad * 3 * d * ff + d * self.n_experts \
                + self.n_shared_experts * 3 * d * ff
            n = self.n_layers * (att + moe + 2 * d)
        elif self.family == "ssm":
            blk = self._ssm_block_params()
            n = self.n_layers * (blk + d)
        elif self.family == "hybrid":
            blk = self._ssm_block_params()
            shared = att + mlp_dense + 2 * d
            n = self.n_layers * (blk + d) + shared
        elif self.family == "encdec":
            enc = self.enc_layers * (att + mlp_dense + 2 * d)
            dec = self.n_layers * (2 * att + mlp_dense + 3 * d)
            n = enc + dec
        n += V * d * (1 if self.tie_embeddings else 2) + d
        if self.family in ("vlm",) :
            n += self.d_model * self.d_model  # projector stub
        return n

    def _ssm_block_params(self) -> int:
        d, di, N = self.d_model, self.d_inner, self.ssm_state
        H = self.ssm_heads
        g = 1  # single B/C group
        in_proj = d * (2 * di + 2 * g * N + H)
        return in_proj + self.ssm_conv * (di + 2 * g * N) + H * 2 \
            + di * d + di

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6·N_active·D roofline)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        hd = self.head_dim
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        act_moe = (self.top_k + self.n_shared_experts) * 3 * d * ff \
            + d * self.n_experts
        n = self.n_layers * (att + act_moe + 2 * d)
        n += self.vocab * d * 2 + d
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str    # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) dry-run cell applies (assignment rules)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is full-attention (skip per assignment)")
    return True, ""
