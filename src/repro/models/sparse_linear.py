"""PackSELL sparse-weight linear layers (pruned-weight serving).

This is the paper's kernel in the LM serving path (DESIGN.md §4.1): decode
is memory-bound matvec — exactly the regime the paper targets — so a
magnitude-pruned projection stored in PackSELL cuts the bytes per decode
step by (1 − density) × compression_ratio, with the value codec (fp16 /
bf16 / E8MY) choosing the accuracy/bandwidth point.

``PackSELLLinear`` is built offline from a dense weight; at decode time
``apply`` runs SpMV per batch element (the jnp path vmaps over the batch;
the Pallas kernel path serves the single-request case).
"""
from __future__ import annotations

import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.core import packsell as pk
from repro.kernels import plan as kplan

log = logging.getLogger(__name__)


def prune_magnitude(w: np.ndarray, density: float) -> np.ndarray:
    """Keep the top-``density`` fraction of |w| entries (global threshold).
    Returns the pruned dense weight (zeros elsewhere)."""
    if not (0.0 < density <= 1.0):
        raise ValueError(density)
    flat = np.abs(w).ravel()
    k = max(int(round(density * flat.size)), 1)
    if k >= flat.size:
        return w.copy()
    thresh = np.partition(flat, flat.size - k)[flat.size - k]
    out = np.where(np.abs(w) >= thresh, w, 0.0)
    return out


@dataclasses.dataclass
class PackSELLLinear:
    """y = W x with W pruned + stored as PackSELL ([out, in] row-major)."""

    mat: pk.PackSELLMatrix
    density: float
    dense_bytes: int
    # adaptive-precision provenance (codec="auto"; DESIGN.md §8)
    precision_plan: object = None     # precision.select.PrecisionPlan | None
    fingerprint: str | None = None
    from_store: bool = False
    # retained pruned weight (CSR) — the self-healing rebuild source
    # (serving warmup rebuilds unhealthy plans from it; DESIGN.md §11.4)
    _csr: object = None               # scipy.sparse.csr_matrix | None

    @classmethod
    def from_dense(cls, w: np.ndarray, *, density: float = 0.3,
                   codec: str = "bf16", D: int = 15, C: int = 128,
                   sigma: int = 256, error_budget: float = 1e-3,
                   store=None) -> "PackSELLLinear":
        """``w``: [in, out] dense kernel (column-major convention used by
        ``layers.dense_init``); stored transposed so rows = outputs.

        ``codec="auto"`` hands the choice to the adaptive precision
        subsystem: ``repro.precision`` selects the cheapest ``(codec, D)``
        whose probe error fits ``error_budget`` on the pruned weight, with
        ``store`` (a ``precision.PrecisionStore`` or path) skipping
        re-analysis across restarts. The selection plan and matrix
        fingerprint are kept on the layer for serving-warmup logs.
        """
        from repro import precision as pr
        wp = prune_magnitude(np.asarray(w, np.float32), density)
        csr = sp.csr_matrix(wp.T)     # [out, in]
        pplan, from_store = None, False
        # fingerprint unconditionally: warmup restores (sb, wb) retile
        # winners for caller-fixed codecs too, not only codec="auto"
        fingerprint = pr.matrix_fingerprint(csr)
        if codec == "auto":
            if store is not None:
                store = pr.PrecisionStore.coerce(store)
                pplan, from_store = store.lookup_or_select(
                    csr, error_budget, sigma=sigma)
            else:
                pplan = pr.select_codec(csr, error_budget, sigma=sigma)
            prim = pplan.primary
            if prim.codec == "fp32":
                # no packed codec fits the budget; the best PackSELL can
                # store is E8M21 — louder than the budget, so say so
                codec, D = "e8m", 1
                log.warning(
                    "PackSELLLinear codec='auto': no packed codec fits "
                    "error_budget=%.3g (selection says fp32); storing "
                    "e8m/D=1 (~2.4e-7 relative error) instead — the "
                    "budget is NOT met", error_budget)
            else:
                codec, D = prim.codec, prim.D
        mat = pk.from_csr(csr, C=C, sigma=sigma, D=D, codec=codec)
        return cls(mat=mat, density=density,
                   dense_bytes=w.size * np.dtype(np.float32).itemsize,
                   precision_plan=pplan, fingerprint=fingerprint,
                   from_store=from_store, _csr=csr)

    @property
    def plan(self) -> kplan.SpMVPlan:
        """The cached SpMVPlan (built once, shared by every decode tick)."""
        return kplan.get_plan(self.mat)

    def rebuild(self) -> kplan.SpMVPlan:
        """Re-pack the matrix and plan from the retained pruned CSR —
        the recovery path when the guard layer marks the live plan
        unhealthy (bit flips in packed operands survive jit re-dispatch,
        so only a fresh build clears them). Raises if the layer was
        constructed without a retained CSR (e.g. unpickled from an old
        snapshot)."""
        if self._csr is None:
            raise RuntimeError(
                "PackSELLLinear.rebuild: no retained CSR on this layer")
        self.mat = pk.from_csr(self._csr, C=self.mat.C, sigma=self.mat.sigma,
                               D=self.mat.D, codec=self.mat.codec_name)
        return kplan.get_plan(self.mat)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: [in] or [..., in] → [..., out]. Dispatches through the cached
        SpMVPlan: single jitted call per tick, no host-side re-planning.
        Batched inputs go through the multi-RHS SpMM path: one pass over
        the packed words for the whole batch."""
        plan = self.plan
        if x.ndim == 1:
            return plan.spmv(self.mat, x)
        lead = x.shape[:-1]
        flat = x.reshape(-1, x.shape[-1])
        y = plan.spmm(self.mat, flat.T).T
        return y.reshape(*lead, -1)

    def warmup(self, batch: int = 0) -> kplan.SpMVPlan:
        """Build the plan and trace the dispatch (spmv; plus spmm at the
        given batch size) so the first serving tick pays nothing."""
        x = jnp.zeros((self.mat.m,), jnp.float32)
        jax.block_until_ready(self(x))
        if batch:
            xb = jnp.zeros((batch, self.mat.m), jnp.float32)
            jax.block_until_ready(self(xb))
        return self.plan

    def describe(self) -> dict:
        """Codec provenance for serving-warmup logs (DecodeEngine)."""
        return {
            "codec": self.mat.codec_name, "D": self.mat.D,
            "shape": [self.mat.n, self.mat.m], "density": self.density,
            "auto_selected": self.precision_plan is not None,
            # False only when selection fell back to fp32 but the layer
            # had to store a packed codec anyway (budget not certified)
            "budget_met": (self.precision_plan is None
                           or self.precision_plan.primary.codec
                           == self.mat.codec_name),
            "from_store": self.from_store, "fingerprint": self.fingerprint,
            "memory_ratio": self.memory_ratio(),
        }

    def memory_ratio(self) -> float:
        """Stored bytes vs the dense fp32 weight."""
        return self.mat.memory_stats()["packsell_bytes"] / self.dense_bytes

    def decode_bytes_per_token(self) -> int:
        """Bytes streamed per matvec (the decode-step cost)."""
        return self.mat.memory_stats()["packsell_bytes"]
