"""Shared neural layers (pure JAX, functional): norms, RoPE, MLP, embeddings.

Every ``init_*`` returns ``(params, specs)`` — a param pytree and a parallel
tree of ``jax.sharding.PartitionSpec`` encoding the TP/DP layout (DESIGN.md
§5). ``specs`` use logical axis names resolved by ``repro.parallel``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def _split(key, n):
    return jax.random.split(key, n)


def dense_init(key, d_in: int, d_out: int, dtype, in_axis=None,
               out_axis="model", bias: bool = False):
    scale = 1.0 / np.sqrt(d_in)
    w = jax.random.uniform(key, (d_in, d_out), dtype, -scale, scale)
    p = {"w": w}
    s = {"w": P(in_axis, out_axis)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = P(out_axis)
    return p, s


def dense_apply(p, x, dtype):
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def rmsnorm_init(d: int, dtype):
    return {"g": jnp.ones((d,), dtype)}, {"g": P(None)}


def rmsnorm_apply(p, x, eps: float, dtype):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(dtype)


def swiglu_init(key, d: int, ff: int, dtype):
    k1, k2, k3 = _split(key, 3)
    wi, si = dense_init(k1, d, ff, dtype, out_axis="model")
    wg, sg = dense_init(k2, d, ff, dtype, out_axis="model")
    wo, so = dense_init(k3, ff, d, dtype, in_axis="model", out_axis=None)
    return ({"wi": wi, "wg": wg, "wo": wo}, {"wi": si, "wg": sg, "wo": so})


def swiglu_apply(p, x, dtype):
    h = jax.nn.silu(dense_apply(p["wg"], x, dtype)) * \
        dense_apply(p["wi"], x, dtype)
    return dense_apply(p["wo"], h, dtype)


def embed_init(key, vocab: int, d: int, dtype):
    w = jax.random.normal(key, (vocab, d), dtype) * 0.02
    return {"w": w}, {"w": P(None, "model")}


def embed_apply(p, tokens, dtype):
    return jnp.take(p["w"].astype(dtype), tokens, axis=0)


def rope(q, k, positions, theta: float):
    """Rotary embeddings. q,k: [..., S, H, hd]; positions: [..., S]."""
    hd = q.shape[-1]
    half = hd // 2
    freqs = (1.0 / (theta ** (np.arange(0, half) * 2.0 / hd))).astype(
        np.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]   # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]

    def rot(x):
        x1, x2 = x[..., :half], x[..., half:]
        xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
        return jnp.concatenate([xf1 * cos - xf2 * sin,
                                xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)

    return rot(q), rot(k)
