"""GQA attention with chunked (flash-style) softmax and KV caching.

``flash_attention`` never materializes the full S×S score matrix: queries are
processed in chunks with an online-softmax running (max, sum, acc) over KV
chunks — the standard memory-bounded formulation, required for the 32k
prefill cells. A Pallas-fused variant is a §Perf hillclimb candidate
(benchmarked separately); this jnp version is the portable baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel import constrain, current_mesh

from . import layers as L

NEG_INF = -1e30


def _score_shard_dim(KV: int, G: int, q_chunk: int) -> str | None:
    """Which score dim the 'model' axis shards: KV heads, GQA groups, or the
    query chunk. head_dim is NEVER sharded (a sharded contraction in the
    score einsum makes GSPMD emit an all-reduce per KV chunk — measured as
    the dominant collective of the qwen2-0.5b prefill cell, §Perf A1)."""
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    M = mesh.shape["model"]
    if KV % M == 0:
        return "kv"
    if G % M == 0:
        return "g"
    if q_chunk % M == 0:
        return "qc"
    return None


def _spec6(dim: str | None, which: str):
    """P-spec builders for the chunked tensors (dims documented inline)."""
    dp = ("pod", "data")
    m = "model"
    if which == "qp":      # [B, nq, qc, KV, G, hd]
        return P(dp, None, m if dim == "qc" else None,
                 m if dim == "kv" else None, m if dim == "g" else None, None)
    if which == "kvp":     # [nk, B, kc, KV, hd]
        return P(None, dp, None, m if dim == "kv" else None, None)
    if which == "ms":      # [B, qc, G, KV]
        return P(dp, m if dim == "qc" else None,
                 m if dim == "g" else None, m if dim == "kv" else None)
    if which == "acc":     # [B, qc, G, KV, hd]
        return P(dp, m if dim == "qc" else None,
                 m if dim == "g" else None, m if dim == "kv" else None, None)
    raise ValueError(which)


def init(key, cfg, dtype):
    d = cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    wq, sq = L.dense_init(k1, d, H * hd, dtype, bias=cfg.qkv_bias)
    wk, sk = L.dense_init(k2, d, KV * hd, dtype, bias=cfg.qkv_bias)
    wv, sv = L.dense_init(k3, d, KV * hd, dtype, bias=cfg.qkv_bias)
    wo, so = L.dense_init(k4, H * hd, d, dtype, in_axis="model",
                          out_axis=None)
    return ({"wq": wq, "wk": wk, "wv": wv, "wo": wo},
            {"wq": sq, "wk": sk, "wv": sv, "wo": so})


def _qkv(p, cfg, x, positions, dtype):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L.dense_apply(p["wq"], x, dtype).reshape(B, S, H, hd)
    k = L.dense_apply(p["wk"], x, dtype).reshape(B, S, KV, hd)
    v = L.dense_apply(p["wv"], x, dtype).reshape(B, S, KV, hd)
    q, k = L.rope(q, k, positions, cfg.rope_theta)
    return q, k, v


def flash_attention(q, k, v, *, causal: bool, q_offset=0, kv_len=None,
                    q_chunk: int = 512, kv_chunk: int = 1024):
    """Online-softmax attention.

    q: [B, Sq, H, hd]; k,v: [B, Sk, KV, hd] (GQA: H % KV == 0).
    q_offset: absolute position of q[0] (causal masking with a cache).
    kv_len: optional [B] valid KV lengths (decode with ragged cache).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = np.float32(1.0 / np.sqrt(hd))
    # bound the q unroll (§Perf A2) to <=16 chunks on long sequences
    q_chunk = min(max(q_chunk, -(-Sq // 16)), Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = (Sq + q_chunk - 1) // q_chunk
    nk = (Sk + kv_chunk - 1) // kv_chunk
    # pad to whole chunks
    qp = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_chunk - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_chunk - Sk), (0, 0), (0, 0)))
    # [B, nq, qc, KV, G, hd]
    qp = qp.reshape(B, nq, q_chunk, KV, G, hd)
    kp = kp.reshape(B, nk, kv_chunk, KV, hd)
    vp = vp.reshape(B, nk, kv_chunk, KV, hd)

    kp_t = kp.transpose(1, 0, 2, 3, 4)        # [nk, B, kc, KV, hd]
    vp_t = vp.transpose(1, 0, 2, 3, 4)

    # explicit score-compute sharding (§Perf A1): pick the dim the model
    # axis shards; hd stays replicated so the score contraction is local.
    # Decode (Sq == 1) keeps GSPMD's cache-driven layout — constraining
    # here replicated the KV cache over 'model' (measured regression).
    sdim = _score_shard_dim(KV, G, q_chunk) if Sq > 1 else None
    if sdim is not None:
        qp = constrain(qp, _spec6(sdim, "qp"))
        kp_t = constrain(kp_t, _spec6(sdim, "kvp"))
        vp_t = constrain(vp_t, _spec6(sdim, "kvp"))

    def q_step(qi):
        qc = qp[:, qi]                        # [B, qc, KV, G, hd]
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            m, s, acc = carry
            kc, vc, ki = inp                  # [B, kc, KV, hd]
            logits = jnp.einsum("bqkgh,bckh->bqgkc", qc.astype(jnp.float32),
                                kc.astype(jnp.float32)) * scale
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            valid = jnp.broadcast_to(k_pos[None, :] < Sk,
                                     (q_chunk, kv_chunk))
            if causal:
                valid = valid & (k_pos[None, :] <= q_pos[:, None])
            logits = jnp.where(valid[None, :, None, None, :],
                               logits, NEG_INF)
            if kv_len is not None:
                lv = k_pos[None, :] < kv_len[:, None]   # [B, kc]
                logits = jnp.where(lv[:, None, None, None, :], logits,
                                   NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            s_new = s * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqgkc,bckh->bqgkh", p, vc.astype(jnp.float32))
            return (m_new, s_new, acc_new), None

        # checkpoint: backward recomputes logits/p per kv chunk instead of
        # saving [B, qc, G, KV, kc] fp32 residuals for every chunk pair
        kv_step_ck = jax.checkpoint(kv_step)
        m0 = jnp.full((B, q_chunk, G, KV), NEG_INF, jnp.float32)
        s0 = jnp.zeros((B, q_chunk, G, KV), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, G, KV, hd), jnp.float32)
        if sdim is not None:
            m0 = constrain(m0, _spec6(sdim, "ms"))
            s0 = constrain(s0, _spec6(sdim, "ms"))
            a0 = constrain(a0, _spec6(sdim, "acc"))
        # causal chunk skip (§Perf A2): kv chunks strictly above the
        # diagonal are fully masked — don't compute them. Static per-q-chunk
        # trip counts (the q loop is a Python unroll over nq).
        nk_i = min(nk, (qi * q_chunk + q_chunk - 1) // kv_chunk + 1) \
            if causal else nk
        (m, s, acc), _ = jax.lax.scan(
            kv_step_ck, (m0, s0, a0),
            (kp_t[:nk_i], vp_t[:nk_i], jnp.arange(nk_i)))
        out = acc / jnp.maximum(s[..., None], 1e-30)
        return out                             # [B, qc, G, KV, hd]

    outs = jnp.stack([q_step(qi) for qi in range(nq)], axis=0)
    # outs: [nq, B, qc, G, KV, hd] -> [B, Sq, H, hd]
    out = outs.transpose(1, 0, 2, 4, 3, 5).reshape(B, nq * q_chunk, KV * G,
                                                   hd)[:, :Sq]
    return out.astype(q.dtype)


def apply_full(p, cfg, x, positions, dtype, *, causal=True):
    """Training / prefill path (no cache in, optionally cache out)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions, dtype)
    out = flash_attention(q, k, v, causal=causal)
    y = L.dense_apply(p["wo"], out.reshape(B, S, -1), dtype)
    return y, (k, v)


def apply_decode(p, cfg, x, cache_k, cache_v, cache_len, dtype):
    """Single-token decode. x: [B, 1, d]; cache: [B, Smax, KV, hd]."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = cache_len[:, None]            # [B, 1]
    q, k, v = _qkv(p, cfg, x, positions, dtype)
    # write the new K/V at cache_len (per-batch dynamic index)
    idx = cache_len[:, None]                  # [B,1]
    oh = jax.nn.one_hot(idx, cache_k.shape[1], dtype=cache_k.dtype)  # [B,1,S]
    cache_k = cache_k + jnp.einsum("bos,bokh->bskh", oh, k.astype(cache_k.dtype))
    cache_v = cache_v + jnp.einsum("bos,bokh->bskh", oh, v.astype(cache_v.dtype))
    out = flash_attention(q, cache_k.astype(dtype), cache_v.astype(dtype),
                          causal=False, kv_len=cache_len + 1,
                          q_chunk=1, kv_chunk=4096)
    y = L.dense_apply(p["wo"], out.reshape(B, 1, -1), dtype)
    return y, cache_k, cache_v


def cross_kv(p, cfg, enc_out, dtype):
    """Project encoder memory to K/V once (reused by every decode step)."""
    B, S, _ = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    k = L.dense_apply(p["wk"], enc_out, dtype).reshape(B, S, KV, hd)
    v = L.dense_apply(p["wv"], enc_out, dtype).reshape(B, S, KV, hd)
    return k, v


def apply_cross(p, cfg, x, enc_k, enc_v, dtype):
    """Cross-attention over fixed encoder memory (enc-dec decode/train)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L.dense_apply(p["wq"], x, dtype).reshape(B, S, H, hd)
    out = flash_attention(q, enc_k, enc_v, causal=False)
    return L.dense_apply(p["wo"], out.reshape(B, S, -1), dtype)
