"""Input ShapeDtypeStruct builders for every (arch × shape) dry-run cell.

Shapes are *global* (the jit in_shardings distribute them over the mesh).
Modality frontends are stubs per the assignment: ``patches`` / ``frames``
are precomputed embeddings with hidden size 1024.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer as tfm
from .config import ModelConfig, ShapeConfig

STUB_DIM = 1024


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def frontend_lens(cfg: ModelConfig, seq_len: int) -> tuple[int, int]:
    """(frontend tokens, text tokens) for a given total sequence length."""
    if cfg.frontend == "vision_stub":
        p = min(cfg.frontend_len, seq_len // 2)
        return p, seq_len - p
    if cfg.frontend == "audio_stub":
        return seq_len // 4, seq_len          # encoder frames, decoder tokens
    return 0, seq_len


def train_batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    fl, tl = frontend_lens(cfg, S)
    spec = {
        "tokens": _sds((B, tl), jnp.int32),
        "labels": _sds((B, tl), jnp.int32),
        "mask": _sds((B, tl), jnp.float32),
    }
    if cfg.frontend == "vision_stub":
        spec["patches"] = _sds((B, fl, STUB_DIM), jnp.bfloat16)
    if cfg.frontend == "audio_stub":
        spec["frames"] = _sds((B, fl, STUB_DIM), jnp.bfloat16)
    return spec


def prefill_batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    fl, tl = frontend_lens(cfg, S)
    spec = {"tokens": _sds((B, tl), jnp.int32)}
    if cfg.frontend == "vision_stub":
        spec["patches"] = _sds((B, fl, STUB_DIM), jnp.bfloat16)
    if cfg.frontend == "audio_stub":
        spec["frames"] = _sds((B, fl, STUB_DIM), jnp.bfloat16)
    return spec


def decode_spec(cfg: ModelConfig, shape: ShapeConfig) -> tuple[dict, dict]:
    """(token spec, cache spec tree) for a serve_step with a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    enc_len = S // 4 if cfg.frontend == "audio_stub" else 0
    cache = jax.eval_shape(
        lambda: tfm.init_cache(cfg, B, S, enc_len=enc_len))
    return {"tokens": _sds((B, 1), jnp.int32)}, cache
