"""Pure-JAX model stack for the assigned architectures."""
from . import attention, config, io_spec, layers, moe, ssm, transformer  # noqa: F401
from .config import SHAPES, ModelConfig, ShapeConfig, cell_applicable  # noqa: F401
