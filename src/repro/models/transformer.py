"""Model assembly for all families: init, train forward, prefill, decode.

Layers are stacked ([L, ...] leading dim) and applied with ``lax.scan`` +
``jax.checkpoint`` (keeps the HLO compact — essential for 80 dry-run compiles
— and implements the activation-recompute policy). The LM head loss is
computed in sequence chunks so the [B, S, vocab] logits tensor never
materializes (vocab up to 256k).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel import constrain, constrain_batch
from . import attention as attn
from . import layers as L
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig

PyTree = Any


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Parameter init (+ spec trees)
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, dtype):
    """One decoder block of the arch family (pre-norm residual)."""
    keys = jax.random.split(key, 8)
    p, s = {}, {}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model, dtype)
        p["attn"], s["attn"] = attn.init(keys[0], cfg, dtype)
        p["ln2"], s["ln2"] = L.rmsnorm_init(cfg.d_model, dtype)
        if fam == "moe":
            p["moe"], s["moe"] = moe_mod.init(keys[1], cfg, dtype)
        else:
            p["mlp"], s["mlp"] = L.swiglu_init(keys[1], cfg.d_model,
                                               cfg.d_ff, dtype)
        if fam == "encdec":
            p["ln3"], s["ln3"] = L.rmsnorm_init(cfg.d_model, dtype)
            p["xattn"], s["xattn"] = attn.init(keys[2], cfg, dtype)
    elif fam in ("ssm", "hybrid"):
        p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model, dtype)
        p["ssm"], s["ssm"] = ssm_mod.init(keys[0], cfg, dtype)
    else:
        raise ValueError(fam)
    return p, s


def _enc_block_init(key, cfg: ModelConfig, dtype):
    keys = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln1"], s["ln1"] = L.rmsnorm_init(cfg.d_model, dtype)
    p["attn"], s["attn"] = attn.init(keys[0], cfg, dtype)
    p["ln2"], s["ln2"] = L.rmsnorm_init(cfg.d_model, dtype)
    p["mlp"], s["mlp"] = L.swiglu_init(keys[1], cfg.d_model, cfg.d_ff, dtype)
    return p, s


def _stack_init(block_init, key, n: int, cfg, dtype):
    """Init n blocks with a vmapped single-block init, stacked on a new
    leading layer dim; specs gain a leading None."""
    keys = jax.random.split(key, n)
    holder = {}

    def params_only(k):
        p, s = block_init(k, cfg, dtype)
        holder["s"] = s           # specs are static python data
        return p

    stacked = jax.vmap(params_only)(keys)
    specs = jax.tree.map(lambda sp: P(*(None,) + tuple(sp)), holder["s"],
                         is_leaf=lambda x: isinstance(x, P))
    return stacked, specs


def init_params(cfg: ModelConfig, key) -> tuple[PyTree, PyTree]:
    dtype = _pdt(cfg)
    k = jax.random.split(key, 10)
    p, s = {}, {}
    p["embed"], s["embed"] = L.embed_init(k[0], cfg.vocab_padded,
                                          cfg.d_model, dtype)
    p["blocks"], s["blocks"] = _stack_init(_block_init, k[1], cfg.n_layers,
                                           cfg, dtype)
    p["lnf"], s["lnf"] = L.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["head"], s["head"] = L.dense_init(k[2], cfg.d_model,
                                            cfg.vocab_padded, dtype,
                                            out_axis="model")
    if cfg.family == "hybrid":
        # one shared attention+MLP block reused every cfg.attn_every layers
        sp, ss_ = {}, {}
        sp["ln1"], ss_["ln1"] = L.rmsnorm_init(cfg.d_model, dtype)
        sp["attn"], ss_["attn"] = attn.init(k[3], cfg, dtype)
        sp["ln2"], ss_["ln2"] = L.rmsnorm_init(cfg.d_model, dtype)
        sp["mlp"], ss_["mlp"] = L.swiglu_init(k[4], cfg.d_model, cfg.d_ff,
                                              dtype)
        p["shared"], s["shared"] = sp, ss_
    if cfg.family == "encdec":
        p["enc_blocks"], s["enc_blocks"] = _stack_init(
            _enc_block_init, k[5], cfg.enc_layers, cfg, dtype)
        p["enc_lnf"], s["enc_lnf"] = L.rmsnorm_init(cfg.d_model, dtype)
    if cfg.frontend == "vision_stub":
        p["projector"], s["projector"] = L.dense_init(
            k[6], 1024, cfg.d_model, dtype, out_axis=None)
    if cfg.frontend == "audio_stub":
        p["projector"], s["projector"] = L.dense_init(
            k[7], 1024, cfg.d_model, dtype, out_axis=None)
    return p, s


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct tree, spec tree) — no allocation (dry-run path).

    Specs are static python data constructed eagerly during tracing, so a
    single eval_shape of init yields both."""
    holder = {}

    def run(key):
        p, s = init_params(cfg, key)
        holder["s"] = s
        return p

    shapes = jax.eval_shape(run, jax.random.PRNGKey(0))
    return shapes, holder["s"]


def param_specs(cfg: ModelConfig) -> PyTree:
    return abstract_params(cfg)[1]


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _apply_block(cfg: ModelConfig, bp, x, positions, dtype, layer_idx=None,
                 shared=None):
    fam = cfg.family
    aux = {}
    _sp = P(("pod", "data"), "model", None)   # sequence-parallel residual
    if fam in ("dense", "moe", "vlm"):
        h, _ = attn.apply_full(bp["attn"], cfg,
                               L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps,
                                               dtype),
                               positions, dtype, causal=True)
        # §Perf C2: pin each branch output to the SP layout so the backward
        # of the row-parallel projection reduce-scatters instead of
        # all-reducing the full [B, S, d] activation gradient
        x = x + constrain(h, _sp)
        z = L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps, dtype)
        if fam == "moe":
            m, aux = moe_mod.apply(bp["moe"], cfg, z, dtype)
        else:
            m = L.swiglu_apply(bp["mlp"], z, dtype)
        x = x + constrain(m, _sp)
    elif fam in ("ssm", "hybrid"):
        h, _ = ssm_mod.apply_full(
            bp["ssm"], cfg, L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps,
                                            dtype), dtype)
        x = x + h
        if fam == "hybrid" and shared is not None and layer_idx is not None:
            def attn_branch(x):
                h, _ = attn.apply_full(
                    shared["attn"], cfg,
                    L.rmsnorm_apply(shared["ln1"], x, cfg.norm_eps, dtype),
                    positions, dtype, causal=True)
                x = x + h
                m = L.swiglu_apply(
                    shared["mlp"],
                    L.rmsnorm_apply(shared["ln2"], x, cfg.norm_eps, dtype),
                    dtype)
                return x + m

            use = (layer_idx % cfg.attn_every) == (cfg.attn_every - 1)
            x = jax.lax.cond(use, attn_branch, lambda x: x, x)
    # sequence-parallel residual stream: the scan carry (saved for remat) is
    # sharded over the model axis on the sequence dim; GSPMD all-gathers at
    # the next block's projections and reduce-scatters after (Megatron SP)
    x = constrain(x, P(("pod", "data"), "model", None))
    return x, aux


def _cast_block(bp, dtype, spec_tree=None):
    """Per-layer master->compute cast (§Perf B4a): keeps ONE layer's
    compute params live inside the scan instead of materializing the cast
    of the whole stack up front (measured 0.79 GB/layer on dbrx-132b).

    ``spec_tree`` (§Perf C3) = the stacked-block specs; each leaf's
    per-layer spec (leading layer dim stripped) is re-asserted so the cast
    is the ZeRO all-gather point and its transpose reduce-scatters the
    gradient — without it GSPMD emits full tuple all-reduces of the block
    grads over every DP axis."""
    def one(t, sp=None):
        if jnp.issubdtype(t.dtype, jnp.inexact):
            t = t.astype(dtype)
            if sp is not None:
                t = constrain(t, P(*tuple(sp)[1:]))
        return t

    if spec_tree is None:
        return jax.tree.map(one, bp)
    sp_leaves = jax.tree.flatten(spec_tree,
                                 is_leaf=lambda s: isinstance(s, P))[0]
    leaves, treedef = jax.tree.flatten(bp)
    return jax.tree.unflatten(
        treedef, [one(t, sp) for t, sp in zip(leaves, sp_leaves)])


def _scan_blocks(cfg, blocks, x, positions, dtype, shared=None,
                 block_specs=None):
    """lax.scan over stacked layers with activation checkpointing."""
    n_layers = jax.tree.leaves(blocks)[0].shape[0]

    def body(carry, inp):
        x, aux_lb, aux_z = carry
        bp, idx = inp
        bp = _cast_block(bp, dtype, block_specs)
        x, aux = _apply_block(cfg, bp, x, positions, dtype, layer_idx=idx,
                              shared=shared)
        aux_lb = aux_lb + aux.get("moe_lb", 0.0)
        aux_z = aux_z + aux.get("moe_z", 0.0)
        return (x, aux_lb, aux_z), None

    # full per-layer remat: only the (sequence-parallel) carry is saved
    body = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux_lb, aux_z), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (blocks, jnp.arange(n_layers)))
    return x, {"moe_lb": aux_lb, "moe_z": aux_z}


# ---------------------------------------------------------------------------
# Losses (chunked over sequence; logits never fully materialized)
# ---------------------------------------------------------------------------


def chunked_ce_loss(cfg, head_w, x, labels, mask, *, chunk: int = 512):
    """x: [B, S, d]; labels, mask: [B, S]. Returns (sum_loss, count)."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    nc = (S + chunk - 1) // chunk
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xs = x.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(B, nc, chunk).transpose(1, 0, 2)

    vpad = head_w.shape[-1]
    # keep the contraction dim (d) unsharded and vocab sharded — critical for
    # tied embeddings whose transpose would otherwise flip the sharding and
    # force a full-vocab all-reduce of the logits
    head_w = constrain(head_w, P(None, "model"))

    def step(carry, inp):
        xc, lc, mc = inp
        logits = (xc @ head_w.astype(xc.dtype)).astype(jnp.float32)
        logits = constrain(logits, P(("pod", "data"), None, "model"))
        if vpad > cfg.vocab:   # mask padded vocab columns
            logits = jnp.where(jnp.arange(vpad) < cfg.vocab, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        loss = jnp.sum((logz - ll) * mc)
        return carry + loss, None

    # §Perf B4b: recompute per-chunk logits in the backward instead of
    # saving [nc, B, chunk, vocab/16] fp32 residuals (1.6 GB/device each)
    step = jax.checkpoint(step)
    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xs, ls, ms))
    return total, jnp.maximum(mask.sum(), 1.0)


def _logits_last(cfg, params, x):
    """Logits for the last position only (decode). x: [B, 1, d]. Padded
    vocab columns are masked so sampling/argmax never picks them."""
    head = params["head"]["w"] if "head" in params else params["embed"]["w"].T
    head = constrain(head, P(None, "model"))
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    if head.shape[-1] > cfg.vocab:
        logits = jnp.where(jnp.arange(head.shape[-1]) < cfg.vocab,
                           logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(cfg, params, batch, dtype):
    """Token (+frontend) embedding. Returns (x, positions, labels, mask)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    x = L.embed_apply(params["embed"], tokens, dtype)
    # keep the embedding gather output d-sharded (§Perf B4c): the table is
    # [vocab, d/16]-sharded, so the local gather result is [B, S, d/16] —
    # without this pin GSPMD materialized the full 25.8 GB activation
    x = constrain(x, P(("pod", "data"), None, "model"))
    labels = batch.get("labels")
    if cfg.frontend == "vision_stub":
        patches = batch["patches"].astype(dtype)           # [B, Pn, 1024]
        proj = L.dense_apply(params["projector"], patches, dtype)
        x = jnp.concatenate([proj, x], axis=1)
        if labels is not None:
            pad = jnp.zeros((B, proj.shape[1]), labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((B, proj.shape[1]), jnp.float32),
                 batch["mask"].astype(jnp.float32)], axis=1)
        else:
            mask = None
    else:
        mask = batch.get("mask")
        mask = mask.astype(jnp.float32) if mask is not None else (
            jnp.ones(tokens.shape, jnp.float32) if labels is not None
            else None)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    return x, positions, labels, mask


def _encode(cfg, params, batch, dtype):
    frames = batch["frames"].astype(dtype)                 # [B, Se, 1024]
    h = L.dense_apply(params["projector"], frames, dtype)
    B, Se, _ = h.shape
    pos = jnp.broadcast_to(jnp.arange(Se)[None, :], (B, Se))

    def body(x, bp):
        bp = _cast_block(bp, dtype)
        a, _ = attn.apply_full(bp["attn"], cfg,
                               L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps,
                                               dtype),
                               pos, dtype, causal=False)
        x = x + a
        m = L.swiglu_apply(bp["mlp"],
                           L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps,
                                           dtype), dtype)
        return x + m, None

    body = jax.checkpoint(
        body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return L.rmsnorm_apply(params["enc_lnf"], h, cfg.norm_eps, dtype)


def _decode_stack_full(cfg, params, x, positions, enc_out, dtype):
    """Enc-dec decoder over full sequences (train)."""
    def body(carry, bp):
        x = carry
        bp = _cast_block(bp, dtype)
        h, _ = attn.apply_full(bp["attn"], cfg,
                               L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps,
                                               dtype),
                               positions, dtype, causal=True)
        x = x + h
        ek, ev = attn.cross_kv(bp["xattn"], cfg, enc_out, dtype)
        x = x + attn.apply_cross(bp["xattn"], cfg,
                                 L.rmsnorm_apply(bp["ln3"], x, cfg.norm_eps,
                                                 dtype), ek, ev, dtype)
        m = L.swiglu_apply(bp["mlp"],
                           L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps,
                                           dtype), dtype)
        return x + m, None

    body = jax.checkpoint(
        body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return x


# ---------------------------------------------------------------------------
# Serving: prefill + decode with caches
# ---------------------------------------------------------------------------


def n_attn_caches(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "ssm":
        return 0
    return cfg.n_layers


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    """Zeroed decode cache for a batch (shapes only matter for dry-run)."""
    dt = _dt(cfg)
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    cache = {}
    na = n_attn_caches(cfg)
    if na:
        cache["k"] = jnp.zeros((na, batch, max_len, KV, hd), dt)
        cache["v"] = jnp.zeros((na, batch, max_len, KV, hd), dt)
        cache["len"] = jnp.zeros((batch,), jnp.int32)
    if cfg.family in ("ssm", "hybrid"):
        ch = cfg.d_inner + 2 * cfg.ssm_state
        cache["conv"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_conv - 1, ch), dt)
        cache["ssm"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state,
             cfg.ssm_head_dim), jnp.float32)
        if cfg.family == "ssm":
            cache["len"] = jnp.zeros((batch,), jnp.int32)
    if cfg.family == "encdec":
        cache["ek"] = jnp.zeros((cfg.n_layers, batch, enc_len, KV, hd), dt)
        cache["ev"] = jnp.zeros((cfg.n_layers, batch, enc_len, KV, hd), dt)
    return cache


def forward_prefill(cfg: ModelConfig, params, batch, max_len: int):
    """Process a prompt; returns (last-position logits, populated cache)."""
    dtype = _dt(cfg)
    if cfg.family == "encdec":
        return _prefill_encdec(cfg, params, batch, max_len)
    x, pos, _, _ = _embed_inputs(cfg, params, batch, dtype)
    x = constrain_batch(x)
    B, S, _ = x.shape
    shared = params.get("shared")
    na = cfg.attn_every if cfg.family == "hybrid" else 1

    def body(carry, inp):
        x = carry
        bp, idx = inp
        ys = {}
        if cfg.family in ("dense", "moe", "vlm"):
            h, (k, v) = attn.apply_full(
                bp["attn"], cfg,
                L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps, dtype),
                pos, dtype, causal=True)
            x = x + h
            z = L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps, dtype)
            if cfg.family == "moe":
                m, _ = moe_mod.apply(bp["moe"], cfg, z, dtype)
            else:
                m = L.swiglu_apply(bp["mlp"], z, dtype)
            x = x + m
            ys = {"k": k.astype(dtype), "v": v.astype(dtype)}
        else:  # ssm / hybrid
            h, st = ssm_mod.apply_full(
                bp["ssm"], cfg,
                L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps, dtype), dtype)
            x = x + h
            ys = {"conv": st["conv"], "ssm": st["ssm"]}
            if cfg.family == "hybrid":
                def attn_branch(x):
                    zq = L.rmsnorm_apply(shared["ln1"], x, cfg.norm_eps,
                                         dtype)
                    h, (k, v) = attn.apply_full(shared["attn"], cfg, zq, pos,
                                                dtype, causal=True)
                    x = x + h
                    m = L.swiglu_apply(
                        shared["mlp"],
                        L.rmsnorm_apply(shared["ln2"], x, cfg.norm_eps,
                                        dtype), dtype)
                    return x + m, k.astype(dtype), v.astype(dtype)

                def skip(x):
                    KV, hd = cfg.n_kv_heads, cfg.head_dim
                    z = jnp.zeros((B, S, KV, hd), dtype)
                    return x, z, z

                use = (idx % cfg.attn_every) == (cfg.attn_every - 1)
                x, k, v = jax.lax.cond(use, attn_branch, skip, x)
                ys["k"], ys["v"] = k, v
        # sequence-parallel residual stream (§Perf A3), same as train path:
        # turns the per-layer full-activation all-reduce into RS+AG and
        # shards the inter-matmul elementwise work over 'model'. SSM/hybrid
        # keep the batch-only layout — the SSD conv/scan over a seq-sharded
        # carry forced per-chunk gathers (measured 4x memory regression).
        if cfg.family in ("ssm", "hybrid"):
            x = constrain(x, P(("pod", "data"), None, None))
        else:
            x = constrain(x, P(("pod", "data"), "model", None))
        return x, ys

    x, ys = jax.lax.scan(body, x, (params["blocks"],
                                   jnp.arange(cfg.n_layers)))
    x = L.rmsnorm_apply(params["lnf"], x, cfg.norm_eps, dtype)
    logits = _logits_last(cfg, params, x[:, -1:, :])

    cache = init_cache(cfg, B, max_len)
    if "k" in ys:
        k, v = ys["k"], ys["v"]              # [L, B, S, KV, hd]
        if cfg.family == "hybrid":           # keep only the attn layers
            sel = np.nonzero(np.arange(cfg.n_layers) % cfg.attn_every ==
                             (cfg.attn_every - 1))[0]
            k = k[sel]
            v = v[sel]
        cache["k"] = cache["k"].at[:, :, :S].set(k)
        cache["v"] = cache["v"].at[:, :, :S].set(v)
    if "conv" in ys:
        cache["conv"] = ys["conv"]
        cache["ssm"] = ys["ssm"]
    cache["len"] = jnp.full((B,), S, jnp.int32)
    return logits, cache


def _prefill_encdec(cfg, params, batch, max_len: int):
    dtype = _dt(cfg)
    enc_out = _encode(cfg, params, batch, dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed_apply(params["embed"], tokens, dtype)
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(x, bp):
        h, (k, v) = attn.apply_full(
            bp["attn"], cfg,
            L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps, dtype),
            pos, dtype, causal=True)
        x = x + h
        ek, ev = attn.cross_kv(bp["xattn"], cfg, enc_out, dtype)
        x = x + attn.apply_cross(bp["xattn"], cfg,
                                 L.rmsnorm_apply(bp["ln3"], x, cfg.norm_eps,
                                                 dtype), ek, ev, dtype)
        m = L.swiglu_apply(bp["mlp"],
                           L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps,
                                           dtype), dtype)
        return x + m, {"k": k.astype(dtype), "v": v.astype(dtype),
                       "ek": ek.astype(dtype), "ev": ev.astype(dtype)}

    x, ys = jax.lax.scan(body, x, params["blocks"])
    x = L.rmsnorm_apply(params["lnf"], x, cfg.norm_eps, dtype)
    logits = _logits_last(cfg, params, x[:, -1:, :])
    cache = init_cache(cfg, B, max_len, enc_len=ys["ek"].shape[2])
    cache["k"] = cache["k"].at[:, :, :S].set(ys["k"])
    cache["v"] = cache["v"].at[:, :, :S].set(ys["v"])
    cache["ek"], cache["ev"] = ys["ek"], ys["ev"]
    cache["len"] = jnp.full((B,), S, jnp.int32)
    return logits, cache


def forward_decode(cfg: ModelConfig, params, token, cache):
    """One decode step. token: [B, 1] int32. Returns (logits, new cache)."""
    dtype = _dt(cfg)
    B = token.shape[0]
    x = L.embed_apply(params["embed"], token, dtype)
    x = constrain_batch(x)
    clen = cache["len"]
    shared = params.get("shared")

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, inp):
            bp, ck, cv = inp
            h, ck, cv = attn.apply_decode(
                bp["attn"], cfg,
                L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps, dtype),
                ck, cv, clen, dtype)
            x = x + h
            z = L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps, dtype)
            if cfg.family == "moe":
                m, _ = moe_mod.apply(bp["moe"], cfg, z, dtype)
            else:
                m = L.swiglu_apply(bp["mlp"], z, dtype)
            return x + m, {"k": ck, "v": cv}

        x, ys = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                       cache["v"]))
        new_cache = {**cache, "k": ys["k"], "v": ys["v"],
                     "len": clen + 1}
    elif cfg.family == "ssm":
        def body(x, inp):
            bp, conv, st = inp
            h, nc = ssm_mod.apply_decode(
                bp["ssm"], cfg,
                L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps, dtype),
                {"conv": conv, "ssm": st}, dtype)
            return x + h, nc

        x, ys = jax.lax.scan(body, x, (params["blocks"], cache["conv"],
                                       cache["ssm"]))
        new_cache = {**cache, "conv": ys["conv"], "ssm": ys["ssm"],
                     "len": clen + 1}
    elif cfg.family == "hybrid":
        ak, av = cache["k"], cache["v"]

        def body(carry, inp):
            x, ak, av = carry
            bp, conv, st, idx = inp
            h, nc = ssm_mod.apply_decode(
                bp["ssm"], cfg,
                L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps, dtype),
                {"conv": conv, "ssm": st}, dtype)
            x = x + h
            aidx = idx // cfg.attn_every

            def attn_branch(args):
                x, ak, av = args
                h, nk, nv = attn.apply_decode(
                    shared["attn"], cfg,
                    L.rmsnorm_apply(shared["ln1"], x, cfg.norm_eps, dtype),
                    ak[aidx], av[aidx], clen, dtype)
                x = x + h
                m = L.swiglu_apply(
                    shared["mlp"],
                    L.rmsnorm_apply(shared["ln2"], x, cfg.norm_eps, dtype),
                    dtype)
                return x + m, ak.at[aidx].set(nk), av.at[aidx].set(nv)

            use = (idx % cfg.attn_every) == (cfg.attn_every - 1)
            x, ak, av = jax.lax.cond(use, attn_branch,
                                     lambda a: a, (x, ak, av))
            return (x, ak, av), nc

        (x, ak, av), ys = jax.lax.scan(
            body, (x, ak, av),
            (params["blocks"], cache["conv"], cache["ssm"],
             jnp.arange(cfg.n_layers)))
        new_cache = {**cache, "conv": ys["conv"], "ssm": ys["ssm"],
                     "k": ak, "v": av, "len": clen + 1}
    elif cfg.family == "encdec":
        def body(x, inp):
            bp, ck, cv, ek, ev = inp
            h, ck, cv = attn.apply_decode(
                bp["attn"], cfg,
                L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps, dtype),
                ck, cv, clen, dtype)
            x = x + h
            x = x + attn.apply_cross(
                bp["xattn"], cfg,
                L.rmsnorm_apply(bp["ln3"], x, cfg.norm_eps, dtype),
                ek.astype(dtype), ev.astype(dtype), dtype)
            m = L.swiglu_apply(bp["mlp"],
                               L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps,
                                               dtype), dtype)
            return x + m, {"k": ck, "v": cv}

        x, ys = jax.lax.scan(body, x, (params["blocks"], cache["k"],
                                       cache["v"], cache["ek"],
                                       cache["ev"]))
        new_cache = {**cache, "k": ys["k"], "v": ys["v"], "len": clen + 1}
    else:
        raise ValueError(cfg.family)

    x = L.rmsnorm_apply(params["lnf"], x, cfg.norm_eps, dtype)
    logits = _logits_last(cfg, params, x)
    return logits, new_cache


def forward_train(cfg: ModelConfig, params, batch):
    """Returns scalar loss (CE + MoE aux)."""
    dtype = _dt(cfg)
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch, dtype)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = L.embed_apply(params["embed"], tokens, dtype)
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x = _decode_stack_full(cfg, params, x, pos, enc_out, dtype)
        aux = {"moe_lb": 0.0, "moe_z": 0.0}
        labels, mask = batch["labels"], batch["mask"].astype(jnp.float32)
    else:
        x, pos, labels, mask = _embed_inputs(cfg, params, batch, dtype)
        x = constrain_batch(x)
        shared = params.get("shared")
        x, aux = _scan_blocks(cfg, params["blocks"], x, pos, dtype,
                              shared=shared,
                              block_specs=param_specs(cfg)["blocks"])
    x = L.rmsnorm_apply(params["lnf"], x, cfg.norm_eps, dtype)
    head = params["head"]["w"] if "head" in params else params["embed"]["w"].T
    total, count = chunked_ce_loss(cfg, head, x, labels, mask)
    loss = total / count
    return loss + 0.01 * aux["moe_lb"] + 0.001 * aux["moe_z"]
