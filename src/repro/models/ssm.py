"""Mamba2 block via SSD (state-space duality, arXiv:2405.21060), pure JAX.

Train/prefill use the chunked SSD algorithm: quadratic attention-like compute
inside chunks of length Q plus a linear inter-chunk state recurrence —
sub-quadratic overall and scan-friendly. Decode is the O(1) recurrent update
on the [B, H, P, N] state (the long_500k cells).

Simplifications vs the reference CUDA implementation (documented): a single
B/C group (G=1), scalar-per-head A, no D skip-connection bias term beyond the
standard D·x, RMSNorm gate as in Mamba2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import layers as L


def init(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    conv = cfg.ssm_conv
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(d)
    # in_proj produces [z (di), x (di), B (N), C (N), dt (H)]
    p = {
        "in_proj": jax.random.uniform(k1, (d, 2 * di + 2 * N + H), dtype,
                                      -scale, scale),
        "conv_w": jax.random.uniform(k2, (conv, di + 2 * N), dtype,
                                     -0.5, 0.5) / conv,
        "conv_b": jnp.zeros((di + 2 * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_g": jnp.ones((di,), dtype),
        "out_proj": jax.random.uniform(k3, (di, d), dtype,
                                       -1.0 / np.sqrt(di), 1.0 / np.sqrt(di)),
    }
    s = {
        "in_proj": P(None, "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm_g": P("model"),
        "out_proj": P("model", None),
    }
    return p, s


def _split_proj(cfg, zxbcdt):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, conv_state=None):
    """Depthwise causal conv along S. xbc: [B, S, Cch]; w: [K, Cch]."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)                  # [B, K-1, Cch]
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i][None, None, :]
              for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return jax.nn.silu(out + b[None, None, :]), new_state


def _ssd_chunked(cfg, xh, dt, Bc, Cc, A):
    """Chunked SSD scan.

    xh: [B, S, H, P]; dt: [B, S, H] (softplus'd); Bc, Cc: [B, S, N];
    A: [H] (negative). Returns y: [B, S, H, P].
    """
    Bsz, S, H, Pd = xh.shape
    N = Bc.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    nq = (S + Q - 1) // Q
    pad = nq * Q - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    # chunk views [B, nq, Q, ...]
    xh = xh.reshape(Bsz, nq, Q, H, Pd)
    dt = dt.reshape(Bsz, nq, Q, H)
    Bc = Bc.reshape(Bsz, nq, Q, N)
    Cc = Cc.reshape(Bsz, nq, Q, N)

    da = dt * A[None, None, None, :]                     # [B,nq,Q,H] (<=0)
    cums = jnp.cumsum(da, axis=2)                        # within-chunk csum
    seg_end = cums[:, :, -1, :]                          # [B,nq,H]

    # ---- intra-chunk (quadratic in Q) ----
    # L[b,c,h,i,j] = exp(cums_i - cums_j) for i >= j
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # [B,nq,Q,Q,H]
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    # double-where: masked (i<j) entries have diff > 0 and would overflow in
    # exp, poisoning gradients through the outer where
    diff = jnp.where(causal, diff, 0.0)
    Lmat = jnp.where(causal, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)       # [B,nq,Q,Q]
    M = scores[..., None] * Lmat                          # [B,nq,Q,Q,H]
    xdt = xh * dt[..., None]                              # [B,nq,Q,H,P]
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", M, xdt)

    # ---- chunk states + inter-chunk recurrence ----
    decay_to_end = jnp.exp(seg_end[:, :, None, :] - cums)  # [B,nq,Q,H]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                        Bc, dt * decay_to_end, xh)          # [B,nq,H,N,P]

    def scan_fn(h_prev, inp):
        st, dend = inp                                     # [B,H,N,P], [B,H]
        h_new = h_prev * jnp.exp(dend)[..., None, None] + st
        return h_new, h_prev

    states_t = states.transpose(1, 0, 2, 3, 4)             # [nq,B,H,N,P]
    dend_t = seg_end.transpose(1, 0, 2)                    # [nq,B,H]
    h0 = jnp.zeros_like(states_t[0])
    _, h_prevs = jax.lax.scan(scan_fn, h0, (states_t, dend_t))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)             # [B,nq,H,N,P]

    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                       Cc, jnp.exp(cums), h_prevs)
    y = (y_diag + y_off).reshape(Bsz, nq * Q, H, Pd)[:, :S]
    return y


def apply_full(p, cfg, x, dtype):
    """Train / prefill. x: [B, S, d] -> (y, cache) with the final SSM and
    conv states (prefill hands them to decode)."""
    B, S, d = x.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x.astype(dtype) @ p["in_proj"].astype(dtype)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(dtype),
                                   p["conv_b"].astype(dtype))
    xh = xbc[..., :di].reshape(B, S, H, Pd).astype(jnp.float32)
    Bc = xbc[..., di:di + N].astype(jnp.float32)
    Cc = xbc[..., di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y = _ssd_chunked(cfg, xh, dt, Bc, Cc, A)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(dtype)
    # gated RMSNorm (Mamba2)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) +
                            cfg.norm_eps)).astype(dtype) * \
        p["norm_g"].astype(dtype)
    out = y @ p["out_proj"].astype(dtype)

    # final SSM state for decode handoff (recompute from last chunk is free
    # inside jit; here we run the recurrence once more over the last chunk)
    ssm_state = _final_state(cfg, xh, dt, Bc, A)
    return out, {"conv": conv_state, "ssm": ssm_state}


def _final_state(cfg, xh, dt, Bc, A):
    """h(S) = sum_j exp(sum_{i>j} da_i) dt_j B_j x_j  — [B, H, N, P]."""
    da = dt * A[None, None, :]
    total = da.sum(axis=1, keepdims=True)
    decay = jnp.exp(total - jnp.cumsum(da, axis=1))        # [B,S,H]
    return jnp.einsum("bsn,bsh,bshp->bhnp", Bc, dt * decay, xh)


def apply_decode(p, cfg, x, cache, dtype):
    """Single-token decode. x: [B, 1, d]; cache {'conv': [B,K-1,ch],
    'ssm': [B,H,N,P]} -> (y, new_cache)."""
    B = x.shape[0]
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x.astype(dtype) @ p["in_proj"].astype(dtype)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(dtype),
                                   p["conv_b"].astype(dtype),
                                   conv_state=cache["conv"])
    xh = xbc[:, 0, :di].reshape(B, H, Pd).astype(jnp.float32)
    Bc = xbc[:, 0, di:di + N].astype(jnp.float32)
    Cc = xbc[:, 0, di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) +
                         p["dt_bias"][None, :])            # [B,H]
    A = -jnp.exp(p["A_log"])
    h = cache["ssm"]                                       # [B,H,N,P]
    decay = jnp.exp(dt * A[None, :])                       # [B,H]
    h = h * decay[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bc, dt, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cc, h) + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) +
                            cfg.norm_eps)).astype(dtype) * \
        p["norm_g"].astype(dtype)
    out = y @ p["out_proj"].astype(dtype)
    return out, {"conv": conv_state, "ssm": h}
