"""Pallas TPU kernel for baseline SELL-C-σ SpMV (paper §3, cuSELL analogue).

Identical tiling to the PackSELL kernel so benchmark deltas isolate the
format difference: SELL moves (value_bytes + 4) per stored element across two
arrays; PackSELL moves 4 bytes from one array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat


def _kernel(val_ref, col_ref, x_ref, y_ref, acc_ref, *, nw: int, wb: int):
    wi = pl.program_id(1)

    @pl.when(wi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc = acc_ref[...]
    val = val_ref[...]              # [SB, WB, C]
    col = col_ref[...]              # [SB, WB, C] int32
    x = x_ref[...]
    mlim = np.int32(x.shape[0] - 1)

    def body(j, acc):
        v = val[:, j, :].astype(jnp.float32)
        idx = jnp.minimum(col[:, j, :], mlim)
        xv = jnp.take(x, idx.reshape(-1), axis=0).reshape(idx.shape)
        return acc + v * xv

    acc = jax.lax.fori_loop(0, wb, body, acc)
    acc_ref[...] = acc

    @pl.when(wi == nw - 1)
    def _fin():
        y_ref[...] = acc


def sell_spmv_bucket(val: jnp.ndarray, col: jnp.ndarray, x: jnp.ndarray, *,
                     sb: int = 8, wb: int = 32,
                     interpret: bool = True) -> jnp.ndarray:
    S, w, C = val.shape
    s_pad = -S % sb
    w_pad = -w % wb
    if s_pad or w_pad:
        val = jnp.pad(val, ((0, s_pad), (0, w_pad), (0, 0)))
        col = jnp.pad(col, ((0, s_pad), (0, w_pad), (0, 0)))
    Sp, wp, _ = val.shape
    m_pad = -x.shape[0] % 128
    xp = jnp.pad(x.astype(jnp.float32), (0, m_pad))
    nw = wp // wb
    grid = (Sp // sb, nw)

    kernel = functools.partial(_kernel, nw=nw, wb=wb)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb, wb, C), lambda si, wi: (si, wi, 0)),
            pl.BlockSpec((sb, wb, C), lambda si, wi: (si, wi, 0)),
            pl.BlockSpec((xp.shape[0],), lambda si, wi: (0,)),
        ],
        out_specs=pl.BlockSpec((sb, C), lambda si, wi: (si, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((sb, C), jnp.float32)],
        compiler_params=compat.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
        name="sell_spmv",
    )(val, col, xp)
    return y[:S]
