"""Jit'd public wrappers around the Pallas SpMV kernels.

``packsell_spmv(mat, x)`` picks the band-windowed kernel automatically when
every slice-block's column span fits the half-window budget (the paper's
banded/RCM regime), otherwise runs the full-x-in-VMEM kernel, and finally
applies the σ-permutation scatter (paper §4.4 line 15, done once outside the
kernel exactly as implicit SELL-C-σ prescribes).

On non-TPU backends the kernels execute with ``interpret=True`` (kernel body
evaluated in Python/XLA on CPU) — numerically identical, used by the test
suite to validate against the pure-jnp oracles in ``ref.py``.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packsell import PackSELLMatrix
from repro.core.sell import SELLMatrix
from . import packsell_spmv as _pk
from . import sell_spmv as _sk

# VMEM budget for a full x residency (fp32 elements)
_FULL_X_LIMIT = int(os.environ.get("REPRO_FULL_X_LIMIT", 2_000_000))
_DEF_HW = 4096  # default half-window (elements, multiple of 128)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def band_plan(mat: PackSELLMatrix, sb: int, hw: int):
    """Host-side: per-bucket window ids (half-window units) if the band kernel
    is feasible for every slice-block, else None.

    Feasibility needs column locality *within each sb-slice block*; width
    bucketing can interleave distant slices, so banded matrices should be
    built with ``bucket_strategy='uniform'`` (contiguous slices) when the
    band kernel is desired — cheap in the low-RSD regime the paper targets.
    """
    wins = []
    for d0, maxcol in zip(mat.d0s, mat.maxcols):
        d0 = np.asarray(d0)
        mc = np.asarray(maxcol)
        S = len(d0)
        s_pad = -S % sb
        if s_pad:
            d0 = np.concatenate([d0, np.full(s_pad, d0[-1] if S else 0,
                                             np.int32)])
            mc = np.concatenate([mc, np.full(s_pad, mc[-1] if S else 0,
                                             np.int32)])
        d0b = d0.reshape(-1, sb).min(axis=1)
        mcb = mc.reshape(-1, sb).max(axis=1)
        win = d0b // hw
        if np.any(mcb - win * hw >= 2 * hw):
            return None
        wins.append(win.astype(np.int32))
    return wins


def packsell_spmv(mat: PackSELLMatrix, x: jnp.ndarray, *, sb: int = 8,
                  wb: int = 32, hw: int = _DEF_HW,
                  interpret: bool | None = None,
                  force: str | None = None) -> jnp.ndarray:
    """y = A @ x via the Pallas kernel. ``force`` in {None,'full','band'}."""
    interpret = _interpret_default() if interpret is None else interpret
    wins = None
    if force != "full" and mat.m > 0:
        wins = band_plan(mat, sb, hw)
    if force == "band" and wins is None:
        raise ValueError("band kernel infeasible for this matrix/hw")
    use_band = wins is not None and (force == "band" or mat.m > _FULL_X_LIMIT
                                     or force is None)
    # default policy: prefer band when feasible (it bounds VMEM); tests
    # exercise both paths explicitly via `force`.
    y = jnp.zeros((mat.n,), dtype=jnp.float32)
    for b, (pack, d0, outrow) in enumerate(
            zip(mat.packs, mat.d0s, mat.outrows)):
        if use_band:
            t = _pk.packsell_spmv_band_bucket(
                pack, d0, jnp.asarray(wins[b]), x, codec_name=mat.codec_name,
                D=mat.D, hw=hw, sb=sb, wb=wb, interpret=interpret)
        else:
            if mat.m > _FULL_X_LIMIT:
                raise ValueError(
                    f"x too large for VMEM residency ({mat.m}) and band "
                    f"kernel infeasible; increase hw or use jnp path")
            t = _pk.packsell_spmv_bucket(
                pack, d0, x, codec_name=mat.codec_name, D=mat.D, sb=sb,
                wb=wb, interpret=interpret)
        y = y.at[outrow].set(t.reshape(-1), mode="drop")
    return y


def sell_spmv(mat: SELLMatrix, x: jnp.ndarray, *, sb: int = 8, wb: int = 32,
              interpret: bool | None = None) -> jnp.ndarray:
    interpret = _interpret_default() if interpret is None else interpret
    y = jnp.zeros((mat.n,), dtype=jnp.float32)
    for val, col, outrow in zip(mat.vals, mat.cols, mat.outrows):
        t = _sk.sell_spmv_bucket(val, col, x, sb=sb, wb=wb,
                                 interpret=interpret)
        y = y.at[outrow].set(t.reshape(-1), mode="drop")
    return y
