"""Jit'd public wrappers around the Pallas SpMV kernels.

``packsell_spmv(mat, x)`` routes through the :mod:`repro.kernels.plan`
execution engine: a cached :class:`~repro.kernels.plan.SpMVPlan` carries the
host-side decisions (band feasibility/windows, tile parameters, kernel
variant) and a jitted dispatch function, so repeated matvecs never re-plan or
re-trace. The σ-permutation scatter (paper §4.4 line 15) is applied once over
the concatenated bucket outputs — or skipped entirely with ``permuted=True``.

Variant policy is explicit (logged in ``plan.policy``) and overridable via
``force=`` or the ``REPRO_SPMV_POLICY`` env var (``auto|fused|full|band|jnp``).

On non-TPU backends the Pallas kernels execute with ``interpret=True``
(kernel body evaluated in Python/XLA on CPU) — numerically identical, used by
the test suite to validate against the pure-jnp oracles in ``ref.py``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packsell import PackSELLMatrix
from repro.core.sell import SELLMatrix
from . import plan as _plan
from . import sell_spmv as _sk

# Re-exported for band feasibility probing (tests, benchmarks).
band_plan = _plan.band_plan
_FULL_X_LIMIT = _plan._FULL_X_LIMIT
_DEF_HW = _plan._DEF_HW


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _debug_check_finite(x) -> None:
    """Opt-in input screen (``REPRO_DEBUG_FINITE=1``): reject NaN/Inf in
    x BEFORE it enters the packed kernels, where a poisoned entry smears
    into every output row touching its column. Host-side only — skipped
    for tracers (inside jit the guard layer owns detection)."""
    if os.environ.get("REPRO_DEBUG_FINITE", "0") != "1":
        return
    if isinstance(x, jax.core.Tracer):
        return
    xh = np.asarray(x)
    if not np.all(np.isfinite(xh)):
        bad = int(np.count_nonzero(~np.isfinite(xh)))
        raise FloatingPointError(
            f"packsell_spmv: input x has {bad} non-finite (NaN/Inf) "
            "entries (REPRO_DEBUG_FINITE=1)")


def packsell_spmv(mat: PackSELLMatrix, x: jnp.ndarray, *, sb: int = 8,
                  wb: int = 32, hw: int = _DEF_HW,
                  interpret: bool | None = None,
                  force: str | None = None,
                  decode_cache: str | None = None,
                  permuted: bool = False) -> jnp.ndarray:
    """y = A @ x via the plan engine (single jitted dispatch).

    ``force`` in {None, 'full', 'band', 'jnp'} pins the kernel variant;
    ``decode_cache`` in {None, 'checkpoint', 'full', '0'} pins the plan's
    decode-cache layout (default: ``REPRO_PLAN_CURSOR_CACHE``);
    ``permuted=True`` returns y in stored-row order (no σ-scatter).
    """
    _debug_check_finite(x)
    plan = _plan.get_plan(mat, sb=sb, wb=wb, hw=hw, force=force,
                          interpret=interpret, decode_cache=decode_cache)
    return plan.spmv(mat, x, permuted=permuted)


def packsell_spmm(mat: PackSELLMatrix, x: jnp.ndarray, *, sb: int = 8,
                  wb: int = 32, hw: int = _DEF_HW,
                  interpret: bool | None = None,
                  force: str | None = None,
                  decode_cache: str | None = None,
                  permuted: bool = False) -> jnp.ndarray:
    """Y = A @ X for X: [m, nb] via the multi-RHS kernel (one pass over the
    packed words for all nb right-hand sides)."""
    if x.ndim != 2:
        raise ValueError(f"packsell_spmm expects x of shape [m, nb], got "
                         f"{x.shape}; use packsell_spmv for a single RHS")
    plan = _plan.get_plan(mat, sb=sb, wb=wb, hw=hw, force=force,
                          interpret=interpret, decode_cache=decode_cache)
    return plan.spmm(mat, x, permuted=permuted)


def sell_spmv(mat: SELLMatrix, x: jnp.ndarray, *, sb: int = 8, wb: int = 32,
              interpret: bool | None = None) -> jnp.ndarray:
    interpret = _interpret_default() if interpret is None else interpret
    parts = []
    for val, col in zip(mat.vals, mat.cols):
        t = _sk.sell_spmv_bucket(val, col, x, sb=sb, wb=wb,
                                 interpret=interpret)
        parts.append(t.reshape(-1))
    y = jnp.zeros((mat.n,), dtype=jnp.float32)
    if not parts:
        return y
    t_cat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    outrow_cat = jnp.concatenate([o.reshape(-1) for o in mat.outrows])
    return y.at[outrow_cat].set(t_cat, mode="drop")
