"""Pallas TPU kernel for PackSELL SpMV (paper §4.4, TPU-adapted).

Grid = (slice_blocks, width_blocks). Each kernel instance owns a
``[SB, WB, C]`` VMEM tile of packed words (C = slice size = 128 lanes by
default, SB slices stack on the sublane dimension → word tiles are
VREG-aligned).

Two cursor regimes (DESIGN.md §10.2):

* **legacy carry** (``ckpt=None``) — the column cursor ``c`` and the
  accumulator carry across the width dimension in VMEM scratch (the classic
  reduction-grid pattern): width blocks are a *sequential* carry chain.
* **checkpoint-seeded** (``ckpt=int32[S, nw, C]`` from
  ``plan.py::_build_block_checkpoints``) — each width block seeds its
  cursor from the checkpoint ref instead of the previous block's scratch,
  so width blocks have no data dependence on each other: the width grid
  dimension becomes **parallel**, each block writes its own partial output
  tile and the wrapper reduces over width blocks outside the kernel. No
  cursor scratch, no carry chain.

Unpacking is the paper's branch-free sequence on int32 VREGs (VPU); the MXU
is deliberately unused (SpMV is memory-bound; see DESIGN.md §2).

Two x-delivery variants:

* ``full-x``  — the dense input vector is resident in VMEM (fits for
  n ≲ 1–2M fp32 on a 16 MB VMEM part after tiling the pack stream).
* ``band``    — for RCM/banded matrices (the paper's main regime) only an
  ``XW``-wide window of ``x`` is prefetched per slice-block, selected via a
  scalar-prefetched window id (HBM→VMEM streaming; the GPU kernel gets the
  same effect implicitly through L2).

A third kernel family (:func:`packsell_spmv_fused` / spmm twin) consumes
the plan engine's **fused ragged checkpoint stream** (DESIGN.md §10/§14)
instead of the per-bucket packs: ``uint32[G, wr, C]`` words whose offsets
were prefix-summed at build time and re-based to the per-group ``int32[G,
C]`` checkpoint, so the in-kernel column reconstruction is ONE add per
word (dummy-word chains are already folded into the offsets) and the
group grid axis is embarrassingly parallel. The word decode itself is
:func:`fused_decode_word` — the single definition the jnp fused body
(``plan._fused_decode``) delegates to, so kernel/XLA bit-parity holds by
construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import codecs as cd
from . import compat


def _unpack(words: jnp.ndarray, codec: cd.Codec, D: int):
    """Branch-free unpack on uint32 VREGs (paper Fig. 3b)."""
    return cd.unpack_words_jnp(words, codec, D)


def _pad_ckpt(ckpt: jnp.ndarray, s_pad: int) -> jnp.ndarray:
    """Pad the slice axis of a width-block checkpoint (padded slices hold
    PAD words only: any in-range cursor works, 0 is fine)."""
    if s_pad:
        ckpt = jnp.pad(ckpt, ((0, s_pad), (0, 0), (0, 0)))
    return ckpt


# ---------------------------------------------------------------------------
# full-x variant
# ---------------------------------------------------------------------------


def _kernel_full(d0_ref, pack_ref, x_ref, y_ref, c_ref, acc_ref, *,
                 codec_name: str, D: int, nw: int, wb: int):
    codec = cd.make_codec(codec_name)
    wi = pl.program_id(1)

    @pl.when(wi == 0)
    def _init():
        c_ref[...] = jnp.broadcast_to(
            d0_ref[...][:, None], c_ref.shape).astype(jnp.int32)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = c_ref[...]
    acc = acc_ref[...]
    pack = pack_ref[...]            # [SB, WB, C] uint32
    x = x_ref[...]                  # [m_pad] f32
    mlim = np.int32(x.shape[0] - 1)

    def body(j, carry):
        c, acc = carry
        v, d = _unpack(pack[:, j, :], codec, D)
        c = c + d.astype(jnp.int32)
        xv = jnp.take(x, jnp.minimum(c, mlim).reshape(-1), axis=0,
                      mode="clip").reshape(c.shape)
        return c, acc + v.astype(jnp.float32) * xv

    c, acc = jax.lax.fori_loop(0, wb, body, (c, acc))
    c_ref[...] = c
    acc_ref[...] = acc

    @pl.when(wi == nw - 1)
    def _fin():
        y_ref[...] = acc


def _kernel_full_ckpt(ckpt_ref, pack_ref, x_ref, y_ref, *,
                      codec_name: str, D: int, wb: int):
    """Checkpoint-seeded full-x kernel: no scratch, no carry — each
    (si, wi) instance is independent and writes its own partial tile."""
    codec = cd.make_codec(codec_name)
    c = ckpt_ref[...].reshape(ckpt_ref.shape[0], ckpt_ref.shape[2])
    pack = pack_ref[...]            # [SB, WB, C] uint32
    x = x_ref[...]                  # [m_pad] f32
    mlim = np.int32(x.shape[0] - 1)
    acc = jnp.zeros(c.shape, jnp.float32)

    def body(j, carry):
        c, acc = carry
        v, d = _unpack(pack[:, j, :], codec, D)
        c = c + d.astype(jnp.int32)
        xv = jnp.take(x, jnp.minimum(c, mlim).reshape(-1), axis=0,
                      mode="clip").reshape(c.shape)
        return c, acc + v.astype(jnp.float32) * xv

    _, acc = jax.lax.fori_loop(0, wb, body, (c, acc))
    y_ref[...] = acc[None]


def packsell_spmv_bucket(pack: jnp.ndarray, d0: jnp.ndarray, x: jnp.ndarray,
                         *, codec_name: str, D: int, sb: int = 8,
                         wb: int = 32, interpret: bool = True,
                         ckpt: jnp.ndarray | None = None) -> jnp.ndarray:
    """Run the full-x kernel over one width bucket. Returns y in stored-row
    order, shape [S, C] float32. Caller applies the σ-permutation gather.

    ``ckpt`` (int32 [S, nw, C], cursor before word ``wi*wb``) switches to
    the checkpoint-seeded kernel: width blocks run grid-parallel and the
    wrapper sums their partial tiles."""
    S, w, C = pack.shape
    s_pad = -S % sb
    w_pad = -w % wb
    if s_pad or w_pad:
        pack = jnp.pad(pack, ((0, s_pad), (0, w_pad), (0, 0)))
        d0 = jnp.pad(d0, (0, s_pad))
    Sp, wp, _ = pack.shape
    m_pad = -x.shape[0] % 128
    xp = jnp.pad(x.astype(jnp.float32), (0, m_pad))
    nw = wp // wb
    grid = (Sp // sb, nw)

    if ckpt is not None:
        kernel = functools.partial(_kernel_full_ckpt, codec_name=codec_name,
                                   D=D, wb=wb)
        y = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((sb, 1, C), lambda si, wi: (si, wi, 0)),
                pl.BlockSpec((sb, wb, C), lambda si, wi: (si, wi, 0)),
                pl.BlockSpec((xp.shape[0],), lambda si, wi: (0,)),
            ],
            out_specs=pl.BlockSpec((1, sb, C), lambda si, wi: (wi, si, 0)),
            out_shape=jax.ShapeDtypeStruct((nw, Sp, C), jnp.float32),
            compiler_params=compat.compiler_params("parallel", "parallel"),
            interpret=interpret,
            name=f"packsell_spmv_ckpt_{codec_name}_D{D}",
        )(_pad_ckpt(ckpt, s_pad), pack, xp)
        return (y[0] if nw == 1 else jnp.sum(y, axis=0))[:S]

    kernel = functools.partial(_kernel_full, codec_name=codec_name, D=D,
                               nw=nw, wb=wb)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb,), lambda si, wi: (si,)),
            pl.BlockSpec((sb, wb, C), lambda si, wi: (si, wi, 0)),
            pl.BlockSpec((xp.shape[0],), lambda si, wi: (0,)),
        ],
        out_specs=pl.BlockSpec((sb, C), lambda si, wi: (si, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((sb, C), jnp.int32),
                        pltpu.VMEM((sb, C), jnp.float32)],
        compiler_params=compat.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
        name=f"packsell_spmv_{codec_name}_D{D}",
    )(d0, pack, xp)
    return y[:S]


# ---------------------------------------------------------------------------
# band variant
# ---------------------------------------------------------------------------


def _kernel_band(win_ref, d0_ref, pack_ref, xlo_ref, xhi_ref, y_ref, c_ref,
                 acc_ref, *, codec_name: str, D: int, nw: int, wb: int,
                 hw: int):
    """Band variant. The x window is two consecutive half-windows of ``hw``
    elements starting at element ``win[si] * hw`` (delivered as two (1, hw)
    blocks of the same array so the window can slide at half-window
    granularity with plain Blocked indexing); coverage is guaranteed by the
    wrapper when the slice-block's column span fits in ``hw`` elements."""
    codec = cd.make_codec(codec_name)
    si = pl.program_id(0)
    wi = pl.program_id(1)

    @pl.when(wi == 0)
    def _init():
        c_ref[...] = jnp.broadcast_to(
            d0_ref[...][:, None], c_ref.shape).astype(jnp.int32)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = c_ref[...]
    acc = acc_ref[...]
    pack = pack_ref[...]
    x = jnp.concatenate([xlo_ref[...].reshape(-1),
                         xhi_ref[...].reshape(-1)])   # [2*hw] window
    base = win_ref[si] * np.int32(hw)
    lim = np.int32(2 * hw - 1)

    def body(j, carry):
        c, acc = carry
        v, d = _unpack(pack[:, j, :], codec, D)
        c = c + d.astype(jnp.int32)
        local = jnp.clip(c - base, 0, lim)
        xv = jnp.take(x, local.reshape(-1), axis=0,
                      mode="clip").reshape(c.shape)
        return c, acc + v.astype(jnp.float32) * xv

    c, acc = jax.lax.fori_loop(0, wb, body, (c, acc))
    c_ref[...] = c
    acc_ref[...] = acc

    @pl.when(wi == nw - 1)
    def _fin():
        y_ref[...] = acc


def _kernel_band_ckpt(win_ref, ckpt_ref, pack_ref, xlo_ref, xhi_ref, y_ref,
                      *, codec_name: str, D: int, wb: int, hw: int):
    """Checkpoint-seeded band kernel: width blocks grid-parallel, partial
    tiles reduced by the wrapper."""
    codec = cd.make_codec(codec_name)
    si = pl.program_id(0)
    c = ckpt_ref[...].reshape(ckpt_ref.shape[0], ckpt_ref.shape[2])
    pack = pack_ref[...]
    x = jnp.concatenate([xlo_ref[...].reshape(-1),
                         xhi_ref[...].reshape(-1)])   # [2*hw] window
    base = win_ref[si] * np.int32(hw)
    lim = np.int32(2 * hw - 1)
    acc = jnp.zeros(c.shape, jnp.float32)

    def body(j, carry):
        c, acc = carry
        v, d = _unpack(pack[:, j, :], codec, D)
        c = c + d.astype(jnp.int32)
        local = jnp.clip(c - base, 0, lim)
        xv = jnp.take(x, local.reshape(-1), axis=0,
                      mode="clip").reshape(c.shape)
        return c, acc + v.astype(jnp.float32) * xv

    _, acc = jax.lax.fori_loop(0, wb, body, (c, acc))
    y_ref[...] = acc[None]


def packsell_spmv_band_bucket(pack: jnp.ndarray, d0: jnp.ndarray,
                              win: jnp.ndarray, x: jnp.ndarray, *,
                              codec_name: str, D: int, hw: int, sb: int = 8,
                              wb: int = 32, interpret: bool = True,
                              ckpt: jnp.ndarray | None = None
                              ) -> jnp.ndarray:
    """Band-windowed variant: ``win[si]`` (scalar-prefetched, so the x DMA
    can be issued ahead of the pack tiles) selects a 2×hw element window of
    x for slice-block ``si``: elements [win*hw, win*hw + 2*hw). The wrapper
    guarantees each slice-block's column span fits within hw, so coverage is
    exact regardless of alignment. ``ckpt`` as in
    :func:`packsell_spmv_bucket`."""
    S, w, C = pack.shape
    s_pad = -S % sb
    w_pad = -w % wb
    if s_pad or w_pad:
        pack = jnp.pad(pack, ((0, s_pad), (0, w_pad), (0, 0)))
        d0 = jnp.pad(d0, (0, s_pad))
    Sp, wp, _ = pack.shape
    # pad x to a whole number of half-windows plus one slack half-window
    x_pad = (-x.shape[0]) % hw + hw
    xp = jnp.pad(x.astype(jnp.float32), (0, x_pad)).reshape(-1, hw)
    nw = wp // wb
    grid = (Sp // sb, nw)

    if ckpt is not None:
        kernel = functools.partial(_kernel_band_ckpt, codec_name=codec_name,
                                   D=D, wb=wb, hw=hw)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((sb, 1, C), lambda si, wi, win: (si, wi, 0)),
                pl.BlockSpec((sb, wb, C), lambda si, wi, win: (si, wi, 0)),
                pl.BlockSpec((1, hw), lambda si, wi, win: (win[si], 0)),
                pl.BlockSpec((1, hw), lambda si, wi, win: (win[si] + 1, 0)),
            ],
            out_specs=pl.BlockSpec((1, sb, C),
                                   lambda si, wi, win: (wi, si, 0)),
        )
        y = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((nw, Sp, C), jnp.float32),
            compiler_params=compat.compiler_params("parallel", "parallel"),
            interpret=interpret,
            name=f"packsell_spmv_band_ckpt_{codec_name}_D{D}",
        )(win, _pad_ckpt(ckpt, s_pad), pack, xp, xp)
        return (y[0] if nw == 1 else jnp.sum(y, axis=0))[:S]

    kernel = functools.partial(_kernel_band, codec_name=codec_name, D=D,
                               nw=nw, wb=wb, hw=hw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb,), lambda si, wi, win: (si,)),
            pl.BlockSpec((sb, wb, C), lambda si, wi, win: (si, wi, 0)),
            pl.BlockSpec((1, hw), lambda si, wi, win: (win[si], 0)),
            pl.BlockSpec((1, hw), lambda si, wi, win: (win[si] + 1, 0)),
        ],
        out_specs=pl.BlockSpec((sb, C), lambda si, wi, win: (si, 0)),
        scratch_shapes=[pltpu.VMEM((sb, C), jnp.int32),
                        pltpu.VMEM((sb, C), jnp.float32)],
    )
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Sp, C), jnp.float32),
        compiler_params=compat.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
        name=f"packsell_spmv_band_{codec_name}_D{D}",
    )(win, d0, pack, xp, xp)
    return y[:S]


# ---------------------------------------------------------------------------
# multi-RHS variant
# ---------------------------------------------------------------------------


def _kernel_spmm(d0_ref, pack_ref, x_ref, y_ref, c_ref, acc_ref, *,
                 codec_name: str, D: int, nw: int, wb: int):
    """Multi-RHS variant of :func:`_kernel_full`: one walk over the packed
    words feeds all nb right-hand sides (nb× arithmetic intensity — the
    block-Krylov / batched-serving regime)."""
    codec = cd.make_codec(codec_name)
    wi = pl.program_id(1)

    @pl.when(wi == 0)
    def _init():
        c_ref[...] = jnp.broadcast_to(
            d0_ref[...][:, None], c_ref.shape).astype(jnp.int32)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = c_ref[...]
    acc = acc_ref[...]
    pack = pack_ref[...]            # [SB, WB, C] uint32
    x = x_ref[...]                  # [m_pad, nb] f32
    mlim = np.int32(x.shape[0] - 1)
    nb = x.shape[1]

    def body(j, carry):
        c, acc = carry
        v, d = _unpack(pack[:, j, :], codec, D)
        c = c + d.astype(jnp.int32)
        xv = jnp.take(x, jnp.minimum(c, mlim).reshape(-1), axis=0,
                      mode="clip").reshape(c.shape + (nb,))
        return c, acc + v.astype(jnp.float32)[..., None] * xv

    c, acc = jax.lax.fori_loop(0, wb, body, (c, acc))
    c_ref[...] = c
    acc_ref[...] = acc

    @pl.when(wi == nw - 1)
    def _fin():
        y_ref[...] = acc


def _kernel_spmm_ckpt(ckpt_ref, pack_ref, x_ref, y_ref, *,
                      codec_name: str, D: int, wb: int):
    codec = cd.make_codec(codec_name)
    c = ckpt_ref[...].reshape(ckpt_ref.shape[0], ckpt_ref.shape[2])
    pack = pack_ref[...]            # [SB, WB, C] uint32
    x = x_ref[...]                  # [m_pad, nb] f32
    mlim = np.int32(x.shape[0] - 1)
    nb = x.shape[1]
    acc = jnp.zeros(c.shape + (nb,), jnp.float32)

    def body(j, carry):
        c, acc = carry
        v, d = _unpack(pack[:, j, :], codec, D)
        c = c + d.astype(jnp.int32)
        xv = jnp.take(x, jnp.minimum(c, mlim).reshape(-1), axis=0,
                      mode="clip").reshape(c.shape + (nb,))
        return c, acc + v.astype(jnp.float32)[..., None] * xv

    _, acc = jax.lax.fori_loop(0, wb, body, (c, acc))
    y_ref[...] = acc[None]


def packsell_spmm_bucket(pack: jnp.ndarray, d0: jnp.ndarray, x: jnp.ndarray,
                         *, codec_name: str, D: int, sb: int = 8,
                         wb: int = 32, interpret: bool = True,
                         ckpt: jnp.ndarray | None = None) -> jnp.ndarray:
    """Run the multi-RHS full-x kernel over one width bucket.

    ``x``: [m, nb]. Returns Y in stored-row order, shape [S, C, nb] float32;
    the caller applies the σ-permutation gather once (plan.py epilogue).
    ``nb`` is padded to a sublane multiple internally; real-TPU deployments
    want nb a multiple of the 128-lane VREG width for full effect.
    ``ckpt`` as in :func:`packsell_spmv_bucket`.
    """
    S, w, C = pack.shape
    nb = x.shape[1]
    s_pad = -S % sb
    w_pad = -w % wb
    if s_pad or w_pad:
        pack = jnp.pad(pack, ((0, s_pad), (0, w_pad), (0, 0)))
        d0 = jnp.pad(d0, (0, s_pad))
    Sp, wp, _ = pack.shape
    m_pad = -x.shape[0] % 128
    nb_pad = -nb % 8
    xp = jnp.pad(x.astype(jnp.float32), ((0, m_pad), (0, nb_pad)))
    nbp = xp.shape[1]
    nw = wp // wb
    grid = (Sp // sb, nw)

    if ckpt is not None:
        kernel = functools.partial(_kernel_spmm_ckpt, codec_name=codec_name,
                                   D=D, wb=wb)
        y = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((sb, 1, C), lambda si, wi: (si, wi, 0)),
                pl.BlockSpec((sb, wb, C), lambda si, wi: (si, wi, 0)),
                pl.BlockSpec((xp.shape[0], nbp), lambda si, wi: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, sb, C, nbp),
                                   lambda si, wi: (wi, si, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((nw, Sp, C, nbp), jnp.float32),
            compiler_params=compat.compiler_params("parallel", "parallel"),
            interpret=interpret,
            name=f"packsell_spmm_ckpt_{codec_name}_D{D}",
        )(_pad_ckpt(ckpt, s_pad), pack, xp)
        ys = y[0] if nw == 1 else jnp.sum(y, axis=0)
        return ys[:S, :, :nb]

    kernel = functools.partial(_kernel_spmm, codec_name=codec_name, D=D,
                               nw=nw, wb=wb)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((sb,), lambda si, wi: (si,)),
            pl.BlockSpec((sb, wb, C), lambda si, wi: (si, wi, 0)),
            pl.BlockSpec((xp.shape[0], nbp), lambda si, wi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((sb, C, nbp), lambda si, wi: (si, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, C, nbp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((sb, C), jnp.int32),
                        pltpu.VMEM((sb, C, nbp), jnp.float32)],
        compiler_params=compat.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
        name=f"packsell_spmm_{codec_name}_D{D}",
    )(d0, pack, xp)
    return y[:S, :, :nb]


# ---------------------------------------------------------------------------
# fused-stream variant (the plan engine's ragged checkpoint operand)
# ---------------------------------------------------------------------------


def fused_decode_word(w: jnp.ndarray, codec: cd.Codec, D: int,
                      encoding: str, scale: float):
    """(value f32, run-local column offset i32) for fused-stream words.

    The ONE decode definition shared by the jnp fused body
    (``plan._fused_decode``) and the Pallas fused kernels below —
    kernel/XLA bit-parity is by construction, not by test luck. The
    16/16 split encodings are two fixed shifts; ``'words'`` is the
    canonical branch-free unpack with the delta field already rewritten
    to the re-based offset."""
    if encoding == "f16":
        v16 = (w >> np.uint32(16)).astype(jnp.uint16)
        v = jax.lax.bitcast_convert_type(v16, jnp.float16)
        local = (w & np.uint32(0xFFFF)).astype(jnp.int32)
    elif encoding == "top16":
        v = jax.lax.bitcast_convert_type(w & np.uint32(0xFFFF0000),
                                         jnp.float32)
        local = (w & np.uint32(0xFFFF)).astype(jnp.int32)
    elif encoding == "fixed16":
        v = (jax.lax.bitcast_convert_type(w, jnp.int32)
             >> np.int32(16)).astype(jnp.float32) * np.float32(scale)
        local = (w & np.uint32(0xFFFF)).astype(jnp.int32)
    else:                           # 'words'
        v, local = cd.unpack_words_jnp(w, codec, D)
        local = local.astype(jnp.int32)
    return v.astype(jnp.float32), local


def _kernel_fused(ckpt_ref, words_ref, x_ref, y_ref, *, codec_name: str,
                  D: int, encoding: str, scale: float, wk: int):
    """Fused-stream SpMV kernel body: checkpoint-seeded, carry-free.

    Each (gi, wi) grid instance owns a ``[GB, WK, C]`` word tile plus the
    matching ``[GB, C]`` checkpoints and reconstructs every column as
    ``ckpt + offset`` (the offsets are build-time prefix sums re-based to
    the checkpoint, so dummy-word chains cost nothing at runtime), then
    runs the unrolled decode → gather → FMA chain over the word axis in
    stream order — the same accumulation order as the jnp fused body."""
    codec = cd.make_codec(codec_name)
    ck = ckpt_ref[...]              # [GB, C] int32
    words = words_ref[...]          # [GB, WK, C] uint32
    x = x_ref[...]                  # [m_pad] f32
    mlim = np.int32(x.shape[0] - 1)
    acc = jnp.zeros(ck.shape, jnp.float32)

    def body(j, acc):
        v, local = fused_decode_word(words[:, j, :], codec, D, encoding,
                                     scale)
        cols = ck + local
        xv = jnp.take(x, jnp.minimum(cols, mlim).reshape(-1), axis=0,
                      mode="clip").reshape(ck.shape)
        return acc + v * xv

    acc = jax.lax.fori_loop(0, wk, body, acc)
    y_ref[...] = acc[None]


def _kernel_fused_mm(ckpt_ref, words_ref, x_ref, y_ref, *, codec_name: str,
                     D: int, encoding: str, scale: float, wk: int):
    """Multi-RHS twin of :func:`_kernel_fused`: one walk over the word
    tile feeds all nb right-hand sides (nb× arithmetic intensity)."""
    codec = cd.make_codec(codec_name)
    ck = ckpt_ref[...]              # [GB, C] int32
    words = words_ref[...]          # [GB, WK, C] uint32
    x = x_ref[...]                  # [m_pad, nb] f32
    mlim = np.int32(x.shape[0] - 1)
    nb = x.shape[1]
    acc = jnp.zeros(ck.shape + (nb,), jnp.float32)

    def body(j, acc):
        v, local = fused_decode_word(words[:, j, :], codec, D, encoding,
                                     scale)
        cols = ck + local
        xv = jnp.take(x, jnp.minimum(cols, mlim).reshape(-1), axis=0,
                      mode="clip").reshape(ck.shape + (nb,))
        return acc + v[..., None] * xv

    acc = jax.lax.fori_loop(0, wk, body, acc)
    y_ref[...] = acc[None]


def packsell_spmv_fused(words3d: jnp.ndarray, ckpt: jnp.ndarray,
                        x: jnp.ndarray, *, codec_name: str, D: int,
                        encoding: str = "words", scale: float = 0.0,
                        gb: int = 8, wk: int | None = None,
                        interpret: bool = True) -> jnp.ndarray:
    """One Pallas kernel over the whole fused word stream: group partials
    ``[G, C]`` float32 in stream order. The caller (the plan engine)
    applies the unrolled level-chain reduction + the 2-D inverse-perm
    gather epilogue (``plan._fused_epilogue``) — static ``FusedSegment``
    metadata, so the chain unrolls inside the same jitted dispatch.

    Grid = (group tiles, word-run tiles): both axes are parallel because
    every word's column offset is re-based to its group checkpoint — no
    cursor carry exists to serialize on. ``wk`` (word-run tile, default
    the full ``wr``) keeps a single word tile per group by default so the
    accumulation order matches the jnp fused body term for term; smaller
    ``wk`` trades that for more grid parallelism (partial tiles summed
    by the wrapper, like the checkpoint-seeded bucket kernels)."""
    G, wr, C = words3d.shape
    if G == 0:
        return jnp.zeros((0, C), jnp.float32)
    wk = wr if wk is None else max(1, min(int(wk), wr))
    g_pad = -G % gb
    w_pad = -wr % wk
    if g_pad or w_pad:
        # PAD groups/words decode to (v=0, offset=0): they gather x[ckpt]
        # and contribute 0, and padded group rows are trimmed below
        words3d = jnp.pad(words3d, ((0, g_pad), (0, w_pad), (0, 0)))
        ckpt = jnp.pad(ckpt, ((0, g_pad), (0, 0)))
    Gp, wrp, _ = words3d.shape
    m_pad = -x.shape[0] % 128
    xp = jnp.pad(x.astype(jnp.float32), (0, m_pad))
    nwk = wrp // wk
    grid = (Gp // gb, nwk)
    kernel = functools.partial(_kernel_fused, codec_name=codec_name, D=D,
                               encoding=encoding, scale=scale, wk=wk)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((gb, C), lambda gi, wi: (gi, 0)),
            pl.BlockSpec((gb, wk, C), lambda gi, wi: (gi, wi, 0)),
            pl.BlockSpec((xp.shape[0],), lambda gi, wi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, gb, C), lambda gi, wi: (wi, gi, 0)),
        out_shape=jax.ShapeDtypeStruct((nwk, Gp, C), jnp.float32),
        compiler_params=compat.compiler_params("parallel", "parallel"),
        interpret=interpret,
        name=f"packsell_spmv_fused_{encoding}_{codec_name}_D{D}",
    )(ckpt, words3d, xp)
    return (y[0] if nwk == 1 else jnp.sum(y, axis=0))[:G]


def packsell_spmm_fused(words3d: jnp.ndarray, ckpt: jnp.ndarray,
                        x: jnp.ndarray, *, codec_name: str, D: int,
                        encoding: str = "words", scale: float = 0.0,
                        gb: int = 8, wk: int | None = None,
                        interpret: bool = True) -> jnp.ndarray:
    """Multi-RHS fused-stream kernel: ``x`` is [m, nb], returns group
    partials [G, C, nb] float32 (epilogue as in
    :func:`packsell_spmv_fused`). ``nb`` is padded to a sublane multiple
    internally; the whole [m, nb] block is VMEM-resident, so the plan
    engine applies the same residency limit as the full-x kernels."""
    G, wr, C = words3d.shape
    nb = x.shape[1]
    if G == 0:
        return jnp.zeros((0, C, nb), jnp.float32)
    wk = wr if wk is None else max(1, min(int(wk), wr))
    g_pad = -G % gb
    w_pad = -wr % wk
    if g_pad or w_pad:
        words3d = jnp.pad(words3d, ((0, g_pad), (0, w_pad), (0, 0)))
        ckpt = jnp.pad(ckpt, ((0, g_pad), (0, 0)))
    Gp, wrp, _ = words3d.shape
    m_pad = -x.shape[0] % 128
    nb_pad = -nb % 8
    xp = jnp.pad(x.astype(jnp.float32), ((0, m_pad), (0, nb_pad)))
    nbp = xp.shape[1]
    nwk = wrp // wk
    grid = (Gp // gb, nwk)
    kernel = functools.partial(_kernel_fused_mm, codec_name=codec_name, D=D,
                               encoding=encoding, scale=scale, wk=wk)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((gb, C), lambda gi, wi: (gi, 0)),
            pl.BlockSpec((gb, wk, C), lambda gi, wi: (gi, wi, 0)),
            pl.BlockSpec((xp.shape[0], nbp), lambda gi, wi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, gb, C, nbp),
                               lambda gi, wi: (wi, gi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nwk, Gp, C, nbp), jnp.float32),
        compiler_params=compat.compiler_params("parallel", "parallel"),
        interpret=interpret,
        name=f"packsell_spmm_fused_{encoding}_{codec_name}_D{D}",
    )(ckpt, words3d, xp)
    ys = y[0] if nwk == 1 else jnp.sum(y, axis=0)
    return ys[:G, :, :nb]
