"""Pallas TPU kernels for the paper's compute hot-spot (SpMV).

Modules: ``packsell_spmv`` (the paper's kernel, TPU-adapted), ``sell_spmv``
(cuSELL-analogue baseline), ``ops`` (jit'd wrappers + kernel selection),
``ref`` (pure-jnp oracles).
"""
from . import ops, ref  # noqa: F401
