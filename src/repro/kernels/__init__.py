"""Pallas TPU kernels for the paper's compute hot-spot (SpMV).

Modules: ``packsell_spmv`` (the paper's kernels, TPU-adapted; single- and
multi-RHS), ``sell_spmv`` (cuSELL-analogue baseline), ``plan`` (the SpMVPlan
execution engine: cached plans, single-dispatch spmv/spmm, fused σ-scatter),
``composite`` (CompositePlan: the block-composition engine shared by plain,
mixed-precision, and distributed SpMV), ``ops`` (thin public wrappers over
the engine), ``ref`` (pure-jnp oracles), ``compat`` (Pallas API shim across
JAX versions).
"""
from . import compat, composite, ops, plan, ref  # noqa: F401
from .composite import (CompositeMember, CompositePlan,  # noqa: F401
                        composite_memory_stats, member_from_csr)
