"""SpMVPlan execution engine: cached plans, single-dispatch SpMV (DESIGN.md
§2.4, §10).

The paper's speedups live or die on SpMV being launch- and memory-lean; the
per-call path used to re-run host-side band planning, re-trace the kernels,
and issue one full-length σ-scatter per width bucket on every matvec. This
module moves every host-side decision out of the hot path:

* :func:`get_plan` builds a :class:`SpMVPlan` once per matrix — band-window
  feasibility, per-bucket tile parameters ``(sb, wb)``, half-window ``hw``,
  and kernel-variant selection — and caches it keyed on
  ``(mat token, sb, wb, hw, policy, interpret, decode-cache mode)``.
  Repeated matvecs (CG/GMRES inner loops, serving ticks) hit the cache and
  the plan's jitted dispatch function: zero host planning, zero re-tracing.
* The epilogue is fused: stored-row outputs get ONE σ-permutation step —
  for concrete plans a *gather* by the plan-precomputed inverse permutation
  (XLA CPU scatters are serial; the gather is ~100× cheaper).
  ``permuted=True`` skips it entirely (see ``cg.jacobi_pcg_stored``).
* For the ``'jnp'`` variant the plan's decode cache comes in three modes
  (``REPRO_PLAN_CURSOR_CACHE`` = ``checkpoint`` | ``full`` | ``0``):

  - ``checkpoint`` (default, DESIGN.md §10) — the **fused ragged stream**:
    all width buckets are repacked once at build time into one
    ``uint32[R, wr]`` word-stream operand (each row = one ``wr``-word run
    of a single stored row) plus ONE int32 **cursor checkpoint per row**
    — the column cursor before the row's first word. Each dispatch is one
    unpack → in-register prefix-sum from the checkpoint → one clip-mode
    gather → one segmented reduction over the per-segment ``(S, C, runs)``
    metadata. No per-word cursor stream (the paper's β is restored: the
    stream is the packed words themselves + 4/wr bytes of checkpoint per
    word), no per-bucket Python loop, no ``concatenate`` epilogue over
    bucket intermediates.
  - ``full`` — the PR-1 cursor cache: column indices decoded at build time,
    one extra int32 per stored word (≈ pack-sized) streamed per matvec.
  - ``0`` — no cache; runtime scan decode (``core.packsell``).

* For the Pallas variants ``checkpoint`` mode builds per-bucket **width
  -block checkpoints** ``int32[S, nw, C]`` (cursor at the start of each
  ``wb``-word grid block): the kernels seed the cursor from the checkpoint
  ref instead of carrying it across width blocks in VMEM scratch, making
  the width dimension of the grid parallel instead of a sequential carry
  chain (``packsell_spmv.py``).
* Variant selection is explicit and logged (:attr:`SpMVPlan.policy`):

  - ``'fused'`` — the fused-stream Pallas kernel
    (``packsell_spmv.packsell_spmv_fused``): ONE kernel over the whole
    repacked ``uint32[G, wr, C]`` word stream + ``int32[G, C]``
    checkpoints, grid parallel over group × word-run tiles (no per-bucket
    dispatch, no cursor carry). The auto default on compiled backends
    when the stream is feasible and x fits VMEM residency.
  - ``'band'``  — band-windowed per-bucket Pallas kernel (bounded VMEM;
    RCM/banded regime),
  - ``'full'``  — full-x-in-VMEM per-bucket Pallas kernel,
  - ``'jnp'``   — the fused-stream / scan-decode XLA path (the fast path on
    non-TPU backends, where the Pallas kernels only run in interpret mode).

  The automatic choice can be overridden per call (``force=``) or globally
  via the ``REPRO_SPMV_POLICY`` env var (``auto|fused|full|band|jnp``).
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import time
import weakref
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs as cd
from repro.core import packsell as pk
from repro.core.packsell import PackSELLMatrix
from repro.observe import metrics as _obs
from . import packsell_spmv as _pk

_DEF_HW = 4096              # default half-window (elements, multiple of 128)
_FULL_X_LIMIT = int(os.environ.get("REPRO_FULL_X_LIMIT", 2_000_000))
_BAND_MIN_M = int(os.environ.get("REPRO_BAND_MIN_M", 65_536))

_POLICIES = ("auto", "full", "band", "jnp", "fused")
_CACHE_MODES = ("checkpoint", "full", "0")


class LRUDict(dict):
    """A dict with LRU eviction, capacity read from an env var at insert
    time (so long-running processes can be re-tuned and tests can shrink
    it). Backs the per-plan jit-function caches: eviction only drops a
    compiled executable or the cached operand dict — both rebuild on the
    next call, bit-identically (the computation graph is a pure function
    of the plan's static fields)."""

    def __init__(self, env: str = "REPRO_JIT_CACHE_CAP", cap: int = 64):
        super().__init__()
        self._env = env
        self._default_cap = cap

    def _cap(self) -> int:
        try:
            return int(os.environ.get(self._env, self._default_cap))
        except ValueError:
            return self._default_cap

    def _touch(self, key) -> None:
        val = super().pop(key)
        super().__setitem__(key, val)       # move to MRU position

    def __getitem__(self, key):
        val = super().__getitem__(key)
        self._touch(key)
        return val

    def get(self, key, default=None):
        if key not in self:
            return default
        return self[key]

    def __setitem__(self, key, value) -> None:
        if key in self:
            super().pop(key)
        super().__setitem__(key, value)
        cap = max(self._cap(), 1)
        while len(self) > cap:
            super().pop(next(iter(self)))   # evict LRU
            _obs.inc("jit_cache.evict", cache=self._env)

    @classmethod
    def default_cap(cls) -> int:
        try:
            return int(os.environ.get("REPRO_JIT_CACHE_CAP", 64))
        except ValueError:
            return 64

#: candidate checkpoint row widths (words between checkpoints), largest
#: first. Power-of-two so pow2 bucket widths >= wr need no run padding.
_CKPT_WIDTHS = (128, 64, 32, 16, 8)


def _env_policy() -> str:
    pol = os.environ.get("REPRO_SPMV_POLICY", "auto").lower()
    if pol not in _POLICIES:
        raise ValueError(f"REPRO_SPMV_POLICY={pol!r} not in {_POLICIES}")
    return pol


def _env_cache_mode() -> str:
    raw = os.environ.get("REPRO_PLAN_CURSOR_CACHE", "checkpoint").lower()
    if raw in ("1", "checkpoint"):
        return "checkpoint"          # "1" kept for PR-1 compatibility
    if raw == "full":
        return "full"
    if raw in ("0", "off", "none"):
        return "0"
    raise ValueError(
        f"REPRO_PLAN_CURSOR_CACHE={raw!r} not in {_CACHE_MODES}")


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _is_traced(mat: PackSELLMatrix) -> bool:
    leaves = jax.tree_util.tree_leaves(
        (mat.packs, mat.d0s, mat.outrows, mat.maxcols))
    return any(isinstance(leaf, jax.core.Tracer) for leaf in leaves)


# ---------------------------------------------------------------------------
# Band-window planning (host-side, per bucket)
# ---------------------------------------------------------------------------


def bucket_band_windows(d0, maxcol, sb: int, hw: int):
    """Per-slice-block window ids (half-window units) for one bucket, or
    None when some slice-block's column span exceeds the 2*hw window."""
    d0 = np.asarray(d0)
    mc = np.asarray(maxcol)
    S = len(d0)
    s_pad = -S % sb
    if s_pad:
        d0 = np.concatenate([d0, np.full(s_pad, d0[-1] if S else 0, np.int32)])
        mc = np.concatenate([mc, np.full(s_pad, mc[-1] if S else 0, np.int32)])
    d0b = d0.reshape(-1, sb).min(axis=1)
    mcb = mc.reshape(-1, sb).max(axis=1)
    win = d0b // hw
    if np.any(mcb - win * hw >= 2 * hw):
        return None
    return win.astype(np.int32)


def band_plan(mat: PackSELLMatrix, sb: int, hw: int):
    """Host-side: per-bucket window ids if the band kernel is feasible for
    every slice-block, else None.

    Feasibility needs column locality *within each sb-slice block*; width
    bucketing can interleave distant slices, so banded matrices should be
    built with ``bucket_strategy='uniform'`` (contiguous slices) when the
    band kernel is desired — cheap in the low-RSD regime the paper targets.
    """
    wins = []
    for d0, maxcol in zip(mat.d0s, mat.maxcols):
        win = bucket_band_windows(d0, maxcol, sb, hw)
        if win is None:
            return None
        wins.append(win)
    return wins


# ---------------------------------------------------------------------------
# Host-side delta prefix sums (checkpoint + cursor-cache builders)
# ---------------------------------------------------------------------------


def _bucket_cursor_prefix(pack, d0, codec, D):
    """Exact int64 cursor BEFORE each word of one bucket: ``cum0[s, j, c]``
    = column cursor of stored row (s, c) before consuming word j
    (``cum0[:, 0, :]`` = d0). Shape [S, w+1, C]; entry ``w`` is the final
    cursor."""
    words = np.asarray(pack)
    S, w, C = words.shape
    _, d, _ = cd.unpack_words_np(words.reshape(-1), codec, D)
    cum = np.cumsum(d.reshape(S, w, C).astype(np.int64), axis=1)
    zero = np.zeros((S, 1, C), np.int64)
    return np.asarray(d0)[:, None, None].astype(np.int64) + \
        np.concatenate([zero, cum], axis=1)


# ---------------------------------------------------------------------------
# Cursor-cached decode (jnp variant, mode='full' — the PR-1 layout)
# ---------------------------------------------------------------------------


def _cursor_spmv(pack, cols, xc, codec, D):
    """One bucket via the full cursor cache: value unpack + one gather +
    one reduction — no runtime cumsum, but one int32 streamed per word."""
    S, w, C = pack.shape
    v, _ = cd.unpack_words_jnp(pack, codec, D)
    xv = jnp.take(xc, cols.reshape(-1), axis=0,
                  mode="clip").reshape(S, w, C)
    return jnp.sum(v.astype(jnp.float32) * xv, axis=1)


def _cursor_spmm(pack, cols, xc, codec, D):
    """Multi-RHS cursor-cached bucket; width-chunked to bound the
    [S, chunk, C, nb] gather intermediate."""
    S, w, C = pack.shape
    nb = xc.shape[1]
    chunk = pk._SCAN_CHUNK
    v, _ = cd.unpack_words_jnp(pack, codec, D)
    acc = jnp.zeros((S, C, nb), jnp.float32)
    for j0 in range(0, w, chunk):
        vc = v[:, j0:j0 + chunk, :].astype(jnp.float32)
        cc = cols[:, j0:j0 + chunk, :]
        xv = jnp.take(xc, cc.reshape(-1), axis=0,
                      mode="clip").reshape(cc.shape + (nb,))
        acc = acc + jnp.sum(vc[..., None] * xv, axis=1)
    return acc


def _build_cursor_cache(mat: PackSELLMatrix):
    """Decode every bucket's column cursors once (host-side numpy): the
    prefix-sum of word deltas, clamped to [0, m-1] exactly as the runtime
    decode would."""
    mlim = max(mat.m - 1, 0)
    cols = []
    for pack, d0 in zip(mat.packs, mat.d0s):
        cum0 = _bucket_cursor_prefix(pack, d0, mat.codec, mat.D)
        cols.append(jnp.asarray(
            np.minimum(cum0[:, 1:, :], mlim).astype(np.int32)))
    return tuple(cols)


# ---------------------------------------------------------------------------
# Fused ragged stream + compact cursor checkpoints (mode='checkpoint')
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedSegment:
    """One width bucket's span inside the fused stream, laid out
    LEVEL-major over run-count-sorted slices.

    The plan re-orders the bucket's slices by per-slice *content* width
    (descending run count, stable) and trims every all-padding trailing
    run, so level k = run k of the first ``levels[k]`` sorted slices — a
    shrinking contiguous prefix. The segment's reduction is an unrolled
    chain of zero-padded aligned adds (no reshape, no reduce HLO, no
    scatter), and — because bucket padding concentrates in trailing runs
    of the narrower slices — the stream often ends up SMALLER than the
    bucketed packs. The slice re-order is baked into the plan's
    ``outrow_cat``/inverse permutation, so outputs land exactly where the
    epilogue expects them."""

    g0: int
    S: int
    C: int
    levels: tuple            # level k covers sorted slices [0, levels[k])

    @property
    def groups(self) -> int:
        return int(sum(self.levels))

    @property
    def stored(self) -> int:
        return self.S * self.C


@dataclasses.dataclass(frozen=True)
class FusedLayout:
    """Static shape of the fused ragged word stream (device arrays:
    ``words uint32[groups, wr, C]`` + ``ckpt int32[groups, C]``).

    The lane axis C stays minor — the same VREG-friendly orientation as
    the bucketed packs, so every elementwise op and the run-axis
    accumulation vectorize across lanes; a group is a pure reshape of
    ``pack[s, k*wr:(k+1)*wr, :]``, so building the stream is a width-pad
    + reshape, never a transpose.

    ``encoding`` names how each 32-bit stream word carries its
    (value, run-local column offset) pair — the offsets are the word
    deltas **prefix-summed at build time and re-based to the group's
    checkpoint**, so the runtime decode is one add per word, no scan:

    * ``'f16'``     — fp16 payload in the top 16 bits, offset in the low
      16 (requires every group's column span < 2^16).
    * ``'top16'``   — top-16-of-fp32 payload (bf16; E8MY with V <= 16),
      offset in the low 16.
    * ``'fixed16'`` — fixed-point payload in the top 16 bits with the
      static dequant ``scale``, offset in the low 16.
    * ``'words'``   — canonical pack words with the delta field rewritten
      to the re-based offset (any codec; offsets must fit the D-bit
      field of flag=1 words).
    """

    wr: int                  # words per group per lane == ckpt granularity
    groups: int
    C: int
    words_exact: int         # bucketed words before run padding
    segments: tuple          # of FusedSegment, in bucket order
    encoding: str = "words"
    scale: float = 0.0       # fixed16 dequant scale

    @property
    def pad_words(self) -> int:
        return self.groups * self.wr * self.C - self.words_exact

    @property
    def checkpoint_bytes(self) -> int:
        return 4 * self.groups * self.C

    @property
    def stream_bytes(self) -> int:
        return 4 * self.groups * self.wr * self.C


#: cost-model constants for the checkpoint-width choice: a streamed word
#: costs ~3 passes (decode + x-gather + fma), a level add re-reads +
#: rewrites the [S_k, C] accumulator (~3 passes per element), and every
#: level is one more XLA op on the dispatch path (~tens of µs ≈ 40k
#: element-passes on the CPU backend). Fit against the small benchmark
#: suite; only the argmin matters, not the absolute scale.
_STREAM_PASSES = 3
_LEVEL_ADD_PASSES = 3
_LEVEL_OP_ELEMS = 40_000


def _pick_ckpt_width(widths, total: int) -> int:
    """Checkpoint width minimizing the modeled per-matvec cost, subject
    to the decode cache shrinking >= ``min(_CKPT_WIDTHS)``× vs the full
    cursor cache. ``widths`` is the list of (per-slice content widths, C)
    pairs per bucket; after all-pad-run trimming the stream holds
    ``ceil(width/wr)*wr`` words per slice. Small ``wr`` trims more
    padding but deepens the level chains of wide buckets (accumulator
    re-streaming + one op per level), so the model charges both; ties
    prefer the larger width (fewer checkpoints)."""
    floor = _CKPT_WIDTHS[-1]
    best = None                      # (ineligible, cost, -wr)
    for wr in _CKPT_WIDTHS:
        streamed = groups = levels = slices = 0
        for w, C in widths:
            runs = -(-np.maximum(w, 1) // wr)
            streamed += int(runs.sum()) * wr * C
            groups += int(runs.sum())
            levels += int(runs.max(initial=1)) - 1
            slices += len(w)
            last_C = C
        cbytes = groups * (last_C if widths else 1)
        shrink = total / cbytes if cbytes else float("inf")
        cost = _STREAM_PASSES * streamed \
            + _LEVEL_ADD_PASSES * (groups - slices) * (last_C if widths
                                                       else 1) \
            + _LEVEL_OP_ELEMS * levels
        key = (shrink < floor, cost, -wr)
        if best is None or key < best[0]:
            best = (key, wr)
    return best[1]


def _split16_encoding(mat: PackSELLMatrix):
    """The 16/16 split encoding for this matrix's codec, or None.

    Valid when the word's value payload lives entirely in the top 16 bits
    (fp16/bf16 embed at any D; E8MY and fixed-point once V = 31-D <= 16),
    so the plan stream can carry (payload16 | offset16) and the decode is
    two fixed shifts — no flag arithmetic, no variable shifts."""
    name, D = mat.codec_name, mat.D
    if name == "fp16":
        return "f16", 0.0
    if name == "bf16":
        return "top16", 0.0
    if name == "e8m" and cd.vbits_for(D) <= 16:
        return "top16", 0.0
    if name.startswith("fixed") and cd.vbits_for(D) <= 16:
        frac = int(name[len("fixed"):])
        return "fixed16", float(2.0 ** -(frac + D - 15))
    return None


def _build_fused_stream(mat: PackSELLMatrix, *, trim: bool = True,
                        wr: int | None = None):
    """Repack the bucketed words into the fused ragged-group layout, once,
    host-side (DESIGN.md §10.1). Returns ``((words3d, ckpt), layout,
    orders)`` — ``orders`` is the per-bucket slice permutation the caller
    must bake into ``outrow_cat`` — or ``(None, None, None)`` when no
    encoding fits (a group's column span overflows every offset field —
    the caller falls back to the full cursor cache).

    Each bucket's slices are sorted by content width (descending run
    count, stable), their word runs padded to a multiple of ``wr`` with
    ``PAD_WORD`` (flag=0, delta=0: contributes nothing) and carved into
    ``wr``-word groups laid out LEVEL-major: level k = run k of the
    sorted slices that still have one — all-padding trailing runs are
    trimmed away, which is where SELL bucket padding lives, so the
    stream is usually *smaller* than the bucketed packs. ``ckpt[g, c]``
    is the exact column cursor of stored row (slice-of(g), c) before the
    group's first word, and every word's delta is replaced by its
    **build-time prefix sum re-based to that checkpoint**, so the
    runtime column decode is ONE add per word — no scan, no carry, no
    per-word cursor stream.

    ``trim=False`` keeps the identity slice order and the full
    shape-derived run count per slice (every level = all S slices): the
    layout then depends only on the bucket SHAPES, which SPMD consumers
    (the distributed stacker) need uniform across shards. ``wr=`` pins
    the checkpoint width instead of the modeled pick — the autotune
    sweep's third axis (:meth:`SpMVPlan.retile` triples).
    """
    C, D = mat.C, mat.D
    dmask = np.uint32(cd.delta_mask(D))
    total = sum(int(np.prod(p.shape)) for p in mat.packs)
    used_w = []
    for pack in mat.packs:
        words = np.asarray(pack)
        S, w, C = words.shape
        if trim:
            nz = (words != pk.PAD_WORD).any(axis=2)        # [S, w]
            used = np.where(nz.any(axis=1),
                            w - np.argmax(nz[:, ::-1], axis=1), 1)
        else:
            used = np.full(S, w, np.int64)
        used_w.append((used.astype(np.int64), C))
    wr = _pick_ckpt_width(used_w, total) if wr is None else max(int(wr), 1)

    per_bucket, segs, orders = [], [], []
    g0 = 0
    locals_max = 0
    flag1_max = 0
    for (used, _), pack, d0 in zip(used_w, mat.packs, mat.d0s):
        words = np.asarray(pack)
        S, w, C = words.shape
        runs_s = -(-np.maximum(used, 1) // wr)             # >= 1 per slice
        order = np.argsort(-runs_s, kind="stable").astype(np.int64)
        runs_sorted = runs_s[order]
        maxr = int(runs_sorted[0]) if S else 1
        levels = tuple(int((runs_sorted > k).sum()) for k in range(maxr))
        wpad = maxr * wr
        cum0 = _bucket_cursor_prefix(pack, d0, mat.codec, D)[order]
        wp = np.full((S, wpad, C), pk.PAD_WORD, np.uint32)
        wk = min(w, wpad)           # trimming can shrink below w
        wp[:, :wk, :] = words[order][:, :wk, :]
        ck = cum0[:, ::wr, :][:, :maxr, :]                 # [S, maxr, C]
        # inclusive cursor per word, padding words frozen at the last real
        # cursor, re-based to the group checkpoint
        cum = np.concatenate(
            [cum0[:, 1:, :],
             np.broadcast_to(cum0[:, -1:, :],
                             (S, max(wpad - w, 0), C))], axis=1)[:, :wpad]
        local = (cum.reshape(S, maxr, wr, C)
                 - ck[:, :, None, :]).reshape(S, wpad, C)
        flag = wp & np.uint32(1)
        # only the KEPT groups constrain the encoding
        keep = np.zeros((S, maxr), bool)
        for k, Sk in enumerate(levels):
            keep[:Sk, k] = True
        keepw = np.repeat(keep, wr, axis=1)[:, :, None]
        lk = np.where(keepw, local, 0)
        locals_max = max(locals_max, int(lk.max(initial=0)))
        f1 = lk[(flag == 1) & keepw]
        flag1_max = max(flag1_max, int(f1.max(initial=0)))
        per_bucket.append((wp, flag, local, ck, S, maxr, levels))
        segs.append(FusedSegment(g0=g0, S=S, C=C, levels=levels))
        orders.append(order)
        g0 += int(sum(levels))

    split = _split16_encoding(mat)
    if split is not None and locals_max < (1 << 16):
        encoding, scale = split
    elif flag1_max < (1 << D) and locals_max < (1 << 31):
        encoding, scale = "words", 0.0
    else:
        return None, None, None     # span overflow: no compact encoding

    blk_w, blk_c = [], []
    for wp, flag, local, ck, S, maxr, levels in per_bucket:
        lu = np.minimum(local, (1 << 16) - 1 if encoding != "words"
                        else (1 << 31) - 1).astype(np.uint32)
        if encoding == "words":
            payload = wp & ~dmask
            w1 = payload | (lu << np.uint32(1)) | np.uint32(1)
            w0 = lu << np.uint32(1)
            nw = np.where(flag == 1, w1, w0)
        else:
            # value payload is top-16-aligned: keep it, splice the offset
            payload16 = np.where(flag == 1, wp & ~dmask, np.uint32(0))
            nw = (payload16 & np.uint32(0xFFFF0000)) | lu
        C_b = nw.shape[-1]
        nw4 = nw.reshape(S, maxr, wr, C_b)
        ck3 = ck
        for k, Sk in enumerate(levels):
            blk_w.append(nw4[:Sk, k])
            blk_c.append(ck3[:Sk, k])
    words3d = (np.concatenate(blk_w) if blk_w
               else np.zeros((0, wr, C), np.uint32))
    ckpt = (np.concatenate(blk_c) if blk_c
            else np.zeros((0, C), np.int64))
    layout = FusedLayout(
        wr=wr, groups=g0, C=C, words_exact=total,
        segments=tuple(segs), encoding=encoding, scale=scale)
    return ((jnp.asarray(words3d), jnp.asarray(ckpt.astype(np.int32))),
            layout, orders)


def _fused_decode(w, codec, D, layout: FusedLayout):
    """(value f32, run-local column offset i32) for a stream slice.
    Delegates to :func:`packsell_spmv.fused_decode_word` — the single
    decode definition shared with the fused Pallas kernels, so the
    'jnp' and 'fused' variants stay bit-compatible by construction."""
    return _pk.fused_decode_word(w, codec, D, layout.encoding,
                                 layout.scale)


def _fused_tail2(part, layout: FusedLayout):
    """Segmented reduction over group partials: [groups, C(, nb)] →
    [total_slices, C(, nb)] in sorted-slice-major stored order. The
    level-major layout makes each segment's reduction an unrolled chain
    of zero-padded aligned adds over shrinking slice prefixes (static
    slices; no reshape, no reduce HLO, no scatter) — and when every
    segment is single-level the partials ARE the result, copy-free."""
    if not layout.segments or all(len(seg.levels) == 1
                                  for seg in layout.segments):
        return part
    pad_tail = ((0, 0),) * (part.ndim - 1)
    outs = []
    for seg in layout.segments:
        t = part[seg.g0:seg.g0 + seg.levels[0]]
        off = seg.levels[0]
        for Sk in seg.levels[1:]:
            lk = part[seg.g0 + off:seg.g0 + off + Sk]
            if Sk < seg.S:
                lk = jnp.pad(lk, ((0, seg.S - Sk),) + pad_tail)
            t = t + lk
            off += Sk
        outs.append(t)
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs)


def _fused_tail(part, layout: FusedLayout):
    """[groups, C(, nb)] → flat [total_stored(, nb)] in ``outrow_cat``
    order (the ``permuted=True`` contract). The flattening reshape is a
    real XLA copy on CPU, so the un-permuted epilogue avoids this path
    and gathers 2-D (:func:`_fused_unpermute2`)."""
    tail = tuple(part.shape[2:])
    if not layout.segments:
        return jnp.zeros((0,) + tail, part.dtype)
    return _fused_tail2(part, layout).reshape((-1,) + tail)


def _fused_unpermute2(t2, inv2):
    """y[r] = t2[slice(r), lane(r)] — the σ-unpermutation applied
    directly to the 2-D slice-major tail, skipping the flatten copy AND
    the separate 1-D gather (one gather, unique in-bounds indices)."""
    return t2.at[inv2[:, 0], inv2[:, 1]].get(mode="clip",
                                             unique_indices=True)


def _fused_part_spmv(words3d, ckpt, xc, codec, D, layout: FusedLayout):
    """The fused single-pass SpMV body (group partials [G, C]): one
    decode over the whole stream, one checkpoint add, one clip-mode
    gather, an unrolled accumulate over the group-width axis (an explicit
    add chain — XLA fuses it into one pass where its reduce HLO would
    not)."""
    G, wr, C = words3d.shape
    v, local = _fused_decode(words3d, codec, D, layout)
    cols = ckpt[:, None, :] + local
    xv = jnp.take(xc, cols.reshape(-1), axis=0,
                  mode="clip").reshape(G, wr, C)
    p = v * xv
    acc = p[:, 0, :]
    for j in range(1, wr):
        acc = acc + p[:, j, :]
    return acc


def _fused_part_spmm(words3d, ckpt, xc, codec, D, layout: FusedLayout):
    """Multi-RHS fused pass (group partials [G, C, nb]): per word
    position, decode + gather + FMA on [G, C, nb] slices (bounds the
    gather intermediate the way the cursor path's width chunking did,
    with the same unrolled accumulation)."""
    G, wr, C = words3d.shape
    nb = xc.shape[1]
    acc = None
    for j in range(wr):
        v, local = _fused_decode(words3d[:, j, :], codec, D, layout)
        cols = ckpt + local
        xv = jnp.take(xc, cols.reshape(-1), axis=0,
                      mode="clip").reshape(G, C, nb)
        t = v[..., None] * xv
        acc = t if acc is None else acc + t
    if acc is None:
        acc = jnp.zeros((G, C, nb), jnp.float32)
    return acc


def _build_block_checkpoints(mat: PackSELLMatrix, tiles):
    """Per-bucket ``int32[S, nw, C]`` width-block checkpoints for the
    Pallas kernels: the cursor before word ``wi * wb`` of each stored row.
    Replaces the kernels' d0-seeded sequential VMEM cursor carry
    (``packsell_spmv.py``); recomputed on :meth:`SpMVPlan.retile` because
    the granularity is the width-block size ``wb``."""
    out = []
    for (sb, wb), pack, d0 in zip(tiles, mat.packs, mat.d0s):
        words = np.asarray(pack)
        S, w, C = words.shape
        nw = -(-w // wb)
        cum0 = _bucket_cursor_prefix(pack, d0, mat.codec, mat.D)
        ck = cum0[:, ::wb, :][:, :nw, :]
        out.append(jnp.asarray(ck.astype(np.int32)))
    return tuple(out)


def stored_permute(v, outrow_cat, n: int):
    """Original-row-order → stored-row order (σ-padding slots become 0).
    Operand-explicit so jitted callers (the fused solver step in
    ``solvers/cg.py``) can pass the plan buffers as arguments instead of
    closure constants."""
    val = jnp.take(v, outrow_cat, axis=0, mode="clip")
    mask = (outrow_cat < n).reshape((-1,) + (1,) * (v.ndim - 1))
    return jnp.where(mask, val, 0).astype(v.dtype)


def stored_unpermute(t, inv_cat):
    """Stored-row order → original-row order: the σ-permutation applied
    as a gather by the precomputed inverse map (equals the scatter
    bit-for-bit: each original row has exactly one stored slot, so the
    indices are unique and in-bounds)."""
    return jnp.take(t, inv_cat, axis=0, mode="clip", unique_indices=True)


def _build_inverse_perm(mat: PackSELLMatrix, outrow_cat: jnp.ndarray):
    """inv[r] = stored slot of original row r (each row has exactly one),
    turning the σ-scatter epilogue into a gather."""
    outrow_np = np.asarray(outrow_cat)
    valid = outrow_np < mat.n
    inv = np.zeros(mat.n, np.int32)
    inv[outrow_np[valid]] = np.nonzero(valid)[0].astype(np.int32)
    return jnp.asarray(inv)


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpMVPlan:
    """Everything host-side the hot path would otherwise recompute.

    Static decisions (variant, tiles, windows, decode-cache layout, the
    concatenated σ-scatter map) are fixed at build time; :meth:`spmv` /
    :meth:`spmm` dispatch straight into a cached jitted executable.
    """

    variant: str                      # 'fused' | 'band' | 'full' | 'jnp'
    policy: str                       # human-readable decision log
    hw: int
    interpret: bool
    tiles: tuple                      # per-bucket (sb, wb)
    wins: Optional[tuple]             # per-bucket int32 windows (band only)
    outrow_cat: jnp.ndarray           # int32 [total_stored] fused scatter map
    n: int
    m: int
    total_stored: int
    inv_cat: Optional[jnp.ndarray] = None   # int32 [n] inverse σ-permutation
    inv2_cat: Optional[jnp.ndarray] = None  # int32 [n, 2] (slice, lane) form
    cols: Optional[tuple] = None      # per-bucket int32 [S, w, C] cursor cache
    cache_mode: str = "0"             # 'checkpoint' | 'full' | '0'
    fused: Optional[tuple] = None     # (words2d uint32[R, wr], ckpt int32[R])
    fused_layout: Optional[FusedLayout] = None
    kckpts: Optional[tuple] = None    # per-bucket int32 [S, nw, C] (Pallas)
    total_words: int = 0              # bucketed words (decode-cache pricing)
    fused_trim: bool = True           # fused layout built with trimming?
    ephemeral: bool = False           # built under tracing: never cached/jitted
    _matref: Optional[weakref.ref] = None
    _fns: dict = dataclasses.field(default_factory=LRUDict)
    _view: Optional[PackSELLMatrix] = None

    # -- σ-permutation helpers (stored-row order <-> original order) -------
    def _unpermute(self, t, inv_cat, outrow_cat):
        if inv_cat is not None:
            return stored_unpermute(t, inv_cat)
        # tracing fallback: ONE drop-mode scatter over the already-fused
        # stored vector (never per bucket); sentinel slots (>= n) drop, and
        # the surviving indices are unique by construction
        shape = (self.n,) + tuple(t.shape[1:])
        return jnp.zeros(shape, t.dtype).at[outrow_cat].set(t, mode="drop")

    def from_stored(self, t: jnp.ndarray) -> jnp.ndarray:
        """Map a stored-row-order vector [total_stored] (or
        [total_stored, nb]) back to original row order [n] ([n, nb])."""
        return self._unpermute(t, self.inv_cat, self.outrow_cat)

    def to_stored(self, v: jnp.ndarray) -> jnp.ndarray:
        """Gather an original-row-order vector into stored-row order;
        σ-padding slots become 0 (they stay 0 through SpMV, so stored-space
        dot products equal original-space ones)."""
        return stored_permute(v, self.outrow_cat, self.n)

    # -- execution ---------------------------------------------------------
    def _device_operands(self) -> dict:
        """Plan-held device buffers, passed as jit *arguments* so XLA never
        constant-folds them into (or duplicates them inside) the
        executable. Cached: the dict is rebuilt only after retile()."""
        dev = self._fns.get("_dev")
        if dev is None:
            dev = {"cols": self.cols, "inv": self.inv_cat,
                   "inv2": self.inv2_cat, "outrow": self.outrow_cat,
                   "fused": self.fused, "kckpt": self.kckpts}
            self._fns["_dev"] = dev
        return dev

    def _mm_vmem_fallback(self) -> bool:
        """Multi-RHS VMEM-residency guard: the spmm kernels (bucket AND
        fused) hold the whole ``[m, nb]`` x block in VMEM, so past the
        full-x limit the plan routes spmm through an XLA body instead of
        raising (the decision is static — logged once in :meth:`spmm`)."""
        return (self.variant in ("band", "full", "fused")
                and self.m > _FULL_X_LIMIT)

    def _execute(self, mat: PackSELLMatrix, dev: dict, x: jnp.ndarray,
                 permuted: bool) -> jnp.ndarray:
        xc = x.astype(jnp.float32)
        fused = dev.get("fused")
        if fused is not None and self.variant in ("jnp", "fused"):
            lay = self.fused_layout
            if self.variant == "fused":
                with _obs.span("packsell.fused_kernel"):
                    part = _pk.packsell_spmv_fused(
                        fused[0], fused[1], xc,
                        codec_name=mat.codec_name, D=mat.D,
                        encoding=lay.encoding, scale=lay.scale,
                        gb=self.tiles[0][0] if self.tiles else 8,
                        interpret=self.interpret)
            else:
                with _obs.span("packsell.fused_decode"):
                    part = _fused_part_spmv(fused[0], fused[1], xc,
                                            mat.codec, mat.D, lay)
            return self._fused_epilogue(part, dev, permuted)
        if self.variant == "fused":
            raise ValueError("fused plan dispatched without its stream "
                             "operand (dev['fused'] is None)")
        with _obs.span("packsell.bucket_decode"):
            t_cat = self._bucket_parts(mat, dev, x, xc, multi_rhs=False)
        if permuted:
            return t_cat
        with _obs.span("packsell.gather_epilogue"):
            return self._unpermute(t_cat, dev.get("inv"), dev["outrow"])

    def _execute_mm(self, mat: PackSELLMatrix, dev: dict, x: jnp.ndarray,
                    permuted: bool) -> jnp.ndarray:
        xc = x.astype(jnp.float32)
        fused = dev.get("fused")
        if fused is not None and self.variant in ("jnp", "fused"):
            lay = self.fused_layout
            if self.variant == "fused" and not self._mm_vmem_fallback():
                with _obs.span("packsell.fused_kernel"):
                    part = _pk.packsell_spmm_fused(
                        fused[0], fused[1], xc,
                        codec_name=mat.codec_name, D=mat.D,
                        encoding=lay.encoding, scale=lay.scale,
                        gb=self.tiles[0][0] if self.tiles else 8,
                        interpret=self.interpret)
            else:
                # 'jnp', or a fused plan whose x block breaks VMEM
                # residency: same decode, XLA body
                with _obs.span("packsell.fused_decode"):
                    part = _fused_part_spmm(fused[0], fused[1], xc,
                                            mat.codec, mat.D, lay)
            return self._fused_epilogue(part, dev, permuted)
        if self.variant == "fused":
            raise ValueError("fused plan dispatched without its stream "
                             "operand (dev['fused'] is None)")
        with _obs.span("packsell.bucket_decode"):
            t_cat = self._bucket_parts(mat, dev, x, xc, multi_rhs=True)
        if permuted:
            return t_cat
        with _obs.span("packsell.gather_epilogue"):
            return self._unpermute(t_cat, dev.get("inv"), dev["outrow"])

    def _fused_epilogue(self, part, dev: dict, permuted: bool):
        """Reduce group partials to the requested order. Un-permuted
        output gathers 2-D straight off the slice-major tail
        (:func:`_fused_unpermute2`): no flatten copy, one gather."""
        with _obs.span("packsell.gather_epilogue"):
            if permuted:
                return _fused_tail(part, self.fused_layout)
            inv2 = dev.get("inv2")
            if inv2 is not None:
                return _fused_unpermute2(
                    _fused_tail2(part, self.fused_layout), inv2)
            return self._unpermute(_fused_tail(part, self.fused_layout),
                                   dev.get("inv"), dev["outrow"])

    def _bucket_parts(self, mat, dev, x, xc, *, multi_rhs: bool):
        """The per-bucket execution bodies (Pallas variants, the 'full'
        cursor cache, and the tracing scan fallback)."""
        kck = dev.get("kckpt")
        parts = []
        for b, (pack, d0) in enumerate(zip(mat.packs, mat.d0s)):
            sb, wb = self.tiles[b]
            ck = None if kck is None else kck[b]
            if multi_rhs:
                if (self.variant in ("band", "full")
                        and not self._mm_vmem_fallback()):
                    # multi-RHS ships the full-x kernel only; a banded plan
                    # falls back to it. Past the VMEM residency limit the
                    # bucket routes to an XLA body below instead.
                    t = _pk.packsell_spmm_bucket(
                        pack, d0, x, codec_name=mat.codec_name, D=mat.D,
                        sb=sb, wb=wb, interpret=self.interpret, ckpt=ck)
                elif dev["cols"] is not None:
                    t = _cursor_spmm(pack, dev["cols"][b], xc, mat.codec,
                                     mat.D)
                else:
                    t = pk._bucket_spmm_scan(
                        pack, d0, xc, mat.codec, mat.D,
                        np.int32(max(mat.m - 1, 0)), jnp.float32)
                parts.append(t.reshape(-1, xc.shape[1]))
                continue
            if self.variant == "band":
                t = _pk.packsell_spmv_band_bucket(
                    pack, d0, jnp.asarray(self.wins[b]), x,
                    codec_name=mat.codec_name, D=mat.D, hw=self.hw,
                    sb=sb, wb=wb, interpret=self.interpret, ckpt=ck)
            elif self.variant == "full":
                t = _pk.packsell_spmv_bucket(
                    pack, d0, x, codec_name=mat.codec_name, D=mat.D,
                    sb=sb, wb=wb, interpret=self.interpret, ckpt=ck)
            elif dev["cols"] is not None:
                t = _cursor_spmv(pack, dev["cols"][b], xc, mat.codec, mat.D)
            else:
                t = pk._bucket_spmv_scan(
                    pack, d0, xc, mat.codec, mat.D,
                    np.int32(max(mat.m - 1, 0)), jnp.float32)
            parts.append(t.reshape(-1))
        if not parts:
            shape = (0, xc.shape[1]) if multi_rhs else (0,)
            return jnp.zeros(shape, jnp.float32)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    def _dispatch(self, kind: str):
        fn = self._fns.get(kind)
        if fn is None:
            impl = self._execute if kind == "spmv" else self._execute_mm
            fn = jax.jit(impl, static_argnums=(3,))
            self._fns[kind] = fn
        return fn

    def execute_with(self, mat: PackSELLMatrix, dev: dict, x: jnp.ndarray,
                     *, permuted: bool = False,
                     multi_rhs: bool = False) -> jnp.ndarray:
        """Run the plan's execution body with externally supplied device
        operands (``{'cols': tuple|None, 'inv': array|None, 'outrow':
        array, 'fused': (words2d, ckpt)|None, 'kckpt': tuple|None}``;
        missing keys are treated as None) inside an existing trace — the
        shard_map reuse hook.

        The distributed layer builds one concrete plan per shard, stacks
        the per-shard operands along the mesh axis, and calls this inside
        the mapped body with each shard's slice (``DistSpMVPlan``): the
        plan's static decisions (variant, tiles, fused-stream layout) are
        reused across shards while the arrays flow through shard_map
        in_specs.
        """
        impl = self._execute_mm if multi_rhs else self._execute
        return impl(mat, dev, x, permuted)

    def _exec_mat(self, mat: PackSELLMatrix) -> PackSELLMatrix:
        """What the jitted dispatch receives as the matrix argument. The
        fused body reads only the plan's stream operands plus the static
        codec metadata, so a placeholder-leaf view keeps the per-call
        pytree flattening down to a handful of arrays (the distributed
        layer's `_member_view` trick)."""
        if self.fused is None or self.variant not in ("jnp", "fused"):
            return mat
        if self._view is None:
            # numpy placeholders: building the view must never capture a
            # live trace (spmv can be first called inside a solver trace)
            z1 = np.zeros((1,), np.int32)
            self._view = PackSELLMatrix(
                packs=(np.zeros((1, 1, 1), np.uint32),), d0s=(z1,),
                outrows=(z1,), maxcols=(z1,),
                perm=np.zeros((1,), np.uint8),
                n=mat.n, m=mat.m, C=mat.C, sigma=mat.sigma, D=mat.D,
                codec_name=mat.codec_name, k_left=mat.k_left, nnz=mat.nnz,
                n_dummy=mat.n_dummy,
                words_sell_padded=mat.words_sell_padded,
                words_bucketed=mat.words_bucketed)
        return self._view

    def _obs_record(self, mat: PackSELLMatrix, kind: str) -> None:
        """Per-dispatch flight-recorder record (DESIGN.md §12): variant,
        checkpoint width ``wr``, hot-path stream bytes and bytes/nnz.
        Called only from host entry points with concrete operands — never
        from inside a trace, where it would freeze at trace time. The
        derived byte figures are per-plan constants, computed once and
        parked in ``_fns`` (cleared by :meth:`retile`, so they re-derive)."""
        bump = self._fns.get(("_obs", kind))
        if bump is None:
            dcs = self.decode_cache_stats()
            stream = (dcs["fused_stream_bytes"] or 4 * self.total_words) \
                + dcs["decode_cache_bytes"]
            lab = dict(variant=self.variant, codec=mat.codec_name,
                       cache_mode=self.cache_mode)
            # per-plan constants: gauges set once here, not per call (a
            # registry reset() loses them until the next retile — fine,
            # they describe the plan, not traffic)
            _obs.gauge("spmv.wr", 0 if self.fused_layout is None
                       else int(self.fused_layout.wr), **lab)
            _obs.gauge("spmv.stream_bytes", int(stream), **lab)
            _obs.gauge("spmv.bytes_per_nnz",
                       stream / max(int(mat.nnz), 1), **lab)
            # label sort/stringification paid once per (plan, kind): the
            # steady-state record is one prebuilt two-counter closure
            bump = _obs.counter_bump((
                (_obs.series_key("spmv.dispatch", kind=kind, **lab), 1),
                (_obs.series_key("spmv.nnz", **lab), int(mat.nnz))))
            self._fns[("_obs", kind)] = bump
        bump()

    def spmv(self, mat: PackSELLMatrix, x: jnp.ndarray, *,
             permuted: bool = False) -> jnp.ndarray:
        """y = A @ x — one jitted dispatch; ``permuted=True`` returns y in
        stored-row order, skipping the σ-permutation epilogue entirely."""
        if self.ephemeral or _is_traced(mat):
            return self._execute(mat, self._device_operands(), x, permuted)
        if _obs.enabled() and not isinstance(x, jax.core.Tracer):
            self._obs_record(mat, "spmv")
        return self._dispatch("spmv")(self._exec_mat(mat),
                                      self._device_operands(), x,
                                      permuted)

    def spmm(self, mat: PackSELLMatrix, x: jnp.ndarray, *,
             permuted: bool = False) -> jnp.ndarray:
        """Y = A @ X for X: [m, nb] via the multi-RHS kernel.

        spmm has no banded-window variant: the whole [m, nb] x block must
        be VMEM-resident, so past the full-x limit a band/full plan routes
        to the scan-decode XLA body and a fused plan to the jnp fused
        body — explicitly, logged once in :attr:`policy` (this used to be
        a silent undocumented drop / a hard raise)."""
        if self._mm_vmem_fallback() and "; spmm:" not in self.policy:
            via = ("jnp fused body" if self.variant == "fused"
                   else "scan-decode body")
            self.policy += (
                f"; spmm: m={self.m} > REPRO_FULL_X_LIMIT="
                f"{_FULL_X_LIMIT} breaks multi-RHS VMEM residency — "
                f"routed to {via}")
            _obs.inc("spmv.mm_fallback", variant=self.variant)
        if self.ephemeral or _is_traced(mat):
            return self._execute_mm(mat, self._device_operands(), x,
                                    permuted)
        if _obs.enabled() and not isinstance(x, jax.core.Tracer):
            self._obs_record(mat, "spmm")
        return self._dispatch("spmm")(self._exec_mat(mat),
                                      self._device_operands(), x,
                                      permuted)

    def as_composite(self, mat: PackSELLMatrix):
        """This plan as the single-member case of the block-composition
        engine (:class:`~repro.kernels.composite.CompositePlan`) — the
        degenerate composition mixed-precision and distributed SpMV build
        on."""
        from . import composite
        return composite.CompositePlan.single(mat, self)

    def validate(self, mat: PackSELLMatrix | None = None, *,
                 raise_: bool = True) -> list:
        """Full structural validation of the plan's derived operands
        (checkpoint monotonicity/range, fused-stream accounting, offset
        range, permutation bijectivity) — the on-demand deep check;
        :func:`_quick_validate` already ran the cheap subset at build.
        Returns the issue list (``raise_=False``) or raises
        ``robust.guard.IntegrityError``."""
        from repro.robust import guard as _guard

        if mat is None:
            mat = self._matref() if self._matref is not None else None
        if mat is None:
            raise ValueError("cannot validate: matrix is gone; pass mat=")
        return _guard.validate_plan(mat, self, raise_=raise_)

    def describe(self) -> dict:
        """Machine-readable plan summary (serving warmup logs, and the
        precision store's retile records key off this)."""
        return {"variant": self.variant, "policy": self.policy,
                "tiles": [list(t) for t in self.tiles], "hw": self.hw,
                "interpret": self.interpret, "n": self.n, "m": self.m,
                "total_stored": self.total_stored,
                "cache_mode": self.cache_mode,
                "cursor_cache": self.cols is not None,
                "fused": self.fused is not None,
                "ckpt_width": (None if self.fused_layout is None
                               else self.fused_layout.wr)}

    def decode_cache_stats(self) -> dict:
        """Decode-cache device memory, priced against the PR-1 full cursor
        cache (4 bytes per bucketed word) — the accounting behind the
        BENCH_spmv.json footprint trajectory (DESIGN.md §10.3).

        ``decode_cache_bytes`` is the per-matvec *auxiliary* decode stream
        (cursors or checkpoints); ``fused_stream_bytes`` is the repacked
        word stream, which REPLACES the bucketed packs on the hot path
        (same words ± run padding, streamed instead of them)."""
        full = 4 * self.total_words
        if self.cache_mode == "checkpoint" and self.fused_layout is not None:
            cache = self.fused_layout.checkpoint_bytes
            stream = self.fused_layout.stream_bytes
            pad = self.fused_layout.pad_words
        elif self.cache_mode == "checkpoint" and self.kckpts is not None:
            cache = sum(4 * int(np.prod(c.shape)) for c in self.kckpts)
            stream, pad = 0, 0
        elif self.cols is not None:
            cache, stream, pad = full, 0, 0
        else:
            cache, stream, pad = 0, 0, 0
        return dict(cache_mode=self.cache_mode,
                    decode_cache_bytes=cache,
                    full_cursor_bytes=full,
                    fused_stream_bytes=stream,
                    fused_pad_words=pad,
                    shrink_vs_full=(full / cache) if cache else float("inf"))

    # -- autotune hook -----------------------------------------------------
    def retile(self, tiles) -> None:
        """Install per-bucket ``(sb, wb)`` — or ``(sb, wb, wr)`` — winners
        (benchmarks/bench_kernels.py autotune). Band windows and
        width-block checkpoints are recomputed for the new tiles; a third
        element pins the fused-stream checkpoint width ``wr`` (plan-global
        — all triples must agree) and rebuilds the stream plus the
        σ-permutation maps when it changes. Jitted dispatch functions are
        invalidated and re-trace on next call."""
        tiles = tuple(tuple(int(v) for v in t) for t in tiles)
        if len(tiles) != len(self.tiles):
            raise ValueError(f"need {len(self.tiles)} (sb, wb[, wr]) "
                             "tuples")
        if any(len(t) not in (2, 3) for t in tiles):
            raise ValueError("tiles must be (sb, wb) or (sb, wb, wr)")
        wrs = {t[2] for t in tiles if len(t) == 3}
        if len(wrs) > 1:
            raise ValueError("the fused checkpoint width wr is plan-"
                             f"global; got conflicting values {sorted(wrs)}")
        new_wr = wrs.pop() if wrs else None
        tiles = tuple(t[:2] for t in tiles)
        mat = self._matref() if self._matref is not None else None
        if self.variant == "band":
            if mat is None:
                raise ValueError("cannot retile a band plan: matrix is gone")
            wins = []
            for (sb, _), d0, maxcol in zip(tiles, mat.d0s, mat.maxcols):
                win = bucket_band_windows(d0, maxcol, sb, self.hw)
                if win is None:
                    raise ValueError(
                        f"band kernel infeasible at sb={sb}, hw={self.hw}")
                wins.append(win)
            self.wins = tuple(wins)
        if self.kckpts is not None:
            if mat is None:
                raise ValueError("cannot retile checkpoints: matrix is gone")
            self.kckpts = _build_block_checkpoints(mat, tiles)
        if (new_wr is not None and self.fused is not None
                and self.fused_layout is not None
                and new_wr != self.fused_layout.wr):
            if mat is None:
                raise ValueError(
                    "cannot re-width the fused stream: matrix is gone")
            fused, layout, orders = _build_fused_stream(
                mat, trim=self.fused_trim, wr=new_wr)
            if fused is None:
                raise ValueError(
                    f"wr={new_wr}: fused stream infeasible (group column "
                    "span overflows every compact offset encoding)")
            self.fused, self.fused_layout = fused, layout
            # the slice sort depends on runs-per-slice = f(wr): re-bake the
            # stored order and both inverse-permutation forms
            outs = [np.asarray(o).reshape(len(ordr), -1)[ordr].reshape(-1)
                    for o, ordr in zip(mat.outrows, orders)]
            self.outrow_cat = (jnp.asarray(np.concatenate(outs)) if outs
                               else jnp.zeros((0,), jnp.int32))
            self.inv_cat = _build_inverse_perm(mat, self.outrow_cat)
            inv = np.asarray(self.inv_cat)
            self.inv2_cat = jnp.asarray(np.stack(
                [inv // mat.C, inv % mat.C], axis=1).astype(np.int32))
            self.tiles = tiles
            self._fns.clear()
            _quick_validate(mat, self)
            return
        self.tiles = tiles
        self._fns.clear()


# ---------------------------------------------------------------------------
# Plan construction + cache
# ---------------------------------------------------------------------------


def build_plan(mat: PackSELLMatrix, *, sb: int = 8, wb: int = 32,
               hw: int = _DEF_HW, force: str | None = None,
               interpret: bool | None = None,
               decode_cache: str | None = None,
               fused_trim: bool = True,
               ckpt_wr: int | None = None) -> SpMVPlan:
    """Host-side plan construction (the slow path — run once per matrix).

    ``decode_cache`` in {'checkpoint', 'full', '0'} (default: the
    ``REPRO_PLAN_CURSOR_CACHE`` env var, itself defaulting to
    'checkpoint') picks the decode-cache layout for the 'jnp' variant and
    whether the Pallas variants get width-block checkpoints.
    ``fused_trim=False`` keeps the fused layout shape-derived (no
    data-dependent slice sort / all-pad-run trimming) so SPMD consumers
    get identical layouts across shards. ``ckpt_wr=`` pins the fused
    checkpoint width instead of the modeled pick (the autotune sweep's
    third axis).
    """
    t0 = time.perf_counter()
    with _obs.span("packsell.plan_build"):
        plan = _build_plan(mat, sb=sb, wb=wb, hw=hw, force=force,
                           interpret=interpret, decode_cache=decode_cache,
                           fused_trim=fused_trim, ckpt_wr=ckpt_wr)
    if not plan.ephemeral:
        _obs.inc("plan.build", variant=plan.variant,
                 cache_mode=plan.cache_mode)
        _obs.observe("plan.build_s", time.perf_counter() - t0,
                     variant=plan.variant)
    return plan


def _build_plan(mat: PackSELLMatrix, *, sb: int = 8, wb: int = 32,
                hw: int = _DEF_HW, force: str | None = None,
                interpret: bool | None = None,
                decode_cache: str | None = None,
                fused_trim: bool = True,
                ckpt_wr: int | None = None) -> SpMVPlan:
    interpret = _interpret_default() if interpret is None else interpret
    policy = (force or _env_policy()).lower()
    if policy not in _POLICIES:
        raise ValueError(f"force={policy!r} not in {_POLICIES}")
    mode = (decode_cache or _env_cache_mode()).lower()
    if mode not in _CACHE_MODES:
        raise ValueError(f"decode_cache={mode!r} not in {_CACHE_MODES}")
    n_buckets = len(mat.packs)
    tiles = tuple((sb, wb) for _ in range(n_buckets))

    if _is_traced(mat):
        # Under jit tracing the host cannot inspect column metadata: band
        # feasibility is undecidable and the decode caches cannot be built,
        # so fall back to the scan-decode variant and never cache (the plan
        # holds tracers).
        if policy == "band":
            raise ValueError(
                "force='band' requires a concrete matrix (host-side window "
                "planning); build the plan outside jit via get_plan(mat)")
        variant = "jnp" if policy in ("auto", "jnp", "fused") else "full"
        return SpMVPlan(
            variant=variant,
            policy=f"{variant} (tracing: host-side band planning "
                   f"unavailable; policy={policy})",
            hw=hw, interpret=interpret, tiles=tiles, wins=None,
            outrow_cat=jnp.concatenate([o.reshape(-1) for o in mat.outrows])
            if n_buckets else jnp.zeros((0,), jnp.int32),
            n=mat.n, m=mat.m,
            total_stored=sum(int(p.shape[0]) * int(p.shape[2])
                             for p in mat.packs),
            cache_mode="0",
            ephemeral=True)

    wins = None
    if policy in ("auto", "band") and mat.m > 0:
        wins = band_plan(mat, sb, hw)

    # Probe fused-stream feasibility up front where the fused Pallas
    # variant is in play: forced, or the auto default on compiled backends
    # (the kernel gathers the whole x, so the full-x residency limit
    # applies to it like the 'full' bucket kernel).
    fused, layout, orders = (None, None, None)
    want_fused = (policy == "fused"
                  or (policy == "auto" and not interpret
                      and mat.m <= _FULL_X_LIMIT))
    if want_fused:
        fused, layout, orders = _build_fused_stream(mat, trim=fused_trim,
                                                    wr=ckpt_wr)

    if policy == "band":
        if wins is None:
            raise ValueError("band kernel infeasible for this matrix/hw")
        variant, reason = "band", "forced via " + (
            f"force={force!r}" if force else "REPRO_SPMV_POLICY")
    elif policy == "full":
        variant, reason = "full", "forced via " + (
            f"force={force!r}" if force else "REPRO_SPMV_POLICY")
    elif policy == "jnp":
        variant, reason = "jnp", "forced via " + (
            f"force={force!r}" if force else "REPRO_SPMV_POLICY")
    elif policy == "fused":
        if mat.m > _FULL_X_LIMIT:
            raise ValueError(
                f"x too large for VMEM residency (m={mat.m}); the fused "
                "kernel gathers the whole x — use band/jnp")
        src = f"force={force!r}" if force else "REPRO_SPMV_POLICY"
        if fused is None:
            # forced fused but no compact encoding fits: demote to the
            # jnp variant on the full cursor cache, loudly
            variant = "jnp"
            reason = (f"forced fused via {src} demoted to jnp: fused "
                      "stream infeasible (group column span overflows "
                      "every compact offset encoding)")
            mode = "full"
        else:
            variant, reason = "fused", f"forced via {src}"
    else:  # auto
        if interpret:
            variant = "jnp"
            reason = ("auto: non-TPU backend — Pallas (incl. the fused-"
                      "stream kernel) would run in interpret mode, fused-"
                      "stream XLA path is faster (force='fused' runs the "
                      "interpret kernel anyway)")
        elif fused is not None:
            variant = "fused"
            reason = (f"auto: compiled backend, fused stream feasible and "
                      f"m={mat.m} fits VMEM residency — fused-stream "
                      "Pallas kernel")
        elif wins is not None and mat.m >= _BAND_MIN_M:
            variant = "band"
            reason = (f"auto: band feasible and m={mat.m} >= "
                      f"REPRO_BAND_MIN_M={_BAND_MIN_M} (bounds VMEM)"
                      + ("; fused stream infeasible (span overflow)"
                         if want_fused else ""))
        elif mat.m <= _FULL_X_LIMIT:
            variant = "full"
            reason = (f"auto: m={mat.m} fits VMEM residency"
                      + ("; fused stream infeasible (span overflow)"
                         if want_fused else "")
                      + ("" if wins is None else
                         f" (band feasible but m < REPRO_BAND_MIN_M="
                         f"{_BAND_MIN_M}: window bookkeeping not worth it)"))
        elif wins is not None:
            variant = "band"
            reason = f"auto: m={mat.m} > REPRO_FULL_X_LIMIT={_FULL_X_LIMIT}"
        else:
            raise ValueError(
                f"x too large for VMEM residency (m={mat.m}) and band "
                f"kernel infeasible; increase hw or force='jnp'")
    if variant == "full" and mat.m > _FULL_X_LIMIT:
        raise ValueError(
            f"x too large for VMEM residency (m={mat.m}); use band/jnp")
    if variant != "band":
        wins = None
    if variant != "fused" and policy != "fused":
        # a probe-built stream the selected variant will not consume
        fused, layout, orders = (None, None, None)

    cols = None
    kckpts = None
    if variant == "fused":
        if mode != "checkpoint":
            # the fused stream IS the decode cache: offsets are baked into
            # the words, checkpoints are the only auxiliary stream
            reason += (f"; decode_cache={mode!r} overridden to "
                       "'checkpoint' (the fused stream is the decode "
                       "cache)")
            mode = "checkpoint"
    elif variant == "jnp":
        if mode == "checkpoint" and fused is None:
            fused, layout, orders = _build_fused_stream(mat,
                                                        trim=fused_trim,
                                                        wr=ckpt_wr)
            if fused is None:
                # a group's column span overflows every compact offset
                # encoding — fall back to the full cursor cache, loudly
                mode = "full"
                reason += ("; checkpoint stream infeasible (group column "
                           "span overflow), fell back to full cursor "
                           "cache")
        if mode == "full":
            cols = _build_cursor_cache(mat)
    elif mode == "checkpoint":
        kckpts = _build_block_checkpoints(mat, tiles)
    if orders is not None:
        # bake the fused layout's per-bucket slice sort into the plan's
        # stored order (outputs of the fused tail land in sorted order)
        outs = [np.asarray(o).reshape(len(ordr), -1)[ordr].reshape(-1)
                for o, ordr in zip(mat.outrows, orders)]
        outrow_cat = (jnp.asarray(np.concatenate(outs)) if outs
                      else jnp.zeros((0,), jnp.int32))
    else:
        outrow_cat = (jnp.concatenate([o.reshape(-1) for o in mat.outrows])
                      if n_buckets else jnp.zeros((0,), jnp.int32))
    plan = SpMVPlan(
        variant=variant, policy=f"{variant} ({reason})", hw=hw,
        interpret=interpret, tiles=tiles,
        wins=None if wins is None else tuple(wins),
        outrow_cat=outrow_cat, n=mat.n, m=mat.m,
        total_stored=sum(int(p.shape[0]) * int(p.shape[2])
                         for p in mat.packs),
        inv_cat=(inv := _build_inverse_perm(mat, outrow_cat)),
        inv2_cat=(None if fused is None else jnp.asarray(np.stack(
            [np.asarray(inv) // mat.C, np.asarray(inv) % mat.C],
            axis=1).astype(np.int32))),
        cols=cols, cache_mode=mode, fused=fused, fused_layout=layout,
        kckpts=kckpts,
        total_words=sum(int(np.prod(p.shape)) for p in mat.packs),
        fused_trim=fused_trim,
        _matref=weakref.ref(mat))
    _quick_validate(mat, plan)
    return plan


def _quick_validate(mat: PackSELLMatrix, plan: SpMVPlan) -> None:
    """Cheap build-time structural invariants (O(n) bincount + O(segments)
    accounting — no word decode; the deep pass is
    :meth:`SpMVPlan.validate`). A violation here is a construction bug,
    never input data: raise immediately rather than hand the kernels a
    plan that scatters out of bounds."""
    outrow = np.asarray(plan.outrow_cat)
    if len(outrow) != plan.total_stored:
        raise ValueError(
            f"plan build: outrow_cat length {len(outrow)} != total_stored "
            f"{plan.total_stored}")
    counts = np.bincount(outrow[outrow < plan.n], minlength=max(plan.n, 1))
    if plan.n and (counts[:plan.n].min() < 1 or counts[:plan.n].max() > 1):
        raise ValueError("plan build: outrow_cat is not a bijection onto "
                         "[0, n)")
    layout = plan.fused_layout
    if plan.fused is not None and layout is not None:
        w3, ck = plan.fused
        if tuple(w3.shape) != (layout.groups, layout.wr, layout.C):
            raise ValueError(
                f"plan build: fused stream shape {tuple(w3.shape)} != "
                f"layout ({layout.groups}, {layout.wr}, {layout.C})")
        if tuple(ck.shape) != (layout.groups, layout.C):
            raise ValueError(
                f"plan build: fused checkpoint shape {tuple(ck.shape)} != "
                f"({layout.groups}, {layout.C})")
        g_sum = sum(seg.groups for seg in layout.segments)
        if g_sum != layout.groups:
            raise ValueError(
                f"plan build: segment group accounting {g_sum} != "
                f"{layout.groups}")
        stored = sum(seg.stored for seg in layout.segments)
        if stored != plan.total_stored:
            raise ValueError(
                f"plan build: segment stored accounting {stored} != "
                f"{plan.total_stored}")


_PLANS: dict = {}
_STATS = {"hits": 0, "misses": 0, "evicted": 0}
_TOKENS = itertools.count()


def _plan_cache_cap() -> int:
    """Plan-cache capacity (env-tunable so serving processes stay
    bounded; read per call so tests can shrink it at runtime)."""
    try:
        return max(int(os.environ.get("REPRO_PLAN_CACHE_CAP", 256)), 1)
    except ValueError:
        return 256


def _plan_token(mat: PackSELLMatrix) -> int:
    """Monotonic per-matrix cache token. ``id(mat)`` is unusable as a key
    component: after GC reuses an address, the dead matrix's deferred
    weakref callback would evict the *new* matrix's freshly cached plan
    (same key). The token is assigned once per matrix object and never
    recycled, so keys of distinct matrices can never collide."""
    tok = getattr(mat, "_plan_token", None)
    if tok is None:
        tok = next(_TOKENS)
        mat._plan_token = tok
    return tok


def get_plan(mat: PackSELLMatrix, *, sb: int = 8, wb: int = 32,
             hw: int = _DEF_HW, force: str | None = None,
             interpret: bool | None = None,
             decode_cache: str | None = None,
             fused_trim: bool = True,
             ckpt_wr: int | None = None) -> SpMVPlan:
    """Cached plan lookup. Keyed on ``(mat._plan_token, sb, wb, hw, policy,
    interpret, decode-cache mode, trim, ckpt_wr)`` — a monotonically
    assigned per-matrix token (see :func:`_plan_token`); entries are
    dropped (weakref) when the matrix dies."""
    interpret = _interpret_default() if interpret is None else interpret
    policy = (force or _env_policy()).lower()
    mode = (decode_cache or _env_cache_mode()).lower()
    if _is_traced(mat):
        # tracer matrices are per-trace objects: build ephemeral, skip cache
        return build_plan(mat, sb=sb, wb=wb, hw=hw, force=force,
                          interpret=interpret, decode_cache=decode_cache)
    key = (_plan_token(mat), sb, wb, hw, policy, interpret, mode,
           fused_trim, ckpt_wr)
    ent = _PLANS.get(key)
    if ent is not None and ent[0]() is mat:
        _STATS["hits"] += 1
        _obs.inc("plan_cache.hit")
        _PLANS[key] = _PLANS.pop(key)       # move to MRU position
        return ent[1]
    plan = build_plan(mat, sb=sb, wb=wb, hw=hw, force=force,
                      interpret=interpret, decode_cache=decode_cache,
                      fused_trim=fused_trim, ckpt_wr=ckpt_wr)

    def _drop(_ref, key=key):
        if _PLANS.pop(key, None) is not None:
            _STATS["evicted"] += 1
            _obs.inc("plan_cache.evict", cause="matrix_dead")

    _PLANS[key] = (weakref.ref(mat, _drop), plan)
    _STATS["misses"] += 1
    _obs.inc("plan_cache.miss")
    # LRU bound: a long-running serving process cycling many matrices must
    # not grow without limit; an evicted plan rebuilds bit-identically
    # (build_plan is deterministic in (mat, key))
    cap = _plan_cache_cap()
    while len(_PLANS) > cap:
        _PLANS.pop(next(iter(_PLANS)))
        _STATS["evicted"] += 1
        _obs.inc("plan_cache.evict", cause="capacity")
    return plan


def cache_stats() -> dict:
    """Plan-cache counters; also the live source behind
    ``repro.observe.report()``'s ``plan_cache`` block — the registry's
    ``plan_cache.*`` event counters mirror the same increments."""
    return dict(_STATS, size=len(_PLANS))


def clear_cache() -> None:
    _PLANS.clear()
    _STATS.update(hits=0, misses=0, evicted=0)
