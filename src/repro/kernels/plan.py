"""SpMVPlan execution engine: cached plans, single-dispatch SpMV (DESIGN.md §2.4).

The paper's speedups live or die on SpMV being launch- and memory-lean; the
per-call path used to re-run host-side band planning, re-trace the kernels,
and issue one full-length σ-scatter per width bucket on every matvec. This
module moves every host-side decision out of the hot path:

* :func:`get_plan` builds a :class:`SpMVPlan` once per matrix — band-window
  feasibility, per-bucket tile parameters ``(sb, wb)``, half-window ``hw``,
  and kernel-variant selection — and caches it keyed on
  ``(id(mat), sb, wb, hw, policy, interpret)``. Repeated matvecs (CG/GMRES
  inner loops, serving ticks) hit the cache and the plan's jitted dispatch
  function: zero host planning, zero re-tracing.
* The epilogue is fused: stored-row bucket outputs are concatenated and ONE
  σ-permutation step produces y — instead of one full-length scatter per
  bucket. For concrete plans even that is a *gather* by the plan-precomputed
  inverse permutation (XLA CPU scatters are serial; the gather is ~100×
  cheaper). ``permuted=True`` skips it entirely, returning stored-row order
  for solvers that permute their other operands once at setup
  (:func:`SpMVPlan.to_stored` / :func:`SpMVPlan.from_stored` round-trip the
  σ-permutation; see ``solvers/cg.py::jacobi_pcg_stored``).
* For the ``'jnp'`` variant the plan also carries a **cursor cache**: the
  column indices (prefix sums of the word deltas, clamped) are decoded once
  at build time, so each dispatch is value-unpack + gather + reduce with no
  runtime cumsum and no sequential word walk. Costs one extra int32 per
  stored word (≈ pack-sized); disable with ``REPRO_PLAN_CURSOR_CACHE=0``.
* Variant selection is explicit and logged (:attr:`SpMVPlan.policy`):

  - ``'band'``  — band-windowed Pallas kernel (bounded VMEM; RCM/banded
    regime),
  - ``'full'``  — full-x-in-VMEM Pallas kernel,
  - ``'jnp'``   — scan-parallel cumsum decode in plain XLA (the fast path on
    non-TPU backends, where the Pallas kernels only run in interpret mode).

  The automatic choice can be overridden per call (``force=``) or globally
  via the ``REPRO_SPMV_POLICY`` env var (``auto|full|band|jnp``).
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import weakref
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs as cd
from repro.core import packsell as pk
from repro.core.packsell import PackSELLMatrix
from . import packsell_spmv as _pk

_DEF_HW = 4096              # default half-window (elements, multiple of 128)
_FULL_X_LIMIT = int(os.environ.get("REPRO_FULL_X_LIMIT", 2_000_000))
_BAND_MIN_M = int(os.environ.get("REPRO_BAND_MIN_M", 65_536))
_CURSOR_CACHE = os.environ.get("REPRO_PLAN_CURSOR_CACHE", "1") != "0"

_POLICIES = ("auto", "full", "band", "jnp")


def _env_policy() -> str:
    pol = os.environ.get("REPRO_SPMV_POLICY", "auto").lower()
    if pol not in _POLICIES:
        raise ValueError(f"REPRO_SPMV_POLICY={pol!r} not in {_POLICIES}")
    return pol


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _is_traced(mat: PackSELLMatrix) -> bool:
    leaves = jax.tree_util.tree_leaves(
        (mat.packs, mat.d0s, mat.outrows, mat.maxcols))
    return any(isinstance(leaf, jax.core.Tracer) for leaf in leaves)


# ---------------------------------------------------------------------------
# Band-window planning (host-side, per bucket)
# ---------------------------------------------------------------------------


def bucket_band_windows(d0, maxcol, sb: int, hw: int):
    """Per-slice-block window ids (half-window units) for one bucket, or
    None when some slice-block's column span exceeds the 2*hw window."""
    d0 = np.asarray(d0)
    mc = np.asarray(maxcol)
    S = len(d0)
    s_pad = -S % sb
    if s_pad:
        d0 = np.concatenate([d0, np.full(s_pad, d0[-1] if S else 0, np.int32)])
        mc = np.concatenate([mc, np.full(s_pad, mc[-1] if S else 0, np.int32)])
    d0b = d0.reshape(-1, sb).min(axis=1)
    mcb = mc.reshape(-1, sb).max(axis=1)
    win = d0b // hw
    if np.any(mcb - win * hw >= 2 * hw):
        return None
    return win.astype(np.int32)


def band_plan(mat: PackSELLMatrix, sb: int, hw: int):
    """Host-side: per-bucket window ids if the band kernel is feasible for
    every slice-block, else None.

    Feasibility needs column locality *within each sb-slice block*; width
    bucketing can interleave distant slices, so banded matrices should be
    built with ``bucket_strategy='uniform'`` (contiguous slices) when the
    band kernel is desired — cheap in the low-RSD regime the paper targets.
    """
    wins = []
    for d0, maxcol in zip(mat.d0s, mat.maxcols):
        win = bucket_band_windows(d0, maxcol, sb, hw)
        if win is None:
            return None
        wins.append(win)
    return wins


# ---------------------------------------------------------------------------
# Cursor-cached decode (jnp variant, concrete plans)
# ---------------------------------------------------------------------------


def _cursor_spmv(pack, cols, xc, codec, D):
    """One bucket via the plan's cursor cache: value unpack + one gather +
    one reduction — no runtime cumsum, no sequential word walk."""
    S, w, C = pack.shape
    v, _ = cd.unpack_words_jnp(pack, codec, D)
    xv = jnp.take(xc, cols.reshape(-1), axis=0).reshape(S, w, C)
    return jnp.sum(v.astype(jnp.float32) * xv, axis=1)


def _cursor_spmm(pack, cols, xc, codec, D):
    """Multi-RHS cursor-cached bucket; width-chunked to bound the
    [S, chunk, C, nb] gather intermediate."""
    S, w, C = pack.shape
    nb = xc.shape[1]
    chunk = pk._SCAN_CHUNK
    v, _ = cd.unpack_words_jnp(pack, codec, D)
    acc = jnp.zeros((S, C, nb), jnp.float32)
    for j0 in range(0, w, chunk):
        vc = v[:, j0:j0 + chunk, :].astype(jnp.float32)
        cc = cols[:, j0:j0 + chunk, :]
        xv = jnp.take(xc, cc.reshape(-1), axis=0).reshape(cc.shape + (nb,))
        acc = acc + jnp.sum(vc[..., None] * xv, axis=1)
    return acc


def _build_cursor_cache(mat: PackSELLMatrix):
    """Decode every bucket's column cursors once (host-side numpy): the
    prefix-sum of word deltas, clamped to [0, m-1] exactly as the runtime
    decode would."""
    codec = mat.codec
    mlim = max(mat.m - 1, 0)
    cols = []
    for pack, d0 in zip(mat.packs, mat.d0s):
        words = np.asarray(pack)
        S, w, C = words.shape
        _, d, _ = cd.unpack_words_np(words.reshape(-1), codec, mat.D)
        c = np.asarray(d0)[:, None, None].astype(np.int64) + \
            np.cumsum(d.reshape(S, w, C).astype(np.int64), axis=1)
        cols.append(jnp.asarray(np.minimum(c, mlim).astype(np.int32)))
    return tuple(cols)


def _build_inverse_perm(mat: PackSELLMatrix, outrow_cat: jnp.ndarray):
    """inv[r] = stored slot of original row r (each row has exactly one),
    turning the σ-scatter epilogue into a gather."""
    outrow_np = np.asarray(outrow_cat)
    valid = outrow_np < mat.n
    inv = np.zeros(mat.n, np.int32)
    inv[outrow_np[valid]] = np.nonzero(valid)[0].astype(np.int32)
    return jnp.asarray(inv)


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpMVPlan:
    """Everything host-side the hot path would otherwise recompute.

    Static decisions (variant, tiles, windows, the concatenated σ-scatter
    map) are fixed at build time; :meth:`spmv` / :meth:`spmm` dispatch
    straight into a cached jitted executable.
    """

    variant: str                      # 'band' | 'full' | 'jnp'
    policy: str                       # human-readable decision log
    hw: int
    interpret: bool
    tiles: tuple                      # per-bucket (sb, wb)
    wins: Optional[tuple]             # per-bucket int32 windows (band only)
    outrow_cat: jnp.ndarray           # int32 [total_stored] fused scatter map
    n: int
    m: int
    total_stored: int
    inv_cat: Optional[jnp.ndarray] = None   # int32 [n] inverse σ-permutation
    cols: Optional[tuple] = None      # per-bucket int32 [S, w, C] cursor cache
    ephemeral: bool = False           # built under tracing: never cached/jitted
    _matref: Optional[weakref.ref] = None
    _fns: dict = dataclasses.field(default_factory=dict)

    # -- σ-permutation helpers (stored-row order <-> original order) -------
    def _unpermute(self, t, inv_cat, outrow_cat):
        if inv_cat is not None:
            # the σ-permutation applied as a gather by the precomputed
            # inverse map (equals the scatter bit-for-bit: each original row
            # has exactly one stored slot)
            return jnp.take(t, inv_cat, axis=0)
        shape = (self.n,) + tuple(t.shape[1:])
        return jnp.zeros(shape, t.dtype).at[outrow_cat].set(t, mode="drop")

    def from_stored(self, t: jnp.ndarray) -> jnp.ndarray:
        """Map a stored-row-order vector [total_stored] (or
        [total_stored, nb]) back to original row order [n] ([n, nb])."""
        return self._unpermute(t, self.inv_cat, self.outrow_cat)

    def to_stored(self, v: jnp.ndarray) -> jnp.ndarray:
        """Gather an original-row-order vector into stored-row order;
        σ-padding slots become 0 (they stay 0 through SpMV, so stored-space
        dot products equal original-space ones)."""
        safe = jnp.minimum(self.outrow_cat, max(self.n - 1, 0))
        val = jnp.take(v, safe, axis=0)
        mask = (self.outrow_cat < self.n)
        mask = mask.reshape((-1,) + (1,) * (v.ndim - 1))
        return jnp.where(mask, val, 0).astype(v.dtype)

    # -- execution ---------------------------------------------------------
    def _device_operands(self) -> dict:
        """Plan-held device buffers, passed as jit *arguments* so XLA never
        constant-folds them into (or duplicates them inside) the
        executable."""
        return {"cols": self.cols, "inv": self.inv_cat,
                "outrow": self.outrow_cat}

    def _execute(self, mat: PackSELLMatrix, dev: dict, x: jnp.ndarray,
                 permuted: bool) -> jnp.ndarray:
        xc = x.astype(jnp.float32)
        parts = []
        for b, (pack, d0) in enumerate(zip(mat.packs, mat.d0s)):
            sb, wb = self.tiles[b]
            if self.variant == "band":
                t = _pk.packsell_spmv_band_bucket(
                    pack, d0, jnp.asarray(self.wins[b]), x,
                    codec_name=mat.codec_name, D=mat.D, hw=self.hw,
                    sb=sb, wb=wb, interpret=self.interpret)
            elif self.variant == "full":
                t = _pk.packsell_spmv_bucket(
                    pack, d0, x, codec_name=mat.codec_name, D=mat.D,
                    sb=sb, wb=wb, interpret=self.interpret)
            elif dev["cols"] is not None:
                t = _cursor_spmv(pack, dev["cols"][b], xc, mat.codec, mat.D)
            else:
                t = pk._bucket_spmv_scan(
                    pack, d0, xc, mat.codec, mat.D,
                    np.int32(max(mat.m - 1, 0)), jnp.float32)
            parts.append(t.reshape(-1))
        if not parts:
            t_cat = jnp.zeros((0,), jnp.float32)
        else:
            t_cat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        if permuted:
            return t_cat
        return self._unpermute(t_cat, dev["inv"], dev["outrow"])

    def _execute_mm(self, mat: PackSELLMatrix, dev: dict, x: jnp.ndarray,
                    permuted: bool) -> jnp.ndarray:
        nb = x.shape[1]
        xc = x.astype(jnp.float32)
        parts = []
        for b, (pack, d0) in enumerate(zip(mat.packs, mat.d0s)):
            sb, wb = self.tiles[b]
            if self.variant in ("band", "full"):
                # multi-RHS currently ships the full-x kernel only; a banded
                # plan falls back to it (x·nb residency checked in spmm()).
                t = _pk.packsell_spmm_bucket(
                    pack, d0, x, codec_name=mat.codec_name, D=mat.D,
                    sb=sb, wb=wb, interpret=self.interpret)
            elif dev["cols"] is not None:
                t = _cursor_spmm(pack, dev["cols"][b], xc, mat.codec, mat.D)
            else:
                t = pk._bucket_spmm_scan(
                    pack, d0, xc, mat.codec, mat.D,
                    np.int32(max(mat.m - 1, 0)), jnp.float32)
            parts.append(t.reshape(-1, nb))
        if not parts:
            t_cat = jnp.zeros((0, nb), jnp.float32)
        else:
            t_cat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        if permuted:
            return t_cat
        return self._unpermute(t_cat, dev["inv"], dev["outrow"])

    def _dispatch(self, kind: str):
        fn = self._fns.get(kind)
        if fn is None:
            impl = self._execute if kind == "spmv" else self._execute_mm
            fn = jax.jit(impl, static_argnums=(3,))
            self._fns[kind] = fn
        return fn

    def execute_with(self, mat: PackSELLMatrix, dev: dict, x: jnp.ndarray,
                     *, permuted: bool = False,
                     multi_rhs: bool = False) -> jnp.ndarray:
        """Run the plan's execution body with externally supplied device
        operands (``{'cols': tuple|None, 'inv': array|None, 'outrow':
        array}``) inside an existing trace — the shard_map reuse hook.

        The distributed layer builds one concrete plan per shard, stacks the
        per-shard operands along the mesh axis, and calls this inside the
        mapped body with each shard's slice (``DistSpMVPlan``): the plan's
        static decisions (variant, tiles, cursor-cache layout) are reused
        across shards while the arrays flow through shard_map in_specs.
        """
        impl = self._execute_mm if multi_rhs else self._execute
        return impl(mat, dev, x, permuted)

    def spmv(self, mat: PackSELLMatrix, x: jnp.ndarray, *,
             permuted: bool = False) -> jnp.ndarray:
        """y = A @ x — one jitted dispatch; ``permuted=True`` returns y in
        stored-row order, skipping the σ-permutation epilogue entirely."""
        if self.ephemeral or _is_traced(mat):
            return self._execute(mat, self._device_operands(), x, permuted)
        return self._dispatch("spmv")(mat, self._device_operands(), x,
                                      permuted)

    def spmm(self, mat: PackSELLMatrix, x: jnp.ndarray, *,
             permuted: bool = False) -> jnp.ndarray:
        """Y = A @ X for X: [m, nb] via the multi-RHS kernel."""
        if self.variant in ("band", "full") and self.m > _FULL_X_LIMIT:
            # spmm has no banded-window variant yet: the whole [m, nb] x
            # block must be VMEM-resident, so the full-x limit applies even
            # to band plans (which exist precisely because m is large).
            raise ValueError(
                f"x too large for multi-RHS VMEM residency (m={self.m} > "
                f"REPRO_FULL_X_LIMIT={_FULL_X_LIMIT}); use force='jnp'")
        if self.ephemeral or _is_traced(mat):
            return self._execute_mm(mat, self._device_operands(), x,
                                    permuted)
        return self._dispatch("spmm")(mat, self._device_operands(), x,
                                      permuted)

    def as_composite(self, mat: PackSELLMatrix):
        """This plan as the single-member case of the block-composition
        engine (:class:`~repro.kernels.composite.CompositePlan`) — the
        degenerate composition mixed-precision and distributed SpMV build
        on."""
        from . import composite
        return composite.CompositePlan.single(mat, self)

    def describe(self) -> dict:
        """Machine-readable plan summary (serving warmup logs, and the
        precision store's retile records key off this)."""
        return {"variant": self.variant, "policy": self.policy,
                "tiles": [list(t) for t in self.tiles], "hw": self.hw,
                "interpret": self.interpret, "n": self.n, "m": self.m,
                "total_stored": self.total_stored,
                "cursor_cache": self.cols is not None}

    # -- autotune hook -----------------------------------------------------
    def retile(self, tiles) -> None:
        """Install per-bucket (sb, wb) winners (benchmarks/bench_kernels.py
        autotune). Band windows are recomputed for the new sb's; jitted
        dispatch functions are invalidated and re-trace on next call."""
        tiles = tuple((int(sb), int(wb)) for sb, wb in tiles)
        if len(tiles) != len(self.tiles):
            raise ValueError(f"need {len(self.tiles)} (sb, wb) pairs")
        if self.variant == "band":
            mat = self._matref() if self._matref is not None else None
            if mat is None:
                raise ValueError("cannot retile a band plan: matrix is gone")
            wins = []
            for (sb, _), d0, maxcol in zip(tiles, mat.d0s, mat.maxcols):
                win = bucket_band_windows(d0, maxcol, sb, self.hw)
                if win is None:
                    raise ValueError(
                        f"band kernel infeasible at sb={sb}, hw={self.hw}")
                wins.append(win)
            self.wins = tuple(wins)
        self.tiles = tiles
        self._fns.clear()


# ---------------------------------------------------------------------------
# Plan construction + cache
# ---------------------------------------------------------------------------


def build_plan(mat: PackSELLMatrix, *, sb: int = 8, wb: int = 32,
               hw: int = _DEF_HW, force: str | None = None,
               interpret: bool | None = None) -> SpMVPlan:
    """Host-side plan construction (the slow path — run once per matrix)."""
    interpret = _interpret_default() if interpret is None else interpret
    policy = (force or _env_policy()).lower()
    if policy not in _POLICIES:
        raise ValueError(f"force={policy!r} not in {_POLICIES}")
    n_buckets = len(mat.packs)
    tiles = tuple((sb, wb) for _ in range(n_buckets))

    if _is_traced(mat):
        # Under jit tracing the host cannot inspect column metadata: band
        # feasibility is undecidable, so fall back to a non-band variant and
        # never cache (the plan holds tracers).
        if policy == "band":
            raise ValueError(
                "force='band' requires a concrete matrix (host-side window "
                "planning); build the plan outside jit via get_plan(mat)")
        variant = "jnp" if policy in ("auto", "jnp") else "full"
        return SpMVPlan(
            variant=variant,
            policy=f"{variant} (tracing: host-side band planning "
                   f"unavailable; policy={policy})",
            hw=hw, interpret=interpret, tiles=tiles, wins=None,
            outrow_cat=jnp.concatenate([o.reshape(-1) for o in mat.outrows])
            if n_buckets else jnp.zeros((0,), jnp.int32),
            n=mat.n, m=mat.m,
            total_stored=sum(int(p.shape[0]) * int(p.shape[2])
                             for p in mat.packs),
            ephemeral=True)

    wins = None
    if policy in ("auto", "band") and mat.m > 0:
        wins = band_plan(mat, sb, hw)

    if policy == "band":
        if wins is None:
            raise ValueError("band kernel infeasible for this matrix/hw")
        variant, reason = "band", "forced via " + (
            f"force={force!r}" if force else "REPRO_SPMV_POLICY")
    elif policy == "full":
        variant, reason = "full", "forced via " + (
            f"force={force!r}" if force else "REPRO_SPMV_POLICY")
    elif policy == "jnp":
        variant, reason = "jnp", "forced via " + (
            f"force={force!r}" if force else "REPRO_SPMV_POLICY")
    else:  # auto
        if interpret:
            variant = "jnp"
            reason = ("auto: non-TPU backend — Pallas would run in "
                      "interpret mode, scan-decode XLA path is faster")
        elif wins is not None and mat.m >= _BAND_MIN_M:
            variant = "band"
            reason = (f"auto: band feasible and m={mat.m} >= "
                      f"REPRO_BAND_MIN_M={_BAND_MIN_M} (bounds VMEM)")
        elif mat.m <= _FULL_X_LIMIT:
            variant = "full"
            reason = (f"auto: m={mat.m} fits VMEM residency"
                      + ("" if wins is None else
                         f" (band feasible but m < REPRO_BAND_MIN_M="
                         f"{_BAND_MIN_M}: window bookkeeping not worth it)"))
        elif wins is not None:
            variant = "band"
            reason = f"auto: m={mat.m} > REPRO_FULL_X_LIMIT={_FULL_X_LIMIT}"
        else:
            raise ValueError(
                f"x too large for VMEM residency (m={mat.m}) and band "
                f"kernel infeasible; increase hw or force='jnp'")
    if variant == "full" and mat.m > _FULL_X_LIMIT:
        raise ValueError(
            f"x too large for VMEM residency (m={mat.m}); use band/jnp")
    if variant != "band":
        wins = None

    outrow_cat = (jnp.concatenate([o.reshape(-1) for o in mat.outrows])
                  if n_buckets else jnp.zeros((0,), jnp.int32))
    return SpMVPlan(
        variant=variant, policy=f"{variant} ({reason})", hw=hw,
        interpret=interpret, tiles=tiles,
        wins=None if wins is None else tuple(wins),
        outrow_cat=outrow_cat, n=mat.n, m=mat.m,
        total_stored=sum(int(p.shape[0]) * int(p.shape[2])
                         for p in mat.packs),
        inv_cat=_build_inverse_perm(mat, outrow_cat),
        cols=(_build_cursor_cache(mat)
              if variant == "jnp" and _CURSOR_CACHE else None),
        _matref=weakref.ref(mat))


_PLANS: dict = {}
_STATS = {"hits": 0, "misses": 0, "evicted": 0}
_TOKENS = itertools.count()


def _plan_token(mat: PackSELLMatrix) -> int:
    """Monotonic per-matrix cache token. ``id(mat)`` is unusable as a key
    component: after GC reuses an address, the dead matrix's deferred
    weakref callback would evict the *new* matrix's freshly cached plan
    (same key). The token is assigned once per matrix object and never
    recycled, so keys of distinct matrices can never collide."""
    tok = getattr(mat, "_plan_token", None)
    if tok is None:
        tok = next(_TOKENS)
        mat._plan_token = tok
    return tok


def get_plan(mat: PackSELLMatrix, *, sb: int = 8, wb: int = 32,
             hw: int = _DEF_HW, force: str | None = None,
             interpret: bool | None = None) -> SpMVPlan:
    """Cached plan lookup. Keyed on ``(mat._plan_token, sb, wb, hw, policy,
    interpret)`` — a monotonically assigned per-matrix token (see
    :func:`_plan_token`); entries are dropped (weakref) when the matrix
    dies."""
    interpret = _interpret_default() if interpret is None else interpret
    policy = (force or _env_policy()).lower()
    if _is_traced(mat):
        # tracer matrices are per-trace objects: build ephemeral, skip cache
        return build_plan(mat, sb=sb, wb=wb, hw=hw, force=force,
                          interpret=interpret)
    key = (_plan_token(mat), sb, wb, hw, policy, interpret)
    ent = _PLANS.get(key)
    if ent is not None and ent[0]() is mat:
        _STATS["hits"] += 1
        return ent[1]
    plan = build_plan(mat, sb=sb, wb=wb, hw=hw, force=force,
                      interpret=interpret)

    def _drop(_ref, key=key):
        if _PLANS.pop(key, None) is not None:
            _STATS["evicted"] += 1

    _PLANS[key] = (weakref.ref(mat, _drop), plan)
    _STATS["misses"] += 1
    return plan


def cache_stats() -> dict:
    return dict(_STATS, size=len(_PLANS))


def clear_cache() -> None:
    _PLANS.clear()
    _STATS.update(hits=0, misses=0, evicted=0)
