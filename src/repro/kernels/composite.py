"""CompositePlan: ONE block-composition engine for every SpMV path
(DESIGN.md §9).

Three subsystems used to re-implement the same recipe — "stack format
blocks → one jitted dispatch → global inverse-permutation gather":
``kernels/plan.py::SpMVPlan`` (width buckets of one matrix),
``precision/mixed.py::MixedPackSELL`` (per-row-class codec blocks) and
``distributed/plan.py::DistSpMVPlan`` (per-shard local/remote block pairs).
This module is the single composition layer the paper's unified SELL-C-σ
argument calls for (Kreutzer et al., arXiv:1307.6209; GPGPU-cluster
follow-up arXiv:1112.5588): the others are now thin wrappers.

Model
-----
A :class:`CompositePlan` is an ordered list of :class:`CompositeMember`\\ s.
Each member is one format block — a
:class:`~repro.core.packsell.PackSELLMatrix` executed through its
:class:`~repro.kernels.plan.SpMVPlan` in ``permuted=True`` (stored-row)
mode, or an uncompressed :class:`~repro.core.sell.SELLMatrix` (fp32/fp64)
executed by :func:`sell_stored_spmv` — annotated with

* ``rows``   — the stored→global row map (which global rows the block
  covers; ``None`` = block rows are already global rows),
* ``term``   — the **sum group**. Members of one term cover disjoint row
  sets; their stored outputs are concatenated and ONE precomputed global
  inverse-permutation *gather* per term produces a full-length vector.
  Terms are then **added** — the distributed ``y = A_loc x + A_rem x_halo``
  pattern, where the local and remote blocks both cover every row.
* ``x_index`` — which input vector the member consumes (0 = x; the
  distributed layer feeds the halo buffer as input 1, produced by the
  halo-exchange *pre-stage*, ``distributed/halo.py``).

So: mixed precision = one term, many members (concat + one gather);
distributed = two terms (local + remote), each one member; distributed ×
mixed = two terms, many members each — the composition the paper's
headline mixed-precision results need, previously structurally impossible.

The whole composite runs as ONE jitted dispatch; everything host-side
(member plans, term inverse permutations, coverage validation) happens at
build time. ``execute_with`` exposes the raw body for reuse inside an
existing trace (the ``shard_map`` hook, mirroring
:meth:`~repro.kernels.plan.SpMVPlan.execute_with`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core.packsell import PackSELLMatrix
from repro.core.sell import SELLMatrix
from repro.observe import metrics as _obs

from . import plan as kplan


# ---------------------------------------------------------------------------
# SELL member execution (stored-row order, gather-epilogue compatible)
# ---------------------------------------------------------------------------


def sell_stored_spmv(mat: SELLMatrix, x: jnp.ndarray, *,
                     multi_rhs: bool = False) -> jnp.ndarray:
    """One SELL block in **stored-row order** — the fp32/fp64 analogue of
    ``SpMVPlan.execute_with(..., permuted=True)``.

    Unlike :func:`repro.core.sell.sell_spmv_jnp` this emits the raw
    ``[S*C]`` slice outputs with NO per-block scatter; the composite's term
    inverse permutation (built from the block's ``outrows``) maps them to
    global rows in one gather. Compute dtype is the block's value dtype
    promoted to at least fp32, so fp32 blocks match ``sell_spmv_jnp(...,
    float32)`` bit-for-bit and fp64 blocks serve as exact operators.
    """
    cdt = jnp.promote_types(mat.vals[0].dtype, jnp.float32)
    xc = x.astype(cdt)
    parts = []
    for val, col in zip(mat.vals, mat.cols):
        S, w, C = val.shape
        if multi_rhs:
            nb = xc.shape[1]
            t0 = jnp.zeros((S, C, nb), cdt)

            def body_mm(j, t, val=val, col=col):
                v = val[:, j, :].astype(cdt)
                xv = jnp.take(xc, col[:, j, :], axis=0, mode="clip")
                return t + v[..., None] * xv

            t = jax.lax.fori_loop(0, w, body_mm, t0)
            parts.append(t.reshape(-1, nb))
        else:
            t0 = jnp.zeros((S, C), cdt)

            def body(j, t, val=val, col=col):
                v = val[:, j, :].astype(cdt)
                xv = jnp.take(xc, col[:, j, :], axis=0, mode="clip")
                return t + v * xv

            t = jax.lax.fori_loop(0, w, body, t0)
            parts.append(t.reshape(-1))
    if not parts:
        shape = (0, xc.shape[1]) if multi_rhs else (0,)
        return jnp.zeros(shape, cdt)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# Members
# ---------------------------------------------------------------------------

#: codecs stored as uncompressed SELL value/column blocks
SELL_CODECS = ("fp32", "fp64")


@dataclasses.dataclass
class CompositeMember:
    """One format block inside a composite (see module docstring)."""

    mat: object                    # PackSELLMatrix | SELLMatrix
    plan: Optional[kplan.SpMVPlan]  # execution engine; None for SELL blocks
    codec: str
    D: int
    rows: Optional[np.ndarray] = None   # block row -> global row (ascending)
    x_index: int = 0
    term: int = 0
    label: str = ""

    @property
    def fmt(self) -> str:
        return "sell" if self.plan is None else "packsell"

    @property
    def stored(self) -> int:
        """Stored output slots this member emits."""
        if self.plan is not None:
            return self.plan.total_stored
        return sum(int(v.shape[0]) * int(v.shape[2]) for v in self.mat.vals)

    @property
    def block_n(self) -> int:
        return int(self.mat.n)

    def outrow_host(self) -> np.ndarray:
        """Host copy of the stored-slot → block-row map (sentinel >= n)."""
        if self.plan is not None:
            return np.asarray(self.plan.outrow_cat)
        outs = [np.asarray(o).reshape(-1) for o in self.mat.outrows]
        return (np.concatenate(outs) if outs
                else np.zeros((0,), np.int32))

    def device_operands(self) -> dict:
        """The member's plan-held device buffers (the fused checkpoint
        stream, or the legacy cursor cache). ``inv``/``outrow`` are None:
        the composite's term gather replaces the per-block epilogue."""
        if self.plan is None:
            return {}
        return {"cols": self.plan.cols, "inv": None, "outrow": None,
                "fused": self.plan.fused, "kckpt": self.plan.kckpts}

    def execute(self, mat, dev: dict, x: jnp.ndarray, *,
                multi_rhs: bool = False) -> jnp.ndarray:
        """Stored-row-order block output (inside an existing trace)."""
        if self.plan is None:
            return sell_stored_spmv(mat, x, multi_rhs=multi_rhs)
        return self.plan.execute_with(mat, dev, x, permuted=True,
                                      multi_rhs=multi_rhs)


def member_from_csr(sub, codec: str, D: int, *, C: int = 32,
                    sigma: int = 256, rows=None, x_index: int = 0,
                    term: int = 0, label: str = "",
                    bucket_strategy: str | None = None,
                    device: bool = True,
                    force: str | None = None) -> CompositeMember:
    """Build one member from a CSR block. ``codec`` in
    :data:`SELL_CODECS` builds an uncompressed SELL block; anything else a
    PackSELL block with its cached :class:`~repro.kernels.plan.SpMVPlan`."""
    if codec in SELL_CODECS:
        vd = {"fp32": "float32", "fp64": "float64"}[codec]
        mat = sl.from_csr(sub, C=C, sigma=sigma, value_dtype=vd,
                          bucket_strategy=bucket_strategy or "pow2",
                          device=device)
        splan = None
    else:
        mat = pk.from_csr(sub, C=C, sigma=sigma, D=D, codec=codec,
                          bucket_strategy=bucket_strategy or "pow2",
                          device=device)
        splan = (kplan.get_plan(mat) if device
                 else kplan.build_plan(mat, force=force or "jnp"))
    return CompositeMember(
        mat=mat, plan=splan, codec=codec, D=D,
        rows=None if rows is None else np.asarray(rows, np.int64),
        x_index=x_index, term=term, label=label or f"{codec}/D={D}")


# ---------------------------------------------------------------------------
# Term inverse permutations (the ONE-gather epilogue)
# ---------------------------------------------------------------------------


def term_inverse(n: int, members: Sequence[CompositeMember], *,
                 allow_uncovered: bool = False,
                 term: int = 0) -> np.ndarray:
    """``inv[r]`` = slot of global row r in the term's concatenated member
    outputs. Requires disjoint member row sets; rows no member covers are
    an error unless ``allow_uncovered`` — then they point at the appended
    all-zero pad slot (index = term's total stored), so uncovered rows read
    exactly 0 through the gather.
    """
    inv = np.full(n, -1, np.int64)
    off = 0
    for mem in members:
        out = mem.outrow_host()
        valid = out < mem.block_n
        blk = out[valid]
        g = blk if mem.rows is None else mem.rows[blk]
        if np.any(inv[g] >= 0):
            raise ValueError(
                f"composite members overlap in rows (term {term})")
        inv[g] = off + np.nonzero(valid)[0]
        off += mem.stored
    missing = inv < 0
    if np.any(missing):
        if not allow_uncovered:
            raise ValueError(
                f"composite members cover {int((~missing).sum())} of {n} "
                f"rows in term {term}; every row needs exactly one class")
        inv[missing] = off          # the zero pad slot
    return inv.astype(np.int32)


# ---------------------------------------------------------------------------
# Unified memory accounting (satellite: one blend for plain/mixed/dist)
# ---------------------------------------------------------------------------


def _block_bytes(mat) -> int:
    st = mat.memory_stats()
    return int(st.get("packsell_bytes", st.get("sell_bytes", 0)))


def composite_memory_stats(entries, *, halo: dict | None = None) -> dict:
    """Blend per-block memory stats into one profile with a per-member
    breakdown — THE accounting used by :meth:`CompositePlan.memory_stats`,
    ``MixedPackSELL.memory_stats`` and ``DistSpMVPlan.memory_stats``.

    ``entries``: iterable of ``(label, codec, D, n_rows, mats)`` where
    ``mats`` is one block or a per-shard list of blocks. ``halo``: optional
    communication profile merged in (the distributed layer's traffic).
    """
    members = []
    total_bytes = total_nnz = 0
    for label, codec, D, n_rows, mats in entries:
        mats = mats if isinstance(mats, (list, tuple)) else [mats]
        b = sum(_block_bytes(m) for m in mats)
        nnz = sum(int(m.nnz) for m in mats)
        members.append({
            "label": label, "codec": codec, "D": D, "rows": n_rows,
            "bytes": b, "nnz": nnz, "bytes_per_nnz": b / max(nnz, 1)})
        total_bytes += b
        total_nnz += nnz
    out = {
        "composite_bytes": total_bytes,
        "bytes_per_nnz": total_bytes / max(total_nnz, 1),
        "nnz": total_nnz,
        "members": members,
    }
    if halo:
        out.update(halo)
    return out


# ---------------------------------------------------------------------------
# The composite plan
# ---------------------------------------------------------------------------


class CompositePlan:
    """Ordered member blocks, one jitted dispatch, one gather per term.

    ``allow_uncovered=True`` (distributed shard composites: padding rows
    beyond the shard's real row count) routes uncovered rows to an
    appended all-zero pad slot instead of raising.
    """

    def __init__(self, members: Sequence[CompositeMember], n: int, m: int,
                 *, allow_uncovered: bool = False, name: str = "composite"):
        self.members = list(members)
        if not self.members:
            raise ValueError("composite needs at least one member")
        self.n = int(n)
        self.m = int(m)
        self.name = name
        self.pad_slot = bool(allow_uncovered)
        terms = sorted({mem.term for mem in self.members})
        if terms != list(range(len(terms))):
            raise ValueError(f"member terms must be 0..T-1, got {terms}")
        self.n_terms = len(terms)
        self.n_inputs = 1 + max(mem.x_index for mem in self.members)
        # coverage/overlap validation happens eagerly (host numpy); the
        # device copies are built lazily — shard_map templates supply
        # per-shard inverses through execute_with and never need these
        self._invs_np = tuple(
            term_inverse(self.n,
                         [mm for mm in self.members if mm.term == t],
                         allow_uncovered=allow_uncovered, term=t)
            for t in range(self.n_terms))
        self._invs: Optional[tuple] = None
        self.nnz = sum(int(mem.mat.nnz) for mem in self.members)
        self._fns: dict = {}
        self._cat: Optional[tuple] = None
        self._cat_built = False

    @property
    def invs(self) -> tuple:
        """Per-term inverse permutations on device (lazy)."""
        if self._invs is None:
            self._invs = tuple(jnp.asarray(v) for v in self._invs_np)
        return self._invs

    def validate(self, *, raise_: bool = True) -> list:
        """Structural integrity check over every member block and term
        inverse (robust.guard.validate_composite). Returns the list of
        problem strings (empty when clean); raises IntegrityError instead
        when ``raise_`` is set."""
        from repro.robust import guard as _guard
        return _guard.validate_composite(self, raise_=raise_)

    # -- operand plumbing --------------------------------------------------
    def member_mats(self) -> tuple:
        return tuple(mem.mat for mem in self.members)

    def member_devs(self) -> tuple:
        return tuple(mem.device_operands() for mem in self.members)

    def fused_cat(self) -> Optional[tuple]:
        """ONE concatenated word-stream operand for the whole composite
        (lazy): every fused member's ``(words3d, ckpt)`` flattened into a
        single ``(words_cat, ckpt_cat, slices)`` pair of device buffers
        plus static slice metadata. The jitted dispatch streams one
        operand for all member blocks; members that carry no fused stream
        (SELL blocks, cursor/scan plans) keep their own operands. None
        when no member is fused."""
        if not self._cat_built:
            self._cat_built = True
            ws, cks, slices = [], [], []
            w_off = c_off = 0
            for mem in self.members:
                fz = None if mem.plan is None else mem.plan.fused
                if fz is None:
                    slices.append(None)
                    continue
                w3, ck = fz
                slices.append((w_off, tuple(w3.shape), c_off,
                               tuple(ck.shape)))
                ws.append(w3.reshape(-1))
                cks.append(ck.reshape(-1))
                w_off += int(np.prod(w3.shape))
                c_off += int(np.prod(ck.shape))
            # one fused member needs no concatenation — and the cat is a
            # real device copy next to the (possibly shared) member plans'
            # own streams, so only pay it when it actually merges operands
            if len(ws) >= 2:
                self._cat = (jnp.concatenate(ws), jnp.concatenate(cks),
                             tuple(slices))
        return self._cat

    def _devs_with_cat(self, devs, cat):
        """Rebuild per-member dev dicts from the concatenated word-stream
        operand (static slices — XLA fuses them into the consumers). The
        slice table is composite-static (``self._cat``); only the two
        buffers flow as jit arguments."""
        wcat, ckcat = cat
        slices = self._cat[2]
        out = []
        for dev, sl in zip(devs, slices):
            if sl is None:
                out.append(dev)
                continue
            w_off, wsh, c_off, csh = sl
            nd = dict(dev)
            nd["fused"] = (
                jax.lax.slice(wcat, (w_off,),
                              (w_off + int(np.prod(wsh)),)).reshape(wsh),
                jax.lax.slice(ckcat, (c_off,),
                              (c_off + int(np.prod(csh)),)).reshape(csh))
            out.append(nd)
        return tuple(out)

    # -- execution body ----------------------------------------------------
    def _execute(self, mats, devs, invs, xs, multi_rhs, cat=None):
        if cat is not None:
            devs = self._devs_with_cat(devs, cat)
        parts = [[] for _ in range(self.n_terms)]
        for mem, mat, dev in zip(self.members, mats, devs):
            t = mem.execute(mat, dev, xs[mem.x_index], multi_rhs=multi_rhs)
            parts[mem.term].append(t)
        y = None
        for term_parts, inv in zip(parts, invs):
            t_cat = (term_parts[0] if len(term_parts) == 1
                     else jnp.concatenate(term_parts))
            if self.pad_slot:
                pad = jnp.zeros((1,) + tuple(t_cat.shape[1:]), t_cat.dtype)
                t_cat = jnp.concatenate([t_cat, pad])
            # each covered row has exactly one term slot (unique indices;
            # with a pad slot the uncovered rows share it, so the hint is
            # only safe without one)
            yt = jnp.take(t_cat, inv, axis=0, mode="clip",
                          unique_indices=not self.pad_slot)
            y = yt if y is None else y + yt
        return y

    def execute_with(self, mats, devs, invs, xs, *,
                     multi_rhs: bool = False) -> jnp.ndarray:
        """Run the composition body with externally supplied operands
        inside an existing trace — the shard_map reuse hook. The
        distributed layer stacks every member's arrays along the mesh axis
        and calls this with each shard's slices; the composite's static
        decisions (member order, terms, per-member plan statics) are reused
        across shards.

        ``mats``/``devs``: per-member block views and device-buffer dicts;
        ``invs``: per-term inverse permutations; ``xs``: the input vectors
        (``xs[mem.x_index]`` feeds each member — index 1 is the
        halo-exchange pre-stage output in the distributed composition).
        """
        return self._execute(mats, devs, invs, xs, multi_rhs)

    # -- public dispatch ---------------------------------------------------
    def _dispatch(self, multi_rhs: bool):
        fn = self._fns.get(multi_rhs)
        if fn is None:
            fn = jax.jit(lambda mats, devs, invs, xs, cat, mr=multi_rhs:
                         self._execute(mats, devs, invs, xs, mr, cat=cat))
            self._fns[multi_rhs] = fn
        return fn

    def _run_args(self):
        """(mats, devs, invs, cat): with a concatenated word stream the
        per-member dev dicts drop their fused arrays — the single cat
        operand carries them all. Fused members ship their plan's
        placeholder-leaf matrix view (the body reads only codec statics),
        keeping the dispatch pytree small."""
        devs = self.member_devs()
        mats = tuple(mem.mat if mem.plan is None
                     else mem.plan._exec_mat(mem.mat)
                     for mem in self.members)
        cat = self.fused_cat()
        if cat is not None:
            devs = tuple(
                {**dev, "fused": None} if sl is not None else dev
                for dev, sl in zip(devs, cat[2]))
            cat = cat[:2]
        return mats, devs, self.invs, cat

    def _run(self, x: jnp.ndarray, multi_rhs: bool) -> jnp.ndarray:
        if self.n_inputs != 1:
            raise ValueError(
                "composite has members on input index > 0 (a distributed "
                "halo composition); drive it via execute_with")
        mats, devs, invs, cat = self._run_args()
        if isinstance(x, jax.core.Tracer):
            return self._execute(mats, devs, invs, (x,), multi_rhs,
                                 cat=cat)
        _obs.inc("composite.dispatch", composite=self.name,
                 kind="spmm" if multi_rhs else "spmv",
                 members=len(self.members), terms=self.n_terms)
        return self._dispatch(multi_rhs)(mats, devs, invs, (x,), cat)

    def spmv(self, x: jnp.ndarray) -> jnp.ndarray:
        """y = A x — one jitted dispatch over every member block."""
        return self._run(x, False)

    def spmm(self, x: jnp.ndarray) -> jnp.ndarray:
        """Y = A X for X: [m, nb] (every member's multi-RHS path)."""
        return self._run(x, True)

    @property
    def matvec(self):
        return self.spmv

    @property
    def shape(self):
        return (self.n, self.m)

    # -- unified plumbing --------------------------------------------------
    def warmup(self, nb: int = 0) -> "CompositePlan":
        """Trace the dispatch(es) ahead of the first real call (the serving
        engine's WarmupSpec contract)."""
        jax.block_until_ready(self.spmv(jnp.zeros((self.m,), jnp.float32)))
        if nb:
            jax.block_until_ready(
                self.spmm(jnp.zeros((self.m, nb), jnp.float32)))
        return self

    def memory_stats(self, *, halo: dict | None = None) -> dict:
        return composite_memory_stats(
            [(mem.label, mem.codec, mem.D,
              mem.block_n if mem.rows is None else len(mem.rows), mem.mat)
             for mem in self.members], halo=halo)

    def describe(self) -> dict:
        """Machine-readable composite summary (warmup logs, stores)."""
        return {
            "name": self.name, "n": self.n, "m": self.m,
            "terms": self.n_terms, "inputs": self.n_inputs,
            "members": [{
                "label": mem.label, "fmt": mem.fmt, "codec": mem.codec,
                "D": mem.D, "term": mem.term, "x_index": mem.x_index,
                "stored": mem.stored,
                "plan": None if mem.plan is None
                else mem.plan.describe()["variant"],
            } for mem in self.members],
        }

    def retile(self, member: int, tiles) -> None:
        """Install autotuned (sb, wb) winners into one member's plan and
        invalidate the composite dispatch (re-traces on next call)."""
        splan = self.members[member].plan
        if splan is None:
            raise ValueError(f"member {member} is a SELL block (no plan)")
        splan.retile(tiles)
        self._fns.clear()

    # -- constructors ------------------------------------------------------
    @classmethod
    def single(cls, mat, plan: kplan.SpMVPlan | None = None
               ) -> "CompositePlan":
        """The degenerate one-member composite: ``SpMVPlan`` (or a SELL
        matrix) as the single-member case of the composition engine."""
        if isinstance(mat, PackSELLMatrix):
            plan = plan or kplan.get_plan(mat)
            mem = CompositeMember(mat=mat, plan=plan, codec=mat.codec_name,
                                  D=mat.D, label=f"{mat.codec_name}/"
                                                 f"D={mat.D}")
        elif isinstance(mat, SELLMatrix):
            codec = {"float32": "fp32", "float64": "fp64"}.get(
                mat.value_dtype, mat.value_dtype)
            mem = CompositeMember(mat=mat, plan=None, codec=codec, D=0,
                                  label=codec)
        else:
            raise TypeError(f"cannot wrap {type(mat).__name__}")
        return cls([mem], n=mat.n, m=mat.m, name="single")

    @classmethod
    def from_classes(cls, a, classes, *, C: int = 32, sigma: int = 256,
                     name: str = "mixed") -> "CompositePlan":
        """Row-class composition over one CSR matrix: each ``(codec, D,
        rows)`` class becomes a member over its row submatrix (full column
        space — x is shared), all in one term. The MixedPackSELL layout."""
        a = a.tocsr()
        a.sort_indices()
        n = a.shape[0]
        members = []
        for cls_i in classes:
            codec, D, rows = cls_i
            rows = (np.arange(n, dtype=np.int64) if rows is None
                    else np.asarray(rows, dtype=np.int64))
            members.append(member_from_csr(
                a[rows], codec, D, C=C, sigma=sigma, rows=rows))
        return cls(members, n=n, m=a.shape[1], name=name)
