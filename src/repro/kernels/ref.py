"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth in kernel tests: the vectorized jnp SpMV paths in
``repro.core`` (which are themselves validated against dense numpy in
``tests/test_core_formats.py``), plus a direct dense oracle.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import codecs as cd
from repro.core.packsell import PackSELLMatrix, decode_to_dense, packsell_spmv_jnp
from repro.core.sell import SELLMatrix, sell_spmv_jnp


def packsell_spmv_ref(mat: PackSELLMatrix, x: jnp.ndarray) -> jnp.ndarray:
    return packsell_spmv_jnp(mat, x, compute_dtype=jnp.float32)


def sell_spmv_ref(mat: SELLMatrix, x: jnp.ndarray) -> jnp.ndarray:
    return sell_spmv_jnp(mat, x, compute_dtype=jnp.float32)


def packsell_spmv_dense_oracle(mat: PackSELLMatrix, x: np.ndarray) -> np.ndarray:
    """Slow exact oracle: decode to dense (quantized) and matvec in float64."""
    return decode_to_dense(mat) @ np.asarray(x, dtype=np.float64)
