"""Pallas TPU API compatibility shim.

The Pallas TPU surface renamed ``TPUCompilerParams`` → ``CompilerParams``
and moved ``dimension_semantics`` from plain strings to a
``GridDimensionSemantics`` enum across JAX releases. The kernels target
whichever spelling the installed JAX provides, so the same source runs on
JAX 0.4.x (this container ships 0.4.37) and on newer releases.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
_SEMANTICS_ENUM = getattr(pltpu, "GridDimensionSemantics", None)


def dimension_semantics(*kinds: str) -> tuple:
    """('parallel', 'arbitrary', ...) in whichever form this JAX accepts."""
    if _SEMANTICS_ENUM is None:
        return tuple(kinds)
    return tuple(getattr(_SEMANTICS_ENUM, k.upper()) for k in kinds)


def compiler_params(*kinds: str, **kw):
    """Build the TPU compiler-params object with the given grid semantics."""
    return _PARAMS_CLS(dimension_semantics=dimension_semantics(*kinds), **kw)
