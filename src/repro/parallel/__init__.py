"""Parallelism substrate: logical shardings, mesh helpers, collectives."""
from .sharding import (batch_axes, constrain, constrain_batch, current_mesh,  # noqa: F401
                       filter_spec, named_sharding, sanitize_spec,
                       tree_shardings, tree_shardings_shaped)
