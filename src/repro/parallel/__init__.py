"""Parallelism substrate: logical shardings, mesh helpers, collectives."""
from .sharding import (batch_axes, constrain, constrain_batch, current_mesh,  # noqa: F401
                       filter_spec, named_sharding, sanitize_spec,
                       shard_map_compat, tree_shardings,
                       tree_shardings_shaped)
