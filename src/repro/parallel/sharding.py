"""Mesh-aware sharding helpers.

All model code expresses layouts with *logical* PartitionSpecs over axis
names {"pod", "data", "model"}. ``constrain`` applies a sharding constraint
only when a mesh with those axes is active (no-op on a single device, so
smoke tests and the quickstart run unchanged), and ``filter_spec`` adapts
specs to whichever mesh (single- or multi-pod) is in scope.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def current_mesh() -> Mesh | None:
    try:
        m = jax._src.mesh.thread_resources.env.physical_mesh
        if m is None or m.empty:
            return None
        return m
    except Exception:
        return None


def batch_axes(mesh: Mesh | None = None):
    """The data-parallel axes: ('pod','data') on multi-pod, ('data',) else."""
    mesh = mesh or current_mesh()
    if mesh is None:
        return ()
    ax = [a for a in ("pod", "data") if a in mesh.axis_names]
    return tuple(ax)


def filter_spec(spec: P, mesh: Mesh | None = None) -> P:
    """Drop axis names not present in the mesh (adapts to any mesh shape)."""
    mesh = mesh or current_mesh()
    names = set(mesh.axis_names) if mesh is not None else set()

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(keep(e) for e in spec))


def _manual_axes() -> frozenset:
    """Axis names currently under shard_map manual control."""
    try:
        am = jax.sharding.get_abstract_mesh()
        ma = frozenset(getattr(am, "manual_axes", ()) or ())
        if ma:
            return ma
    except Exception:
        pass
    try:
        # JAX 0.4.x: shard_map binds its mesh axes in the global axis env
        from jax._src import core as _core
        return frozenset(_core.get_axis_env().axis_sizes)
    except Exception:
        return frozenset()


def _in_manual_context() -> bool:
    return bool(_manual_axes())


def constrain(x, spec: P):
    """with_sharding_constraint that degrades gracefully: no-op without a
    mesh (single-device tests); inside a shard_map manual region, manual
    axis names are dropped from the spec (constraints on the remaining
    auto axes still apply — partial-manual pod steps keep the TP/SP
    layout); if nothing remains, the constraint is skipped entirely."""
    mesh = current_mesh()
    if mesh is None:
        return x
    manual = _manual_axes()
    fs = filter_spec(spec, mesh)
    if manual:
        def drop(e):
            if e is None:
                return None
            names = e if isinstance(e, (tuple, list)) else (e,)
            kept = tuple(a for a in names if a not in manual)
            return kept if kept else None

        fs = P(*(drop(e) for e in fs))
        if all(e is None for e in fs):
            return x
        # inside shard_map the constraint must be expressed against the
        # context (abstract) mesh — pass the raw PartitionSpec
        return jax.lax.with_sharding_constraint(x, fs)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fs))


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on
    0.4.x, with replication checking disabled (``check_vma`` /
    ``check_rep``). ``axis_names`` restricts the manually-mapped axes; on
    0.4.x it maps to the complementary ``auto=`` set."""
    if hasattr(jax, "shard_map"):                         # JAX >= 0.6
        kw = {"check_vma": False}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm   # JAX 0.4.x
    kw = {"check_rep": False}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_shard_mesh(n_shards: int | None = None, *,
                    axis_name: str = "shards", devices=None) -> Mesh:
    """1-D mesh over (the first ``n_shards``) local devices — the device
    axis the distributed PackSELL layer partitions matrices across
    (``repro.distributed``). Defaults to every visible device."""
    devs = list(devices) if devices is not None else jax.devices()
    if n_shards is not None:
        if n_shards > len(devs):
            raise ValueError(f"n_shards={n_shards} > {len(devs)} devices "
                             "(run under XLA_FLAGS="
                             "--xla_force_host_platform_device_count=N)")
        devs = devs[:n_shards]
    return Mesh(np.array(devs), (axis_name,))


def constrain_batch(x):
    """Shard the leading (batch) dim over the DP axes."""
    mesh = current_mesh()
    if mesh is None or _in_manual_context():
        return x
    ax = batch_axes(mesh)
    if not ax:
        return x
    spec = P(ax, *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, filter_spec(spec, mesh))


def tree_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: named_sharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh cannot divide evenly (jit argument
    shardings require exact divisibility, e.g. batch=1 long-context decode)."""
    sizes = _axis_sizes(mesh)
    spec = filter_spec(spec, mesh)
    entries = []
    for i, e in enumerate(spec):
        if e is None or i >= len(shape):
            entries.append(None if i >= len(shape) else e)
            continue
        names = e if isinstance(e, (tuple, list)) else (e,)
        prod = 1
        for nm in names:
            prod *= sizes.get(nm, 1)
        entries.append(e if prod and shape[i] % prod == 0 else None)
    return P(*entries)


def tree_shardings_shaped(mesh: Mesh, spec_tree, shape_tree):
    """NamedShardings with per-leaf divisibility sanitation."""
    spec_leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda s: isinstance(s, P))
    shape_leaves = jax.tree.leaves(shape_tree)
    assert len(spec_leaves) == len(shape_leaves), \
        (len(spec_leaves), len(shape_leaves))
    out = [NamedSharding(mesh, sanitize_spec(s, sh.shape, mesh))
           for s, sh in zip(spec_leaves, shape_leaves)]
    return jax.tree.unflatten(treedef, out)
