"""Adaptive precision subsystem (DESIGN.md §8).

The paper's central axis is precision *agnosticism*: PackSELL gives
fine-grained control over the bit split between column deltas and values.
This package is the decision layer above the format — it chooses the split
instead of requiring the caller to:

* :mod:`~repro.precision.analyze` — per-matrix value/delta statistics, an
  a-priori quantization-error model per codec, and a cheap empirical probe.
* :mod:`~repro.precision.select` — turns an error budget into a
  :class:`~repro.precision.select.PrecisionPlan` (globally or per
  row-class), with a machine-readable rationale.
* :mod:`~repro.precision.mixed` — :class:`~repro.precision.mixed.MixedPackSELL`,
  rows partitioned by required precision into PackSELL blocks at different
  codecs, composed as one jitted operator.
* :mod:`~repro.precision.store` — on-disk JSON autotune store keyed by a
  matrix fingerprint, merged with ``(sb, wb)`` retile winners, so serving
  restarts skip re-analysis.

The end-to-end mixed-precision solve (``solvers/cg.py::adaptive_pcg``)
consumes the plan's tier ladder: low-precision inner PCG, residual
stagnation detection, codec-tier promotion mid-solve.
"""
from .analyze import (AnalysisReport, CandidateReport, analyze_matrix,  # noqa: F401
                      matrix_stats, model_error, probe_error,
                      probe_error_rows)
from .mixed import MixedPackSELL  # noqa: F401
from .select import (PrecisionClass, PrecisionPlan, select_codec,  # noqa: F401
                     tier_ladder)
from .store import PrecisionStore, matrix_fingerprint  # noqa: F401
