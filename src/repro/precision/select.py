"""Codec selection: error budget → :class:`PrecisionPlan` (DESIGN.md §8.2).

Policy
------
Candidates are scored by :func:`~repro.precision.analyze.analyze_matrix`
and ranked by storage cost ascending — stored words ``nnz + dummies(D)``,
i.e. the delta-feasibility constraint priced in (a small ``D`` frees
mantissa bits but forces dummy words on long-gap rows; this is exactly the
paper's value/delta bit-allocation axis). The selector walks the ranking
and picks the FIRST candidate whose measured probe error fits
``safety × error_budget`` (the a-priori model bound is a pre-filter only:
it decides which candidates are worth probing, the probe decides). Ties in
cost are broken toward the smaller model bound, so ``e8m`` beats ``fp16``
at equal words when the value range strains fp16.

``mode='rows'`` does the same per row: every row gets the cheapest
candidate whose deterministic row-wise error bound
(:func:`~repro.precision.analyze.row_error_bound` — valid for every x,
unlike a sampled probe) fits the budget, the resulting
classes are coalesced to ``max_classes`` (small classes are bumped UP in
precision, never down, so the budget still holds), and the outcome is a
multi-class plan for :class:`~repro.precision.mixed.MixedPackSELL`.

Every decision — per-candidate metrics, rejection reasons, the winner —
lands in ``PrecisionPlan.rationale`` (machine-readable; persisted by
:mod:`repro.precision.store`).
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np
import scipy.sparse as sp

from . import analyze as an

# Default candidate ladder: the E8MY sweep over the delta/value split plus
# the two 16-bit embeddings. Cost-ranked at selection time.
DEFAULT_CANDIDATES = (
    ("e8m", 15), ("e8m", 12), ("e8m", 8), ("e8m", 4), ("e8m", 1),
    ("bf16", 15), ("fp16", 15),
)

#: The always-feasible fallback: uncompressed fp32 (SELL / plan passthrough).
FP32_CLASS = ("fp32", 0)


@dataclasses.dataclass(frozen=True)
class PrecisionClass:
    """One (codec, D) assignment, optionally restricted to a row set."""

    codec: str
    D: int
    rows: tuple | None = None     # None: all rows (global plan)

    @property
    def label(self) -> str:
        if self.codec == "fp32":
            return "fp32"
        return f"{self.codec}/D={self.D}"

    @property
    def sub32(self) -> bool:
        """True when the stored value representation is below 32 bits."""
        return self.codec != "fp32"

    def n_rows(self) -> int | None:
        return None if self.rows is None else len(self.rows)

    def to_dict(self) -> dict:
        return {"codec": self.codec, "D": self.D,
                "rows": None if self.rows is None else list(map(int,
                                                                self.rows))}

    @classmethod
    def from_dict(cls, d: dict) -> "PrecisionClass":
        rows = d.get("rows")
        return cls(codec=d["codec"], D=int(d["D"]),
                   rows=None if rows is None else tuple(int(r)
                                                        for r in rows))


@dataclasses.dataclass(frozen=True)
class PrecisionPlan:
    """The machine-readable outcome of codec selection.

    ``classes`` are ordered lowest-precision first; a global plan has one
    class with ``rows=None``. ``rationale`` holds every candidate's
    scorecard and the decision trail.
    """

    mode: str                       # 'global' | 'rows'
    classes: tuple                  # tuple[PrecisionClass, ...]
    error_budget: float
    rationale: dict
    fingerprint: str | None = None

    @property
    def primary(self) -> PrecisionClass:
        return self.classes[0]

    @property
    def is_mixed(self) -> bool:
        return self.mode == "rows" and len(self.classes) > 1

    def to_dict(self) -> dict:
        return {"mode": self.mode,
                "classes": [c.to_dict() for c in self.classes],
                "error_budget": self.error_budget,
                "rationale": self.rationale,
                "fingerprint": self.fingerprint}

    @classmethod
    def from_dict(cls, d: dict) -> "PrecisionPlan":
        return cls(mode=d["mode"],
                   classes=tuple(PrecisionClass.from_dict(c)
                                 for c in d["classes"]),
                   error_budget=float(d["error_budget"]),
                   rationale=d.get("rationale", {}),
                   fingerprint=d.get("fingerprint"))

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s: str) -> "PrecisionPlan":
        return cls.from_dict(json.loads(s))


def _rank(reports) -> list:
    """Cost-ascending candidate order: (words, model_err) lexicographic."""
    return sorted(reports, key=lambda r: (r.words, r.model_err))


def select_codec(a: sp.csr_matrix, error_budget: float, *,
                 mode: str = "global", candidates=DEFAULT_CANDIDATES,
                 sigma: int = 256, n_probes: int = 3, seed: int = 0,
                 safety: float = 0.5, max_classes: int = 2,
                 fingerprint: str | None = None) -> PrecisionPlan:
    """Pick ``(codec, D)`` (globally or per row-class) for ``a``.

    The chosen candidate's measured probe error is at most
    ``safety × error_budget`` (default 0.5: headroom for probe-vector
    variance, so independent probes still respect the budget). Falls back
    to uncompressed fp32 when no packed codec fits.
    """
    if mode not in ("global", "rows"):
        raise ValueError(f"mode={mode!r} not in ('global', 'rows')")
    if not (error_budget > 0):
        raise ValueError(f"error_budget must be positive, got {error_budget}")
    a = a.tocsr()
    report = an.analyze_matrix(a, candidates, sigma=sigma,
                               n_probes=n_probes, seed=seed,
                               error_budget=error_budget)
    ranked = _rank(report.candidates)
    threshold = safety * error_budget
    trail, winner = [], None
    for cand in ranked:
        entry = cand.to_dict()
        if cand.probe_err is None:
            entry["decision"] = "rejected:model-bound-over-budget"
        elif cand.probe_err > threshold:
            entry["decision"] = ("rejected:probe-error-over-threshold"
                                 f" ({cand.probe_err:.3e} > {threshold:.3e})")
        elif winner is None:
            entry["decision"] = "selected:cheapest-within-budget"
            winner = cand
        else:
            entry["decision"] = "skipped:costlier-than-winner"
        trail.append(entry)

    rationale = {
        "budget": error_budget, "safety": safety, "threshold": threshold,
        "mode": mode, "n_probes": n_probes, "seed": seed, "sigma": sigma,
        "matrix": {"n": report.stats.n, "m": report.stats.m,
                   "nnz": report.stats.nnz,
                   "max_delta": report.stats.max_delta,
                   "dyn_range": report.stats.dyn_range,
                   "max_abs": report.stats.max_abs},
        "candidates": trail,
    }

    if mode == "global":
        if winner is None:
            rationale["fallback"] = "no packed codec within budget -> fp32"
            classes = (PrecisionClass(*FP32_CLASS),)
        else:
            classes = (PrecisionClass(winner.codec, winner.D),)
        return PrecisionPlan(mode="global", classes=classes,
                             error_budget=error_budget, rationale=rationale,
                             fingerprint=fingerprint)

    return _select_rows(a, report, ranked, threshold, error_budget,
                        rationale, n_probes, seed, max_classes, fingerprint)


def _select_rows(a, report, ranked, threshold, error_budget, rationale,
                 n_probes, seed, max_classes, fingerprint) -> PrecisionPlan:
    """Per-row assignment: cheapest candidate whose row-wise probe error
    fits, coalesced to ``max_classes`` classes (bumping UP in precision)."""
    n = a.shape[0]
    assign = np.full(n, -1, dtype=np.int64)       # index into `viable`
    viable = [c for c in ranked if c.probe_err is not None]
    for ci, cand in enumerate(viable):
        # deterministic per-row bound: holds for EVERY x, so independent
        # probes always respect the budget (the global mode's probe only
        # certifies sampled vectors; per-row noise is too high for that)
        errs = an.row_error_bound(a, cand.codec, cand.D)
        take = (assign < 0) & (errs <= threshold)
        assign[take] = ci
        if not np.any(assign < 0):
            break

    # unassigned rows -> fp32 passthrough class (index len(viable))
    fp32_idx = len(viable)
    assign[assign < 0] = fp32_idx

    def acc_err(ci: int) -> float:   # model accuracy of a class index
        return 0.0 if ci == fp32_idx else viable[ci].model_err

    # Coalesce to <= max_classes: keep the most-populated classes (always
    # including the most accurate one, so every drop has a bump target),
    # then bump each dropped class UP to the least-accurate kept class that
    # is still at least as accurate — row errors can only shrink, so the
    # budget keeps holding.
    used, counts = np.unique(assign, return_counts=True)
    if len(used) > max_classes:
        by_pop = used[np.argsort(-counts)].tolist()
        most_accurate = min(used.tolist(), key=acc_err)
        kept = by_pop[:max_classes]
        if most_accurate not in kept:
            kept[-1] = most_accurate
        kept = set(kept)
        for drop in used:
            if drop in kept:
                continue
            ok = [k for k in kept if acc_err(k) <= acc_err(drop)]
            target = max(ok, key=acc_err) if ok else most_accurate
            assign[assign == drop] = target

    classes = []
    class_info = []
    for ci in np.unique(assign):
        rows = tuple(int(r) for r in np.nonzero(assign == ci)[0])
        if ci == fp32_idx:
            pc = PrecisionClass("fp32", 0, rows=rows)
            class_info.append({"codec": "fp32", "D": 0,
                               "n_rows": len(rows)})
        else:
            cand = viable[ci]
            pc = PrecisionClass(cand.codec, cand.D, rows=rows)
            class_info.append({"codec": cand.codec, "D": cand.D,
                               "n_rows": len(rows),
                               "model_err": cand.model_err})
        classes.append(pc)
    # lowest precision (largest model error) first
    classes.sort(key=lambda c: 0.0 if c.codec == "fp32"
                 else -an.model_error(c.codec, c.D, report.stats))
    rationale["row_classes"] = class_info
    return PrecisionPlan(mode="rows", classes=tuple(classes),
                         error_budget=error_budget, rationale=rationale,
                         fingerprint=fingerprint)


# ---------------------------------------------------------------------------
# Tier ladder for the adaptive solver
# ---------------------------------------------------------------------------


def tier_ladder(plan: PrecisionPlan, *, top: str = "fp32") -> list:
    """Promotion ladder for ``solvers.cg.adaptive_pcg``: the plan's chosen
    codec first, then strictly more accurate packed tiers, ending at the
    uncompressed ``top`` tier. Each entry is a :class:`PrecisionClass`
    (``rows=None`` — tiers are whole-operator)."""
    first = plan.primary
    if first.codec == "fp32":
        return [PrecisionClass(top, 0)]   # fallback plan: nothing to promote
    ladder = [PrecisionClass(first.codec, first.D)]
    first_err = _tier_err(first)
    for codec, D in (("e8m", 8), ("e8m", 4), ("e8m", 1)):
        c = PrecisionClass(codec, D)
        if _tier_err(c) < 0.25 * first_err:
            ladder.append(c)
            first_err = _tier_err(c)
    ladder.append(PrecisionClass(top, 0))
    return ladder


def _tier_err(c: PrecisionClass) -> float:
    return an.ulp_bound(c.codec, c.D)


def operator_kind(c: PrecisionClass, *, engine: str = "plan") -> str:
    """The ``solvers.operators.OperatorSet`` kind string of a tier."""
    if c.codec == "fp32":
        return "fp32"
    if c.codec in ("fp16", "bf16"):
        return f"{engine}_{c.codec}"
    if c.codec == "e8m":
        return f"{engine}_e8m{c.D}"
    raise ValueError(f"no OperatorSet kind for codec {c.codec!r}")


def build_tier_matvecs(ops, ladder, *, engine: str = "plan"):
    """Materialize a ladder against an ``OperatorSet``: returns
    ``(matvecs, labels, sub32_mask)`` — the inputs of ``adaptive_pcg``."""
    matvecs = [ops.matvec(operator_kind(c, engine=engine)) for c in ladder]
    labels = [c.label for c in ladder]
    sub32 = np.array([c.sub32 for c in ladder], dtype=bool)
    return matvecs, labels, sub32
