"""Matrix analysis for codec selection: statistics, error model, probes.

Three layers, each cheap enough to run at format-construction time:

1. :func:`matrix_stats` — vectorized numpy pass over the CSR stream: value
   dynamic range (global and per row), the delta distribution under the
   paper's σ-block base-offset convention (max |Δcol|, dummy-word counts for
   every candidate ``D``), and row-regularity numbers.
2. :func:`model_error` — the a-priori quantization-error model per codec
   (DESIGN.md §8.1): a relative ulp bound for the float codecs
   (``2^-(Y+1)`` for E8MY, ``2^-11``/``2^-8`` for fp16/bf16 with
   range-clipping penalties where the value range leaves the codec's
   representable range) and an absolute-step bound for ``fixed<F>``.
3. :func:`probe_error` — the empirical validation of the model:
   ``||A_q x − A x|| / ||A x||`` on seeded probe vectors, with ``A_q`` the
   element-wise codec round-trip of ``A`` (quantization is element-wise, so
   the probe needs no PackSELL build; dummy words are exact by
   construction).

:func:`analyze_matrix` bundles all three into an :class:`AnalysisReport`
for a candidate list — the input :mod:`repro.precision.select` ranks.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import scipy.sparse as sp

from repro.core import codecs as cd
from repro.core import delta as de

# fp32 exponent range landmarks
_F32_MIN_NORMAL = 2.0 ** -126
_F16_MAX = 65504.0
_F16_MIN_NORMAL = 2.0 ** -14
_F16_MIN_SUBNORMAL = 2.0 ** -24


@dataclasses.dataclass(frozen=True)
class MatrixStats:
    """Host-side value/delta statistics of one CSR matrix."""

    n: int
    m: int
    nnz: int
    k_left: int
    max_abs: float
    min_abs_nz: float           # smallest nonzero magnitude (inf if empty)
    dyn_range: float            # max_abs / min_abs_nz
    has_subnormal: bool         # any |v| below the fp32 normal range
    row_max_abs: np.ndarray     # float64[n]
    row_min_abs_nz: np.ndarray  # float64[n] (inf for empty rows)
    row_nnz: np.ndarray         # int64[n]
    max_delta: int              # largest column delta under the σ-block d0
    deltas_sorted: np.ndarray   # int64[nnz] ascending (dummy counting)
    sigma: int

    def dummy_words(self, D: int) -> int:
        """Dummy words needed at delta width ``D`` (chained for >31-bit
        gaps) — the delta-feasibility cost of a candidate. Delegates the
        chain-length rule to :func:`repro.core.delta.dummies_for_deltas`
        so pricing can never diverge from what ``from_csr`` emits."""
        return int(de.dummies_for_deltas(self.deltas_sorted, D).sum())

    def words(self, D: int) -> int:
        """Stored words (real + dummy) at delta width ``D``."""
        return self.nnz + self.dummy_words(D)


def matrix_stats(a: sp.csr_matrix, *, sigma: int = 256) -> MatrixStats:
    """One vectorized pass: value-range and delta statistics of ``a``."""
    a = a.tocsr()
    a.sort_indices()
    n, m = a.shape
    data = np.abs(a.data.astype(np.float64))
    indptr = a.indptr.astype(np.int64)
    indices = a.indices.astype(np.int64)
    row_nnz = np.diff(indptr)

    nz = data > 0
    max_abs = float(data.max(initial=0.0))
    min_abs_nz = float(data[nz].min()) if nz.any() else math.inf
    row_max_abs = np.zeros(n)
    row_min_abs_nz = np.full(n, math.inf)
    rows_of = np.repeat(np.arange(n), row_nnz)
    np.maximum.at(row_max_abs, rows_of, data)
    np.minimum.at(row_min_abs_nz, rows_of[nz], data[nz])

    k_left = de.lower_bandwidth(indptr, indices, n)
    d0 = de.d0_for_rows(n, sigma, k_left)
    deltas, _, _ = de.encode_rows(indptr, indices, d0, D=31)
    deltas_sorted = np.sort(deltas)

    return MatrixStats(
        n=n, m=m, nnz=int(a.nnz), k_left=k_left,
        max_abs=max_abs, min_abs_nz=min_abs_nz,
        dyn_range=(max_abs / min_abs_nz if nz.any() and min_abs_nz > 0
                   else 1.0),
        has_subnormal=bool(nz.any() and min_abs_nz < _F32_MIN_NORMAL),
        row_max_abs=row_max_abs, row_min_abs_nz=row_min_abs_nz,
        row_nnz=row_nnz.astype(np.int64),
        max_delta=int(deltas_sorted[-1]) if len(deltas_sorted) else 0,
        deltas_sorted=deltas_sorted, sigma=sigma)


# ---------------------------------------------------------------------------
# A-priori error model (DESIGN.md §8.1)
# ---------------------------------------------------------------------------


def ulp_bound(codec_name: str, D: int) -> float:
    """Stats-free relative RNE half-ulp bound of a codec — the single
    source of the per-codec constants (``model_error`` degrades it with
    range penalties; ``select._tier_err`` orders promotion ladders)."""
    if codec_name == "fp32":
        return 0.0
    if codec_name == "e8m":
        return 2.0 ** -(23 - D)          # Y = 22 - D mantissa bits kept
    if codec_name == "bf16":
        return 2.0 ** -8                 # 7 fraction bits
    if codec_name == "fp16":
        return 2.0 ** -11                # 10 fraction bits
    return math.inf                      # fixed<F>: absolute, not relative


def model_error(codec_name: str, D: int, stats: MatrixStats) -> float:
    """A-priori element-wise relative quantization-error bound.

    Float codecs: the ulp bound of the truncated format, degraded to 1.0
    (no guarantee) when the matrix's value range leaves the codec's normal
    range, and to ``inf`` when values overflow the representable range
    entirely (fp16/fixed clipping). Fixed point: absolute step ``2^-F``
    turned relative via the smallest nonzero magnitude.
    """
    if codec_name == "fp32":
        return 0.0
    if codec_name in ("e8m", "bf16"):
        if stats.has_subnormal:          # mantissa truncation of subnormals
            return 1.0                   # has no relative-error guarantee
        return ulp_bound(codec_name, D)
    if codec_name == "fp16":
        if stats.max_abs > _F16_MAX:
            return math.inf              # overflow clips to inf
        bound = ulp_bound(codec_name, D)
        if stats.min_abs_nz < _F16_MIN_SUBNORMAL:
            return 1.0                   # flushed to zero
        if stats.min_abs_nz < _F16_MIN_NORMAL:
            # subnormal fp16: absolute step 2^-24 relative to the value
            bound = max(bound, _F16_MIN_SUBNORMAL / (2 * stats.min_abs_nz))
        return min(bound, 1.0)
    if codec_name.startswith("fixed"):
        frac = int(codec_name[len("fixed"):])
        V = cd.vbits_for(D)
        if stats.max_abs >= 2.0 ** (V - 1 - frac):
            return math.inf              # range clipping
        step = 2.0 ** -frac
        if not math.isfinite(stats.min_abs_nz):
            return 0.0
        return min(0.5 * step / stats.min_abs_nz, 1.0) if stats.min_abs_nz \
            else 1.0
    raise ValueError(f"unknown codec {codec_name!r}")


# ---------------------------------------------------------------------------
# Empirical probe
# ---------------------------------------------------------------------------


def _quantized(a: sp.csr_matrix, codec_name: str, D: int) -> sp.csr_matrix:
    if codec_name == "fp32":
        aq = a.copy()
        aq.data = a.data.astype(np.float32)
        return aq
    codec = cd.make_codec(codec_name)
    aq = a.copy()
    aq.data = cd.quantize_np(a.data.astype(np.float32), codec, D)
    return aq


def _probe_vectors(m: int, n_probes: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n_probes, m))


def _probe_context(a: sp.csr_matrix, n_probes: int, seed: int):
    """Candidate-independent probe precomputation: the float64 matrix,
    the probe vectors, and the reference ``||A x||`` norms — shared by
    every candidate in :func:`analyze_matrix` (one conversion + one
    reference SpMV per probe instead of one per candidate)."""
    a64 = a.astype(np.float64)
    xs = _probe_vectors(a.shape[1], n_probes, seed)
    ax_norms = [max(float(np.linalg.norm(a64 @ x)), 1e-300) for x in xs]
    return a64, xs, ax_norms


def probe_error(a: sp.csr_matrix, codec_name: str, D: int, *,
                n_probes: int = 3, seed: int = 0, _ctx=None) -> float:
    """max over seeded probes of ``||A_q x − A x||₂ / ||A x||₂``."""
    a64, xs, ax_norms = _ctx or _probe_context(a, n_probes, seed)
    e = _quantized(a, codec_name, D).astype(np.float64) - a64
    worst = 0.0
    for x, axn in zip(xs, ax_norms):
        err = float(np.linalg.norm(e @ x)) / axn
        if not np.isfinite(err):
            # range overflow quantizes to ±inf, so e @ x is inf/nan —
            # and max(0.0, nan) would silently report a PERFECT probe;
            # an out-of-range codec certifies nothing
            return float("inf")
        worst = max(worst, err)
    return worst


def row_error_bound(a: sp.csr_matrix, codec_name: str, D: int) -> np.ndarray:
    """Deterministic per-row relative error bound (float64[n]).

    ``max_j |q(a_ij) − a_ij| / |a_ij|`` per row: since
    ``|(A_q − A) x|_i ≤ max_j(|E_ij|/|A_ij|) · (|A| |x|)_i`` for EVERY x,
    this bounds the row-wise probe error of any probe vector — the
    guarantee per-row-class selection needs (a sampled probe would only
    bound the sampled x's)."""
    a = a.tocsr()
    e = np.abs(_quantized(a, codec_name, D).data.astype(np.float64)
               - a.data.astype(np.float64))
    da = np.abs(a.data.astype(np.float64))
    ratio = np.where(da > 0, e / np.maximum(da, 1e-300), 0.0)
    out = np.zeros(a.shape[0])
    rows_of = np.repeat(np.arange(a.shape[0]), np.diff(a.indptr))
    np.maximum.at(out, rows_of, ratio)
    return out


def probe_error_rows(a: sp.csr_matrix, codec_name: str, D: int, *,
                     n_probes: int = 3, seed: int = 0) -> np.ndarray:
    """Per-row relative probe error: max over probes of
    ``|(A_q − A) x|_i / (|A| |x|)_i`` — the row-wise backward-error
    analogue used by per-row-class selection."""
    a64 = a.astype(np.float64)
    e = _quantized(a, codec_name, D).astype(np.float64) - a64
    aabs = abs(a64)
    worst = np.zeros(a.shape[0])
    for x in _probe_vectors(a.shape[1], n_probes, seed):
        denom = aabs @ np.abs(x)
        err = np.abs(e @ x) / np.maximum(denom, 1e-300)
        np.maximum(worst, err, out=worst)
    return worst


# ---------------------------------------------------------------------------
# Bundled report
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CandidateReport:
    """One (codec, D) candidate's full scorecard."""

    codec: str
    D: int
    value_bits: int
    words: int                  # nnz + dummy words at this D
    dummy_words: int
    bytes_per_nnz: float        # 4 * words / nnz (bucket padding excluded)
    model_err: float
    probe_err: float | None     # None when the probe was skipped

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for k in ("model_err", "probe_err"):   # JSON has no inf
            if d[k] is not None and not math.isfinite(d[k]):
                d[k] = 1e308
        return d


@dataclasses.dataclass(frozen=True)
class AnalysisReport:
    """Stats + scored candidates for one matrix (selection input)."""

    stats: MatrixStats
    candidates: tuple            # tuple[CandidateReport, ...]
    n_probes: int
    seed: int


def _candidate_value_bits(codec_name: str, D: int) -> int:
    if codec_name == "fp32":
        return 32
    return int(cd.make_codec(codec_name).value_bits(D))


def analyze_matrix(a: sp.csr_matrix, candidates, *, sigma: int = 256,
                   n_probes: int = 3, seed: int = 0,
                   probe_skip_factor: float = 100.0,
                   error_budget: float | None = None) -> AnalysisReport:
    """Score every ``(codec, D)`` candidate on ``a``.

    The probe (the expensive part: one sparse matvec pair per probe vector)
    is skipped for candidates whose a-priori model bound already exceeds
    ``probe_skip_factor × error_budget`` — they cannot be selected, so the
    measurement would be wasted.
    """
    a = a.tocsr()
    stats = matrix_stats(a, sigma=sigma)
    ctx = None          # built lazily: all-skipped analyses never pay it
    reports = []
    for codec_name, D in candidates:
        if codec_name != "fp32":
            obj = cd.make_codec(codec_name)
            if not (obj.min_D <= D <= obj.max_D):
                continue
        mod = model_error(codec_name, D, stats)
        skip = (error_budget is not None
                and mod > probe_skip_factor * error_budget)
        if skip:
            perr = None
        else:
            ctx = ctx or _probe_context(a, n_probes, seed)
            perr = probe_error(a, codec_name, D, _ctx=ctx)
        dummy = 0 if codec_name == "fp32" else stats.dummy_words(D)
        words = stats.nnz + dummy
        reports.append(CandidateReport(
            codec=codec_name, D=D,
            value_bits=_candidate_value_bits(codec_name, D),
            words=words, dummy_words=dummy,
            bytes_per_nnz=4.0 * words / max(stats.nnz, 1),
            model_err=mod, probe_err=perr))
    return AnalysisReport(stats=stats, candidates=tuple(reports),
                          n_probes=n_probes, seed=seed)
