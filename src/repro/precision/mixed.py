"""MixedPackSELL: one operator, rows split across codecs (DESIGN.md §8.3).

A per-row-class :class:`~repro.precision.select.PrecisionPlan` partitions
the rows by required precision. Each class becomes its own PackSELL block
(built over that class's row submatrix — full column space, so x is shared)
at its own ``(codec, D)``; an ``fp32`` class becomes an uncompressed SELL
block. The blocks are composed exactly like the distributed layer's
local/remote block pair (``distributed/plan.py``): one jitted dispatch runs
every block's :class:`~repro.kernels.plan.SpMVPlan` body via
``SpMVPlan.execute_with`` in ``permuted=True`` (stored-row) mode,
concatenates the block outputs, and applies ONE precomputed global
inverse-permutation gather — no per-block scatters, no per-block dispatch.

``memory_stats()`` reports the blended bytes/nnz across blocks plus the
per-class breakdown (the mixed analogue of
:meth:`~repro.core.packsell.PackSELLMatrix.memory_stats`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.core import packsell as pk
from repro.core import sell as sl
from repro.kernels import plan as kplan

from .select import PrecisionPlan


@dataclasses.dataclass
class _Block:
    """One row-class block: a PackSELL (or SELL fp32) sub-operator."""

    codec: str
    D: int
    rows: np.ndarray             # int64[n_b] global rows, ascending
    mat: object                  # PackSELLMatrix | SELLMatrix
    plan: object                 # SpMVPlan | None (fp32/SELL block)
    stored: int                  # stored output slots this block emits


class MixedPackSELL:
    """Rows partitioned by precision class into stacked format blocks.

    Built from a CSR matrix and a ``mode='rows'`` (or global)
    :class:`PrecisionPlan`. Use :meth:`spmv` / :meth:`spmm` or the
    ``matvec`` callable; both run one jitted dispatch.
    """

    def __init__(self, a: sp.csr_matrix, plan: PrecisionPlan, *,
                 C: int = 32, sigma: int = 256):
        a = a.tocsr()
        a.sort_indices()
        self.n, self.m = a.shape
        self.nnz = int(a.nnz)
        self.pplan = plan
        self.C, self.sigma = C, sigma

        covered = np.zeros(self.n, dtype=bool)
        self.blocks: list[_Block] = []
        for cls in plan.classes:
            rows = (np.arange(self.n, dtype=np.int64) if cls.rows is None
                    else np.asarray(cls.rows, dtype=np.int64))
            if np.any(covered[rows]):
                raise ValueError("precision classes overlap in rows")
            covered[rows] = True
            sub = a[rows]                       # row submatrix, all columns
            if cls.codec == "fp32":
                mat = sl.from_csr(sub, C=C, sigma=sigma,
                                  value_dtype="float32")
                blk = _Block(cls.codec, cls.D, rows, mat, None, len(rows))
            else:
                mat = pk.from_csr(sub, C=C, sigma=sigma, D=cls.D,
                                  codec=cls.codec)
                splan = kplan.get_plan(mat)
                blk = _Block(cls.codec, cls.D, rows, mat, splan,
                             splan.total_stored)
            self.blocks.append(blk)
        if not np.all(covered):
            raise ValueError(
                f"precision classes cover {int(covered.sum())} of "
                f"{self.n} rows; every row needs a class")

        self._inv = jnp.asarray(self._build_global_inverse())
        self._fns: dict = {}

    # ------------------------------------------------------------------
    def _build_global_inverse(self) -> np.ndarray:
        """inv[r] = slot of global row r in the concatenated block
        outputs — the mixed analogue of ``SpMVPlan.inv_cat``."""
        inv = np.zeros(self.n, dtype=np.int32)
        off = 0
        for blk in self.blocks:
            if blk.plan is None:
                # SELL block output is already in block-row order
                inv[blk.rows] = off + np.arange(len(blk.rows),
                                                dtype=np.int32)
            else:
                out = np.asarray(blk.plan.outrow_cat)
                valid = out < len(blk.rows)
                slots = np.nonzero(valid)[0].astype(np.int32)
                inv[blk.rows[out[valid]]] = off + slots
            off += blk.stored
        return inv

    def _mats(self) -> tuple:
        return tuple(blk.mat for blk in self.blocks)

    def _devs(self) -> tuple:
        return tuple({} if blk.plan is None else
                     blk.plan._device_operands() for blk in self.blocks)

    def _execute(self, mats, devs, inv, x, multi_rhs):
        xc = x.astype(jnp.float32)
        parts = []
        for blk, mat, dev in zip(self.blocks, mats, devs):
            if blk.plan is None:
                if multi_rhs:
                    # SELL spmv is single-RHS; map over columns
                    t = jax.vmap(lambda col, m_=mat: sl.sell_spmv_jnp(
                        m_, col, jnp.float32), in_axes=1, out_axes=1)(xc)
                else:
                    t = sl.sell_spmv_jnp(mat, xc, jnp.float32)
                parts.append(t)
            else:
                t = blk.plan.execute_with(mat, dev, xc, permuted=True,
                                          multi_rhs=multi_rhs)
                parts.append(t)
        t_cat = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
        return jnp.take(t_cat, inv, axis=0)

    def _dispatch(self, multi_rhs: bool):
        fn = self._fns.get(multi_rhs)
        if fn is None:
            fn = jax.jit(lambda mats, devs, inv, x,
                         mr=multi_rhs: self._execute(mats, devs, inv, x, mr))
            self._fns[multi_rhs] = fn
        return fn

    # ------------------------------------------------------------------
    def spmv(self, x: jnp.ndarray) -> jnp.ndarray:
        """y = A x with each row computed at its class's precision."""
        if isinstance(x, jax.core.Tracer):
            return self._execute(self._mats(), self._devs(), self._inv, x,
                                 False)
        return self._dispatch(False)(self._mats(), self._devs(), self._inv,
                                     x)

    def spmm(self, x: jnp.ndarray) -> jnp.ndarray:
        """Y = A X for X: [m, nb]."""
        if isinstance(x, jax.core.Tracer):
            return self._execute(self._mats(), self._devs(), self._inv, x,
                                 True)
        return self._dispatch(True)(self._mats(), self._devs(), self._inv,
                                    x)

    @property
    def matvec(self):
        return self.spmv

    @property
    def shape(self):
        return (self.n, self.m)

    # ------------------------------------------------------------------
    def memory_stats(self) -> dict:
        """Blended memory profile: total bytes, bytes/nnz, and the
        per-class breakdown."""
        per_class = []
        total_bytes = 0
        for blk in self.blocks:
            st = blk.mat.memory_stats()
            b = int(st.get("packsell_bytes") or st.get("sell_bytes") or 0)
            nnz_b = int(blk.mat.nnz)
            per_class.append({
                "codec": blk.codec, "D": blk.D, "rows": len(blk.rows),
                "bytes": b, "nnz": nnz_b,
                "bytes_per_nnz": b / max(nnz_b, 1)})
            total_bytes += b
        return {
            "mixed_bytes": total_bytes,
            "bytes_per_nnz": total_bytes / max(self.nnz, 1),
            "nnz": self.nnz, "n": self.n, "m": self.m,
            "classes": per_class,
        }

    def warmup(self, nb: int = 0) -> "MixedPackSELL":
        """Trace the dispatch(es) ahead of the first real call."""
        jax.block_until_ready(self.spmv(jnp.zeros((self.m,), jnp.float32)))
        if nb:
            jax.block_until_ready(
                self.spmm(jnp.zeros((self.m, nb), jnp.float32)))
        return self
