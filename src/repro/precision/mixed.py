"""MixedPackSELL: one operator, rows split across codecs (DESIGN.md §8.3).

A per-row-class :class:`~repro.precision.select.PrecisionPlan` partitions
the rows by required precision. Each class becomes its own PackSELL block
(built over that class's row submatrix — full column space, so x is shared)
at its own ``(codec, D)``; an ``fp32`` class becomes an uncompressed SELL
block.

Since PR 4 this class is a THIN wrapper over the shared block-composition
engine, :class:`~repro.kernels.composite.CompositePlan` (DESIGN.md §9):
every class is one composite member in a single term — one jitted dispatch
runs every member's stored-row body, and ONE precomputed global
inverse-permutation gather produces y. The bespoke dispatch/blend code this
module used to carry (a re-implementation of the distributed layer's
local/remote composition) is gone; ``memory_stats`` is the composite blend
re-keyed to the historical per-class layout.
"""
from __future__ import annotations

import jax.numpy as jnp
import scipy.sparse as sp

from repro.kernels import composite as kc

from .select import PrecisionPlan


class MixedPackSELL:
    """Rows partitioned by precision class into stacked format blocks.

    Built from a CSR matrix and a ``mode='rows'`` (or global)
    :class:`PrecisionPlan`. Use :meth:`spmv` / :meth:`spmm` or the
    ``matvec`` callable; both run one jitted composite dispatch.
    """

    def __init__(self, a: sp.csr_matrix, plan: PrecisionPlan, *,
                 C: int = 32, sigma: int = 256):
        a = a.tocsr()
        a.sort_indices()
        self.n, self.m = a.shape
        self.nnz = int(a.nnz)
        self.pplan = plan
        self.C, self.sigma = C, sigma
        # coverage/overlap validation happens inside the composite build:
        # every row needs exactly one class slot for the gather epilogue
        self.cplan = kc.CompositePlan.from_classes(
            a, [(c.codec, c.D, c.rows) for c in plan.classes],
            C=C, sigma=sigma, name="mixed")

    # ------------------------------------------------------------------
    @property
    def blocks(self):
        """The per-class composite members (back-compat alias)."""
        return self.cplan.members

    def spmv(self, x: jnp.ndarray) -> jnp.ndarray:
        """y = A x with each row computed at its class's precision."""
        return self.cplan.spmv(x)

    def spmm(self, x: jnp.ndarray) -> jnp.ndarray:
        """Y = A X for X: [m, nb]."""
        return self.cplan.spmm(x)

    @property
    def matvec(self):
        return self.spmv

    @property
    def shape(self):
        return (self.n, self.m)

    # ------------------------------------------------------------------
    def memory_stats(self) -> dict:
        """Blended memory profile: total bytes, bytes/nnz, and the
        per-class breakdown (composite blend, historical key layout)."""
        st = self.cplan.memory_stats()
        return {
            "mixed_bytes": st["composite_bytes"],
            "bytes_per_nnz": st["composite_bytes"] / max(self.nnz, 1),
            "nnz": self.nnz, "n": self.n, "m": self.m,
            "classes": [{
                "codec": mb["codec"], "D": mb["D"], "rows": mb["rows"],
                "bytes": mb["bytes"], "nnz": mb["nnz"],
                "bytes_per_nnz": mb["bytes_per_nnz"],
            } for mb in st["members"]],
        }

    def warmup(self, nb: int = 0) -> "MixedPackSELL":
        """Trace the dispatch(es) ahead of the first real call."""
        self.cplan.warmup(nb=nb)
        return self
