"""On-disk autotune store for precision selections (DESIGN.md §8.4).

Selection costs an analysis pass plus probe matvecs per candidate; a
serving restart should not pay it again. The store is a single JSON file
mapping a **matrix fingerprint** — shape / nnz / bandwidth / row-degree
histogram / value-range hash, NOT the full contents — to:

* ``precision``: the serialized :class:`~repro.precision.select.PrecisionPlan`
  (with its machine-readable rationale), and
* ``retile``: the ``(sb, wb)`` tile winners per plan-cache key from the
  kernel autotuner (``benchmarks/bench_kernels.py`` →
  ``SpMVPlan.retile``), merged into the same entry so one lookup restores
  both decisions.

Writes are atomic (tmp file + ``os.replace``) so a crashed process never
truncates the store. The fingerprint hashes a deterministic sample of the
sparsity pattern and values: collisions between *different* matrices of
identical shape statistics are possible in principle but harmless — the
stored plan is a starting point whose probe guarantee can be re-validated
cheaply via ``validate=True``.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import tempfile
import warnings

import numpy as np
import scipy.sparse as sp

from repro.observe import metrics as _obs

from . import analyze as an
from . import select as se

try:
    import fcntl
except ImportError:  # non-POSIX: locking degrades to a no-op
    fcntl = None


@contextlib.contextmanager
def _file_lock(path: str):
    """Advisory cross-process lock on ``path + '.lock'`` (flock): two
    processes autotuning against one store serialize their
    read-modify-write cycles instead of losing each other's entries.
    No-op where fcntl is unavailable."""
    if fcntl is None:
        yield
        return
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    lockpath = path + ".lock"
    with open(lockpath, "w") as lf:
        fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lf.fileno(), fcntl.LOCK_UN)


def matrix_fingerprint(a: sp.csr_matrix) -> str:
    """Stable content fingerprint of a CSR matrix (hex, 16 chars)."""
    a = a.tocsr()
    a.sort_indices()
    h = hashlib.sha256()
    n, m = a.shape
    row_nnz = np.diff(a.indptr)
    # log2-binned row-degree histogram: shape of the sparsity structure
    hist = np.bincount(
        np.clip(np.log2(np.maximum(row_nnz, 1)).astype(np.int64), 0, 31),
        minlength=32)
    data = np.abs(a.data.astype(np.float64))
    nzmin = float(data[data > 0].min()) if np.any(data > 0) else 0.0
    stats = (n, m, int(a.nnz), float(data.max(initial=0.0)), nzmin,
             float(a.data.astype(np.float64).sum()))
    h.update(repr(stats).encode())
    h.update(hist.tobytes())
    # deterministic sample of the pattern + values
    step = max(1, a.nnz // 1024)
    h.update(np.ascontiguousarray(a.indices[::step]).tobytes())
    h.update(np.ascontiguousarray(
        a.data[::step].astype(np.float32)).tobytes())
    return h.hexdigest()[:16]


class PrecisionStore:
    """A JSON file of fingerprint → {precision, retile, meta} entries."""

    VERSION = 1

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        self._entries: dict = {}
        self.load()

    @classmethod
    def coerce(cls, store_or_path) -> "PrecisionStore":
        """Accept an existing store or a path to one (the polymorphic
        ``store=`` argument every integration point takes)."""
        if isinstance(store_or_path, cls):
            return store_or_path
        return cls(store_or_path)

    # -- persistence -------------------------------------------------------
    def _quarantine(self, why: str) -> dict:
        """Move an unreadable store aside (``*.corrupt``) and start fresh
        — a truncated or garbled file must not take selection down with
        it; the quarantined copy is kept for post-mortems."""
        quarantine = self.path + ".corrupt"
        try:
            os.replace(self.path, quarantine)
        except OSError:
            quarantine = "<could not move>"
        warnings.warn(
            f"precision store {self.path} is unreadable ({why}); "
            f"quarantined to {quarantine}, starting with an empty store",
            RuntimeWarning, stacklevel=4)
        _obs.inc("store.quarantine")
        return {}

    def _read_entries(self) -> dict:
        if not os.path.exists(self.path):
            return {}
        try:
            with open(self.path) as f:
                blob = json.load(f)
        except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
            return self._quarantine(str(e))
        if not isinstance(blob, dict) \
                or not isinstance(blob.get("entries", {}), dict):
            return self._quarantine("top-level JSON is not a store object")
        if blob.get("version", 1) != self.VERSION:
            raise ValueError(
                f"precision store {self.path} has version "
                f"{blob.get('version')}, expected {self.VERSION}")
        return blob.get("entries", {})

    def load(self) -> None:
        with _file_lock(self.path):
            self._entries = self._read_entries()

    def save(self) -> None:
        """Atomic write (tmp file + ``os.replace``) under the advisory
        ``*.lock`` file. Disk entries another process added since our
        load are merged back in first (ours win per key), so concurrent
        autotuners don't silently drop each other's selections."""
        d = os.path.dirname(os.path.abspath(self.path)) or "."
        os.makedirs(d, exist_ok=True)
        with _file_lock(self.path):
            for fp, ent in self._read_entries().items():
                mine = self._entries.setdefault(fp, {})
                for k, v in ent.items():
                    if k == "retile" and isinstance(mine.get(k), dict):
                        for rk, rv in v.items():
                            mine[k].setdefault(rk, rv)
                    else:
                        mine.setdefault(k, v)
            blob = {"version": self.VERSION, "entries": self._entries}
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(blob, f, indent=1, default=float)
                os.replace(tmp, self.path)
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise

    # -- precision plans ---------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, fingerprint: str) -> bool:
        return fingerprint in self._entries

    def get_plan(self, fingerprint: str,
                 mode: str = "global") -> se.PrecisionPlan | None:
        ent = self._entries.get(fingerprint)
        key = "precision" if mode == "global" else f"precision:{mode}"
        if ent is None or key not in ent:
            return None
        return se.PrecisionPlan.from_dict(ent[key])

    def put_plan(self, plan: se.PrecisionPlan, *,
                 fingerprint: str | None = None, save: bool = True) -> str:
        fp = fingerprint or plan.fingerprint
        if not fp:
            raise ValueError("need a fingerprint (plan.fingerprint unset)")
        key = ("precision" if plan.mode == "global"
               else f"precision:{plan.mode}")
        self._entries.setdefault(fp, {})[key] = plan.to_dict()
        if save:
            self.save()
        return fp

    def lookup_or_select(self, a: sp.csr_matrix, error_budget: float, *,
                         validate: bool = False, save: bool = True,
                         **select_kw):
        """Return ``(plan, from_store)``: the stored selection when the
        fingerprint hits (optionally re-validating its probe guarantee
        against the actual matrix), a fresh :func:`~repro.precision.select.
        select_codec` run (persisted) otherwise.

        A stored plan only counts as a hit when its selection semantics
        cover the request: same ``mode`` (a rows-mode plan's primary class
        is NOT budget-certified for the whole matrix and vice versa), a
        budget and safety at least as tight as requested, and — when the
        caller restricts ``candidates`` — every stored class inside the
        requested candidate set.
        """
        fp = matrix_fingerprint(a)
        mode = select_kw.get("mode", "global")
        safety = select_kw.get("safety", 0.5)
        plan = self.get_plan(fp, mode=mode)
        if plan is not None and "candidates" in select_kw:
            allowed = {tuple(c) for c in select_kw["candidates"]}
            allowed.add(("fp32", 0))     # the fallback is always legal
            if not all((c.codec, c.D) in allowed for c in plan.classes):
                plan = None              # stored plan uses excluded codecs
        if plan is not None and plan.primary.codec == "fp32":
            # fallback plan: certifies "nothing packed fits plan.budget",
            # which transfers to TIGHTER requests only — a looser budget
            # may admit a packed codec and must reselect
            budget_ok = error_budget <= plan.error_budget
        elif plan is not None:
            budget_ok = plan.error_budget <= error_budget
        else:
            budget_ok = False
        if (plan is not None and budget_ok
                and plan.rationale.get("safety", 1.0) <= safety):
            if not validate:
                _obs.inc("store.lookup", outcome="hit", mode=mode)
                return plan, True
            c = plan.primary
            err = (0.0 if c.codec == "fp32" else an.probe_error(
                a, c.codec, c.D,
                n_probes=select_kw.get("n_probes", 3),
                seed=select_kw.get("seed", 0) + 1))
            if err <= error_budget:
                _obs.inc("store.lookup", outcome="hit", mode=mode)
                return plan, True
            # stale entry (fingerprint collision / matrix drift): reselect
        _obs.inc("store.lookup", outcome="miss", mode=mode)
        plan = se.select_codec(a, error_budget, fingerprint=fp, **select_kw)
        self.put_plan(plan, fingerprint=fp, save=save)
        return plan, False

    # -- retile winners ----------------------------------------------------
    @staticmethod
    def _backend(backend: str | None) -> str:
        """The accelerator qualifier for retile keys. Lazy: jax is only
        touched when no explicit ``backend=`` is given, and a failure to
        resolve one degrades to ``'unknown'`` rather than raising inside
        a store write."""
        if backend is not None:
            return str(backend)
        try:
            import jax
            return jax.default_backend()
        except Exception:
            return "unknown"

    def put_retile(self, fingerprint: str, key: str, tiles, *,
                   backend: str | None = None, save: bool = True) -> None:
        """Record kernel-autotune ``(sb, wb)`` or ``(sb, wb, wr)`` winners
        under a plan key (e.g. ``'plan_e8m8'`` or a bucket signature).

        Winners are stored under a backend-qualified key
        (``'<key>@<jax.default_backend()>'``): tile/width choices tuned
        on a CPU interpret sweep must never be applied to a TPU/GPU plan
        (and vice versa). ``backend=`` overrides the qualifier."""
        bk = self._backend(backend)
        ent = self._entries.setdefault(fingerprint, {})
        ent.setdefault("retile", {})[f"{key}@{bk}"] = [
            [int(v) for v in t] for t in tiles]
        if save:
            self.save()

    def get_retile(self, fingerprint: str, key: str, *,
                   backend: str | None = None):
        """Backend-qualified lookup with read-compatible migration:
        legacy un-qualified entries (written before winners were keyed
        per backend) still resolve when no qualified entry shadows
        them."""
        ent = self._entries.get(fingerprint, {})
        retile = ent.get("retile", {})
        tiles = retile.get(f"{key}@{self._backend(backend)}")
        if tiles is None:
            tiles = retile.get(key)      # legacy un-keyed entry
        return None if tiles is None else [tuple(t) for t in tiles]

    def apply_retile(self, fingerprint: str, key: str, plan, *,
                     backend: str | None = None) -> bool:
        """Install stored tile winners into an
        :class:`~repro.kernels.plan.SpMVPlan`; True when applied."""
        tiles = self.get_retile(fingerprint, key, backend=backend)
        if tiles is None or len(tiles) != len(plan.tiles):
            _obs.inc("store.retile", applied="no")
            return False
        plan.retile(tiles)
        _obs.inc("store.retile", applied="yes")
        return True


# ---------------------------------------------------------------------------
# Per-shard selection (distributed composites, DESIGN.md §9.3)
# ---------------------------------------------------------------------------


def shard_fingerprints(a: sp.csr_matrix, n_shards: int) -> list[str]:
    """Per-row-shard content fingerprints: the distributed layer's store
    key. Shards are the same balanced contiguous row blocks the
    partitioner produces, so a restart with the same fleet size hits the
    same entries."""
    from repro.distributed.partition import partition_rows

    a = a.tocsr()
    part = partition_rows(a.shape[0], n_shards)
    return [matrix_fingerprint(a[part.rows_of(p)[0]:part.rows_of(p)[1]])
            for p in range(n_shards)]


def select_codec_per_shard(a: sp.csr_matrix, n_shards: int,
                           error_budget: float, *, store=None,
                           **select_kw):
    """Global-mode codec selection run per row shard — fingerprint +
    store lookup per shard — then coalesced to ONE fleet-wide class.

    SPMD dispatch traces one program for every shard, so the fleet must
    agree on a codec; the coalescing rule is *most conservative wins*,
    certified per shard: distinct per-shard picks are tried most-accurate
    first (smallest a-priori ulp bound; the fp32 fallback dominates
    everything) and the fleet takes the first one whose measured probe
    error fits ``safety × budget`` on EVERY shard — a shard's pick can be
    range-infeasible on another shard (fp16 overflow, say), so the ulp
    ranking alone is not a certificate. No pick certifying everywhere →
    fp32. Each shard's selection (with its own fingerprint) is still
    recorded in ``store``, so a later repartition or per-shard-capable
    dispatch reuses the analyses.

    Returns ``(per_shard_plans, fleet_class)``.
    """
    from repro.distributed.partition import partition_rows

    from . import select as se_

    a = a.tocsr()
    part = partition_rows(a.shape[0], n_shards)
    fps = shard_fingerprints(a, n_shards)
    store = None if store is None else PrecisionStore.coerce(store)
    plans, subs = [], []
    for p in range(n_shards):
        r0, r1 = part.rows_of(p)
        sub = a[r0:r1]
        if sub.shape[0] == 0:
            plans.append(None)        # empty shard: no constraint
            continue
        subs.append(sub)
        if store is not None:
            plan, _ = store.lookup_or_select(sub, error_budget, **select_kw)
        else:
            plan = se_.select_codec(sub, error_budget, fingerprint=fps[p],
                                    **select_kw)
        plans.append(plan)

    threshold = select_kw.get("safety", 0.5) * error_budget
    n_probes = select_kw.get("n_probes", 3)
    seed = select_kw.get("seed", 0)
    picks = {(pl.primary.codec, pl.primary.D)
             for pl in plans if pl is not None}
    # one probe context per shard, shared across candidate certifications
    ctxs = [an._probe_context(sub, n_probes, seed + 1) for sub in subs]
    fleet = se_.PrecisionClass(*se_.FP32_CLASS)
    for codec, D in sorted(picks, key=lambda cd_: an.ulp_bound(*cd_)):
        if codec == "fp32":
            break                     # a shard fell back: fleet must too
        if all(an.probe_error(sub, codec, D, n_probes=n_probes,
                              seed=seed + 1, _ctx=ctx) <= threshold
               for sub, ctx in zip(subs, ctxs)):
            fleet = se_.PrecisionClass(codec, D)   # rows=None
            break
    return plans, fleet
