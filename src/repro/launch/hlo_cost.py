"""While-aware cost model over post-partition HLO text.

``compiled.cost_analysis()`` counts each while (scan) body ONCE, ignoring the
trip count — our models are scans-of-layers with scans-of-chunks inside, so
naive numbers are off by orders of magnitude. This module parses the
optimized HLO, recovers counted-loop trip counts, and aggregates
recursively:

    total(comp) = own_costs(comp)
                + Σ_child_call   total(child)          (full cost)
                + Σ_child_fusion flops(child)          (bytes at boundary)
                + Σ_child_while  trip(child) × total(body + cond)

``compiled.as_text()`` emits the NON-verbose HLO dialect: operands are bare
``%name`` references without shapes. We therefore build a symbol table
(name → shape) from every instruction's result shape plus every
computation's parameter declarations, and resolve operand shapes through it.

Costs tracked per computation:
  * dot FLOPs: 2 × |result| × Π(lhs contracting dims)   (lhs via symbols)
  * bytes: result + operands of top-level instructions; fusion bodies count
    FLOPs only (fused internals live in registers; the fusion instruction
    itself contributes its boundary bytes)
  * collective bytes/counts by opcode (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), result-shape sized

This is the per-device cost of the SPMD-partitioned module: exactly the
quantity the roofline terms need.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_PARAM_DECL = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*"
                         r"\[[0-9,]*\](?:\{[^}]*\})?))")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRANSCENDENTAL = ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "sine", "cosine", "logistic", "erf", "atan2")
_SKIP_BYTES = ("parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "copy-start", "copy-done", "after-all",
               "partition-id", "replica-id", "iota", "opt-barrier")


def _shapes_in(text: str):
    """All (dtype, dims) array shapes literally present in ``text``."""
    out = []
    for d, dims in _SHAPE_RE.findall(text):
        if d not in _DTYPE_BYTES:
            continue
        sizes = [int(x) for x in dims.split(",")] if dims else []
        out.append((d, sizes))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for d, sizes in shapes:
        n = 1
        for s in sizes:
            n *= s
        total += n * _DTYPE_BYTES[d]
    return total


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: [0.0, 0]))
    whiles: list = dataclasses.field(default_factory=list)  # (cond, body)
    calls: list = dataclasses.field(default_factory=list)        # full cost
    fusion_calls: list = dataclasses.field(default_factory=list)  # flops only
    consts: dict = dataclasses.field(default_factory=dict)   # name -> int
    compare_ops: list = dataclasses.field(default_factory=list)


class _Parsed:
    def __init__(self):
        self.comps: dict[str, CompCost] = {}
        self.symbols: dict[str, list] = {}   # name -> [(dtype, dims), ...]
        self.entry: str | None = None

    def sym_bytes(self, name: str) -> int:
        return _nbytes(self.symbols.get(name, []))

    def sym_first(self, name: str):
        shapes = self.symbols.get(name)
        return shapes[0] if shapes else None


def _opcode_of(rhs: str) -> str | None:
    """The opcode is the first identifier followed by '(' after the result
    shape(s). Result shapes never contain '(' except tuple results, which
    are wrapped in parens at the very start."""
    pos = 0
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    pos = i + 1
                    break
    m = re.search(r"([a-z][a-z0-9\-]*)\(", rhs[pos:])
    return m.group(1) if m else None


def _operand_names(rhs: str, opcode: str) -> list[str]:
    """%name references inside the opcode's argument parens."""
    try:
        inner = rhs.split(opcode + "(", 1)[1]
    except IndexError:
        return []
    depth, end = 1, len(inner)
    for i, ch in enumerate(inner):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND.findall(inner[:end])


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LEGACY_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(rhs: str) -> int:
    m = _GROUPS_RE.search(rhs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LEGACY_RE.search(rhs)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2  # unknown: assume the smallest non-trivial group


def _wire_bytes(opcode: str, result_bytes: int, rhs: str) -> float:
    """Per-device ICI wire traffic of one collective, ring-algorithm model.

    result_bytes is the (per-device) result shape size. With group size N:
      all-gather      result is the gathered (global) tensor -> (N-1)/N x R
      reduce-scatter  result is one shard; input = N x R     -> (N-1)   x R
      all-reduce      ring RS + AG on the full payload       -> 2(N-1)/N x R
      all-to-all      each device sends (N-1)/N of its data  -> (N-1)/N x R
      collective-permute  point-to-point                     -> 1 x R
    """
    n = _group_size(rhs)
    if opcode == "all-gather":
        return result_bytes * (n - 1) / n
    if opcode == "reduce-scatter":
        return result_bytes * (n - 1)
    if opcode == "all-reduce":
        return result_bytes * 2 * (n - 1) / n
    if opcode == "all-to-all":
        return result_bytes * (n - 1) / n
    return float(result_bytes)


def parse(hlo: str) -> _Parsed:
    p = _Parsed()
    cur: CompCost | None = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and "->" in line:
            hdr = _COMP_HDR.match(line)
            if hdr:
                name = hdr.group(2)
                cur = CompCost()
                p.comps[name] = cur
                if hdr.group(1):
                    p.entry = name
                # parameter declarations carry shapes in both dialects
                for pname, pshape in _PARAM_DECL.findall(hdr.group(3)):
                    p.symbols[pname] = _shapes_in(pshape)
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # strip metadata/backend_config tails (they can contain shape-like
        # or %name-like text)
        rhs_core = re.split(r",\s*(?:metadata=|backend_config=|"
                            r"frontend_attributes=)", rhs)[0]

        opcode = _opcode_of(rhs_core)
        if opcode is None:
            continue

        # result shape(s): everything before the opcode token
        head = rhs_core.split(opcode + "(", 1)[0]
        res_shapes = _shapes_in(head)
        p.symbols[name] = res_shapes

        cm = re.match(r"^s32\[\]\s+constant\((\d+)\)", rhs_core)
        if cm:
            cur.consts[name] = int(cm.group(1))

        if opcode == "while":
            cnd = re.search(r"condition=%?([\w.\-]+)", rhs)
            bdy = re.search(r"body=%?([\w.\-]+)", rhs)
            if cnd and bdy:
                cur.whiles.append((cnd.group(1), bdy.group(1)))
            continue

        if opcode == "compare":
            ops = _operand_names(rhs_core, opcode)
            cur.compare_ops.append(ops)

        callees = [c.group(1) for c in re.finditer(
            r"(?:calls|to_apply)=%?([\w.\-]+)", rhs)]
        if opcode == "conditional":
            for cm3 in re.finditer(
                    r"(?:true_computation|false_computation|"
                    r"branch_computations)={?%?([\w.,%\- ]+?)}", rhs):
                for nm in re.split(r"[,\s]+", cm3.group(1)):
                    nm = nm.strip().lstrip("%")
                    if nm:
                        callees.append(nm)
        if opcode == "fusion":
            cur.fusion_calls.extend(callees)
        elif callees and opcode in ("call", "conditional", "custom-call",
                                    "async-start"):
            cur.calls.extend(callees)
        # reduce/scatter/map/sort to_apply bodies are scalar lambdas; their
        # cost is negligible and already reflected at the boundary.

        if opcode in COLLECTIVES:
            nbytes = _nbytes(res_shapes)
            wire = _wire_bytes(opcode, nbytes, rhs)
            cur.coll[opcode][0] += wire
            cur.coll[opcode][1] += 1
            cur.bytes += 2 * nbytes   # read shard + write result
            continue

        if opcode == "dot":
            n_res = 1
            if res_shapes:
                for s in res_shapes[0][1]:
                    n_res *= s
            ops = _operand_names(rhs_core, opcode)
            cdims = re.search(r"lhs_contracting_dims={([0-9,]*)}", rhs)
            k = 1
            lhs = p.sym_first(ops[0]) if ops else None
            if cdims is not None and lhs is not None and cdims.group(1):
                for idx in cdims.group(1).split(","):
                    k *= lhs[1][int(idx)]
            cur.flops += 2.0 * n_res * k
            cur.bytes += _nbytes(res_shapes) + sum(p.sym_bytes(o)
                                                   for o in ops)
            continue

        if opcode in _SKIP_BYTES:
            continue

        ops = _operand_names(rhs_core, opcode)
        cur.bytes += _nbytes(res_shapes) + sum(p.sym_bytes(o) for o in ops)
        if opcode in _TRANSCENDENTAL and res_shapes:
            n = 1
            for s in res_shapes[0][1]:
                n *= s
            cur.transcendentals += n
    return p


def _trip_count(p: _Parsed, cond_name: str) -> int:
    """Trip count from the condition computation: the constant operand of
    its compare instruction (fallback: max s32[] constant in the comp)."""
    c = p.comps.get(cond_name)
    if c is None:
        return 1
    for ops in c.compare_ops:
        for o in ops:
            if o in c.consts:
                return max(c.consts[o], 1)
    if c.consts:
        return max(max(c.consts.values()), 1)
    return 1


def aggregate(hlo: str, entry: str | None = None) -> dict:
    p = parse(hlo)
    if not p.comps:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {},
                "collective_bytes": 0.0, "transcendentals": 0.0}
    entry = entry or p.entry or next(iter(p.comps))

    memo: dict[str, tuple] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        c = p.comps.get(name)
        if c is None or depth > 64:
            return (0.0, 0.0, 0.0, {})
        fl, by, tr = c.flops, c.bytes, c.transcendentals
        coll = {k: [v[0], v[1]] for k, v in c.coll.items()}
        memo[name] = (fl, by, tr, coll)  # provisional (guards cycles)
        for callee in c.calls:
            f2, b2, t2, c2 = total(callee, depth + 1)
            fl += f2
            by += b2
            tr += t2
            for k, v in c2.items():
                coll.setdefault(k, [0.0, 0])
                coll[k][0] += v[0]
                coll[k][1] += v[1]
        for callee in c.fusion_calls:   # flops only: bytes at boundary
            f2, _, t2, c2 = total(callee, depth + 1)
            fl += f2
            tr += t2
            for k, v in c2.items():
                coll.setdefault(k, [0.0, 0])
                coll[k][0] += v[0]
                coll[k][1] += v[1]
        for cnd, bdy in c.whiles:
            trip = _trip_count(p, cnd)
            f2, b2, t2, c2 = total(bdy, depth + 1)
            fc, bc, tc, cc = total(cnd, depth + 1)
            fl += trip * (f2 + fc)
            by += trip * (b2 + bc)
            tr += trip * (t2 + tc)
            for k, v in list(c2.items()) + list(cc.items()):
                coll.setdefault(k, [0.0, 0])
                coll[k][0] += trip * v[0]
                coll[k][1] += trip * v[1]
        memo[name] = (fl, by, tr, coll)
        return memo[name]

    fl, by, tr, coll = total(entry)
    coll_total = sum(v[0] for v in coll.values())
    return {
        "flops": fl,
        "bytes": by,
        "transcendentals": tr,
        "collectives": {k: {"bytes": v[0], "count": v[1]}
                        for k, v in coll.items()},
        "collective_bytes": coll_total,
    }
