"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init.

Production target: TPU v5e pods, 256 chips each in a 16×16 (data, model)
mesh; ``multi_pod=True`` adds the leading ``pod`` axis (2 pods = 512 chips).
The ``pod`` axis participates in data parallelism (gradient psum crosses the
inter-pod DCI; see the E8MY gradient-compression option for that link).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
