"""Training launcher: ``--arch <id>`` selects an assigned architecture (or
its reduced smoke variant), builds the mesh from the local topology, and
runs the fault-tolerant Trainer.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduce \
        --steps 20 --seq-len 128 --global-batch 4

On a real TPU fleet the same entry point runs under multi-host jax.distributed
initialization; the mesh axes and logical specs are identical (DESIGN.md §5).
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.optim import OptConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--reduce", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--microbatch", type=int, default=None,
                    help="gradient-accumulation microbatch size")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-compression", type=int, default=None)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduce:
        cfg = configs.reduce(cfg)
    print(f"[launch] {cfg.name} ({cfg.family}) "
          f"~{cfg.param_count() / 1e6:.1f}M params on "
          f"{jax.device_count()} device(s)")
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, log_every=max(args.steps // 10, 1),
        seq_len=args.seq_len, global_batch=args.global_batch,
        microbatch=args.microbatch,
        data_axis=args.data_axis, model_axis=args.model_axis,
        grad_compression=args.grad_compression)
    opt = OptConfig(lr_peak=args.lr, warmup=max(args.steps // 10, 1),
                    total_steps=args.steps)
    trainer = Trainer(cfg, opt, tcfg)
    trainer.run()
    print(f"[launch] done; checkpoints: {trainer.ckpt.steps()}")


if __name__ == "__main__":
    main()
