"""Serving launcher: continuous-batching decode engine for an assigned
architecture (reduced config on CPU), fed with synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --requests 8 --slots 4 --max-new 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import transformer as tfm
from repro.serving import DecodeEngine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.reduce(configs.get(args.arch))
    if cfg.family == "encdec":
        raise SystemExit("enc-dec serving needs encoder inputs; use the "
                         "engine API directly (see examples/serve_sparse.py)")
    params, _ = tfm.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = DecodeEngine(cfg, params, ServeConfig(
        slots=args.slots, max_len=args.max_len,
        temperature=args.temperature, seed=args.seed))
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        plen = int(rng.integers(4, 12))
        eng.submit(rng.integers(1, cfg.vocab, size=plen), args.max_new)
    eng.run()
    st = eng.stats()
    print(f"[serve] {st['requests']} requests, {st['tokens']} tokens, "
          f"{st['tokens_per_s']:.2f} tok/s, "
          f"mean TTFT {st['mean_ttft_s'] * 1e3:.0f} ms, "
          f"mean latency {st['mean_latency_s'] * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
