import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh, print memory/cost analysis, dump roofline terms.

MUST be run as its own process (the two lines above must execute before any
jax initialization):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import configs                    # noqa: E402
from repro.launch import hlo_cost as hc      # noqa: E402
from repro.launch import roofline as rl      # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (batch_spec_tree, cache_spec_tree,  # noqa: E402
                                make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import SHAPES, cell_applicable, io_spec  # noqa: E402
from repro.models import transformer as tfm  # noqa: E402
from repro.optim import OptConfig  # noqa: E402
from repro.parallel import tree_shardings_shaped  # noqa: E402


def _abstract_state(cfg):
    shapes, _ = tfm.abstract_params(cfg)
    f32 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), shapes)
    from repro.optim.adamw import TrainState
    return TrainState(jax.ShapeDtypeStruct((), jnp.int32), f32, f32, f32)


def _lower_cell(cfg, shape, mesh, pod_wire=None, microbatch=None):
    """Build + lower the jitted step for one (arch, shape) on ``mesh``."""
    if shape.kind == "train":
        step, specs, zspecs = make_train_step(cfg, OptConfig(),
                                              pod_wire=pod_wire,
                                              microbatch=microbatch)
        state = _abstract_state(cfg)
        batch = io_spec.train_batch_spec(cfg, shape)
        from repro.optim.adamw import TrainState
        state_specs = TrainState(P(), zspecs, zspecs, zspecs)
        state_sh = tree_shardings_shaped(mesh, state_specs, state)
        in_sh = (state_sh,
                 tree_shardings_shaped(mesh, batch_spec_tree(batch), batch))
        out_sh = (state_sh,
                  tree_shardings_shaped(
                      mesh, {"loss": P()},
                      {"loss": jax.ShapeDtypeStruct((), jnp.float32)}))
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0,))
        return jitted.lower(state, batch)
    if shape.kind == "prefill":
        step, specs = make_prefill_step(cfg, shape.seq_len)
        params, _ = tfm.abstract_params(cfg)
        batch = io_spec.prefill_batch_spec(cfg, shape)
        cache_shape = jax.eval_shape(
            lambda: tfm.init_cache(
                cfg, shape.global_batch, shape.seq_len,
                enc_len=(shape.seq_len // 4
                         if cfg.frontend == "audio_stub" else 0)))
        logits_shape = jax.ShapeDtypeStruct(
            (shape.global_batch, 1, cfg.vocab_padded), jnp.float32)
        in_sh = (tree_shardings_shaped(mesh, specs, params),
                 tree_shardings_shaped(mesh, batch_spec_tree(batch), batch))
        out_sh = (tree_shardings_shaped(
            mesh, P(("pod", "data"), None, "model"), logits_shape),
            tree_shardings_shaped(mesh, cache_spec_tree(cfg, cache_shape),
                                  cache_shape))
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        return jitted.lower(params, batch)
    # decode
    step, specs = make_decode_step(cfg)
    params, _ = tfm.abstract_params(cfg)
    tok_spec, cache_shape = io_spec.decode_spec(cfg, shape)
    cache_sh = tree_shardings_shaped(
        mesh, cache_spec_tree(cfg, cache_shape), cache_shape)
    tok_sh = tree_shardings_shaped(
        mesh, P(("pod", "data"), None), tok_spec["tokens"])
    in_sh = (tree_shardings_shaped(mesh, specs, params), tok_sh, cache_sh)
    out_sh = (tok_sh, cache_sh)
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(2,))
    return jitted.lower(params, tok_spec["tokens"], cache_shape)


def compile_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 pod_wire=None, microbatch=None):
    """Lower + compile one cell; returns (rec, compiled). Raises on error
    (the sweep wrapper run_cell catches and records)."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        lowered = _lower_cell(cfg, shape, mesh, pod_wire=pod_wire,
                              microbatch=microbatch)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["memory_analysis"] = _mem_dict(compiled.memory_analysis())
    return rec, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        with mesh:
            lowered = _lower_cell(cfg, shape, mesh)
            compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)

        mem = compiled.memory_analysis()
        rec["memory_analysis"] = _mem_dict(mem)
        cost = compiled.cost_analysis()
        rec["cost_xla"] = {k: cost[k] for k in ("flops", "bytes accessed")
                           if k in cost}
        # while-aware cost model: XLA's cost_analysis counts scan bodies
        # ONCE (ignoring trip count); our models are scans-of-layers, so we
        # re-aggregate from the optimized HLO with trip-count expansion.
        hlo = compiled.as_text()
        agg = hc.aggregate(hlo)
        rec["cost"] = {"flops": agg["flops"], "bytes accessed": agg["bytes"]}
        rec["collectives"] = {k: v for k, v in agg["collectives"].items()
                              if v["count"]}
        rec["roofline"] = rl.roofline_terms(
            rec["cost"], agg["collective_bytes"],
            rl.model_flops(cfg, shape), n_chips)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            out[f] = int(getattr(mem, f))
        except Exception:
            pass
    if out:
        per_dev = out.get("argument_size_in_bytes", 0) + \
            out.get("temp_size_in_bytes", 0) + \
            out.get("output_size_in_bytes", 0) - \
            out.get("alias_size_in_bytes", 0)
        out["live_bytes_per_device"] = per_dev
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        archs = list(configs.ARCH_IDS)
        shapes = list(SHAPES)
        meshes = [False, True] if args.both_meshes or not args.multi_pod \
            else [True]
        if not args.both_meshes:
            meshes = [args.multi_pod]
        for a in archs:
            for s in shapes:
                for mp in meshes:
                    cells.append((a, s, mp))
    else:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    results = []
    for a, s, mp in cells:
        rec = run_cell(a, s, mp)
        results.append(rec)
        tag = f"{a} × {s} × {rec['mesh']}"
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"[ok]   {tag}: compile {rec['compile_s']}s, "
                  f"dominant={r['dominant']}, "
                  f"t=(C {r['t_compute_s']:.2e}, M {r['t_memory_s']:.2e}, "
                  f"X {r['t_collective_s']:.2e})s, "
                  f"roofline_frac={r['roofline_fraction']:.3f}")
            print(f"       memory: {rec['memory_analysis']}")
        elif rec["status"] == "skipped":
            print(f"[skip] {tag}: {rec['reason']}")
        else:
            print(f"[ERR]  {tag}: {rec['error']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_err = sum(r["status"] == "error" for r in results)
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
